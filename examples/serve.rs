//! Serve: drive the division service with an open-loop synthetic load
//! and report latency/throughput — the "coordinator as a product" demo.
//!
//! Requests go through the typed API (`DivRequest` bit-pattern lanes +
//! format + rounding); `--format mixed` interleaves all four formats to
//! exercise per-`(Op, Format, Rounding)` batch keying.
//!
//! ```bash
//! cargo run --release --example serve -- --backend native --seconds 3
//! cargo run --release --example serve -- --format mixed --rounding up
//! cargo run --release --example serve -- --backend pjrt          # needs artifacts
//! ```

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use tsdiv::coordinator::{BackendChoice, DivRequest, DivisionService, ServiceConfig, SubmitError};
use tsdiv::fp::{Format, Rounding, ALL_FORMATS};
use tsdiv::harness::gen_bits_batch;
use tsdiv::util::cli::Command;
use tsdiv::util::stats::Summary;
use tsdiv::util::table::{sig, Align, Table};

fn main() {
    let cmd = Command::new("serve", "open-loop load against the division service")
        .opt_choice(
            "backend",
            "native",
            &["native", "native-ilm", "pjrt"],
            "worker backend",
        )
        .opt_choice(
            "format",
            "f32",
            &["f16", "bf16", "f32", "f64", "mixed"],
            "request operand format",
        )
        .opt_choice(
            "rounding",
            "nearest",
            &["nearest", "zero", "up", "down"],
            "rounding mode",
        )
        .opt("seconds", "3", "load duration")
        .opt("clients", "4", "client threads")
        .opt("request-lanes", "64", "divisions per request")
        .opt("max-batch", "4096", "coalescing budget (f32-equivalent lanes; cost-weighted per format)")
        .opt("spare-divisor", "4", "budget divisor under spare capacity (1 disables)")
        .opt("workers", "2", "worker threads")
        .opt("shards", "", "submission shards (empty = one per worker)");
    let args = match cmd.parse(std::env::args().skip(1)) {
        Ok(a) => a,
        Err(help) => {
            eprintln!("{help}");
            return;
        }
    };
    let backend = match args.get_or("backend", "native") {
        "pjrt" => {
            if !tsdiv::runtime::artifacts_available() {
                eprintln!("artifacts/ missing — run `make artifacts` first");
                std::process::exit(1);
            }
            BackendChoice::Pjrt
        }
        "native-ilm" => BackendChoice::Native {
            order: 5,
            ilm_iterations: Some(8),
        },
        _ => BackendChoice::Native {
            order: 5,
            ilm_iterations: None,
        },
    };
    let seconds: u64 = args.parse_or("seconds", 3);
    let clients: usize = args.parse_or("clients", 4);
    let lanes: usize = args.parse_or("request-lanes", 64);
    let rm = Rounding::from_name(args.get_or("rounding", "nearest")).unwrap();
    let fmt_name = args.get_or("format", "f32").to_string();
    let formats: Arc<Vec<Format>> = Arc::new(match fmt_name.as_str() {
        "mixed" => ALL_FORMATS.to_vec(),
        name => vec![Format::from_name(name).unwrap()],
    });
    if backend == BackendChoice::Pjrt && (fmt_name != "f32" || rm != Rounding::NearestEven) {
        eprintln!("the pjrt backend serves f32 at nearest-even only");
        std::process::exit(1);
    }

    let shards: Option<usize> = match args.get("shards") {
        Some("") | None => None,
        Some(s) => Some(s.parse().unwrap_or_else(|_| {
            eprintln!("option --shards: cannot parse '{s}'");
            std::process::exit(1);
        })),
    };
    let svc = Arc::new(
        DivisionService::start(
            ServiceConfig {
                workers: args.parse_or("workers", 2),
                shards,
                max_batch: args.parse_or("max-batch", 4096),
                max_wait: Duration::from_micros(200),
                queue_capacity: 1 << 14,
                spare_divisor: args.parse_or("spare-divisor", 4),
            },
            backend,
        )
        .expect("service start"),
    );
    println!(
        "serving with backend={backend:?}, format={fmt_name}, rounding={}, \
         {clients} clients × {lanes} lanes/request, {seconds}s\n",
        rm.name()
    );

    let stop = Arc::new(AtomicBool::new(false));
    let mut handles = Vec::new();
    for cid in 0..clients {
        let svc = Arc::clone(&svc);
        let stop = Arc::clone(&stop);
        let formats = Arc::clone(&formats);
        handles.push(std::thread::spawn(move || {
            let mut lat = Summary::keeping_samples();
            let mut done = 0u64;
            let mut busy = 0u64;
            let mut req_no = 0u64;
            while !stop.load(Ordering::Relaxed) {
                let fmt = formats[(req_no % formats.len() as u64) as usize];
                let (a, b) = gen_bits_batch(fmt, lanes, 12, cid as u64 * 1_000_000 + req_no);
                req_no += 1;
                let t0 = Instant::now();
                match svc.submit_request(DivRequest::new(fmt, rm, a, b)) {
                    Ok(t) => {
                        t.wait().expect("division failed");
                        lat.push(t0.elapsed().as_secs_f64());
                        done += 1;
                    }
                    Err(SubmitError::Busy) => {
                        busy += 1;
                        std::thread::yield_now();
                    }
                    Err(e) => panic!("{e}"),
                }
            }
            (lat, done, busy)
        }));
    }
    std::thread::sleep(Duration::from_secs(seconds));
    stop.store(true, Ordering::Relaxed);

    let mut all = Summary::keeping_samples();
    let mut requests = 0u64;
    let mut busy = 0u64;
    for h in handles {
        let (lat, done, b) = h.join().unwrap();
        // Per-client mean goes into the cross-client summary; the exact
        // p50/p99 come from the service's own latency sink below.
        requests += done;
        busy += b;
        if lat.count() > 0 {
            all.push(lat.mean());
        }
    }
    let m = svc.metrics();

    let mut t = Table::new("serve results", &["metric", "value"]).aligns(&[Align::Left, Align::Right]);
    t.row(&["requests completed".into(), requests.to_string()]);
    t.row(&["lanes served".into(), m.lanes.to_string()]);
    t.row(&["throughput".into(), format!("{} div/s", sig(m.lanes as f64 / seconds as f64, 4))]);
    t.row(&["requests/s".into(), sig(requests as f64 / seconds as f64, 4)]);
    t.row(&["backend batches".into(), m.batches.to_string()]);
    t.row(&["mean lanes/batch".into(), sig(m.mean_batch_lanes(), 4)]);
    t.row(&["cost units dispatched".into(), m.cost_units.to_string()]);
    t.row(&["mean cost/batch".into(), sig(m.mean_batch_cost(), 4)]);
    t.row(&["service latency p50".into(), format!("{:.3} ms", m.latency_p50 * 1e3)]);
    t.row(&["service latency p99".into(), format!("{:.3} ms", m.latency_p99 * 1e3)]);
    t.row(&["batch latency p50".into(), format!("{:.3} ms", m.batch_latency_p50 * 1e3)]);
    t.row(&["batch latency p99".into(), format!("{:.3} ms", m.batch_latency_p99 * 1e3)]);
    t.row(&["shards".into(), m.shards.to_string()]);
    t.row(&["worker parks / noops".into(), format!("{} / {}", m.parks, m.noops)]);
    t.row(&["batches stolen (raids)".into(), format!("{} ({})", m.steals, m.steal_operations)]);
    t.row(&["worker busy time".into(), format!("{:.3} s", m.busy_seconds)]);
    t.row(&["backpressure rejections".into(), busy.to_string()]);
    t.row(&["worker failures".into(), m.failures.to_string()]);
    t.print();

    match Arc::try_unwrap(svc) {
        Ok(s) => s.shutdown(),
        Err(_) => {}
    }
}
