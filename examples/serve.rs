//! Serve: drive the division service with an open-loop synthetic load
//! and report latency/throughput — the "coordinator as a product" demo.
//!
//! ```bash
//! cargo run --release --example serve -- --backend native --seconds 3
//! cargo run --release --example serve -- --backend pjrt          # needs artifacts
//! ```

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use tsdiv::coordinator::{BackendChoice, DivisionService, ServiceConfig, SubmitError};
use tsdiv::util::cli::Command;
use tsdiv::util::rng::Rng;
use tsdiv::util::stats::Summary;
use tsdiv::util::table::{sig, Align, Table};

fn main() {
    let cmd = Command::new("serve", "open-loop load against the division service")
        .opt("backend", "native", "native | native-ilm | pjrt")
        .opt("seconds", "3", "load duration")
        .opt("clients", "4", "client threads")
        .opt("request-lanes", "64", "divisions per request")
        .opt("max-batch", "4096", "coalescing budget (lanes)")
        .opt("workers", "2", "worker threads");
    let args = match cmd.parse(std::env::args().skip(1)) {
        Ok(a) => a,
        Err(help) => {
            eprintln!("{help}");
            return;
        }
    };
    let backend = match args.get_or("backend", "native") {
        "pjrt" => {
            if !tsdiv::runtime::artifacts_available() {
                eprintln!("artifacts/ missing — run `make artifacts` first");
                std::process::exit(1);
            }
            BackendChoice::Pjrt
        }
        "native-ilm" => BackendChoice::Native {
            order: 5,
            ilm_iterations: Some(8),
        },
        _ => BackendChoice::Native {
            order: 5,
            ilm_iterations: None,
        },
    };
    let seconds: u64 = args.parse_or("seconds", 3);
    let clients: usize = args.parse_or("clients", 4);
    let lanes: usize = args.parse_or("request-lanes", 64);

    let svc = Arc::new(
        DivisionService::start(
            ServiceConfig {
                workers: args.parse_or("workers", 2),
                max_batch: args.parse_or("max-batch", 4096),
                max_wait: Duration::from_micros(200),
                queue_capacity: 1 << 14,
            },
            backend,
        )
        .expect("service start"),
    );
    println!(
        "serving with backend={:?}, {clients} clients × {lanes} lanes/request, {seconds}s\n",
        backend
    );

    let stop = Arc::new(AtomicBool::new(false));
    let mut handles = Vec::new();
    for cid in 0..clients {
        let svc = Arc::clone(&svc);
        let stop = Arc::clone(&stop);
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(cid as u64 + 1);
            let mut lat = Summary::keeping_samples();
            let mut done = 0u64;
            let mut busy = 0u64;
            while !stop.load(Ordering::Relaxed) {
                let a: Vec<f32> = (0..lanes).map(|_| rng.f32_log_uniform(-12, 12)).collect();
                let b: Vec<f32> = (0..lanes).map(|_| rng.f32_log_uniform(-12, 12)).collect();
                let t0 = Instant::now();
                match svc.submit(a, b) {
                    Ok(t) => {
                        t.wait().expect("division failed");
                        lat.push(t0.elapsed().as_secs_f64());
                        done += 1;
                    }
                    Err(SubmitError::Busy) => {
                        busy += 1;
                        std::thread::yield_now();
                    }
                    Err(e) => panic!("{e}"),
                }
            }
            (lat, done, busy)
        }));
    }
    std::thread::sleep(Duration::from_secs(seconds));
    stop.store(true, Ordering::Relaxed);

    let mut all = Summary::keeping_samples();
    let mut requests = 0u64;
    let mut busy = 0u64;
    for h in handles {
        let (lat, done, b) = h.join().unwrap();
        // Per-client mean goes into the cross-client summary; the exact
        // p50/p99 come from the service's own latency sink below.
        requests += done;
        busy += b;
        if lat.count() > 0 {
            all.push(lat.mean());
        }
    }
    let m = svc.metrics();

    let mut t = Table::new("serve results", &["metric", "value"]).aligns(&[Align::Left, Align::Right]);
    t.row(&["requests completed".into(), requests.to_string()]);
    t.row(&["lanes served".into(), m.lanes.to_string()]);
    t.row(&["throughput".into(), format!("{} div/s", sig(m.lanes as f64 / seconds as f64, 4))]);
    t.row(&["requests/s".into(), sig(requests as f64 / seconds as f64, 4)]);
    t.row(&["backend batches".into(), m.batches.to_string()]);
    t.row(&["mean lanes/batch".into(), sig(m.mean_batch_lanes(), 4)]);
    t.row(&["service latency p50".into(), format!("{:.3} ms", m.latency_p50 * 1e3)]);
    t.row(&["service latency p99".into(), format!("{:.3} ms", m.latency_p99 * 1e3)]);
    t.row(&["backpressure rejections".into(), busy.to_string()]);
    t.row(&["worker failures".into(), m.failures.to_string()]);
    t.print();

    match Arc::try_unwrap(svc) {
        Ok(s) => s.shutdown(),
        Err(_) => {}
    }
}
