//! Profiling driver for the divider hot path (used by the §Perf pass):
//!
//! ```bash
//! cargo build --release --example profile_div
//! perf record -F 999 ./target/release/examples/profile_div
//! perf report --stdio | head -20
//! ```

fn main() {
    use tsdiv::divider::{Divider, TaylorDivider};
    let mut d = TaylorDivider::paper_exact();
    let batch = tsdiv::harness::gen_batch(tsdiv::analysis::Workload::LogUniform, 4096, 9);
    let mut acc = 0u32;
    for _ in 0..3000 {
        for i in 0..batch.len() {
            acc ^= d.div_f32(batch.a[i], batch.b[i]).to_bits();
        }
    }
    println!("{acc}");
}
