//! Quickstart: divide numbers with the paper's architecture and watch
//! the Taylor-series converge.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use tsdiv::divider::{BackendKind, Divider, TaylorDivider};
use tsdiv::pla::SegmentTable;
use tsdiv::taylor::TaylorConfig;
use tsdiv::util::table::{sig, Align, Table};

fn main() {
    println!("tsdiv quickstart — {}\n", tsdiv::PAPER);

    // 1. The headline configuration: Table-I segments (8), order n = 5,
    //    60-bit datapath, exact fixed-point multiplies.
    let mut div = TaylorDivider::paper_exact();
    println!("divider: {}\n", div.name());

    let pairs = [
        (355.0f32, 113.0f32),
        (1.0, 3.0),
        (2.0, 7.0),
        (-10.0, 4.0),
        (6.02214e23, 1.602e-19),
        (1.0, 0.0),
        (0.0, 0.0),
    ];
    let mut t = Table::new("divisions", &["a", "b", "tsdiv a/b", "hardware a/b", "ulp Δ"])
        .aligns(&[Align::Right; 5]);
    for (a, b) in pairs {
        let q = div.div_f32(a, b);
        let hw = a / b;
        let ulp = tsdiv::fp::ulp_diff_f32(q, hw)
            .map(|u| u.to_string())
            .unwrap_or_else(|| "NaN".into());
        t.row(&[
            format!("{a:e}"),
            format!("{b:e}"),
            format!("{q:e}"),
            format!("{hw:e}"),
            ulp,
        ]);
    }
    t.print();

    // 2. Convergence: reciprocal error of 1/x after n Taylor iterations
    //    (paper §2: each added power of m sharpens the estimate).
    println!();
    let mut t = Table::new(
        "reciprocal of x = 1.37 vs Taylor order (8 segments)",
        &["order n", "reciprocal", "abs error", "error bits"],
    );
    for order in 0..=6 {
        let cfg = TaylorConfig {
            order,
            ..TaylorConfig::paper_default(60)
        };
        let mut be = tsdiv::powering::ExactMul::default();
        let mut eng = tsdiv::taylor::TaylorEngine::new(cfg, &mut be);
        let got = eng.reciprocal_f64(1.37);
        let err = (got - 1.0 / 1.37).abs();
        let bits = if err > 0.0 { -err.log2() } else { 60.0 };
        t.row(&[
            order.to_string(),
            format!("{got:.17}"),
            sig(err, 3),
            format!("{bits:.1}"),
        ]);
    }
    t.print();

    // 3. The same division with the ILM backend at different correction
    //    budgets (paper §4: accuracy is programmable).
    println!();
    let mut t = Table::new(
        "354.0 / 113.0 with the ILM backend",
        &["ILM corrections", "quotient", "rel error"],
    );
    for iters in [0u32, 1, 2, 4, 8, 16] {
        let mut d = TaylorDivider::paper_ilm(iters);
        let q = d.div_f32(354.0, 113.0);
        let rel = ((q as f64 - 354.0 / 113.0) / (354.0 / 113.0)).abs();
        t.row(&[iters.to_string(), format!("{q:.7}"), sig(rel, 3)]);
    }
    t.print();

    // 4. One-segment vs Table-I seed, order 17 vs 5 (paper §3).
    println!();
    let single = TaylorConfig {
        order: 17,
        frac_bits: 60,
        table: SegmentTable::build(&[1.0, 2.0], 60),
    };
    let mut d17 = TaylorDivider::new(single, BackendKind::Exact);
    let mut d5 = TaylorDivider::paper_exact();
    let (a, b) = (1.0f32, 1.0000001f32);
    println!(
        "worst-case-style division {a}/{b}:\n  1 segment, n=17 → {:e}\n  8 segments, n=5 → {:e}\n  hardware        → {:e}",
        d17.div_f32(a, b),
        d5.div_f32(a, b),
        a / b
    );
    println!("\nSee `tsdiv --help` (the CLI) and rust/benches/ for the full evaluation.");
}
