//! End-to-end driver: K-Means clustering served by the division unit.
//!
//! The paper's introduction motivates hardware FP division with exactly
//! this workload ("K-Means Clustering and QR Decomposition"). Here the
//! centroid updates (sum / count) run through the **coordinator
//! service** — batched, on the PJRT AOT artifact when `artifacts/` is
//! built (f32 divisions), otherwise on the staged-kernel datapath as
//! **bfloat16 fused scale-by-reciprocal requests**: one divisor per
//! centroid is inverted once and broadcast across its DIM sum lanes
//! (centroids tolerate bf16's 8-bit significand easily, and ML-shaped
//! traffic is exactly where bf16 shows up) — proving all layers, the
//! multi-format path, and the typed op axis compose end to end.
//!
//! ```bash
//! make artifacts && cargo run --release --example kmeans
//! ```

use std::time::{Duration, Instant};

use tsdiv::coordinator::{BackendChoice, DivRequest, DivisionService, ServiceConfig};
use tsdiv::fp::{decode_f32, encode_f32, Rounding, BF16};
use tsdiv::runtime::artifacts_available;
use tsdiv::util::rng::Rng;
use tsdiv::util::table::{sig, Align, Table};

const K: usize = 8;
const DIM: usize = 16;
const POINTS: usize = 20_000;
const MAX_ITERS: usize = 25;

fn main() {
    // The PJRT artifact serves f32/nearest divisions only; the local
    // path takes the centroid updates as bf16 scale-by-recip requests
    // through the kernel backend (the only local family that serves
    // the fused op) to exercise the typed op + format axes end to end.
    let (backend, use_bf16) = if artifacts_available() {
        println!("backend: PJRT (AOT JAX/Pallas artifact — L1+L2+L3 composed), f32 requests");
        (BackendChoice::Pjrt, false)
    } else {
        println!(
            "backend: staged-kernel datapath, bf16 scale-by-recip centroid \
             updates (run `make artifacts` for PJRT)"
        );
        (
            BackendChoice::Kernel {
                order: 5,
                kernel: tsdiv::kernel::KernelConfig::default(),
            },
            true,
        )
    };
    let svc = DivisionService::start(
        ServiceConfig {
            workers: 2,
            max_batch: 4096,
            max_wait: Duration::from_micros(300),
            queue_capacity: 1 << 14,
            ..ServiceConfig::default()
        },
        backend,
    )
    .expect("service start");

    // Synthetic blobs: K ground-truth centers, Gaussian-ish noise.
    let mut rng = Rng::new(2026);
    let mut centers = vec![[0.0f32; DIM]; K];
    for c in centers.iter_mut() {
        for v in c.iter_mut() {
            *v = (rng.f64_range(-10.0, 10.0)) as f32;
        }
    }
    let mut points = Vec::with_capacity(POINTS);
    let mut truth = Vec::with_capacity(POINTS);
    for _ in 0..POINTS {
        let c = rng.below(K as u64) as usize;
        truth.push(c);
        let mut p = [0.0f32; DIM];
        for d in 0..DIM {
            // Sum of 4 uniforms ≈ gaussian, σ≈0.6.
            let noise: f64 = (0..4).map(|_| rng.f64_range(-0.5, 0.5)).sum();
            p[d] = centers[c][d] + noise as f32;
        }
        points.push(p);
    }

    // Lloyd's algorithm; every division goes through the service.
    let mut est = vec![[0.0f32; DIM]; K];
    for (i, e) in est.iter_mut().enumerate() {
        *e = points[i * POINTS / K]; // spread initial guesses
    }
    let mut assign = vec![0usize; POINTS];
    let mut divisions_served = 0u64;
    let t0 = Instant::now();
    let mut inertia_log = Vec::new();

    for iter in 0..MAX_ITERS {
        // Assign step (pure arithmetic, no division).
        let mut inertia = 0.0f64;
        for (p, a) in points.iter().zip(assign.iter_mut()) {
            let mut best = (f32::INFINITY, 0usize);
            for (ci, c) in est.iter().enumerate() {
                let mut d2 = 0.0f32;
                for j in 0..DIM {
                    let d = p[j] - c[j];
                    d2 += d * d;
                }
                if d2 < best.0 {
                    best = (d2, ci);
                }
            }
            *a = best.1;
            inertia += best.0 as f64;
        }
        inertia_log.push(inertia);

        // Update step: centroid = sum / count — one batched request of
        // K·DIM divisions through the coordinator.
        let mut sums = vec![[0.0f64; DIM]; K];
        let mut counts = vec![0u32; K];
        for (p, &a) in points.iter().zip(&assign) {
            counts[a] += 1;
            for j in 0..DIM {
                sums[a][j] += p[j] as f64;
            }
        }
        let mut num = Vec::with_capacity(K * DIM);
        for ci in 0..K {
            for j in 0..DIM {
                num.push(sums[ci][j] as f32);
            }
        }
        divisions_served += num.len() as u64;
        // bf16 path: one fused scale-by-reciprocal request — K divisor
        // rows (the counts, inverted once each) broadcast across their
        // DIM sum lanes. Quotients decode back exactly (every bf16
        // value is an f32); centroids only steer the assignment step,
        // so bf16's ~3 significant decimal digits cost nothing against
        // blob spacing.
        let q: Vec<f32> = if use_bf16 {
            let lanes: Vec<u64> = num.iter().map(|&x| encode_f32(x, BF16)).collect();
            let divisors: Vec<u64> = counts
                .iter()
                .map(|&c| encode_f32(c.max(1) as f32, BF16))
                .collect();
            let req = DivRequest::scale_by_recip(BF16, Rounding::NearestEven, lanes, divisors);
            let resp = svc
                .divide_request_blocking(req)
                .expect("bf16 centroid scale-by-recip batch");
            resp.to_u16_bits()
                .expect("bfloat16 response")
                .iter()
                .map(|&b| decode_f32(b as u64, BF16))
                .collect()
        } else {
            let den: Vec<f32> = (0..K * DIM).map(|i| counts[i / DIM].max(1) as f32).collect();
            svc.divide_request_blocking(DivRequest::from_f32(&num, &den))
                .expect("centroid division batch")
                .to_f32()
                .expect("binary32 response")
        };
        for ci in 0..K {
            for j in 0..DIM {
                est[ci][j] = q[ci * DIM + j];
            }
        }

        let delta = if iter > 0 {
            (inertia_log[iter - 1] - inertia) / inertia_log[iter - 1]
        } else {
            1.0
        };
        println!(
            "iter {iter:>2}: inertia {:.1} (Δ {:.4}%)",
            inertia,
            delta * 100.0
        );
        if iter > 0 && delta.abs() < 1e-6 {
            break;
        }
    }
    let wall = t0.elapsed().as_secs_f64();

    // Evaluate: majority-vote cluster → truth mapping accuracy.
    let mut votes = vec![[0u32; K]; K];
    for (&a, &t) in assign.iter().zip(&truth) {
        votes[a][t] += 1;
    }
    let correct: u64 = votes
        .iter()
        .map(|row| *row.iter().max().unwrap() as u64)
        .sum();
    let accuracy = correct as f64 / POINTS as f64;

    let m = svc.metrics();
    println!();
    let mut t = Table::new("k-means end-to-end summary", &["metric", "value"])
        .aligns(&[Align::Left, Align::Right]);
    t.row(&["points × dims".into(), format!("{POINTS} × {DIM}")]);
    t.row(&["clusters".into(), K.to_string()]);
    let fmt_label = if use_bf16 {
        "bf16 (scale-by-recip requests)"
    } else {
        "f32"
    };
    t.row(&["division format".into(), fmt_label.into()]);
    t.row(&["iterations run".into(), inertia_log.len().to_string()]);
    t.row(&["final inertia".into(), sig(*inertia_log.last().unwrap(), 6)]);
    t.row(&["cluster accuracy (majority map)".into(), format!("{:.2}%", accuracy * 100.0)]);
    t.row(&["divisions served".into(), divisions_served.to_string()]);
    t.row(&["service batches".into(), m.batches.to_string()]);
    t.row(&["mean lanes/batch".into(), sig(m.mean_batch_lanes(), 4)]);
    t.row(&["request latency p50".into(), format!("{:.3} ms", m.latency_p50 * 1e3)]);
    t.row(&["request latency p99".into(), format!("{:.3} ms", m.latency_p99 * 1e3)]);
    t.row(&["wall time".into(), format!("{wall:.3} s")]);
    t.print();

    assert!(accuracy > 0.9, "clustering should recover the blobs");
    assert_eq!(m.failures, 0);
    svc.shutdown();
    println!("\nOK — all layers composed (see EXPERIMENTS.md §E2E for the recorded run).");
}
