//! Precision explorer: the full design space of the paper's divider —
//! Taylor order × segment count × ILM correction budget — with achieved
//! precision and hardware cost side by side.
//!
//! ```bash
//! cargo run --release --example precision_explorer
//! ```

use tsdiv::analysis::reciprocal_precision_bits;
use tsdiv::divider::TaylorDivider;
use tsdiv::fp::ulp_diff_f32;
use tsdiv::pla::{derive_segments, min_iterations_piecewise, SegmentTable};
use tsdiv::taylor::TaylorConfig;
use tsdiv::util::rng::Rng;
use tsdiv::util::table::{sig, Align, Table};

fn main() {
    // 1. Order × derivation-n: achieved reciprocal precision (exact muls).
    //    The diagonal (order == derivation n) is the paper's intended
    //    operating point; off-diagonal shows the waste/deficit.
    let mut t = Table::new(
        "achieved reciprocal precision (bits) — datapath F=60, exact multiplies",
        &["segments(n)", "order 2", "order 3", "order 5", "order 8"],
    )
    .aligns(&[Align::Left, Align::Right, Align::Right, Align::Right, Align::Right]);
    for derive_n in [2u32, 3, 5, 8] {
        let bounds = derive_segments(derive_n, 53).expect("segment derivation");
        let mut row = vec![format!("{} (n={derive_n})", bounds.len() - 1)];
        for order in [2u32, 3, 5, 8] {
            let cfg = TaylorConfig {
                order,
                frac_bits: 60,
                table: SegmentTable::build(&bounds, 60),
            };
            row.push(format!("{:.1}", reciprocal_precision_bits(&cfg, 600)));
        }
        t.row(&row);
    }
    t.print();
    println!("(row: segment table derived for n iterations; column: order actually run)\n");

    // 2. Analytic minimum iterations per partition (paper §3 procedure).
    let mut t = Table::new(
        "eq-(17) minimum iterations for 53-bit precision",
        &["partition", "segments", "min iterations"],
    )
    .aligns(&[Align::Left, Align::Right, Align::Right]);
    for (label, bounds) in [
        ("single segment [1,2]", vec![1.0, 2.0]),
        ("two segments at √2", vec![1.0, 2f64.sqrt(), 2.0]),
        ("Table I (n=5)", derive_segments(5, 53).expect("derivation")),
        ("n=3 partition", derive_segments(3, 53).expect("derivation")),
        ("n=8 partition", derive_segments(8, 53).expect("derivation")),
    ] {
        t.row(&[
            label.to_string(),
            (bounds.len() - 1).to_string(),
            min_iterations_piecewise(&bounds, 53).expect("iteration bound").to_string(),
        ]);
    }
    t.print();
    println!("(paper: 17 / 15 / 5 — our eq-(17) solver reproduces 17 and 5;\n the two-segment value is smaller than the paper's 15, see EXPERIMENTS.md E5)\n");

    // 3. ILM correction budget vs f32 division quality + hardware area.
    let mut rng = Rng::new(99);
    let samples: Vec<(f32, f32)> = (0..4000)
        .map(|_| (rng.f32_log_uniform(-10, 10), rng.f32_log_uniform(-10, 10)))
        .collect();
    let mut t = Table::new(
        "ILM budget: f32 division quality vs multiplier hardware",
        &["ILM corrections", "max ulp", "mean ulp", "exact %", "mult area (NAND2, w=24)"],
    );
    for iters in [0u32, 1, 2, 4, 8, 16] {
        let mut d = TaylorDivider::paper_ilm(iters);
        let mut max_u = 0u64;
        let mut sum_u = 0.0;
        let mut exact = 0u64;
        for &(a, b) in &samples {
            use tsdiv::divider::Divider;
            let q = d.div_f32(a, b);
            let u = ulp_diff_f32(q, a / b).unwrap_or(u64::MAX);
            max_u = max_u.max(u);
            sum_u += u as f64;
            exact += (u == 0) as u64;
        }
        // Iterative ILM reuses one block; pipelined would multiply area by
        // stages — report the pipelined cost as the paper's §7 option.
        let base = tsdiv::hw::ilm_unit(24).area();
        let piped = tsdiv::hw::cycles::pipeline_overhead(&tsdiv::hw::ilm_unit(24), 24, 1 + iters);
        t.row(&[
            iters.to_string(),
            max_u.to_string(),
            format!("{:.3}", sum_u / samples.len() as f64),
            format!("{:.1}", exact as f64 / samples.len() as f64 * 100.0),
            format!("{} (pipelined {})", sig(base, 5), sig(piped.area(), 5)),
        ]);
    }
    t.print();
    println!("\nOK — see rust/benches/ for the reproducible versions of these tables.");
}
