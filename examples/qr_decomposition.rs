//! QR decomposition via Modified Gram–Schmidt, with every division
//! executed by the paper's division unit (the second workload the
//! paper's introduction motivates).
//!
//! MGS normalizes each column as `q_k = v_k / r_kk` with
//! `r_kk = ‖v_k‖`, and back-substitution divides by the diagonal of R
//! when the factors solve `Ax = b`. The normalization goes through the
//! **coordinator service as binary16 fused-op requests**: an `Rsqrt`
//! request serves `1/√(norm²)` (r_kk is reconstructed client-side as
//! `norm² · rsqrt(norm²)`), then one `ScaleByRecip` row of N lanes
//! scales the column by `1/r_kk` — the divisor is inverted once and
//! broadcast, exactly the QR shape the fused op exists for.
//! Back-substitution runs on [`tsdiv::divider::TaylorDivider`]
//! directly. The example verifies ‖QR − A‖, orthogonality of Q, and
//! the solve residual at tolerances that account for f16's 11-bit
//! significand.
//!
//! ```bash
//! cargo run --release --example qr_decomposition
//! ```

use std::time::Duration;

use tsdiv::coordinator::{BackendChoice, DivRequest, DivisionService, ServiceConfig};
use tsdiv::divider::{Divider, TaylorDivider};
use tsdiv::fp::{decode_f32, encode_f32, Rounding, F16};
use tsdiv::util::rng::Rng;
use tsdiv::util::table::{sig, Align, Table};

const N: usize = 48; // A is N×N

struct Mat {
    n: usize,
    v: Vec<f32>,
}

impl Mat {
    fn zeros(n: usize) -> Self {
        Self { n, v: vec![0.0; n * n] }
    }
    fn at(&self, r: usize, c: usize) -> f32 {
        self.v[r * self.n + c]
    }
    fn set(&mut self, r: usize, c: usize, x: f32) {
        self.v[r * self.n + c] = x;
    }
}

fn main() {
    let mut div = TaylorDivider::paper_exact();
    // The service handling the f16 rsqrt + scale-by-recip batches: the
    // Goldschmidt datapath serves every typed op (the native backend is
    // division-only), so QR exercises the second kernel family while
    // kmeans exercises the Taylor one.
    let svc = DivisionService::start(
        ServiceConfig {
            workers: 2,
            max_batch: 4096,
            max_wait: Duration::from_micros(200),
            queue_capacity: 1 << 12,
            ..ServiceConfig::default()
        },
        BackendChoice::Goldschmidt {
            iterations: 3,
            kernel: tsdiv::kernel::KernelConfig::default(),
            trunc_bits: 0,
        },
    )
    .expect("service start");
    let mut rng = Rng::new(7);

    // Well-conditioned random A: diagonally dominated noise.
    let mut a = Mat::zeros(N);
    for r in 0..N {
        for c in 0..N {
            let x = rng.f64_range(-1.0, 1.0) as f32 + if r == c { 4.0 } else { 0.0 };
            a.set(r, c, x);
        }
    }

    // Modified Gram–Schmidt: Q (N×N), R (N×N upper).
    let mut q = Mat::zeros(N);
    let mut r = Mat::zeros(N);
    let mut divisions = 0u64;
    // v starts as the columns of A.
    let mut v = Mat::zeros(N);
    v.v.copy_from_slice(&a.v);
    for k in 0..N {
        // r_kk = ||v_k||
        let mut norm2 = 0.0f32;
        for i in 0..N {
            norm2 += v.at(i, k) * v.at(i, k);
        }
        // r_kk = ‖v_k‖ = norm² · rsqrt(norm²): the square root itself
        // is served as a typed f16 Rsqrt request and the norm is
        // reconstructed client-side with one f32 multiply.
        let rsq = svc
            .divide_request_blocking(DivRequest::rsqrt(
                F16,
                Rounding::NearestEven,
                vec![encode_f32(norm2, F16)],
            ))
            .expect("f16 rsqrt request")
            .to_u16_bits()
            .expect("binary16 response");
        let inv_norm = decode_f32(rsq[0] as u64, F16);
        let rkk = norm2 * inv_norm;
        r.set(k, k, rkk);
        divisions += 1;
        // q_k = v_k · (1/r_kk) — one fused scale-by-recip row of N
        // lanes: the divisor is inverted once and broadcast across the
        // column. The f16 quotients decode exactly back into f32.
        let lanes: Vec<u64> = (0..N).map(|i| encode_f32(v.at(i, k), F16)).collect();
        let divisors = vec![encode_f32(rkk, F16)];
        let quot = svc
            .divide_request_blocking(DivRequest::scale_by_recip(
                F16,
                Rounding::NearestEven,
                lanes,
                divisors,
            ))
            .expect("f16 scale-by-recip normalization")
            .to_u16_bits()
            .expect("binary16 response");
        for i in 0..N {
            q.set(i, k, decode_f32(quot[i] as u64, F16));
            divisions += 1;
        }
        // Orthogonalize the remaining columns against q_k.
        for j in k + 1..N {
            let mut dot = 0.0f32;
            for i in 0..N {
                dot += q.at(i, k) * v.at(i, j);
            }
            r.set(k, j, dot);
            for i in 0..N {
                let nv = v.at(i, j) - dot * q.at(i, k);
                v.set(i, j, nv);
            }
        }
    }

    // Verification 1: ‖QR − A‖_max.
    let mut qr_err = 0.0f32;
    for i in 0..N {
        for j in 0..N {
            let mut s = 0.0f32;
            for k in 0..N {
                s += q.at(i, k) * r.at(k, j);
            }
            qr_err = qr_err.max((s - a.at(i, j)).abs());
        }
    }

    // Verification 2: ‖QᵀQ − I‖_max.
    let mut ortho_err = 0.0f32;
    for i in 0..N {
        for j in 0..N {
            let mut s = 0.0f32;
            for k in 0..N {
                s += q.at(k, i) * q.at(k, j);
            }
            let want = if i == j { 1.0 } else { 0.0 };
            ortho_err = ortho_err.max((s - want).abs());
        }
    }

    // Verification 3: solve A x = b via QR (back-substitution divides by
    // the diagonal of R — more unit divisions).
    let xtrue: Vec<f32> = (0..N).map(|i| (i as f32 * 0.37).sin()).collect();
    let mut b = vec![0.0f32; N];
    for i in 0..N {
        for j in 0..N {
            b[i] += a.at(i, j) * xtrue[j];
        }
    }
    // y = Qᵀ b
    let mut y = vec![0.0f32; N];
    for i in 0..N {
        for k in 0..N {
            y[i] += q.at(k, i) * b[k];
        }
    }
    // Back substitution R x = y.
    let mut x = vec![0.0f32; N];
    for i in (0..N).rev() {
        let mut s = y[i];
        for j in i + 1..N {
            s -= r.at(i, j) * x[j];
        }
        x[i] = div.div_f32(s, r.at(i, i));
        divisions += 1;
    }
    let solve_err = x
        .iter()
        .zip(&xtrue)
        .map(|(&g, &w)| (g - w).abs())
        .fold(0.0f32, f32::max);

    let m = svc.metrics();
    let mut t = Table::new("QR decomposition via the division unit", &["metric", "value"])
        .aligns(&[Align::Left, Align::Right]);
    t.row(&["matrix".into(), format!("{N} × {N}")]);
    t.row(&["divider (back-substitution)".into(), div.name()]);
    t.row(&[
        "normalization ops".into(),
        "f16 rsqrt + scale-by-recip".into(),
    ]);
    t.row(&["unit ops performed".into(), divisions.to_string()]);
    t.row(&["service batches".into(), m.batches.to_string()]);
    t.row(&["‖QR − A‖_max".into(), sig(qr_err as f64, 3)]);
    t.row(&["‖QᵀQ − I‖_max".into(), sig(ortho_err as f64, 3)]);
    t.row(&["solve ‖x − x*‖_max".into(), sig(solve_err as f64, 3)]);
    t.print();

    // Tolerances scale with f16's 2^-11 granularity: the fused
    // normalization chain (rsqrt, reciprocal, broadcast multiply) puts
    // ~3 half-precision roundings on each Q entry (~1.5e-3 relative),
    // so reconstruction/orthogonality land around N·ε ≈ 1e-2 and the
    // back-substituted solve a step above.
    assert!(qr_err < 5e-2, "QR reconstruction too loose: {qr_err}");
    assert!(ortho_err < 5e-2, "Q not orthogonal: {ortho_err}");
    assert!(solve_err < 2.5e-1, "solve failed: {solve_err}");
    assert_eq!(m.failures, 0);
    svc.shutdown();
    println!(
        "\nOK — QR with f16 rsqrt + scale-by-recip normalization through the service \
         is numerically sound at half-precision tolerances."
    );
}
