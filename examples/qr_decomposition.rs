//! QR decomposition via Modified Gram–Schmidt, with every division
//! executed by the paper's Taylor/ILM unit (the second workload the
//! paper's introduction motivates).
//!
//! MGS needs divisions in the normalization step `q_k = v_k / r_kk` and
//! in back-substitution when the factors are used to solve `Ax = b`.
//! Both run through [`tsdiv::divider::TaylorDivider`]; the example
//! verifies ‖QR − A‖, orthogonality of Q, and the solve residual.
//!
//! ```bash
//! cargo run --release --example qr_decomposition
//! ```

use tsdiv::divider::{Divider, TaylorDivider};
use tsdiv::util::rng::Rng;
use tsdiv::util::table::{sig, Align, Table};

const N: usize = 48; // A is N×N

struct Mat {
    n: usize,
    v: Vec<f32>,
}

impl Mat {
    fn zeros(n: usize) -> Self {
        Self { n, v: vec![0.0; n * n] }
    }
    fn at(&self, r: usize, c: usize) -> f32 {
        self.v[r * self.n + c]
    }
    fn set(&mut self, r: usize, c: usize, x: f32) {
        self.v[r * self.n + c] = x;
    }
}

fn main() {
    let mut div = TaylorDivider::paper_exact();
    let mut rng = Rng::new(7);

    // Well-conditioned random A: diagonally dominated noise.
    let mut a = Mat::zeros(N);
    for r in 0..N {
        for c in 0..N {
            let x = rng.f64_range(-1.0, 1.0) as f32 + if r == c { 4.0 } else { 0.0 };
            a.set(r, c, x);
        }
    }

    // Modified Gram–Schmidt: Q (N×N), R (N×N upper).
    let mut q = Mat::zeros(N);
    let mut r = Mat::zeros(N);
    let mut divisions = 0u64;
    // v starts as the columns of A.
    let mut v = Mat::zeros(N);
    v.v.copy_from_slice(&a.v);
    for k in 0..N {
        // r_kk = ||v_k||
        let mut norm2 = 0.0f32;
        for i in 0..N {
            norm2 += v.at(i, k) * v.at(i, k);
        }
        let rkk = norm2.sqrt();
        r.set(k, k, rkk);
        // q_k = v_k / r_kk — N divisions through the unit.
        for i in 0..N {
            q.set(i, k, div.div_f32(v.at(i, k), rkk));
            divisions += 1;
        }
        // Orthogonalize the remaining columns against q_k.
        for j in k + 1..N {
            let mut dot = 0.0f32;
            for i in 0..N {
                dot += q.at(i, k) * v.at(i, j);
            }
            r.set(k, j, dot);
            for i in 0..N {
                let nv = v.at(i, j) - dot * q.at(i, k);
                v.set(i, j, nv);
            }
        }
    }

    // Verification 1: ‖QR − A‖_max.
    let mut qr_err = 0.0f32;
    for i in 0..N {
        for j in 0..N {
            let mut s = 0.0f32;
            for k in 0..N {
                s += q.at(i, k) * r.at(k, j);
            }
            qr_err = qr_err.max((s - a.at(i, j)).abs());
        }
    }

    // Verification 2: ‖QᵀQ − I‖_max.
    let mut ortho_err = 0.0f32;
    for i in 0..N {
        for j in 0..N {
            let mut s = 0.0f32;
            for k in 0..N {
                s += q.at(k, i) * q.at(k, j);
            }
            let want = if i == j { 1.0 } else { 0.0 };
            ortho_err = ortho_err.max((s - want).abs());
        }
    }

    // Verification 3: solve A x = b via QR (back-substitution divides by
    // the diagonal of R — more unit divisions).
    let xtrue: Vec<f32> = (0..N).map(|i| (i as f32 * 0.37).sin()).collect();
    let mut b = vec![0.0f32; N];
    for i in 0..N {
        for j in 0..N {
            b[i] += a.at(i, j) * xtrue[j];
        }
    }
    // y = Qᵀ b
    let mut y = vec![0.0f32; N];
    for i in 0..N {
        for k in 0..N {
            y[i] += q.at(k, i) * b[k];
        }
    }
    // Back substitution R x = y.
    let mut x = vec![0.0f32; N];
    for i in (0..N).rev() {
        let mut s = y[i];
        for j in i + 1..N {
            s -= r.at(i, j) * x[j];
        }
        x[i] = div.div_f32(s, r.at(i, i));
        divisions += 1;
    }
    let solve_err = x
        .iter()
        .zip(&xtrue)
        .map(|(&g, &w)| (g - w).abs())
        .fold(0.0f32, f32::max);

    let mut t = Table::new("QR decomposition via the division unit", &["metric", "value"])
        .aligns(&[Align::Left, Align::Right]);
    t.row(&["matrix".into(), format!("{N} × {N}")]);
    t.row(&["divider".into(), div.name()]);
    t.row(&["unit divisions performed".into(), divisions.to_string()]);
    t.row(&["‖QR − A‖_max".into(), sig(qr_err as f64, 3)]);
    t.row(&["‖QᵀQ − I‖_max".into(), sig(ortho_err as f64, 3)]);
    t.row(&["solve ‖x − x*‖_max".into(), sig(solve_err as f64, 3)]);
    t.print();

    assert!(qr_err < 1e-3, "QR reconstruction too loose: {qr_err}");
    assert!(ortho_err < 1e-3, "Q not orthogonal: {ortho_err}");
    assert!(solve_err < 1e-2, "solve failed: {solve_err}");
    println!("\nOK — QR factorization through the Taylor/ILM divider is numerically sound.");
}
