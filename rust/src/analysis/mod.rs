//! Accuracy analysis: ULP/relative-error sweeps over operand
//! distributions, and the parameter sweeps behind the evaluation tables.

use crate::divider::{longdiv::LongDivider, Divider};
use crate::fp::{ulp_diff, Rounding};
use crate::util::rng::Rng;
use crate::util::stats::Summary;

/// Error statistics of a divider against the exactly-rounded reference.
#[derive(Clone, Debug)]
pub struct AccuracyReport {
    pub divider: String,
    pub samples: u64,
    /// ULP distance distribution vs the correctly rounded quotient.
    pub max_ulp: u64,
    pub mean_ulp: f64,
    /// Fraction of samples that exactly match the reference bits.
    pub exact_rate: f64,
    /// Max/mean relative error (f64 computation domain).
    pub max_rel: f64,
    pub mean_rel: f64,
}

/// Operand distributions for accuracy/throughput sweeps.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Workload {
    /// Log-uniform over a moderate exponent range (typical numerics).
    LogUniform,
    /// Uniform significands with equal exponents (stresses the mantissa
    /// path only — the paper's setting).
    SignificandOnly,
    /// Fully random bit patterns (includes subnormals, huge/tiny ratios).
    RandomBits,
}

impl Workload {
    pub fn sample_f32(&self, rng: &mut Rng) -> (f32, f32) {
        match self {
            Workload::LogUniform => (rng.f32_log_uniform(-30, 30), rng.f32_log_uniform(-30, 30)),
            Workload::SignificandOnly => {
                (1.0 + rng.f32(), 1.0 + rng.f32())
            }
            Workload::RandomBits => {
                let mut a = rng.f32_bits();
                let mut b = rng.f32_bits();
                while !a.is_finite() {
                    a = rng.f32_bits();
                }
                while !b.is_finite() {
                    b = rng.f32_bits();
                }
                (a, b)
            }
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Workload::LogUniform => "log-uniform",
            Workload::SignificandOnly => "significand-only",
            Workload::RandomBits => "random-bits",
        }
    }
}

/// Measure a divider's accuracy against the digit-recurrence reference.
pub fn measure_accuracy_f32(
    div: &mut dyn Divider,
    workload: Workload,
    samples: u64,
    seed: u64,
) -> AccuracyReport {
    let mut rng = Rng::new(seed);
    let mut gold = LongDivider::new();
    let fmt = crate::fp::F32;
    let mut ulps = Summary::new();
    let mut rels = Summary::new();
    let mut max_ulp = 0u64;
    let mut exact = 0u64;
    for _ in 0..samples {
        let (a, b) = workload.sample_f32(&mut rng);
        let ours = div.div_bits(a.to_bits() as u64, b.to_bits() as u64, fmt, Rounding::NearestEven);
        let reference =
            gold.div_bits(a.to_bits() as u64, b.to_bits() as u64, fmt, Rounding::NearestEven);
        if let Some(u) = ulp_diff(ours, reference, fmt) {
            ulps.push(u as f64);
            max_ulp = max_ulp.max(u);
            if u == 0 {
                exact += 1;
            }
        }
        let of = f32::from_bits(ours as u32) as f64;
        let rf = f32::from_bits(reference as u32) as f64;
        if rf.is_finite() && rf != 0.0 {
            rels.push(((of - rf) / rf).abs());
        }
    }
    AccuracyReport {
        divider: div.name(),
        samples,
        max_ulp,
        mean_ulp: ulps.mean(),
        exact_rate: exact as f64 / samples as f64,
        max_rel: rels.max(),
        mean_rel: rels.mean(),
    }
}

/// Reciprocal-only accuracy vs `1/x` in f64 across a significand sweep:
/// returns (x, abs_error) series — the data behind Fig 1/3-style plots.
pub fn reciprocal_error_series(
    cfg: &crate::taylor::TaylorConfig,
    points: usize,
) -> Vec<(f64, f64)> {
    let mut backend = crate::powering::ExactMul::default();
    // One scratch for the whole sweep — no per-point allocation.
    let mut scratch = crate::powering::PowersScratch::new();
    let scale = (1u128 << cfg.frac_bits) as f64;
    (0..points)
        .map(|i| {
            let x = 1.0 + (i as f64 + 0.5) / points as f64;
            let xq = (x * scale) as u64;
            let r = crate::taylor::reciprocal_fixed_with(cfg, &mut backend, xq, &mut scratch);
            let err = (r.recip as f64 / scale - 1.0 / x).abs();
            (x, err)
        })
        .collect()
}

/// Worst-case reciprocal error (bits of precision) for a configuration.
pub fn reciprocal_precision_bits(cfg: &crate::taylor::TaylorConfig, points: usize) -> f64 {
    let worst = reciprocal_error_series(cfg, points)
        .into_iter()
        .map(|(_, e)| e)
        .fold(0.0f64, f64::max);
    if worst == 0.0 {
        cfg.frac_bits as f64
    } else {
        -worst.log2()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::divider::TaylorDivider;
    use crate::taylor::TaylorConfig;

    #[test]
    fn exact_divider_reports_zero_ulp() {
        let mut gold = LongDivider::new();
        let r = measure_accuracy_f32(&mut gold, Workload::LogUniform, 2_000, 1);
        assert_eq!(r.max_ulp, 0);
        assert_eq!(r.exact_rate, 1.0);
        assert_eq!(r.mean_ulp, 0.0);
    }

    #[test]
    fn taylor_divider_accuracy_report_sane() {
        let mut d = TaylorDivider::paper_exact();
        let r = measure_accuracy_f32(&mut d, Workload::LogUniform, 5_000, 2);
        assert!(r.max_ulp <= 1, "max ulp {}", r.max_ulp);
        assert!(r.exact_rate > 0.999);
        assert!(r.mean_rel < 1e-7);
    }

    #[test]
    fn workloads_produce_finite_pairs() {
        let mut rng = Rng::new(5);
        for w in [Workload::LogUniform, Workload::SignificandOnly, Workload::RandomBits] {
            for _ in 0..100 {
                let (a, b) = w.sample_f32(&mut rng);
                assert!(a.is_finite() && b.is_finite(), "{}", w.name());
            }
        }
    }

    #[test]
    fn significand_only_in_unit_binade() {
        let mut rng = Rng::new(6);
        for _ in 0..100 {
            let (a, b) = Workload::SignificandOnly.sample_f32(&mut rng);
            assert!((1.0..2.0).contains(&a) && (1.0..2.0).contains(&b));
        }
    }

    #[test]
    fn precision_bits_matches_paper_config() {
        let cfg = TaylorConfig::paper_default(60);
        let bits = reciprocal_precision_bits(&cfg, 400);
        assert!(bits >= 53.0, "paper config delivers {bits:.1} bits");
        // Lower order → fewer bits.
        let cfg2 = TaylorConfig {
            order: 2,
            ..TaylorConfig::paper_default(60)
        };
        let bits2 = reciprocal_precision_bits(&cfg2, 400);
        assert!(bits2 < bits);
    }

    #[test]
    fn error_series_has_requested_length_and_positive_x() {
        let cfg = TaylorConfig::paper_default(60);
        let s = reciprocal_error_series(&cfg, 64);
        assert_eq!(s.len(), 64);
        assert!(s.iter().all(|&(x, e)| (1.0..2.0).contains(&x) && e >= 0.0));
    }
}
