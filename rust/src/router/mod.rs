//! Adaptive backend router: picks a kernel datapath per traffic bucket.
//!
//! The service has two first-class fast paths — the Taylor/ILM staged
//! kernel and the Goldschmidt iterate datapath — and which one wins
//! depends on the traffic: format width changes the per-lane multiply
//! cost, rounding mode is free but keys the batch buckets, and batch
//! size moves the fixed per-batch overhead around. The operation is a
//! fourth bucket axis: reciprocal skips the final multiply, rsqrt adds
//! a Newton refinement, and scale-by-reciprocal amortizes one
//! reciprocal across a whole row, so the datapaths' relative cost
//! shifts per op. [`BackendRouter`] keeps one scoring cell per
//! `(Op, Format, Rounding, batch-size bucket)` and answers "which
//! datapath should run this batch?".
//!
//! Scores are **per-lane seconds** (lower is better), blended from
//! three sources in priority order:
//!
//! 1. **Bench history.** [`BackendRouter::seed_from_history`] takes the
//!    rolling `BENCH_HISTORY.jsonl` records (as read by
//!    [`crate::harness::read_bench_history`]) and seeds each cell from
//!    the per-key medians of the `coordinator_serve` throughput rows:
//!    `kernel_div_per_s` / `goldschmidt_div_per_s_{fmt}` for division,
//!    and `{recip,rsqrt,scale_recip}_div_per_s_{kernel,goldschmidt}`
//!    for the fused ops (keys spelled via [`Op::key_name`], matching
//!    the bench emission exactly), inverting per-second throughput
//!    into seconds/lane.
//! 2. **Static cost model.** With no history, cells start from a
//!    per-op multiply-count prior (see `per_lane_muls`): ~7 wide
//!    multiplies per division lane on the order-5 Taylor pipeline vs
//!    ~8 on 3-iteration Goldschmidt, one fewer each for reciprocal,
//!    ~12 more each for rsqrt's Newton tail, and ~2-3 amortized for
//!    scale-by-reciprocal, scaled by
//!    [`crate::fp::Format::lane_cost`].
//! 3. **Online measurement.** Every routed batch reports its wall
//!    latency back via [`BackendRouter::observe`]; the cell keeps an
//!    EWMA of per-lane seconds so the table tracks the machine it is
//!    actually running on, not the machine that wrote the history.
//!
//! Selection is epsilon-greedy with two safeguards so a cold or
//! temporarily-losing datapath keeps getting sampled: any candidate
//! with fewer than [`COLD_FLOOR`] observed batches in a cell is picked
//! first (deterministically, lowest candidate index on ties), and the
//! exploration rate never drops below [`EXPLORATION_FLOOR`] even if a
//! caller asks for pure exploitation. Randomness comes from the
//! in-tree [`crate::util::rng::Rng`], so a seeded router is fully
//! deterministic — the router unit tests and the service identity
//! tests rely on that.
//!
//! The router lives below the coordinator: it depends only on `fp`,
//! `util`, and `harness`, and the coordinator's `RoutedBackend` wraps
//! it around concrete backends. `BackendChoice::Auto` (and
//! `tsdiv serve --backend auto`, or `TSDIV_ROUTER=auto` upgrading the
//! default) is the user-facing switch.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::fp::{Format, Op, Rounding, F32};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Datapaths the router arbitrates between. Indices are dense so the
/// table and the dispatch counters can be plain arrays.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Candidate {
    /// The Taylor-series staged kernel (`BackendChoice::Kernel`).
    Kernel = 0,
    /// The Goldschmidt iterate datapath (`BackendChoice::Goldschmidt`).
    Goldschmidt = 1,
}

/// Number of datapaths under arbitration.
pub const NUM_CANDIDATES: usize = 2;

impl Candidate {
    /// All candidates, in index order.
    pub const fn all() -> [Candidate; NUM_CANDIDATES] {
        [Candidate::Kernel, Candidate::Goldschmidt]
    }

    /// Stable short name (metrics keys, logs).
    pub const fn name(self) -> &'static str {
        match self {
            Candidate::Kernel => "kernel",
            Candidate::Goldschmidt => "goldschmidt",
        }
    }

    const fn idx(self) -> usize {
        self as usize
    }
}

/// Below this many observed batches in a cell, a candidate is "cold"
/// and gets picked unconditionally so the table has real data before
/// epsilon-greedy takes over.
pub const COLD_FLOOR: u64 = 3;

/// The exploration rate never drops below this, so a datapath that
/// loses early keeps getting re-sampled as conditions change.
pub const EXPLORATION_FLOOR: f64 = 0.05;

/// Default epsilon for epsilon-greedy selection.
const DEFAULT_EPSILON: f64 = 0.1;

/// EWMA smoothing for online per-lane latency updates.
const EWMA_ALPHA: f64 = 0.2;

/// Batch sizes are bucketed by log2, clamped to this many buckets
/// (lane counts of 2^16 and beyond share the top bucket).
const NUM_BUCKETS: usize = 17;

const NUM_OPS: usize = 4;
const NUM_FORMATS: usize = 4;
const NUM_ROUNDINGS: usize = 4;
const NUM_CELLS: usize = NUM_OPS * NUM_FORMATS * NUM_ROUNDINGS * NUM_BUCKETS;

/// Rough wide-multiply count per lane for the static prior, per op:
/// division's order-5 Taylor pipeline spends ~7 wide multiplies per
/// lane vs ~8 for 3-iteration Goldschmidt; reciprocal drops the final
/// dividend multiply on both; rsqrt appends the shared Newton tail
/// (~3 multiplies × 4 sweeps); scale-by-reciprocal amortizes the whole
/// reciprocal chain across a row, leaving roughly the final multiply
/// per lane (Goldschmidt's dedupe pass costs it one more).
fn per_lane_muls(c: Candidate, op: Op) -> f64 {
    match (c, op) {
        (Candidate::Kernel, Op::Div) => 7.0,
        (Candidate::Goldschmidt, Op::Div) => 8.0,
        (Candidate::Kernel, Op::Recip) => 6.0,
        (Candidate::Goldschmidt, Op::Recip) => 7.0,
        (Candidate::Kernel, Op::Rsqrt) => 19.0,
        (Candidate::Goldschmidt, Op::Rsqrt) => 20.0,
        (Candidate::Kernel, Op::ScaleByRecip) => 2.0,
        (Candidate::Goldschmidt, Op::ScaleByRecip) => 3.0,
    }
}

/// Pseudo-seconds one wide multiply costs in the static prior. The
/// absolute scale is irrelevant (only the ratio between candidates
/// matters until real observations arrive); it is chosen to be in the
/// same ballpark as measured per-lane times so history-seeded and
/// prior-seeded cells are comparable.
const MUL_COST_S: f64 = 2e-9;

#[derive(Clone, Copy, Debug)]
struct CandStat {
    /// EWMA of per-lane seconds (lower is better).
    per_lane: f64,
    /// Observed batches folded into the EWMA (history seeding leaves
    /// this at zero so cold-start exploration still runs).
    samples: u64,
}

#[derive(Clone, Copy, Debug)]
struct Cell {
    stats: [CandStat; NUM_CANDIDATES],
}

struct RouterState {
    rng: Rng,
    cells: Vec<Cell>,
}

/// Per-bucket adaptive scoring table. See the module docs for the
/// seeding and selection policy.
pub struct BackendRouter {
    state: Mutex<RouterState>,
    dispatches: [AtomicU64; NUM_CANDIDATES],
    epsilon: f64,
}

fn format_idx(fmt: Format) -> usize {
    match (fmt.exp_bits, fmt.frac_bits) {
        (5, 10) => 0, // f16
        (8, 7) => 1,  // bf16
        (8, 23) => 2, // f32
        _ => 3,       // f64 and custom layouts
    }
}

fn rounding_idx(rm: Rounding) -> usize {
    match rm {
        Rounding::NearestEven => 0,
        Rounding::TowardZero => 1,
        Rounding::TowardPositive => 2,
        Rounding::TowardNegative => 3,
    }
}

fn bucket_idx(lanes: usize) -> usize {
    let log2 = usize::BITS - lanes.max(1).leading_zeros() - 1;
    (log2 as usize).min(NUM_BUCKETS - 1)
}

fn cell_idx(op: Op, fmt: Format, rm: Rounding, lanes: usize) -> usize {
    ((op.idx() * NUM_FORMATS + format_idx(fmt)) * NUM_ROUNDINGS + rounding_idx(rm)) * NUM_BUCKETS
        + bucket_idx(lanes)
}

/// Static-prior per-lane seconds for `c` running `op` on `fmt` (see
/// module docs).
fn prior_per_lane(c: Candidate, op: Op, fmt: Format) -> f64 {
    per_lane_muls(c, op) * MUL_COST_S * fmt.lane_cost() as f64 / F32.lane_cost() as f64
}

impl BackendRouter {
    /// Router with the default exploration rate, priors from the
    /// static cost model, and a fixed RNG seed (callers wanting
    /// varied exploration order pass their own seed).
    pub fn new(seed: u64) -> Self {
        Self::with_epsilon(seed, DEFAULT_EPSILON)
    }

    /// Router with an explicit exploration rate. Clamped to
    /// [`EXPLORATION_FLOOR`] from below so no configuration can starve
    /// a candidate forever.
    pub fn with_epsilon(seed: u64, epsilon: f64) -> Self {
        let cells: Vec<Cell> = Op::ALL
            .iter()
            .flat_map(|&op| {
                crate::fp::ALL_FORMATS.iter().flat_map(move |&fmt| {
                    (0..NUM_ROUNDINGS * NUM_BUCKETS).map(move |_| Cell {
                        stats: [
                            CandStat {
                                per_lane: prior_per_lane(Candidate::Kernel, op, fmt),
                                samples: 0,
                            },
                            CandStat {
                                per_lane: prior_per_lane(Candidate::Goldschmidt, op, fmt),
                                samples: 0,
                            },
                        ],
                    })
                })
            })
            .collect();
        debug_assert_eq!(cells.len(), NUM_CELLS);
        BackendRouter {
            state: Mutex::new(RouterState {
                rng: Rng::new(seed),
                cells,
            }),
            dispatches: [AtomicU64::new(0), AtomicU64::new(0)],
            epsilon: epsilon.max(EXPLORATION_FLOOR),
        }
    }

    /// Overwrite the static priors from rolling bench-history records
    /// (the parsed lines of `BENCH_HISTORY.jsonl`). Only
    /// `coordinator_serve` rows contribute; per-key medians of the
    /// positive finite throughput values are inverted into per-lane
    /// seconds. Division: the Taylor kernel publishes one f32
    /// throughput key (`kernel_div_per_s`), so other formats are
    /// scaled by the [`Format::lane_cost`] ratio; Goldschmidt
    /// publishes per-format keys. The fused ops publish one
    /// f32-traffic lanes/s key per candidate
    /// (`{recip,rsqrt,scale_recip}_div_per_s_{kernel,goldschmidt}` —
    /// the spelling is [`Op::key_name`], underscore-safe so the bench
    /// JSON and this lookup can never drift apart again), scaled the
    /// same way. Seeded cells keep `samples == 0`, so cold-start
    /// exploration still measures the live machine.
    pub fn seed_from_history(&self, records: &[Json]) {
        let serve: Vec<&Json> = records
            .iter()
            .filter(|r| r.get("bench").and_then(|b| b.as_str()) == Some("coordinator_serve"))
            .collect();
        if serve.is_empty() {
            return;
        }
        let key_median = |key: &str| -> Option<f64> {
            let vals: Vec<f64> = serve
                .iter()
                .filter_map(|r| r.get(key).and_then(|v| v.as_f64()))
                .filter(|v| v.is_finite() && *v > 0.0)
                .collect();
            if vals.is_empty() {
                None
            } else {
                Some(crate::harness::median(&vals))
            }
        };
        // f32-traffic medians, rescaled per format below. Keys are
        // spelled with `key_name()` (underscore-safe) — `name()` would
        // produce `scale-recip_…`, which no bench ever emits.
        let kernel_div_f32 = key_median("kernel_div_per_s");
        let fused_f32 = |op: Op, c: Candidate| -> Option<f64> {
            key_median(&format!("{}_div_per_s_{}", op.key_name(), c.name()))
        };
        let mut state = self.state.lock().unwrap();
        for &op in Op::ALL.iter() {
            for &fmt in crate::fp::ALL_FORMATS.iter() {
                let rescale =
                    |per_s: f64| F32.lane_cost() as f64 / (per_s * fmt.lane_cost() as f64);
                let (kernel, gold) = match op {
                    Op::Div => (
                        kernel_div_f32.map(rescale),
                        key_median(&format!("goldschmidt_div_per_s_{}", fmt.name()))
                            .map(|per_s| 1.0 / per_s),
                    ),
                    Op::Recip | Op::Rsqrt | Op::ScaleByRecip => (
                        fused_f32(op, Candidate::Kernel).map(rescale),
                        fused_f32(op, Candidate::Goldschmidt).map(rescale),
                    ),
                };
                let base =
                    (op.idx() * NUM_FORMATS + format_idx(fmt)) * NUM_ROUNDINGS * NUM_BUCKETS;
                for cell in state.cells[base..]
                    .iter_mut()
                    .take(NUM_ROUNDINGS * NUM_BUCKETS)
                {
                    if let Some(s) = kernel {
                        cell.stats[Candidate::Kernel.idx()].per_lane = s;
                    }
                    if let Some(s) = gold {
                        cell.stats[Candidate::Goldschmidt.idx()].per_lane = s;
                    }
                }
            }
        }
    }

    /// Pick the datapath for one batch. Cold candidates (fewer than
    /// [`COLD_FLOOR`] samples in this cell) are drained first in
    /// index order; after that, epsilon-greedy over the per-lane EWMA.
    pub fn pick(&self, op: Op, fmt: Format, rm: Rounding, lanes: usize) -> Candidate {
        let mut state = self.state.lock().unwrap();
        let explore = state.rng.f64() < self.epsilon;
        let cell = &state.cells[cell_idx(op, fmt, rm, lanes)];
        let coldest = Candidate::all()
            .into_iter()
            .min_by_key(|c| cell.stats[c.idx()].samples)
            .unwrap();
        let choice = if cell.stats[coldest.idx()].samples < COLD_FLOOR {
            coldest
        } else if explore {
            // Uniform over candidates; `below` keeps determinism tied
            // to the seeded RNG stream.
            let mut rng_pick = Candidate::Kernel;
            let n = state.rng.below(NUM_CANDIDATES as u64) as usize;
            for c in Candidate::all() {
                if c.idx() == n {
                    rng_pick = c;
                }
            }
            rng_pick
        } else {
            Candidate::all()
                .into_iter()
                .min_by(|a, b| {
                    cell.stats[a.idx()]
                        .per_lane
                        .total_cmp(&cell.stats[b.idx()].per_lane)
                })
                .unwrap()
        };
        drop(state);
        self.dispatches[choice.idx()].fetch_add(1, Ordering::Relaxed);
        choice
    }

    /// Fold one measured batch back into the table.
    #[allow(clippy::too_many_arguments)]
    pub fn observe(
        &self,
        op: Op,
        fmt: Format,
        rm: Rounding,
        lanes: usize,
        c: Candidate,
        elapsed: Duration,
    ) {
        if lanes == 0 {
            return;
        }
        let per_lane = elapsed.as_secs_f64() / lanes as f64;
        if !per_lane.is_finite() {
            return;
        }
        let mut state = self.state.lock().unwrap();
        let stat = &mut state.cells[cell_idx(op, fmt, rm, lanes)].stats[c.idx()];
        if stat.samples == 0 {
            stat.per_lane = per_lane;
        } else {
            stat.per_lane += EWMA_ALPHA * (per_lane - stat.per_lane);
        }
        stat.samples += 1;
    }

    /// Total batches routed to `c` since construction.
    pub fn dispatches(&self, c: Candidate) -> u64 {
        self.dispatches[c.idx()].load(Ordering::Relaxed)
    }

    /// Fraction of cells with at least one observed sample where `c`
    /// currently holds the best (lowest) per-lane score. `0.0` when
    /// nothing has been observed yet.
    pub fn win_rate(&self, c: Candidate) -> f64 {
        let state = self.state.lock().unwrap();
        let mut measured = 0usize;
        let mut wins = 0usize;
        for cell in state.cells.iter() {
            if cell.stats.iter().all(|s| s.samples == 0) {
                continue;
            }
            measured += 1;
            let best = Candidate::all()
                .into_iter()
                .min_by(|a, b| {
                    cell.stats[a.idx()]
                        .per_lane
                        .total_cmp(&cell.stats[b.idx()].per_lane)
                })
                .unwrap();
            if best == c {
                wins += 1;
            }
        }
        if measured == 0 {
            0.0
        } else {
            wins as f64 / measured as f64
        }
    }
}

impl std::fmt::Debug for BackendRouter {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("BackendRouter")
            .field("epsilon", &self.epsilon)
            .field("kernel_dispatches", &self.dispatches(Candidate::Kernel))
            .field(
                "goldschmidt_dispatches",
                &self.dispatches(Candidate::Goldschmidt),
            )
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp::{F16, F64};
    use crate::util::json::Json;

    fn warm(router: &BackendRouter, fmt: Format, rm: Rounding, lanes: usize) {
        // Drain the cold floor for both candidates with neutral equal
        // timings so epsilon-greedy is in charge afterwards.
        for _ in 0..COLD_FLOOR {
            for c in Candidate::all() {
                router.observe(Op::Div, fmt, rm, lanes, c, Duration::from_micros(10));
            }
        }
    }

    #[test]
    fn cold_start_drains_both_candidates_before_scoring() {
        let router = BackendRouter::new(7);
        let mut counts = [0u64; NUM_CANDIDATES];
        for _ in 0..(2 * COLD_FLOOR) {
            let c = router.pick(Op::Div, F32, Rounding::NearestEven, 64);
            counts[c.idx()] += 1;
            // Report wildly lopsided timings: Goldschmidt 100x slower.
            let us = if c == Candidate::Kernel { 1 } else { 100 };
            router.observe(Op::Div, F32, Rounding::NearestEven, 64, c, Duration::from_micros(us));
        }
        // Despite Goldschmidt losing every observation, the cold floor
        // forces an even split of the first 2*COLD_FLOOR picks.
        assert_eq!(counts[Candidate::Kernel.idx()], COLD_FLOOR);
        assert_eq!(counts[Candidate::Goldschmidt.idx()], COLD_FLOOR);
    }

    #[test]
    fn static_prior_prefers_kernel_when_no_history() {
        // Fewer modelled multiplies -> kernel scores lower in every
        // warm cell that has only neutral observations layered on the
        // prior... but the prior itself is what we check here: a
        // freshly constructed router ranks kernel ahead of goldschmidt
        // in its table for every format.
        for &fmt in crate::fp::ALL_FORMATS.iter() {
            for &op in Op::ALL.iter() {
                assert!(
                    prior_per_lane(Candidate::Kernel, op, fmt)
                        < prior_per_lane(Candidate::Goldschmidt, op, fmt),
                    "static prior must favour the kernel for {}/{}",
                    op.name(),
                    fmt.name()
                );
            }
            // And the per-op ordering reflects the tails: amortized
            // scale-by-recip is cheapest, the Newton rsqrt dearest.
            for c in Candidate::all() {
                assert!(
                    prior_per_lane(c, Op::ScaleByRecip, fmt)
                        < prior_per_lane(c, Op::Recip, fmt)
                );
                assert!(prior_per_lane(c, Op::Recip, fmt) < prior_per_lane(c, Op::Div, fmt));
                assert!(prior_per_lane(c, Op::Div, fmt) < prior_per_lane(c, Op::Rsqrt, fmt));
            }
        }
    }

    #[test]
    fn observations_flip_the_greedy_choice() {
        let router = BackendRouter::with_epsilon(11, EXPLORATION_FLOOR);
        warm(&router, F32, Rounding::TowardZero, 256);
        // Now make Goldschmidt decisively faster in this cell.
        for _ in 0..20 {
            router.observe(
                Op::Div,
                F32,
                Rounding::TowardZero,
                256,
                Candidate::Goldschmidt,
                Duration::from_micros(1),
            );
            router.observe(
                Op::Div,
                F32,
                Rounding::TowardZero,
                256,
                Candidate::Kernel,
                Duration::from_micros(50),
            );
        }
        let mut gold = 0;
        let total = 200;
        for _ in 0..total {
            if router.pick(Op::Div, F32, Rounding::TowardZero, 256) == Candidate::Goldschmidt {
                gold += 1;
            }
        }
        // Greedy picks goldschmidt except for the epsilon exploration
        // slice (~5% at the floor, split between both candidates).
        assert!(gold > total * 8 / 10, "goldschmidt won {gold}/{total}");
    }

    #[test]
    fn epsilon_exploration_floor_keeps_sampling_the_loser() {
        // Even with epsilon "disabled" (0.0 clamps up to the floor),
        // the losing candidate must still be picked occasionally.
        let router = BackendRouter::with_epsilon(23, 0.0);
        warm(&router, F64, Rounding::NearestEven, 1024);
        for _ in 0..20 {
            router.observe(
                Op::Div,
                F64,
                Rounding::NearestEven,
                1024,
                Candidate::Kernel,
                Duration::from_micros(1),
            );
            router.observe(
                Op::Div,
                F64,
                Rounding::NearestEven,
                1024,
                Candidate::Goldschmidt,
                Duration::from_micros(50),
            );
        }
        let mut loser_picks = 0;
        for _ in 0..2000 {
            if router.pick(Op::Div, F64, Rounding::NearestEven, 1024) == Candidate::Goldschmidt {
                loser_picks += 1;
            }
        }
        assert!(
            loser_picks > 0,
            "exploration floor must keep sampling the cold/losing backend"
        );
        // But it stays a minority: exploration, not thrashing.
        assert!(loser_picks < 400, "loser picked {loser_picks}/2000");
    }

    #[test]
    fn seeded_rng_makes_pick_sequences_deterministic() {
        let run = || {
            let router = BackendRouter::new(99);
            warm(&router, F16, Rounding::TowardPositive, 32);
            (0..64)
                .map(|_| router.pick(Op::Div, F16, Rounding::TowardPositive, 32).idx())
                .collect::<Vec<_>>()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn history_seeding_prefers_the_measured_winner() {
        let mut rec = Json::obj();
        rec.set("bench", "coordinator_serve".into());
        // Goldschmidt measured 4x the kernel's throughput on f32.
        rec.set("kernel_div_per_s", Json::Num(1.0e8));
        rec.set("goldschmidt_div_per_s_f32", Json::Num(4.0e8));
        let router = BackendRouter::with_epsilon(5, EXPLORATION_FLOOR);
        router.seed_from_history(&[rec]);
        // Cold floor still applies (samples stay 0 after seeding), so
        // warm the cell with *equal* observations... except observe()
        // overwrites the seed on the first sample. To check the seeded
        // table directly, inspect win_rate after a single neutral
        // observation pair would destroy the seed — so instead verify
        // via the greedy path: drain the cold floor by picks alone
        // without observations (samples stay 0, cold rule keeps
        // alternating), then confirm the seeded ordering via win_rate
        // over a hand-marked cell.
        let state = router.state.lock().unwrap();
        let cell = &state.cells[cell_idx(Op::Div, F32, Rounding::NearestEven, 64)];
        assert!(
            cell.stats[Candidate::Goldschmidt.idx()].per_lane
                < cell.stats[Candidate::Kernel.idx()].per_lane,
            "history seeding must rank the measured winner first"
        );
        // Formats without their own kernel key scale from the f32 row.
        let f64_cell = &state.cells[cell_idx(Op::Div, F64, Rounding::NearestEven, 64)];
        assert!(
            f64_cell.stats[Candidate::Kernel.idx()].per_lane
                > cell.stats[Candidate::Kernel.idx()].per_lane,
            "wider formats must be priced slower from the same f32 row"
        );
        // Division history never bleeds into other ops' cells.
        let recip_cell = &state.cells[cell_idx(Op::Recip, F32, Rounding::NearestEven, 64)];
        assert_eq!(
            recip_cell.stats[Candidate::Kernel.idx()].per_lane,
            prior_per_lane(Candidate::Kernel, Op::Recip, F32),
        );
    }

    #[test]
    fn per_op_history_keys_seed_their_own_cells_only() {
        let mut rec = Json::obj();
        rec.set("bench", "coordinator_serve".into());
        // Kernel wins recip and scale-recip, goldschmidt wins rsqrt —
        // decisively.
        rec.set("recip_div_per_s_kernel", Json::Num(8.0e8));
        rec.set("recip_div_per_s_goldschmidt", Json::Num(1.0e8));
        rec.set("rsqrt_div_per_s_kernel", Json::Num(1.0e8));
        rec.set("rsqrt_div_per_s_goldschmidt", Json::Num(8.0e8));
        rec.set("scale_recip_div_per_s_kernel", Json::Num(9.0e8));
        rec.set("scale_recip_div_per_s_goldschmidt", Json::Num(1.0e8));
        let router = BackendRouter::new(17);
        router.seed_from_history(&[rec]);
        let state = router.state.lock().unwrap();
        let recip = &state.cells[cell_idx(Op::Recip, F32, Rounding::NearestEven, 64)];
        assert!(
            recip.stats[Candidate::Kernel.idx()].per_lane
                < recip.stats[Candidate::Goldschmidt.idx()].per_lane
        );
        let rsqrt = &state.cells[cell_idx(Op::Rsqrt, F32, Rounding::NearestEven, 64)];
        assert!(
            rsqrt.stats[Candidate::Goldschmidt.idx()].per_lane
                < rsqrt.stats[Candidate::Kernel.idx()].per_lane
        );
        // Wider formats reprice the same f32-traffic key by lane cost.
        let recip64 = &state.cells[cell_idx(Op::Recip, F64, Rounding::NearestEven, 64)];
        assert!(
            recip64.stats[Candidate::Kernel.idx()].per_lane
                > recip.stats[Candidate::Kernel.idx()].per_lane
        );
        // Scale-by-recip seeds from its underscore-spelled keys (the
        // hyphenated `Op::name()` spelling would silently miss them —
        // the regression this test pins).
        let scale = &state.cells[cell_idx(Op::ScaleByRecip, F32, Rounding::NearestEven, 64)];
        assert!(
            scale.stats[Candidate::Kernel.idx()].per_lane
                < scale.stats[Candidate::Goldschmidt.idx()].per_lane,
            "scale-recip history must seed its cells"
        );
        assert_ne!(
            scale.stats[Candidate::Kernel.idx()].per_lane,
            prior_per_lane(Candidate::Kernel, Op::ScaleByRecip, F32),
            "seeded scale-recip cells must leave the static prior"
        );
        // And division cells keep the prior (no div keys in the record).
        let div = &state.cells[cell_idx(Op::Div, F32, Rounding::NearestEven, 64)];
        assert_eq!(
            div.stats[Candidate::Kernel.idx()].per_lane,
            prior_per_lane(Candidate::Kernel, Op::Div, F32),
        );
    }

    #[test]
    fn every_op_seeds_both_candidates_from_history() {
        // One record carrying a history key for every (op, candidate)
        // pair: after seeding, no cell of any op may still sit on its
        // static prior, and the seeded values must match the inverted
        // medians exactly.
        let mut rec = Json::obj();
        rec.set("bench", "coordinator_serve".into());
        rec.set("kernel_div_per_s", Json::Num(2.0e8));
        rec.set("goldschmidt_div_per_s_f32", Json::Num(1.0e8));
        for op in [Op::Recip, Op::Rsqrt, Op::ScaleByRecip] {
            for c in Candidate::all() {
                let per_s = 1.0e8 * (1 + op.idx() + c.idx()) as f64;
                rec.set(
                    &format!("{}_div_per_s_{}", op.key_name(), c.name()),
                    Json::Num(per_s),
                );
            }
        }
        let router = BackendRouter::new(41);
        router.seed_from_history(&[rec]);
        let state = router.state.lock().unwrap();
        for &op in Op::ALL.iter() {
            let cell = &state.cells[cell_idx(op, F32, Rounding::NearestEven, 64)];
            for c in Candidate::all() {
                let seeded = cell.stats[c.idx()].per_lane;
                assert_ne!(
                    seeded,
                    prior_per_lane(c, op, F32),
                    "{}/{} cell still on the static prior after seeding",
                    op.name(),
                    c.name()
                );
                let expect = match (op, c) {
                    (Op::Div, Candidate::Kernel) => 1.0 / 2.0e8,
                    (Op::Div, Candidate::Goldschmidt) => 1.0 / 1.0e8,
                    _ => 1.0 / (1.0e8 * (1 + op.idx() + c.idx()) as f64),
                };
                assert!(
                    (seeded - expect).abs() < expect * 1e-12,
                    "{}/{}: seeded {seeded:e} vs expected {expect:e}",
                    op.name(),
                    c.name()
                );
            }
        }
    }

    #[test]
    fn ops_score_in_independent_cells() {
        let router = BackendRouter::with_epsilon(31, EXPLORATION_FLOOR);
        // Same (fmt, rm, lanes), different ops: flip rsqrt toward
        // goldschmidt while div keeps favouring the kernel.
        for _ in 0..COLD_FLOOR + 20 {
            router.observe(
                Op::Rsqrt,
                F32,
                Rounding::NearestEven,
                64,
                Candidate::Goldschmidt,
                Duration::from_micros(1),
            );
            router.observe(
                Op::Rsqrt,
                F32,
                Rounding::NearestEven,
                64,
                Candidate::Kernel,
                Duration::from_micros(50),
            );
            router.observe(
                Op::Div,
                F32,
                Rounding::NearestEven,
                64,
                Candidate::Kernel,
                Duration::from_micros(1),
            );
            router.observe(
                Op::Div,
                F32,
                Rounding::NearestEven,
                64,
                Candidate::Goldschmidt,
                Duration::from_micros(50),
            );
        }
        let (mut rsqrt_gold, mut div_kernel) = (0, 0);
        let total = 200;
        for _ in 0..total {
            if router.pick(Op::Rsqrt, F32, Rounding::NearestEven, 64) == Candidate::Goldschmidt {
                rsqrt_gold += 1;
            }
            if router.pick(Op::Div, F32, Rounding::NearestEven, 64) == Candidate::Kernel {
                div_kernel += 1;
            }
        }
        assert!(rsqrt_gold > total * 8 / 10, "rsqrt→goldschmidt {rsqrt_gold}/{total}");
        assert!(div_kernel > total * 8 / 10, "div→kernel {div_kernel}/{total}");
    }

    #[test]
    fn non_serve_records_are_ignored_and_fallback_is_the_prior() {
        let mut rec = Json::obj();
        rec.set("bench", "kernel_formats".into());
        rec.set("kernel_div_per_s", Json::Num(1.0));
        let router = BackendRouter::new(3);
        router.seed_from_history(&[rec]);
        let state = router.state.lock().unwrap();
        let cell = &state.cells[cell_idx(Op::Div, F32, Rounding::NearestEven, 8)];
        assert_eq!(
            cell.stats[Candidate::Kernel.idx()].per_lane,
            prior_per_lane(Candidate::Kernel, Op::Div, F32),
            "non-serve records must not disturb the static prior"
        );
    }

    #[test]
    fn win_rate_and_dispatch_counters_track_observations() {
        let router = BackendRouter::new(1);
        assert_eq!(router.win_rate(Candidate::Kernel), 0.0);
        assert_eq!(router.dispatches(Candidate::Kernel), 0);
        router.observe(
            Op::Div,
            F32,
            Rounding::NearestEven,
            128,
            Candidate::Kernel,
            Duration::from_micros(1),
        );
        router.observe(
            Op::Div,
            F32,
            Rounding::NearestEven,
            128,
            Candidate::Goldschmidt,
            Duration::from_micros(9),
        );
        assert_eq!(router.win_rate(Candidate::Kernel), 1.0);
        assert_eq!(router.win_rate(Candidate::Goldschmidt), 0.0);
        let c = router.pick(Op::Div, F32, Rounding::NearestEven, 128);
        assert_eq!(router.dispatches(c), 1);
    }

    #[test]
    fn buckets_split_batch_sizes_by_log2() {
        assert_eq!(bucket_idx(1), 0);
        assert_eq!(bucket_idx(2), 1);
        assert_eq!(bucket_idx(3), 1);
        assert_eq!(bucket_idx(4), 2);
        assert_eq!(bucket_idx(1 << 16), NUM_BUCKETS - 1);
        assert_eq!(bucket_idx(usize::MAX), NUM_BUCKETS - 1);
        // Distinct buckets are distinct cells for the same key.
        assert_ne!(
            cell_idx(Op::Div, F32, Rounding::NearestEven, 2),
            cell_idx(Op::Div, F32, Rounding::NearestEven, 4)
        );
        // Distinct ops are distinct cells for the same traffic shape,
        // and every cell index stays inside the table.
        let mut seen = std::collections::HashSet::new();
        for &op in Op::ALL.iter() {
            let i = cell_idx(op, F32, Rounding::NearestEven, 64);
            assert!(i < NUM_CELLS);
            assert!(seen.insert(i), "op cells must not collide");
        }
        // And zero lanes does not panic.
        assert_eq!(bucket_idx(0), 0);
    }
}
