//! Differential fuzzing across the three datapaths.
//!
//! The property suite samples fixed configurations; the fuzzer samples
//! the *configuration space itself*: every case draws a random
//! `(op, format, rounding, tile, lane engine, trunc_bits)` tuple plus
//! an adversarial operand pattern, runs the same lanes through the
//! Taylor kernel, the Goldschmidt kernel and the exactly-rounded gold
//! reference, and checks the documented conformance contract lane by
//! lane (specials bit-identical, finite lanes inside the per-datapath
//! ulp band, NaN lanes NaN on both sides).
//!
//! Reproducibility is the core invariant: the case stream is a pure
//! function of the master seed (case `k` is generated from the `k`-th
//! output of a `SplitMix64` stream over it), so any failure is
//! replayable from the two numbers the report line prints. On a
//! mismatch the driver first shrinks to the single faulting lane
//! (re-verifying that the shrunk case still fails) and then emits one
//! self-contained reproducer line with the full configuration, operand
//! bits and a copy-paste `tsdiv fuzz` replay command.
//!
//! Driven by `tsdiv fuzz --cases N --seed S` and, with a small budget,
//! by the unit suite below.

use crate::coordinator::{Backend, BackendChoice};
use crate::divider::{prepare, Prepared};
use crate::fp::{ulp_diff, unpack, Class, Format, Op, Rounding, ALL_FORMATS, F64};
use crate::harness::special_patterns;
use crate::kernel::KernelConfig;
use crate::simd::SimdChoice;
use crate::util::rng::{Rng, SplitMix64};

/// Adversarial operand patterns the generator draws from.
pub const PATTERNS: [&str; 5] = [
    "uniform",
    "limb-boundary",
    "subnormal-cluster",
    "repeated-divisor",
    "specials-heavy",
];

/// Lane-tile widths the generator draws from (deliberately including
/// widths that leave ragged tail tiles at common batch sizes).
const TILES: [usize; 8] = [1, 2, 3, 4, 8, 13, 16, 32];

/// Fuzzing budget and master seed.
#[derive(Clone, Copy, Debug)]
pub struct FuzzConfig {
    pub cases: u64,
    pub seed: u64,
}

/// One generated differential case: a full datapath configuration plus
/// operand vectors in the op's shape.
#[derive(Clone, Debug)]
pub struct FuzzCase {
    pub index: u64,
    pub op: Op,
    pub fmt: Format,
    pub rm: Rounding,
    pub tile: usize,
    pub simd: SimdChoice,
    pub trunc_bits: u32,
    pub pattern: &'static str,
    pub a: Vec<u64>,
    pub b: Vec<u64>,
    /// Per-row lane counts (`ScaleByRecip` only — always ragged here,
    /// so the fuzzer continuously exercises the ragged-row datapath).
    pub rows: Vec<u32>,
}

/// First lane where a datapath broke the conformance contract.
#[derive(Clone, Debug)]
pub struct CaseFailure {
    pub backend: &'static str,
    pub lane: usize,
    pub got: u64,
    pub gold: u64,
    pub detail: String,
}

/// What a fuzzing run covered: `failures` holds one fully formatted
/// reproducer line per diverging case (empty = conformant), `digest`
/// folds every generated operand bit so replay determinism is a single
/// integer comparison.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FuzzOutcome {
    pub cases: u64,
    /// Input lanes checked through *each* datapath.
    pub lanes: u64,
    pub digest: u64,
    pub failures: Vec<String>,
}

/// One operand bit pattern under `pattern`'s distribution.
fn gen_operand(rng: &mut Rng, fmt: Format, pattern: &str) -> u64 {
    match pattern {
        "limb-boundary" => {
            // Significands at limb edges: empty, one ulp in from each
            // end, all ones, and the half-width bit — near 1.0 where
            // seed-segment boundaries live.
            let fracs = [
                0u64,
                1,
                fmt.frac_mask(),
                fmt.frac_mask() - 1,
                1u64 << (fmt.frac_bits / 2),
            ];
            let frac = *rng.choose(&fracs);
            let e = (fmt.bias() + rng.range_i64(-2, 2) as i32) as u64;
            fmt.assemble(rng.bool(0.5), e, frac)
        }
        "subnormal-cluster" => fmt.assemble(rng.bool(0.5), 0, 1 + rng.below(15)),
        "specials-heavy" if rng.bool(0.5) => *rng.choose(&special_patterns(fmt)),
        _ => rng.next_u64() & fmt.width_mask(),
    }
}

fn gen_vec(rng: &mut Rng, fmt: Format, pattern: &str, n: usize) -> Vec<u64> {
    (0..n).map(|_| gen_operand(rng, fmt, pattern)).collect()
}

/// Generate case `index` from its stream seed. Pure: the same
/// `(case_seed, index)` always yields the same case.
pub fn gen_case_from(case_seed: u64, index: u64) -> FuzzCase {
    let mut rng = Rng::new(case_seed);
    let op = *rng.choose(&Op::ALL);
    let fmt = *rng.choose(&ALL_FORMATS);
    let rm = *rng.choose(&Rounding::ALL);
    let tile = *rng.choose(&TILES);
    // Forced SIMD errors on hosts without a vector engine, so the
    // generator stays on the two choices that build everywhere.
    let simd = if rng.bool(0.5) { SimdChoice::Auto } else { SimdChoice::Scalar };
    let trunc_bits = if rng.bool(0.5) {
        0
    } else {
        let max = if fmt.frac_bits > 23 { 4 } else { 8 };
        rng.range_u64(1, max) as u32
    };
    let pattern = *rng.choose(&PATTERNS);
    let n = 1 + rng.below(96) as usize;
    let a = gen_vec(&mut rng, fmt, pattern, n);
    let (b, rows) = match op {
        Op::Div => {
            let b = if pattern == "repeated-divisor" {
                vec![gen_operand(&mut rng, fmt, "uniform"); n]
            } else {
                gen_vec(&mut rng, fmt, pattern, n)
            };
            (b, Vec::new())
        }
        Op::Recip | Op::Rsqrt => (Vec::new(), Vec::new()),
        Op::ScaleByRecip => {
            // Always ragged: random positive row lengths summing to n.
            let nrows = 1 + rng.below(n as u64) as usize;
            let mut rows = vec![1u32; nrows];
            for _ in 0..n - nrows {
                rows[rng.below(nrows as u64) as usize] += 1;
            }
            let b = if pattern == "repeated-divisor" {
                vec![gen_operand(&mut rng, fmt, "uniform"); nrows]
            } else {
                gen_vec(&mut rng, fmt, pattern, nrows)
            };
            (b, rows)
        }
    };
    FuzzCase {
        index,
        op,
        fmt,
        rm,
        tile,
        simd,
        trunc_bits,
        pattern,
        a,
        b,
        rows,
    }
}

/// Row index of each lane (`ScaleByRecip`); empty for the other ops.
fn lane_rows(case: &FuzzCase) -> Vec<usize> {
    let mut map = Vec::with_capacity(case.a.len());
    for (r, &len) in case.rows.iter().enumerate() {
        for _ in 0..len {
            map.push(r);
        }
    }
    map
}

/// Is this lane resolved by the shared special-case path (and therefore
/// required to be bit-identical to gold)? Mirrors the per-op detection
/// the property suite uses.
fn lane_is_special(case: &FuzzCase, lane: usize, row_of: &[usize]) -> bool {
    let fmt = case.fmt;
    let special =
        |bits: u64| matches!(unpack(bits, fmt).class, Class::NaN | Class::Inf | Class::Zero);
    match case.op {
        Op::Div => matches!(prepare(case.a[lane], case.b[lane], fmt), Prepared::Done(_)),
        Op::Recip => special(case.a[lane]),
        Op::Rsqrt => unpack(case.a[lane], fmt).sign || special(case.a[lane]),
        Op::ScaleByRecip => special(case.a[lane]) || special(case.b[row_of[lane]]),
    }
}

/// First contract violation of `got` vs `gold` under the `band`-ulp
/// finite-lane allowance.
fn divergence(
    case: &FuzzCase,
    backend: &'static str,
    band: u64,
    got: &[u64],
    gold: &[u64],
) -> Option<CaseFailure> {
    let fmt = case.fmt;
    let row_of = lane_rows(case);
    for (lane, (&k, &g)) in got.iter().zip(gold.iter()).enumerate() {
        let special = lane_is_special(case, lane, &row_of);
        let detail = match ulp_diff(k, g, fmt) {
            Some(0) => continue,
            Some(u) if special => {
                format!("special lane differs by {u} ulp (must be bit-identical)")
            }
            Some(u) if u > band => format!("{u} ulp exceeds the ≤{band}-ulp band"),
            Some(_) => continue,
            None => {
                if unpack(k, fmt).class == Class::NaN && unpack(g, fmt).class == Class::NaN {
                    continue;
                }
                "NaN class mismatch".to_string()
            }
        };
        return Some(CaseFailure {
            backend,
            lane,
            got: k,
            gold: g,
            detail,
        });
    }
    None
}

/// Run the case through all three datapaths and return the first
/// contract violation, if any.
pub fn check_case(case: &FuzzCase) -> Option<CaseFailure> {
    let cfg = KernelConfig {
        tile: case.tile,
        ilm_iterations: None,
        simd: case.simd,
    };
    let mut kern = BackendChoice::Kernel {
        order: 5,
        kernel: cfg,
    }
    .build()
    .expect("kernel backend");
    let mut gs = BackendChoice::Goldschmidt {
        iterations: 3,
        kernel: cfg,
        trunc_bits: case.trunc_bits,
    }
    .build()
    .expect("goldschmidt backend");
    let mut gold = BackendChoice::Gold.build().expect("gold backend");
    let qg = gold
        .compute(case.op, &case.a, &case.b, &case.rows, case.fmt, case.rm)
        .expect("gold compute");
    let qk = kern
        .compute(case.op, &case.a, &case.b, &case.rows, case.fmt, case.rm)
        .expect("kernel compute");
    let qs = gs
        .compute(case.op, &case.a, &case.b, &case.rows, case.fmt, case.rm)
        .expect("goldschmidt compute");
    // Documented bands: ≤1 ulp vs gold for ≤24-bit formats, ≤2 for f64;
    // truncated Goldschmidt multiplies add at most one more ulp.
    let band = if case.fmt == F64 { 2 } else { 1 };
    divergence(case, "kernel", band, &qk, &qg).or_else(|| {
        let gs_band = band + u64::from(case.trunc_bits > 0);
        divergence(case, "goldschmidt", gs_band, &qs, &qg)
    })
}

/// Reduce a faulting case to its single faulting lane (keeping the
/// lane's own row divisor for `ScaleByRecip`).
pub fn shrink_case(case: &FuzzCase, lane: usize) -> FuzzCase {
    let mut small = case.clone();
    small.a = vec![case.a[lane]];
    match case.op {
        Op::Div => small.b = vec![case.b[lane]],
        Op::Recip | Op::Rsqrt => small.b = Vec::new(),
        Op::ScaleByRecip => {
            small.b = vec![case.b[lane_rows(case)[lane]]];
            small.rows = vec![1];
        }
    }
    small
}

fn simd_name(simd: SimdChoice) -> &'static str {
    match simd {
        SimdChoice::Auto => "auto",
        SimdChoice::Forced => "forced",
        SimdChoice::Scalar => "scalar",
    }
}

fn hex_list(xs: &[u64]) -> String {
    xs.iter().map(|x| format!("{x:#x}")).collect::<Vec<_>>().join(",")
}

/// One self-contained reproducer line for a diverging case.
pub fn format_failure(master_seed: u64, case: &FuzzCase, f: &CaseFailure, shrunk: bool) -> String {
    let scope = if shrunk { "shrunk to 1 lane" } else { "unshrunk" };
    format!(
        "fuzz mismatch: case={} op={} fmt={} rm={} tile={} simd={} trunc={} pattern={} \
         backend={} lane={} got={:#x} gold={:#x} ({}) a=[{}] b=[{}] rows={:?} ({scope}) \
         [replay: tsdiv fuzz --seed {master_seed:#x} --cases {}]",
        case.index,
        case.op.name(),
        case.fmt.name(),
        case.rm.name(),
        case.tile,
        simd_name(case.simd),
        case.trunc_bits,
        case.pattern,
        f.backend,
        f.lane,
        f.got,
        f.gold,
        f.detail,
        hex_list(&case.a),
        hex_list(&case.b),
        case.rows,
        case.index + 1,
    )
}

fn mix(acc: u64, x: u64) -> u64 {
    SplitMix64::new(acc ^ x).next_u64()
}

/// Fold a case's seed and every operand bit into the running digest.
fn fold_digest(mut acc: u64, case_seed: u64, case: &FuzzCase) -> u64 {
    acc = mix(acc, case_seed);
    for &x in case.a.iter().chain(case.b.iter()) {
        acc = mix(acc, x);
    }
    for &r in &case.rows {
        acc = mix(acc, r as u64);
    }
    acc
}

/// Run the full differential budget. Pure in `cfg`: the same config
/// reproduces the same case stream, digest and failure lines.
pub fn run_fuzz(cfg: &FuzzConfig) -> FuzzOutcome {
    let mut stream = SplitMix64::new(cfg.seed);
    let mut out = FuzzOutcome {
        cases: cfg.cases,
        lanes: 0,
        digest: 0,
        failures: Vec::new(),
    };
    for index in 0..cfg.cases {
        let case_seed = stream.next_u64();
        let case = gen_case_from(case_seed, index);
        out.digest = fold_digest(out.digest, case_seed, &case);
        out.lanes += case.a.len() as u64;
        if let Some(first) = check_case(&case) {
            let small = shrink_case(&case, first.lane);
            let line = match check_case(&small) {
                Some(sf) => format_failure(cfg.seed, &small, &sf, true),
                None => format_failure(cfg.seed, &case, &first, false),
            };
            out.failures.push(line);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_seed_replays_identically() {
        let cfg = FuzzConfig { cases: 16, seed: 0xDEAD_BEEF };
        let a = run_fuzz(&cfg);
        let b = run_fuzz(&cfg);
        assert_eq!(a, b, "a seed must replay to the identical run");
        let c = run_fuzz(&FuzzConfig { cases: 16, seed: 0xDEAD_BEF0 });
        assert_ne!(a.digest, c.digest, "different seeds must diverge");
    }

    #[test]
    fn generated_cases_have_valid_shapes() {
        let mut stream = SplitMix64::new(99);
        let mut ops_seen = [false; 4];
        for index in 0..64 {
            let case = gen_case_from(stream.next_u64(), index);
            ops_seen[case.op.idx()] = true;
            assert!((1..=96).contains(&case.a.len()));
            assert!(TILES.contains(&case.tile));
            assert!(PATTERNS.contains(&case.pattern));
            let max_trunc = if case.fmt.frac_bits > 23 { 4 } else { 8 };
            assert!(case.trunc_bits <= max_trunc);
            let mask = case.fmt.width_mask();
            assert!(case.a.iter().chain(case.b.iter()).all(|&x| x & !mask == 0));
            match case.op {
                Op::Div => {
                    assert_eq!(case.a.len(), case.b.len());
                    assert!(case.rows.is_empty());
                }
                Op::Recip | Op::Rsqrt => {
                    assert!(case.b.is_empty() && case.rows.is_empty());
                }
                Op::ScaleByRecip => {
                    assert_eq!(case.rows.len(), case.b.len());
                    assert!(case.rows.iter().all(|&r| r > 0));
                    let total: usize = case.rows.iter().map(|&r| r as usize).sum();
                    assert_eq!(total, case.a.len());
                }
            }
        }
        assert!(ops_seen.iter().all(|&s| s), "64 cases should draw every op");
    }

    #[test]
    fn small_budget_finds_no_divergence() {
        // The in-suite smoke: a small budget through the real checker
        // must come back clean on conformant datapaths.
        let out = run_fuzz(&FuzzConfig { cases: 24, seed: 7 });
        assert!(out.failures.is_empty(), "{:#?}", out.failures);
        assert_eq!(out.cases, 24);
        assert!(out.lanes >= 24);
    }

    #[test]
    fn shrink_keeps_the_ragged_lane_row_pairing() {
        let mut case = gen_case_from(1, 0);
        case.op = Op::ScaleByRecip;
        case.a = (0..9u64).map(|i| 0x100 + i).collect();
        case.b = vec![0xA, 0xB, 0xC];
        case.rows = vec![2, 3, 4];
        // Lane 5 lives in row 2 (lanes 0-1 → row 0, 2-4 → row 1).
        let small = shrink_case(&case, 5);
        assert_eq!(small.a, vec![0x105]);
        assert_eq!(small.b, vec![0xC]);
        assert_eq!(small.rows, vec![1]);
        // Div shrinks keep the paired divisor.
        case.op = Op::Div;
        case.b = (0..9u64).map(|i| 0x200 + i).collect();
        case.rows = Vec::new();
        let small = shrink_case(&case, 4);
        assert_eq!((small.a.clone(), small.b.clone()), (vec![0x104], vec![0x204]));
        assert!(small.rows.is_empty());
    }

    #[test]
    fn failure_lines_carry_the_replay_command() {
        let case = gen_case_from(42, 6);
        let f = CaseFailure {
            backend: "kernel",
            lane: 0,
            got: 1,
            gold: 2,
            detail: "synthetic".into(),
        };
        let line = format_failure(0x2A, &case, &f, true);
        assert!(line.contains("replay: tsdiv fuzz --seed 0x2a --cases 7"));
        assert!(line.contains("backend=kernel"));
        assert!(line.contains("(synthetic)"));
        assert!(!line.contains('\n'), "reproducer must be a single line");
    }
}
