//! In-tree mutation smoke harness over the rounding and seeding layers.
//!
//! A small table of hand-picked mutants — an operator flip, off-by-one
//! boundaries, a dropped sticky chain, a skipped renormalize — is
//! compiled into the datapath behind `cfg(any(test, feature =
//! "mutation"))` injection points (in [`crate::fp::round`] and
//! [`crate::pla`]). Activating a mutant flips exactly one decision on
//! the current thread; the harness then replays a battery of contract
//! checks distilled from the unit suites of those modules and asserts
//! every mutant is **killed** (at least one check fails). This guards
//! the guards: a rounding suite that silently stopped observing the
//! sticky chain or the carry-out renormalize would let a mutant
//! survive, and the smoke test turns that survival into a failure with
//! the mutant's name in it.
//!
//! The active-mutant cell is thread-local, so the parallel test runner
//! cannot leak a mutant into an unrelated test, and the injection
//! points compile to nothing in normal release builds (the `mutation`
//! cargo feature carries them into a release binary for out-of-tree
//! tooling).

use std::cell::Cell;

use crate::fp::{round_pack, Rounding, F16, F32};

/// One hand-picked defect, injectable at a named datapath decision.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mutant {
    /// `fp::round`: discard the sticky bit after normalization — the
    /// classic "guard bit only" rounding defect.
    DropSticky,
    /// `fp::round`: the nearest-even tie decision loses its LSB-parity
    /// term (`guard && (sticky || lsb_odd)` → `guard && sticky`), so
    /// true ties never round up.
    TieDropsParity,
    /// `fp::round`: overflow comparison off by one (`exp > emax` →
    /// `exp >= emax`), turning the entire top finite binade into Inf.
    OverflowBoundaryOffByOne,
    /// `fp::round`: skip the renormalize after a rounding carry-out,
    /// leaving an all-ones significand rounded into the wrong binade.
    SkipCarryRenorm,
    /// `pla::segment_index`: the left-closed boundary compare flipped
    /// to right-closed (`x < edge` → `x <= edge`), seeding boundary
    /// operands from the segment below the one that owns them.
    SegmentBoundaryOffByOne,
}

impl Mutant {
    /// Every mutant in the table, in stable order.
    pub const ALL: [Mutant; 5] = [
        Mutant::DropSticky,
        Mutant::TieDropsParity,
        Mutant::OverflowBoundaryOffByOne,
        Mutant::SkipCarryRenorm,
        Mutant::SegmentBoundaryOffByOne,
    ];

    /// Short stable name (smoke-report lines).
    pub const fn name(self) -> &'static str {
        match self {
            Mutant::DropSticky => "drop-sticky",
            Mutant::TieDropsParity => "tie-drops-parity",
            Mutant::OverflowBoundaryOffByOne => "overflow-boundary-off-by-one",
            Mutant::SkipCarryRenorm => "skip-carry-renorm",
            Mutant::SegmentBoundaryOffByOne => "segment-boundary-off-by-one",
        }
    }
}

thread_local! {
    static ACTIVE: Cell<Option<Mutant>> = const { Cell::new(None) };
}

/// Is `m` the active mutant on this thread? Queried by the injection
/// points; `false` everywhere outside a [`with_mutant`] scope.
pub fn is_active(m: Mutant) -> bool {
    ACTIVE.with(|a| a.get() == Some(m))
}

/// The active mutant on this thread, if any (diagnostics).
pub fn active() -> Option<Mutant> {
    ACTIVE.with(|a| a.get())
}

/// Run `f` with mutant `m` active on this thread, restoring the
/// previous state afterwards (panic-safe via an RAII guard, so an
/// asserting check cannot leak a live mutant into later tests).
pub fn with_mutant<T>(m: Mutant, f: impl FnOnce() -> T) -> T {
    struct Restore(Option<Mutant>);
    impl Drop for Restore {
        fn drop(&mut self) {
            ACTIVE.with(|a| a.set(self.0));
        }
    }
    let _restore = Restore(ACTIVE.with(|a| a.replace(Some(m))));
    f()
}

/// One contract check replayed under each mutant; `run` returns `true`
/// when the datapath behaves correctly. Each is distilled from a named
/// behavior the unit suites of `fp::round` / `pla` already pin, so a
/// kill is attributable to an independently-tested contract.
#[derive(Clone, Copy)]
pub struct KillCheck {
    pub name: &'static str,
    pub run: fn() -> bool,
}

fn check_exact_pack() -> bool {
    // 1.0 presented at q = 60: packs exactly, inexact clear.
    let (bits, inexact) = round_pack(false, 0, 1 << 60, 60, false, F32, Rounding::NearestEven);
    bits as u32 == 1.0f32.to_bits() && !inexact
}

fn check_sticky_tie() -> bool {
    // 1 + 2^-24 + 2^-40: just above the halfway point, so the sticky
    // bit must push nearest-even up to 1 + 2^-23.
    let q = 40u32;
    let sig = (1u128 << q) + (1u128 << (q - 24)) + 1;
    let (bits, _) = round_pack(false, 0, sig, q, false, F32, Rounding::NearestEven);
    bits as u32 == (1.0f32 + 2f32.powi(-23)).to_bits()
}

fn check_tie_parity() -> bool {
    // 1 + 3·2^-24: a true tie (guard set, sticky clear) with an odd
    // kept LSB — parity must round it up to the even 1 + 2^-22.
    let q = 40u32;
    let sig = (1u128 << q) + 3 * (1u128 << (q - 24));
    let (bits, _) = round_pack(false, 0, sig, q, false, F32, Rounding::NearestEven);
    bits as u32 == (1.0f32 + 2.0 * 2f32.powi(-23)).to_bits()
}

fn check_top_binade() -> bool {
    // 2^15 sits at f16's emax and is finite (max finite is 65504);
    // only exponents *above* emax overflow to Inf.
    let (bits, _) = round_pack(false, 15, 1 << 30, 30, false, F16, Rounding::NearestEven);
    bits == F16.assemble(false, (15 + F16.bias()) as u64, 0)
}

fn check_carry_renorm() -> bool {
    // 25 ones at q = 24 ≈ 2·(1 − 2^-25): the rounding carry must
    // propagate out of the significand and bump the result to 2.0.
    let sig = (1u128 << 25) - 1;
    let (bits, _) = round_pack(false, 0, sig, 24, false, F32, Rounding::NearestEven);
    bits as u32 == 2.0f32.to_bits()
}

fn check_segment_edges() -> bool {
    // A boundary operand belongs to the segment it *opens*: 1.25 is in
    // segment 1 of [1.0, 1.25, 1.5, 2.0], and lookups clamp at the top.
    let bounds = [1.0, 1.25, 1.5, 2.0];
    crate::pla::segment_index(&bounds, 1.25) == 1
        && crate::pla::segment_index(&bounds, 1.0) == 0
        && crate::pla::segment_index(&bounds, 2.5) == 2
}

/// The full check battery, in attribution order.
pub fn kill_checks() -> [KillCheck; 6] {
    [
        KillCheck { name: "exact value packs exactly", run: check_exact_pack },
        KillCheck { name: "sticky breaks a near-tie upward", run: check_sticky_tie },
        KillCheck { name: "true tie rounds to even by parity", run: check_tie_parity },
        KillCheck { name: "top finite binade stays finite", run: check_top_binade },
        KillCheck { name: "rounding carry-out renormalizes", run: check_carry_renorm },
        KillCheck { name: "segment boundaries are left-closed", run: check_segment_edges },
    ]
}

/// Outcome of one mutant's smoke run.
#[derive(Clone, Copy, Debug)]
pub struct MutantVerdict {
    pub mutant: Mutant,
    /// The first check the mutant failed (`None` = the mutant survived
    /// the whole battery, which the smoke test treats as a bug).
    pub killed_by: Option<&'static str>,
}

impl MutantVerdict {
    pub fn killed(&self) -> bool {
        self.killed_by.is_some()
    }
}

/// Activate each mutant in turn and replay the battery; a mutant is
/// killed when at least one check fails under it.
pub fn run_mutation_smoke() -> Vec<MutantVerdict> {
    Mutant::ALL
        .iter()
        .map(|&mutant| {
            let killed_by = with_mutant(mutant, || {
                kill_checks().iter().find(|c| !(c.run)()).map(|c| c.name)
            });
            MutantVerdict { mutant, killed_by }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn baseline_battery_is_green() {
        assert_eq!(active(), None, "a previous test leaked an active mutant");
        for c in kill_checks() {
            assert!((c.run)(), "baseline check '{}' failed with no mutant active", c.name);
        }
    }

    #[test]
    fn every_mutant_is_killed() {
        for v in run_mutation_smoke() {
            assert!(
                v.killed(),
                "mutant '{}' survived the battery — a rounding/seeding \
                 contract has lost its witness",
                v.mutant.name()
            );
            println!("mutant '{}' killed by '{}'", v.mutant.name(), v.killed_by.unwrap());
        }
    }

    #[test]
    fn mutant_state_is_scoped_and_thread_local() {
        let observed = with_mutant(Mutant::DropSticky, || {
            let here = is_active(Mutant::DropSticky);
            // A fresh thread must not see this thread's mutant.
            let elsewhere = std::thread::spawn(active).join().unwrap();
            (here, elsewhere)
        });
        assert_eq!(observed, (true, None));
        assert_eq!(active(), None, "scope exit must clear the mutant");
        // Nested scopes restore the outer mutant, not None.
        with_mutant(Mutant::TieDropsParity, || {
            with_mutant(Mutant::DropSticky, || {
                assert!(is_active(Mutant::DropSticky));
                assert!(!is_active(Mutant::TieDropsParity));
            });
            assert!(is_active(Mutant::TieDropsParity));
        });
    }

    #[test]
    fn scope_clears_on_panic() {
        let caught = std::panic::catch_unwind(|| {
            with_mutant(Mutant::SkipCarryRenorm, || panic!("boom"));
        });
        assert!(caught.is_err());
        assert_eq!(active(), None, "panic must not leak the mutant");
    }

    #[test]
    fn names_are_unique_and_stable() {
        let names: Vec<&str> = Mutant::ALL.iter().map(|m| m.name()).collect();
        let mut deduped = names.clone();
        deduped.sort_unstable();
        deduped.dedup();
        assert_eq!(deduped.len(), Mutant::ALL.len(), "duplicate mutant names in {names:?}");
    }
}
