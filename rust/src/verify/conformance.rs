//! Sharded exhaustive-divisor binary32 conformance sweeps.
//!
//! f16 is small enough to sweep in one `#[ignore]`d test
//! (`tests/conformance_f16.rs`); f32 is not — its divisor space alone
//! is 2^23 mantissas × the interesting exponent binades, and a naive
//! full cross against the dividend menu and all rounding modes is tens
//! of billions of lanes. This module makes the f32 sweep *shardable*
//! instead: the mantissa space is partitioned into deterministic,
//! disjoint slices keyed by `(slice_index, slice_count)` — slice `s`
//! owns every mantissa ≡ `s (mod count)` — so any machine can sweep any
//! slice independently and a rotating CI pass covers the whole space
//! over successive runs with no coordination and no repetition.
//!
//! Two entry points:
//!
//! * [`sweep_f32_slice`] — the **complete cross** (7 exponent binades ×
//!   4 rounding modes × the 17-dividend menu) over one mantissa slice.
//!   At the CI default of 1024 slices this is ~3.9 M lanes per backend
//!   per slice.
//! * [`sweep_f32_full`] — every one of the 2^23 mantissas exactly once,
//!   with the (exponent, rounding) pair rotating with period 28 so all
//!   combinations appear throughout the space: ~143 M lanes per
//!   backend, about a minute in release. Run from the `#[ignore]`d
//!   test in `tests/conformance_f32.rs`.
//!
//! Every lane goes through the Taylor [`BackendChoice::Kernel`] *and*
//! the [`BackendChoice::Goldschmidt`] datapath, each checked against
//! the exactly-rounded `Gold` long divider: special lanes (resolved by
//! the shared `prepare()` path) must be bit-identical, finite lanes
//! must stay inside the documented ≤ 2-ulp band, and NaN lanes must be
//! NaN on both sides. Divisor sign alternates with mantissa parity so
//! both sign datapaths are exercised at every binade without doubling
//! the sweep.

use crate::coordinator::{Backend, BackendChoice};
use crate::divider::{prepare, Prepared};
use crate::fp::{ulp_diff, unpack, Class, Rounding, F32};
use crate::harness::special_patterns;
use crate::kernel::KernelConfig;

/// Size of the f32 mantissa space being sharded.
pub const F32_MANTISSAS: u64 = 1 << 23;

/// Divisor exponent binades swept per slice (biased): the subnormal
/// binade, the smallest normal, the two binades around 1.0, the binade
/// above, the top finite binade and the Inf/NaN binade.
pub const DIVISOR_EXPONENTS: [u64; 7] = [0, 1, 126, 127, 128, 254, 255];

/// Divisor block size fed to the backends per call: big enough to
/// amortize dispatch, small enough to keep peak memory trivial.
const BLOCK: usize = 1 << 15;

/// The mantissas owned by `slice` out of `count` shards: every `m` in
/// `0..2^23` with `m ≡ slice (mod count)`, ascending. Slices are
/// disjoint by congruence and jointly cover the space exactly once.
pub fn slice_mantissas(slice: u64, count: u64) -> impl Iterator<Item = u64> {
    assert!(count > 0, "slice count must be positive");
    (slice % count..F32_MANTISSAS).step_by(count as usize)
}

/// The fixed dividend menu: the full special-pattern set (NaN, ±Inf,
/// ±0, smallest/largest subnormal, 1.0, max finite) plus finite probes
/// mirroring the f16 sweep — negatives, an exact power of two,
/// non-trivial significands, the smallest normal on both signs and a
/// near-overflow value.
pub fn f32_dividends() -> Vec<u64> {
    let mut d: Vec<u64> = special_patterns(F32).to_vec();
    d.extend([
        0xBF80_0000, // -1.0
        0x4000_0000, // 2.0
        0x3EAA_AAAB, // ~0.3333
        0x4049_0FDB, // ~3.1416
        0x0080_0000, // smallest positive normal
        0x8080_0000, // smallest negative normal
        0x7F7F_FFFE, // just below +max finite
        0xBE4C_CCCD, // ~-0.2
    ]);
    d
}

/// What one sweep covered and the worst finite deviation it observed
/// per datapath. `PartialEq` so determinism is testable: sweeping the
/// same `(slice, count)` twice must yield identical reports.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SliceReport {
    /// Distinct divisor bit patterns swept.
    pub divisors: u64,
    /// Lanes checked through *each* of the three backends.
    pub lanes_per_backend: u64,
    /// Worst finite kernel-vs-gold deviation, in ulp.
    pub max_ulp_kernel: u64,
    /// Worst finite goldschmidt-vs-gold deviation, in ulp.
    pub max_ulp_goldschmidt: u64,
}

/// The three datapaths under test plus the running report.
struct Sweeper {
    kern: Box<dyn Backend>,
    gs: Box<dyn Backend>,
    gold: Box<dyn Backend>,
    dividends: Vec<u64>,
    report: SliceReport,
}

/// Check one backend's block against gold, panicking with a replayable
/// lane identification on any contract violation. Returns the largest
/// finite deviation in the block.
fn check_lanes(
    label: &str,
    got: &[u64],
    gold: &[u64],
    a: u64,
    divisors: &[u64],
    rm: Rounding,
) -> u64 {
    let mut max_ulp = 0u64;
    for (i, (&k, &g)) in got.iter().zip(gold.iter()).enumerate() {
        let b = divisors[i];
        let special = matches!(prepare(a, b, F32), Prepared::Done(_));
        match ulp_diff(k, g, F32) {
            Some(u) if special => assert_eq!(
                k, g,
                "special lane {a:#010x}/{b:#010x} ({rm:?}) not bit-identical: \
                 {label} {k:#010x} vs gold {g:#010x} ({u} ulp)"
            ),
            Some(u) => {
                assert!(
                    u <= 2,
                    "finite lane {a:#010x}/{b:#010x} ({rm:?}) outside the ≤2-ulp \
                     band: {label} {k:#010x} vs gold {g:#010x} ({u} ulp)"
                );
                max_ulp = max_ulp.max(u);
            }
            None => assert!(
                unpack(k, F32).class == Class::NaN && unpack(g, F32).class == Class::NaN,
                "NaN mismatch at {a:#010x}/{b:#010x} ({rm:?}): \
                 {label} {k:#010x} vs gold {g:#010x}"
            ),
        }
    }
    max_ulp
}

impl Sweeper {
    fn new() -> Self {
        let kern = BackendChoice::Kernel {
            order: 5,
            kernel: KernelConfig::default(),
        }
        .build()
        .expect("kernel backend");
        let gs = BackendChoice::Goldschmidt {
            iterations: 3,
            kernel: KernelConfig::default(),
            trunc_bits: 0,
        }
        .build()
        .expect("goldschmidt backend");
        let gold = BackendChoice::Gold.build().expect("gold backend");
        Sweeper {
            kern,
            gs,
            gold,
            dividends: f32_dividends(),
            report: SliceReport::default(),
        }
    }

    /// Run every dividend against `divisors` under `rm` through all
    /// three backends and fold the contract checks into the report.
    fn check_block(&mut self, rm: Rounding, divisors: &[u64]) {
        for &a in &self.dividends {
            let av = vec![a; divisors.len()];
            let qg = self.gold.divide(&av, divisors, F32, rm).expect("gold divide");
            let qk = self.kern.divide(&av, divisors, F32, rm).expect("kernel divide");
            let qs = self.gs.divide(&av, divisors, F32, rm).expect("goldschmidt divide");
            let uk = check_lanes("kernel", &qk, &qg, a, divisors, rm);
            let us = check_lanes("goldschmidt", &qs, &qg, a, divisors, rm);
            self.report.max_ulp_kernel = self.report.max_ulp_kernel.max(uk);
            self.report.max_ulp_goldschmidt = self.report.max_ulp_goldschmidt.max(us);
        }
        self.report.lanes_per_backend += (divisors.len() * self.dividends.len()) as u64;
    }
}

/// Assemble divisor bit patterns for a block of mantissas at one
/// exponent binade; sign alternates with mantissa parity.
fn divisor_block(mantissas: &[u64], exp: u64) -> Vec<u64> {
    mantissas.iter().map(|&m| F32.assemble(m & 1 == 1, exp, m)).collect()
}

/// The complete cross — every [`DIVISOR_EXPONENTS`] binade × every
/// rounding mode × the full dividend menu — over the mantissas of one
/// deterministic slice. Panics on any conformance violation; returns
/// the coverage/deviation report otherwise.
pub fn sweep_f32_slice(slice: u64, count: u64) -> SliceReport {
    let mut sweeper = Sweeper::new();
    let mantissas: Vec<u64> = slice_mantissas(slice, count).collect();
    for &exp in &DIVISOR_EXPONENTS {
        for chunk in mantissas.chunks(BLOCK) {
            let divisors = divisor_block(chunk, exp);
            sweeper.report.divisors += divisors.len() as u64;
            for rm in Rounding::ALL {
                sweeper.check_block(rm, &divisors);
            }
        }
    }
    sweeper.report
}

/// Every one of the 2^23 mantissas exactly once, with the (exponent,
/// rounding) pair rotating with period 28 = 7 binades × 4 modes:
/// sub-slice `p` of 28 sweeps its mantissas at `DIVISOR_EXPONENTS[p %
/// 7]` under `Rounding::ALL[p / 7]`. Each combination therefore lands
/// on a different residue class of the mantissa space, and the union
/// covers it with no repetition (~143 M lanes per backend).
pub fn sweep_f32_full() -> SliceReport {
    let mut sweeper = Sweeper::new();
    for p in 0..28u64 {
        let exp = DIVISOR_EXPONENTS[(p % 7) as usize];
        let rm = Rounding::ALL[(p / 7) as usize];
        let mut mantissas = slice_mantissas(p, 28);
        loop {
            let chunk: Vec<u64> = mantissas.by_ref().take(BLOCK).collect();
            if chunk.is_empty() {
                break;
            }
            let divisors = divisor_block(&chunk, exp);
            sweeper.report.divisors += divisors.len() as u64;
            sweeper.check_block(rm, &divisors);
        }
    }
    sweeper.report
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Expected slice length by the partition formula.
    fn slice_len(slice: u64, count: u64) -> u64 {
        (F32_MANTISSAS - slice % count).div_ceil(count)
    }

    #[test]
    fn slices_partition_the_mantissa_space() {
        // Lengths follow the formula and sum to the whole space.
        let count = 1024u64;
        let mut total = 0u64;
        for s in 0..count {
            total += slice_len(s, count);
        }
        assert_eq!(total, F32_MANTISSAS);
        // Spot-check the iterator against the formula at a coarse count.
        let count = 1 << 20;
        for s in [0u64, 1, 12_345, count - 1, count + 3] {
            let got: Vec<u64> = slice_mantissas(s, count).collect();
            assert_eq!(got.len() as u64, slice_len(s, count), "slice {s}");
            assert!(got.iter().all(|&m| m % count == s % count));
            assert!(got.windows(2).all(|w| w[1] == w[0] + count));
            assert!(got.iter().all(|&m| m < F32_MANTISSAS));
        }
        // Out-of-range indices wrap: slice `count + 3` IS slice 3.
        let a: Vec<u64> = slice_mantissas(3, count).collect();
        let b: Vec<u64> = slice_mantissas(count + 3, count).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn distinct_slices_are_disjoint() {
        let count = 1 << 20;
        let a: Vec<u64> = slice_mantissas(0, count).collect();
        let b: Vec<u64> = slice_mantissas(1, count).collect();
        assert!(a.iter().all(|m| !b.contains(m)));
    }

    #[test]
    fn dividend_menu_covers_every_class_and_both_signs() {
        let menu = f32_dividends();
        let mut classes = [false; 5];
        let mut signs = [false; 2];
        for &d in &menu {
            let u = unpack(d, F32);
            let i = match u.class {
                Class::NaN => 0,
                Class::Inf => 1,
                Class::Zero => 2,
                Class::Subnormal => 3,
                Class::Normal => 4,
            };
            classes[i] = true;
            if u.class == Class::Normal {
                signs[usize::from(u.sign)] = true;
            }
        }
        assert!(classes.iter().all(|&c| c), "menu misses an IEEE class");
        assert!(signs.iter().all(|&s| s), "menu misses a normal sign");
        assert_eq!(menu.len(), 17);
    }

    #[test]
    fn tiny_slice_sweep_is_deterministic_and_counts_lanes() {
        // 4 mantissas per slice at count = 2^21: cheap enough for the
        // debug-mode suite, yet it drives the full cross machinery.
        let count = 1 << 21;
        let r1 = sweep_f32_slice(5, count);
        let r2 = sweep_f32_slice(5, count);
        assert_eq!(r1, r2, "same (slice, count) must reproduce bit-identically");
        assert_eq!(r1.divisors, 4 * DIVISOR_EXPONENTS.len() as u64);
        let dividends = f32_dividends().len() as u64;
        assert_eq!(r1.lanes_per_backend, r1.divisors * 4 * dividends);
        assert!(r1.max_ulp_kernel <= 2 && r1.max_ulp_goldschmidt <= 2);
    }
}
