//! Production-scale verification: the machinery that checks the
//! datapaths *at scale* rather than at sampled points.
//!
//! Three legs, one contract (specials bit-identical to gold, finite
//! lanes inside the documented ulp band, NaN lanes NaN on both sides):
//!
//! * [`conformance`] — sharded exhaustive-divisor binary32 sweeps: the
//!   2^23-mantissa divisor space partitioned into deterministic slices
//!   keyed by `(slice_index, slice_count)`, so CI can rotate through
//!   the space one slice per run and any failure names a replayable
//!   slice. Driven by `tests/conformance_f32.rs`.
//! * [`fuzz`] — differential fuzzing over the *configuration* space:
//!   random `(op, format, rounding, tile, simd, trunc_bits)` tuples
//!   plus adversarial operand patterns through all three datapaths,
//!   with seed-replayable single-line reproducers. Driven by
//!   `tsdiv fuzz`.
//! * [`mutation`] — an in-tree mutation smoke harness: hand-picked
//!   defects compiled into the rounding/seeding layers behind cfg'd
//!   injection points, with a check battery that must kill every one.
//!
//! The sweeps and the fuzzer verify the datapaths; the mutation smoke
//! verifies the verifiers.

pub mod conformance;
pub mod fuzz;
pub mod mutation;
