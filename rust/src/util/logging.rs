//! A tiny leveled stderr logger.
//!
//! Controlled by `TSDIV_LOG` (`error|warn|info|debug|trace`, default
//! `info`). Thread-safe via a single atomic level; formatting happens in
//! the caller's thread.

use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn from_str(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }

    pub fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX); // MAX = uninitialized

fn init_from_env() -> u8 {
    let lvl = std::env::var("TSDIV_LOG")
        .ok()
        .and_then(|s| Level::from_str(&s))
        .unwrap_or(Level::Info) as u8;
    LEVEL.store(lvl, Ordering::Relaxed);
    lvl
}

/// Current level, lazily initialized from the environment.
pub fn level() -> Level {
    let raw = LEVEL.load(Ordering::Relaxed);
    let raw = if raw == u8::MAX { init_from_env() } else { raw };
    match raw {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

/// Override the level programmatically (tests, CLI --verbose).
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn enabled(l: Level) -> bool {
    l <= level()
}

/// Log a message at a level; used through the macros below.
pub fn log(l: Level, module: &str, msg: std::fmt::Arguments<'_>) {
    if enabled(l) {
        eprintln!("[{} {}] {}", l.tag(), module, msg);
    }
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Error, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, module_path!(), format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parsing() {
        assert_eq!(Level::from_str("error"), Some(Level::Error));
        assert_eq!(Level::from_str("WARN"), Some(Level::Warn));
        assert_eq!(Level::from_str("Debug"), Some(Level::Debug));
        assert_eq!(Level::from_str("bogus"), None);
    }

    #[test]
    fn set_and_check_levels() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Trace);
        assert!(enabled(Level::Debug));
        // Restore default-ish for other tests.
        set_level(Level::Info);
    }

    #[test]
    fn ordering_matches_severity() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Warn < Level::Info);
        assert!(Level::Info < Level::Debug);
        assert!(Level::Debug < Level::Trace);
    }
}
