//! Minimal declarative command-line parsing (clap is not vendored).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments,
//! subcommands and generated `--help` text. Only what `tsdiv`'s CLI and
//! the bench binaries need.

use std::collections::BTreeMap;

/// Specification of a single option.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub takes_value: bool,
    pub default: Option<&'static str>,
    /// Closed value set; `parse` rejects anything else (None = free-form).
    pub choices: Option<&'static [&'static str]>,
}

/// Parsed arguments.
#[derive(Clone, Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    positionals: Vec<String>,
}

impl Args {
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn parse_or<T: std::str::FromStr>(&self, name: &str, default: T) -> T {
        match self.get(name) {
            Some(s) => s.parse().unwrap_or(default),
            None => default,
        }
    }

    /// Like `parse_or` but errors on malformed values instead of hiding them.
    pub fn parse_required<T: std::str::FromStr>(&self, name: &str) -> Result<T, String> {
        let s = self
            .get(name)
            .ok_or_else(|| format!("missing required option --{name}"))?;
        s.parse()
            .map_err(|_| format!("option --{name}: cannot parse '{s}'"))
    }

    pub fn positionals(&self) -> &[String] {
        &self.positionals
    }
}

/// A command with options; `parse` consumes an iterator of raw args.
#[derive(Clone, Debug)]
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<OptSpec>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Self {
            name,
            about,
            opts: Vec::new(),
        }
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            takes_value: false,
            default: None,
            choices: None,
        });
        self
    }

    pub fn opt(mut self, name: &'static str, default: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            takes_value: true,
            default: Some(default),
            choices: None,
        });
        self
    }

    /// An option restricted to a closed value set; anything outside the
    /// set is a parse error (listing the choices). The default must be
    /// one of the choices.
    pub fn opt_choice(
        mut self,
        name: &'static str,
        default: &'static str,
        choices: &'static [&'static str],
        help: &'static str,
    ) -> Self {
        debug_assert!(choices.contains(&default));
        self.opts.push(OptSpec {
            name,
            help,
            takes_value: true,
            default: Some(default),
            choices: Some(choices),
        });
        self
    }

    pub fn opt_required(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            takes_value: true,
            default: None,
            choices: None,
        });
        self
    }

    pub fn help_text(&self) -> String {
        let mut s = format!("{} — {}\n\nOptions:\n", self.name, self.about);
        for o in &self.opts {
            let head = if o.takes_value {
                format!("  --{} <value>", o.name)
            } else {
                format!("  --{}", o.name)
            };
            let def = match o.default {
                Some(d) if o.takes_value => format!(" [default: {d}]"),
                _ => String::new(),
            };
            let choices = match o.choices {
                Some(cs) => format!(" ({})", cs.join("|")),
                None => String::new(),
            };
            s.push_str(&format!("{head:<28} {}{choices}{def}\n", o.help));
        }
        s.push_str("  --help                       show this help\n");
        s
    }

    /// Parse raw arguments. Returns Err(message) on unknown options or
    /// missing values; the caller decides how to report.
    pub fn parse<I: IntoIterator<Item = String>>(&self, raw: I) -> Result<Args, String> {
        let mut args = Args::default();
        // Apply defaults first.
        for o in &self.opts {
            if let Some(d) = o.default {
                args.values.insert(o.name.to_string(), d.to_string());
            }
        }
        let mut it = raw.into_iter().peekable();
        while let Some(tok) = it.next() {
            if tok == "--help" || tok == "-h" {
                return Err(self.help_text());
            }
            if let Some(stripped) = tok.strip_prefix("--") {
                let (name, inline_val) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| format!("unknown option --{name}\n\n{}", self.help_text()))?;
                if spec.takes_value {
                    let val = match inline_val {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| format!("option --{name} requires a value"))?,
                    };
                    if let Some(choices) = spec.choices {
                        if !choices.contains(&val.as_str()) {
                            return Err(format!(
                                "option --{name}: '{val}' is not one of {}",
                                choices.join("|")
                            ));
                        }
                    }
                    args.values.insert(name, val);
                } else {
                    if inline_val.is_some() {
                        return Err(format!("flag --{name} does not take a value"));
                    }
                    args.flags.push(name);
                }
            } else {
                args.positionals.push(tok);
            }
        }
        Ok(args)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("t", "test command")
            .opt("n", "5", "iterations")
            .opt_required("path", "input path")
            .opt_choice("mode", "fast", &["fast", "slow"], "speed mode")
            .flag("verbose", "log more")
    }

    fn parse(raw: &[&str]) -> Result<Args, String> {
        cmd().parse(raw.iter().map(|s| s.to_string()))
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&[]).unwrap();
        assert_eq!(a.get("n"), Some("5"));
        assert_eq!(a.get("path"), None);
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn space_and_equals_forms() {
        let a = parse(&["--n", "9", "--path=/tmp/x"]).unwrap();
        assert_eq!(a.parse_or::<u32>("n", 0), 9);
        assert_eq!(a.get("path"), Some("/tmp/x"));
    }

    #[test]
    fn flags_and_positionals() {
        let a = parse(&["--verbose", "one", "two"]).unwrap();
        assert!(a.flag("verbose"));
        assert_eq!(a.positionals(), &["one".to_string(), "two".to_string()]);
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(parse(&["--bogus"]).is_err());
    }

    #[test]
    fn missing_value_rejected() {
        assert!(parse(&["--n"]).is_err());
    }

    #[test]
    fn flag_with_value_rejected() {
        assert!(parse(&["--verbose=yes"]).is_err());
    }

    #[test]
    fn help_is_err_with_text() {
        let e = parse(&["--help"]).unwrap_err();
        assert!(e.contains("test command"));
        assert!(e.contains("--path"));
    }

    #[test]
    fn choice_options_validated() {
        let a = parse(&["--mode", "slow"]).unwrap();
        assert_eq!(a.get("mode"), Some("slow"));
        assert_eq!(parse(&[]).unwrap().get("mode"), Some("fast"));
        let e = parse(&["--mode", "warp"]).unwrap_err();
        assert!(e.contains("fast|slow"), "{e}");
        let help = cmd().help_text();
        assert!(help.contains("(fast|slow)"), "{help}");
    }

    #[test]
    fn parse_required_works() {
        let a = parse(&["--path", "p", "--n", "bad"]).unwrap();
        assert_eq!(a.parse_required::<String>("path").unwrap(), "p");
        assert!(a.parse_required::<u32>("n").is_err());
        assert_eq!(a.parse_or::<u32>("n", 7), 7);
    }
}
