//! Deterministic pseudo-random number generation.
//!
//! `SplitMix64` seeds `Xoshiro256StarStar` (the standard public-domain
//! constructions); on top of the raw generator sit the distributions the
//! workload generators need: uniform integers without modulo bias,
//! uniform floats in `[0,1)`, log-uniform positive floats spanning the
//! full exponent range, and IEEE-754 special values.

/// SplitMix64 — used for seeding and as a cheap standalone generator.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — the crate's main PRNG. Fast, 256-bit state, passes BigCrush.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Construct from a 64-bit seed (expanded through SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let mut s = [0u64; 4];
        for w in s.iter_mut() {
            *w = sm.next_u64();
        }
        // All-zero state is the one invalid state; SplitMix64 cannot
        // produce four consecutive zeros from any seed, but keep the
        // guard for clarity.
        if s == [0, 0, 0, 0] {
            s[0] = 0x1;
        }
        Self { s }
    }

    #[inline]
    fn rotl(x: u64, k: u32) -> u64 {
        x.rotate_left(k)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = Self::rotl(self.s[1].wrapping_mul(5), 7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = Self::rotl(self.s[3], 45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, bound)` without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "below(0) is meaningless");
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in the inclusive range `[lo, hi]`.
    #[inline]
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        if lo == 0 && hi == u64::MAX {
            return self.next_u64();
        }
        lo + self.below(hi - lo + 1)
    }

    /// Uniform in the inclusive range `[lo, hi]` for i64.
    #[inline]
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        let span = (hi as i128 - lo as i128) as u64;
        if span == u64::MAX {
            return self.next_u64() as i64;
        }
        (lo as i128 + self.below(span + 1) as i128) as i64
    }

    /// Uniform f64 in `[0, 1)` with 53 random bits.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)` with 24 random bits.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform f64 in `[lo, hi)`.
    #[inline]
    pub fn f64_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Log-uniform positive f64 over `[2^emin, 2^emax)` — every binade
    /// equally likely, mantissa uniform. This is the right operand
    /// distribution for divider accuracy sweeps.
    pub fn f64_log_uniform(&mut self, emin: i32, emax: i32) -> f64 {
        let e = self.range_i64(emin as i64, emax as i64 - 1) as i32;
        let mant = 1.0 + self.f64(); // [1, 2)
        mant * pow2(e)
    }

    /// Log-uniform positive f32.
    pub fn f32_log_uniform(&mut self, emin: i32, emax: i32) -> f32 {
        self.f64_log_uniform(emin, emax) as f32
    }

    /// Random boolean with probability `p` of `true`.
    #[inline]
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick a random element of a slice.
    #[inline]
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// An f32 drawn from [`F32_SPECIALS`], used by the failure-injection
    /// and specials tests.
    pub fn f32_special(&mut self) -> f32 {
        *self.choose(&F32_SPECIALS)
    }

    /// Fully random f32 bit pattern (covers NaNs, subnormals, everything).
    #[inline]
    pub fn f32_bits(&mut self) -> f32 {
        f32::from_bits(self.next_u32())
    }

    /// Fully random f64 bit pattern.
    #[inline]
    pub fn f64_bits(&mut self) -> f64 {
        f64::from_bits(self.next_u64())
    }
}

/// The menu of IEEE-754 f32 special/corner values shared by
/// [`Rng::f32_special`] and the special-value batch generators.
pub const F32_SPECIALS: [f32; 12] = [
    0.0,
    -0.0,
    f32::INFINITY,
    f32::NEG_INFINITY,
    f32::NAN,
    f32::MIN_POSITIVE, // smallest normal
    1.0e-45,           // smallest subnormal
    f32::MAX,
    f32::MIN,
    1.0,
    -1.0,
    2.0,
];

/// Exact power of two as f64 (no powi rounding concerns for |e| < 1023).
#[inline]
pub fn pow2(e: i32) -> f64 {
    assert!((-1022..=1023).contains(&e), "pow2 exponent out of normal range");
    f64::from_bits(((e + 1023) as u64) << 52)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_known_values() {
        // Reference values from the public-domain splitmix64.c with seed 0.
        let mut sm = SplitMix64::new(0);
        assert_eq!(sm.next_u64(), 0xE220A8397B1DCDAF);
        assert_eq!(sm.next_u64(), 0x6E789E6AA1B965F4);
        assert_eq!(sm.next_u64(), 0x06C45D188009454F);
    }

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn below_is_in_range_and_not_constant() {
        let mut r = Rng::new(3);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen.insert(v);
        }
        assert_eq!(seen.len(), 10, "all residues should appear in 1000 draws");
    }

    #[test]
    fn below_one_is_zero() {
        let mut r = Rng::new(3);
        for _ in 0..10 {
            assert_eq!(r.below(1), 0);
        }
    }

    #[test]
    fn range_endpoints_inclusive() {
        let mut r = Rng::new(5);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..2000 {
            let v = r.range_u64(10, 13);
            assert!((10..=13).contains(&v));
            lo_seen |= v == 10;
            hi_seen |= v == 13;
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn range_i64_negative() {
        let mut r = Rng::new(5);
        for _ in 0..1000 {
            let v = r.range_i64(-126, 127);
            assert!((-126..=127).contains(&v));
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(11);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean} too far from 0.5");
    }

    #[test]
    fn log_uniform_exponent_span() {
        let mut r = Rng::new(13);
        for _ in 0..1000 {
            let v = r.f64_log_uniform(-10, 10);
            assert!(v > 0.0);
            assert!(v >= pow2(-10) && v < pow2(10));
        }
    }

    #[test]
    fn pow2_exact() {
        assert_eq!(pow2(0), 1.0);
        assert_eq!(pow2(10), 1024.0);
        assert_eq!(pow2(-1), 0.5);
        assert_eq!(pow2(-1022), f64::MIN_POSITIVE);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>(), "shuffle left input in order");
    }
}
