//! Minimal in-tree error handling.
//!
//! The build image vendors no general-purpose crates, so the fallible
//! layers (runtime manifest loading, service startup, worker backends)
//! use this message-carrying error instead of `anyhow`. The surface is a
//! deliberately small subset of the same idioms: [`Result`], a
//! [`Context`] extension trait for `Result`/`Option`, and the
//! [`crate::err!`]/[`crate::bail!`] macros.

use std::fmt;

/// A message-carrying error; context layers prepend `context: cause`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Error {
    msg: String,
}

impl Error {
    pub fn new(msg: impl Into<String>) -> Self {
        Self { msg: msg.into() }
    }

    /// Prepend a context layer, like `anyhow::Error::context`.
    pub fn context(self, ctx: impl fmt::Display) -> Self {
        Self {
            msg: format!("{ctx}: {}", self.msg),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

impl From<String> for Error {
    fn from(msg: String) -> Self {
        Error::new(msg)
    }
}

impl From<&str> for Error {
    fn from(msg: &str) -> Self {
        Error::new(msg)
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::new(e.to_string())
    }
}

impl From<crate::util::json::ParseError> for Error {
    fn from(e: crate::util::json::ParseError) -> Self {
        Error::new(e.to_string())
    }
}

/// Crate-wide result type defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `.context(...)` / `.with_context(|| ...)` on results and options.
pub trait Context<T> {
    /// Wrap the error (or `None`) with a fixed context message.
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    /// Wrap with a lazily-built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::new(format!("{ctx}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::new(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::new(ctx.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::new(f().to_string()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! err {
    ($($arg:tt)*) => {
        $crate::util::error::Error::new(format!($($arg)*))
    };
}

/// Early-return with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::err!($($arg)*).into())
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<u32> {
        bail!("broke with code {}", 7)
    }

    #[test]
    fn bail_and_display() {
        let e = fails().unwrap_err();
        assert_eq!(e.to_string(), "broke with code 7");
    }

    #[test]
    fn context_layers_prepend() {
        let r: Result<u32> = fails().context("loading manifest");
        assert_eq!(r.unwrap_err().to_string(), "loading manifest: broke with code 7");
        let e = err!("inner").context("outer");
        assert_eq!(e.to_string(), "outer: inner");
    }

    #[test]
    fn with_context_is_lazy() {
        let ok: std::result::Result<u32, String> = Ok(5);
        let r = ok.with_context(|| -> String { panic!("must not be called") });
        assert_eq!(r.unwrap(), 5);
        let bad: std::result::Result<u32, String> = Err("nope".into());
        let r = bad.with_context(|| format!("step {}", 3));
        assert_eq!(r.unwrap_err().to_string(), "step 3: nope");
    }

    #[test]
    fn option_context() {
        let none: Option<u32> = None;
        assert_eq!(none.context("missing key").unwrap_err().to_string(), "missing key");
        assert_eq!(Some(2u32).context("unused").unwrap(), 2);
    }

    #[test]
    fn conversions() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = io.into();
        assert!(e.to_string().contains("gone"));
        let e: Error = "plain".into();
        assert_eq!(e.to_string(), "plain");
        let e: Error = String::from("owned").into();
        assert_eq!(e.to_string(), "owned");
    }

    #[test]
    fn question_mark_converts() {
        fn io_fail() -> Result<()> {
            std::fs::read_to_string("/definitely/not/a/path/xyz")?;
            Ok(())
        }
        assert!(io_fail().is_err());
    }
}
