//! In-tree utility substrates.
//!
//! The build image vendors no general-purpose crates (no rand, serde,
//! clap, criterion or proptest), so the small pieces of infrastructure the
//! rest of the crate needs are implemented here from scratch:
//!
//! * [`rng`] — SplitMix64 / xoshiro256** PRNG plus floating-point and
//!   special-value distributions for workload generation;
//! * [`error`] — message-carrying error type with context layers (the
//!   crate's `anyhow` replacement);
//! * [`stats`] — streaming summary statistics, percentiles, histograms;
//! * [`json`] — a minimal JSON value/writer for metrics and reports;
//! * [`cli`] — a small declarative command-line parser;
//! * [`check`] — a seeded property-testing framework with shrinking;
//! * [`table`] — fixed-width ASCII table rendering for bench reports;
//! * [`timing`] — robust measurement loops used by the bench harness;
//! * [`logging`] — a leveled stderr logger.

pub mod check;
pub mod cli;
pub mod error;
pub mod json;
pub mod logging;
pub mod rng;
pub mod stats;
pub mod table;
pub mod timing;

/// Prevent the optimizer from deleting a benchmarked computation.
///
/// Same contract as `criterion::black_box`: the value is forced to exist
/// in memory via a volatile read.
#[inline]
pub fn black_box<T>(x: T) -> T {
    // SAFETY: `x` is a valid initialized value; a volatile read of it is
    // defined behaviour and the original is forgotten (moved out).
    unsafe {
        let ret = std::ptr::read_volatile(&x);
        std::mem::forget(x);
        ret
    }
}

#[cfg(test)]
mod tests {
    use super::black_box;

    #[test]
    fn black_box_is_identity() {
        assert_eq!(black_box(42u64), 42);
        assert_eq!(black_box("s"), "s");
        let v = vec![1, 2, 3];
        assert_eq!(black_box(v.clone()), v);
    }
}
