//! A small seeded property-testing framework (proptest is not vendored).
//!
//! Usage pattern:
//!
//! ```no_run
//! use tsdiv::util::check::{Config, forall};
//! use tsdiv::check_eq;
//! forall(Config::named("mul commutes"), |r| {
//!     let a = r.range_u64(0, 1 << 20);
//!     let b = r.range_u64(0, 1 << 20);
//!     check_eq!(a.wrapping_mul(b), b.wrapping_mul(a));
//!     Ok(())
//! });
//! ```
//!
//! A failing case is re-run with a shrinking pass over the recorded draw
//! tape: the framework retries the property with each draw clamped toward
//! its minimum, and reports the smallest failing tape it found, plus the
//! seed to reproduce.

use super::rng::Rng;

/// Property test configuration.
#[derive(Clone, Debug)]
pub struct Config {
    pub name: &'static str,
    pub cases: u32,
    pub seed: u64,
}

impl Config {
    pub fn named(name: &'static str) -> Self {
        Self {
            name,
            cases: 256,
            seed: 0xC0FFEE,
        }
    }

    pub fn cases(mut self, n: u32) -> Self {
        self.cases = n;
        self
    }

    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }
}

/// A deterministic draw source handed to properties. Records every draw
/// so failures can be shrunk and replayed.
pub struct Draw {
    rng: Rng,
    tape: Vec<u64>,
    /// When replaying a shrunk tape, draws come from here instead.
    replay: Option<(Vec<u64>, usize)>,
}

impl Draw {
    fn new(seed: u64) -> Self {
        Self {
            rng: Rng::new(seed),
            tape: Vec::new(),
            replay: None,
        }
    }

    fn replaying(tape: Vec<u64>) -> Self {
        Self {
            rng: Rng::new(0),
            tape: Vec::new(),
            replay: Some((tape, 0)),
        }
    }

    #[inline]
    fn raw(&mut self) -> u64 {
        if let Some((tape, idx)) = &mut self.replay {
            let v = tape.get(*idx).copied().unwrap_or(0);
            *idx += 1;
            self.tape.push(v);
            v
        } else {
            let v = self.rng.next_u64();
            self.tape.push(v);
            v
        }
    }

    pub fn u64(&mut self) -> u64 {
        self.raw()
    }

    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        let span = hi - lo;
        if span == u64::MAX {
            return self.raw();
        }
        lo + self.raw() % (span + 1)
    }

    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        let span = (hi as i128 - lo as i128) as u64;
        (lo as i128 + (self.raw() % (span.wrapping_add(1)).max(1)) as i128) as i64
    }

    pub fn u32(&mut self) -> u32 {
        self.raw() as u32
    }

    pub fn bool(&mut self) -> bool {
        self.raw() & 1 == 1
    }

    /// f64 in [0,1).
    pub fn unit_f64(&mut self) -> f64 {
        (self.raw() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f64_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.unit_f64() * (hi - lo)
    }

    /// Arbitrary f32 bit pattern.
    pub fn f32_bits(&mut self) -> f32 {
        f32::from_bits(self.u32())
    }

    /// A *finite* f32 (resamples NaN/Inf patterns).
    pub fn f32_finite(&mut self) -> f32 {
        loop {
            let x = self.f32_bits();
            if x.is_finite() {
                return x;
            }
        }
    }

    pub fn choose_idx(&mut self, len: usize) -> usize {
        assert!(len > 0);
        (self.raw() % len as u64) as usize
    }
}

/// Property outcome: `Err(reason)` fails the case.
pub type PropResult = Result<(), String>;

/// Run `prop` for `config.cases` random cases. Panics (test failure) with
/// the seed, case index and a shrunk counterexample description if the
/// property fails.
pub fn forall<F>(config: Config, mut prop: F)
where
    F: FnMut(&mut Draw) -> PropResult,
{
    for case in 0..config.cases {
        let case_seed = config
            .seed
            .wrapping_add((case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut draw = Draw::new(case_seed);
        if let Err(msg) = prop(&mut draw) {
            let tape = draw.tape.clone();
            let (shrunk_tape, shrunk_msg) = shrink(&tape, &mut prop).unwrap_or((tape, msg));
            panic!(
                "property '{}' failed (case {}, seed {:#x}):\n  {}\n  shrunk tape: {:?}",
                config.name, case, case_seed, shrunk_msg, truncated(&shrunk_tape)
            );
        }
    }
}

fn truncated(tape: &[u64]) -> Vec<u64> {
    tape.iter().copied().take(16).collect()
}

/// Greedy tape shrinking: try zeroing and halving each draw; keep any
/// change that still fails. Bounded passes so shrinking always halts.
fn shrink<F>(tape: &[u64], prop: &mut F) -> Option<(Vec<u64>, String)>
where
    F: FnMut(&mut Draw) -> PropResult,
{
    let mut best: Option<(Vec<u64>, String)> = None;
    let mut current = tape.to_vec();
    for _pass in 0..8 {
        let mut improved = false;
        for i in 0..current.len() {
            if current[i] == 0 {
                continue;
            }
            for candidate_val in [0u64, current[i] >> 1, current[i] >> 8] {
                if candidate_val == current[i] {
                    continue;
                }
                let mut cand = current.clone();
                cand[i] = candidate_val;
                let mut d = Draw::replaying(cand.clone());
                if let Err(msg) = prop(&mut d) {
                    current = cand;
                    best = Some((current.clone(), msg));
                    improved = true;
                    break;
                }
            }
        }
        if !improved {
            break;
        }
    }
    best
}

/// Assert equality inside a property, producing a useful message.
#[macro_export]
macro_rules! check_eq {
    ($a:expr, $b:expr) => {{
        let (a, b) = (&$a, &$b);
        if a != b {
            return Err(format!(
                "{} != {}  ({:?} vs {:?})",
                stringify!($a),
                stringify!($b),
                a,
                b
            ));
        }
    }};
}

/// Assert a predicate inside a property.
#[macro_export]
macro_rules! check_that {
    ($cond:expr) => {{
        if !$cond {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    }};
    ($cond:expr, $($fmt:tt)*) => {{
        if !$cond {
            return Err(format!($($fmt)*));
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall(Config::named("add commutes").cases(64), |d| {
            count += 1;
            let a = d.range_u64(0, 1000);
            let b = d.range_u64(0, 1000);
            check_eq!(a + b, b + a);
            Ok(())
        });
        assert_eq!(count, 64);
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics_with_name() {
        forall(Config::named("always fails").cases(4), |_d| {
            Err("nope".to_string())
        });
    }

    #[test]
    #[should_panic(expected = "shrunk tape")]
    fn failure_reports_shrunk_tape() {
        forall(Config::named("large values fail").cases(64), |d| {
            let x = d.u64();
            check_that!(x < (1 << 20), "x too big: {x}");
            Ok(())
        });
    }

    #[test]
    fn shrinking_reaches_small_counterexample() {
        // Drive shrink() directly: property fails whenever draw >= 100.
        let mut prop = |d: &mut Draw| -> PropResult {
            let x = d.u64();
            if x >= 100 {
                Err(format!("x={x}"))
            } else {
                Ok(())
            }
        };
        let tape = vec![u64::MAX];
        let (shrunk, _msg) = shrink(&tape, &mut prop).unwrap();
        assert!(shrunk[0] < u64::MAX, "shrink made no progress");
    }

    #[test]
    fn draw_ranges_respect_bounds() {
        forall(Config::named("draw bounds").cases(128), |d| {
            let v = d.range_u64(5, 10);
            check_that!((5..=10).contains(&v));
            let w = d.range_i64(-4, 4);
            check_that!((-4..=4).contains(&w));
            let f = d.f64_range(1.0, 2.0);
            check_that!((1.0..2.0).contains(&f));
            Ok(())
        });
    }

    #[test]
    fn finite_f32_is_finite() {
        forall(Config::named("finite f32").cases(256), |d| {
            check_that!(d.f32_finite().is_finite());
            Ok(())
        });
    }
}
