//! Fixed-width ASCII table rendering for bench/report output.
//!
//! Every bench target prints its results through this module so the
//! paper-vs-measured tables in `bench_output.txt` and EXPERIMENTS.md look
//! uniform.

/// Column alignment.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Align {
    Left,
    Right,
}

/// A simple table builder.
#[derive(Clone, Debug)]
pub struct Table {
    title: String,
    headers: Vec<String>,
    aligns: Vec<Align>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            aligns: vec![Align::Right; headers.len()],
            rows: Vec::new(),
        }
    }

    /// Set alignment per column (defaults to right).
    pub fn aligns(mut self, aligns: &[Align]) -> Self {
        assert_eq!(aligns.len(), self.headers.len());
        self.aligns = aligns.to_vec();
        self
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row width mismatch in table '{}'",
            self.title
        );
        self.rows.push(cells.to_vec());
        self
    }

    /// Convenience for building a row from display values.
    pub fn row_disp(&mut self, cells: &[&dyn std::fmt::Display]) -> &mut Self {
        let cells: Vec<String> = cells.iter().map(|c| c.to_string()).collect();
        self.row(&cells)
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.chars().count());
            }
        }
        let sep: String = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for i in 0..ncols {
                let cell = &cells[i];
                let pad = widths[i] - cell.chars().count();
                match self.aligns[i] {
                    Align::Left => s.push_str(&format!(" {}{} |", cell, " ".repeat(pad))),
                    Align::Right => s.push_str(&format!(" {}{} |", " ".repeat(pad), cell)),
                }
            }
            s
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a float with a sensible number of significant digits for tables.
pub fn sig(x: f64, digits: usize) -> String {
    if x == 0.0 {
        return "0".to_string();
    }
    if !x.is_finite() {
        return format!("{x}");
    }
    let mag = x.abs().log10().floor() as i32;
    if (-3..6).contains(&mag) {
        let decimals = (digits as i32 - 1 - mag).max(0) as usize;
        format!("{x:.decimals$}")
    } else {
        format!("{x:.prec$e}", prec = digits.saturating_sub(1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_headers_rows_and_borders() {
        let mut t = Table::new("demo", &["k", "value"]);
        t.row(&["b0".into(), "1.09811".into()]);
        t.row(&["b1".into(), "1.20835".into()]);
        let r = t.render();
        assert!(r.contains("== demo =="));
        assert!(r.contains("| 1.09811 |"));
        assert_eq!(r.matches('+').count() % 3, 0, "borders well-formed");
        // All data lines same length
        let widths: Vec<usize> = r
            .lines()
            .skip(1)
            .map(|l| l.chars().count())
            .collect();
        assert!(widths.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    fn alignment_left_vs_right() {
        let mut t = Table::new("", &["name", "n"]).aligns(&[Align::Left, Align::Right]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["longer".into(), "100".into()]);
        let r = t.render();
        assert!(r.contains("| a      |"));
        assert!(r.contains("|   1 |"));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn row_width_mismatch_panics() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn sig_formatting() {
        assert_eq!(sig(0.0, 4), "0");
        assert_eq!(sig(1.23456789, 6), "1.23457"); // rounds
        assert_eq!(sig(123456.0, 4), "123456");
        assert!(sig(1.0e-9, 3).contains('e'));
        assert!(sig(f64::INFINITY, 3) == "inf");
    }

    #[test]
    fn row_disp_accepts_mixed_types() {
        let mut t = Table::new("", &["a", "b", "c"]);
        t.row_disp(&[&1u32, &2.5f64, &"s"]);
        assert!(t.render().contains("| 2.5 |"));
    }
}
