//! A minimal JSON value model and serializer (no external crates).
//!
//! Only what the metrics/report paths need: construction, ordered object
//! keys (insertion order, so reports are stable), escaping, and pretty
//! printing. Parsing is implemented for the small config/manifest files
//! the runtime reads (`artifacts/manifest.json`).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Objects keep insertion order via a parallel key list.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Insert/replace a key in an object (panics on non-objects).
    pub fn set(&mut self, key: &str, val: Json) -> &mut Self {
        match self {
            Json::Obj(pairs) => {
                if let Some(p) = pairs.iter_mut().find(|(k, _)| k == key) {
                    p.1 = val;
                } else {
                    pairs.push((key.to_string(), val));
                }
            }
            _ => panic!("set() on non-object"),
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Compact single-line rendering.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty rendering with 2-space indent.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !pairs.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, x: f64) {
    if x.is_nan() || x.is_infinite() {
        // JSON has no NaN/Inf; serialize as null like most encoders.
        out.push_str("null");
    } else if x == x.trunc() && x.abs() < 1e15 {
        let _ = write!(out, "{}", x as i64);
    } else {
        let _ = write!(out, "{x}");
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<f64> for Json {
    fn from(x: f64) -> Self {
        Json::Num(x)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Self {
        Json::Num(x as f64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Self {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Self {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Self {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Self {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Self {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Self {
        Json::Arr(xs.into_iter().map(Into::into).collect())
    }
}

// ---------------------------------------------------------------------------
// Parser — small recursive-descent, enough for manifests and configs.
// ---------------------------------------------------------------------------

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    pub offset: usize,
    pub message: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.message)
    }
}
impl std::error::Error for ParseError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: &str) -> Result<T, ParseError> {
        Err(ParseError {
            offset: self.pos,
            message: msg.to_string(),
        })
    }

    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn eat(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            self.err(&format!("expected '{}'", b as char))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => self.err("expected a JSON value"),
        }
    }

    fn lit(&mut self, word: &str, val: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            self.err(&format!("expected '{word}'"))
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.eat(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let val = self.value()?;
            pairs.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(pairs));
                }
                _ => return self.err("expected ',' or '}'"),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return self.err("expected ',' or ']'"),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return self.err("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return self.err("truncated \\u escape");
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| ParseError {
                                        offset: self.pos,
                                        message: "bad \\u escape".into(),
                                    })?;
                            let cp = u32::from_str_radix(hex, 16).map_err(|_| ParseError {
                                offset: self.pos,
                                message: "bad \\u escape".into(),
                            })?;
                            // BMP only — fine for our manifests.
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return self.err("bad escape"),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Copy a full UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..]).map_err(|_| {
                        ParseError {
                            offset: self.pos,
                            message: "invalid utf-8".into(),
                        }
                    })?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| ParseError {
                offset: start,
                message: format!("bad number '{text}'"),
            })
    }
}

/// Parse a complete JSON document.
pub fn parse(text: &str) -> Result<Json, ParseError> {
    let mut p = Parser {
        bytes: text.as_bytes(),
        pos: 0,
    };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return p.err("trailing garbage");
    }
    Ok(v)
}

/// Convenience: map of string keys to f64, for flat metric dumps.
pub fn flat_metrics(pairs: &BTreeMap<String, f64>) -> Json {
    let mut o = Json::obj();
    for (k, v) in pairs {
        o.set(k, Json::Num(*v));
    }
    o
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let mut o = Json::obj();
        o.set("name", "tsdiv".into())
            .set("n", 5u64.into())
            .set("ok", true.into())
            .set("xs", vec![1.0f64, 2.5, -3.0].into());
        let text = o.to_string_compact();
        let back = parse(&text).unwrap();
        assert_eq!(back, o);
    }

    #[test]
    fn escapes() {
        let j = Json::Str("a\"b\\c\nd\u{1}".to_string());
        let text = j.to_string_compact();
        assert_eq!(text, "\"a\\\"b\\\\c\\nd\\u0001\"");
        assert_eq!(parse(&text).unwrap(), j);
    }

    #[test]
    fn numbers() {
        assert_eq!(parse("3.25").unwrap(), Json::Num(3.25));
        assert_eq!(parse("-12").unwrap(), Json::Num(-12.0));
        assert_eq!(parse("1e3").unwrap(), Json::Num(1000.0));
        assert_eq!(parse("2.5E-2").unwrap(), Json::Num(0.025));
    }

    #[test]
    fn nan_serializes_as_null() {
        assert_eq!(Json::Num(f64::NAN).to_string_compact(), "null");
    }

    #[test]
    fn integer_like_numbers_have_no_point() {
        assert_eq!(Json::Num(1024.0).to_string_compact(), "1024");
    }

    #[test]
    fn nested_parse() {
        let text = r#"{"a": [1, {"b": null}, "x"], "c": false}"#;
        let v = parse(text).unwrap();
        assert_eq!(v.get("c"), Some(&Json::Bool(false)));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[1].get("b"), Some(&Json::Null));
    }

    #[test]
    fn errors_carry_offset() {
        let e = parse("{\"a\": }").unwrap_err();
        assert!(e.offset > 0);
        assert!(parse("[1, 2").is_err());
        assert!(parse("12 x").is_err());
    }

    #[test]
    fn pretty_output_parses_back() {
        let mut o = Json::obj();
        o.set("rows", vec![1u64, 2, 3].into());
        let pretty = o.to_string_pretty();
        assert!(pretty.contains('\n'));
        assert_eq!(parse(&pretty).unwrap(), o);
    }

    #[test]
    fn get_on_missing_and_wrong_kind() {
        let o = Json::obj();
        assert!(o.get("missing").is_none());
        assert!(Json::Num(1.0).get("k").is_none());
        assert_eq!(Json::Num(2.0).as_f64(), Some(2.0));
        assert_eq!(Json::Str("s".into()).as_str(), Some("s"));
    }
}
