//! Measurement loops for the bench harness (criterion is not vendored).
//!
//! The model is criterion-like but simpler: warm up, then run batches of
//! iterations until a wall-clock budget is spent, and report robust
//! statistics (median of per-iteration times across batches).

use std::time::{Duration, Instant};

use super::stats::percentile_of;

/// Result of a measurement.
#[derive(Clone, Debug)]
pub struct Measurement {
    /// Median per-iteration time, seconds.
    pub median_s: f64,
    /// Mean per-iteration time, seconds.
    pub mean_s: f64,
    /// 5th / 95th percentile per-iteration time, seconds.
    pub p05_s: f64,
    pub p95_s: f64,
    /// Total iterations executed (excluding warmup).
    pub iterations: u64,
    /// Number of timed batches.
    pub batches: u32,
}

impl Measurement {
    pub fn throughput(&self) -> f64 {
        if self.median_s > 0.0 {
            1.0 / self.median_s
        } else {
            f64::INFINITY
        }
    }

    /// Per-iteration time scaled to "items per second" given items/iter.
    pub fn items_per_sec(&self, items_per_iter: u64) -> f64 {
        self.throughput() * items_per_iter as f64
    }

    pub fn human(&self) -> String {
        format!(
            "median {} (p05 {}, p95 {}, n={})",
            human_time(self.median_s),
            human_time(self.p05_s),
            human_time(self.p95_s),
            self.iterations
        )
    }
}

/// Render seconds human-readably.
pub fn human_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Benchmark configuration.
#[derive(Clone, Debug)]
pub struct BenchConfig {
    pub warmup: Duration,
    pub budget: Duration,
    pub min_batches: u32,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(200),
            budget: Duration::from_millis(900),
            min_batches: 8,
        }
    }
}

impl BenchConfig {
    /// Quick mode for CI/tests.
    pub fn quick() -> Self {
        Self {
            warmup: Duration::from_millis(20),
            budget: Duration::from_millis(120),
            min_batches: 4,
        }
    }

    /// Honour `TSDIV_BENCH_QUICK=1` so the full suite stays fast in CI.
    pub fn from_env() -> Self {
        match std::env::var("TSDIV_BENCH_QUICK") {
            Ok(v) if v == "1" || v.eq_ignore_ascii_case("true") => Self::quick(),
            _ => Self::default(),
        }
    }
}

/// Measure `f`, which performs ONE logical iteration per call.
pub fn bench<F: FnMut()>(cfg: &BenchConfig, mut f: F) -> Measurement {
    // Warmup + calibration: find an iteration count per batch that takes
    // roughly budget / (2 * min_batches).
    let warm_start = Instant::now();
    let mut calib_iters: u64 = 0;
    while warm_start.elapsed() < cfg.warmup {
        f();
        calib_iters += 1;
    }
    let per_iter = if calib_iters > 0 {
        cfg.warmup.as_secs_f64() / calib_iters as f64
    } else {
        cfg.warmup.as_secs_f64()
    };
    let target_batch_time = cfg.budget.as_secs_f64() / (2.0 * cfg.min_batches as f64);
    let batch_iters = ((target_batch_time / per_iter.max(1e-12)) as u64).clamp(1, 1 << 24);

    let mut per_iter_times: Vec<f64> = Vec::new();
    let mut total_iters = 0u64;
    let start = Instant::now();
    while start.elapsed() < cfg.budget || per_iter_times.len() < cfg.min_batches as usize {
        let t0 = Instant::now();
        for _ in 0..batch_iters {
            f();
        }
        let dt = t0.elapsed().as_secs_f64();
        per_iter_times.push(dt / batch_iters as f64);
        total_iters += batch_iters;
        if per_iter_times.len() > 10_000 {
            break; // pathologically fast function; enough data
        }
    }

    let mean = per_iter_times.iter().sum::<f64>() / per_iter_times.len() as f64;
    Measurement {
        median_s: percentile_of(&per_iter_times, 0.5),
        mean_s: mean,
        p05_s: percentile_of(&per_iter_times, 0.05),
        p95_s: percentile_of(&per_iter_times, 0.95),
        iterations: total_iters,
        batches: per_iter_times.len() as u32,
    }
}

/// Measure a function once (for coarse, long-running operations).
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::black_box;

    #[test]
    fn bench_reports_sane_numbers() {
        let cfg = BenchConfig::quick();
        let m = bench(&cfg, || {
            let mut acc = 0u64;
            for i in 0..100u64 {
                acc = acc.wrapping_add(black_box(i) * 3);
            }
            black_box(acc);
        });
        assert!(m.median_s > 0.0);
        assert!(m.iterations > 0);
        assert!(m.batches >= cfg.min_batches);
        assert!(m.p05_s <= m.median_s && m.median_s <= m.p95_s * 1.0001);
        assert!(m.throughput().is_finite());
    }

    #[test]
    fn time_once_returns_value_and_duration() {
        let (v, dt) = time_once(|| {
            std::thread::sleep(Duration::from_millis(5));
            42
        });
        assert_eq!(v, 42);
        assert!(dt >= 0.004);
    }

    #[test]
    fn human_time_units() {
        assert_eq!(human_time(2.0), "2.000 s");
        assert_eq!(human_time(0.002), "2.000 ms");
        assert_eq!(human_time(2e-6), "2.000 µs");
        assert_eq!(human_time(2e-9), "2.0 ns");
    }

    #[test]
    fn items_per_sec_scales() {
        let m = Measurement {
            median_s: 0.001,
            mean_s: 0.001,
            p05_s: 0.001,
            p95_s: 0.001,
            iterations: 10,
            batches: 1,
        };
        assert!((m.items_per_sec(100) - 100_000.0).abs() < 1e-6);
    }
}
