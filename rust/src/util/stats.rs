//! Summary statistics, percentiles and histograms for the analysis and
//! bench layers.

/// Streaming summary (Welford) over f64 samples, plus retained samples
/// for exact percentiles when `keep_samples` is on.
#[derive(Clone, Debug)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
    samples: Option<Vec<f64>>,
}

impl Default for Summary {
    fn default() -> Self {
        Self::new()
    }
}

impl Summary {
    /// Streaming-only summary (no percentile support).
    pub fn new() -> Self {
        Self {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            samples: None,
        }
    }

    /// Summary that also retains samples so percentiles are exact.
    pub fn keeping_samples() -> Self {
        Self {
            samples: Some(Vec::new()),
            ..Self::new()
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        if x < self.min {
            self.min = x;
        }
        if x > self.max {
            self.max = x;
        }
        if let Some(s) = &mut self.samples {
            s.push(x);
        }
    }

    pub fn extend<I: IntoIterator<Item = f64>>(&mut self, it: I) {
        for x in it {
            self.push(x);
        }
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }

    /// Sample variance (n-1 denominator).
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            f64::NAN
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.max
        }
    }

    /// Exact percentile (nearest-rank with linear interpolation); requires
    /// `keeping_samples()`. `q` in [0,1].
    pub fn percentile(&self, q: f64) -> f64 {
        let s = self
            .samples
            .as_ref()
            .expect("percentile() requires Summary::keeping_samples()");
        percentile_of(s, q)
    }

    pub fn median(&self) -> f64 {
        self.percentile(0.5)
    }
}

/// Percentile of an unsorted slice (copies + sorts; linear interpolation
/// between the two nearest order statistics). `q` in [0,1].
pub fn percentile_of(xs: &[f64], q: f64) -> f64 {
    assert!((0.0..=1.0).contains(&q), "q must be in [0,1]");
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).expect("NaN in percentile input"));
    let pos = q * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let frac = pos - lo as f64;
        v[lo] * (1.0 - frac) + v[hi] * frac
    }
}

/// Fixed-bin histogram over a closed range, with saturating edge bins.
#[derive(Clone, Debug)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    bins: Vec<u64>,
    underflow: u64,
    overflow: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbins: usize) -> Self {
        assert!(hi > lo && nbins > 0);
        Self {
            lo,
            hi,
            bins: vec![0; nbins],
            underflow: 0,
            overflow: 0,
        }
    }

    pub fn push(&mut self, x: f64) {
        if x < self.lo {
            self.underflow += 1;
            return;
        }
        if x >= self.hi {
            self.overflow += 1;
            return;
        }
        let idx = ((x - self.lo) / (self.hi - self.lo) * self.bins.len() as f64) as usize;
        let idx = idx.min(self.bins.len() - 1);
        self.bins[idx] += 1;
    }

    pub fn bins(&self) -> &[u64] {
        &self.bins
    }

    pub fn underflow(&self) -> u64 {
        self.underflow
    }

    pub fn overflow(&self) -> u64 {
        self.overflow
    }

    pub fn total(&self) -> u64 {
        self.bins.iter().sum::<u64>() + self.underflow + self.overflow
    }

    /// Render as sparkline-ish rows: `lo..hi count bar`.
    pub fn render(&self, width: usize) -> String {
        let maxc = self.bins.iter().copied().max().unwrap_or(1).max(1);
        let step = (self.hi - self.lo) / self.bins.len() as f64;
        let mut out = String::new();
        for (i, &c) in self.bins.iter().enumerate() {
            let l = self.lo + step * i as f64;
            let r = l + step;
            let bar = "#".repeat(((c as f64 / maxc as f64) * width as f64).round() as usize);
            out.push_str(&format!("[{l:>12.4e}, {r:>12.4e})  {c:>8}  {bar}\n"));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic_moments() {
        let mut s = Summary::new();
        s.extend([1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.count(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.variance() - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
    }

    #[test]
    fn summary_empty_is_nan() {
        let s = Summary::new();
        assert!(s.mean().is_nan());
        assert!(s.min().is_nan());
    }

    #[test]
    fn percentiles_exact() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile_of(&xs, 0.0), 1.0);
        assert_eq!(percentile_of(&xs, 1.0), 100.0);
        assert!((percentile_of(&xs, 0.5) - 50.5).abs() < 1e-12);
        // p99 of 1..=100 (interpolated at index 98.01)
        assert!((percentile_of(&xs, 0.99) - 99.01).abs() < 1e-9);
    }

    #[test]
    fn summary_percentile_matches_free_fn() {
        let mut s = Summary::keeping_samples();
        let xs = [5.0, 1.0, 9.0, 3.0, 7.0];
        s.extend(xs);
        assert_eq!(s.median(), percentile_of(&xs, 0.5));
    }

    #[test]
    #[should_panic]
    fn percentile_without_samples_panics() {
        let s = Summary::new();
        let _ = s.percentile(0.5);
    }

    #[test]
    fn histogram_bins_and_edges() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..10 {
            h.push(i as f64 + 0.5);
        }
        h.push(-1.0);
        h.push(10.0); // hi edge counts as overflow
        assert_eq!(h.bins(), &[1; 10]);
        assert_eq!(h.underflow(), 1);
        assert_eq!(h.overflow(), 1);
        assert_eq!(h.total(), 12);
    }

    #[test]
    fn histogram_render_nonempty() {
        let mut h = Histogram::new(0.0, 1.0, 4);
        h.push(0.1);
        h.push(0.9);
        let r = h.render(20);
        assert_eq!(r.lines().count(), 4);
        assert!(r.contains('#'));
    }
}
