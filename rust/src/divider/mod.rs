//! The complete floating-point division unit (paper Fig 7) and the
//! baseline dividers it is evaluated against.
//!
//! Division is split, as in any IEEE divider, into
//!
//! 1. a **special-value path** (NaN/Inf/zero/subnormal handling, sign
//!    and exponent arithmetic) shared by every algorithm, and
//! 2. a **significand path** `sig_a / sig_b` with both operands
//!    normalized to `[1, 2)` — this is where the paper's contribution
//!    (PLA seed → Taylor series → ILM powering) lives.
//!
//! Baselines:
//! * [`longdiv`] — restoring digit recurrence; exactly rounded, the gold
//!   reference for every accuracy table;
//! * [`newton`] — Newton–Raphson reciprocal iteration (paper ref [5]);
//! * [`goldschmidt`] — Goldschmidt multiplicative division.

pub mod goldschmidt;
pub mod longdiv;
pub mod newton;

use crate::fp::{round_pack, unpack, Class, Format, Rounding};
use crate::kernel::{self, KernelScratch};
use crate::powering::{ExactMul, IlmBackend, OpCounts};
use crate::simd::{Engine, SimdChoice};
use crate::taylor::{reciprocal_fast, TaylorConfig};

/// A divider over raw bit patterns of an arbitrary format.
pub trait Divider {
    fn name(&self) -> String;

    /// Divide `a / b`, both given as `fmt` bit patterns (in the low bits
    /// of `u64`), returning the quotient pattern.
    fn div_bits(&mut self, a_bits: u64, b_bits: u64, fmt: Format, rm: Rounding) -> u64;

    /// Divide many lanes at once: `out[i] = a[i] / b[i]`, all slices the
    /// same length. Bit-identical to calling [`Divider::div_bits`] per
    /// lane — the default implementation *is* that loop, so every
    /// divider gets the API; implementations with per-op setup worth
    /// amortizing (see [`TaylorDivider`]) override it.
    fn div_bits_batch(&mut self, a: &[u64], b: &[u64], fmt: Format, rm: Rounding, out: &mut [u64]) {
        assert_eq!(a.len(), b.len(), "operand length mismatch");
        assert_eq!(a.len(), out.len(), "output length mismatch");
        for ((&ab, &bb), q) in a.iter().zip(b.iter()).zip(out.iter_mut()) {
            *q = self.div_bits(ab, bb, fmt, rm);
        }
    }

    /// f32 convenience.
    fn div_f32(&mut self, a: f32, b: f32) -> f32 {
        let q = self.div_bits(
            a.to_bits() as u64,
            b.to_bits() as u64,
            crate::fp::F32,
            Rounding::NearestEven,
        );
        f32::from_bits(q as u32)
    }

    /// f64 convenience.
    fn div_f64(&mut self, a: f64, b: f64) -> f64 {
        let q = self.div_bits(a.to_bits(), b.to_bits(), crate::fp::F64, Rounding::NearestEven);
        f64::from_bits(q)
    }
}

/// Outcome of the shared special-value path.
pub enum Prepared {
    /// The result is already decided (special operands).
    Done(u64),
    /// Proceed to the significand datapath.
    Divide {
        sign: bool,
        /// Unbiased result exponent before normalization.
        exp: i32,
        /// Dividend significand, normalized, hidden bit at `frac_bits`.
        sig_a: u64,
        /// Divisor significand, normalized, hidden bit at `frac_bits`.
        sig_b: u64,
    },
}

/// IEEE-754 special handling shared by all dividers:
/// NaN propagation, `0/0` and `Inf/Inf` → NaN, `x/0` → Inf, `0/x` → 0,
/// `Inf/x` → Inf, `x/Inf` → 0; subnormals are normalized into the
/// extended exponent range.
pub fn prepare(a_bits: u64, b_bits: u64, fmt: Format) -> Prepared {
    // §Perf fast path: both operands normal (the overwhelmingly common
    // case) — skip classification and subnormal renormalization.
    let ea = fmt.exp_field(a_bits);
    let eb = fmt.exp_field(b_bits);
    let emax = fmt.exp_max();
    if ea != 0 && ea != emax && eb != 0 && eb != emax {
        return Prepared::Divide {
            sign: fmt.sign_field(a_bits) ^ fmt.sign_field(b_bits),
            exp: ea as i32 - eb as i32,
            sig_a: fmt.frac_field(a_bits) | (1 << fmt.frac_bits),
            sig_b: fmt.frac_field(b_bits) | (1 << fmt.frac_bits),
        };
    }
    let a = unpack(a_bits, fmt);
    let b = unpack(b_bits, fmt);
    let sign = a.sign ^ b.sign;
    use Class::*;
    match (a.class, b.class) {
        (NaN, _) | (_, NaN) => Prepared::Done(fmt.nan()),
        (Inf, Inf) => Prepared::Done(fmt.nan()),
        (Zero, Zero) => Prepared::Done(fmt.nan()),
        (Inf, _) => Prepared::Done(fmt.inf(sign)),
        (_, Inf) => Prepared::Done(fmt.zero(sign)),
        (Zero, _) => Prepared::Done(fmt.zero(sign)),
        (_, Zero) => Prepared::Done(fmt.inf(sign)),
        _ => Prepared::Divide {
            sign,
            exp: a.exp - b.exp,
            sig_a: a.sig,
            sig_b: b.sig,
        },
    }
}

/// Which multiplier implementation drives the Taylor datapath.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// Exact fixed-point multiplies (isolates Taylor/PLA error).
    Exact,
    /// Iterative Logarithmic Multiplier with a correction budget.
    Ilm { iterations: u32 },
}

enum BackendImpl {
    Exact(ExactMul),
    Ilm(IlmBackend),
}

/// The paper's divider: PLA seed + Taylor series + ILM/squaring powering
/// unit, wrapped in the IEEE special/exponent path (Fig 7).
pub struct TaylorDivider {
    pub cfg: TaylorConfig,
    backend: BackendImpl,
    kind: BackendKind,
    /// Staged-kernel buffers reused across `div_bits_batch` calls.
    batch_scratch: KernelScratch,
    /// Lane-tile width of the staged kernel (see [`crate::kernel`]).
    batch_tile: usize,
    /// Resolved lane engine under the kernel's stage loops (see
    /// [`crate::simd`]); defaults to the `TSDIV_SIMD`-aware auto choice.
    batch_engine: Engine,
}

impl TaylorDivider {
    /// General constructor.
    pub fn new(cfg: TaylorConfig, backend: BackendKind) -> Self {
        let be = match backend {
            BackendKind::Exact => BackendImpl::Exact(ExactMul::default()),
            BackendKind::Ilm { iterations } => BackendImpl::Ilm(IlmBackend::new(iterations)),
        };
        Self {
            cfg,
            backend: be,
            kind: backend,
            batch_scratch: KernelScratch::new(),
            batch_tile: kernel::DEFAULT_TILE,
            // Auto already defers to the TSDIV_SIMD override inside
            // resolve(); lenient because a library constructor cannot
            // fail (service backends re-select through the fallible
            // set_batch_simd with their configured choice).
            batch_engine: SimdChoice::Auto.resolve_lenient(),
        }
    }

    /// Override the staged kernel's lane-tile width (the service threads
    /// `KernelConfig::tile` through here).
    pub fn set_batch_tile(&mut self, tile: usize) {
        assert!(tile >= 1, "kernel tile must be ≥ 1 lane");
        self.batch_tile = tile;
    }

    /// Current lane-tile width of the batch path.
    pub fn batch_tile(&self) -> usize {
        self.batch_tile
    }

    /// Select the lane engine under the staged kernel (the service
    /// threads `KernelConfig::simd` through here). Errors when `Forced`
    /// asks for a vector engine the host lacks.
    pub fn set_batch_simd(&mut self, choice: SimdChoice) -> crate::util::error::Result<()> {
        self.batch_engine = choice.resolve()?;
        Ok(())
    }

    /// The resolved lane engine of the batch path.
    pub fn batch_engine(&self) -> Engine {
        self.batch_engine
    }

    /// The paper's headline configuration (Table-I segments, n = 5) on a
    /// 60-bit datapath with exact multiplies.
    pub fn paper_exact() -> Self {
        Self::new(TaylorConfig::paper_default(60), BackendKind::Exact)
    }

    /// Paper configuration with the ILM backend at a correction budget.
    pub fn paper_ilm(iterations: u32) -> Self {
        Self::new(
            TaylorConfig::paper_default(60),
            BackendKind::Ilm { iterations },
        )
    }

    /// Multiplier op counters accumulated so far.
    pub fn op_counts(&self) -> OpCounts {
        match &self.backend {
            BackendImpl::Exact(m) => m.counts(),
            BackendImpl::Ilm(m) => m.counts(),
        }
    }

    pub fn backend_kind(&self) -> BackendKind {
        self.kind
    }

    /// Op-generic staged batch path: the same kernel pipeline as
    /// [`Divider::div_bits_batch`] with the op-specific tail selected
    /// after the shared plan→seed→power core
    /// ([`crate::kernel::compute_batch`]). Operand shapes per
    /// [`crate::fp::Op`]: `Div` wants matched `a`/`b` and empty `rows`;
    /// the unary ops want `b` and `rows` empty; `ScaleByRecip` wants
    /// one divisor per row with `rows[r]` lanes each.
    #[allow(clippy::too_many_arguments)]
    pub fn compute_bits_batch(
        &mut self,
        op: crate::fp::Op,
        a: &[u64],
        b: &[u64],
        rows: &[u32],
        fmt: Format,
        rm: Rounding,
        out: &mut [u64],
    ) {
        let tile = self.batch_tile;
        let eng = self.batch_engine;
        match &mut self.backend {
            BackendImpl::Exact(m) => kernel::compute_batch(
                &self.cfg,
                m,
                &mut self.batch_scratch,
                tile,
                eng,
                op,
                a,
                b,
                rows,
                fmt,
                rm,
                out,
            ),
            BackendImpl::Ilm(m) => kernel::compute_batch(
                &self.cfg,
                m,
                &mut self.batch_scratch,
                tile,
                eng,
                op,
                a,
                b,
                rows,
                fmt,
                rm,
                out,
            ),
        }
    }
}

impl Divider for TaylorDivider {
    fn name(&self) -> String {
        let be = match self.kind {
            BackendKind::Exact => "exact".to_string(),
            BackendKind::Ilm { iterations } => format!("ilm{iterations}"),
        };
        format!(
            "taylor(n={}, segs={}, F={}, {be})",
            self.cfg.order,
            self.cfg.table.num_segments(),
            self.cfg.frac_bits
        )
    }

    fn div_bits(&mut self, a_bits: u64, b_bits: u64, fmt: Format, rm: Rounding) -> u64 {
        let f = self.cfg.frac_bits;
        assert!(
            f >= fmt.frac_bits,
            "datapath narrower than format significand"
        );
        match prepare(a_bits, b_bits, fmt) {
            Prepared::Done(bits) => bits,
            Prepared::Divide {
                sign,
                exp,
                sig_a,
                sig_b,
            } => {
                // Map divisor significand into the Q2.F datapath.
                let x = sig_b << (f - fmt.frac_bits);
                // §Perf: monomorphized, allocation-free reciprocal.
                let recip = match &mut self.backend {
                    BackendImpl::Exact(m) => reciprocal_fast(&self.cfg, m, x),
                    BackendImpl::Ilm(m) => reciprocal_fast(&self.cfg, m, x),
                };
                // Quotient significand: sig_a · recip, fraction width
                // fmt.frac_bits + F. Value in (0.5, 2].
                let q = sig_a as u128 * recip as u128;
                // The reciprocal is itself inexact below ~2^-53; mark
                // sticky so directed rounding never pretends exactness
                // unless the product is *exactly* representable anyway
                // (handled by longdiv users; the Taylor unit is inherently
                // approximate — matching the paper).
                round_pack(sign, exp, q, fmt.frac_bits + f, false, fmt, rm).0
            }
        }
    }

    /// Staged batch path: delegate to the structure-of-arrays kernel
    /// ([`crate::kernel::divide_batch`]) — the same stages the
    /// `BackendChoice::Kernel` service backend runs, so there is exactly
    /// one batch division loop in the crate. The backend `match`
    /// monomorphizes the whole batch against one multiplier.
    fn div_bits_batch(&mut self, a: &[u64], b: &[u64], fmt: Format, rm: Rounding, out: &mut [u64]) {
        let tile = self.batch_tile;
        let eng = self.batch_engine;
        match &mut self.backend {
            BackendImpl::Exact(m) => kernel::divide_batch(
                &self.cfg,
                m,
                &mut self.batch_scratch,
                tile,
                eng,
                a,
                b,
                fmt,
                rm,
                out,
            ),
            BackendImpl::Ilm(m) => kernel::divide_batch(
                &self.cfg,
                m,
                &mut self.batch_scratch,
                tile,
                eng,
                a,
                b,
                fmt,
                rm,
                out,
            ),
        }
    }
}

/// Convenience: collect one divider of every kind for comparison tables.
pub fn all_dividers() -> Vec<Box<dyn Divider>> {
    vec![
        Box::new(TaylorDivider::paper_exact()),
        Box::new(TaylorDivider::paper_ilm(8)),
        Box::new(newton::NewtonDivider::paper_default()),
        Box::new(goldschmidt::GoldschmidtDivider::paper_default()),
        Box::new(longdiv::LongDivider::new()),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::check_that;
    use crate::fp::{ulp_diff_f32, ulp_diff_f64, F32};
    use crate::util::check::{forall, Config};
    use crate::util::rng::Rng;

    #[test]
    fn specials_table() {
        let mut d = TaylorDivider::paper_exact();
        // NaN propagation
        assert!(d.div_f32(f32::NAN, 1.0).is_nan());
        assert!(d.div_f32(1.0, f32::NAN).is_nan());
        // inf/inf, 0/0
        assert!(d.div_f32(f32::INFINITY, f32::INFINITY).is_nan());
        assert!(d.div_f32(0.0, 0.0).is_nan());
        // x/0 → signed inf
        assert_eq!(d.div_f32(1.0, 0.0), f32::INFINITY);
        assert_eq!(d.div_f32(-1.0, 0.0), f32::NEG_INFINITY);
        assert_eq!(d.div_f32(1.0, -0.0), f32::NEG_INFINITY);
        // 0/x → signed zero
        assert_eq!(d.div_f32(0.0, -2.0).to_bits(), (-0.0f32).to_bits());
        // inf/x, x/inf
        assert_eq!(d.div_f32(f32::INFINITY, -2.0), f32::NEG_INFINITY);
        assert_eq!(d.div_f32(3.0, f32::NEG_INFINITY).to_bits(), (-0.0f32).to_bits());
    }

    #[test]
    fn exact_quotients() {
        let mut d = TaylorDivider::paper_exact();
        assert_eq!(d.div_f32(6.0, 2.0), 3.0);
        assert_eq!(d.div_f32(1.0, 2.0), 0.5);
        assert_eq!(d.div_f32(-7.5, 2.5), -3.0);
        assert_eq!(d.div_f32(1.0, 1.0), 1.0);
        // f64 sits at the datapath's precision edge (53-bit reciprocal):
        // exact dyadic quotients can land one ulp low.
        let q = d.div_f64(10.0, 4.0);
        assert!(ulp_diff_f64(q, 2.5).unwrap() <= 1, "10/4 = {q}");
    }

    #[test]
    fn f32_matches_hardware_division_randomized() {
        // With the exact backend the reciprocal is good to ~2^-53, far
        // below f32's half-ulp (2^-25 relative): results must be
        // correctly rounded (division has no exact-tie cases).
        let mut d = TaylorDivider::paper_exact();
        let mut r = Rng::new(2024);
        let mut checked = 0;
        while checked < 30_000 {
            let a = f32::from_bits(r.next_u32());
            let b = f32::from_bits(r.next_u32());
            if !a.is_finite() || !b.is_finite() {
                continue;
            }
            checked += 1;
            let ours = d.div_f32(a, b);
            let hw = a / b;
            if hw.is_nan() {
                assert!(ours.is_nan(), "{a:?}/{b:?}");
            } else {
                let ulps = ulp_diff_f32(ours, hw).unwrap();
                assert!(ulps <= 1, "{a:?}/{b:?}: {ours:?} vs {hw:?} ({ulps} ulps)");
            }
        }
    }

    #[test]
    fn f32_correctly_rounded_rate_is_high() {
        let mut d = TaylorDivider::paper_exact();
        let mut r = Rng::new(7);
        let mut exact = 0u64;
        let total = 20_000u64;
        for _ in 0..total {
            let a = r.f32_log_uniform(-20, 20);
            let b = r.f32_log_uniform(-20, 20);
            let ours = d.div_f32(a, b);
            if ours.to_bits() == (a / b).to_bits() {
                exact += 1;
            }
        }
        let rate = exact as f64 / total as f64;
        assert!(rate > 0.9999, "correctly-rounded rate {rate}");
    }

    #[test]
    fn f64_within_2_ulp_randomized() {
        // 53-bit reciprocal precision (the paper's target) leaves up to
        // ~1 ulp of f64 slack; assert ≤ 2 ulps defensively.
        let mut d = TaylorDivider::paper_exact();
        let mut r = Rng::new(11);
        for _ in 0..20_000 {
            let a = r.f64_log_uniform(-300, 300);
            let b = r.f64_log_uniform(-300, 300);
            let ours = d.div_f64(a, b);
            let hw = a / b;
            let ulps = ulp_diff_f64(ours, hw).unwrap();
            assert!(ulps <= 2, "{a:e}/{b:e}: {ulps} ulps");
        }
    }

    #[test]
    fn subnormal_operands_and_results() {
        let mut d = TaylorDivider::paper_exact();
        // Subnormal / normal. NB: subnormal-by-power-of-two quotients
        // land exactly on rounding ties (odd significand / 2), where the
        // reciprocal's 2^-53 defect can flip the tie — allow 1 ulp.
        let a = f32::from_bits(0x0000_0123);
        let ours = d.div_f32(a, 2.0);
        assert!(ulp_diff_f32(ours, a / 2.0).unwrap() <= 1);
        // Normal / large → subnormal result
        let ours = d.div_f32(1.0e-38, 1.0e7);
        let hw = 1.0e-38f32 / 1.0e7;
        assert!(ulp_diff_f32(ours, hw).unwrap() <= 1, "{ours:e} vs {hw:e}");
        // Subnormal / subnormal
        let a = f32::from_bits(0x0000_7FFF);
        let b = f32::from_bits(0x0000_0011);
        let ours = d.div_f32(a, b);
        assert!(ulp_diff_f32(ours, a / b).unwrap() <= 1);
    }

    #[test]
    fn overflow_and_underflow() {
        let mut d = TaylorDivider::paper_exact();
        assert_eq!(d.div_f32(f32::MAX, 0.5), f32::INFINITY);
        assert_eq!(d.div_f32(f32::MAX, -0.5), f32::NEG_INFINITY);
        let tiny = d.div_f32(f32::from_bits(1), 2.0);
        assert_eq!(tiny, f32::from_bits(1) / 2.0); // rounds to 0 or stays subnormal
    }

    #[test]
    fn ilm_backend_accuracy_improves_with_iterations() {
        let mut r = Rng::new(5);
        let mut worst_by_iter = Vec::new();
        for iters in [2u32, 4, 8, 16] {
            let mut d = TaylorDivider::paper_ilm(iters);
            let mut worst = 0u64;
            let mut rr = Rng::new(5);
            let _ = &mut r;
            for _ in 0..2_000 {
                let a = rr.f32_log_uniform(-10, 10);
                let b = rr.f32_log_uniform(-10, 10);
                let ours = d.div_f32(a, b);
                let ulps = ulp_diff_f32(ours, a / b).unwrap_or(u64::MAX);
                worst = worst.max(ulps);
            }
            worst_by_iter.push(worst);
        }
        for w in worst_by_iter.windows(2) {
            assert!(w[1] <= w[0], "worst ulp rose with ILM iterations: {worst_by_iter:?}");
        }
        // Plenty of corrections → f32-exactness territory.
        assert!(*worst_by_iter.last().unwrap() <= 1);
    }

    #[test]
    fn property_sign_and_magnitude_structure() {
        forall(Config::named("division sign/exponent structure").cases(300), |d| {
            let a = d.f64_range(0.5, 100.0);
            let b = d.f64_range(0.5, 100.0);
            let mut div = TaylorDivider::paper_exact();
            let q_pp = div.div_f64(a, b);
            let q_np = div.div_f64(-a, b);
            let q_pn = div.div_f64(a, -b);
            let q_nn = div.div_f64(-a, -b);
            check_that!(q_pp > 0.0 && q_nn > 0.0);
            check_that!(q_np < 0.0 && q_pn < 0.0);
            check_that!(q_pp == -q_np && q_pp == -q_pn && q_pp == q_nn);
            Ok(())
        });
    }

    #[test]
    fn property_scaling_by_powers_of_two_is_exact() {
        // a / 2^k should track exponent arithmetic exactly.
        forall(Config::named("power-of-two divisors exact").cases(300), |d| {
            let a = f32::from_bits((d.u32() % 0x7F00_0000).max(0x0080_0000));
            let k = d.range_i64(-10, 10) as i32;
            let b = 2f32.powi(k);
            let mut div = TaylorDivider::paper_exact();
            let got = div.div_f32(a, b);
            let want = a / b;
            check_that!(got.to_bits() == want.to_bits(), "{a:?} / 2^{k}");
            Ok(())
        });
    }

    #[test]
    fn prepare_classifies_all_special_pairs() {
        let specials = [
            0.0f32,
            -0.0,
            1.5,
            f32::INFINITY,
            f32::NEG_INFINITY,
            f32::NAN,
            f32::from_bits(1),
        ];
        for &a in &specials {
            for &b in &specials {
                let hw = a / b;
                match prepare(a.to_bits() as u64, b.to_bits() as u64, F32) {
                    Prepared::Done(bits) => {
                        let got = f32::from_bits(bits as u32);
                        if hw.is_nan() {
                            assert!(got.is_nan(), "{a:?}/{b:?}");
                        } else {
                            assert_eq!(got.to_bits(), hw.to_bits(), "{a:?}/{b:?}");
                        }
                    }
                    Prepared::Divide { .. } => {
                        assert!(
                            hw.is_finite() && hw != 0.0 || hw.is_infinite() || hw == 0.0,
                            "datapath case must be a real division: {a:?}/{b:?}"
                        );
                        assert!(a.is_finite() && b.is_finite() && a != 0.0 && b != 0.0);
                    }
                }
            }
        }
    }

    #[test]
    fn op_counts_via_diagnostic_engine() {
        // div_bits uses the non-counting hot path (§Perf step 3); op
        // accounting lives in the diagnostic reciprocal_fixed path.
        use crate::powering::{ExactMul, Multiplier};
        let cfg = crate::taylor::TaylorConfig::paper_default(60);
        let mut be = ExactMul::default();
        let r = crate::taylor::reciprocal_fixed(&cfg, &mut be, 3u64 << 59); // 1.5
        assert!(r.counts.muls > 0 && r.counts.squares > 0);
        assert_eq!(be.counts().muls, r.counts.muls);
    }

    #[test]
    fn all_dividers_agree_on_simple_case() {
        for mut d in all_dividers() {
            let q = d.div_f32(84.0, 2.0);
            assert_eq!(q, 42.0, "{}", d.name());
        }
    }

    #[test]
    fn batch_matches_scalar_for_all_dividers_including_specials() {
        // Covers the TaylorDivider specialization AND the default loop
        // (Newton/Goldschmidt/longdiv) on one mixed operand set.
        let a: Vec<u64> = [
            6.0f32,
            1.0,
            -7.5,
            f32::NAN,
            0.0,
            -0.0,
            f32::INFINITY,
            f32::NEG_INFINITY,
            1.0e-40,
            f32::MAX,
            355.0,
            1.5,
        ]
        .iter()
        .map(|x| x.to_bits() as u64)
        .collect();
        let b: Vec<u64> = [
            2.0f32,
            3.0,
            2.5,
            1.0,
            0.0,
            5.0,
            f32::INFINITY,
            2.0,
            2.0,
            0.5,
            113.0,
            1.5,
        ]
        .iter()
        .map(|x| x.to_bits() as u64)
        .collect();
        for rm in [
            Rounding::NearestEven,
            Rounding::TowardZero,
            Rounding::TowardPositive,
            Rounding::TowardNegative,
        ] {
            for mut d in all_dividers() {
                let name = d.name();
                let scalar: Vec<u64> = a
                    .iter()
                    .zip(&b)
                    .map(|(&x, &y)| d.div_bits(x, y, F32, rm))
                    .collect();
                let mut batch = vec![0u64; a.len()];
                d.div_bits_batch(&a, &b, F32, rm, &mut batch);
                assert_eq!(scalar, batch, "{name} {rm:?}");
            }
        }
    }

    #[test]
    fn batch_reciprocal_cache_repeated_divisors_bit_identical() {
        // Constant divisor: every lane after the first hits the cache;
        // results must still equal the scalar path bit for bit.
        let mut d = TaylorDivider::paper_ilm(4);
        let a: Vec<u64> = (0..64)
            .map(|i| (1.5f32 + i as f32).to_bits() as u64)
            .collect();
        let b: Vec<u64> = vec![3.0f32.to_bits() as u64; 64];
        let mut out = vec![0u64; 64];
        d.div_bits_batch(&a, &b, F32, Rounding::NearestEven, &mut out);
        for i in 0..64 {
            let want = d.div_bits(a[i], b[i], F32, Rounding::NearestEven);
            assert_eq!(out[i], want, "lane {i}");
        }
    }

    #[test]
    fn batch_recip_cache_many_divisors_all_formats_bit_identical() {
        // More distinct divisors than cache ways, interleaved so ways
        // collide and evict mid-batch — results must stay bit-identical
        // to the scalar path in every format the service offers.
        use crate::fp::ALL_FORMATS;
        use crate::kernel::RECIP_CACHE_WAYS;
        let mut rng = crate::util::rng::Rng::new(77);
        for fmt in ALL_FORMATS {
            let divisors: Vec<u64> = (0..3 * RECIP_CACHE_WAYS as u64)
                .map(|_| {
                    let e = fmt.bias() as u64 + rng.below(5);
                    fmt.assemble(false, e, rng.next_u64() & fmt.frac_mask())
                })
                .collect();
            let a: Vec<u64> = (0..256)
                .map(|_| {
                    let e = fmt.bias() as u64 - rng.below(5);
                    fmt.assemble(rng.bool(0.5), e, rng.next_u64() & fmt.frac_mask())
                })
                .collect();
            let b: Vec<u64> = (0..256)
                .map(|i| divisors[i % divisors.len()])
                .collect();
            let mut d = TaylorDivider::paper_exact();
            let mut out = vec![0u64; a.len()];
            d.div_bits_batch(&a, &b, fmt, Rounding::NearestEven, &mut out);
            for i in 0..a.len() {
                let want = d.div_bits(a[i], b[i], fmt, Rounding::NearestEven);
                assert_eq!(out[i], want, "{} lane {i}", fmt.name());
            }
        }
    }

    #[test]
    fn batch_simd_choice_bit_identical_and_forced_follows_host() {
        // Forced-scalar and (when the host supports it) forced-SIMD
        // through the divider's own setter must agree bit for bit with
        // the per-lane scalar path on a specials-heavy batch.
        let a: Vec<u64> = [6.0f32, -1.5, f32::NAN, 0.0, 1.0e-40, 355.0, 9.0, 0.1, 2.5]
            .iter()
            .map(|x| x.to_bits() as u64)
            .collect();
        let b: Vec<u64> = [2.0f32, 3.0, 2.0, 3.0, 3.0, 113.0, 3.0, 0.7, 2.5]
            .iter()
            .map(|x| x.to_bits() as u64)
            .collect();
        let mut choices = vec![SimdChoice::Scalar, SimdChoice::Auto];
        if crate::simd::simd_available() {
            choices.push(SimdChoice::Forced);
        } else {
            let mut d = TaylorDivider::paper_exact();
            assert!(d.set_batch_simd(SimdChoice::Forced).is_err());
        }
        for choice in choices {
            let mut d = TaylorDivider::paper_exact();
            d.set_batch_simd(choice).unwrap();
            let mut out = vec![0u64; a.len()];
            d.div_bits_batch(&a, &b, F32, Rounding::NearestEven, &mut out);
            for i in 0..a.len() {
                let want = d.div_bits(a[i], b[i], F32, Rounding::NearestEven);
                assert_eq!(out[i], want, "{choice:?} lane {i}");
            }
        }
    }

    #[test]
    fn batch_f64_matches_scalar() {
        let mut d = TaylorDivider::paper_exact();
        let a: Vec<u64> = [1.0f64, 10.0, -3.25, 1e300, 5e-324, f64::NAN]
            .iter()
            .map(|x| x.to_bits())
            .collect();
        let b: Vec<u64> = [3.0f64, 4.0, 1.5, 1e-300, 2.0, 1.0]
            .iter()
            .map(|x| x.to_bits())
            .collect();
        let mut out = vec![0u64; a.len()];
        d.div_bits_batch(&a, &b, crate::fp::F64, Rounding::NearestEven, &mut out);
        for i in 0..a.len() {
            let want = d.div_bits(a[i], b[i], crate::fp::F64, Rounding::NearestEven);
            assert_eq!(out[i], want, "lane {i}");
        }
    }

    #[test]
    #[should_panic(expected = "output length mismatch")]
    fn batch_rejects_mismatched_output() {
        let mut d = TaylorDivider::paper_exact();
        let mut out = vec![0u64; 1];
        d.div_bits_batch(&[0, 0], &[0, 0], F32, Rounding::NearestEven, &mut out);
    }
}
