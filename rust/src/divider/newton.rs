//! Newton–Raphson reciprocal divider (the paper's §1 / ref [5] baseline).
//!
//! `y_{k+1} = y_k · (2 − x·y_k)` converges quadratically: each iteration
//! doubles the number of correct bits. It shares the PLA seed table and
//! fixed-point datapath with the Taylor unit so the comparison isolates
//! the *refinement algorithm*, not the seed quality.
//!
//! Hardware note (for the cost model): one NR iteration needs **two
//! dependent full multiplies** (x·y, then y·t), whereas one Taylor
//! "cycle" of the Fig-6 powering unit performs a multiply and a square
//! in parallel and the squarer is half the hardware — this is exactly
//! the tradeoff the paper argues (§5–6).

use super::{prepare, Divider, Prepared};
use crate::fp::{round_pack, Format, Rounding};
use crate::pla::SegmentTable;
use crate::powering::{ExactMul, Multiplier};

/// Newton–Raphson divider on the shared Q2.F datapath.
pub struct NewtonDivider {
    /// NR iterations (each doubles precision).
    pub iterations: u32,
    /// Datapath fraction bits.
    pub frac_bits: u32,
    /// Seed table (same PLA unit as the Taylor divider).
    pub table: SegmentTable,
    backend: ExactMul,
    /// Dependent multiply count (cost model).
    pub dependent_muls: u64,
}

impl NewtonDivider {
    pub fn new(iterations: u32, frac_bits: u32, table: SegmentTable) -> Self {
        assert_eq!(table.frac_bits, frac_bits);
        Self {
            iterations,
            frac_bits,
            table,
            backend: ExactMul::default(),
            dependent_muls: 0,
        }
    }

    /// Paper-comparable default: same Table-I seed (8 segments), 60-bit
    /// datapath. The seed is good to ~2^-9 (m_max ≈ 2.2e-3 ⇒ relative
    /// error < 2^-8.8), so 3 quadratic iterations exceed 53 bits.
    pub fn paper_default() -> Self {
        let bounds = crate::pla::derive_segments(5, 53).expect("Table-I derivation");
        Self::new(3, 60, SegmentTable::build(&bounds, 60))
    }

    /// Reciprocal of `x ∈ [1,2)` in Q2.F.
    pub fn reciprocal_fixed(&mut self, x: u64) -> u64 {
        let f = self.frac_bits;
        let two = 2u64 << f;
        let (mut y, _) = self.table.seed(x);
        for _ in 0..self.iterations {
            // t = 2 − x·y  (x·y ≤ ~1 + ε so the subtraction is safe).
            let xy = (self.backend.mul(x, y) >> f) as u64;
            let t = two.saturating_sub(xy);
            y = (self.backend.mul(y, t) >> f) as u64;
            self.dependent_muls += 2;
        }
        y
    }
}

impl Divider for NewtonDivider {
    fn name(&self) -> String {
        format!(
            "newton(k={}, segs={}, F={})",
            self.iterations,
            self.table.num_segments(),
            self.frac_bits
        )
    }

    fn div_bits(&mut self, a_bits: u64, b_bits: u64, fmt: Format, rm: Rounding) -> u64 {
        let f = self.frac_bits;
        assert!(f >= fmt.frac_bits);
        match prepare(a_bits, b_bits, fmt) {
            Prepared::Done(bits) => bits,
            Prepared::Divide {
                sign,
                exp,
                sig_a,
                sig_b,
            } => {
                let x = sig_b << (f - fmt.frac_bits);
                let recip = self.reciprocal_fixed(x);
                let q = sig_a as u128 * recip as u128;
                round_pack(sign, exp, q, fmt.frac_bits + f, false, fmt, rm).0
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp::ulp_diff_f32;
    use crate::util::rng::Rng;

    #[test]
    fn quadratic_convergence_bits_double() {
        // Measure worst-case reciprocal error across [1,2) per iteration
        // count; correct bits must roughly double until the datapath floor.
        let mut worst_bits = Vec::new();
        for k in 0..4 {
            let bounds = crate::pla::derive_segments(5, 53).expect("Table-I derivation");
            let mut d = NewtonDivider::new(k, 60, SegmentTable::build(&bounds, 60));
            let mut worst: f64 = 0.0;
            let scale = (1u128 << 60) as f64;
            for i in 0..1000 {
                let xf = 1.0 + (i as f64 + 0.5) / 1000.0;
                let x = (xf * scale) as u64;
                let got = d.reciprocal_fixed(x) as f64 / scale;
                worst = worst.max((got - 1.0 / xf).abs());
            }
            worst_bits.push(-worst.log2());
        }
        // Seed alone ≥ 8 bits; then ~double per iteration.
        assert!(worst_bits[0] >= 8.0, "{worst_bits:?}");
        assert!(worst_bits[1] >= worst_bits[0] * 1.8, "{worst_bits:?}");
        assert!(worst_bits[2] >= worst_bits[1] * 1.8, "{worst_bits:?}");
        assert!(worst_bits[3] >= 53.0, "{worst_bits:?}");
    }

    #[test]
    fn f32_division_correct_to_1ulp() {
        let mut d = NewtonDivider::paper_default();
        let mut r = Rng::new(3);
        for _ in 0..20_000 {
            let a = r.f32_log_uniform(-30, 30);
            let b = r.f32_log_uniform(-30, 30);
            let ours = d.div_f32(a, b);
            let ulps = ulp_diff_f32(ours, a / b).unwrap();
            assert!(ulps <= 1, "{a:e}/{b:e}: {ulps} ulps");
        }
    }

    #[test]
    fn specials_handled() {
        let mut d = NewtonDivider::paper_default();
        assert!(d.div_f32(0.0, 0.0).is_nan());
        assert_eq!(d.div_f32(-4.0, 0.0), f32::NEG_INFINITY);
        assert_eq!(d.div_f32(4.0, f32::INFINITY), 0.0);
    }

    #[test]
    fn dependent_mul_count_model() {
        let mut d = NewtonDivider::paper_default();
        let _ = d.div_f32(1.0, 3.0);
        // 3 iterations × 2 dependent muls.
        assert_eq!(d.dependent_muls, 6);
    }
}
