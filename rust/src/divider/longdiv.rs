//! Restoring digit-recurrence division — the exactly-rounded gold
//! reference (and a latency baseline: one quotient bit per cycle).
//!
//! The significand quotient is computed as an integer division with two
//! extra bits (guard + round position) and an exact sticky from the
//! remainder, so [`crate::fp::round_pack`] produces the correctly rounded
//! result in every rounding mode. Every accuracy table in the benches is
//! measured against this unit.

use super::{prepare, Divider, Prepared};
use crate::fp::{round_pack, Format, Rounding};

/// Digit-recurrence divider (restoring; 1 bit/cycle latency model).
#[derive(Debug, Default, Clone)]
pub struct LongDivider {
    /// Total significand-datapath cycles consumed (latency model).
    pub cycles: u64,
}

impl LongDivider {
    pub fn new() -> Self {
        Self::default()
    }

    /// Cycles one division's significand path takes: `frac_bits + 3`
    /// quotient bits (hidden + frac + guard + round margin).
    pub const fn cycles_per_div(fmt: Format) -> u64 {
        (fmt.frac_bits + 3) as u64
    }
}

impl Divider for LongDivider {
    fn name(&self) -> String {
        "longdiv(restoring)".to_string()
    }

    fn div_bits(&mut self, a_bits: u64, b_bits: u64, fmt: Format, rm: Rounding) -> u64 {
        match prepare(a_bits, b_bits, fmt) {
            Prepared::Done(bits) => bits,
            Prepared::Divide {
                sign,
                exp,
                sig_a,
                sig_b,
            } => {
                self.cycles += Self::cycles_per_div(fmt);
                // q = (sig_a << (frac_bits + 2)) / sig_b gives a quotient
                // in (2^(frac_bits+1), 2^(frac_bits+3)): at least
                // frac_bits + 2 significant bits — hidden + frac + guard —
                // with the remainder providing the exact sticky.
                let shift = fmt.frac_bits + 2;
                let num = (sig_a as u128) << shift;
                let den = sig_b as u128;
                let q = num / den;
                let rem = num % den;
                round_pack(
                    sign,
                    exp - shift as i32 + fmt.frac_bits as i32,
                    q,
                    fmt.frac_bits,
                    rem != 0,
                    fmt,
                    rm,
                )
                .0
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp::{F32, F64};
    use crate::util::rng::Rng;

    #[test]
    fn exactly_matches_hardware_f32_randomized() {
        let mut d = LongDivider::new();
        let mut r = Rng::new(99);
        for _ in 0..50_000 {
            let a = f32::from_bits(r.next_u32());
            let b = f32::from_bits(r.next_u32());
            let ours = d.div_f32(a, b);
            let hw = a / b;
            if hw.is_nan() {
                assert!(ours.is_nan(), "{a:?}/{b:?}");
            } else {
                assert_eq!(ours.to_bits(), hw.to_bits(), "{a:?}/{b:?}");
            }
        }
    }

    #[test]
    fn exactly_matches_hardware_f64_randomized() {
        let mut d = LongDivider::new();
        let mut r = Rng::new(100);
        for _ in 0..30_000 {
            let a = f64::from_bits(r.next_u64());
            let b = f64::from_bits(r.next_u64());
            let ours = d.div_f64(a, b);
            let hw = a / b;
            if hw.is_nan() {
                assert!(ours.is_nan());
            } else {
                assert_eq!(ours.to_bits(), hw.to_bits(), "{a:?}/{b:?}");
            }
        }
    }

    #[test]
    fn directed_rounding_modes_match_bracketing() {
        // RTZ result ≤ RNE result magnitude; RUP ≥ exact; RDN ≤ exact.
        let mut d = LongDivider::new();
        let cases = [(1.0f32, 3.0f32), (2.0, 7.0), (10.0, 9.0), (-1.0, 3.0)];
        for (a, b) in cases {
            let q_rtz = f32::from_bits(d.div_bits(
                a.to_bits() as u64,
                b.to_bits() as u64,
                F32,
                Rounding::TowardZero,
            ) as u32);
            let q_rup = f32::from_bits(d.div_bits(
                a.to_bits() as u64,
                b.to_bits() as u64,
                F32,
                Rounding::TowardPositive,
            ) as u32);
            let q_rdn = f32::from_bits(d.div_bits(
                a.to_bits() as u64,
                b.to_bits() as u64,
                F32,
                Rounding::TowardNegative,
            ) as u32);
            let exact = a as f64 / b as f64;
            assert!(q_rtz.abs() as f64 <= exact.abs() + 1e-12, "{a}/{b} RTZ");
            assert!((q_rup as f64) >= exact, "{a}/{b} RUP {q_rup} < {exact}");
            assert!((q_rdn as f64) <= exact, "{a}/{b} RDN");
            assert!(q_rdn <= q_rup);
        }
    }

    #[test]
    fn exact_division_inexact_flag_via_sticky() {
        // 1/4 is exact: directed modes agree with RNE.
        let mut d = LongDivider::new();
        for rm in [
            Rounding::NearestEven,
            Rounding::TowardZero,
            Rounding::TowardPositive,
            Rounding::TowardNegative,
        ] {
            let q = d.div_bits(1.0f32.to_bits() as u64, 4.0f32.to_bits() as u64, F32, rm);
            assert_eq!(f32::from_bits(q as u32), 0.25);
        }
    }

    #[test]
    fn cycle_model_accumulates() {
        let mut d = LongDivider::new();
        assert_eq!(d.cycles, 0);
        let _ = d.div_f32(1.0, 3.0);
        assert_eq!(d.cycles, LongDivider::cycles_per_div(F32));
        let _ = d.div_f64(1.0, 3.0);
        assert_eq!(
            d.cycles,
            LongDivider::cycles_per_div(F32) + LongDivider::cycles_per_div(F64)
        );
        // Specials don't use the significand path.
        let _ = d.div_f32(1.0, 0.0);
        assert_eq!(
            d.cycles,
            LongDivider::cycles_per_div(F32) + LongDivider::cycles_per_div(F64)
        );
    }

    #[test]
    fn bf16_and_f16_supported() {
        use crate::fp::{BF16, F16};
        let mut d = LongDivider::new();
        // 1.5 / 0.5 = 3.0 in f16: 1.5=0x3E00, 0.5=0x3800, 3.0=0x4200.
        let q = d.div_bits(0x3E00, 0x3800, F16, Rounding::NearestEven);
        assert_eq!(q, 0x4200);
        // In bf16: 1.5=0x3FC0, 0.5=0x3F00, 3.0=0x4040.
        let q = d.div_bits(0x3FC0, 0x3F00, BF16, Rounding::NearestEven);
        assert_eq!(q, 0x4040);
    }
}
