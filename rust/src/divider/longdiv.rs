//! Restoring digit-recurrence division — the exactly-rounded gold
//! reference (and a latency baseline: one quotient bit per cycle).
//!
//! The significand quotient is computed as an integer division with two
//! extra bits (guard + round position) and an exact sticky from the
//! remainder, so [`crate::fp::round_pack`] produces the correctly rounded
//! result in every rounding mode. Every accuracy table in the benches is
//! measured against this unit.
//!
//! Beyond division the unit carries exactly-rounded scalar references
//! for the service's other ops: [`LongDivider::recip_bits`] (`1/x`, the
//! division with a literal one dividend) and [`LongDivider::rsqrt_bits`]
//! (`1/sqrt(x)` via an exact integer square root with remainder-driven
//! sticky). The fused scale-by-reciprocal op needs no new reference —
//! its per-lane semantics *are* `div_bits(a[i], b[row])`.

use super::{prepare, Divider, Prepared};
use crate::fp::{round_pack, unpack, Class, Format, Rounding};

/// Digit-recurrence divider (restoring; 1 bit/cycle latency model).
#[derive(Debug, Default, Clone)]
pub struct LongDivider {
    /// Total significand-datapath cycles consumed (latency model).
    pub cycles: u64,
}

impl LongDivider {
    pub fn new() -> Self {
        Self::default()
    }

    /// Cycles one division's significand path takes: `frac_bits + 3`
    /// quotient bits (hidden + frac + guard + round margin).
    pub const fn cycles_per_div(fmt: Format) -> u64 {
        (fmt.frac_bits + 3) as u64
    }

    /// Exactly-rounded reciprocal reference: `1 / x`. Division with the
    /// format's literal one as dividend — specials fall out of the
    /// shared [`prepare`] table (NaN → NaN, ±0 → ±Inf, ±Inf → ±0).
    pub fn recip_bits(&mut self, x_bits: u64, fmt: Format, rm: Rounding) -> u64 {
        self.div_bits(fmt.one(), x_bits, fmt, rm)
    }

    /// Exactly-rounded reciprocal square root reference: `1 / sqrt(x)`.
    ///
    /// Specials follow IEEE `rSqrt`: NaN → NaN, negative non-zero
    /// (including −Inf) → NaN, ±0 → ±Inf, +Inf → +0. The finite
    /// positive path folds the exponent parity into the significand
    /// (`v = s'·2^(2k)`, `s' ∈ [1,4)`), computes `q = ⌊2^P / S⌋` with an
    /// exact remainder and `W = ⌊sqrt(q)⌋ = ⌊y·2^G⌋` (the nested-floor
    /// identity makes the composition exact), and rounds `W` with a
    /// remainder-driven sticky — correctly rounded in every mode.
    pub fn rsqrt_bits(&mut self, x_bits: u64, fmt: Format, rm: Rounding) -> u64 {
        let u = unpack(x_bits, fmt);
        match u.class {
            Class::NaN => return fmt.nan(),
            Class::Zero => return fmt.inf(u.sign),
            _ if u.sign => return fmt.nan(),
            Class::Inf => return fmt.zero(false),
            Class::Normal | Class::Subnormal => {}
        }
        self.cycles += Self::cycles_per_div(fmt);
        // Fold the exponent parity: x = (sig/2^frac)·2^exp = s'·2^(2k)
        // with s' ∈ [1,4) — even exp keeps S = sig, odd exp doubles it.
        let (s, k) = if u.exp.rem_euclid(2) == 0 {
            (u.sig as u128, u.exp / 2)
        } else {
            ((u.sig as u128) << 1, (u.exp - 1) / 2)
        };
        // Result 1/sqrt(x) = y·2^(−k), y = sqrt(2^frac / S) ∈ (1/2, 1].
        // W = ⌊y·2^G⌋ = ⌊sqrt(2^P / S)⌋ with P = 2G + frac: G = frac + 2
        // gives hidden + frac + guard bits before the sticky.
        let g = fmt.frac_bits + 2;
        let p = 2 * g + fmt.frac_bits;
        let (q, rem) = if p <= 127 {
            let num = 1u128 << p;
            (num / s, num % s)
        } else {
            // f64: P = 160 exceeds u128 — stage the division as
            // 2^P / S = (t1·2^60 + r1·2^60 / S) with P1 = P − 60 ≤ 127.
            let p1 = p - 60;
            let t1 = (1u128 << p1) / s;
            let r1 = (1u128 << p1) % s;
            ((t1 << 60) + (r1 << 60) / s, (r1 << 60) % s)
        };
        let w = isqrt_u128(q);
        // Exact iff both the division and the square root were: any
        // remainder below W's last kept bit ORs into sticky.
        let sticky = rem != 0 || w * w != q;
        round_pack(false, -k, w, g, sticky, fmt, rm).0
    }
}

/// `⌊sqrt(n)⌋` over `u128` (monotone-descending integer Newton).
fn isqrt_u128(n: u128) -> u128 {
    if n < 2 {
        return n;
    }
    // Start above the root: x0 = 2^(⌊log2 n⌋/2 + 1) ⇒ x0² > n.
    let mut x = 1u128 << ((127 - n.leading_zeros()) / 2 + 1);
    loop {
        let y = (x + n / x) >> 1;
        if y >= x {
            return x;
        }
        x = y;
    }
}

impl Divider for LongDivider {
    fn name(&self) -> String {
        "longdiv(restoring)".to_string()
    }

    fn div_bits(&mut self, a_bits: u64, b_bits: u64, fmt: Format, rm: Rounding) -> u64 {
        match prepare(a_bits, b_bits, fmt) {
            Prepared::Done(bits) => bits,
            Prepared::Divide {
                sign,
                exp,
                sig_a,
                sig_b,
            } => {
                self.cycles += Self::cycles_per_div(fmt);
                // q = (sig_a << (frac_bits + 2)) / sig_b gives a quotient
                // in (2^(frac_bits+1), 2^(frac_bits+3)): at least
                // frac_bits + 2 significant bits — hidden + frac + guard —
                // with the remainder providing the exact sticky.
                let shift = fmt.frac_bits + 2;
                let num = (sig_a as u128) << shift;
                let den = sig_b as u128;
                let q = num / den;
                let rem = num % den;
                round_pack(
                    sign,
                    exp - shift as i32 + fmt.frac_bits as i32,
                    q,
                    fmt.frac_bits,
                    rem != 0,
                    fmt,
                    rm,
                )
                .0
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp::{F32, F64};
    use crate::util::rng::Rng;

    #[test]
    fn exactly_matches_hardware_f32_randomized() {
        let mut d = LongDivider::new();
        let mut r = Rng::new(99);
        for _ in 0..50_000 {
            let a = f32::from_bits(r.next_u32());
            let b = f32::from_bits(r.next_u32());
            let ours = d.div_f32(a, b);
            let hw = a / b;
            if hw.is_nan() {
                assert!(ours.is_nan(), "{a:?}/{b:?}");
            } else {
                assert_eq!(ours.to_bits(), hw.to_bits(), "{a:?}/{b:?}");
            }
        }
    }

    #[test]
    fn exactly_matches_hardware_f64_randomized() {
        let mut d = LongDivider::new();
        let mut r = Rng::new(100);
        for _ in 0..30_000 {
            let a = f64::from_bits(r.next_u64());
            let b = f64::from_bits(r.next_u64());
            let ours = d.div_f64(a, b);
            let hw = a / b;
            if hw.is_nan() {
                assert!(ours.is_nan());
            } else {
                assert_eq!(ours.to_bits(), hw.to_bits(), "{a:?}/{b:?}");
            }
        }
    }

    #[test]
    fn directed_rounding_modes_match_bracketing() {
        // RTZ result ≤ RNE result magnitude; RUP ≥ exact; RDN ≤ exact.
        let mut d = LongDivider::new();
        let cases = [(1.0f32, 3.0f32), (2.0, 7.0), (10.0, 9.0), (-1.0, 3.0)];
        for (a, b) in cases {
            let q_rtz = f32::from_bits(d.div_bits(
                a.to_bits() as u64,
                b.to_bits() as u64,
                F32,
                Rounding::TowardZero,
            ) as u32);
            let q_rup = f32::from_bits(d.div_bits(
                a.to_bits() as u64,
                b.to_bits() as u64,
                F32,
                Rounding::TowardPositive,
            ) as u32);
            let q_rdn = f32::from_bits(d.div_bits(
                a.to_bits() as u64,
                b.to_bits() as u64,
                F32,
                Rounding::TowardNegative,
            ) as u32);
            let exact = a as f64 / b as f64;
            assert!(q_rtz.abs() as f64 <= exact.abs() + 1e-12, "{a}/{b} RTZ");
            assert!((q_rup as f64) >= exact, "{a}/{b} RUP {q_rup} < {exact}");
            assert!((q_rdn as f64) <= exact, "{a}/{b} RDN");
            assert!(q_rdn <= q_rup);
        }
    }

    #[test]
    fn exact_division_inexact_flag_via_sticky() {
        // 1/4 is exact: directed modes agree with RNE.
        let mut d = LongDivider::new();
        for rm in [
            Rounding::NearestEven,
            Rounding::TowardZero,
            Rounding::TowardPositive,
            Rounding::TowardNegative,
        ] {
            let q = d.div_bits(1.0f32.to_bits() as u64, 4.0f32.to_bits() as u64, F32, rm);
            assert_eq!(f32::from_bits(q as u32), 0.25);
        }
    }

    #[test]
    fn cycle_model_accumulates() {
        let mut d = LongDivider::new();
        assert_eq!(d.cycles, 0);
        let _ = d.div_f32(1.0, 3.0);
        assert_eq!(d.cycles, LongDivider::cycles_per_div(F32));
        let _ = d.div_f64(1.0, 3.0);
        assert_eq!(
            d.cycles,
            LongDivider::cycles_per_div(F32) + LongDivider::cycles_per_div(F64)
        );
        // Specials don't use the significand path.
        let _ = d.div_f32(1.0, 0.0);
        assert_eq!(
            d.cycles,
            LongDivider::cycles_per_div(F32) + LongDivider::cycles_per_div(F64)
        );
    }

    #[test]
    fn isqrt_is_exact_floor_sqrt() {
        let mut r = Rng::new(41);
        for &n in &[0u128, 1, 2, 3, 4, 8, 9, 15, 16, 17, u64::MAX as u128] {
            let s = isqrt_u128(n);
            assert!(s * s <= n, "{n}");
            assert!((s + 1) * (s + 1) > n, "{n}");
        }
        for _ in 0..20_000 {
            let n = ((r.next_u64() as u128) << 64 | r.next_u64() as u128) >> (r.below(120) as u32);
            let s = isqrt_u128(n);
            assert!(s * s <= n, "{n}");
            // (s+1)² may overflow u128 for 128-bit n — overflow means
            // it certainly exceeds n.
            let above = s
                .checked_add(1)
                .and_then(|s1| s1.checked_mul(s1))
                .map_or(true, |sq| sq > n);
            assert!(above, "{n}");
        }
    }

    #[test]
    fn recip_matches_hardware_f32_randomized() {
        let mut d = LongDivider::new();
        let mut r = Rng::new(43);
        for _ in 0..30_000 {
            let x = f32::from_bits(r.next_u32());
            let ours =
                f32::from_bits(d.recip_bits(x.to_bits() as u64, F32, Rounding::NearestEven) as u32);
            let hw = 1.0 / x;
            if hw.is_nan() {
                assert!(ours.is_nan(), "1/{x:?}");
            } else {
                assert_eq!(ours.to_bits(), hw.to_bits(), "1/{x:?}");
            }
        }
    }

    #[test]
    fn rsqrt_specials_table() {
        use crate::fp::{ALL_FORMATS, BF16, F16};
        let mut d = LongDivider::new();
        for fmt in ALL_FORMATS {
            let rm = Rounding::NearestEven;
            assert_eq!(d.rsqrt_bits(fmt.nan(), fmt, rm), fmt.nan(), "{}", fmt.name());
            assert_eq!(d.rsqrt_bits(fmt.zero(false), fmt, rm), fmt.inf(false));
            assert_eq!(d.rsqrt_bits(fmt.zero(true), fmt, rm), fmt.inf(true));
            assert_eq!(d.rsqrt_bits(fmt.inf(false), fmt, rm), fmt.zero(false));
            assert_eq!(d.rsqrt_bits(fmt.inf(true), fmt, rm), fmt.nan());
            // Any negative non-zero value, finite or not → NaN.
            let neg = fmt.assemble(true, fmt.bias() as u64, 1);
            assert_eq!(d.rsqrt_bits(neg, fmt, rm), fmt.nan());
            // Exact powers of four are exact in every mode.
            for rm in Rounding::ALL {
                assert_eq!(d.rsqrt_bits(fmt.one(), fmt, rm), fmt.one(), "{rm:?}");
                let four = fmt.assemble(false, fmt.bias() as u64 + 2, 0);
                let half = fmt.assemble(false, fmt.bias() as u64 - 1, 0);
                assert_eq!(d.rsqrt_bits(four, fmt, rm), half, "{rm:?}");
            }
        }
        // Known constants: 1/sqrt(2) and sqrt(2) in f32.
        let q = d.rsqrt_bits(2.0f32.to_bits() as u64, F32, Rounding::NearestEven);
        assert_eq!(q as u32, 0x3F35_04F3);
        let q = d.rsqrt_bits(0.5f32.to_bits() as u64, F32, Rounding::NearestEven);
        assert_eq!(q as u32, 0x3FB5_04F3);
        // Odd-exponent parity fold in the narrow formats: rsqrt(0.25)=2.
        for fmt in [F16, BF16] {
            let quarter = fmt.assemble(false, fmt.bias() as u64 - 2, 0);
            let two = fmt.assemble(false, fmt.bias() as u64 + 1, 0);
            assert_eq!(d.rsqrt_bits(quarter, fmt, Rounding::NearestEven), two);
        }
    }

    #[test]
    fn rsqrt_matches_f64_reference_f32_randomized() {
        // An f64-computed 1/sqrt(x) carries ≲2^−52 relative error — far
        // below the f32 half-ulp (2^−25) — so away from rounding-tie
        // proximity the references agree bit for bit; allow the 1-ulp
        // slack only for the directed modes where the f64 double
        // rounding can sit on the boundary.
        let mut d = LongDivider::new();
        let mut r = Rng::new(44);
        let mut checked = 0;
        while checked < 30_000 {
            let x = f32::from_bits(r.next_u32() & 0x7FFF_FFFF);
            if !x.is_finite() || x == 0.0 {
                continue;
            }
            checked += 1;
            let want = (1.0 / (x as f64).sqrt()) as f32;
            let ours =
                f32::from_bits(d.rsqrt_bits(x.to_bits() as u64, F32, Rounding::NearestEven) as u32);
            let ulps = crate::fp::ulp_diff_f32(ours, want).unwrap();
            assert!(ulps <= 1, "rsqrt({x:?}) = {ours:?} vs {want:?}");
        }
    }

    #[test]
    fn rsqrt_directed_modes_bracket_the_exact_value() {
        let mut d = LongDivider::new();
        let mut r = Rng::new(45);
        for _ in 0..5_000 {
            let x = f64::from_bits(
                (r.next_u64() & !F64.sign_mask()) % f64::MAX.to_bits() | 1,
            );
            let exact = 1.0 / x.sqrt(); // ≲1 ulp off; brackets still hold with slack
            let up = f64::from_bits(d.rsqrt_bits(x.to_bits(), F64, Rounding::TowardPositive));
            let dn = f64::from_bits(d.rsqrt_bits(x.to_bits(), F64, Rounding::TowardNegative));
            let tz = f64::from_bits(d.rsqrt_bits(x.to_bits(), F64, Rounding::TowardZero));
            let ne = f64::from_bits(d.rsqrt_bits(x.to_bits(), F64, Rounding::NearestEven));
            assert!(dn <= up, "rsqrt({x:e})");
            assert!(tz <= up && dn <= tz, "rsqrt({x:e})");
            assert!(ne == up || ne == dn, "nearest must be one of the brackets");
            // `exact` itself carries two f64 roundings (sqrt then
            // divide): allow the binade-boundary worst case.
            assert!(
                crate::fp::ulp_diff_f64(ne, exact).unwrap() <= 2,
                "rsqrt({x:e}) = {ne:e} vs {exact:e}"
            );
        }
    }

    #[test]
    fn bf16_and_f16_supported() {
        use crate::fp::{BF16, F16};
        let mut d = LongDivider::new();
        // 1.5 / 0.5 = 3.0 in f16: 1.5=0x3E00, 0.5=0x3800, 3.0=0x4200.
        let q = d.div_bits(0x3E00, 0x3800, F16, Rounding::NearestEven);
        assert_eq!(q, 0x4200);
        // In bf16: 1.5=0x3FC0, 0.5=0x3F00, 3.0=0x4040.
        let q = d.div_bits(0x3FC0, 0x3F00, BF16, Rounding::NearestEven);
        assert_eq!(q, 0x4040);
    }
}
