//! Goldschmidt multiplicative divider (baseline).
//!
//! Both numerator and denominator are repeatedly multiplied by a
//! correction factor `F_k = 2 − D_k`; `D_k → 1`, `N_k → a/b`. Unlike
//! Newton–Raphson, the two multiplies of one iteration are *independent*
//! (pipelinable), which is why real FPUs often prefer it — a useful
//! contrast for the paper's parallel-squaring argument.

use super::{prepare, Divider, Prepared};
use crate::fp::{round_pack, Format, Rounding};
use crate::pla::SegmentTable;
use crate::powering::{ExactMul, Multiplier};

/// Goldschmidt divider on the shared Q2.F datapath.
pub struct GoldschmidtDivider {
    pub iterations: u32,
    pub frac_bits: u32,
    pub table: SegmentTable,
    backend: ExactMul,
    /// Independent multiply pairs issued (cost model).
    pub mul_pairs: u64,
}

impl GoldschmidtDivider {
    pub fn new(iterations: u32, frac_bits: u32, table: SegmentTable) -> Self {
        assert_eq!(table.frac_bits, frac_bits);
        Self {
            iterations,
            frac_bits,
            table,
            backend: ExactMul::default(),
            mul_pairs: 0,
        }
    }

    /// Same seed/datapath as the other units; 3 iterations ≥ 53 bits.
    pub fn paper_default() -> Self {
        let bounds = crate::pla::derive_segments(5, 53).expect("Table-I derivation");
        Self::new(3, 60, SegmentTable::build(&bounds, 60))
    }

    /// Significand quotient `sig_a/sig_b`, both Q2.F in [1,2); returns Q2.F.
    pub fn quotient_fixed(&mut self, sig_a: u64, sig_b: u64) -> u64 {
        let f = self.frac_bits;
        let two = 2u64 << f;
        // Seed: N0 = a·y0, D0 = b·y0.
        let (y0, _) = self.table.seed(sig_b);
        let mut n = (self.backend.mul(sig_a, y0) >> f) as u64;
        let mut d = (self.backend.mul(sig_b, y0) >> f) as u64;
        for _ in 0..self.iterations {
            let fk = two.saturating_sub(d);
            // The two multiplies are independent — one "pair" per cycle.
            n = (self.backend.mul(n, fk) >> f) as u64;
            d = (self.backend.mul(d, fk) >> f) as u64;
            self.mul_pairs += 1;
        }
        n
    }
}

impl Divider for GoldschmidtDivider {
    fn name(&self) -> String {
        format!(
            "goldschmidt(k={}, segs={}, F={})",
            self.iterations,
            self.table.num_segments(),
            self.frac_bits
        )
    }

    fn div_bits(&mut self, a_bits: u64, b_bits: u64, fmt: Format, rm: Rounding) -> u64 {
        let f = self.frac_bits;
        assert!(f >= fmt.frac_bits);
        match prepare(a_bits, b_bits, fmt) {
            Prepared::Done(bits) => bits,
            Prepared::Divide {
                sign,
                exp,
                sig_a,
                sig_b,
            } => {
                let a = sig_a << (f - fmt.frac_bits);
                let b = sig_b << (f - fmt.frac_bits);
                let q = self.quotient_fixed(a, b); // in (0.5, 2) Q2.F
                round_pack(sign, exp, q as u128, f, true, fmt, rm).0
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp::ulp_diff_f32;
    use crate::util::rng::Rng;

    #[test]
    fn converges_to_quotient() {
        let mut d = GoldschmidtDivider::paper_default();
        let f = 60u32;
        let scale = (1u128 << f) as f64;
        for (a, b) in [(1.5, 1.25), (1.0, 1.9999), (1.7, 1.1), (1.0, 1.0)] {
            let qa = (a * scale) as u64;
            let qb = (b * scale) as u64;
            let got = d.quotient_fixed(qa, qb) as f64 / scale;
            assert!(
                (got - a / b).abs() < 2f64.powi(-50),
                "{a}/{b}: got {got}"
            );
        }
    }

    #[test]
    fn f32_division_correct_to_1ulp() {
        let mut d = GoldschmidtDivider::paper_default();
        let mut r = Rng::new(17);
        for _ in 0..20_000 {
            let a = r.f32_log_uniform(-30, 30);
            let b = r.f32_log_uniform(-30, 30);
            let ours = d.div_f32(a, b);
            let ulps = ulp_diff_f32(ours, a / b).unwrap();
            assert!(ulps <= 1, "{a:e}/{b:e}: {ulps} ulps");
        }
    }

    #[test]
    fn specials_handled() {
        let mut d = GoldschmidtDivider::paper_default();
        assert!(d.div_f32(f32::INFINITY, f32::INFINITY).is_nan());
        assert_eq!(d.div_f32(5.0, 0.0), f32::INFINITY);
        assert_eq!(d.div_f32(0.0, 5.0), 0.0);
    }

    #[test]
    fn mul_pair_count_model() {
        let mut d = GoldschmidtDivider::paper_default();
        let _ = d.div_f32(1.0, 3.0);
        assert_eq!(d.mul_pairs, 3);
    }

    #[test]
    fn iteration_sweep_improves_error() {
        let bounds = crate::pla::derive_segments(5, 53).expect("Table-I derivation");
        let scale = (1u128 << 60) as f64;
        let mut prev = f64::INFINITY;
        for k in 0..4 {
            let mut d = GoldschmidtDivider::new(k, 60, SegmentTable::build(&bounds, 60));
            let mut worst: f64 = 0.0;
            for i in 0..500 {
                let a = 1.0 + i as f64 / 500.0;
                let b = 1.0 + ((i * 7) % 500) as f64 / 500.0;
                let got = d.quotient_fixed((a * scale) as u64, (b * scale) as u64) as f64 / scale;
                worst = worst.max((got - a / b).abs());
            }
            assert!(worst <= prev, "error rose at k={k}");
            prev = worst;
        }
        assert!(prev < 2f64.powi(-50));
    }
}
