//! Word-level models of the ILM's front-end blocks.
//!
//! * **Priority encoder** — returns the position `k` of the most
//!   significant set bit (`k = ⌊log2 N⌋`, the "characteristic" of eq 21).
//! * **Leading-one detector (LOD)** — isolates the leading one
//!   (`2^k`); the residue `N − 2^k` of eq (25) is the operand with that
//!   bit cleared.
//!
//! These functions correspond one-to-one with the PE/LOD boxes of Fig 4;
//! their gate costs are modelled in [`crate::hw::components`].

/// Position of the most significant set bit: `⌊log2 n⌋`. Panics on 0 in
/// debug builds (hardware would never be fed a zero here; the unit's
/// control logic short-circuits zero operands).
#[inline]
pub fn leading_one_pos(n: u64) -> u32 {
    debug_assert!(n != 0, "priority encoder fed zero");
    63 - n.leading_zeros()
}

/// Priority encoder output: `(k, N − 2^k)` — characteristic and residue.
#[inline]
pub fn priority_encode(n: u64) -> (u32, u64) {
    let k = leading_one_pos(n);
    (k, n ^ (1 << k))
}

/// Leading-one detector: the isolated leading one, `2^k` (0 for 0 input —
/// LOD hardware is combinational and well defined on zero).
#[inline]
pub fn lod(n: u64) -> u64 {
    if n == 0 {
        0
    } else {
        1 << leading_one_pos(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::check_eq;
    use crate::util::check::{forall, Config};

    #[test]
    fn known_positions() {
        assert_eq!(leading_one_pos(1), 0);
        assert_eq!(leading_one_pos(2), 1);
        assert_eq!(leading_one_pos(3), 1);
        assert_eq!(leading_one_pos(255), 7);
        assert_eq!(leading_one_pos(256), 8);
        assert_eq!(leading_one_pos(u64::MAX), 63);
    }

    #[test]
    fn lod_isolates_top_bit() {
        assert_eq!(lod(0), 0);
        assert_eq!(lod(1), 1);
        assert_eq!(lod(0b1011), 0b1000);
        assert_eq!(lod(u64::MAX), 1 << 63);
    }

    #[test]
    fn encode_decomposition_reconstructs() {
        forall(Config::named("N = 2^k + residue").cases(1000), |d| {
            let n = d.range_u64(1, u64::MAX);
            let (k, r) = priority_encode(n);
            check_eq!((1u64 << k) + r, n);
            // Residue is strictly below the leading one.
            crate::check_that!(r < (1 << k) || k == 0 && r == 0);
            Ok(())
        });
    }

    #[test]
    fn matches_float_log2_floor() {
        for n in 1u64..(1 << 16) {
            assert_eq!(leading_one_pos(n), (n as f64).log2().floor() as u32, "{n}");
        }
    }
}
