//! The Iterative Logarithmic Multiplier (paper §4, eq 21–27, Fig 4).
//!
//! Mitchell's logarithmic multiplier approximates `N1·N2` by dropping the
//! `x1·x2` cross term of eq (22). The ILM (Babić/Avramović/Bulić, paper
//! ref [12]) recovers that term iteratively: the error after the basic
//! approximation is itself a product of two smaller numbers — the
//! operands with their leading ones cleared — so the same hardware block
//! can be reapplied. Each correction stage either terminates exactly
//! (one residue reaches zero) or adds one more `P_approx` term.
//!
//! This module is the *bit-exact word-level model* of that hardware:
//! every operation below (leading-one detection, bit clear, shifts, adds)
//! corresponds one-to-one to a block in Fig 4. The gate-level cost of
//! those blocks lives in [`crate::hw`]; the cycle schedule in
//! [`crate::hw::cycles`].

pub mod priority_encoder;

pub use priority_encoder::{leading_one_pos, lod, priority_encode};

use crate::simd::Engine;

/// Outcome of an ILM multiplication.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct IlmResult {
    /// The (possibly approximate) product.
    pub product: u128,
    /// Correction stages actually executed (≤ requested iterations).
    pub stages: u32,
    /// True if the result is the exact product (a residue hit zero
    /// before the iteration budget ran out).
    pub exact: bool,
}

/// One basic-block evaluation: `P_approx^(0)` of eq (24) plus the residue
/// pair that generates `E^(0)` of eq (25).
///
/// For non-zero `n1, n2`:
/// `P0 = 2^(k1+k2) + (n1 − 2^k1)·2^k2 + (n2 − 2^k2)·2^k1`
#[inline]
pub fn basic_block(n1: u64, n2: u64) -> (u128, u64, u64) {
    debug_assert!(n1 != 0 && n2 != 0);
    let k1 = leading_one_pos(n1);
    let k2 = leading_one_pos(n2);
    let r1 = n1 ^ (1 << k1); // N1 with its leading one cleared (eq 25 note)
    let r2 = n2 ^ (1 << k2);
    let p0 = (1u128 << (k1 + k2)) + ((r1 as u128) << k2) + ((r2 as u128) << k1);
    (p0, r1, r2)
}

/// ILM multiply of `n1 · n2` with at most `iterations` correction stages
/// (eq 26–27). `iterations = 0` is Mitchell's basic approximation.
///
/// The result is always ≤ the exact product, and equals it when the
/// recursion terminates (some residue becomes zero) within the budget.
pub fn ilm_mul(n1: u64, n2: u64, iterations: u32) -> IlmResult {
    if n1 == 0 || n2 == 0 {
        return IlmResult {
            product: 0,
            stages: 0,
            exact: true,
        };
    }
    let (mut acc, mut r1, mut r2) = basic_block(n1, n2);
    let mut stages = 0;
    while stages < iterations {
        if r1 == 0 || r2 == 0 {
            return IlmResult {
                product: acc,
                stages,
                exact: true,
            };
        }
        let (p, nr1, nr2) = basic_block(r1, r2);
        acc += p;
        r1 = nr1;
        r2 = nr2;
        stages += 1;
    }
    let exact = r1 == 0 || r2 == 0;
    IlmResult {
        product: acc,
        stages,
        exact,
    }
}

/// Mitchell's basic logarithmic product (zero correction stages).
#[inline]
pub fn mitchell_mul(n1: u64, n2: u64) -> u128 {
    ilm_mul(n1, n2, 0).product
}

/// Exact ILM product: iterate until a residue is zero. For `w`-bit
/// operands at most `w − 1` stages are needed (each stage clears one
/// leading one from each residue).
#[inline]
pub fn ilm_mul_exact(n1: u64, n2: u64) -> u128 {
    ilm_mul(n1, n2, 64).product
}

/// Worst-case stage count to make a `w`-bit × `w`-bit product exact:
/// the residue loses at least its leading bit per stage, so `w − 1`
/// corrections always suffice (an all-ones operand realizes the bound).
pub const fn max_stages_for_width(w: u32) -> u32 {
    if w == 0 {
        0
    } else {
        w - 1
    }
}

/// Absolute error of an `iterations`-stage ILM product vs exact.
pub fn ilm_abs_error(n1: u64, n2: u64, iterations: u32) -> u128 {
    let approx = ilm_mul(n1, n2, iterations).product;
    let exact = (n1 as u128) * (n2 as u128);
    exact - approx // ILM never overshoots
}

/// Relative error of an `iterations`-stage ILM product vs exact
/// (0 for zero products).
pub fn ilm_rel_error(n1: u64, n2: u64, iterations: u32) -> f64 {
    let exact = (n1 as u128) * (n2 as u128);
    if exact == 0 {
        return 0.0;
    }
    ilm_abs_error(n1, n2, iterations) as f64 / exact as f64
}

/// Fixed-point multiply through the ILM: operands are unsigned Q(m.f)
/// values (integers scaled by 2^f); the 2f-fraction product is truncated
/// back to f fraction bits, exactly as a hardware datapath would wire it.
#[inline]
pub fn ilm_mul_fixed(a: u64, b: u64, frac_bits: u32, iterations: u32) -> u64 {
    (ilm_mul(a, b, iterations).product >> frac_bits) as u64
}

/// Lane-array fixed-point ILM multiplies:
/// `out[i] = ilm_mul_fixed(a[i], b[i], frac_bits, iterations)` — the
/// odd-power stage of the [`crate::kernel`] pipeline, restructured for
/// the explicit lane engine ([`crate::simd`]). Each correction **stage**
/// runs over the whole tile: the priority-encoder inner loop is one
/// [`Engine::priority_encode_batch`] pass per operand array —
/// branch-light, lane-parallel, and genuinely vectorized on the
/// engines with a vector leading-one detector (`vplzcntq` on AVX-512,
/// the `vclzq` half-select on NEON) — followed by the eq-24 assembly.
/// Per
/// lane the executed operation sequence is exactly [`ilm_mul`]'s —
/// settled lanes (a residue hit zero) skip their remaining stages like
/// the scalar early-out — so results are bit-identical per lane; the
/// unit test pins this per engine.
pub fn ilm_mul_fixed_batch(
    eng: Engine,
    a: &[u64],
    b: &[u64],
    frac_bits: u32,
    iterations: u32,
    out: &mut [u64],
) {
    debug_assert!(a.len() == b.len() && a.len() == out.len());
    const W: usize = 16;
    let mut k1 = [0u32; W];
    let mut k2 = [0u32; W];
    let mut r1 = [0u64; W];
    let mut r2 = [0u64; W];
    let mut acc = [0u128; W];
    let mut done = 0;
    while done < a.len() {
        let n = (a.len() - done).min(W);
        let ac = &a[done..done + n];
        let bc = &b[done..done + n];
        // Stage 0 — eq (24) over the tile: one PE pass per operand
        // array, then the basic-block assembly. Zero operands settle
        // immediately (product 0), mirroring the scalar short-circuit.
        eng.priority_encode_batch(ac, &mut k1[..n], &mut r1[..n]);
        eng.priority_encode_batch(bc, &mut k2[..n], &mut r2[..n]);
        for j in 0..n {
            if ac[j] == 0 || bc[j] == 0 {
                acc[j] = 0;
                r1[j] = 0;
                r2[j] = 0;
            } else {
                acc[j] = (1u128 << (k1[j] + k2[j]))
                    + ((r1[j] as u128) << k2[j])
                    + ((r2[j] as u128) << k1[j]);
            }
        }
        // Correction stages (eq 26–27): the error term is itself a
        // product of the residues, so the same block iterates. A lane
        // whose residue reached zero is exact and contributes nothing
        // further, exactly like the scalar loop's early return.
        for _stage in 0..iterations {
            if (0..n).all(|j| r1[j] == 0 || r2[j] == 0) {
                break;
            }
            let p1 = r1;
            let p2 = r2;
            eng.priority_encode_batch(&p1[..n], &mut k1[..n], &mut r1[..n]);
            eng.priority_encode_batch(&p2[..n], &mut k2[..n], &mut r2[..n]);
            for j in 0..n {
                if p1[j] == 0 || p2[j] == 0 {
                    r1[j] = 0;
                    r2[j] = 0;
                } else {
                    acc[j] += (1u128 << (k1[j] + k2[j]))
                        + ((r1[j] as u128) << k2[j])
                        + ((r2[j] as u128) << k1[j]);
                }
            }
        }
        for (o, &p) in out[done..done + n].iter_mut().zip(acc[..n].iter()) {
            *o = (p >> frac_bits) as u64;
        }
        done += n;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::check_that;
    use crate::util::check::{forall, Config};

    #[test]
    fn zero_operands() {
        assert_eq!(ilm_mul(0, 5, 4), IlmResult { product: 0, stages: 0, exact: true });
        assert_eq!(ilm_mul(5, 0, 4).product, 0);
        assert_eq!(ilm_mul(0, 0, 0).product, 0);
    }

    #[test]
    fn powers_of_two_are_exact_at_zero_iterations() {
        for a in [1u64, 2, 4, 1 << 13, 1 << 40] {
            for b in [1u64, 8, 1 << 20] {
                let r = ilm_mul(a, b, 0);
                assert_eq!(r.product, (a as u128) * (b as u128));
                // residues are zero → detected exact on the *next* query,
                // but at 0 iterations exact is checked post-loop:
                assert!(r.exact);
            }
        }
    }

    #[test]
    fn paper_example_style_case() {
        // 3 · 3: k=1, r=1 → P0 = 4 + 2 + 2 = 8, E0 = 1·1 = 1 → exact 9 in 1 stage.
        assert_eq!(mitchell_mul(3, 3), 8);
        let r = ilm_mul(3, 3, 1);
        assert_eq!(r.product, 9);
        assert!(r.exact);
        assert_eq!(r.stages, 1);
    }

    #[test]
    fn exhaustive_8bit_exactness() {
        // Every 8-bit pair is exact within max_stages_for_width(8) = 7.
        for a in 0u64..256 {
            for b in 0u64..256 {
                let exact = (a as u128) * (b as u128);
                let r = ilm_mul(a, b, max_stages_for_width(8));
                assert_eq!(r.product, exact, "{a} * {b}");
                assert!(r.exact, "{a} * {b} not flagged exact");
            }
        }
    }

    #[test]
    fn exhaustive_8bit_monotone_in_iterations() {
        // More iterations never hurt; approximation always ≤ exact.
        for a in (1u64..256).step_by(7) {
            for b in (1u64..256).step_by(5) {
                let exact = (a as u128) * (b as u128);
                let mut last = 0u128;
                for i in 0..8 {
                    let p = ilm_mul(a, b, i).product;
                    assert!(p >= last, "{a}*{b} iter {i} decreased");
                    assert!(p <= exact, "{a}*{b} iter {i} overshoots");
                    last = p;
                }
                assert_eq!(last, exact);
            }
        }
    }

    #[test]
    fn mitchell_worst_case_error_bound() {
        // The classic Mitchell bound: relative error < 25 % — worst at
        // operands just below a power of two... in fact at x1=x2=0.5
        // mantissas. Verify the empirical max over 8-bit space is close
        // to but below 0.25.
        let mut max_err: f64 = 0.0;
        for a in 1u64..256 {
            for b in 1u64..256 {
                max_err = max_err.max(ilm_rel_error(a, b, 0));
            }
        }
        assert!(max_err < 0.25, "mitchell error {max_err} above bound");
        assert!(max_err > 0.2, "mitchell worst case should approach 25 %, got {max_err}");
    }

    #[test]
    fn one_correction_tightens_bound_to_over_93_percent_accuracy() {
        // Babić et al. report ≥ 98.98 % average accuracy with one stage on
        // 16-bit operands; the worst case for one stage is ~6.25 %.
        let mut max_err: f64 = 0.0;
        for a in 1u64..256 {
            for b in 1u64..256 {
                max_err = max_err.max(ilm_rel_error(a, b, 1));
            }
        }
        assert!(max_err < 0.0625 + 1e-9, "1-stage error {max_err}");
    }

    #[test]
    fn error_quarters_per_stage_trend() {
        // Worst-case error shrinks roughly 4× per stage (each stage
        // removes the top bit of each residue → product error /4).
        let mut prev = 1.0f64;
        for iters in 0..4 {
            let mut max_err: f64 = 0.0;
            for a in 1u64..512 {
                for b in 1u64..512 {
                    max_err = max_err.max(ilm_rel_error(a, b, iters));
                }
            }
            assert!(
                max_err < prev * 0.5,
                "stage {iters}: {max_err} did not shrink vs {prev}"
            );
            prev = max_err;
        }
    }

    #[test]
    fn property_random_wide_operands_exact_with_full_stages() {
        forall(Config::named("ilm exact with full budget").cases(500), |d| {
            let a = d.range_u64(1, (1 << 32) - 1);
            let b = d.range_u64(1, (1 << 32) - 1);
            let r = ilm_mul(a, b, 64);
            check_that!(r.exact, "not exact: {a} * {b}");
            check_that!(
                r.product == (a as u128) * (b as u128),
                "wrong product for {a} * {b}"
            );
            Ok(())
        });
    }

    #[test]
    fn property_stage_count_bounded_by_popcount() {
        // Each stage clears exactly one set bit from each residue, so the
        // stage count to exactness is ≤ min(popcount(a), popcount(b)) − …
        // bounded by min(popcount(a), popcount(b)).
        forall(Config::named("ilm stage bound").cases(500), |d| {
            let a = d.range_u64(1, u32::MAX as u64);
            let b = d.range_u64(1, u32::MAX as u64);
            let r = ilm_mul(a, b, 64);
            let bound = a.count_ones().min(b.count_ones());
            check_that!(
                r.stages < bound.max(1),
                "stages {} ≥ popcount bound {} for {a}*{b}",
                r.stages,
                bound
            );
            Ok(())
        });
    }

    #[test]
    fn fixed_point_truncation() {
        // 1.5 * 1.5 = 2.25 in Q(2.8): 384*384 = 147456 → >>8 = 576 = 2.25·256
        let a = 3u64 << 7; // 1.5 in Q.8
        let r = ilm_mul_fixed(a, a, 8, 8);
        assert_eq!(r, 576);
        // Truncation drops sub-ulp bits: 1.004·1.004 in Q.8
        let b = 257u64; // ~1.00390625
        let exact = (257u128 * 257) >> 8; // truncated exact
        assert_eq!(ilm_mul_fixed(b, b, 8, 8) as u128, exact);
    }

    #[test]
    fn fixed_point_batch_matches_scalar_ilm_every_engine_and_budget() {
        // 41 lanes (not a tile multiple): zeros, powers of two (settle at
        // stage 0), dense mantissas (use the whole budget), random. The
        // staged tile recursion must equal per-lane ilm_mul bit for bit.
        let mut a: Vec<u64> = vec![0, 1, 3, 1 << 20, (1 << 24) - 1, 0xFFFF, 7, 0];
        let mut b: Vec<u64> = vec![5, 0, 3, 1 << 10, (1 << 24) - 1, 0xF0F0, 7, 0];
        let mut rng = crate::util::rng::Rng::new(29);
        while a.len() < 41 {
            a.push(rng.next_u64() >> rng.below(40));
            b.push(rng.next_u64() >> rng.below(40));
        }
        let mut out = vec![0u64; a.len()];
        for eng in crate::simd::engines_available() {
            for iters in [0u32, 1, 3, 8, 64] {
                ilm_mul_fixed_batch(eng, &a, &b, 16, iters, &mut out);
                for i in 0..a.len() {
                    assert_eq!(
                        out[i],
                        ilm_mul_fixed(a[i], b[i], 16, iters),
                        "{} lane {i} ({} × {}) iters={iters}",
                        eng.name(),
                        a[i],
                        b[i]
                    );
                }
            }
        }
    }

    #[test]
    fn stages_reported_not_exceeding_budget() {
        for iters in 0..6 {
            let r = ilm_mul(0xFFFF, 0xFFFF, iters);
            assert!(r.stages <= iters);
        }
    }
}
