//! `tsdiv` — command-line front end of the Taylor/ILM division unit.
//!
//! Subcommands map one-to-one onto the evaluation experiments
//! (DESIGN.md §4) plus operational helpers:
//!
//! * `divide`    — divide two numbers, showing the datapath diagnostics;
//! * `table1`    — regenerate paper Table I (E1);
//! * `bounds`    — §3 iteration-count claims (E5);
//! * `hw`        — hardware cost tables, Fig 4 vs 5 (E6);
//! * `accuracy`    — divider accuracy report vs gold (E9);
//! * `serve`       — run the batched division service under load (E10);
//! * `fuzz`        — differential fuzzing of the kernel and Goldschmidt
//!   datapaths against gold, with seed-replayable reproducer lines;
//! * `bench-trend` — per-bench deltas vs the previous run, from the
//!   accumulated `BENCH_HISTORY.jsonl` trajectory;
//! * `selftest`    — quick end-to-end health check of all layers.

use tsdiv::analysis::{measure_accuracy_f32, Workload};
use tsdiv::divider::{BackendKind, Divider, TaylorDivider};
use tsdiv::taylor::TaylorConfig;
use tsdiv::util::cli::Command;
use tsdiv::util::table::{sig, Align, Table};

fn main() {
    let mut args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        print_usage();
        return;
    }
    let sub = args.remove(0);
    let code = match sub.as_str() {
        "divide" => cmd_divide(args),
        "table1" => cmd_table1(),
        "bounds" => cmd_bounds(),
        "hw" => cmd_hw(args),
        "accuracy" => cmd_accuracy(args),
        "serve" => cmd_serve(args),
        "fuzz" => cmd_fuzz(args),
        "bench-trend" => cmd_bench_trend(args),
        "selftest" => cmd_selftest(),
        "--help" | "-h" | "help" => {
            print_usage();
            0
        }
        other => {
            eprintln!("unknown subcommand '{other}'\n");
            print_usage();
            2
        }
    };
    std::process::exit(code);
}

fn print_usage() {
    println!(
        "tsdiv {} — {}\n\n\
         USAGE: tsdiv <subcommand> [options]\n\n\
         SUBCOMMANDS:\n\
         \x20 divide <a> <b>   divide via the Taylor/ILM unit (add --order N, --ilm K)\n\
         \x20 table1           regenerate paper Table I (segment boundaries)\n\
         \x20 bounds           §3 iteration-count analysis (17/15/5)\n\
         \x20 hw               hardware cost model (Fig 4 vs Fig 5, system)\n\
         \x20 accuracy         divider-vs-gold accuracy report (add --samples N)\n\
         \x20 serve            run the division service under synthetic load\n\
         \x20                  (--backend native|kernel|goldschmidt|auto|\n\
         \x20                   native-scalar|gold|pjrt — 'auto' routes each batch\n\
         \x20                   to the fastest kernel datapath per (format,\n\
         \x20                   rounding, batch-size) bucket; TSDIV_ROUTER=auto\n\
         \x20                   upgrades the default backend the same way;\n\
         \x20                   --workers N and --shards N size the sharded runtime;\n\
         \x20                   --tile N, --ilm K and --simd auto|forced|scalar\n\
         \x20                   configure the kernel backends' lane engine;\n\
         \x20                   --op div|recip|rsqrt|scale-recip picks the operation\n\
         \x20                   each request carries (non-div needs a kernel-family\n\
         \x20                   or gold backend); --trunc-bits N drops N low product\n\
         \x20                   bits per goldschmidt refinement multiply;\n\
         \x20                   --spare-divisor N tunes the idle-burst budget shrink)\n\
         \x20 fuzz             differential fuzz of the kernel/goldschmidt datapaths\n\
         \x20                  vs gold (--cases N --seed S; the seed replays the exact\n\
         \x20                  case stream, and any mismatch prints one reproducer\n\
         \x20                  line ending in its replay command)\n\
         \x20 bench-trend      per-bench deltas vs the previous BENCH_HISTORY.jsonl run;\n\
         \x20                  --gate --window K --tolerance PCT exits non-zero when a\n\
         \x20                  per_s metric drops (or a p99/latency/wait metric rises)\n\
         \x20                  > PCT percent past the rolling median\n\
         \x20 selftest         quick health check across all layers\n",
        tsdiv::VERSION,
        tsdiv::PAPER
    );
}

fn cmd_divide(args: Vec<String>) -> i32 {
    let cmd = Command::new("divide", "divide a by b through the paper's datapath")
        .opt("order", "5", "Taylor order n")
        .opt("ilm", "", "ILM correction budget (empty = exact multiplier)")
        .opt("frac-bits", "60", "datapath fraction bits");
    let parsed = match cmd.parse(args) {
        Ok(p) => p,
        Err(help) => {
            eprintln!("{help}");
            return 2;
        }
    };
    let pos = parsed.positionals();
    if pos.len() != 2 {
        eprintln!("usage: tsdiv divide <a> <b> [--order N] [--ilm K]");
        return 2;
    }
    let (a, b): (f64, f64) = match (pos[0].parse(), pos[1].parse()) {
        (Ok(a), Ok(b)) => (a, b),
        _ => {
            eprintln!("operands must be numbers");
            return 2;
        }
    };
    let order: u32 = parsed.parse_or("order", 5);
    let frac: u32 = parsed.parse_or("frac-bits", 60);
    // Reject configurations the datapath cannot serve — as errors, not
    // panics (the same bounds the service's BackendChoice::validate
    // enforces): the fast path's power buffer is MAX_FAST_ORDER wide,
    // and this command divides in binary64, so the Q2.F datapath must
    // cover 52..=61 fraction bits.
    if order > tsdiv::taylor::MAX_FAST_ORDER {
        eprintln!(
            "--order {order} exceeds the fast-path maximum {}",
            tsdiv::taylor::MAX_FAST_ORDER
        );
        return 2;
    }
    if !(52..=61).contains(&frac) {
        eprintln!("--frac-bits must be 52..=61 (binary64 significand .. Q2.F-in-u64 limit)");
        return 2;
    }
    let kind = match parsed.get("ilm") {
        Some("") | None => BackendKind::Exact,
        Some(s) => BackendKind::Ilm {
            iterations: s.parse().unwrap_or(8),
        },
    };
    let cfg = match TaylorConfig::try_paper_default(frac) {
        Ok(base) => TaylorConfig { order, ..base },
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    let mut d = TaylorDivider::new(cfg, kind);
    let q32 = d.div_f32(a as f32, b as f32);
    let q64 = d.div_f64(a, b);
    println!("divider : {}", d.name());
    println!("f32     : {q32:e}   (hardware {:e})", a as f32 / b as f32);
    println!("f64     : {q64:e}   (hardware {:e})", a / b);
    if let Some(u) = tsdiv::fp::ulp_diff_f64(q64, a / b) {
        println!("f64 Δ   : {u} ulp");
    }
    let c = d.op_counts();
    println!(
        "ops     : {} multiplies, {} squares, {} PE evals ({} saved by §6 cache)",
        c.muls, c.squares, c.pe_ops, c.pe_cache_hits
    );
    0
}

fn cmd_table1() -> i32 {
    let bounds = match tsdiv::pla::derive_segments(5, 53) {
        Ok(b) => b,
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    let mut t = Table::new(
        "Table I — segment boundaries (n=5, 53-bit)",
        &["boundary", "derived", "paper"],
    )
    .aligns(&[Align::Left, Align::Right, Align::Right]);
    for (i, (&ours, paper)) in bounds[1..].iter().zip(tsdiv::pla::PAPER_TABLE_I).enumerate() {
        t.row(&[format!("b{i}"), sig(ours, 6), format!("{paper}")]);
    }
    t.print();
    0
}

fn cmd_bounds() -> i32 {
    use tsdiv::pla::{derive_segments, equal_error_split, min_iterations, min_iterations_piecewise};
    let p = equal_error_split(1.0, 2.0);
    // The solvers are fallible (a pathological precision target may
    // never converge); the CLI shows the error in place of a value.
    let show = |r: tsdiv::util::error::Result<u32>| match r {
        Ok(n) => n.to_string(),
        Err(e) => format!("error: {e}"),
    };
    let mut t = Table::new(
        "minimum iterations for 53-bit precision (eq 17)",
        &["partition", "paper", "derived"],
    )
    .aligns(&[Align::Left, Align::Right, Align::Right]);
    t.row(&[
        "1 segment [1,2]".into(),
        "17".into(),
        show(min_iterations(1.0, 2.0, 53)),
    ]);
    t.row(&[
        "2 segments at √2".into(),
        "15".into(),
        show(min_iterations_piecewise(&[1.0, p, 2.0], 53)),
    ]);
    t.row(&[
        "Table I (8 segments)".into(),
        "5".into(),
        show(derive_segments(5, 53).and_then(|b| min_iterations_piecewise(&b, 53))),
    ]);
    t.print();
    println!("(the 2-segment row is a documented paper discrepancy — see EXPERIMENTS.md E5)");
    0
}

fn cmd_hw(args: Vec<String>) -> i32 {
    let cmd = Command::new("hw", "hardware cost model").opt("width", "53", "operand width in bits");
    let parsed = match cmd.parse(args) {
        Ok(p) => p,
        Err(help) => {
            eprintln!("{help}");
            return 2;
        }
    };
    let w: u32 = parsed.parse_or("width", 53);
    print!("{}", tsdiv::hw::ilm_unit(w).render());
    println!();
    print!("{}", tsdiv::hw::squaring_unit(w).render());
    println!(
        "\nsquaring/ILM ratio @ w={w}: datapath {:.3}, total {:.3}  (paper §5: < 0.5)",
        tsdiv::hw::squaring_vs_ilm_ratio(w),
        tsdiv::hw::units::squaring_vs_ilm_ratio_total(w)
    );
    0
}

fn cmd_accuracy(args: Vec<String>) -> i32 {
    let cmd = Command::new("accuracy", "divider accuracy vs exactly-rounded gold")
        .opt("samples", "20000", "sample count per row");
    let parsed = match cmd.parse(args) {
        Ok(p) => p,
        Err(help) => {
            eprintln!("{help}");
            return 2;
        }
    };
    let samples: u64 = parsed.parse_or("samples", 20_000);
    let mut t = Table::new(
        "accuracy vs gold",
        &["divider", "workload", "max ulp", "mean ulp", "exact %"],
    )
    .aligns(&[Align::Left, Align::Left, Align::Right, Align::Right, Align::Right]);
    for ilm in [None, Some(8u32), Some(2)] {
        for wl in [Workload::LogUniform, Workload::RandomBits] {
            let mut d = match ilm {
                None => TaylorDivider::paper_exact(),
                Some(k) => TaylorDivider::paper_ilm(k),
            };
            let r = measure_accuracy_f32(&mut d, wl, samples, 11);
            t.row(&[
                r.divider.clone(),
                wl.name().into(),
                r.max_ulp.to_string(),
                format!("{:.4}", r.mean_ulp),
                format!("{:.2}", r.exact_rate * 100.0),
            ]);
        }
    }
    t.print();
    0
}

fn cmd_serve(args: Vec<String>) -> i32 {
    use std::time::Duration;
    use tsdiv::coordinator::{BackendChoice, DivRequest, DivisionService, ServiceConfig};
    use tsdiv::fp::{Format, Op, Rounding};
    let cmd = Command::new("serve", "run the division service under load")
        .opt_choice(
            "op",
            "div",
            &["div", "recip", "rsqrt", "scale-recip"],
            "operation each request carries",
        )
        .opt_choice(
            "backend",
            "native",
            &["native", "kernel", "goldschmidt", "auto", "native-scalar", "gold", "pjrt"],
            "worker backend",
        )
        .opt("tile", "8", "kernel backend: lanes per SoA pipeline tile")
        .opt("ilm", "", "kernel backend: ILM correction budget (empty = exact)")
        .opt(
            "trunc-bits",
            "0",
            "goldschmidt backend: low product bits dropped per refinement multiply",
        )
        .opt_choice(
            "simd",
            "auto",
            &["auto", "forced", "scalar"],
            "kernel backend: lane engine under the stage loops",
        )
        .opt_choice(
            "format",
            "f32",
            &["f16", "bf16", "f32", "f64", "mixed"],
            "request operand format",
        )
        .opt_choice(
            "rounding",
            "nearest",
            &["nearest", "zero", "up", "down"],
            "rounding mode",
        )
        .opt("seconds", "2", "duration")
        .opt("workers", "2", "worker threads")
        .opt(
            "shards",
            "",
            "submission shards, each with its own batcher (empty = one per worker)",
        )
        .opt(
            "max-batch",
            "4096",
            "coalescing budget in f32-equivalent lanes (cost-weighted per format)",
        )
        .opt(
            "spare-divisor",
            "4",
            "budget divisor while all workers are idle (1 disables the shrink)",
        );
    let parsed = match cmd.parse(args) {
        Ok(p) => p,
        Err(help) => {
            eprintln!("{help}");
            return 2;
        }
    };
    let trunc_bits: u32 = match parsed.parse_required("trunc-bits") {
        Ok(n) => n,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let backend = match parsed.get_or("backend", "native") {
        "pjrt" => {
            if !tsdiv::runtime::artifacts_available() {
                eprintln!("artifacts/ missing — run `make artifacts`");
                return 1;
            }
            BackendChoice::Pjrt
        }
        which @ ("kernel" | "goldschmidt") => {
            let ilm_iterations = match parsed.get("ilm") {
                Some("") | None => None,
                Some(s) => match s.parse() {
                    Ok(k) => Some(k),
                    Err(_) => {
                        eprintln!("option --ilm: cannot parse '{s}'");
                        return 2;
                    }
                },
            };
            let tile = match parsed.parse_required::<usize>("tile") {
                Ok(t) => t,
                Err(e) => {
                    eprintln!("{e}");
                    return 2;
                }
            };
            let simd = tsdiv::simd::SimdChoice::from_name(parsed.get_or("simd", "auto"))
                .expect("opt_choice guarantees a valid simd name");
            let kernel = tsdiv::kernel::KernelConfig {
                tile,
                ilm_iterations,
                simd,
            };
            if let Err(e) = kernel.validate() {
                eprintln!("{e}");
                return 2;
            }
            if which == "goldschmidt" {
                // Goldschmidt refinement multiplies are exact wide
                // products; the --ilm budget has nothing to act on.
                if ilm_iterations.is_some() {
                    eprintln!("--ilm only applies to --backend kernel (Taylor/ILM datapath)");
                    return 2;
                }
                BackendChoice::Goldschmidt {
                    iterations: 3,
                    kernel,
                    trunc_bits,
                }
            } else {
                BackendChoice::Kernel { order: 5, kernel }
            }
        }
        "auto" => BackendChoice::Auto,
        "native-scalar" => BackendChoice::NativeScalar {
            order: 5,
            ilm_iterations: None,
        },
        "gold" => BackendChoice::Gold,
        _ => BackendChoice::Native {
            order: 5,
            ilm_iterations: None,
        },
    };
    // A pinned engine must never be silently ignored: only the kernel
    // datapaths take --simd (the others resolve the lane engine as
    // 'auto', overridable process-wide via TSDIV_SIMD).
    let simd_flag = parsed.get_or("simd", "auto");
    if simd_flag != "auto"
        && !matches!(
            backend,
            BackendChoice::Kernel { .. } | BackendChoice::Goldschmidt { .. }
        )
    {
        eprintln!(
            "--simd {simd_flag} only applies to --backend kernel|goldschmidt; \
             other backends resolve the lane engine as 'auto' \
             (set TSDIV_SIMD to override process-wide)"
        );
        return 2;
    }
    // Only the Goldschmidt datapath has refinement multiplies to
    // truncate; a nonzero budget anywhere else would be silently lost.
    if trunc_bits != 0 && !matches!(backend, BackendChoice::Goldschmidt { .. }) {
        eprintln!(
            "--trunc-bits only applies to --backend goldschmidt \
             (truncated refinement multiplies)"
        );
        return 2;
    }
    // Surface a bad --trunc-bits bound (or any other backend knob) as
    // exit code 2 with the message, not a panic through expect().
    if let Err(e) = backend.validate() {
        eprintln!("{e}");
        return 2;
    }
    let op = Op::from_name(parsed.get_or("op", "div"))
        .expect("opt_choice guarantees a valid op name");
    if op != Op::Div
        && matches!(
            backend,
            BackendChoice::Native { .. } | BackendChoice::NativeScalar { .. } | BackendChoice::Pjrt
        )
    {
        eprintln!(
            "--op {} needs --backend kernel|goldschmidt|auto|gold \
             (the native and pjrt backends serve div only)",
            op.name()
        );
        return 2;
    }
    let rm = Rounding::from_name(parsed.get_or("rounding", "nearest")).unwrap();
    // "mixed" cycles through all four formats, exercising per-key
    // batching; otherwise every request carries the one format.
    let formats: Vec<Format> = match parsed.get_or("format", "f32") {
        "mixed" => tsdiv::fp::ALL_FORMATS.to_vec(),
        name => vec![Format::from_name(name).unwrap()],
    };
    if backend == BackendChoice::Pjrt
        && (parsed.get_or("format", "f32") != "f32" || rm != Rounding::NearestEven)
    {
        eprintln!("the pjrt backend serves f32 at nearest-even only");
        return 2;
    }
    let spare_divisor: usize = match parsed.parse_required("spare-divisor") {
        Ok(n) => n,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let shards: Option<usize> = match parsed.get("shards") {
        Some("") | None => None,
        Some(s) => match s.parse() {
            Ok(n) => Some(n),
            Err(_) => {
                eprintln!("option --shards: cannot parse '{s}'");
                return 2;
            }
        },
    };
    let cfg = ServiceConfig {
        workers: parsed.parse_or("workers", 2),
        shards,
        max_batch: parsed.parse_or("max-batch", 4096),
        max_wait: Duration::from_micros(200),
        queue_capacity: 1 << 14,
        spare_divisor,
    };
    // validate() runs inside start() too; calling it here turns a bad
    // knob (e.g. --spare-divisor 0) into exit code 2 with the message,
    // not a panic through expect().
    if let Err(e) = cfg.validate() {
        eprintln!("{e}");
        return 2;
    }
    let svc = DivisionService::start(cfg, backend).expect("service");
    let seconds: u64 = parsed.parse_or("seconds", 2);
    let deadline = std::time::Instant::now() + Duration::from_secs(seconds);
    let mut lanes = 0u64;
    let mut req_no = 0usize;
    while std::time::Instant::now() < deadline {
        let fmt = formats[req_no % formats.len()];
        req_no += 1;
        let (a, b) = tsdiv::harness::gen_bits_batch(fmt, 256, 8, req_no as u64);
        let req = match op {
            Op::Div => DivRequest::new(fmt, rm, a, b),
            Op::Recip => DivRequest::recip(fmt, rm, a),
            Op::Rsqrt => {
                // rsqrt of a negative is NaN; clear the sign so the
                // load measures the refinement path, not NaN fill.
                let mut xs = a;
                for x in xs.iter_mut() {
                    *x &= !fmt.sign_mask();
                }
                DivRequest::rsqrt(fmt, rm, xs)
            }
            // 8 rows of 32 lanes each: the batch straddles pipeline
            // tiles, so the broadcast path is actually exercised.
            Op::ScaleByRecip => DivRequest::scale_by_recip(fmt, rm, a, b[..8].to_vec()),
        };
        if svc.divide_request_blocking(req).is_ok() {
            lanes += 256;
        }
    }
    let m = svc.metrics();
    println!(
        "served {lanes} {} lanes in {seconds}s ({} lanes/s, {} rm={}), {} batches over {} shard(s), \
         {} stolen, p50 {:.3} ms, p99 {:.3} ms",
        op.name(),
        sig(lanes as f64 / seconds as f64, 4),
        parsed.get_or("format", "f32"),
        rm.name(),
        m.batches,
        m.shards,
        m.steals,
        m.latency_p50 * 1e3,
        m.latency_p99 * 1e3
    );
    svc.shutdown();
    0
}

fn cmd_bench_trend(args: Vec<String>) -> i32 {
    use tsdiv::util::json::Json;
    let cmd = Command::new(
        "bench-trend",
        "per-bench metric deltas vs the previous recorded run",
    )
    .opt(
        "history",
        "",
        "history file (default: the tracked BENCH_HISTORY.jsonl)",
    )
    .flag(
        "gate",
        "regression gate: exit non-zero when a throughput (per_s) metric \
         drops, or a latency (p99/latency/wait) metric rises, more than \
         --tolerance percent past the rolling median",
    )
    .opt("window", "5", "gate: rolling-median window in runs")
    .opt(
        "tolerance",
        "15",
        "gate: allowed move in the bad direction vs the rolling median, in percent",
    );
    let parsed = match cmd.parse(args) {
        Ok(p) => p,
        Err(help) => {
            eprintln!("{help}");
            return 2;
        }
    };
    let path = match parsed.get("history") {
        Some("") | None => tsdiv::harness::bench_history_path(),
        Some(p) => p.to_string(),
    };
    let records = match tsdiv::harness::read_bench_history(&path) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("{e}");
            eprintln!("(benches append to the history: `cargo bench --bench divider_throughput`)");
            return 1;
        }
    };
    if parsed.flag("gate") {
        let window: usize = match parsed.parse_required("window") {
            Ok(k) if k >= 1 => k,
            Ok(_) => {
                eprintln!("option --window: must be ≥ 1 run");
                return 2;
            }
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        };
        let tolerance: f64 = match parsed.parse_required("tolerance") {
            Ok(t) if t >= 0.0 => t,
            Ok(_) => {
                eprintln!("option --tolerance: must be ≥ 0 percent");
                return 2;
            }
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        };
        return run_bench_gate(&path, &records, window, tolerance);
    }
    if records.is_empty() {
        println!(
            "no records in {path} — run a serving bench first \
             (e.g. `cargo bench --bench divider_throughput`)"
        );
        return 0;
    }
    // Group runs by bench name, preserving first-seen order.
    let mut names: Vec<String> = Vec::new();
    let mut groups: std::collections::HashMap<String, Vec<&Json>> = std::collections::HashMap::new();
    for r in &records {
        let name = r
            .get("bench")
            .and_then(|j| j.as_str())
            .unwrap_or("(unnamed)")
            .to_string();
        if !groups.contains_key(&name) {
            names.push(name.clone());
        }
        groups.entry(name).or_default().push(r);
    }
    let mut t = Table::new(
        &format!("bench trend — {} record(s) in {path}", records.len()),
        &["bench", "metric", "previous", "latest", "Δ%"],
    )
    .aligns(&[Align::Left, Align::Left, Align::Right, Align::Right, Align::Right]);
    for name in &names {
        let runs = &groups[name];
        if runs.len() < 2 {
            t.row(&[
                name.clone(),
                "(needs ≥ 2 recorded runs)".into(),
                "-".into(),
                "-".into(),
                "-".into(),
            ]);
            continue;
        }
        let prev = runs[runs.len() - 2];
        let last = runs[runs.len() - 1];
        // Compare every top-level numeric metric of the latest run. A
        // metric absent from (or non-numeric in) the previous run is NEW
        // — shown with an n/a delta rather than dropped, so freshly
        // added bench rows surface on their first recorded run; a
        // zero/non-finite baseline also prints n/a instead of a
        // division-by-zero artifact.
        if let Json::Obj(pairs) = last {
            for (k, v) in pairs {
                if k == "bench" {
                    continue;
                }
                let Some(latest) = v.as_f64() else { continue };
                let previous = prev.get(k).and_then(|j| j.as_f64());
                let (prev_str, delta) = match previous {
                    None => ("(new)".to_string(), "n/a".to_string()),
                    Some(p) if p == 0.0 || !p.is_finite() => (sig(p, 4), "n/a".to_string()),
                    Some(p) => (
                        sig(p, 4),
                        format!("{:+.1}", (latest - p) / p * 100.0),
                    ),
                };
                t.row(&[name.clone(), k.clone(), prev_str, sig(latest, 4), delta]);
            }
        }
    }
    t.print();
    println!("(each bench run appends one record; deltas compare the last two per bench)");
    0
}

/// The `bench-trend --gate` body: judge each bench's latest run against
/// the rolling median (+ MAD context) of the previous `window` runs and
/// turn the verdict into an exit code. Direction-aware: throughput
/// (`per_s`) keys fail on a drop, latency (`p99`/`latency`/`wait`) keys
/// fail on a rise. A history shorter than the window prints `n/a` rows
/// and exits 0 — the gate warms up gracefully while the trajectory
/// accumulates.
fn run_bench_gate(
    path: &str,
    records: &[tsdiv::util::json::Json],
    window: usize,
    tolerance: f64,
) -> i32 {
    use tsdiv::harness::MetricDirection;
    let report = tsdiv::harness::gate_bench_history(records, window, tolerance);
    let mut t = Table::new(
        &format!(
            "bench regression gate — window {window}, tolerance {tolerance}% \
             ({} record(s) in {path})",
            records.len()
        ),
        &["bench", "metric", "dir", "median(k)", "MAD", "latest", "Δ%", "verdict"],
    )
    .aligns(&[
        Align::Left,
        Align::Left,
        Align::Left,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Left,
    ]);
    for m in &report.metrics {
        let dir = match m.direction {
            MetricDirection::HigherIsBetter => "hi",
            MetricDirection::LowerIsBetter => "lo",
        };
        let (med, mad_s, delta, verdict) = if m.warming_up() {
            (
                "n/a".to_string(),
                "n/a".to_string(),
                "n/a".to_string(),
                format!("n/a (warming up, {}/{window} runs)", m.n),
            )
        } else {
            (
                sig(m.baseline_median, 4),
                sig(m.baseline_mad, 3),
                if m.delta_pct.is_finite() {
                    format!("{:+.1}", m.delta_pct)
                } else {
                    "n/a".to_string()
                },
                if m.regressed {
                    "REGRESSED".to_string()
                } else {
                    "ok".to_string()
                },
            )
        };
        t.row(&[
            m.bench.clone(),
            m.metric.clone(),
            dir.to_string(),
            med,
            mad_s,
            sig(m.latest, 4),
            delta,
            verdict,
        ]);
    }
    t.print();
    if report.metrics.is_empty() {
        // The empty-trajectory warm-up case the gate must survive.
        println!("n/a — no gated metrics recorded yet; gate passes while history warms up");
        return 0;
    }
    let regressions = report.regressions();
    if regressions.is_empty() {
        println!(
            "gate PASSED: {} metric(s) judged, {} warming up",
            report.judged(),
            report.metrics.len() - report.judged()
        );
        0
    } else {
        for r in &regressions {
            let bound = match r.direction {
                MetricDirection::HigherIsBetter => format!("{:+.1}% < -{tolerance}%", r.delta_pct),
                MetricDirection::LowerIsBetter => format!("{:+.1}% > +{tolerance}%", r.delta_pct),
            };
            eprintln!(
                "gate FAILED: {}/{} at {} vs rolling median {} ({bound})",
                r.bench,
                r.metric,
                sig(r.latest, 4),
                sig(r.baseline_median, 4),
            );
        }
        1
    }
}

/// `--seed` accepts decimal or `0x`-prefixed hex (reproducer lines
/// print the hex form).
fn parse_seed(s: &str) -> Option<u64> {
    match s.strip_prefix("0x").or_else(|| s.strip_prefix("0X")) {
        Some(hex) => u64::from_str_radix(hex, 16).ok(),
        None => s.parse().ok(),
    }
}

fn cmd_fuzz(args: Vec<String>) -> i32 {
    use tsdiv::verify::fuzz::{run_fuzz, FuzzConfig};
    let cmd = Command::new("fuzz", "differential fuzz of the division datapaths vs gold")
        .opt("cases", "2000", "random cases to generate and cross-check")
        .opt("seed", "1", "master seed (decimal or 0x-hex); replays the exact case stream");
    let parsed = match cmd.parse(args) {
        Ok(p) => p,
        Err(help) => {
            eprintln!("{help}");
            return 2;
        }
    };
    let cases: u64 = parsed.parse_or("cases", 2000);
    let seed = match parse_seed(parsed.get_or("seed", "1")) {
        Some(s) => s,
        None => {
            eprintln!("--seed must be a u64 (decimal or 0x-hex)");
            return 2;
        }
    };
    println!(
        "fuzz: seed={seed:#x} cases={cases} \
         (replay: tsdiv fuzz --seed {seed:#x} --cases {cases})"
    );
    let out = run_fuzz(&FuzzConfig { cases, seed });
    for line in &out.failures {
        println!("{line}");
    }
    println!(
        "fuzz: {} cases, {} lanes/datapath, digest={:#018x}, {} mismatch(es)",
        out.cases,
        out.lanes,
        out.digest,
        out.failures.len()
    );
    if out.failures.is_empty() {
        0
    } else {
        1
    }
}

fn cmd_selftest() -> i32 {
    let mut failures = 0;
    let mut check = |label: &str, ok: bool| {
        println!("  [{}] {label}", if ok { "ok" } else { "FAIL" });
        if !ok {
            failures += 1;
        }
    };
    println!("tsdiv selftest:");
    // L3 datapath
    let mut d = TaylorDivider::paper_exact();
    check("taylor divider 355/113", {
        let q = d.div_f32(355.0, 113.0);
        q == 355.0f32 / 113.0
    });
    check("staged kernel == scalar datapath (f32 batch)", {
        let a: Vec<u64> = (1..=20u32).map(|i| (i as f32 * 1.7).to_bits() as u64).collect();
        let b: Vec<u64> = (1..=20u32).map(|i| ((i % 5 + 1) as f32).to_bits() as u64).collect();
        let mut out = vec![0u64; a.len()];
        d.div_bits_batch(&a, &b, tsdiv::fp::F32, tsdiv::fp::Rounding::NearestEven, &mut out);
        (0..a.len()).all(|i| {
            out[i] == d.div_bits(a[i], b[i], tsdiv::fp::F32, tsdiv::fp::Rounding::NearestEven)
        })
    });
    check(
        "table I derivation (8 segments)",
        tsdiv::pla::derive_segments(5, 53).map(|b| b.len()) == Ok(9),
    );
    check(
        "17-iteration bound on [1,2]",
        tsdiv::pla::min_iterations(1.0, 2.0, 53) == Ok(17),
    );
    check("kernel lane engines bit-identical (f32 batch)", {
        use tsdiv::simd::SimdChoice;
        let a: Vec<u64> = (1..=33u32).map(|i| (i as f32 * 0.37).to_bits() as u64).collect();
        let b: Vec<u64> = (1..=33u32)
            .map(|i| ((i % 9 + 1) as f32 * 1.3).to_bits() as u64)
            .collect();
        let mut scalar_eng = TaylorDivider::paper_exact();
        let mut auto_eng = TaylorDivider::paper_exact();
        // A rejected engine selection (TSDIV_SIMD=forced on a host
        // without a vector engine) fails this check; a health check
        // never aborts the report.
        match (
            scalar_eng.set_batch_simd(SimdChoice::Scalar),
            auto_eng.set_batch_simd(SimdChoice::Auto),
        ) {
            (Ok(()), Ok(())) => {
                let mut q1 = vec![0u64; a.len()];
                let mut q2 = vec![0u64; a.len()];
                let (fmt, rm) = (tsdiv::fp::F32, tsdiv::fp::Rounding::NearestEven);
                scalar_eng.div_bits_batch(&a, &b, fmt, rm, &mut q1);
                auto_eng.div_bits_batch(&a, &b, fmt, rm, &mut q2);
                q1 == q2
            }
            _ => false,
        }
    });
    check(
        "squaring < half ILM datapath",
        tsdiv::hw::squaring_vs_ilm_ratio(53) < 0.5,
    );
    check("ILM exactness (8-bit, full budget)", {
        (1u64..256).all(|a| tsdiv::ilm::ilm_mul(a, 171, 8).product == (a as u128) * 171)
    });
    // Runtime (optional)
    if tsdiv::runtime::artifacts_available() {
        match tsdiv::runtime::DivideEngine::load_default() {
            Ok(engine) => {
                let q = engine.divide(&[84.0], &[2.0]).unwrap();
                check("PJRT artifact round-trip 84/2", q[0] == 42.0);
            }
            Err(e) => check(&format!("PJRT load ({e})"), false),
        }
    } else {
        println!("  [--] PJRT skipped (no artifacts; run `make artifacts`)");
    }
    // Coordinator
    {
        use tsdiv::coordinator::{BackendChoice, DivRequest, DivisionService, ServiceConfig};
        let svc = DivisionService::start(
            ServiceConfig::default(),
            BackendChoice::Native {
                order: 5,
                ilm_iterations: None,
            },
        )
        .unwrap();
        let out = svc
            .divide_request_blocking(DivRequest::from_f32(&[9.0], &[3.0]))
            .map(|r| r.to_f32());
        check("coordinator round-trip 9/3", out == Ok(Some(vec![3.0])));
        let out = svc
            .divide_request_blocking(DivRequest::from_f16_bits(&[0x4600], &[0x4000]))
            .map(|r| r.to_u16_bits());
        check("coordinator f16 round-trip 6/2", out == Ok(Some(vec![0x4200])));
        svc.shutdown();
    }
    if failures == 0 {
        println!("all checks passed");
        0
    } else {
        println!("{failures} check(s) FAILED");
        1
    }
}
