//! PJRT runtime: load the AOT artifacts and execute them from Rust.
//!
//! The interchange format is **HLO text** (see `python/compile/aot.py`
//! and /opt/xla-example/README.md): jax ≥ 0.5 serializes protos with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; text
//! round-trips cleanly through `HloModuleProto::from_text_file`.
//!
//! A [`DivideEngine`] owns one compiled executable per batch size from
//! `artifacts/manifest.json` and pads incoming batches up to the nearest
//! entry — Python is never on this path.
//!
//! The PJRT bindings live behind the **`pjrt` cargo feature**: the build
//! image vendors no `xla` crate, so the default build compiles a stub
//! engine whose loaders fail with a clear message and
//! [`artifacts_available`] reports `false`, letting every caller skip
//! the PJRT path gracefully. Manifest parsing is always available.
//! NB: *enabling* `pjrt` without first vendoring an `xla` crate (via a
//! `[patch]`/path dependency) fails at compile time with unresolved
//! `xla` imports — the feature is an opt-in for environments that ship
//! the bindings, not a runtime toggle; avoid `--all-features` in CI.

use std::path::{Path, PathBuf};

use crate::bail;
#[cfg(feature = "pjrt")]
use crate::err;
use crate::util::error::{Context, Result};
use crate::util::json::{self, Json};

/// One entry of `artifacts/manifest.json`.
#[derive(Clone, Debug)]
pub struct ManifestEntry {
    pub name: String,
    pub path: PathBuf,
    pub kind: String,
    pub batch: usize,
}

/// Parsed artifact manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: Vec<ManifestEntry>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let root = json::parse(&text)?;
        let mut entries = Vec::new();
        for e in root
            .get("entries")
            .and_then(Json::as_arr)
            .context("manifest missing 'entries'")?
        {
            entries.push(ManifestEntry {
                name: e
                    .get("name")
                    .and_then(Json::as_str)
                    .context("entry missing name")?
                    .to_string(),
                path: dir.join(
                    e.get("path")
                        .and_then(Json::as_str)
                        .context("entry missing path")?,
                ),
                kind: e
                    .get("kind")
                    .and_then(Json::as_str)
                    .unwrap_or("unknown")
                    .to_string(),
                batch: e
                    .get("batch")
                    .and_then(Json::as_f64)
                    .context("entry missing batch")? as usize,
            });
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            entries,
        })
    }

    /// Default artifact location (repo-root `artifacts/`, overridable via
    /// `TSDIV_ARTIFACTS`).
    pub fn default_dir() -> PathBuf {
        std::env::var("TSDIV_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }
}

/// A compiled divide executable of fixed batch size.
#[cfg(feature = "pjrt")]
pub struct DivideExecutable {
    pub batch: usize,
    exe: xla::PjRtLoadedExecutable,
}

#[cfg(feature = "pjrt")]
impl DivideExecutable {
    /// Execute on exactly `batch` lanes.
    pub fn run_exact(&self, a: &[f32], b: &[f32]) -> Result<Vec<f32>> {
        assert_eq!(a.len(), self.batch);
        assert_eq!(b.len(), self.batch);
        let la = xla::Literal::vec1(a);
        let lb = xla::Literal::vec1(b);
        let result = self
            .exe
            .execute::<xla::Literal>(&[la, lb])
            .map_err(|e| err!("pjrt execute: {e}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| err!("pjrt transfer: {e}"))?;
        // aot.py lowers with return_tuple=True → 1-tuple.
        let out = result.to_tuple1().map_err(|e| err!("pjrt tuple: {e}"))?;
        out.to_vec::<f32>().map_err(|e| err!("pjrt to_vec: {e}"))
    }
}

/// The division engine: PJRT client + one executable per batch size.
#[cfg(feature = "pjrt")]
pub struct DivideEngine {
    client: xla::PjRtClient,
    /// Sorted ascending by batch size.
    executables: Vec<DivideExecutable>,
}

#[cfg(feature = "pjrt")]
impl DivideEngine {
    /// Compile every `divide` entry in the manifest on the CPU client.
    pub fn load(manifest: &Manifest) -> Result<DivideEngine> {
        let client = xla::PjRtClient::cpu().map_err(|e| err!("pjrt client: {e}"))?;
        let mut executables = Vec::new();
        for e in manifest.entries.iter().filter(|e| e.kind == "divide") {
            let proto = xla::HloModuleProto::from_text_file(
                e.path
                    .to_str()
                    .with_context(|| format!("non-utf8 path {:?}", e.path))?,
            )
            .map_err(|e| err!("hlo parse: {e}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| err!("pjrt compile: {e}"))?;
            executables.push(DivideExecutable { batch: e.batch, exe });
        }
        if executables.is_empty() {
            bail!("manifest has no divide entries");
        }
        executables.sort_by_key(|e| e.batch);
        Ok(DivideEngine {
            client,
            executables,
        })
    }

    /// Convenience: load from the default artifacts directory.
    pub fn load_default() -> Result<DivideEngine> {
        let manifest = Manifest::load(&Manifest::default_dir())?;
        Self::load(&manifest)
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Available executable batch sizes (ascending).
    pub fn batch_sizes(&self) -> Vec<usize> {
        self.executables.iter().map(|e| e.batch).collect()
    }

    /// Smallest executable batch ≥ n (or the largest available).
    fn pick(&self, n: usize) -> &DivideExecutable {
        self.executables
            .iter()
            .find(|e| e.batch >= n)
            .unwrap_or_else(|| self.executables.last().unwrap())
    }

    /// Divide arbitrary-length slices: chunks through the largest
    /// executable, pads the tail with 1.0/1.0 lanes.
    pub fn divide(&self, a: &[f32], b: &[f32]) -> Result<Vec<f32>> {
        assert_eq!(a.len(), b.len());
        let mut out = Vec::with_capacity(a.len());
        let largest = self.executables.last().unwrap().batch;
        let mut off = 0;
        while off < a.len() {
            let n = (a.len() - off).min(largest);
            let exe = self.pick(n);
            if n == exe.batch {
                out.extend(exe.run_exact(&a[off..off + n], &b[off..off + n])?);
            } else {
                // Pad the tail: 1/1 lanes are harmless.
                let mut pa = vec![1.0f32; exe.batch];
                let mut pb = vec![1.0f32; exe.batch];
                pa[..n].copy_from_slice(&a[off..off + n]);
                pb[..n].copy_from_slice(&b[off..off + n]);
                let full = exe.run_exact(&pa, &pb)?;
                out.extend_from_slice(&full[..n]);
            }
            off += n;
        }
        Ok(out)
    }
}

/// Stub engine when the `pjrt` feature is off: loading always fails with
/// a clear message, and [`artifacts_available`] reports `false` so every
/// caller (tests, benches, examples, the serve CLI) skips this path.
#[cfg(not(feature = "pjrt"))]
#[derive(Debug)]
pub struct DivideEngine {
    _private: (),
}

#[cfg(not(feature = "pjrt"))]
impl DivideEngine {
    pub fn load(_manifest: &Manifest) -> Result<DivideEngine> {
        bail!(
            "tsdiv was built without the `pjrt` feature; rebuild with \
             `--features pjrt` and a vendored `xla` crate to run AOT artifacts"
        )
    }

    pub fn load_default() -> Result<DivideEngine> {
        Self::load(&Manifest {
            dir: PathBuf::new(),
            entries: Vec::new(),
        })
    }

    pub fn platform(&self) -> String {
        "unavailable (built without the pjrt feature)".to_string()
    }

    pub fn batch_sizes(&self) -> Vec<usize> {
        Vec::new()
    }

    pub fn divide(&self, _a: &[f32], _b: &[f32]) -> Result<Vec<f32>> {
        bail!("pjrt feature disabled")
    }
}

/// True when the PJRT path is compiled in AND the artifacts directory
/// exists with a manifest — used by tests/benches to skip gracefully
/// before `make artifacts` has run (or on default builds).
pub fn artifacts_available() -> bool {
    cfg!(feature = "pjrt") && Manifest::default_dir().join("manifest.json").exists()
}

#[cfg(test)]
mod tests {
    use super::*;

    // Full PJRT round-trip tests live in rust/tests/integration_runtime.rs
    // (they need `make artifacts` and the pjrt feature). Here: manifest
    // parsing on fixtures, which works on every build.

    #[test]
    fn manifest_parses_fixture() {
        let dir = std::env::temp_dir().join("tsdiv_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"format": 1, "entries": [
                {"name": "divide_b8", "path": "divide_b8.hlo.txt",
                 "kind": "divide", "batch": 8,
                 "inputs": [{"shape": [8], "dtype": "float32"}]}
            ]}"#,
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.entries.len(), 1);
        assert_eq!(m.entries[0].name, "divide_b8");
        assert_eq!(m.entries[0].batch, 8);
        assert_eq!(m.entries[0].kind, "divide");
        assert!(m.entries[0].path.ends_with("divide_b8.hlo.txt"));
    }

    #[test]
    fn manifest_missing_file_errors() {
        let dir = std::env::temp_dir().join("tsdiv_no_such_dir_xyz");
        assert!(Manifest::load(&dir).is_err());
    }

    #[test]
    fn manifest_bad_json_errors() {
        let dir = std::env::temp_dir().join("tsdiv_bad_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), "{not json").unwrap();
        assert!(Manifest::load(&dir).is_err());
        std::fs::write(dir.join("manifest.json"), r#"{"entries": [{}]}"#).unwrap();
        assert!(Manifest::load(&dir).is_err());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_engine_fails_with_clear_message() {
        let e = DivideEngine::load_default().unwrap_err();
        assert!(e.to_string().contains("pjrt"), "{e}");
        assert!(!artifacts_available());
    }
}
