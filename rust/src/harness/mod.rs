//! Bench harness: workload generators and the paper-vs-measured report
//! runner shared by every `rust/benches/*.rs` target.

use crate::util::rng::Rng;
use crate::util::table::{sig, Align, Table};
use crate::util::timing::{bench, BenchConfig, Measurement};

/// A batch of f32 division operands.
#[derive(Clone, Debug)]
pub struct DivBatch {
    pub a: Vec<f32>,
    pub b: Vec<f32>,
}

impl DivBatch {
    pub fn len(&self) -> usize {
        self.a.len()
    }

    pub fn is_empty(&self) -> bool {
        self.a.is_empty()
    }
}

/// Generate a division workload of `n` pairs from a named distribution.
pub fn gen_batch(workload: crate::analysis::Workload, n: usize, seed: u64) -> DivBatch {
    let mut rng = Rng::new(seed);
    let mut a = Vec::with_capacity(n);
    let mut b = Vec::with_capacity(n);
    for _ in 0..n {
        let (x, y) = workload.sample_f32(&mut rng);
        a.push(x);
        b.push(y);
    }
    DivBatch { a, b }
}

/// An adversarial batch: corner values and near-boundary significands
/// (segment edges of the Table-I partition, power-of-two neighbourhoods).
pub fn gen_adversarial_batch(n: usize, seed: u64) -> DivBatch {
    let mut rng = Rng::new(seed);
    let bounds = crate::pla::derive_segments(5, 53);
    let mut a = Vec::with_capacity(n);
    let mut b = Vec::with_capacity(n);
    for i in 0..n {
        let x = match i % 4 {
            0 => {
                // Just inside a segment edge.
                let e = *rng.choose(&bounds);
                (e as f32 + f32::EPSILON).min(1.9999999)
            }
            1 => 1.0 + f32::EPSILON * (rng.below(16) as f32),
            2 => 2.0 - f32::EPSILON * (1.0 + rng.below(16) as f32),
            _ => 1.0 + rng.f32(),
        };
        let scale = 2f32.powi(rng.range_i64(-8, 8) as i32);
        a.push((1.0 + rng.f32()) * scale);
        b.push(x * scale);
    }
    DivBatch { a, b }
}

/// One row of a paper-vs-measured table.
#[derive(Clone, Debug)]
pub struct PaperRow {
    pub id: String,
    pub paper: String,
    pub measured: String,
    pub verdict: Verdict,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Verdict {
    Match,
    /// Shape/direction holds; absolute value differs (expected on a
    /// different substrate).
    Consistent,
    /// Contradicts the paper (documented discrepancies).
    Mismatch,
    /// No paper value to compare against (new measurement).
    New,
}

impl Verdict {
    pub fn symbol(&self) -> &'static str {
        match self {
            Verdict::Match => "MATCH",
            Verdict::Consistent => "consistent",
            Verdict::Mismatch => "MISMATCH",
            Verdict::New => "(new)",
        }
    }
}

/// Collects rows and renders the standard report table for a bench.
#[derive(Clone, Debug)]
pub struct Report {
    pub title: String,
    rows: Vec<PaperRow>,
}

impl Report {
    pub fn new(title: &str) -> Self {
        Self {
            title: title.to_string(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, id: &str, paper: &str, measured: &str, verdict: Verdict) -> &mut Self {
        self.rows.push(PaperRow {
            id: id.to_string(),
            paper: paper.to_string(),
            measured: measured.to_string(),
            verdict,
        });
        self
    }

    /// Numeric convenience with automatic match verdict by tolerance.
    pub fn row_num(&mut self, id: &str, paper: f64, measured: f64, rel_tol: f64) -> &mut Self {
        let verdict = if paper == 0.0 && measured == 0.0 {
            Verdict::Match
        } else if ((measured - paper) / paper).abs() <= rel_tol {
            Verdict::Match
        } else {
            Verdict::Mismatch
        };
        self.row(id, &sig(paper, 6), &sig(measured, 6), verdict)
    }

    pub fn mismatches(&self) -> usize {
        self.rows
            .iter()
            .filter(|r| r.verdict == Verdict::Mismatch)
            .count()
    }

    pub fn render(&self) -> String {
        let mut t = Table::new(
            &self.title,
            &["experiment", "paper", "measured", "verdict"],
        )
        .aligns(&[Align::Left, Align::Right, Align::Right, Align::Left]);
        for r in &self.rows {
            t.row(&[
                r.id.clone(),
                r.paper.clone(),
                r.measured.clone(),
                r.verdict.symbol().to_string(),
            ]);
        }
        t.render()
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Time a closure with the environment-selected bench budget and print a
/// one-line summary; returns the measurement for further reporting.
pub fn timed_section<F: FnMut()>(label: &str, f: F) -> Measurement {
    let cfg = BenchConfig::from_env();
    let m = bench(&cfg, f);
    println!("  {label}: {}", m.human());
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::Workload;

    #[test]
    fn gen_batch_is_deterministic_and_sized() {
        let b1 = gen_batch(Workload::LogUniform, 128, 9);
        let b2 = gen_batch(Workload::LogUniform, 128, 9);
        assert_eq!(b1.len(), 128);
        assert_eq!(b1.a, b2.a);
        assert_eq!(b1.b, b2.b);
        let b3 = gen_batch(Workload::LogUniform, 128, 10);
        assert_ne!(b1.a, b3.a);
    }

    #[test]
    fn adversarial_batch_finite_and_divisor_nonzero() {
        let b = gen_adversarial_batch(256, 3);
        assert_eq!(b.len(), 256);
        for (&x, &y) in b.a.iter().zip(&b.b) {
            assert!(x.is_finite() && y.is_finite());
            assert!(y != 0.0);
        }
    }

    #[test]
    fn report_verdicts() {
        let mut r = Report::new("demo");
        r.row_num("b0", 1.09811, 1.09812, 1e-4);
        r.row_num("b1", 1.20835, 1.5, 1e-4);
        r.row("note", "-", "42", Verdict::New);
        assert_eq!(r.mismatches(), 1);
        let text = r.render();
        assert!(text.contains("MATCH"));
        assert!(text.contains("MISMATCH"));
        assert!(text.contains("(new)"));
    }
}
