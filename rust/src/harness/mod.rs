//! Bench harness: workload generators and the paper-vs-measured report
//! runner shared by every `rust/benches/*.rs` target, plus the robust
//! trend statistics and regression gate over the recorded bench
//! trajectory ([`trend`]).

pub mod trend;

pub use trend::{
    gate_bench_history, is_latency_metric, is_throughput_metric, mad, median, metric_direction,
    GateReport, MetricDirection, MetricGate,
};

use crate::util::rng::Rng;
use crate::util::table::{sig, Align, Table};
use crate::util::timing::{bench, BenchConfig, Measurement};

/// A batch of f32 division operands.
#[derive(Clone, Debug)]
pub struct DivBatch {
    pub a: Vec<f32>,
    pub b: Vec<f32>,
}

impl DivBatch {
    pub fn len(&self) -> usize {
        self.a.len()
    }

    pub fn is_empty(&self) -> bool {
        self.a.is_empty()
    }

    /// Operands packed as raw bit patterns for
    /// [`crate::divider::Divider::div_bits_batch`].
    pub fn bits_f32(&self) -> (Vec<u64>, Vec<u64>) {
        (
            self.a.iter().map(|&x| x.to_bits() as u64).collect(),
            self.b.iter().map(|&x| x.to_bits() as u64).collect(),
        )
    }
}

/// Generate a division workload of `n` pairs from a named distribution.
pub fn gen_batch(workload: crate::analysis::Workload, n: usize, seed: u64) -> DivBatch {
    let mut rng = Rng::new(seed);
    let mut a = Vec::with_capacity(n);
    let mut b = Vec::with_capacity(n);
    for _ in 0..n {
        let (x, y) = workload.sample_f32(&mut rng);
        a.push(x);
        b.push(y);
    }
    DivBatch { a, b }
}

/// An adversarial batch: corner values and near-boundary significands
/// (segment edges of the Table-I partition, power-of-two neighbourhoods).
pub fn gen_adversarial_batch(n: usize, seed: u64) -> DivBatch {
    let mut rng = Rng::new(seed);
    let bounds = crate::pla::derive_segments(5, 53).expect("Table-I derivation (n=5, 53-bit)");
    let mut a = Vec::with_capacity(n);
    let mut b = Vec::with_capacity(n);
    for i in 0..n {
        let x = match i % 4 {
            0 => {
                // Just inside a segment edge.
                let e = *rng.choose(&bounds);
                (e as f32 + f32::EPSILON).min(1.9999999)
            }
            1 => 1.0 + f32::EPSILON * (rng.below(16) as f32),
            2 => 2.0 - f32::EPSILON * (1.0 + rng.below(16) as f32),
            _ => 1.0 + rng.f32(),
        };
        let scale = 2f32.powi(rng.range_i64(-8, 8) as i32);
        a.push((1.0 + rng.f32()) * scale);
        b.push(x * scale);
    }
    DivBatch { a, b }
}

/// A special-value-heavy batch: NaN/±Inf/±0/subnormal lanes cycled
/// deterministically through random bit patterns, exercising the shared
/// special path of the batch datapath.
pub fn gen_special_batch(n: usize, seed: u64) -> DivBatch {
    let menu = &crate::util::rng::F32_SPECIALS;
    let mut rng = Rng::new(seed);
    let mut a = Vec::with_capacity(n);
    let mut b = Vec::with_capacity(n);
    for i in 0..n {
        a.push(if i % 3 == 0 {
            menu[(i / 3) % menu.len()]
        } else {
            rng.f32_bits()
        });
        b.push(if i % 5 == 0 {
            menu[(i / 5) % menu.len()]
        } else {
            rng.f32_bits()
        });
    }
    DivBatch { a, b }
}

/// A batch whose divisors form contiguous runs of at most `distinct`
/// values — the shape service traffic actually has (k-means centroid
/// updates divide whole rows by one count; normalization divides many
/// lanes by one constant). Exercises the batch path's divisor-reciprocal
/// cache.
pub fn gen_repeated_divisor_batch(n: usize, distinct: usize, seed: u64) -> DivBatch {
    let distinct = distinct.max(1);
    let mut rng = Rng::new(seed);
    let divisors: Vec<f32> = (0..distinct).map(|_| rng.f32_log_uniform(-4, 4)).collect();
    let run = n.div_ceil(distinct).max(1);
    let mut a = Vec::with_capacity(n);
    let mut b = Vec::with_capacity(n);
    for i in 0..n {
        a.push(rng.f32_log_uniform(-8, 8));
        b.push(divisors[(i / run).min(distinct - 1)]);
    }
    DivBatch { a, b }
}

/// Generate `n` operand-pair lanes as bit patterns of an arbitrary
/// format: finite normal values with exponents within ±`espread` of the
/// format's bias (log-uniform-ish), random significands, random signs.
/// The multi-format analogue of [`gen_batch`] for
/// [`crate::divider::Divider::div_bits_batch`] and the typed service
/// API.
pub fn gen_bits_batch(
    fmt: crate::fp::Format,
    n: usize,
    espread: u32,
    seed: u64,
) -> (Vec<u64>, Vec<u64>) {
    let mut rng = Rng::new(seed);
    let spread = espread.min(fmt.bias() as u32 - 1) as u64;
    let mut lane = |rng: &mut Rng| {
        let e = fmt.bias() as u64 - spread + rng.below(2 * spread + 1);
        fmt.assemble(rng.bool(0.5), e, rng.next_u64() & fmt.frac_mask())
    };
    let mut a = Vec::with_capacity(n);
    let mut b = Vec::with_capacity(n);
    for _ in 0..n {
        a.push(lane(&mut rng));
        b.push(lane(&mut rng));
    }
    (a, b)
}

/// The format's special-value menu as bit patterns: NaN, ±Inf, ±0, the
/// smallest and largest subnormal, 1.0, and the largest finite value.
/// Format-generic counterpart of `rng::F32_SPECIALS` for mixed-format
/// service tests.
pub fn special_patterns(fmt: crate::fp::Format) -> [u64; 9] {
    [
        fmt.nan(),
        fmt.inf(false),
        fmt.inf(true),
        fmt.zero(false),
        fmt.zero(true),
        1,               // smallest positive subnormal
        fmt.frac_mask(), // largest subnormal
        fmt.assemble(false, fmt.bias() as u64, 0), // 1.0
        fmt.max_finite(false),
    ]
}

/// One row of a paper-vs-measured table.
#[derive(Clone, Debug)]
pub struct PaperRow {
    pub id: String,
    pub paper: String,
    pub measured: String,
    pub verdict: Verdict,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Verdict {
    Match,
    /// Shape/direction holds; absolute value differs (expected on a
    /// different substrate).
    Consistent,
    /// Contradicts the paper (documented discrepancies).
    Mismatch,
    /// No paper value to compare against (new measurement).
    New,
}

impl Verdict {
    pub fn symbol(&self) -> &'static str {
        match self {
            Verdict::Match => "MATCH",
            Verdict::Consistent => "consistent",
            Verdict::Mismatch => "MISMATCH",
            Verdict::New => "(new)",
        }
    }
}

/// Collects rows and renders the standard report table for a bench.
#[derive(Clone, Debug)]
pub struct Report {
    pub title: String,
    rows: Vec<PaperRow>,
}

impl Report {
    pub fn new(title: &str) -> Self {
        Self {
            title: title.to_string(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, id: &str, paper: &str, measured: &str, verdict: Verdict) -> &mut Self {
        self.rows.push(PaperRow {
            id: id.to_string(),
            paper: paper.to_string(),
            measured: measured.to_string(),
            verdict,
        });
        self
    }

    /// Numeric convenience with automatic match verdict by tolerance.
    pub fn row_num(&mut self, id: &str, paper: f64, measured: f64, rel_tol: f64) -> &mut Self {
        let verdict = if paper == 0.0 && measured == 0.0 {
            Verdict::Match
        } else if ((measured - paper) / paper).abs() <= rel_tol {
            Verdict::Match
        } else {
            Verdict::Mismatch
        };
        self.row(id, &sig(paper, 6), &sig(measured, 6), verdict)
    }

    pub fn mismatches(&self) -> usize {
        self.rows
            .iter()
            .filter(|r| r.verdict == Verdict::Mismatch)
            .count()
    }

    pub fn render(&self) -> String {
        let mut t = Table::new(
            &self.title,
            &["experiment", "paper", "measured", "verdict"],
        )
        .aligns(&[Align::Left, Align::Right, Align::Right, Align::Left]);
        for r in &self.rows {
            t.row(&[
                r.id.clone(),
                r.paper.clone(),
                r.measured.clone(),
                r.verdict.symbol().to_string(),
            ]);
        }
        t.render()
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Time a closure with the environment-selected bench budget and print a
/// one-line summary; returns the measurement for further reporting.
pub fn timed_section<F: FnMut()>(label: &str, f: F) -> Measurement {
    let cfg = BenchConfig::from_env();
    let m = bench(&cfg, f);
    println!("  {label}: {}", m.human());
    m
}

/// Write a bench-trajectory record to `<repo root>/BENCH_<name>.json`
/// (repo root = the crate manifest's parent, independent of the cwd the
/// bench was invoked from), and append the same record as one compact
/// line to the tracked `BENCH_HISTORY.jsonl` so successive runs build a
/// trajectory instead of overwriting each other. Failures are reported,
/// not fatal — a bench run on a read-only checkout still prints its
/// tables.
pub fn write_bench_json(name: &str, json: &crate::util::json::Json) {
    let root = concat!(env!("CARGO_MANIFEST_DIR"), "/..");
    let path = format!("{root}/BENCH_{name}.json");
    match std::fs::write(&path, json.to_string_pretty()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
    let history = bench_history_path();
    let line = format!("{}\n", json.to_string_compact());
    let appended = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(&history)
        .and_then(|mut f| std::io::Write::write_all(&mut f, line.as_bytes()));
    match appended {
        Ok(()) => println!("appended to {history}"),
        Err(e) => eprintln!("could not append {history}: {e}"),
    }
}

/// The tracked bench-trajectory file every [`write_bench_json`] call
/// appends to (repo root, resolved from the crate manifest).
pub fn bench_history_path() -> String {
    concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_HISTORY.jsonl").to_string()
}

/// Read a bench-history file (one JSON record per line, as written by
/// [`write_bench_json`]) — the reading counterpart used by
/// `tsdiv bench-trend`. Blank lines are skipped; a malformed line in the
/// **middle** of the file is an error naming its line number (a
/// corrupted history is loud rather than silently truncated), but a
/// malformed **final** record is skipped with a warning: the appender
/// can be interrupted mid-write (CI cancellation, full disk), and one
/// torn trailing line must not kill every future trend report.
pub fn read_bench_history(path: &str) -> crate::util::error::Result<Vec<crate::util::json::Json>> {
    use crate::util::error::Context as _;
    let text =
        std::fs::read_to_string(path).with_context(|| format!("reading bench history {path}"))?;
    let lines: Vec<&str> = text.lines().collect();
    let last_nonblank = lines.iter().rposition(|l| !l.trim().is_empty());
    let mut records = Vec::new();
    for (lineno, line) in lines.iter().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        match crate::util::json::parse(line) {
            Ok(j) => records.push(j),
            Err(e) if Some(lineno) == last_nonblank => {
                crate::log_warn!(
                    "{path}:{}: skipping malformed trailing record (likely a torn append): {e}",
                    lineno + 1
                );
            }
            Err(e) => crate::bail!("{path}:{}: {e}", lineno + 1),
        }
    }
    Ok(records)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::Workload;

    #[test]
    fn gen_batch_is_deterministic_and_sized() {
        let b1 = gen_batch(Workload::LogUniform, 128, 9);
        let b2 = gen_batch(Workload::LogUniform, 128, 9);
        assert_eq!(b1.len(), 128);
        assert_eq!(b1.a, b2.a);
        assert_eq!(b1.b, b2.b);
        let b3 = gen_batch(Workload::LogUniform, 128, 10);
        assert_ne!(b1.a, b3.a);
    }

    #[test]
    fn adversarial_batch_finite_and_divisor_nonzero() {
        let b = gen_adversarial_batch(256, 3);
        assert_eq!(b.len(), 256);
        for (&x, &y) in b.a.iter().zip(&b.b) {
            assert!(x.is_finite() && y.is_finite());
            assert!(y != 0.0);
        }
    }

    #[test]
    fn bits_f32_packs_patterns() {
        let batch = gen_batch(Workload::LogUniform, 32, 4);
        let (ab, bb) = batch.bits_f32();
        assert_eq!(ab.len(), 32);
        assert_eq!(bb.len(), 32);
        assert_eq!(f32::from_bits(ab[0] as u32), batch.a[0]);
        assert_eq!(f32::from_bits(bb[31] as u32), batch.b[31]);
    }

    #[test]
    fn special_batch_contains_specials_deterministically() {
        let b1 = gen_special_batch(300, 1);
        let b2 = gen_special_batch(300, 1);
        assert_eq!(b1.len(), 300);
        assert_eq!(
            b1.a.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            b2.a.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
        // The deterministic menu cycle guarantees NaN and Inf lanes.
        assert!(b1.a.iter().any(|x| x.is_nan()));
        assert!(b1.a.iter().any(|x| x.is_infinite()));
        assert!(b1.b.iter().any(|x| x.is_nan()));
    }

    #[test]
    fn repeated_divisor_batch_has_contiguous_runs() {
        let b = gen_repeated_divisor_batch(256, 8, 2);
        assert_eq!(b.len(), 256);
        let distinct: std::collections::HashSet<u32> =
            b.b.iter().map(|x| x.to_bits()).collect();
        assert!(distinct.len() <= 8, "{} distinct divisors", distinct.len());
        let transitions = b
            .b
            .windows(2)
            .filter(|w| w[0].to_bits() != w[1].to_bits())
            .count();
        assert!(transitions < 8, "{transitions} transitions — not contiguous runs");
        assert!(b.b.iter().all(|x| x.is_finite() && *x != 0.0));
    }

    #[test]
    fn bits_batch_generates_finite_normals_in_any_format() {
        use crate::fp::{unpack, Class, ALL_FORMATS};
        for fmt in ALL_FORMATS {
            let (a, b) = gen_bits_batch(fmt, 200, 8, 3);
            let (a2, _) = gen_bits_batch(fmt, 200, 8, 3);
            assert_eq!(a, a2, "deterministic for a given seed");
            assert_eq!(a.len(), 200);
            for &bits in a.iter().chain(&b) {
                assert_eq!(bits & !fmt.width_mask(), 0, "{}", fmt.name());
                assert_eq!(unpack(bits, fmt).class, Class::Normal, "{}", fmt.name());
            }
        }
    }

    #[test]
    fn special_patterns_cover_every_class() {
        use crate::fp::{unpack, Class, ALL_FORMATS};
        for fmt in ALL_FORMATS {
            let classes: Vec<Class> = special_patterns(fmt)
                .iter()
                .map(|&p| unpack(p, fmt).class)
                .collect();
            for want in [Class::NaN, Class::Inf, Class::Zero, Class::Subnormal, Class::Normal] {
                assert!(classes.contains(&want), "{}: missing {want:?}", fmt.name());
            }
        }
    }

    #[test]
    fn read_bench_history_roundtrip_and_errors() {
        let dir = std::env::temp_dir();
        let path = dir.join("tsdiv_test_history.jsonl");
        let path = path.to_str().unwrap().to_string();
        std::fs::write(
            &path,
            "{\"bench\":\"a\",\"x\":1}\n\n{\"bench\":\"a\",\"x\":2.5}\n",
        )
        .unwrap();
        let records = read_bench_history(&path).unwrap();
        assert_eq!(records.len(), 2, "blank lines skipped");
        assert_eq!(records[0].get("bench").and_then(|j| j.as_str()), Some("a"));
        assert_eq!(records[1].get("x").and_then(|j| j.as_f64()), Some(2.5));
        // A torn trailing line (interrupted appender) is skipped with a
        // warning — the intact prefix still loads…
        std::fs::write(&path, "{\"bench\":\"a\"}\n{\"bench\":\"b\",\"x\"").unwrap();
        let records = read_bench_history(&path).unwrap();
        assert_eq!(records.len(), 1, "torn trailing record skipped");
        // …including when blank lines follow the torn record.
        std::fs::write(&path, "{\"bench\":\"a\"}\nnot json\n\n").unwrap();
        assert_eq!(read_bench_history(&path).unwrap().len(), 1);
        // …but corruption in the middle of the file is still an error
        // naming its line.
        std::fs::write(&path, "{\"bench\":\"a\"}\nnot json\n{\"bench\":\"c\"}\n").unwrap();
        let e = read_bench_history(&path).unwrap_err();
        assert!(e.to_string().contains(":2:"), "line number in {e}");
        let _ = std::fs::remove_file(&path);
        assert!(read_bench_history("/definitely/missing/history.jsonl").is_err());
        assert!(bench_history_path().ends_with("BENCH_HISTORY.jsonl"));
    }

    #[test]
    fn report_verdicts() {
        let mut r = Report::new("demo");
        r.row_num("b0", 1.09811, 1.09812, 1e-4);
        r.row_num("b1", 1.20835, 1.5, 1e-4);
        r.row("note", "-", "42", Verdict::New);
        assert_eq!(r.mismatches(), 1);
        let text = r.render();
        assert!(text.contains("MATCH"));
        assert!(text.contains("MISMATCH"));
        assert!(text.contains("(new)"));
    }
}
