//! Robust statistics over the `BENCH_HISTORY.jsonl` trajectory and the
//! bench **regression gate** behind `tsdiv bench-trend --gate`.
//!
//! Single bench runs are noisy (CI boxes doubly so), so the gate judges
//! the latest run against the **median** of the previous `window` runs
//! per metric, with the median absolute deviation (MAD) reported as the
//! noise context. The gate is **direction-aware**: throughput keys
//! (containing `per_s`, the convention every serving bench follows)
//! gate higher-is-better, while latency keys (containing `p99`,
//! `latency` or `wait`) gate lower-is-better — a latency key wins when
//! both conventions appear in one name, so `p99_wait_per_s`-style keys
//! can never silently pass on a latency blow-up. Keys matching neither
//! convention (ratios, configuration echoes, lane counts) are
//! trend-reported but never gated. A metric whose history is still
//! shorter than the window is reported as `n/a` and never fails the
//! gate: a fresh trajectory (or a freshly added bench row) warms up
//! gracefully instead of blocking CI.

use crate::util::json::Json;
use crate::util::stats::percentile_of;

/// Median of an unsorted slice (`NaN` on empty input).
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    percentile_of(xs, 0.5)
}

/// Median absolute deviation — the robust spread companion to
/// [`median`]: `median(|x_i − median(xs)|)`. `NaN` on empty input.
pub fn mad(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let med = median(xs);
    let devs: Vec<f64> = xs.iter().map(|x| (x - med).abs()).collect();
    median(&devs)
}

/// Is this record key a gated throughput metric? Every serving bench
/// writes its higher-is-better rates with `per_s` in the key
/// (`kernel_div_per_s_f32`, `mixed_format_div_per_s`, …); ratios,
/// configuration echoes and lane counts are trend-reported but never
/// gated.
pub fn is_throughput_metric(key: &str) -> bool {
    key.contains("per_s")
}

/// Is this record key a latency-style metric (lower is better)? The
/// serving benches write tail-latency keys with `p99`, `latency` or
/// `wait` in the name (`serve_p99_latency_us`, …).
pub fn is_latency_metric(key: &str) -> bool {
    key.contains("p99") || key.contains("latency") || key.contains("wait")
}

/// Which way a gated metric is allowed to move.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MetricDirection {
    /// Throughput-style: regresses when it *drops* past the tolerance.
    HigherIsBetter,
    /// Latency-style: regresses when it *rises* past the tolerance.
    LowerIsBetter,
}

/// Gate direction for a record key, `None` when the key is not gated.
/// Latency naming takes precedence: a key carrying both conventions
/// (e.g. a `…wait…per_s` hybrid) gates lower-is-better, because
/// treating a latency as a throughput silently inverts the check.
pub fn metric_direction(key: &str) -> Option<MetricDirection> {
    if is_latency_metric(key) {
        Some(MetricDirection::LowerIsBetter)
    } else if is_throughput_metric(key) {
        Some(MetricDirection::HigherIsBetter)
    } else {
        None
    }
}

/// One gated metric's verdict.
#[derive(Clone, Debug)]
pub struct MetricGate {
    pub bench: String,
    pub metric: String,
    /// Baseline runs found for this metric (capped at the window; the
    /// gate only judges when `n == window`).
    pub n: usize,
    /// Median of the baseline window (`NaN` while warming up).
    pub baseline_median: f64,
    /// MAD of the baseline window (`NaN` while warming up).
    pub baseline_mad: f64,
    /// The latest run's value.
    pub latest: f64,
    /// `(latest − median) / median` in percent (`NaN` while warming up
    /// or on a zero/non-finite baseline).
    pub delta_pct: f64,
    /// Which way this metric is allowed to move (from its key name).
    pub direction: MetricDirection,
    /// True when the latest value moved more than the tolerance in the
    /// bad direction: dropped below the baseline median for
    /// higher-is-better metrics, rose above it for lower-is-better.
    pub regressed: bool,
}

impl MetricGate {
    /// Still accumulating history — reported `n/a`, never failing.
    pub fn warming_up(&self) -> bool {
        !self.baseline_median.is_finite()
    }
}

/// The gate verdict over a whole history.
#[derive(Clone, Debug)]
pub struct GateReport {
    pub window: usize,
    pub tolerance_pct: f64,
    /// One row per `(bench, throughput metric)` of each bench's latest
    /// record, in first-seen order.
    pub metrics: Vec<MetricGate>,
}

impl GateReport {
    /// The failing rows (empty on a passing or warming-up history).
    pub fn regressions(&self) -> Vec<&MetricGate> {
        self.metrics.iter().filter(|m| m.regressed).collect()
    }

    /// Gate outcome: pass unless at least one metric regressed.
    pub fn passed(&self) -> bool {
        self.metrics.iter().all(|m| !m.regressed)
    }

    /// How many metrics had a full baseline window (i.e. were actually
    /// judged rather than reported `n/a`).
    pub fn judged(&self) -> usize {
        self.metrics.iter().filter(|m| !m.warming_up()).count()
    }
}

/// Judge the latest run of every bench in `records` (as returned by
/// [`super::read_bench_history`]) against the rolling median of the
/// `window` runs preceding it. A higher-is-better metric regresses when
/// `latest < median × (1 − tolerance_pct/100)`; a lower-is-better
/// metric when `latest > median × (1 + tolerance_pct/100)` (see
/// [`metric_direction`]). Metrics with fewer than `window` prior
/// recordings — including the everything-is-new case of an empty or
/// short history — are reported with `NaN` baselines and never regress.
pub fn gate_bench_history(records: &[Json], window: usize, tolerance_pct: f64) -> GateReport {
    assert!(window >= 1, "gate window must be ≥ 1 run");
    assert!(
        tolerance_pct >= 0.0 && tolerance_pct.is_finite(),
        "gate tolerance must be a non-negative percentage"
    );
    // Group records by bench name, preserving first-seen order (the
    // same grouping the trend table uses).
    let mut names: Vec<String> = Vec::new();
    let mut groups: std::collections::HashMap<String, Vec<&Json>> =
        std::collections::HashMap::new();
    for r in records {
        let name = r
            .get("bench")
            .and_then(|j| j.as_str())
            .unwrap_or("(unnamed)")
            .to_string();
        if !groups.contains_key(&name) {
            names.push(name.clone());
        }
        groups.entry(name).or_default().push(r);
    }
    let mut metrics = Vec::new();
    for name in &names {
        let runs = &groups[name];
        let (latest, prior) = runs.split_last().expect("groups are non-empty");
        let Json::Obj(pairs) = *latest else { continue };
        for (key, val) in pairs {
            let Some(direction) = metric_direction(key) else {
                continue;
            };
            let Some(latest_val) = val.as_f64() else { continue };
            // Baseline: the most recent `window` prior runs that carry
            // this metric (older runs predating a freshly added row are
            // simply skipped, so new rows warm up instead of erroring).
            let baseline: Vec<f64> = prior
                .iter()
                .rev()
                .filter_map(|r| r.get(key).and_then(|j| j.as_f64()))
                .take(window)
                .collect();
            let n = baseline.len();
            if n < window {
                metrics.push(MetricGate {
                    bench: name.clone(),
                    metric: key.clone(),
                    n,
                    baseline_median: f64::NAN,
                    baseline_mad: f64::NAN,
                    latest: latest_val,
                    delta_pct: f64::NAN,
                    direction,
                    regressed: false,
                });
                continue;
            }
            let med = median(&baseline);
            let spread = mad(&baseline);
            let (delta_pct, regressed) = if med.is_finite() && med > 0.0 {
                let delta = (latest_val - med) / med * 100.0;
                let bad = match direction {
                    MetricDirection::HigherIsBetter => delta < -tolerance_pct,
                    MetricDirection::LowerIsBetter => delta > tolerance_pct,
                };
                (delta, bad)
            } else {
                // Zero or degenerate baseline: nothing meaningful to
                // gate against.
                (f64::NAN, false)
            };
            metrics.push(MetricGate {
                bench: name.clone(),
                metric: key.clone(),
                n,
                baseline_median: med,
                baseline_mad: spread,
                latest: latest_val,
                delta_pct,
                direction,
                regressed,
            });
        }
    }
    GateReport {
        window,
        tolerance_pct,
        metrics,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record(bench: &str, metric: &str, value: f64) -> Json {
        let mut j = Json::obj();
        j.set("bench", Json::Str(bench.to_string()));
        j.set(metric, Json::Num(value));
        j
    }

    #[test]
    fn median_and_mad_basics() {
        assert!(median(&[]).is_nan());
        assert!(mad(&[]).is_nan());
        assert_eq!(median(&[3.0]), 3.0);
        assert_eq!(mad(&[3.0]), 0.0);
        assert_eq!(median(&[1.0, 9.0, 5.0]), 5.0);
        // devs from median 5: [4, 4, 0] → median 4.
        assert_eq!(mad(&[1.0, 9.0, 5.0]), 4.0);
        // An outlier barely moves the median, unlike the mean.
        assert_eq!(median(&[10.0, 10.0, 10.0, 10.0, 1000.0]), 10.0);
        assert_eq!(mad(&[10.0, 10.0, 10.0, 10.0, 1000.0]), 0.0);
    }

    #[test]
    fn throughput_keys_recognized() {
        assert!(is_throughput_metric("kernel_div_per_s_f32"));
        assert!(is_throughput_metric("mixed_format_div_per_s"));
        assert!(is_throughput_metric("batch_div_per_s"));
        assert!(!is_throughput_metric("lanes"));
        assert!(!is_throughput_metric("kernel_over_scalar_f32"));
        assert!(!is_throughput_metric("simd_over_autovec_f64"));
        assert!(!is_throughput_metric("workers"));
    }

    #[test]
    fn latency_keys_recognized_and_take_precedence() {
        assert!(is_latency_metric("serve_p99_latency_us"));
        assert!(is_latency_metric("batch_wait_ms"));
        assert!(!is_latency_metric("kernel_div_per_s_f32"));
        assert_eq!(
            metric_direction("serve_scale_w4_div_per_s"),
            Some(MetricDirection::HigherIsBetter)
        );
        assert_eq!(
            metric_direction("serve_p99_latency_us"),
            Some(MetricDirection::LowerIsBetter)
        );
        // Both conventions in one key: latency wins — a hybrid name must
        // never gate a rising latency as an "improving throughput".
        assert_eq!(
            metric_direction("x_wait_per_s"),
            Some(MetricDirection::LowerIsBetter)
        );
        assert_eq!(metric_direction("lanes"), None);
        assert_eq!(metric_direction("kernel_over_scalar"), None);
    }

    #[test]
    fn latency_rise_fails_and_fall_passes() {
        // Five steady p99 runs, then a 3× blow-up: lower-is-better must
        // fail on the RISE.
        let mut records: Vec<Json> = (0..5)
            .map(|i| record("serve", "serve_p99_latency_us", 100.0 + i as f64))
            .collect();
        records.push(record("serve", "serve_p99_latency_us", 300.0));
        let report = gate_bench_history(&records, 5, 15.0);
        assert!(!report.passed());
        let regs = report.regressions();
        assert_eq!(regs.len(), 1);
        assert_eq!(regs[0].direction, MetricDirection::LowerIsBetter);
        assert!(regs[0].delta_pct > 100.0, "{}", regs[0].delta_pct);
        // A latency IMPROVEMENT (any size drop) passes…
        records.pop();
        records.push(record("serve", "serve_p99_latency_us", 1.0));
        assert!(gate_bench_history(&records, 5, 15.0).passed());
        // …and so does a rise inside the tolerance.
        records.pop();
        records.push(record("serve", "serve_p99_latency_us", 110.0));
        assert!(gate_bench_history(&records, 5, 15.0).passed());
    }

    #[test]
    fn empty_and_short_histories_warm_up_gracefully() {
        let report = gate_bench_history(&[], 5, 15.0);
        assert!(report.passed());
        assert!(report.metrics.is_empty());
        assert_eq!(report.judged(), 0);
        // Three runs against a 5-run window: reported, n/a, passing.
        let records: Vec<Json> = (0..3)
            .map(|i| record("b", "x_div_per_s", 100.0 + i as f64))
            .collect();
        let report = gate_bench_history(&records, 5, 15.0);
        assert!(report.passed());
        assert_eq!(report.metrics.len(), 1);
        assert!(report.metrics[0].warming_up());
        assert_eq!(report.metrics[0].n, 2, "two prior runs found");
        assert_eq!(report.judged(), 0);
    }

    #[test]
    fn synthetic_regression_fails_and_recovery_passes() {
        // Five steady runs, then a crash to half throughput.
        let mut records: Vec<Json> = (0..5)
            .map(|i| record("divider_throughput", "kernel_div_per_s_f32", 100.0 + i as f64))
            .collect();
        records.push(record("divider_throughput", "kernel_div_per_s_f32", 50.0));
        let report = gate_bench_history(&records, 5, 15.0);
        assert!(!report.passed());
        let regs = report.regressions();
        assert_eq!(regs.len(), 1);
        assert_eq!(regs[0].metric, "kernel_div_per_s_f32");
        assert_eq!(regs[0].baseline_median, 102.0);
        assert!(regs[0].delta_pct < -50.0, "{}", regs[0].delta_pct);
        assert_eq!(report.judged(), 1);
        // A small dip inside the tolerance passes…
        records.pop();
        records.push(record("divider_throughput", "kernel_div_per_s_f32", 95.0));
        assert!(gate_bench_history(&records, 5, 15.0).passed());
        // …and so does an improvement, by any margin.
        records.pop();
        records.push(record("divider_throughput", "kernel_div_per_s_f32", 5000.0));
        assert!(gate_bench_history(&records, 5, 15.0).passed());
    }

    #[test]
    fn only_throughput_metrics_gate_and_benches_stay_separate() {
        let mut records = Vec::new();
        for i in 0..6 {
            let mut j = Json::obj();
            j.set("bench", Json::Str("serve".into()));
            j.set("kernel_div_per_s", Json::Num(200.0));
            // A collapsing ratio must NOT trip the gate (not a per_s key).
            j.set("kernel_over_scalar", Json::Num(10.0 - i as f64));
            records.push(j);
        }
        // A different bench with its own short history: n/a, not judged
        // against "serve"'s records.
        records.push(record("other", "other_div_per_s", 1.0));
        let report = gate_bench_history(&records, 5, 15.0);
        assert!(report.passed());
        let other: Vec<_> = report.metrics.iter().filter(|m| m.bench == "other").collect();
        assert_eq!(other.len(), 1);
        assert!(other[0].warming_up());
    }

    #[test]
    fn freshly_added_metric_warms_up_inside_an_old_bench() {
        // Five old runs without the new row, then two runs with it: the
        // new metric has only one prior recording → n/a, while the old
        // metric is judged normally.
        let mut records: Vec<Json> = (0..5).map(|_| record("b", "old_div_per_s", 100.0)).collect();
        for _ in 0..2 {
            let mut j = record("b", "old_div_per_s", 100.0);
            j.set("new_div_per_s", Json::Num(7.0));
            records.push(j);
        }
        let report = gate_bench_history(&records, 5, 15.0);
        assert!(report.passed());
        let new_row = report
            .metrics
            .iter()
            .find(|m| m.metric == "new_div_per_s")
            .unwrap();
        assert!(new_row.warming_up());
        assert_eq!(new_row.n, 1);
        let old_row = report
            .metrics
            .iter()
            .find(|m| m.metric == "old_div_per_s")
            .unwrap();
        assert!(!old_row.warming_up());
    }

    #[test]
    fn zero_baseline_prints_na_instead_of_failing() {
        let mut records: Vec<Json> = (0..5).map(|_| record("b", "x_per_s", 0.0)).collect();
        records.push(record("b", "x_per_s", 0.0));
        let report = gate_bench_history(&records, 5, 15.0);
        assert!(report.passed());
        assert!(report.metrics[0].delta_pct.is_nan());
    }

    #[test]
    fn window_uses_runs_preceding_the_latest_only() {
        // Median must come from the 3 runs before the latest, not
        // include the latest itself: baseline [100, 100, 10] → median
        // 100; latest 10 → −90 % → regression at window 3.
        let values = [100.0, 100.0, 10.0, 10.0];
        let records: Vec<Json> = values
            .iter()
            .map(|&v| record("b", "x_per_s", v))
            .collect();
        let report = gate_bench_history(&records, 3, 15.0);
        assert!(!report.passed());
        assert_eq!(report.metrics[0].baseline_median, 100.0);
    }

    #[test]
    fn gate_reads_a_real_temp_bench_history_file() {
        // End-to-end against the same reader the CLI uses: write a
        // synthetic regression fixture as a temp BENCH_HISTORY, read it
        // back, gate it.
        let path = std::env::temp_dir().join("tsdiv_test_gate_history.jsonl");
        let path = path.to_str().unwrap().to_string();
        let mut lines = String::new();
        for v in [100.0, 101.0, 99.0, 100.0, 102.0, 40.0] {
            lines.push_str(&format!(
                "{{\"bench\":\"divider_throughput\",\"kernel_div_per_s_f32\":{v},\"lanes\":4096}}\n"
            ));
        }
        std::fs::write(&path, lines).unwrap();
        let records = crate::harness::read_bench_history(&path).unwrap();
        assert_eq!(records.len(), 6);
        let report = gate_bench_history(&records, 5, 15.0);
        assert!(!report.passed(), "synthetic regression fixture must fail the gate");
        assert_eq!(report.regressions().len(), 1);
        // The same file passes at a window its history cannot fill.
        let report = gate_bench_history(&records, 50, 15.0);
        assert!(report.passed());
        assert_eq!(report.judged(), 0);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    #[should_panic(expected = "window")]
    fn zero_window_rejected() {
        let _ = gate_bench_history(&[], 0, 15.0);
    }
}
