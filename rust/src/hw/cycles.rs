//! Cycle/latency models for the units, including the pipelined variant
//! the paper's conclusion proposes ("performance … can be improved by
//! pipelining … at the cost of increase in hardware utilization").

use super::census::Census;
use super::units::{ilm_stage_path, squaring_stage_path};
use crate::powering::schedule_cycles;

/// Latency/throughput estimate for a unit configuration.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Timing {
    /// Cycles from operand issue to result.
    pub latency_cycles: u32,
    /// Cycles between successive independent operations.
    pub initiation_interval: u32,
    /// Minimum clock period in gate units (critical stage delay).
    pub min_period_gates: f64,
}

impl Timing {
    /// Wall-clock latency in ns at a given gate delay (ps).
    pub fn latency_ns(&self, gate_ps: f64) -> f64 {
        self.latency_cycles as f64 * self.min_period_gates * gate_ps / 1000.0
    }

    /// Results per second at a given gate delay (ps).
    pub fn throughput_per_s(&self, gate_ps: f64) -> f64 {
        let period_s = self.min_period_gates * gate_ps * 1e-12;
        1.0 / (self.initiation_interval as f64 * period_s)
    }
}

/// ILM timing: `1 + iterations` basic-block passes, iterative (block
/// reused each cycle) or pipelined (II = 1, one block per stage).
pub fn ilm_timing(w: u32, iterations: u32, pipelined: bool) -> Timing {
    let stages = 1 + iterations;
    let stage_delay = ilm_stage_path(w).delay();
    Timing {
        latency_cycles: stages,
        initiation_interval: if pipelined { 1 } else { stages },
        min_period_gates: stage_delay,
    }
}

/// Squaring-unit timing (same schedule, cheaper stage).
pub fn squaring_timing(w: u32, iterations: u32, pipelined: bool) -> Timing {
    let stages = 1 + iterations;
    let stage_delay = squaring_stage_path(w).delay();
    Timing {
        latency_cycles: stages,
        initiation_interval: if pipelined { 1 } else { stages },
        min_period_gates: stage_delay,
    }
}

/// Powering-unit timing for `max_power` powers with a given ILM
/// correction budget: the Fig-6 schedule runs `schedule_cycles` macro
/// cycles, each macro cycle spanning one (pipelined or iterative)
/// multiplier pass; multiplier and squarer run in parallel so the ILM
/// (slower stage) bounds the macro-cycle.
pub fn powering_timing(w: u32, max_power: u32, ilm_iterations: u32, pipelined: bool) -> Timing {
    let macro_cycles = schedule_cycles(max_power);
    let mul = ilm_timing(w, ilm_iterations, pipelined);
    Timing {
        latency_cycles: macro_cycles * mul.latency_cycles.max(1),
        initiation_interval: if pipelined {
            macro_cycles.max(1)
        } else {
            macro_cycles * mul.latency_cycles.max(1)
        },
        min_period_gates: mul.min_period_gates,
    }
}

/// End-to-end divider latency (Fig 7): seed (compare+mul) + powering +
/// accumulate + final multiply + round.
pub fn divider_timing(
    w: u32,
    order: u32,
    ilm_iterations: u32,
    pipelined: bool,
) -> Timing {
    let mul = ilm_timing(w, ilm_iterations, pipelined);
    let powering = powering_timing(w, order, ilm_iterations, pipelined);
    // seed multiply + m multiply + final multiply: 3 multiplier passes
    // outside the powering schedule; accumulate+round ≈ 2 cycles.
    let extra = 3 * mul.latency_cycles + 2;
    Timing {
        latency_cycles: powering.latency_cycles + extra,
        initiation_interval: if pipelined {
            powering.initiation_interval.max(mul.initiation_interval) + 1
        } else {
            powering.latency_cycles + extra
        },
        min_period_gates: mul.min_period_gates,
    }
}

/// Digit-recurrence divider timing: 1 quotient bit per cycle over a
/// short-period datapath (compare+subtract ≈ CLA delay).
pub fn longdiv_timing(frac_bits: u32) -> Timing {
    Timing {
        latency_cycles: frac_bits + 3,
        initiation_interval: frac_bits + 3,
        min_period_gates: super::components::Component::AdderCla {
            bits: frac_bits + 3,
        }
        .delay(),
    }
}

/// Pipelining cost: registers inserted between stages (`stages − 1`
/// borders × the stage's live state width ≈ 2w bits).
pub fn pipeline_overhead(base: &Census, w: u32, stages: u32) -> Census {
    let mut c = base.clone();
    c.name = format!("{} [pipelined x{stages}]", base.name);
    if stages > 1 {
        c.add(
            super::components::Component::Register { bits: 2 * w },
            stages - 1,
        );
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::units::squaring_unit;

    #[test]
    fn pipelining_trades_area_for_throughput() {
        let w = 32;
        let iters = 3;
        let iterative = ilm_timing(w, iters, false);
        let pipelined = ilm_timing(w, iters, true);
        // Same latency, better II.
        assert_eq!(iterative.latency_cycles, pipelined.latency_cycles);
        assert!(pipelined.initiation_interval < iterative.initiation_interval);
        assert!(
            pipelined.throughput_per_s(15.0) > 2.0 * iterative.throughput_per_s(15.0)
        );
        // And costs registers.
        let base = squaring_unit(w);
        let piped = pipeline_overhead(&base, w, 1 + iters);
        assert!(piped.area() > base.area());
    }

    #[test]
    fn squaring_stage_not_slower_than_ilm_stage() {
        for w in [16, 32, 53] {
            assert!(
                squaring_timing(w, 2, false).min_period_gates
                    <= ilm_timing(w, 2, false).min_period_gates
            );
        }
    }

    #[test]
    fn powering_schedule_scales_with_power_count() {
        let t4 = powering_timing(32, 4, 2, false);
        let t12 = powering_timing(32, 12, 2, false);
        assert!(t12.latency_cycles > t4.latency_cycles);
    }

    #[test]
    fn taylor_divider_beats_longdiv_latency_at_paper_config() {
        // The architectural motivation: 5 Taylor iterations with a few ILM
        // corrections complete in far fewer cycles than 53+ digit-recurrence
        // cycles... per cycle-count; wall-clock depends on the period too.
        let taylor = divider_timing(60, 5, 2, false);
        let ld = longdiv_timing(52);
        assert!(
            taylor.latency_cycles < ld.latency_cycles,
            "taylor {} vs longdiv {}",
            taylor.latency_cycles,
            ld.latency_cycles
        );
    }

    #[test]
    fn throughput_and_latency_units_consistent() {
        let t = ilm_timing(32, 2, true);
        let thr = t.throughput_per_s(15.0);
        let lat = t.latency_ns(15.0);
        assert!(thr > 0.0 && lat > 0.0);
        // II=1: throughput = 1/period.
        let period_ns = t.min_period_gates * 15.0 / 1000.0;
        assert!((thr - 1.0 / (period_ns * 1e-9)).abs() / thr < 1e-9);
    }
}
