//! Gate-level component catalog.
//!
//! The paper argues hardware cost structurally (Fig 4 vs Fig 5: the
//! squaring unit needs one of everything where the ILM needs two) but
//! never synthesizes. To quantify the claim we use a standard
//! NAND2-equivalent area catalog and FO4-style delay estimates, the same
//! first-order numbers used in architecture textbooks (e.g. Weste &
//! Harris, CMOS VLSI Design; Ercegovac & Lang, Digital Arithmetic):
//!
//! | primitive | area (NAND2-eq) | delay (gate units) |
//! |-----------|-----------------|--------------------|
//! | INV       | 0.5             | 0.5                |
//! | NAND2     | 1               | 1                  |
//! | XOR2      | 3               | 1.5                |
//! | MUX2      | 3               | 1.5                |
//! | full adder| 9               | 2 (carry path)     |
//! | DFF bit   | 6               | — (sequencing)     |
//!
//! Absolute numbers are nominal; every paper claim we reproduce is a
//! **ratio** between units built from the same catalog, which is robust
//! to the choice of constants (DESIGN.md §2, substitution (a)).

/// A hardware component instance with a parametric size.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Component {
    /// n-bit priority encoder (returns ⌊log2 N⌋).
    PriorityEncoder { bits: u32 },
    /// n-bit leading-one detector (isolates the top set bit).
    Lod { bits: u32 },
    /// n-bit bit-clear stage (residue N − 2^k: mask generated from k).
    BitClear { bits: u32 },
    /// n-bit logarithmic barrel shifter (shift distance up to n).
    BarrelShifter { bits: u32 },
    /// n-bit ripple-carry adder.
    AdderRca { bits: u32 },
    /// n-bit carry-lookahead adder (4-bit groups).
    AdderCla { bits: u32 },
    /// k-input to 2^k-output decoder (ILM's 2^(k1+k2) term).
    Decoder { out_bits: u32 },
    /// n-bit register (DFF row).
    Register { bits: u32 },
    /// n-bit 2:1 multiplexer row.
    Mux2 { bits: u32 },
    /// n-bit magnitude comparator (PLA segment select).
    Comparator { bits: u32 },
    /// ROM storage (segment tables), counted in bits.
    RomBits { bits: u32 },
    /// Control FSM overhead (states).
    Control { states: u32 },
}

impl Component {
    /// Area in NAND2-equivalent gates.
    pub fn area(&self) -> f64 {
        match *self {
            // A priority encoder is a chain of scan cells ≈ 3 gates/bit
            // plus ⌈log2 n⌉·n/4 encode gates.
            Component::PriorityEncoder { bits } => {
                3.0 * bits as f64 + log2c(bits) as f64 * bits as f64 / 4.0
            }
            // LOD: scan chain (2 gates/bit) + isolate AND row.
            Component::Lod { bits } => 3.0 * bits as f64,
            // Bit clear: decoder-free mask via LOD output + n NAND.
            Component::BitClear { bits } => bits as f64,
            // log2(n) stages of n MUX2 (3 gates each).
            Component::BarrelShifter { bits } => 3.0 * bits as f64 * log2c(bits) as f64,
            // 9 NAND2-eq per full adder.
            Component::AdderRca { bits } => 9.0 * bits as f64,
            // CLA: FA row + lookahead tree ≈ 14 gates/bit.
            Component::AdderCla { bits } => 14.0 * bits as f64,
            // One gate per output plus predecode.
            Component::Decoder { out_bits } => 1.25 * out_bits as f64 + 2.0 * log2c(out_bits) as f64,
            Component::Register { bits } => 6.0 * bits as f64,
            Component::Mux2 { bits } => 3.0 * bits as f64,
            // Comparator: XOR row + borrow chain ≈ 4.5/bit.
            Component::Comparator { bits } => 4.5 * bits as f64,
            // ~0.25 NAND2-eq per ROM bit (dense array).
            Component::RomBits { bits } => 0.25 * bits as f64,
            // ~30 gates per FSM state (one-hot + next-state logic).
            Component::Control { states } => 30.0 * states as f64,
        }
    }

    /// Worst-case combinational delay in normalized gate units
    /// (≈ FO4-equivalents; registers contribute sequencing, not delay).
    pub fn delay(&self) -> f64 {
        match *self {
            Component::PriorityEncoder { bits } => 2.0 * log2c(bits) as f64,
            Component::Lod { bits } => 2.0 * log2c(bits) as f64,
            Component::BitClear { .. } => 1.0,
            Component::BarrelShifter { bits } => 1.5 * log2c(bits) as f64,
            Component::AdderRca { bits } => 2.0 * bits as f64,
            Component::AdderCla { bits } => 4.0 + 2.0 * log4c(bits) as f64,
            Component::Decoder { out_bits } => 1.0 + log2c(out_bits) as f64 / 2.0,
            Component::Register { .. } => 0.0,
            Component::Mux2 { .. } => 1.5,
            Component::Comparator { bits } => 2.0 + log2c(bits) as f64,
            Component::RomBits { .. } => 2.0,
            Component::Control { .. } => 2.0,
        }
    }

    /// Short display name.
    pub fn label(&self) -> String {
        match *self {
            Component::PriorityEncoder { bits } => format!("PE{bits}"),
            Component::Lod { bits } => format!("LOD{bits}"),
            Component::BitClear { bits } => format!("CLR{bits}"),
            Component::BarrelShifter { bits } => format!("SHIFT{bits}"),
            Component::AdderRca { bits } => format!("RCA{bits}"),
            Component::AdderCla { bits } => format!("CLA{bits}"),
            Component::Decoder { out_bits } => format!("DEC{out_bits}"),
            Component::Register { bits } => format!("REG{bits}"),
            Component::Mux2 { bits } => format!("MUX{bits}"),
            Component::Comparator { bits } => format!("CMP{bits}"),
            Component::RomBits { bits } => format!("ROM{bits}b"),
            Component::Control { states } => format!("CTL{states}"),
        }
    }
}

/// ⌈log2 n⌉ with log2c(0/1) = 1 (degenerate sizes still cost one stage).
pub fn log2c(n: u32) -> u32 {
    if n <= 2 {
        1
    } else {
        32 - (n - 1).leading_zeros()
    }
}

/// ⌈log4 n⌉, minimum 1.
pub fn log4c(n: u32) -> u32 {
    log2c(n).div_ceil(2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log_helpers() {
        assert_eq!(log2c(2), 1);
        assert_eq!(log2c(3), 2);
        assert_eq!(log2c(16), 4);
        assert_eq!(log2c(17), 5);
        assert_eq!(log4c(16), 2);
        assert_eq!(log4c(64), 3);
    }

    #[test]
    fn areas_scale_with_width() {
        for make in [
            |b| Component::PriorityEncoder { bits: b },
            |b| Component::BarrelShifter { bits: b },
            |b| Component::AdderRca { bits: b },
            |b| Component::Register { bits: b },
        ] {
            let a16 = make(16).area();
            let a32 = make(32).area();
            assert!(a32 > a16 * 1.5, "{:?}", make(32));
        }
    }

    #[test]
    fn rca_slower_but_smaller_than_cla() {
        let rca = Component::AdderRca { bits: 32 };
        let cla = Component::AdderCla { bits: 32 };
        assert!(rca.area() < cla.area());
        assert!(rca.delay() > cla.delay());
    }

    #[test]
    fn register_has_no_combinational_delay() {
        assert_eq!(Component::Register { bits: 64 }.delay(), 0.0);
        assert!(Component::Register { bits: 64 }.area() > 0.0);
    }

    #[test]
    fn labels_unique_enough() {
        assert_eq!(Component::PriorityEncoder { bits: 24 }.label(), "PE24");
        assert_eq!(Component::RomBits { bits: 1008 }.label(), "ROM1008b");
    }
}
