//! Component census: the bill of materials of a hardware unit, with
//! area/delay/power roll-ups.

use super::components::Component;
use crate::util::table::{sig, Align, Table};

/// A unit's bill of materials.
#[derive(Clone, Debug, Default)]
pub struct Census {
    pub name: String,
    items: Vec<(Component, u32)>,
}

impl Census {
    pub fn new(name: &str) -> Self {
        Self {
            name: name.to_string(),
            items: Vec::new(),
        }
    }

    /// Add `count` instances of a component.
    pub fn add(&mut self, c: Component, count: u32) -> &mut Self {
        if count > 0 {
            if let Some(it) = self.items.iter_mut().find(|(k, _)| *k == c) {
                it.1 += count;
            } else {
                self.items.push((c, count));
            }
        }
        self
    }

    /// Merge another census (e.g. a sub-unit) into this one.
    pub fn merge(&mut self, other: &Census) -> &mut Self {
        for &(c, n) in &other.items {
            self.add(c, n);
        }
        self
    }

    pub fn items(&self) -> &[(Component, u32)] {
        &self.items
    }

    /// Total area in NAND2-equivalent gates.
    pub fn area(&self) -> f64 {
        self.items
            .iter()
            .map(|(c, n)| c.area() * *n as f64)
            .sum()
    }

    /// First-order dynamic-power proxy: proportional to gate area
    /// (uniform activity). Reported in the same NAND2-eq units.
    pub fn power_proxy(&self) -> f64 {
        self.area()
    }

    /// Datapath area: combinational compute blocks only (registers and
    /// control excluded). This is the quantity the paper's §5 claim is
    /// about — it compares "the most hardware intensive components"
    /// (priority encoders, LODs, shifters, adders, decoder).
    pub fn datapath_area(&self) -> f64 {
        self.items
            .iter()
            .filter(|(c, _)| {
                !matches!(
                    c,
                    super::components::Component::Register { .. }
                        | super::components::Component::Control { .. }
                )
            })
            .map(|(c, n)| c.area() * *n as f64)
            .sum()
    }

    /// Count instances of a specific component kind (by label prefix).
    pub fn count_matching(&self, label_prefix: &str) -> u32 {
        self.items
            .iter()
            .filter(|(c, _)| c.label().starts_with(label_prefix))
            .map(|(_, n)| *n)
            .sum()
    }

    /// Render a BOM table.
    pub fn render(&self) -> String {
        let mut t = Table::new(
            &format!("{} — bill of materials", self.name),
            &["component", "count", "area(NAND2)", "delay(gates)"],
        )
        .aligns(&[Align::Left, Align::Right, Align::Right, Align::Right]);
        let mut items = self.items.clone();
        items.sort_by(|a, b| {
            (b.0.area() * b.1 as f64)
                .partial_cmp(&(a.0.area() * a.1 as f64))
                .unwrap()
        });
        for (c, n) in &items {
            t.row(&[
                c.label(),
                n.to_string(),
                sig(c.area() * *n as f64, 5),
                sig(c.delay(), 3),
            ]);
        }
        t.row(&[
            "TOTAL".to_string(),
            String::new(),
            sig(self.area(), 6),
            String::new(),
        ]);
        t.render()
    }
}

/// A named critical path: an ordered chain of components whose delays sum.
#[derive(Clone, Debug)]
pub struct CriticalPath {
    pub name: String,
    pub stages: Vec<Component>,
}

impl CriticalPath {
    pub fn new(name: &str, stages: Vec<Component>) -> Self {
        Self {
            name: name.to_string(),
            stages,
        }
    }

    /// Total delay in gate units.
    pub fn delay(&self) -> f64 {
        self.stages.iter().map(|c| c.delay()).sum()
    }

    /// Convert gate units to nanoseconds for a given gate delay in ps
    /// (e.g. ~15 ps FO4 in a mature 28 nm process).
    pub fn delay_ns(&self, gate_ps: f64) -> f64 {
        self.delay() * gate_ps / 1000.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::components::Component as C;

    #[test]
    fn add_and_merge_accumulate() {
        let mut a = Census::new("a");
        a.add(C::PriorityEncoder { bits: 16 }, 1);
        a.add(C::PriorityEncoder { bits: 16 }, 1);
        let mut b = Census::new("b");
        b.add(C::PriorityEncoder { bits: 16 }, 3);
        b.add(C::Lod { bits: 16 }, 1);
        a.merge(&b);
        assert_eq!(a.count_matching("PE16"), 5);
        assert_eq!(a.count_matching("LOD"), 1);
        assert_eq!(a.items().len(), 2);
    }

    #[test]
    fn zero_count_is_noop() {
        let mut a = Census::new("a");
        a.add(C::Lod { bits: 8 }, 0);
        assert!(a.items().is_empty());
        assert_eq!(a.area(), 0.0);
    }

    #[test]
    fn area_is_weighted_sum() {
        let mut a = Census::new("a");
        a.add(C::Register { bits: 10 }, 2);
        assert_eq!(a.area(), 2.0 * 6.0 * 10.0);
        assert_eq!(a.power_proxy(), a.area());
    }

    #[test]
    fn critical_path_sums_delays() {
        let p = CriticalPath::new(
            "pe→shift→add",
            vec![
                C::PriorityEncoder { bits: 32 },
                C::BarrelShifter { bits: 32 },
                C::AdderCla { bits: 32 },
            ],
        );
        let want = C::PriorityEncoder { bits: 32 }.delay()
            + C::BarrelShifter { bits: 32 }.delay()
            + C::AdderCla { bits: 32 }.delay();
        assert_eq!(p.delay(), want);
        assert!((p.delay_ns(15.0) - want * 0.015).abs() < 1e-12);
    }

    #[test]
    fn render_contains_totals() {
        let mut a = Census::new("demo unit");
        a.add(C::AdderRca { bits: 8 }, 1);
        let r = a.render();
        assert!(r.contains("demo unit"));
        assert!(r.contains("TOTAL"));
        assert!(r.contains("RCA8"));
    }
}
