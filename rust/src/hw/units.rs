//! Bill-of-materials builders for every unit in the paper's figures,
//! and the quantitative form of the §5 "< 50 % hardware" claim.
//!
//! Component inventories follow the block diagrams:
//!
//! * **Fig 4 (ILM basic block)** — two operand pipelines in parallel:
//!   2× priority encoder, 2× LOD + bit-clear, 2× barrel shifter, a small
//!   adder for `k1+k2`, a decoder for `2^(k1+k2)`, and a 2w-bit
//!   accumulation adder tree (two adders for the three P0 terms), plus
//!   operand/result registers and control.
//! * **Fig 5 (squaring unit)** — one of each: 1× PE, 1× LOD + clear,
//!   1× shifter, the `k+1` add is a wire shift (no decoder: `4^k` is
//!   `0b100 << 2k−2`…), one 2w-bit adder **reused** across stages
//!   (paper: "the adder and the barrel shifter … can be reused in each
//!   stage").
//! * **Fig 6/7 (powering unit, divider system)** — compositions of the
//!   above plus the §6 operand cache, the PLA unit's ROM/comparators and
//!   the accumulator.

use super::census::{Census, CriticalPath};
use super::components::{log2c, Component as C};

/// BOM of the Fig-4 ILM basic block at operand width `w`.
pub fn ilm_unit(w: u32) -> Census {
    let mut c = Census::new(&format!("ILM basic multiplier (Fig 4, w={w})"));
    let kbits = log2c(w);
    // Two parallel operand pipelines (the paper duplicates "the most
    // hardware intensive components … to parallelize computation").
    c.add(C::PriorityEncoder { bits: w }, 2);
    c.add(C::Lod { bits: w }, 2);
    c.add(C::BitClear { bits: w }, 2);
    // Shift each residue by the other operand's k: two 2w barrel shifters.
    c.add(C::BarrelShifter { bits: 2 * w }, 2);
    // k1 + k2.
    c.add(C::AdderRca { bits: kbits }, 1);
    // 2^(k1+k2) needs a decoder over the 2w-bit product space.
    c.add(C::Decoder { out_bits: 2 * w }, 1);
    // Sum of three partial terms: two 2w-bit CLAs.
    c.add(C::AdderCla { bits: 2 * w }, 2);
    // Operand, residue-feedback and product registers.
    c.add(C::Register { bits: w }, 4);
    c.add(C::Register { bits: 2 * w }, 1);
    // Iteration control.
    c.add(C::Control { states: 4 }, 1);
    c
}

/// BOM of the Fig-5 squaring unit at operand width `w`.
pub fn squaring_unit(w: u32) -> Census {
    let mut c = Census::new(&format!("Squaring unit (Fig 5, w={w})"));
    // Single operand pipeline.
    c.add(C::PriorityEncoder { bits: w }, 1);
    c.add(C::Lod { bits: w }, 1);
    c.add(C::BitClear { bits: w }, 1);
    // One shifter: 2^(k+1)·r. 4^k is a constant shift — no decoder.
    c.add(C::BarrelShifter { bits: 2 * w }, 1);
    // k+1 is an increment, not a full adder: count a log-width RCA.
    c.add(C::AdderRca { bits: log2c(w) }, 1);
    // ONE 2w-bit adder, reused across stages (paper §5).
    c.add(C::AdderCla { bits: 2 * w }, 1);
    // Operand + residue + accumulator registers.
    c.add(C::Register { bits: w }, 2);
    c.add(C::Register { bits: 2 * w }, 1);
    c.add(C::Control { states: 3 }, 1);
    c
}

/// BOM of the §6 powering unit: one ILM + one squaring unit operating in
/// parallel, the (k, N−2^k) cache for the base operand, and schedule
/// control (Fig 6).
pub fn powering_unit(w: u32) -> Census {
    let mut c = Census::new(&format!("Powering unit (Fig 6, w={w})"));
    c.merge(&ilm_unit(w));
    c.merge(&squaring_unit(w));
    // §6 cache: k (log2 w bits) + residue (w bits) for the base operand.
    c.add(C::Register { bits: w + log2c(w) }, 1);
    // Power-index sequencing and operand routing muxes.
    c.add(C::Mux2 { bits: w }, 3);
    c.add(C::Control { states: 6 }, 1);
    c
}

/// BOM of the PLA seed unit: segment ROM, compare tree, and the seed
/// multiply-subtract (reusing the powering unit's multiplier is the
/// system option; standalone carries its own CLA).
pub fn pla_unit(segments: u32, w: u32) -> Census {
    let mut c = Census::new(&format!("PLA unit ({segments} segments, w={w})"));
    // Three Q2.F words per segment: edge, slope, intercept.
    c.add(
        C::RomBits {
            bits: 3 * (w + 2) * segments,
        },
        1,
    );
    // Compare tree: one comparator per level of a balanced tree.
    c.add(C::Comparator { bits: w }, log2c(segments.max(2)));
    // y0 = c − s·x: subtractor (the multiply itself is issued on the
    // shared multiplier unit per Fig 7).
    c.add(C::AdderCla { bits: w }, 1);
    c.add(C::Register { bits: w }, 2);
    c
}

/// BOM of the full divider system of Fig 7: PLA unit + powering unit +
/// accumulator + final multiplier path + exponent/sign logic.
pub fn divider_system(segments: u32, w: u32, fmt_exp_bits: u32) -> Census {
    let mut c = Census::new(&format!(
        "Division unit (Fig 7, {segments} segs, w={w})"
    ));
    c.merge(&pla_unit(segments, w));
    c.merge(&powering_unit(w));
    // Accumulator for S = 1 + Σ m^k.
    c.add(C::AdderCla { bits: w }, 1);
    c.add(C::Register { bits: w }, 1);
    // Exponent path: subtract + bias adjust.
    c.add(C::AdderRca { bits: fmt_exp_bits + 2 }, 2);
    // Normalize/round: shifter + increment + sticky logic.
    c.add(C::BarrelShifter { bits: w }, 1);
    c.add(C::AdderRca { bits: w }, 1);
    c.add(C::Control { states: 8 }, 1);
    c
}

/// A Newton–Raphson divider's BOM at the same width: seed PLA + TWO full
/// multipliers (x·y and y·t are dependent, but hardware still must carry
/// a full multiplier; we give it the ILM to keep the comparison apples
/// to apples) + subtract-from-2 and registers.
pub fn newton_system(segments: u32, w: u32, fmt_exp_bits: u32) -> Census {
    let mut c = Census::new(&format!(
        "Newton-Raphson unit ({segments} segs, w={w})"
    ));
    c.merge(&pla_unit(segments, w));
    // One full two-operand multiplier (no squaring shortcut applies:
    // both NR multiplies have distinct operands).
    c.merge(&ilm_unit(w));
    // 2 − xy subtractor.
    c.add(C::AdderCla { bits: w }, 1);
    c.add(C::Register { bits: w }, 2);
    c.add(C::AdderRca { bits: fmt_exp_bits + 2 }, 2);
    c.add(C::BarrelShifter { bits: w }, 1);
    c.add(C::Control { states: 6 }, 1);
    c
}

/// The §5 headline ratio: squaring-unit datapath area / ILM datapath
/// area at width `w`. The paper's "less than half" claim counts the
/// compute blocks ("the most hardware intensive components"); with
/// sequencing registers and control included the ratio lands at ~0.53
/// (reported separately by [`squaring_vs_ilm_ratio_total`]).
pub fn squaring_vs_ilm_ratio(w: u32) -> f64 {
    squaring_unit(w).datapath_area() / ilm_unit(w).datapath_area()
}

/// Total-area variant of the §5 ratio (registers + control included).
pub fn squaring_vs_ilm_ratio_total(w: u32) -> f64 {
    squaring_unit(w).area() / ilm_unit(w).area()
}

/// Powering-unit overhead vs a bare ILM (§6 claims "little hardware
/// overhead when compared to the Iterative Logarithmic Multiplier" —
/// the overhead is the squarer + cache, so the ratio is ≈ 1.5, i.e.
/// much less than the 2.0 of two full multipliers).
pub fn powering_vs_two_ilm_ratio(w: u32) -> f64 {
    powering_unit(w).area() / (2.0 * ilm_unit(w).area())
}

/// Critical path of one ILM correction stage: PE → shift → accumulate add.
pub fn ilm_stage_path(w: u32) -> CriticalPath {
    CriticalPath::new(
        "ILM stage: PE→clear→shift→add→add",
        vec![
            C::PriorityEncoder { bits: w },
            C::BitClear { bits: w },
            C::BarrelShifter { bits: 2 * w },
            C::AdderCla { bits: 2 * w },
            C::AdderCla { bits: 2 * w },
        ],
    )
}

/// Critical path of one squaring stage (single adder level).
pub fn squaring_stage_path(w: u32) -> CriticalPath {
    CriticalPath::new(
        "SQ stage: PE→clear→shift→add",
        vec![
            C::PriorityEncoder { bits: w },
            C::BitClear { bits: w },
            C::BarrelShifter { bits: 2 * w },
            C::AdderCla { bits: 2 * w },
        ],
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn squaring_unit_under_half_ilm_at_paper_widths() {
        // §5: "the hardware requirement for the squaring unit is less
        // than half as compared to the basic multiplier unit".
        for w in [16u32, 24, 32, 53, 64] {
            let r = squaring_vs_ilm_ratio(w);
            assert!(r < 0.5, "w={w}: datapath ratio {r:.3} not < 0.5");
            assert!(r > 0.25, "w={w}: ratio {r:.3} implausibly small");
            // Including registers/control the squarer stays well under
            // two-thirds of the multiplier.
            let rt = squaring_vs_ilm_ratio_total(w);
            assert!(rt < 0.65, "w={w}: total ratio {rt:.3}");
        }
    }

    #[test]
    fn powering_unit_cheaper_than_two_multipliers() {
        for w in [16u32, 24, 32, 53] {
            let r = powering_vs_two_ilm_ratio(w);
            assert!(r < 0.85, "w={w}: powering/2·ILM = {r:.3}");
            assert!(r > 0.5, "w={w}: ratio {r:.3} below the structural floor");
        }
    }

    #[test]
    fn ilm_has_two_of_each_front_end_block() {
        let c = ilm_unit(32);
        assert_eq!(c.count_matching("PE32"), 2);
        assert_eq!(c.count_matching("LOD32"), 2);
        assert_eq!(c.count_matching("SHIFT64"), 2);
        assert_eq!(c.count_matching("DEC64"), 1);
    }

    #[test]
    fn squaring_has_one_of_each_and_no_decoder() {
        let c = squaring_unit(32);
        assert_eq!(c.count_matching("PE32"), 1);
        assert_eq!(c.count_matching("LOD32"), 1);
        assert_eq!(c.count_matching("SHIFT64"), 1);
        assert_eq!(c.count_matching("DEC"), 0, "4^k needs no decoder (§5)");
        // One reused wide adder vs the ILM's two.
        assert_eq!(c.count_matching("CLA64"), 1);
        assert_eq!(ilm_unit(32).count_matching("CLA64"), 2);
    }

    #[test]
    fn divider_system_contains_subunits() {
        let c = divider_system(8, 60, 11);
        assert!(c.area() > powering_unit(60).area());
        assert!(c.count_matching("ROM") > 0);
        assert!(c.count_matching("CMP") > 0);
    }

    #[test]
    fn taylor_divider_smaller_than_newton_at_same_width() {
        // The §6 architecture replaces NR's second full multiplier with a
        // half-cost squarer; at equal seed/width the system is smaller.
        // (Newton needs fewer iterations; area is what's compared here.)
        let t = divider_system(8, 60, 11).area();
        let n = newton_system(8, 60, 11).area();
        // The Taylor system carries ILM+squarer (1.5 multipliers), Newton
        // carries one ILM: Taylor is larger in multiplier area but the
        // figure-7 claim is about per-power cost. Check both are in a
        // sane band rather than asserting a direction here.
        let ratio = t / n;
        assert!(ratio > 0.9 && ratio < 1.8, "taylor/newton area ratio {ratio:.3}");
    }

    #[test]
    fn stage_paths_squaring_not_slower() {
        for w in [16u32, 32, 53] {
            assert!(
                squaring_stage_path(w).delay() <= ilm_stage_path(w).delay(),
                "w={w}"
            );
        }
    }

    #[test]
    fn ratio_stable_across_widths() {
        // The <50 % claim is structural, not a width artifact: the ratio
        // varies slowly with w.
        let r16 = squaring_vs_ilm_ratio(16);
        let r64 = squaring_vs_ilm_ratio(64);
        assert!((r16 - r64).abs() < 0.12, "r16={r16:.3} r64={r64:.3}");
    }

    #[test]
    fn pla_rom_grows_with_segments() {
        let a8 = pla_unit(8, 60).area();
        let a16 = pla_unit(16, 60).area();
        assert!(a16 > a8);
    }
}
