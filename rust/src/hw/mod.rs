//! Gate-level hardware cost model.
//!
//! The paper's hardware claims (Fig 4 vs Fig 5, "< 50 % hardware" §5;
//! "little hardware overhead" §6; the pipelining remark in §7) are
//! quantified here:
//!
//! * [`components`] — NAND2-equivalent area / gate-delay catalog;
//! * [`census`] — per-unit bill of materials with area/power roll-ups
//!   and critical paths;
//! * [`units`] — the BOM of each block diagram (ILM, squaring unit,
//!   powering unit, PLA unit, full divider, Newton baseline);
//! * [`cycles`] — latency/II models including the pipelined variants.

pub mod census;
pub mod components;
pub mod cycles;
pub mod units;

pub use census::{Census, CriticalPath};
pub use components::Component;
pub use cycles::{divider_timing, ilm_timing, longdiv_timing, powering_timing, squaring_timing, Timing};
pub use units::{
    divider_system, ilm_unit, newton_system, pla_unit, powering_unit, squaring_unit,
    squaring_vs_ilm_ratio,
};
