//! # tsdiv — Taylor-series / ILM floating-point division unit
//!
//! Full-system reproduction of *"A floating point division unit based on
//! Taylor-Series expansion algorithm and Iterative Logarithmic Multiplier"*
//! (Karani, Rana, Reshamwala, Saldanha — CS.AR 2017).
//!
//! The crate is organised as the paper's hardware is:
//!
//! * [`fp`] — soft IEEE-754 formats (pack/unpack/round/classify/mul/ULP);
//! * [`ilm`] — the Iterative Logarithmic Multiplier (§4, eq 21–27, Fig 4);
//! * [`squaring`] — the reduced squaring unit (§5, eq 28, Fig 5);
//! * [`powering`] — the powering unit with operand caching (§6, Fig 6);
//! * [`pla`] — piecewise-linear initial reciprocal approximation
//!   (§3, eq 13–20, Figs 1–3, Table I);
//! * [`taylor`] — the Taylor-series reciprocal engine (§2, eq 9–12);
//! * [`divider`] — the complete FP divider (Fig 7) plus Newton–Raphson,
//!   Goldschmidt and digit-recurrence baselines;
//! * [`kernel`] — the staged structure-of-arrays batch pipeline
//!   (plan → seed → power → mul_round in fixed-width lane tiles) shared
//!   by the batch API and the service backends;
//! * [`simd`] — the explicit lane engine under the kernel's stage loops
//!   (`SimdChoice`: auto/forced/scalar; scalar-unrolled fallback plus
//!   AVX2, AVX-512 and NEON backends behind runtime detection — widest
//!   wins — all bit-identical by construction);
//! * [`hw`] — gate-level cost model reproducing the hardware claims
//!   (Fig 4 vs Fig 5, "< 50 % hardware");
//! * [`analysis`] — ULP/relative-error sweeps used by the benches;
//! * [`router`] — the adaptive backend router (per-(Op, Format,
//!   Rounding, batch-size) scoring cells seeded from bench history or
//!   a static cost model, refined online; drives `BackendChoice::Auto`);
//! * [`runtime`] — PJRT loader for the JAX/Pallas AOT artifacts;
//! * [`coordinator`] — the typed multi-format, multi-op division
//!   service (DivRequest/DivResponse with typed `fp::Op` constructors,
//!   per-(Op, Format, Rounding) dynamic batcher, worker pool, metrics);
//! * [`harness`] — workload generators and the bench runner;
//! * [`verify`] — production-scale verification: sharded exhaustive
//!   f32 conformance sweeps, the differential fuzzer behind
//!   `tsdiv fuzz`, and the in-tree mutation smoke harness;
//! * [`util`] — in-tree substrates (PRNG, JSON, CLI, stats, property
//!   testing, tables, errors) — the image vendors no general-purpose
//!   crates.

pub mod analysis;
pub mod coordinator;
pub mod divider;
pub mod fp;
pub mod harness;
pub mod hw;
pub mod ilm;
pub mod kernel;
pub mod pla;
pub mod powering;
pub mod router;
pub mod runtime;
pub mod simd;
pub mod squaring;
pub mod taylor;
pub mod util;
pub mod verify;

/// Crate version string (mirrors Cargo.toml).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");

/// Paper reference used in reports.
pub const PAPER: &str = "Karani, Rana, Reshamwala, Saldanha — \
 A floating point division unit based on Taylor-Series expansion algorithm \
 and Iterative Logarithmic Multiplier (CS.AR 2017)";
