//! Batched Goldschmidt iteration datapath over the staged SoA pipeline.
//!
//! The second first-class kernel datapath: where the Taylor kernel
//! approximates `1/b` once (seed → power → one final multiply),
//! Goldschmidt refines numerator and denominator together —
//! `N_{k+1} = N_k·F_k`, `D_{k+1} = D_k·F_k` with `F_k = 2 − D_k` —
//! converging quadratically from the same PLA seed. The per-lane
//! reference is [`crate::divider::goldschmidt::GoldschmidtDivider`];
//! this module runs the identical arithmetic one *stage* at a time over
//! dense SoA lanes, reusing the Taylor kernel's plan stage, its
//! [`KernelScratch`] buffers, and the [`crate::simd::Engine`]
//! wide-multiply ops, so both datapaths share one staged machinery:
//!
//! ```text
//!   a[], b[] ──► plan ──► seed ──► iterate ──► round ──► out[]
//!               │ (shared │ PLA     │ k × {F = 2−D,  │ round_pack,
//!               │  with    │ lookup  │      N ←N·F≫f, │ sticky set
//!               │  Taylor  │ → y0    │      D ←D·F≫f} │ (inexact by
//!               │  kernel) │         │ per tile       │  construction)
//! ```
//!
//! The iterate stage optionally models the hardware-reduction trick of
//! truncated-multiplier Goldschmidt dividers (arxiv 1909.10154): with
//! `trunc_bits = t > 0` every intermediate product keeps only its top
//! `f − t` fraction bits (the low `t` bits of the Q2.F word are
//! zeroed), emulating a reduced-width multiplier array. Each truncation
//! loses `< 2^(t−f)` of relative precision, so a `k`-iteration divide
//! stays within `(2k + 2)·2^(t−f)` relative of the full-width quotient
//! — under 1 result ulp while `t ≤ f − fmt.frac_bits − log2(2k+2) − 1`.
//! At the default `t = 0` the datapath is **bit-identical** to the
//! scalar `GoldschmidtDivider`, pinned by the tests below.

use super::{stages, KernelScratch};
use crate::bail;
use crate::fp::{round_pack, Format, Op, Rounding};
use crate::pla::SegmentTable;
use crate::simd::Engine;
use crate::util::error::Result;

/// Most correction iterations a config may request: convergence is
/// quadratic, so anything past ~6 only re-truncates; 32 bounds the
/// damage of a typo'd knob without constraining real use.
pub const MAX_GOLDSCHMIDT_ITERATIONS: u32 = 32;

/// The batched Goldschmidt datapath: seed table + iteration count +
/// optional reduced-width intermediate products, run over the staged
/// SoA pipeline of [`super`].
#[derive(Clone, Debug)]
pub struct GoldschmidtKernel {
    /// Correction iterations `k` (3 reaches 53-bit precision from the
    /// paper's 8-segment seed).
    pub iterations: u32,
    /// Low fraction bits zeroed from every intermediate product
    /// (truncated-multiplier emulation; 0 = full width, bit-identical
    /// to the scalar divider).
    pub trunc_bits: u32,
    /// Q2.F datapath fraction bits (matches `table.frac_bits`).
    pub frac_bits: u32,
    /// PLA reciprocal seed table (shared derivation with the Taylor
    /// datapath).
    pub table: SegmentTable,
}

impl GoldschmidtKernel {
    /// Same seed and datapath width as the scalar
    /// `GoldschmidtDivider::paper_default()`: Table-I segments, Q2.60,
    /// full-width multiplies.
    pub fn paper_default(iterations: u32) -> Result<Self> {
        let bounds = crate::pla::derive_segments(5, 53)?;
        let kernel = Self {
            iterations,
            trunc_bits: 0,
            frac_bits: 60,
            table: SegmentTable::build(&bounds, 60),
        };
        kernel.validate()?;
        Ok(kernel)
    }

    /// Reject configurations that could only fail (or silently produce
    /// garbage) inside a worker thread. Field-specific messages — the
    /// service surfaces these verbatim at start().
    pub fn validate(&self) -> Result<()> {
        if self.iterations == 0 || self.iterations > MAX_GOLDSCHMIDT_ITERATIONS {
            bail!(
                "goldschmidt config: iterations must be 1..={MAX_GOLDSCHMIDT_ITERATIONS}, got {}",
                self.iterations
            );
        }
        if self.trunc_bits > self.frac_bits / 2 {
            bail!(
                "goldschmidt config: trunc_bits of {} exceeds half the Q2.{} datapath",
                self.trunc_bits,
                self.frac_bits
            );
        }
        if self.table.frac_bits != self.frac_bits {
            bail!(
                "goldschmidt config: seed table is Q2.{}, datapath is Q2.{}",
                self.table.frac_bits,
                self.frac_bits
            );
        }
        Ok(())
    }

    /// Run the staged Goldschmidt pipeline over one batch:
    /// `out[i] = a[i] / b[i]`, all slices the same length, bit patterns
    /// of `fmt`, rounded under `rm`. Specials resolve in the shared plan
    /// stage (bit-identical to every other datapath); dense lanes run
    /// the iterate stage tile by tile on the lane engine `eng`.
    ///
    /// With `trunc_bits == 0` this is bit-identical to calling the
    /// scalar `GoldschmidtDivider::div_bits` per lane with the same
    /// `iterations` and table.
    #[allow(clippy::too_many_arguments)]
    pub fn divide_batch(
        &self,
        scratch: &mut KernelScratch,
        tile: usize,
        eng: Engine,
        a: &[u64],
        b: &[u64],
        fmt: Format,
        rm: Rounding,
        out: &mut [u64],
    ) {
        self.compute_batch(scratch, tile, eng, Op::Div, a, b, &[], fmt, rm, out)
    }

    /// Run the staged Goldschmidt pipeline for any [`Op`], mirroring
    /// [`super::compute_batch`]'s operand contract per op:
    ///
    /// * `Div` — `out[i] = a[i]/b[i]`; `rows` empty. The N/D chain as
    ///   documented on [`Self::divide_batch`].
    /// * `Recip` — `out[i] = 1/a[i]`; `b` and `rows` empty. The plan
    ///   stage substitutes a literal `1.0` dividend, which makes
    ///   `a_q = 1 << f` and hence `N₀ = y₀` exactly — the chain is
    ///   **bit-identical** to `Div(1.0, a[i])`.
    /// * `Rsqrt` — `out[i] = 1/sqrt(a[i])`; `b` and `rows` empty. The
    ///   chain runs dividend-free (`N` converges to `1/x`), then the
    ///   shared Newton tail ([`stages::rsqrt_newton`]) and parity-fixup
    ///   rounding ([`stages::rsqrt_round`]) finish — the same tail the
    ///   Taylor datapath uses, so both land in the same ulp band of the
    ///   exact reference.
    /// * `ScaleByRecip` — `a` is `rows.len()` concatenated rows of
    ///   `rows[r]` lanes each, `b[r]` the row's divisor: one reciprocal
    ///   per *distinct* divisor run (planned lanes of a row share their
    ///   `x`, and the iterate stage dedupes consecutive equal values),
    ///   broadcast-multiplied across the row by [`stages::mul_round`]
    ///   with sticky set. Not bit-identical to `Div` on expanded
    ///   divisors — the reciprocal is truncated to Q2.F before the
    ///   final multiply — but inside the same documented band.
    ///
    /// `trunc_bits` applies to the iterate stage of every op; the
    /// Newton tail always runs full width.
    #[allow(clippy::too_many_arguments)]
    pub fn compute_batch(
        &self,
        scratch: &mut KernelScratch,
        tile: usize,
        eng: Engine,
        op: Op,
        a: &[u64],
        b: &[u64],
        rows: &[u32],
        fmt: Format,
        rm: Rounding,
        out: &mut [u64],
    ) {
        match op {
            Op::Div => {
                assert_eq!(a.len(), b.len(), "operand length mismatch");
                assert!(rows.is_empty(), "rows is a ScaleByRecip-only input");
            }
            Op::Recip | Op::Rsqrt => {
                assert!(b.is_empty(), "unary ops take no divisor operand");
                assert!(rows.is_empty(), "rows is a ScaleByRecip-only input");
            }
            Op::ScaleByRecip => {
                assert_eq!(b.len(), rows.len(), "one divisor per row");
                assert_eq!(
                    rows.iter().map(|&r| r as usize).sum::<usize>(),
                    a.len(),
                    "row lane counts must cover the dividend lanes"
                );
            }
        }
        assert_eq!(a.len(), out.len(), "output length mismatch");
        assert!(
            self.frac_bits >= fmt.frac_bits,
            "datapath narrower than format significand"
        );
        assert!(tile >= 1, "kernel tile must be ≥ 1 lane");
        let f = self.frac_bits;
        let shift = f - fmt.frac_bits;
        let two = 2u64 << f;
        // keep-mask of the truncated-multiplier mode; all-ones (a no-op
        // AND) at full width.
        let keep = if self.trunc_bits == 0 {
            u64::MAX
        } else {
            !((1u64 << self.trunc_bits) - 1)
        };

        let KernelScratch {
            plan,
            edge_cache,
            miss_pos,
            miss_x,
            y0,
            m,
            pow,
            sum,
            recip,
            nr_z,
            nr_t,
            nr_u,
            ..
        } = scratch;

        // Stage the PLA edge table once per call (see KernelScratch).
        if !edge_cache.matches(&self.table.edges) {
            edge_cache.rebuild(&self.table.edges);
        }

        // Stage 1 — plan: shared with the Taylor kernel. Specials go to
        // the output sidechannel; dense lanes carry sig_a raw and
        // x = sig_b << shift (Q2.F) — for Rsqrt, the parity flag and
        // the *input* significand (see `stages::plan_rsqrt`).
        match op {
            Op::Div => stages::plan(a, b, fmt, shift, plan, out),
            Op::Recip => stages::plan_recip(a, fmt, shift, plan, out),
            Op::Rsqrt => stages::plan_rsqrt(a, fmt, shift, plan, out),
            Op::ScaleByRecip => stages::plan_scale(a, b, rows, fmt, shift, plan, out),
        }
        let n = plan.lanes();

        match op {
            Op::Div | Op::Recip => {
                // Stages 2–3 — seed + iterate, tile by tile. Unlike the
                // Taylor kernel there is no divisor-reciprocal cache:
                // each lane's refinement couples numerator and
                // denominator, so nothing divisor-only is reusable
                // across lanes.
                let mut t0 = 0;
                while t0 < n {
                    let t1 = (t0 + tile).min(n);
                    let x = &plan.x[t0..t1];
                    let k = x.len();
                    // y0 ≈ 1/x per lane from the PLA seed (identical
                    // lookup to the scalar divider's `table.seed`).
                    stages::seed(eng, &self.table, edge_cache, x, y0);
                    // The dividend significand mapped into Q2.F: a_q =
                    // sig_a << shift (the scalar path's `a`; `1 << f`
                    // for Recip). Staged into `miss_x`, unused by this
                    // pipeline's other stages.
                    miss_x.clear();
                    miss_x.extend(plan.sig_a[t0..t1].iter().map(|&s| s << shift));
                    // N0 = (a_q·y0) ≫ f into `recip`; D0 = (x·y0) ≫ f
                    // into `sum` (buffer reuse — the names belong to
                    // the Taylor stages, the roles here are N and D).
                    recip.clear();
                    recip.resize(k, 0);
                    sum.clear();
                    sum.resize(k, 0);
                    eng.mul_shr(miss_x, y0, f, recip);
                    eng.mul_shr(x, y0, f, sum);
                    m.clear();
                    m.resize(k, 0);
                    pow.clear();
                    pow.resize(k, 0);
                    iterate(eng, self.iterations, two, f, keep, recip, sum, m, pow);
                    // Stage 4 — round: N is the quotient in (0.5, 2)
                    // Q2.F. Sticky is SET (the iteration truncates
                    // continuously), the scalar divider's exact
                    // rounding call.
                    for (j, &q) in recip.iter().enumerate() {
                        let lane = t0 + j;
                        out[plan.idx[lane] as usize] =
                            round_pack(plan.sign[lane], plan.exp[lane], q as u128, f, true, fmt, rm)
                                .0;
                    }
                    t0 = t1;
                }
            }
            Op::Rsqrt | Op::ScaleByRecip => {
                // Dividend-free reciprocal chain: a_q = 1 << f, so
                // N0 = ((1 << f)·y0) ≫ f = y0 exactly and no N0
                // multiply is spent; N converges to 1/x. Consecutive
                // lanes with equal x (a ScaleByRecip row, possibly
                // split across tiles) collapse to one chain lane —
                // the "one reciprocal per row" of the fused op.
                plan.recip.clear();
                plan.recip.resize(n, 0);
                let mut t0 = 0;
                while t0 < n {
                    let t1 = (t0 + tile).min(n);
                    let x = &plan.x[t0..t1];
                    // Run-length dedupe into miss_pos (run start, tile-
                    // relative) / miss_x (the run's divisor value).
                    miss_pos.clear();
                    miss_x.clear();
                    for (j, &xi) in x.iter().enumerate() {
                        if miss_x.last() != Some(&xi) {
                            miss_pos.push(j as u32);
                            miss_x.push(xi);
                        }
                    }
                    let k = miss_x.len();
                    stages::seed(eng, &self.table, edge_cache, miss_x, y0);
                    recip.clear();
                    recip.extend_from_slice(y0);
                    sum.clear();
                    sum.resize(k, 0);
                    eng.mul_shr(miss_x, y0, f, sum);
                    m.clear();
                    m.resize(k, 0);
                    pow.clear();
                    pow.resize(k, 0);
                    iterate(eng, self.iterations, two, f, keep, recip, sum, m, pow);
                    // Broadcast each run's reciprocal across its lanes.
                    for (ri, &p) in miss_pos.iter().enumerate() {
                        let start = p as usize;
                        let end = miss_pos
                            .get(ri + 1)
                            .map_or(x.len(), |&q| q as usize);
                        for slot in &mut plan.recip[t0 + start..t0 + end] {
                            *slot = recip[ri];
                        }
                    }
                    t0 = t1;
                }
                if op == Op::Rsqrt {
                    // Shared Newton tail over the same tiles, full
                    // width (truncation only models the iterate-stage
                    // multiplier array).
                    let mut t0 = 0;
                    while t0 < n {
                        let t1 = (t0 + tile).min(n);
                        stages::rsqrt_newton(
                            eng,
                            f,
                            &plan.x[t0..t1],
                            &plan.recip[t0..t1],
                            nr_z,
                            nr_t,
                            nr_u,
                        );
                        plan.recip[t0..t1].copy_from_slice(nr_z);
                        t0 = t1;
                    }
                    stages::rsqrt_round(plan, fmt, rm, f, out);
                } else {
                    // Fused tail: q = sig_a·recip, sticky set — the
                    // datapath's continuous-truncation contract.
                    stages::mul_round(plan, fmt, rm, f, true, out);
                }
            }
        }
    }
}

/// The Goldschmidt refinement loop: k × { F = 2 − D (saturating, as the
/// scalar path); N ← (N·F) ≫ f; D ← (D·F) ≫ f — independent multiplies,
/// the pipelinability argument of the algorithm }, with the optional
/// truncated-multiplier keep-mask applied to both products. `n`/`d` are
/// N and D in Q2.F; `m`/`pow` are same-length scratch.
#[allow(clippy::too_many_arguments)]
fn iterate(
    eng: Engine,
    iterations: u32,
    two: u64,
    f: u32,
    keep: u64,
    n: &mut Vec<u64>,
    d: &mut Vec<u64>,
    m: &mut Vec<u64>,
    pow: &mut Vec<u64>,
) {
    for _ in 0..iterations {
        m.copy_from_slice(d);
        eng.rsub_sat(two, m);
        eng.mul_shr(n, m, f, pow);
        std::mem::swap(n, pow);
        eng.mul_shr(d, m, f, pow);
        std::mem::swap(d, pow);
        if keep != u64::MAX {
            // Truncated-multiplier emulation: drop the low trunc_bits
            // of both intermediate products.
            for v in n.iter_mut() {
                *v &= keep;
            }
            for v in d.iter_mut() {
                *v &= keep;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::divider::goldschmidt::GoldschmidtDivider;
    use crate::divider::Divider;
    use crate::fp::{ulp_diff, ALL_FORMATS, F32};
    use crate::harness::{gen_bits_batch, special_patterns};

    fn batch_divide(
        kernel: &GoldschmidtKernel,
        tile: usize,
        eng: Engine,
        a: &[u64],
        b: &[u64],
        fmt: Format,
        rm: Rounding,
    ) -> Vec<u64> {
        let mut scratch = KernelScratch::new();
        let mut out = vec![0u64; a.len()];
        kernel.divide_batch(&mut scratch, tile, eng, a, b, fmt, rm, &mut out);
        out
    }

    #[allow(clippy::too_many_arguments)]
    fn batch_compute(
        kernel: &GoldschmidtKernel,
        tile: usize,
        eng: Engine,
        op: Op,
        a: &[u64],
        b: &[u64],
        rows: &[u32],
        fmt: Format,
        rm: Rounding,
    ) -> Vec<u64> {
        let mut scratch = KernelScratch::new();
        let mut out = vec![0u64; a.len()];
        kernel.compute_batch(&mut scratch, tile, eng, op, a, b, rows, fmt, rm, &mut out);
        out
    }

    /// Random lanes with specials sprinkled in, like the kernel suite.
    fn operands(fmt: Format, n: usize, seed: u64) -> (Vec<u64>, Vec<u64>) {
        let (mut a, mut b) = gen_bits_batch(fmt, n, 8, seed);
        for (i, &s) in special_patterns(fmt).iter().enumerate() {
            if i * 2 + 1 < n {
                a[i * 2] = s;
                b[i * 2 + 1] = s;
            }
        }
        (a, b)
    }

    #[test]
    fn bit_identical_to_scalar_goldschmidt_all_formats_and_roundings() {
        let kernel = GoldschmidtKernel::paper_default(3).unwrap();
        for (fi, fmt) in ALL_FORMATS.into_iter().enumerate() {
            for rm in Rounding::ALL {
                let (a, b) = operands(fmt, 67, (fi as u64) << 4 | 5);
                let mut scalar = GoldschmidtDivider::paper_default();
                let want: Vec<u64> = (0..a.len())
                    .map(|i| scalar.div_bits(a[i], b[i], fmt, rm))
                    .collect();
                for tile in [1usize, 3, 8, 67, 200] {
                    for eng in crate::simd::engines_available() {
                        let got = batch_divide(&kernel, tile, eng, &a, &b, fmt, rm);
                        assert_eq!(
                            got,
                            want,
                            "{} {rm:?} tile={tile} {}",
                            fmt.name(),
                            eng.name()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn recip_is_bit_identical_to_div_by_one_every_engine() {
        // The plan stage substitutes a literal 1.0 dividend, making
        // a_q = 1 << f and the chain exactly Div(1.0, x) — including
        // specials (1/NaN, 1/0, 1/Inf) through the shared prepare table.
        let kernel = GoldschmidtKernel::paper_default(3).unwrap();
        for (fi, fmt) in ALL_FORMATS.into_iter().enumerate() {
            for rm in Rounding::ALL {
                let (mut xs, _) = gen_bits_batch(fmt, 53, 8, 0xA1 + fi as u64);
                for (i, &s) in special_patterns(fmt).iter().enumerate() {
                    xs[i] = s;
                }
                let ones = vec![fmt.one(); xs.len()];
                let want = batch_divide(&kernel, 7, Engine::Scalar, &ones, &xs, fmt, rm);
                for eng in crate::simd::engines_available() {
                    let got = batch_compute(&kernel, 7, eng, Op::Recip, &xs, &[], &[], fmt, rm);
                    assert_eq!(got, want, "{} {rm:?} {}", fmt.name(), eng.name());
                }
            }
        }
    }

    #[test]
    fn scale_by_recip_preserves_lane_order_and_stays_in_band_of_gold() {
        // Ragged rows (not tile multiples), a NaN divisor row and a
        // signed-zero divisor row in the middle: every lane must land at
        // its own index with the row's divisor applied. Finite lanes sit
        // in the documented band of the exactly-rounded reference; the
        // fused tail truncates the reciprocal before the broadcast
        // multiply, so it is a band, not bit-identity.
        use crate::divider::longdiv::LongDivider;
        let kernel = GoldschmidtKernel::paper_default(3).unwrap();
        let rows: Vec<u32> = vec![1, 5, 13, 2, 31, 1, 7];
        let lanes: usize = rows.iter().map(|&r| r as usize).sum();
        for (fi, fmt) in ALL_FORMATS.into_iter().enumerate() {
            let band = if fmt.frac_bits > 23 { 2 } else { 1 };
            for rm in Rounding::ALL {
                let (a, _) = gen_bits_batch(fmt, lanes, 6, 0xB2 + fi as u64);
                let (mut b, _) = gen_bits_batch(fmt, rows.len(), 6, 0xC3 + fi as u64);
                b[3] = fmt.nan();
                b[5] = fmt.zero(true);
                let mut gold = LongDivider::new();
                let mut want = Vec::with_capacity(lanes);
                let mut i = 0;
                for (r, &len) in rows.iter().enumerate() {
                    for _ in 0..len {
                        want.push(gold.div_bits(a[i], b[r], fmt, rm));
                        i += 1;
                    }
                }
                for tile in [1usize, 4, 8] {
                    for eng in crate::simd::engines_available() {
                        let got = batch_compute(
                            &kernel,
                            tile,
                            eng,
                            Op::ScaleByRecip,
                            &a,
                            &b,
                            &rows,
                            fmt,
                            rm,
                        );
                        for (i, (&g, &w)) in got.iter().zip(&want).enumerate() {
                            match ulp_diff(g, w, fmt) {
                                Some(u) => assert!(
                                    u <= band,
                                    "lane {i} {} {rm:?} tile={tile} {}: {u} ulp from gold",
                                    fmt.name(),
                                    eng.name()
                                ),
                                None => assert_eq!(
                                    g,
                                    w,
                                    "lane {i} {} {rm:?} tile={tile} {}: NaN class",
                                    fmt.name(),
                                    eng.name()
                                ),
                            }
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn rsqrt_specials_bit_identical_and_finite_in_band_vs_gold() {
        // Specials resolve in plan_rsqrt exactly as LongDivider's table
        // (rsqrt(±0) = ±Inf, rsqrt(neg) = NaN, rsqrt(Inf) = 0); finite
        // positive lanes run chain → Newton → parity rounding and stay
        // inside the same band as the Taylor rsqrt tail.
        use crate::divider::longdiv::LongDivider;
        let kernel = GoldschmidtKernel::paper_default(3).unwrap();
        for (fi, fmt) in ALL_FORMATS.into_iter().enumerate() {
            let band = if fmt.frac_bits > 23 { 2 } else { 1 };
            for rm in Rounding::ALL {
                let (mut xs, _) = gen_bits_batch(fmt, 80, 8, 0xD4 + fi as u64);
                for x in xs.iter_mut() {
                    *x &= !fmt.sign_mask(); // rsqrt wants positive lanes
                }
                for (i, &s) in special_patterns(fmt).iter().enumerate() {
                    xs[i] = s;
                }
                xs[10] = fmt.assemble(true, fmt.bias() as u64, 1); // negative → NaN
                let mut gold = LongDivider::new();
                let want: Vec<u64> = xs.iter().map(|&x| gold.rsqrt_bits(x, fmt, rm)).collect();
                for tile in [1usize, 8, 67] {
                    for eng in crate::simd::engines_available() {
                        let got =
                            batch_compute(&kernel, tile, eng, Op::Rsqrt, &xs, &[], &[], fmt, rm);
                        for (i, (&g, &w)) in got.iter().zip(&want).enumerate() {
                            match ulp_diff(g, w, fmt) {
                                Some(u) => assert!(
                                    u <= band,
                                    "lane {i} {} {rm:?} tile={tile} {}: {u} ulp from gold",
                                    fmt.name(),
                                    eng.name()
                                ),
                                None => assert_eq!(
                                    g,
                                    w,
                                    "lane {i} {} {rm:?} tile={tile} {}: NaN class",
                                    fmt.name(),
                                    eng.name()
                                ),
                            }
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn iteration_count_rides_through_to_the_scalar_oracle() {
        // Any iteration count, not just the paper default, stays
        // bit-identical — the iterate stage is the same loop.
        let bounds = crate::pla::derive_segments(5, 53).unwrap();
        for k in [1u32, 2, 4] {
            let kernel = GoldschmidtKernel {
                iterations: k,
                trunc_bits: 0,
                frac_bits: 60,
                table: SegmentTable::build(&bounds, 60),
            };
            let mut scalar = GoldschmidtDivider::new(k, 60, SegmentTable::build(&bounds, 60));
            let (a, b) = operands(F32, 41, 7 + k as u64);
            let want: Vec<u64> = (0..a.len())
                .map(|i| scalar.div_bits(a[i], b[i], F32, Rounding::NearestEven))
                .collect();
            let got = batch_divide(&kernel, 8, Engine::Scalar, &a, &b, F32, Rounding::NearestEven);
            assert_eq!(got, want, "k={k}");
        }
    }

    #[test]
    fn truncated_multiplier_mode_stays_inside_documented_band() {
        // t = 16 at f = 60 against f32 (frac 23): the bound in the
        // module docs gives (2·3+2)·2^(16−60) = 2^(−41) relative —
        // far under half an ulp (2^(−24)), so results stay within 1 ulp
        // of the full-width datapath, and most lanes are identical.
        let full = GoldschmidtKernel::paper_default(3).unwrap();
        let trunc = GoldschmidtKernel {
            trunc_bits: 16,
            ..full.clone()
        };
        trunc.validate().unwrap();
        let (a, b) = operands(F32, 97, 99);
        for rm in Rounding::ALL {
            let qf = batch_divide(&full, 8, Engine::Scalar, &a, &b, F32, rm);
            let qt = batch_divide(&trunc, 8, Engine::Scalar, &a, &b, F32, rm);
            for i in 0..a.len() {
                match ulp_diff(qt[i], qf[i], F32) {
                    Some(u) => assert!(u <= 1, "lane {i} ({rm:?}): {u} ulp from full width"),
                    None => assert_eq!(qt[i], qf[i], "lane {i} ({rm:?}): NaN class changed"),
                }
            }
        }
    }

    #[test]
    fn specials_resolved_bit_identical_to_prepare() {
        // Special lanes never reach the iterate stage; they resolve in
        // the shared plan stage exactly as every other datapath does.
        let kernel = GoldschmidtKernel::paper_default(3).unwrap();
        let a: Vec<u64> = [f32::NAN, 1.0, 0.0, f32::INFINITY, -1.0, 0.0]
            .iter()
            .map(|x| x.to_bits() as u64)
            .collect();
        let b: Vec<u64> = [1.0f32, 0.0, 0.0, 2.0, f32::INFINITY, 5.0]
            .iter()
            .map(|x| x.to_bits() as u64)
            .collect();
        let got = batch_divide(&kernel, 8, Engine::Scalar, &a, &b, F32, Rounding::NearestEven);
        let mut scalar = GoldschmidtDivider::paper_default();
        for i in 0..a.len() {
            assert_eq!(
                got[i],
                scalar.div_bits(a[i], b[i], F32, Rounding::NearestEven),
                "lane {i}"
            );
        }
    }

    #[test]
    fn validate_rejects_bad_fields_by_name() {
        let good = GoldschmidtKernel::paper_default(3).unwrap();
        assert!(good.validate().is_ok());
        let e = GoldschmidtKernel {
            iterations: 0,
            ..good.clone()
        }
        .validate()
        .unwrap_err()
        .to_string();
        assert!(e.contains("iterations"), "{e}");
        let e = GoldschmidtKernel {
            iterations: MAX_GOLDSCHMIDT_ITERATIONS + 1,
            ..good.clone()
        }
        .validate()
        .unwrap_err()
        .to_string();
        assert!(e.contains("iterations"), "{e}");
        let e = GoldschmidtKernel {
            trunc_bits: 31,
            ..good.clone()
        }
        .validate()
        .unwrap_err()
        .to_string();
        assert!(e.contains("trunc_bits"), "{e}");
    }

    #[test]
    fn scratch_reuse_across_calls_and_datapaths_bit_exact() {
        // One scratch serving a Taylor divide_batch and then a
        // Goldschmidt divide_batch (and back) must not leak state.
        use crate::powering::ExactMul;
        use crate::taylor::TaylorConfig;
        let cfg = TaylorConfig::paper_default(60);
        let kernel = GoldschmidtKernel::paper_default(3).unwrap();
        let (a, b) = gen_bits_batch(F32, 29, 7, 1234);
        let rm = Rounding::NearestEven;
        let want_gs = batch_divide(&kernel, 8, Engine::Scalar, &a, &b, F32, rm);
        let mut scratch = KernelScratch::new();
        let mut be = ExactMul::default();
        let mut out_taylor = vec![0u64; a.len()];
        super::super::divide_batch(
            &cfg,
            &mut be,
            &mut scratch,
            8,
            Engine::Scalar,
            &a,
            &b,
            F32,
            rm,
            &mut out_taylor,
        );
        let mut out_gs = vec![0u64; a.len()];
        kernel.divide_batch(&mut scratch, 8, Engine::Scalar, &a, &b, F32, rm, &mut out_gs);
        assert_eq!(out_gs, want_gs, "goldschmidt after taylor through one scratch");
        let mut out_taylor2 = vec![0u64; a.len()];
        super::super::divide_batch(
            &cfg,
            &mut be,
            &mut scratch,
            8,
            Engine::Scalar,
            &a,
            &b,
            F32,
            rm,
            &mut out_taylor2,
        );
        assert_eq!(out_taylor2, out_taylor, "taylor after goldschmidt through one scratch");
    }
}
