//! The staged structure-of-arrays division kernel — one datapath for
//! every batch entry point.
//!
//! The paper's divider is a staged hardware pipeline (Fig 7): operand
//! unpack, piecewise-linear reciprocal seed, Taylor powering on the
//! ILM/squaring units, final multiply, round. Before this module the
//! software model executed that pipeline one lane at a time inside
//! `TaylorDivider::div_bits_batch`; here each stage instead runs over
//! whole lane arrays in fixed-width tiles, and the stage loops execute
//! on an **explicit lane engine** ([`crate::simd`]: AVX-512, AVX2 or
//! NEON when selected — widest detected wins — and a scalar-unrolled
//! fallback otherwise; `KernelConfig::simd` picks),
//! so the lane parallelism is guaranteed, not an autovectorization hope:
//!
//! ```text
//!   a[], b[] ──► plan ──► seed ──► power ──► mul_round ──► out[]
//!               │ unpack per     │ PLA       │ m = 1−x·y0, │ q = sig_a·recip,
//!               │ Format,        │ segment   │ m²…m^n via  │ Rounding-aware
//!               │ specials to    │ lookup    │ odd/even    │ round_pack
//!               │ a sidechannel  │ → y0      │ schedule,   │
//!               │ (resolved      │ per tile  │ recip=y0·S  │
//!               │  immediately)  │           │ per tile    │
//! ```
//!
//! The same staged implementation serves
//!
//! * the batch API — [`crate::divider::TaylorDivider`]'s
//!   `div_bits_batch` delegates here;
//! * the service backend — `BackendChoice::Kernel`
//!   ([`crate::coordinator::KernelBackend`]) drives it directly with a
//!   configurable tile width;
//! * and, transitively, `BackendChoice::Native`, whose divisor-grouping
//!   wrapper feeds the same `div_bits_batch`.
//!
//! A second datapath shares the machinery: [`goldschmidt::GoldschmidtKernel`]
//! (`BackendChoice::Goldschmidt`) reuses the plan stage, this scratch,
//! and the lane engine, swapping the seed→power→mul_round middle for a
//! Goldschmidt iterate stage.
//!
//! Numerics are **bit-identical** to the scalar `div_bits` path
//! ([`crate::taylor::reciprocal_fast`] + `round_pack`): every per-lane
//! operation and its order are preserved, only the loop nesting changes
//! (per-stage over lanes instead of per-lane over stages). A property
//! test pins this across all formats, rounding modes, specials and
//! subnormals.

pub mod goldschmidt;
pub mod stages;

pub use goldschmidt::GoldschmidtKernel;

use crate::bail;
use crate::fp::{Format, Op, Rounding};
use crate::powering::Multiplier;
use crate::simd::{Engine, SimdChoice};
use crate::taylor::TaylorConfig;
use crate::util::error::{Context as _, Result};

/// Default lane-tile width of the staged pipeline. Eight lanes keeps the
/// whole working set (x, y0, m, powers, sum) inside L1 while giving the
/// stage loops enough width to vectorize.
pub const DEFAULT_TILE: usize = 8;

/// Ways in the kernel's divisor-reciprocal cache. Direct-mapped by a
/// multiplicative hash of the divisor significand: service batches carry
/// a handful of distinct divisors (k-means centroid counts, a few
/// normalization constants), and 8 ways hold them all simultaneously —
/// the coordinator's `NativeBackend` additionally groups lanes by
/// divisor so even colliding divisors arrive in runs and thrash at most
/// once per run.
pub const RECIP_CACHE_WAYS: usize = 8;

/// Take the top `log2(ways)` bits of the mixed key as the way index.
const RECIP_CACHE_SHIFT: u32 = 64 - RECIP_CACHE_WAYS.trailing_zeros();
// ≥ 2 also keeps RECIP_CACHE_SHIFT < 64 (a 64-bit shift would panic).
const _: () = assert!(RECIP_CACHE_WAYS.is_power_of_two() && RECIP_CACHE_WAYS >= 2);

/// Fibonacci-hash a divisor significand into a cache way (the low bits
/// of x are the least-varying across a format's divisors once shifted,
/// so mix the whole word).
#[inline]
pub(crate) fn cache_way(x: u64) -> usize {
    (x.wrapping_mul(0x9E37_79B9_7F4A_7C15) >> RECIP_CACHE_SHIFT) as usize
}

/// Configuration of the staged kernel, threaded from the CLI through the
/// service into each worker's backend.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KernelConfig {
    /// Lanes per pipeline tile (≥ 1). [`DEFAULT_TILE`] unless tuned.
    pub tile: usize,
    /// ILM correction budget of the multiplier backend
    /// (`None` = exact multiplies).
    pub ilm_iterations: Option<u32>,
    /// Lane engine under the stage loops ([`crate::simd`]): auto-detect,
    /// force the vector engine (error on unsupported hosts), or pin the
    /// scalar fallback (the autovectorization baseline).
    pub simd: SimdChoice,
}

impl Default for KernelConfig {
    fn default() -> Self {
        Self {
            tile: DEFAULT_TILE,
            ilm_iterations: None,
            simd: SimdChoice::Auto,
        }
    }
}

impl KernelConfig {
    /// Reject configurations that could only fail later inside a worker
    /// thread (mirrors `ServiceConfig::validate`). A `Forced` SIMD
    /// choice on a host without a vector engine is rejected here (the
    /// error names the missing features for this architecture), so a
    /// misdeployed service fails its start call instead of its first
    /// batch.
    pub fn validate(&self) -> Result<()> {
        if self.tile == 0 {
            bail!("kernel config: tile must be ≥ 1 lane");
        }
        if self.tile > 1 << 20 {
            bail!("kernel config: tile of {} lanes exceeds any batch", self.tile);
        }
        self.simd.validate().context("kernel config: simd")
    }
}

/// Dense structure-of-arrays view of a batch's real-division lanes,
/// produced by the plan stage. Special lanes (NaN/Inf/zero rules) never
/// enter these arrays — they are resolved into the output during
/// planning, which is what keeps every later stage loop branch-light.
#[derive(Clone, Debug, Default)]
pub struct LanePlan {
    /// Original batch position of each dense lane (scatter index).
    pub idx: Vec<u32>,
    /// Result sign per lane.
    pub sign: Vec<bool>,
    /// Unbiased result exponent before normalization.
    pub exp: Vec<i32>,
    /// Dividend significand, hidden bit at `fmt.frac_bits`.
    pub sig_a: Vec<u64>,
    /// Divisor significand mapped into the Q2.F datapath, `[1, 2)`.
    pub x: Vec<u64>,
    /// Reciprocal of `x` in Q2.F, filled by the seed/power stages (or
    /// the divisor cache).
    pub recip: Vec<u64>,
}

impl LanePlan {
    fn clear(&mut self) {
        self.idx.clear();
        self.sign.clear();
        self.exp.clear();
        self.sig_a.clear();
        self.x.clear();
        self.recip.clear();
    }

    /// Dense (non-special) lane count.
    pub fn lanes(&self) -> usize {
        self.idx.len()
    }
}

/// Reusable buffers of the staged pipeline: the dense lane plan, the
/// per-tile compute staging (cache misses compacted), and the divisor
/// reciprocal cache. Capacity warms up to the largest batch and tile
/// seen and stays there — no steady-state allocation.
#[derive(Clone, Debug, Default)]
pub struct KernelScratch {
    /// Plan-stage output (dense SoA lanes).
    pub plan: LanePlan,
    // The PLA edge table staged for the seed stage's compare pass,
    // built once per `divide_batch` call (and reused across calls while
    // the table is unchanged) instead of re-biased inside every
    // `segment_counts` call — with the default 8-lane tile that setup
    // rivaled the compare work itself (ROADMAP item e). Pure
    // re-encoding of the edges: bit-identical on every engine.
    edge_cache: crate::simd::BiasedEdges,
    // Tile staging: positions (into `plan`) and operands of the lanes
    // whose reciprocal missed the cache this tile.
    miss_pos: Vec<u32>,
    miss_x: Vec<u64>,
    // Seed / powering staging over the miss lanes. The accumulator is
    // u64 with wrapping lane adds — bit-identical to the scalar path's
    // u128-then-truncate (see [`stages::power`]).
    y0: Vec<u64>,
    m: Vec<u64>,
    pow: Vec<u64>,
    sum: Vec<u64>,
    recip: Vec<u64>,
    // Newton staging of the rsqrt tail (z, z², 3 − x·z² per tile) —
    // untouched by the other ops.
    nr_z: Vec<u64>,
    nr_t: Vec<u64>,
    nr_u: Vec<u64>,
    // The divisor-reciprocal cache. x ≥ 1.0 in Q2.F, so the zero reset
    // keys can never collide with a real divisor. Reset at the start of
    // every `divide_batch` call: the reciprocal depends on the Taylor
    // config and multiplier backend as well as the significand, and the
    // same scratch may legally serve different (cfg, backend) pairs —
    // within one call both are fixed, so within-batch reuse is bit-exact.
    cache_x: [u64; RECIP_CACHE_WAYS],
    cache_r: [u64; RECIP_CACHE_WAYS],
}

impl KernelScratch {
    pub fn new() -> Self {
        Self::default()
    }
}

/// Run the staged pipeline over one batch of the given operation, bit
/// patterns of `fmt`, rounded under `rm`, with the stage loops driven by
/// the lane engine `eng`.
///
/// Operand shapes per op:
/// * [`Op::Div`] — `a`/`b`/`out` the same length, `rows` empty;
/// * [`Op::Recip`] / [`Op::Rsqrt`] — one operand: `b` and `rows` empty,
///   `a`/`out` the same length;
/// * [`Op::ScaleByRecip`] — `a`/`out` hold the concatenated rows, `b`
///   one divisor per row, `rows[r]` the lane count of row `r`.
///
/// Every op shares the plan → seed → power core (the reciprocal of the
/// planned `x`, behind the divisor-reciprocal cache) and diverges only
/// in the plan unpack and the tail:
/// * `Div` — final multiply `sig_a · recip` ([`stages::mul_round`]);
/// * `Recip` — the reciprocal rounds directly ([`stages::recip_round`]),
///   bit-identical to `Div(1.0, x)`;
/// * `Rsqrt` — Newton tail over the same tiles/engine
///   ([`stages::rsqrt_newton`] + [`stages::rsqrt_round`]);
/// * `ScaleByRecip` — per-lane `Div(a[i], b[row])` with the row's
///   reciprocal amortized by the cache, bit-identical to `Div` against
///   the expanded divisor vector.
///
/// For `Div` this is bit-identical to calling `TaylorDivider::div_bits`
/// per lane with the same `cfg` and multiplier backend — for **every**
/// engine (the engines are bit-identical to each other by construction;
/// property tests pin forced-SIMD against forced-scalar against the
/// scalar datapath).
#[allow(clippy::too_many_arguments)]
pub fn compute_batch<M: Multiplier>(
    cfg: &TaylorConfig,
    backend: &mut M,
    scratch: &mut KernelScratch,
    tile: usize,
    eng: Engine,
    op: Op,
    a: &[u64],
    b: &[u64],
    rows: &[u32],
    fmt: Format,
    rm: Rounding,
    out: &mut [u64],
) {
    match op {
        Op::Div => {
            assert_eq!(a.len(), b.len(), "operand length mismatch");
            assert!(rows.is_empty(), "rows are a ScaleByRecip shape");
        }
        Op::Recip | Op::Rsqrt => {
            assert!(b.is_empty(), "one-operand op carries no divisor lanes");
            assert!(rows.is_empty(), "rows are a ScaleByRecip shape");
        }
        Op::ScaleByRecip => {
            assert_eq!(b.len(), rows.len(), "one divisor per row");
            assert_eq!(
                rows.iter().map(|&n| n as usize).sum::<usize>(),
                a.len(),
                "row lengths must cover the lane vector"
            );
        }
    }
    assert_eq!(a.len(), out.len(), "output length mismatch");
    assert!(
        cfg.frac_bits >= fmt.frac_bits,
        "datapath narrower than format significand"
    );
    assert!(tile >= 1, "kernel tile must be ≥ 1 lane");
    assert!(
        cfg.order <= crate::taylor::MAX_FAST_ORDER,
        "Taylor order beyond the fast-path schedule"
    );
    let f = cfg.frac_bits;
    let shift = f - fmt.frac_bits;

    let KernelScratch {
        plan,
        edge_cache,
        miss_pos,
        miss_x,
        y0,
        m,
        pow,
        sum,
        recip,
        nr_z,
        nr_t,
        nr_u,
        cache_x,
        cache_r,
    } = scratch;

    // Fresh divisor cache per call: reciprocals are only reusable under
    // the (cfg, backend) pair of THIS call (see the field comment).
    cache_x.fill(0);
    cache_r.fill(0);

    // Stage the PLA edge table once for the whole call (every seed tile
    // reuses it); a scratch that last served a different Taylor config
    // rebuilds, otherwise the staging from the previous call stands.
    if !edge_cache.matches(&cfg.table.edges) {
        edge_cache.rebuild(&cfg.table.edges);
    }

    // Stage 1 — plan: unpack per op, classify specials into the output
    // sidechannel, pack real lanes into the dense SoA arrays.
    match op {
        Op::Div => stages::plan(a, b, fmt, shift, plan, out),
        Op::Recip => stages::plan_recip(a, fmt, shift, plan, out),
        Op::Rsqrt => stages::plan_rsqrt(a, fmt, shift, plan, out),
        Op::ScaleByRecip => stages::plan_scale(a, b, rows, fmt, shift, plan, out),
    }
    let n = plan.lanes();
    plan.recip.resize(n, 0);

    // Stages 2–3 — seed + power, tile by tile over the dense lanes: the
    // shared reciprocal core of every op.
    let mut t0 = 0;
    while t0 < n {
        let t1 = (t0 + tile).min(n);
        // Cache probe: lanes whose divisor reciprocal is already known
        // skip straight to the tail; misses are compacted so the
        // compute stages run dense. Duplicate divisors within one tile
        // compute more than once — bit-identical (pure function), and a
        // tile is at most `tile` lanes wide. ScaleByRecip rows arrive
        // as contiguous runs of one divisor, so this probe is what
        // amortizes their reciprocal across the row.
        miss_pos.clear();
        miss_x.clear();
        for j in t0..t1 {
            let x = plan.x[j];
            let way = cache_way(x);
            if cache_x[way] == x {
                plan.recip[j] = cache_r[way];
            } else {
                miss_pos.push(j as u32);
                miss_x.push(x);
            }
        }
        if !miss_pos.is_empty() {
            stages::seed(eng, &cfg.table, edge_cache, miss_x, y0);
            stages::power(eng, backend, f, cfg.order, miss_x, y0, m, pow, sum, recip);
            for (k, &pos) in miss_pos.iter().enumerate() {
                let x = miss_x[k];
                let way = cache_way(x);
                cache_x[way] = x;
                cache_r[way] = recip[k];
                plan.recip[pos as usize] = recip[k];
            }
        }
        t0 = t1;
    }

    // Rsqrt interlude: Newton-refine the reciprocal into 1/sqrt(x) over
    // the same tiles and engine, in place in `plan.recip`.
    if op == Op::Rsqrt {
        let mut t0 = 0;
        while t0 < n {
            let t1 = (t0 + tile).min(n);
            stages::rsqrt_newton(eng, f, &plan.x[t0..t1], &plan.recip[t0..t1], nr_z, nr_t, nr_u);
            plan.recip[t0..t1].copy_from_slice(nr_z);
            t0 = t1;
        }
    }

    // Stage 4 — the op tail: round and scatter back to each lane's
    // original batch position.
    match op {
        Op::Div | Op::ScaleByRecip => stages::mul_round(plan, fmt, rm, f, false, out),
        Op::Recip => stages::recip_round(plan, fmt, rm, f, out),
        Op::Rsqrt => stages::rsqrt_round(plan, fmt, rm, f, out),
    }
}

/// Run the staged pipeline over one division batch: `out[i] = a[i] /
/// b[i]`, all slices the same length — [`compute_batch`] pinned to
/// [`Op::Div`] (the shape every pre-op-enum caller used).
#[allow(clippy::too_many_arguments)]
pub fn divide_batch<M: Multiplier>(
    cfg: &TaylorConfig,
    backend: &mut M,
    scratch: &mut KernelScratch,
    tile: usize,
    eng: Engine,
    a: &[u64],
    b: &[u64],
    fmt: Format,
    rm: Rounding,
    out: &mut [u64],
) {
    compute_batch(
        cfg,
        backend,
        scratch,
        tile,
        eng,
        Op::Div,
        a,
        b,
        &[],
        fmt,
        rm,
        out,
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::divider::{Divider, TaylorDivider};
    use crate::fp::{ALL_FORMATS, F32};
    use crate::powering::{ExactMul, IlmBackend};
    use crate::util::rng::Rng;

    fn bits32(xs: &[f32]) -> Vec<u64> {
        xs.iter().map(|&x| x.to_bits() as u64).collect()
    }

    /// Drive the kernel directly (fresh scratch) with a given tile and
    /// engine.
    #[allow(clippy::too_many_arguments)]
    fn kernel_divide_on(
        cfg: &TaylorConfig,
        ilm: Option<u32>,
        tile: usize,
        eng: Engine,
        a: &[u64],
        b: &[u64],
        fmt: Format,
        rm: Rounding,
    ) -> Vec<u64> {
        let mut out = vec![0u64; a.len()];
        let mut scratch = KernelScratch::new();
        match ilm {
            None => {
                let mut be = ExactMul::default();
                divide_batch(cfg, &mut be, &mut scratch, tile, eng, a, b, fmt, rm, &mut out);
            }
            Some(k) => {
                let mut be = IlmBackend::new(k);
                divide_batch(cfg, &mut be, &mut scratch, tile, eng, a, b, fmt, rm, &mut out);
            }
        }
        out
    }

    /// Scalar-engine shorthand for tests whose point is not the engine.
    fn kernel_divide(
        cfg: &TaylorConfig,
        ilm: Option<u32>,
        tile: usize,
        a: &[u64],
        b: &[u64],
        fmt: Format,
        rm: Rounding,
    ) -> Vec<u64> {
        kernel_divide_on(cfg, ilm, tile, Engine::Scalar, a, b, fmt, rm)
    }

    #[test]
    fn config_default_and_validate() {
        let cfg = KernelConfig::default();
        assert_eq!(cfg.tile, DEFAULT_TILE);
        assert_eq!(cfg.ilm_iterations, None);
        assert_eq!(cfg.simd, SimdChoice::Auto);
        assert!(cfg.validate().is_ok());
        assert!(KernelConfig { tile: 0, ..cfg }.validate().is_err());
        assert!(KernelConfig { tile: 1, ..cfg }.validate().is_ok());
        assert!(KernelConfig {
            tile: (1 << 20) + 1,
            ..cfg
        }
        .validate()
        .is_err());
        // The scalar engine always validates; Forced follows the host.
        assert!(KernelConfig {
            simd: SimdChoice::Scalar,
            ..cfg
        }
        .validate()
        .is_ok());
        let forced = KernelConfig {
            simd: SimdChoice::Forced,
            ..cfg
        };
        assert_eq!(forced.validate().is_ok(), crate::simd::simd_available());
    }

    #[test]
    fn every_engine_matches_the_scalar_datapath() {
        // The same batch through each available engine: identical to the
        // scalar div_bits per lane, and identical across engines.
        let cfg = TaylorConfig::paper_default(60);
        let mut rng = Rng::new(4242);
        for fmt in ALL_FORMATS {
            let (a, b) = crate::harness::gen_bits_batch(fmt, 73, 7, rng.next_u64());
            let mut d = TaylorDivider::paper_exact();
            let want: Vec<u64> = (0..a.len())
                .map(|i| d.div_bits(a[i], b[i], fmt, Rounding::TowardNegative))
                .collect();
            for eng in crate::simd::engines_available() {
                let got = kernel_divide_on(
                    &cfg,
                    None,
                    DEFAULT_TILE,
                    eng,
                    &a,
                    &b,
                    fmt,
                    Rounding::TowardNegative,
                );
                assert_eq!(got, want, "{} {}", eng.name(), fmt.name());
            }
        }
    }

    #[test]
    fn matches_scalar_divider_simple() {
        let cfg = TaylorConfig::paper_default(60);
        let a = bits32(&[6.0, 1.0, -7.5, 84.0, 355.0]);
        let b = bits32(&[2.0, 4.0, 2.5, 2.0, 113.0]);
        let got = kernel_divide(&cfg, None, DEFAULT_TILE, &a, &b, F32, Rounding::NearestEven);
        let mut d = TaylorDivider::paper_exact();
        for i in 0..a.len() {
            assert_eq!(got[i], d.div_bits(a[i], b[i], F32, Rounding::NearestEven), "lane {i}");
        }
    }

    #[test]
    fn specials_resolved_in_plan_stage() {
        let cfg = TaylorConfig::paper_default(60);
        let a = bits32(&[f32::NAN, 1.0, 0.0, f32::INFINITY, -1.0, 0.0]);
        let b = bits32(&[1.0, 0.0, 0.0, 2.0, f32::INFINITY, 5.0]);
        let got = kernel_divide(&cfg, None, DEFAULT_TILE, &a, &b, F32, Rounding::NearestEven);
        let mut d = TaylorDivider::paper_exact();
        for i in 0..a.len() {
            assert_eq!(got[i], d.div_bits(a[i], b[i], F32, Rounding::NearestEven), "lane {i}");
        }
    }

    #[test]
    fn tile_remainders_and_tiny_tiles_bit_identical() {
        // Batch lengths deliberately not divisible by the tile width —
        // the last partial tile must behave exactly like a full one.
        let cfg = TaylorConfig::paper_default(60);
        let mut rng = Rng::new(99);
        for fmt in ALL_FORMATS {
            let (a, b) = crate::harness::gen_bits_batch(fmt, 61, 6, rng.next_u64());
            let mut d = TaylorDivider::paper_exact();
            let want: Vec<u64> = (0..a.len())
                .map(|i| d.div_bits(a[i], b[i], fmt, Rounding::NearestEven))
                .collect();
            for tile in [1usize, 3, 7, 8, 13, 61, 200] {
                for len in [1usize, 7, 8, 9, 17, 61] {
                    let got = kernel_divide(
                        &cfg,
                        None,
                        tile,
                        &a[..len],
                        &b[..len],
                        fmt,
                        Rounding::NearestEven,
                    );
                    assert_eq!(got, want[..len], "{} tile={tile} len={len}", fmt.name());
                }
            }
        }
    }

    #[test]
    fn ilm_backend_matches_scalar_across_tiles() {
        let cfg = TaylorConfig::paper_default(60);
        let mut rng = Rng::new(5);
        let (a, b) = crate::harness::gen_bits_batch(F32, 37, 8, rng.next_u64());
        let mut d = TaylorDivider::paper_ilm(3);
        let want: Vec<u64> = (0..a.len())
            .map(|i| d.div_bits(a[i], b[i], F32, Rounding::TowardZero))
            .collect();
        for tile in [1usize, 4, 8, 37] {
            let got = kernel_divide(&cfg, Some(3), tile, &a, &b, F32, Rounding::TowardZero);
            assert_eq!(got, want, "tile={tile}");
        }
    }

    #[test]
    fn staged_edge_table_multi_tile_call_bit_identical_across_engines() {
        // ROADMAP item e: one divide_batch call spanning many seed
        // tiles stages the PLA edge table once and reuses it per tile —
        // the forced-SIMD engine must equal the forced-scalar engine
        // bit for bit over that whole call (the widest vector engine
        // exercised when the host has one), and both must equal the
        // scalar datapath.
        let cfg = TaylorConfig::paper_default(60);
        let mut rng = Rng::new(2026);
        // 131 lanes at tile 8 → 17 tiles in one call, tail included;
        // random divisors keep the reciprocal cache missing, so nearly
        // every tile runs the seed stage against the shared staging.
        let (a, b) = crate::harness::gen_bits_batch(F32, 131, 8, rng.next_u64());
        let mut d = TaylorDivider::paper_exact();
        let want: Vec<u64> = (0..a.len())
            .map(|i| d.div_bits(a[i], b[i], F32, Rounding::NearestEven))
            .collect();
        for eng in crate::simd::engines_available() {
            let mut be = ExactMul::default();
            let mut scratch = KernelScratch::new();
            let mut out = vec![0u64; a.len()];
            let rm = Rounding::NearestEven;
            divide_batch(&cfg, &mut be, &mut scratch, 8, eng, &a, &b, F32, rm, &mut out);
            assert_eq!(out, want, "{} first call", eng.name());
            // Second call through the SAME scratch: the edge staging
            // from the first call is reused as-is.
            let mut out2 = vec![0u64; a.len()];
            divide_batch(&cfg, &mut be, &mut scratch, 8, eng, &a, &b, F32, rm, &mut out2);
            assert_eq!(out2, want, "{} staged-edge reuse call", eng.name());
            // A different segment table through the same scratch forces
            // a restage — results must match that table's datapath.
            let cfg1 = TaylorConfig {
                order: 5,
                frac_bits: 60,
                table: crate::pla::SegmentTable::build(&[1.0, 2.0], 60),
            };
            assert_ne!(cfg1.table.edges, cfg.table.edges, "fixture needs a second table");
            let mut d1 = TaylorDivider::new(cfg1.clone(), crate::divider::BackendKind::Exact);
            let want1: Vec<u64> = (0..a.len())
                .map(|i| d1.div_bits(a[i], b[i], F32, rm))
                .collect();
            let mut out3 = vec![0u64; a.len()];
            divide_batch(&cfg1, &mut be, &mut scratch, 8, eng, &a, &b, F32, rm, &mut out3);
            assert_eq!(out3, want1, "{} restaged table", eng.name());
        }
    }

    #[test]
    fn recip_cache_scratch_reuse_across_calls_bit_exact() {
        // Two consecutive batches through one scratch with the same
        // divisor: the cache resets between calls (it is only valid
        // under one (cfg, backend) pair), and both batches must match
        // the scalar path bit for bit.
        let cfg = TaylorConfig::paper_default(60);
        let mut be = ExactMul::default();
        let mut scratch = KernelScratch::new();
        let a1 = bits32(&[6.0, 9.0, 12.0]);
        let a2 = bits32(&[15.0, 18.0, 21.0]);
        let b = bits32(&[3.0, 3.0, 3.0]);
        let mut out1 = vec![0u64; 3];
        let mut out2 = vec![0u64; 3];
        let eng = Engine::Scalar;
        let rm = Rounding::NearestEven;
        divide_batch(&cfg, &mut be, &mut scratch, 8, eng, &a1, &b, F32, rm, &mut out1);
        divide_batch(&cfg, &mut be, &mut scratch, 8, eng, &a2, &b, F32, rm, &mut out2);
        let mut d = TaylorDivider::paper_exact();
        for i in 0..3 {
            assert_eq!(out1[i], d.div_bits(a1[i], b[i], F32, Rounding::NearestEven));
            assert_eq!(out2[i], d.div_bits(a2[i], b[i], F32, Rounding::NearestEven));
        }
    }

    #[test]
    fn low_order_configs_match_scalar() {
        // order 0 (seed only), 1 (one Taylor term) and a tall order all
        // ride the same stage loops.
        for order in [0u32, 1, 2, 7, 12] {
            let cfg = TaylorConfig {
                order,
                ..TaylorConfig::paper_default(60)
            };
            let mut d = TaylorDivider::new(cfg.clone(), crate::divider::BackendKind::Exact);
            let a = bits32(&[7.0, 1.0, 100.0, 0.3]);
            let b = bits32(&[1.3, 3.0, 7.0, 0.9]);
            let want: Vec<u64> = (0..a.len())
                .map(|i| d.div_bits(a[i], b[i], F32, Rounding::NearestEven))
                .collect();
            let got = kernel_divide(&cfg, None, 2, &a, &b, F32, Rounding::NearestEven);
            assert_eq!(got, want, "order={order}");
        }
    }

    fn kernel_compute_on(
        cfg: &TaylorConfig,
        tile: usize,
        eng: Engine,
        op: crate::fp::Op,
        a: &[u64],
        b: &[u64],
        rows: &[u32],
        fmt: Format,
        rm: Rounding,
    ) -> Vec<u64> {
        let mut out = vec![0u64; a.len()];
        let mut scratch = KernelScratch::new();
        let mut be = ExactMul::default();
        compute_batch(cfg, &mut be, &mut scratch, tile, eng, op, a, b, rows, fmt, rm, &mut out);
        out
    }

    #[test]
    fn recip_bit_identical_to_div_by_one_every_engine() {
        // Recip skips the final multiply; the tail must still equal
        // Div(1.0, x) bit for bit — the multiply only shifts zeros in.
        let cfg = TaylorConfig::paper_default(60);
        let mut rng = Rng::new(90210);
        for fmt in ALL_FORMATS {
            let (x, _) = crate::harness::gen_bits_batch(fmt, 67, 9, rng.next_u64());
            let ones = vec![fmt.one(); x.len()];
            for rm in Rounding::ALL {
                let want =
                    kernel_divide_on(&cfg, None, 7, Engine::Scalar, &ones, &x, fmt, rm);
                for eng in crate::simd::engines_available() {
                    let got = kernel_compute_on(
                        &cfg,
                        7,
                        eng,
                        crate::fp::Op::Recip,
                        &x,
                        &[],
                        &[],
                        fmt,
                        rm,
                    );
                    assert_eq!(got, want, "{} {} {rm:?}", eng.name(), fmt.name());
                }
            }
        }
    }

    #[test]
    fn scale_by_recip_bit_identical_to_div_with_expanded_divisors() {
        // Mixed row lengths (deliberately not tile multiples) with
        // special divisors and lanes sprinkled in: per-lane results must
        // equal Div against the broadcast-expanded divisor vector, and
        // lane order must survive rows spanning tile boundaries.
        let cfg = TaylorConfig::paper_default(60);
        let mut rng = Rng::new(515);
        for fmt in ALL_FORMATS {
            let rows: Vec<u32> = vec![1, 5, 13, 2, 31, 1, 7];
            let lanes: usize = rows.iter().map(|&n| n as usize).sum();
            let (a, mut b_rows) = crate::harness::gen_bits_batch(fmt, lanes, 7, rng.next_u64());
            b_rows.truncate(rows.len());
            b_rows[3] = fmt.nan();
            b_rows[5] = fmt.zero(true);
            let b_expanded: Vec<u64> = rows
                .iter()
                .zip(&b_rows)
                .flat_map(|(&n, &bb)| std::iter::repeat(bb).take(n as usize))
                .collect();
            for tile in [1usize, 4, 8] {
                let want = kernel_divide_on(
                    &cfg,
                    None,
                    tile,
                    Engine::Scalar,
                    &a,
                    &b_expanded,
                    fmt,
                    Rounding::NearestEven,
                );
                for eng in crate::simd::engines_available() {
                    let got = kernel_compute_on(
                        &cfg,
                        tile,
                        eng,
                        crate::fp::Op::ScaleByRecip,
                        &a,
                        &b_rows,
                        &rows,
                        fmt,
                        Rounding::NearestEven,
                    );
                    assert_eq!(got, want, "{} {} tile={tile}", eng.name(), fmt.name());
                }
            }
        }
    }

    #[test]
    fn rsqrt_specials_bit_identical_and_finite_in_band_vs_gold() {
        use crate::divider::longdiv::LongDivider;
        use crate::fp::ulp_diff;
        let cfg = TaylorConfig::paper_default(60);
        let mut rng = Rng::new(7171);
        let mut gold = LongDivider::new();
        for fmt in ALL_FORMATS {
            // Specials plus positive finite operands (normals and
            // subnormals, odd and even exponents).
            let mut x: Vec<u64> = vec![
                fmt.nan(),
                fmt.zero(false),
                fmt.zero(true),
                fmt.inf(false),
                fmt.inf(true),
                fmt.assemble(true, fmt.bias() as u64, 3),
                fmt.assemble(false, 0, 1), // smallest subnormal
                fmt.one(),
            ];
            for _ in 0..120 {
                let e = 1 + rng.below(fmt.exp_max() - 2);
                x.push(fmt.assemble(false, e, rng.next_u64() & fmt.frac_mask()));
            }
            for rm in Rounding::ALL {
                let want: Vec<u64> = x.iter().map(|&xb| gold.rsqrt_bits(xb, fmt, rm)).collect();
                for eng in crate::simd::engines_available() {
                    let got = kernel_compute_on(
                        &cfg,
                        8,
                        eng,
                        crate::fp::Op::Rsqrt,
                        &x,
                        &[],
                        &[],
                        fmt,
                        rm,
                    );
                    let band = if fmt.frac_bits > 23 { 2 } else { 1 };
                    for i in 0..x.len() {
                        match ulp_diff(got[i], want[i], fmt) {
                            None => assert_eq!(
                                got[i], want[i],
                                "{} {} {rm:?} special lane {i}",
                                eng.name(),
                                fmt.name()
                            ),
                            Some(ulps) => assert!(
                                ulps <= band,
                                "{} {} {rm:?} lane {i}: {ulps} ulps",
                                eng.name(),
                                fmt.name()
                            ),
                        }
                    }
                }
            }
        }
    }

    #[test]
    #[should_panic(expected = "output length mismatch")]
    fn rejects_mismatched_output() {
        let cfg = TaylorConfig::paper_default(60);
        let mut be = ExactMul::default();
        let mut scratch = KernelScratch::new();
        let mut out = vec![0u64; 1];
        divide_batch(
            &cfg,
            &mut be,
            &mut scratch,
            8,
            Engine::Scalar,
            &[0, 0],
            &[0, 0],
            F32,
            Rounding::NearestEven,
            &mut out,
        );
    }
}
