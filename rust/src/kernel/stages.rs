//! The four pipeline stages of the staged SoA division kernel.
//!
//! Each stage is a free function over plain slices so the loop bodies
//! stay branch-light and monomorphize against one multiplier backend —
//! the whole point of the kernel layout (see the module docs of
//! [`super`]). The seed and power stages additionally run on an explicit
//! lane engine ([`crate::simd::Engine`]): the per-op lane loops are
//! vector ops (AVX-512/AVX2/NEON when selected, scalar-unrolled
//! otherwise) instead of autovectorization hopes. The per-lane arithmetic is copied
//! operation-for-operation from the scalar datapath
//! ([`crate::taylor::reciprocal_fast`] and `TaylorDivider::div_bits`),
//! so results are bit-identical; only the loop nesting differs.

use super::LanePlan;
use crate::divider::{prepare, Prepared};
use crate::fp::{round_pack, unpack, Class, Format, Rounding};
use crate::pla::SegmentTable;
use crate::powering::Multiplier;
use crate::simd::Engine;

/// `⌊2^64 / sqrt(2)⌋` — shifted down to the datapath width for the
/// odd-exponent fixup of the rsqrt tail (the nested-floor identity makes
/// the shift of this constant equal the directly computed
/// `⌊2^f / sqrt(2)⌋`).
const INV_SQRT2_Q64: u64 = 0xB504_F333_F9DE_6484;

/// Stage 1 — plan: unpack both operands per `fmt`, resolve the IEEE
/// special cases (NaN/Inf/zero rules) straight into `out` (the
/// sidechannel), and pack every real division into the dense SoA arrays
/// of `lanes`. Subnormal operands are normalized into the extended
/// exponent range here, so later stages never see them.
pub fn plan(a: &[u64], b: &[u64], fmt: Format, shift: u32, lanes: &mut LanePlan, out: &mut [u64]) {
    lanes.clear();
    for (i, ((&ab, &bb), q)) in a.iter().zip(b).zip(out.iter_mut()).enumerate() {
        match prepare(ab, bb, fmt) {
            Prepared::Done(bits) => *q = bits,
            Prepared::Divide {
                sign,
                exp,
                sig_a,
                sig_b,
            } => {
                lanes.idx.push(i as u32);
                lanes.sign.push(sign);
                lanes.exp.push(exp);
                lanes.sig_a.push(sig_a);
                // Map the divisor significand into the Q2.F datapath.
                lanes.x.push(sig_b << shift);
            }
        }
    }
}

/// Stage 1 (Recip variant) — plan `1 / a[i]`: exactly the division plan
/// with the format's literal one as every lane's dividend, so the
/// special table (NaN → NaN, ±0 → ±Inf, ±Inf → ±0) and the packed
/// `sign`/`exp`/`sig_a`/`x` lanes are — by construction — those of
/// `Div(1.0, a[i])`. The downstream tail can then skip the final
/// multiply: `sig_a` is a power of two, so the product stage would only
/// shift zeros in.
pub fn plan_recip(a: &[u64], fmt: Format, shift: u32, lanes: &mut LanePlan, out: &mut [u64]) {
    lanes.clear();
    let one = fmt.one();
    for (i, (&ab, q)) in a.iter().zip(out.iter_mut()).enumerate() {
        match prepare(one, ab, fmt) {
            Prepared::Done(bits) => *q = bits,
            Prepared::Divide {
                sign,
                exp,
                sig_a,
                sig_b,
            } => {
                lanes.idx.push(i as u32);
                lanes.sign.push(sign);
                lanes.exp.push(exp);
                lanes.sig_a.push(sig_a);
                lanes.x.push(sig_b << shift);
            }
        }
    }
}

/// Stage 1 (Rsqrt variant) — plan `1 / sqrt(a[i])`: IEEE `rSqrt`
/// specials (NaN → NaN, negative non-zero including −Inf → NaN,
/// ±0 → ±Inf, +Inf → +0) resolve into the sidechannel; finite positive
/// lanes pack with the divisor significand `s ∈ [1, 2)` in `x`, the
/// half-exponent in `exp`, and — reusing the otherwise-unused dividend
/// slot — the **exponent parity** in `sig_a` (0 = even, 1 = odd): odd
/// exponents fold as `1/sqrt(s·2^(2k+1)) = (1/sqrt(s))·(1/sqrt(2))·2^−k`
/// and the tail multiplies the parity lanes by `1/sqrt(2)` during
/// rounding.
pub fn plan_rsqrt(a: &[u64], fmt: Format, shift: u32, lanes: &mut LanePlan, out: &mut [u64]) {
    lanes.clear();
    for (i, (&ab, q)) in a.iter().zip(out.iter_mut()).enumerate() {
        let u = unpack(ab, fmt);
        match u.class {
            Class::NaN => *q = fmt.nan(),
            Class::Zero => *q = fmt.inf(u.sign),
            _ if u.sign => *q = fmt.nan(),
            Class::Inf => *q = fmt.zero(false),
            Class::Normal | Class::Subnormal => {
                let parity = u.exp.rem_euclid(2);
                // exp = 2k + parity ⇒ result exponent −k, exactly.
                let k = (u.exp - parity) / 2;
                lanes.idx.push(i as u32);
                lanes.sign.push(false);
                lanes.exp.push(-k);
                lanes.sig_a.push(parity as u64);
                lanes.x.push(u.sig << shift);
            }
        }
    }
}

/// Stage 1 (ScaleByRecip variant) — plan `a[lane] / b[row]`: `a` holds
/// the concatenated rows, `b` one divisor per row, and `rows[r]` the
/// lane count of row `r` (aligned with `b`). Per-lane semantics are
/// exactly division with a broadcast divisor — every special resolves
/// through the same [`prepare`] table — so the packed lanes are those
/// `Div` would produce from the expanded divisor vector, and the fused
/// op's saving comes from the divisor-reciprocal cache seeing each
/// row's `x` in one contiguous run.
pub fn plan_scale(
    a: &[u64],
    b: &[u64],
    rows: &[u32],
    fmt: Format,
    shift: u32,
    lanes: &mut LanePlan,
    out: &mut [u64],
) {
    debug_assert_eq!(b.len(), rows.len(), "one divisor per row");
    debug_assert_eq!(
        rows.iter().map(|&n| n as usize).sum::<usize>(),
        a.len(),
        "row lengths must cover the lane vector"
    );
    lanes.clear();
    let mut i = 0usize;
    for (&bb, &row_len) in b.iter().zip(rows) {
        for _ in 0..row_len {
            let ab = a[i];
            match prepare(ab, bb, fmt) {
                Prepared::Done(bits) => out[i] = bits,
                Prepared::Divide {
                    sign,
                    exp,
                    sig_a,
                    sig_b,
                } => {
                    lanes.idx.push(i as u32);
                    lanes.sign.push(sign);
                    lanes.exp.push(exp);
                    lanes.sig_a.push(sig_a);
                    lanes.x.push(sig_b << shift);
                }
            }
            i += 1;
        }
    }
}

/// Stage 2 — seed: PLA segment lookup (compare tree + one multiply) for
/// a tile of divisor significands, `y0[i] ≈ 1/x[i]`, on the explicit
/// lane engine. The compare tree runs as an edge-count pass over the
/// **pre-staged** edge table (`edge_cache`, built once per
/// `divide_batch` call in [`super::KernelScratch`] from `table`'s
/// edges), so the AVX2 bias/broadcast setup is not repeated per tile
/// (AVX-512 and NEON compare unsigned lanes natively and read the
/// cache's raw edges) — see [`SegmentTable::seed_batch_with`].
pub fn seed(
    eng: Engine,
    table: &SegmentTable,
    edge_cache: &crate::simd::BiasedEdges,
    x: &[u64],
    y0: &mut Vec<u64>,
) {
    y0.clear();
    y0.resize(x.len(), 0);
    table.seed_batch_with(eng, edge_cache, x, y0);
}

/// Stage 3 — power: Taylor powering over a tile.
///
/// Per lane: `m = 1 − x·y0` (saturating at 0, as the hardware clamps),
/// then the §6 odd/even simultaneous-powers schedule — every even power
/// is the square of its half power (squaring unit), every odd power the
/// previous odd power times the cached base `m` (ILM) — accumulated into
/// `S = 1 + Σ m^k`, and finally the Fig-7 reciprocal multiply
/// `recip = y0·S`. Each step runs as one loop across the tile's lanes.
///
/// `m = 0` lanes need no special-casing: both multiplier backends map
/// zero operands to zero products, so the power rows contribute nothing
/// and `S` collapses to `1 + m = 1`, exactly as the scalar path's
/// early-out computes it.
///
/// The accumulator runs in **wrapping u64** lane adds on the engine: the
/// scalar datapath sums in `u128` and truncates exactly once (`s as
/// u64`) before the final multiply, and addition commutes with
/// truncation mod 2^64, so the low 64 bits — the only ones that ever
/// reach the datapath — are bit-identical.
#[allow(clippy::too_many_arguments)]
pub fn power<M: Multiplier>(
    eng: Engine,
    backend: &mut M,
    f: u32,
    order: u32,
    x: &[u64],
    y0: &[u64],
    m: &mut Vec<u64>,
    pow: &mut Vec<u64>,
    sum: &mut Vec<u64>,
    recip: &mut Vec<u64>,
) {
    let k = x.len();
    let one = 1u64 << f;
    debug_assert_eq!(y0.len(), k);

    // m = 1 − x·y0, saturating: truncation may push the fixed-point
    // value a hair negative, which hardware clamps (the analytic m is
    // ≥ 0: m(x) = (1 − 2x/(a+b))²).
    m.clear();
    m.resize(k, 0);
    backend.mul_fixed_hot_batch(eng, x, y0, f, m);
    eng.rsub_sat(one, m);

    // Accumulator S = 1 + Σ_{p≤order} m^p (wrapping lane adds, see the
    // function docs).
    sum.clear();
    sum.resize(k, 0);
    if order == 0 {
        sum.fill(one);
    } else {
        eng.fill_add(one, m, sum);
        if order >= 2 {
            // pow rows: pow[(p−1)·k .. p·k] = m^p; row 0 is m itself.
            pow.clear();
            pow.resize(order as usize * k, 0);
            pow[..k].copy_from_slice(m);
            for p in 2..=order {
                let (lower, upper) = pow.split_at_mut((p as usize - 1) * k);
                let dst = &mut upper[..k];
                if p % 2 == 0 {
                    // Even power: squaring unit on m^(p/2).
                    let half = &lower[(p as usize / 2 - 1) * k..][..k];
                    backend.square_fixed_hot_batch(eng, half, f, dst);
                } else {
                    // Odd power: multiplier with the cached base operand.
                    let prev = &lower[(p as usize - 2) * k..][..k];
                    backend.mul_fixed_hot_batch(eng, prev, m, f, dst);
                }
                eng.add_wrapping(sum, dst);
            }
        }
    }

    // recip = y0 · S — the final multiply of the Fig-7 reciprocal
    // datapath.
    recip.clear();
    recip.resize(k, 0);
    backend.mul_fixed_hot_batch(eng, y0, sum, f, recip);
}

/// Stage 4 — mul_round: the quotient significand `sig_a · recip`
/// (fraction width `fmt.frac_bits + f`, value in (0.5, 2]) rounded and
/// packed under `rm`, scattered back to each lane's original batch
/// position. The Taylor datapath passes `sticky = false` — the
/// reciprocal is itself inexact below ~2^-53, so sticky stays clear,
/// matching the paper's inherently approximate unit (and the scalar
/// path, bit for bit); the Goldschmidt fused tail passes `sticky =
/// true`, its continuous-truncation rounding contract.
pub fn mul_round(
    lanes: &LanePlan,
    fmt: Format,
    rm: Rounding,
    f: u32,
    sticky: bool,
    out: &mut [u64],
) {
    let q_frac = fmt.frac_bits + f;
    for j in 0..lanes.lanes() {
        let q = lanes.sig_a[j] as u128 * lanes.recip[j] as u128;
        out[lanes.idx[j] as usize] =
            round_pack(lanes.sign[j], lanes.exp[j], q, q_frac, sticky, fmt, rm).0;
    }
}

/// Stage 4 (Recip tail) — round the reciprocal itself: no final
/// multiply. Feeding `recip` straight to `round_pack` at width `f` is
/// **bit-identical** to `mul_round` with a power-of-two `sig_a`
/// (`Div(1.0, x)`): multiplying by `2^frac_bits` while widening
/// `q_frac_bits` by the same amount only shifts zeros through the
/// normalizer — a property test pins the identity on every datapath.
pub fn recip_round(lanes: &LanePlan, fmt: Format, rm: Rounding, f: u32, out: &mut [u64]) {
    for j in 0..lanes.lanes() {
        out[lanes.idx[j] as usize] = round_pack(
            lanes.sign[j],
            lanes.exp[j],
            lanes.recip[j] as u128,
            f,
            false,
            fmt,
            rm,
        )
        .0;
    }
}

/// Rsqrt tail — Newton–Raphson `z ← z·(3 − x·z²)/2` over a tile, on the
/// lane engine.
///
/// `x` is the planned significand (Q2.F, `[1, 2)`) and `r ≈ 1/x` the
/// reciprocal the shared seed→power core already produced; the seed
/// `z₀ = (1 + r)/2` starts within 6 % of `1/sqrt(x)`, so four quadratic
/// steps land at the fixed-point truncation floor (≲2^−(F−3), far below
/// every format's half-ulp). The iteration's fixed point is `1/sqrt(x)`
/// independent of `r`'s Taylor error — `r` only sets the starting
/// distance. The halving folds into the final multiply's shift (`F+1`).
/// Results land in `z`; `t`/`u` are scratch.
pub fn rsqrt_newton(
    eng: Engine,
    f: u32,
    x: &[u64],
    r: &[u64],
    z: &mut Vec<u64>,
    t: &mut Vec<u64>,
    u: &mut Vec<u64>,
) {
    let k = x.len();
    debug_assert_eq!(r.len(), k);
    let one = 1u64 << f;
    let three = 3u64 << f;
    z.clear();
    z.resize(k, 0);
    t.clear();
    t.resize(k, 0);
    u.clear();
    u.resize(k, 0);
    // Engine-independent seed (plain scalar adds — no rounding freedom).
    for (zi, &ri) in z.iter_mut().zip(r) {
        *zi = (one + ri) >> 1;
    }
    for _ in 0..4 {
        eng.sqr_shr(z, f, t); // t = z²
        eng.mul_shr(x, t, f, u); // u = x·z²
        eng.rsub_sat(three, u); // u = 3 − x·z² (clamped, as hardware)
        eng.mul_shr(z, u, f + 1, t); // t = z·u/2
        std::mem::swap(z, t);
    }
}

/// Stage 4 (Rsqrt tail rounding) — scatter `z ≈ 1/sqrt(s)` back through
/// the odd-exponent fixup: parity lanes (see [`plan_rsqrt`]) multiply by
/// `⌊2^f/sqrt(2)⌋` (fraction width doubles to `2f`), even lanes shift by
/// `f` so both take the same `round_pack` width. Sticky is forced — the
/// Newton value is approximate at ~2^−(F−3), so directed modes must
/// never claim exactness (same contract as the Goldschmidt datapath).
pub fn rsqrt_round(lanes: &LanePlan, fmt: Format, rm: Rounding, f: u32, out: &mut [u64]) {
    let inv_sqrt2 = (INV_SQRT2_Q64 >> (64 - f)) as u128;
    for j in 0..lanes.lanes() {
        let z = lanes.recip[j] as u128;
        let q = if lanes.sig_a[j] == 1 {
            z * inv_sqrt2
        } else {
            z << f
        };
        out[lanes.idx[j] as usize] =
            round_pack(lanes.sign[j], lanes.exp[j], q, 2 * f, true, fmt, rm).0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp::F32;
    use crate::powering::ExactMul;
    use crate::taylor::{reciprocal_fast, TaylorConfig};

    #[test]
    fn plan_splits_specials_from_divisions() {
        let mut lanes = LanePlan::default();
        let a: Vec<u64> = [1.0f32, f32::NAN, 6.0, 0.0]
            .iter()
            .map(|x| x.to_bits() as u64)
            .collect();
        let b: Vec<u64> = [2.0f32, 1.0, 2.0, 3.0]
            .iter()
            .map(|x| x.to_bits() as u64)
            .collect();
        let mut out = vec![0u64; 4];
        plan(&a, &b, F32, 60 - F32.frac_bits, &mut lanes, &mut out);
        // Lanes 1 (NaN) and 3 (0/x) are specials; 0 and 2 are divisions.
        assert_eq!(lanes.idx, vec![0, 2]);
        assert!(f32::from_bits(out[1] as u32).is_nan());
        assert_eq!(out[3] as u32, 0.0f32.to_bits());
        // x is the divisor significand in Q2.60: both divisors are 2.0 →
        // significand 1.0.
        assert_eq!(lanes.x, vec![1u64 << 60; 2]);
    }

    #[test]
    fn seed_power_match_reciprocal_fast_per_lane() {
        let cfg = TaylorConfig::paper_default(60);
        let f = cfg.frac_bits;
        let xs: Vec<u64> = (0..17)
            .map(|i| (1u64 << 60) + i * ((1u64 << 60) / 17) + 4321)
            .map(|x| x.min((1u64 << 61) - 1))
            .collect();
        let mut cache = crate::simd::BiasedEdges::new();
        cache.rebuild(&cfg.table.edges);
        for eng in crate::simd::engines_available() {
            let mut y0 = Vec::new();
            let mut m = Vec::new();
            let mut pow = Vec::new();
            let mut sum = Vec::new();
            let mut recip = Vec::new();
            let mut be = ExactMul::default();
            seed(eng, &cfg.table, &cache, &xs, &mut y0);
            power(eng, &mut be, f, cfg.order, &xs, &y0, &mut m, &mut pow, &mut sum, &mut recip);
            for (i, &x) in xs.iter().enumerate() {
                let mut be2 = ExactMul::default();
                assert_eq!(
                    recip[i],
                    reciprocal_fast(&cfg, &mut be2, x),
                    "{} lane {i}",
                    eng.name()
                );
            }
        }
    }

    #[test]
    fn power_handles_m_zero_lane_like_scalar() {
        // x exactly at a segment midpoint-ish value can give m = 0; the
        // branch-light stage must still produce the scalar result.
        let cfg = TaylorConfig::paper_default(60);
        let f = cfg.frac_bits;
        // Probe many x and keep whichever produce m = 0 alongside
        // ordinary lanes; even if none hit exactly 0, identity holds.
        let xs: Vec<u64> = (0..64)
            .map(|i| (1u64 << 60) + i * ((1u64 << 54) + 7))
            .collect();
        let mut cache = crate::simd::BiasedEdges::new();
        cache.rebuild(&cfg.table.edges);
        for eng in crate::simd::engines_available() {
            let mut y0 = Vec::new();
            let (mut m, mut pow, mut sum, mut recip) =
                (Vec::new(), Vec::new(), Vec::new(), Vec::new());
            let mut be = ExactMul::default();
            seed(eng, &cfg.table, &cache, &xs, &mut y0);
            power(eng, &mut be, f, cfg.order, &xs, &y0, &mut m, &mut pow, &mut sum, &mut recip);
            for (i, &x) in xs.iter().enumerate() {
                let mut be2 = ExactMul::default();
                assert_eq!(
                    recip[i],
                    reciprocal_fast(&cfg, &mut be2, x),
                    "{} lane {i}",
                    eng.name()
                );
            }
        }
    }
}
