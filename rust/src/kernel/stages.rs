//! The four pipeline stages of the staged SoA division kernel.
//!
//! Each stage is a free function over plain slices so the loop bodies
//! stay branch-light and monomorphize against one multiplier backend —
//! the whole point of the kernel layout (see the module docs of
//! [`super`]). The seed and power stages additionally run on an explicit
//! lane engine ([`crate::simd::Engine`]): the per-op lane loops are
//! vector ops (AVX-512/AVX2/NEON when selected, scalar-unrolled
//! otherwise) instead of autovectorization hopes. The per-lane arithmetic is copied
//! operation-for-operation from the scalar datapath
//! ([`crate::taylor::reciprocal_fast`] and `TaylorDivider::div_bits`),
//! so results are bit-identical; only the loop nesting differs.

use super::LanePlan;
use crate::divider::{prepare, Prepared};
use crate::fp::{round_pack, Format, Rounding};
use crate::pla::SegmentTable;
use crate::powering::Multiplier;
use crate::simd::Engine;

/// Stage 1 — plan: unpack both operands per `fmt`, resolve the IEEE
/// special cases (NaN/Inf/zero rules) straight into `out` (the
/// sidechannel), and pack every real division into the dense SoA arrays
/// of `lanes`. Subnormal operands are normalized into the extended
/// exponent range here, so later stages never see them.
pub fn plan(a: &[u64], b: &[u64], fmt: Format, shift: u32, lanes: &mut LanePlan, out: &mut [u64]) {
    lanes.clear();
    for (i, ((&ab, &bb), q)) in a.iter().zip(b).zip(out.iter_mut()).enumerate() {
        match prepare(ab, bb, fmt) {
            Prepared::Done(bits) => *q = bits,
            Prepared::Divide {
                sign,
                exp,
                sig_a,
                sig_b,
            } => {
                lanes.idx.push(i as u32);
                lanes.sign.push(sign);
                lanes.exp.push(exp);
                lanes.sig_a.push(sig_a);
                // Map the divisor significand into the Q2.F datapath.
                lanes.x.push(sig_b << shift);
            }
        }
    }
}

/// Stage 2 — seed: PLA segment lookup (compare tree + one multiply) for
/// a tile of divisor significands, `y0[i] ≈ 1/x[i]`, on the explicit
/// lane engine. The compare tree runs as an edge-count pass over the
/// **pre-staged** edge table (`edge_cache`, built once per
/// `divide_batch` call in [`super::KernelScratch`] from `table`'s
/// edges), so the AVX2 bias/broadcast setup is not repeated per tile
/// (AVX-512 and NEON compare unsigned lanes natively and read the
/// cache's raw edges) — see [`SegmentTable::seed_batch_with`].
pub fn seed(
    eng: Engine,
    table: &SegmentTable,
    edge_cache: &crate::simd::BiasedEdges,
    x: &[u64],
    y0: &mut Vec<u64>,
) {
    y0.clear();
    y0.resize(x.len(), 0);
    table.seed_batch_with(eng, edge_cache, x, y0);
}

/// Stage 3 — power: Taylor powering over a tile.
///
/// Per lane: `m = 1 − x·y0` (saturating at 0, as the hardware clamps),
/// then the §6 odd/even simultaneous-powers schedule — every even power
/// is the square of its half power (squaring unit), every odd power the
/// previous odd power times the cached base `m` (ILM) — accumulated into
/// `S = 1 + Σ m^k`, and finally the Fig-7 reciprocal multiply
/// `recip = y0·S`. Each step runs as one loop across the tile's lanes.
///
/// `m = 0` lanes need no special-casing: both multiplier backends map
/// zero operands to zero products, so the power rows contribute nothing
/// and `S` collapses to `1 + m = 1`, exactly as the scalar path's
/// early-out computes it.
///
/// The accumulator runs in **wrapping u64** lane adds on the engine: the
/// scalar datapath sums in `u128` and truncates exactly once (`s as
/// u64`) before the final multiply, and addition commutes with
/// truncation mod 2^64, so the low 64 bits — the only ones that ever
/// reach the datapath — are bit-identical.
#[allow(clippy::too_many_arguments)]
pub fn power<M: Multiplier>(
    eng: Engine,
    backend: &mut M,
    f: u32,
    order: u32,
    x: &[u64],
    y0: &[u64],
    m: &mut Vec<u64>,
    pow: &mut Vec<u64>,
    sum: &mut Vec<u64>,
    recip: &mut Vec<u64>,
) {
    let k = x.len();
    let one = 1u64 << f;
    debug_assert_eq!(y0.len(), k);

    // m = 1 − x·y0, saturating: truncation may push the fixed-point
    // value a hair negative, which hardware clamps (the analytic m is
    // ≥ 0: m(x) = (1 − 2x/(a+b))²).
    m.clear();
    m.resize(k, 0);
    backend.mul_fixed_hot_batch(eng, x, y0, f, m);
    eng.rsub_sat(one, m);

    // Accumulator S = 1 + Σ_{p≤order} m^p (wrapping lane adds, see the
    // function docs).
    sum.clear();
    sum.resize(k, 0);
    if order == 0 {
        sum.fill(one);
    } else {
        eng.fill_add(one, m, sum);
        if order >= 2 {
            // pow rows: pow[(p−1)·k .. p·k] = m^p; row 0 is m itself.
            pow.clear();
            pow.resize(order as usize * k, 0);
            pow[..k].copy_from_slice(m);
            for p in 2..=order {
                let (lower, upper) = pow.split_at_mut((p as usize - 1) * k);
                let dst = &mut upper[..k];
                if p % 2 == 0 {
                    // Even power: squaring unit on m^(p/2).
                    let half = &lower[(p as usize / 2 - 1) * k..][..k];
                    backend.square_fixed_hot_batch(eng, half, f, dst);
                } else {
                    // Odd power: multiplier with the cached base operand.
                    let prev = &lower[(p as usize - 2) * k..][..k];
                    backend.mul_fixed_hot_batch(eng, prev, m, f, dst);
                }
                eng.add_wrapping(sum, dst);
            }
        }
    }

    // recip = y0 · S — the final multiply of the Fig-7 reciprocal
    // datapath.
    recip.clear();
    recip.resize(k, 0);
    backend.mul_fixed_hot_batch(eng, y0, sum, f, recip);
}

/// Stage 4 — mul_round: the quotient significand `sig_a · recip`
/// (fraction width `fmt.frac_bits + f`, value in (0.5, 2]) rounded and
/// packed under `rm`, scattered back to each lane's original batch
/// position. The reciprocal is itself inexact below ~2^-53, so sticky
/// stays clear — matching the paper's inherently approximate unit (and
/// the scalar path, bit for bit).
pub fn mul_round(lanes: &LanePlan, fmt: Format, rm: Rounding, f: u32, out: &mut [u64]) {
    let q_frac = fmt.frac_bits + f;
    for j in 0..lanes.lanes() {
        let q = lanes.sig_a[j] as u128 * lanes.recip[j] as u128;
        out[lanes.idx[j] as usize] =
            round_pack(lanes.sign[j], lanes.exp[j], q, q_frac, false, fmt, rm).0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp::F32;
    use crate::powering::ExactMul;
    use crate::taylor::{reciprocal_fast, TaylorConfig};

    #[test]
    fn plan_splits_specials_from_divisions() {
        let mut lanes = LanePlan::default();
        let a: Vec<u64> = [1.0f32, f32::NAN, 6.0, 0.0]
            .iter()
            .map(|x| x.to_bits() as u64)
            .collect();
        let b: Vec<u64> = [2.0f32, 1.0, 2.0, 3.0]
            .iter()
            .map(|x| x.to_bits() as u64)
            .collect();
        let mut out = vec![0u64; 4];
        plan(&a, &b, F32, 60 - F32.frac_bits, &mut lanes, &mut out);
        // Lanes 1 (NaN) and 3 (0/x) are specials; 0 and 2 are divisions.
        assert_eq!(lanes.idx, vec![0, 2]);
        assert!(f32::from_bits(out[1] as u32).is_nan());
        assert_eq!(out[3] as u32, 0.0f32.to_bits());
        // x is the divisor significand in Q2.60: both divisors are 2.0 →
        // significand 1.0.
        assert_eq!(lanes.x, vec![1u64 << 60; 2]);
    }

    #[test]
    fn seed_power_match_reciprocal_fast_per_lane() {
        let cfg = TaylorConfig::paper_default(60);
        let f = cfg.frac_bits;
        let xs: Vec<u64> = (0..17)
            .map(|i| (1u64 << 60) + i * ((1u64 << 60) / 17) + 4321)
            .map(|x| x.min((1u64 << 61) - 1))
            .collect();
        let mut cache = crate::simd::BiasedEdges::new();
        cache.rebuild(&cfg.table.edges);
        for eng in crate::simd::engines_available() {
            let mut y0 = Vec::new();
            let mut m = Vec::new();
            let mut pow = Vec::new();
            let mut sum = Vec::new();
            let mut recip = Vec::new();
            let mut be = ExactMul::default();
            seed(eng, &cfg.table, &cache, &xs, &mut y0);
            power(eng, &mut be, f, cfg.order, &xs, &y0, &mut m, &mut pow, &mut sum, &mut recip);
            for (i, &x) in xs.iter().enumerate() {
                let mut be2 = ExactMul::default();
                assert_eq!(
                    recip[i],
                    reciprocal_fast(&cfg, &mut be2, x),
                    "{} lane {i}",
                    eng.name()
                );
            }
        }
    }

    #[test]
    fn power_handles_m_zero_lane_like_scalar() {
        // x exactly at a segment midpoint-ish value can give m = 0; the
        // branch-light stage must still produce the scalar result.
        let cfg = TaylorConfig::paper_default(60);
        let f = cfg.frac_bits;
        // Probe many x and keep whichever produce m = 0 alongside
        // ordinary lanes; even if none hit exactly 0, identity holds.
        let xs: Vec<u64> = (0..64)
            .map(|i| (1u64 << 60) + i * ((1u64 << 54) + 7))
            .collect();
        let mut cache = crate::simd::BiasedEdges::new();
        cache.rebuild(&cfg.table.edges);
        for eng in crate::simd::engines_available() {
            let mut y0 = Vec::new();
            let (mut m, mut pow, mut sum, mut recip) =
                (Vec::new(), Vec::new(), Vec::new(), Vec::new());
            let mut be = ExactMul::default();
            seed(eng, &cfg.table, &cache, &xs, &mut y0);
            power(eng, &mut be, f, cfg.order, &xs, &y0, &mut m, &mut pow, &mut sum, &mut recip);
            for (i, &x) in xs.iter().enumerate() {
                let mut be2 = ExactMul::default();
                assert_eq!(
                    recip[i],
                    reciprocal_fast(&cfg, &mut be2, x),
                    "{} lane {i}",
                    eng.name()
                );
            }
        }
    }
}
