//! The Taylor-series reciprocal engine (paper §2, eq 9–12; system Fig 7).
//!
//! Given a significand `x ∈ [1, 2)` and a seed `y0 ≈ 1/x` from the PLA
//! unit, eq (11) refines the reciprocal:
//!
//! `1/x ≈ y0 · (1 + m + m² + … + m^n)` with `m = 1 − x·y0` (eq 16).
//!
//! The powers of `m` come from the powering unit (§6) — even powers on
//! the squaring unit, odd powers on the ILM with cached operand — and an
//! accumulator sums them (Fig 7). Everything below is fixed-point Q2.F
//! with truncating multiplies, mirroring the datapath; the multiplier
//! backend is pluggable (exact vs ILM with a correction budget) so the
//! benches can sweep the accuracy/hardware tradeoff.

use crate::pla::SegmentTable;
use crate::powering::{Multiplier, OpCounts, PoweringUnit, PowersScratch};
use crate::util::error::Result;

/// Configuration of the reciprocal datapath.
#[derive(Clone, Debug)]
pub struct TaylorConfig {
    /// Highest Taylor power `n` (the paper's "number of iterations").
    pub order: u32,
    /// Fixed-point fraction bits of the datapath (Q2.F).
    pub frac_bits: u32,
    /// PLA seed table (shares the same `frac_bits`).
    pub table: SegmentTable,
}

impl TaylorConfig {
    /// The paper's headline configuration: Table-I segments (n = 5,
    /// 53-bit target) at a given datapath width.
    ///
    /// Panics only on an invalid datapath width or an unsatisfiable
    /// derivation; fallible construction paths (service start) use
    /// [`Self::try_paper_default`].
    pub fn paper_default(frac_bits: u32) -> Self {
        Self::try_paper_default(frac_bits).expect("paper Table-I Taylor configuration")
    }

    /// Fallible [`Self::paper_default`]: segment derivation and table
    /// build errors propagate instead of aborting — the division
    /// service builds its workers' datapath through this, so a bad
    /// configuration is a rejected `DivisionService::start`.
    pub fn try_paper_default(frac_bits: u32) -> Result<Self> {
        let bounds = crate::pla::derive_segments(5, 53)?;
        Ok(Self {
            order: 5,
            frac_bits,
            table: SegmentTable::try_build(&bounds, frac_bits)?,
        })
    }

    /// Arbitrary (order, segments) configuration at `frac_bits`.
    /// Panicking wrapper over [`Self::try_with_segments`].
    pub fn with_segments(order: u32, pr_max: u32, frac_bits: u32) -> Self {
        Self::try_with_segments(order, pr_max, frac_bits).expect("Taylor configuration")
    }

    /// Fallible [`Self::with_segments`].
    pub fn try_with_segments(order: u32, pr_max: u32, frac_bits: u32) -> Result<Self> {
        let bounds = crate::pla::derive_segments(order, pr_max)?;
        Ok(Self {
            order,
            frac_bits,
            table: SegmentTable::try_build(&bounds, frac_bits)?,
        })
    }
}

/// Diagnostics-bearing result of a reciprocal computation.
#[derive(Clone, Debug)]
pub struct RecipResult {
    /// `1/x` in Q2.F.
    pub recip: u64,
    /// PLA segment used.
    pub segment: usize,
    /// `m = 1 − x·y0` in Q2.F.
    pub m: u64,
    /// Powering-unit cycles consumed (Fig 6 schedule).
    pub powering_cycles: u32,
    /// Multiplier/squarer op counts for this reciprocal.
    pub counts: OpCounts,
}

/// The reciprocal engine: PLA seed → powering unit → accumulator →
/// final multiply (Fig 7 datapath).
pub struct TaylorEngine<'m, M: Multiplier + ?Sized> {
    pub cfg: TaylorConfig,
    backend: &'m mut M,
    /// Powering-unit buffers reused across reciprocals (§Perf: the
    /// diagnostic path allocates once per engine, not once per op).
    scratch: PowersScratch,
}

impl<'m, M: Multiplier + ?Sized> TaylorEngine<'m, M> {
    pub fn new(cfg: TaylorConfig, backend: &'m mut M) -> Self {
        assert_eq!(
            cfg.frac_bits, cfg.table.frac_bits,
            "table and datapath widths must agree"
        );
        Self {
            cfg,
            backend,
            scratch: PowersScratch::new(),
        }
    }

    /// Compute `1/x` for `x ∈ [1, 2)` in Q2.F.
    pub fn reciprocal(&mut self, x: u64) -> RecipResult {
        reciprocal_fixed_with(&self.cfg, self.backend, x, &mut self.scratch)
    }

    /// Float-domain convenience wrapper for analysis code: `x ∈ [1,2)`.
    pub fn reciprocal_f64(&mut self, x: f64) -> f64 {
        let f = self.cfg.frac_bits;
        let one = 1u64 << f;
        let scale = (1u128 << f) as f64;
        // Clamp both ends of the Q2.F domain: rounding `x * scale` can
        // land exactly on 2.0 (e.g. x = 1.999…9), which the datapath's
        // [1, 2) interval excludes.
        let xq = ((x * scale) as u64).clamp(one, (one << 1) - 1);
        let r = self.reciprocal(xq);
        r.recip as f64 / scale
    }
}

/// Free-function core of the reciprocal datapath — allocating
/// convenience over [`reciprocal_fixed_with`] for one-off calls.
pub fn reciprocal_fixed<M: Multiplier + ?Sized>(
    cfg: &TaylorConfig,
    backend: &mut M,
    x: u64,
) -> RecipResult {
    let mut scratch = PowersScratch::new();
    reciprocal_fixed_with(cfg, backend, x, &mut scratch)
}

/// The diagnostic reciprocal datapath with caller-owned powering buffers
/// — no per-op allocation once `scratch` has warmed up. The divider hot
/// path uses [`reciprocal_fast`] instead; this path additionally reports
/// segment/m/cycle/op-count diagnostics.
///
/// Steps (Fig 7): PLA seed → `m = 1 − x·y0` → powering unit → accumulator
/// → final multiply.
pub fn reciprocal_fixed_with<M: Multiplier + ?Sized>(
    cfg: &TaylorConfig,
    backend: &mut M,
    x: u64,
    scratch: &mut PowersScratch,
) -> RecipResult {
    let f = cfg.frac_bits;
    let one = 1u64 << f;
    debug_assert!(x >= one && x < (one << 1), "x must be in [1,2) Q2.F");
    let before = backend.counts();

    // 1. Seed from the PLA unit (compare tree + one multiply).
    let (y0, segment) = cfg.table.seed(x);

    // 2. m = 1 − x·y0, saturating at 0: the analytic m is ≥ 0
    //    (m(x) = (1 − 2x/(a+b))²); truncation may push the fixed-point
    //    value a hair negative, which hardware clamps.
    let t = (backend.mul(x, y0) >> f) as u64;
    let m = one.saturating_sub(t);

    // 3. Powers m² … m^n from the powering unit (Fig 6 schedule).
    let (sum, cycles) = if cfg.order == 0 || m == 0 {
        (one, 0)
    } else if cfg.order == 1 {
        (one + m, 0)
    } else {
        let mut pu = PoweringUnit::new(backend, f);
        let (cycles, _counts) = pu.compute_powers_into(m, cfg.order, scratch);
        // 4. Accumulator: S = 1 + Σ m^k.
        let mut s = one as u128;
        for &p in &scratch.powers {
            s += p as u128;
        }
        (s as u64, cycles)
    };

    // 5. recip = y0 · S (final multiply of Fig 7).
    let recip = (backend.mul(y0, sum) >> f) as u64;

    let mut counts = backend.counts();
    counts.muls -= before.muls;
    counts.squares -= before.squares;
    counts.pe_ops -= before.pe_ops;
    counts.pe_cache_hits -= before.pe_cache_hits;

    RecipResult {
        recip,
        segment,
        m,
        powering_cycles: cycles,
        counts,
    }
}

/// Maximum Taylor order served by the allocation-free fast path.
pub const MAX_FAST_ORDER: u32 = 24;

/// Allocation-free reciprocal — the divider's scalar hot path (§Perf
/// step 1).
///
/// Numerically identical to [`reciprocal_fixed`] (same §6 power schedule:
/// even powers squared from the half power, odd powers multiplied by the
/// cached base), but with a fixed-size power buffer, no schedule trace
/// and no op-count bookkeeping. Call through a concrete `M` so the
/// multiplies monomorphize (§Perf step 2).
///
/// The batch counterpart is the staged SoA kernel
/// ([`crate::kernel::stages::power`]), which runs this exact operation
/// sequence per lane with the loops transposed (per stage over a lane
/// tile) — a property test pins the two bit-identical.
#[inline]
pub fn reciprocal_fast<M: Multiplier>(cfg: &TaylorConfig, backend: &mut M, x: u64) -> u64 {
    let f = cfg.frac_bits;
    let one = 1u64 << f;
    debug_assert!(x >= one && x < (one << 1));
    debug_assert!(cfg.order <= MAX_FAST_ORDER);

    let (y0, _) = cfg.table.seed(x);
    let t = (backend.mul_hot(x, y0) >> f) as u64;
    let m = one.saturating_sub(t);

    let mut sum = one as u128;
    if m != 0 && cfg.order >= 1 {
        if cfg.order == 5 {
            // Straight-line §6 schedule for the paper's headline order
            // (§Perf step 4: no loop-carried parity branch).
            let m2 = (backend.square_hot(m) >> f) as u64;
            let m3 = (backend.mul_hot(m2, m) >> f) as u64;
            let m4 = (backend.square_hot(m2) >> f) as u64;
            let m5 = (backend.mul_hot(m4, m) >> f) as u64;
            sum += m as u128 + m2 as u128 + m3 as u128 + m4 as u128 + m5 as u128;
        } else {
            let mut powers = [0u64; MAX_FAST_ORDER as usize];
            powers[0] = m;
            sum += m as u128;
            for p in 2..=cfg.order {
                let v = if p % 2 == 0 {
                    // Even power: squaring unit on x^(p/2).
                    (backend.square_hot(powers[(p / 2 - 1) as usize]) >> f) as u64
                } else {
                    // Odd power: multiplier with the cached base operand.
                    (backend.mul_hot(powers[(p - 2) as usize], m) >> f) as u64
                };
                powers[(p - 1) as usize] = v;
                sum += v as u128;
            }
        }
    }
    (backend.mul_hot(y0, sum as u64) >> f) as u64
}

/// The analytic error term of eq (12): `E_n = m^(n+1) / (1 − ξ)^(n+2)`
/// evaluated at the worst admissible `ξ = m` (upper bound).
pub fn analytic_error_bound(m: f64, n: u32) -> f64 {
    m.powi(n as i32 + 1) / (1.0 - m).powi(n as i32 + 2)
}

/// The truncated geometric sum `y0·Σ_{k≤n} m^k` in exact f64 arithmetic —
/// the infinite-precision reference of eq (11), used to separate
/// *method* error (Taylor truncation) from *datapath* error (fixed point,
/// ILM) in the analysis layer.
pub fn taylor_reference(x: f64, y0: f64, n: u32) -> f64 {
    let m = 1.0 - x * y0;
    let mut sum = 1.0;
    let mut mk = 1.0;
    for _ in 0..n {
        mk *= m;
        sum += mk;
    }
    y0 * sum
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::check_that;
    use crate::powering::{ExactMul, IlmBackend};
    use crate::util::check::{forall, Config};

    const F: u32 = 60;

    fn engine_exact(order: u32) -> (TaylorConfig, ExactMul) {
        (
            TaylorConfig::with_segments(order, 53, F),
            ExactMul::default(),
        )
    }

    #[test]
    fn try_constructors_propagate_table_errors() {
        // frac_bits beyond Q2.61 cannot be represented: the fallible
        // chain reports it; the panicking wrappers are for known-good
        // literals only.
        assert!(TaylorConfig::try_paper_default(62).is_err());
        assert!(TaylorConfig::try_paper_default(60).is_ok());
        assert!(TaylorConfig::try_with_segments(5, 53, 62).is_err());
        let cfg = TaylorConfig::try_with_segments(5, 53, 60).unwrap();
        assert_eq!(cfg.order, 5);
        assert_eq!(cfg.table.num_segments(), 8);
    }

    #[test]
    fn reciprocal_of_one_is_one() {
        let (cfg, mut be) = engine_exact(5);
        let mut eng = TaylorEngine::new(cfg, &mut be);
        let one = 1u64 << F;
        let r = eng.reciprocal(one);
        // x = 1 is the worst point of segment 0: the eq-(17) method error
        // there is ≈ 2^-53 = 128 ulps of Q2.60 (the paper's bound is
        // *at most* 2^-53, attained at segment edges).
        let err = (r.recip as i128 - one as i128).unsigned_abs();
        assert!(err <= 160, "1/1 off by {err} ulps of Q2.{F}");
    }

    #[test]
    fn reaches_53_bit_precision_with_paper_config() {
        // Paper §3: 8 segments + n=5 ⇒ ≥53-bit reciprocal. With the exact
        // multiplier backend the only other error is fixed-point
        // truncation; allow a small multiple of 2^-60 for that.
        let (cfg, mut be) = engine_exact(5);
        let mut eng = TaylorEngine::new(cfg, &mut be);
        for xf in [1.0, 1.001, 1.098, 1.1, 1.33, 1.5, 1.75, 1.9, 1.999999] {
            let got = eng.reciprocal_f64(xf);
            let want = 1.0 / xf;
            let err = (got - want).abs();
            // The eq-(17) bound is ≤ 2^-53 inclusive (attained at segment
            // edges); allow 25 % headroom for fixed-point truncation.
            let bound = 2f64.powi(-53) * 1.25;
            assert!(
                err < bound,
                "x={xf}: err {err:.3e} ≥ 1.25·2^-53 (got {got}, want {want})"
            );
        }
    }

    #[test]
    fn property_53_bit_precision_random_x() {
        let (cfg, mut be) = engine_exact(5);
        let mut eng = TaylorEngine::new(cfg, &mut be);
        forall(Config::named("paper config reaches 2^-53").cases(400), |d| {
            let xf = d.f64_range(1.0, 1.999_999_9);
            let got = eng.reciprocal_f64(xf);
            let err = (got - 1.0 / xf).abs();
            check_that!(err < 2f64.powi(-53) * 1.25, "x={xf}: err {err:.3e}");
            Ok(())
        });
    }

    #[test]
    fn reciprocal_f64_clamps_both_domain_ends() {
        // x values that round to exactly 2.0 (or above/below the domain)
        // in Q2.F must clamp instead of tripping the [1,2) assertion.
        let (cfg, mut be) = engine_exact(5);
        let mut eng = TaylorEngine::new(cfg, &mut be);
        for x in [2.0, 1.999_999_999_999_999_9, 2.5, 1.0, 0.5] {
            let got = eng.reciprocal_f64(x);
            assert!(got.is_finite());
            // Clamped values still approximate the reciprocal of the
            // nearest in-domain point.
            let clamped = x.clamp(1.0, 2.0 - 2f64.powi(-(F as i32)));
            assert!(
                (got - 1.0 / clamped).abs() < 1e-9,
                "x={x}: got {got}, want ~{}",
                1.0 / clamped
            );
        }
    }

    #[test]
    fn scratch_path_matches_allocating_path() {
        let cfg = TaylorConfig::paper_default(60);
        let mut scratch = crate::powering::PowersScratch::new();
        for i in 0..200u64 {
            let x = (1u64 << 60) + i * ((1u64 << 60) / 200) + 999;
            let x = x.min((1u64 << 61) - 1);
            let mut b1 = ExactMul::default();
            let mut b2 = ExactMul::default();
            let alloc = reciprocal_fixed(&cfg, &mut b1, x);
            let reused = reciprocal_fixed_with(&cfg, &mut b2, x, &mut scratch);
            assert_eq!(alloc.recip, reused.recip, "x={x}");
            assert_eq!(alloc.segment, reused.segment);
            assert_eq!(alloc.m, reused.m);
            assert_eq!(alloc.powering_cycles, reused.powering_cycles);
            assert_eq!(alloc.counts, reused.counts);
        }
    }

    #[test]
    fn order_improves_error_until_floor() {
        let mut prev = f64::INFINITY;
        let x = 1.0941; // near a segment's left edge → m near max
        for order in 0..5 {
            let cfg = TaylorConfig::with_segments(5, 53, F);
            let cfg = TaylorConfig { order, ..cfg };
            let mut be = ExactMul::default();
            let mut eng = TaylorEngine::new(cfg, &mut be);
            let err = (eng.reciprocal_f64(x) - 1.0 / x).abs();
            assert!(
                err <= prev * 1.05 + 1e-18,
                "order {order}: err {err} worse than previous {prev}"
            );
            prev = err;
        }
    }

    #[test]
    fn single_segment_17_iterations_reaches_53_bits() {
        // Paper §3: one segment on [1,2] needs 17 iterations. Verify the
        // datapath achieves it at the worst point x = 1.
        let cfg = TaylorConfig {
            order: 17,
            frac_bits: F,
            table: SegmentTable::build(&[1.0, 2.0], F),
        };
        let mut be = ExactMul::default();
        let mut eng = TaylorEngine::new(cfg, &mut be);
        for xf in [1.0, 1.0001, 1.5, 1.99999] {
            let err = (eng.reciprocal_f64(xf) - 1.0 / xf).abs();
            assert!(err < 2f64.powi(-53) * 1.25, "x={xf}: err {err:.3e}");
        }
    }

    #[test]
    fn single_segment_fewer_iterations_fails_worst_case() {
        // With only 8 iterations on one segment the worst-case x=1 must
        // NOT reach 53 bits (bound says ~26 bits) — guards against the
        // test above passing vacuously.
        let cfg = TaylorConfig {
            order: 8,
            frac_bits: F,
            table: SegmentTable::build(&[1.0, 2.0], F),
        };
        let mut be = ExactMul::default();
        let mut eng = TaylorEngine::new(cfg, &mut be);
        let err = (eng.reciprocal_f64(1.0) - 1.0).abs();
        assert!(err > 2f64.powi(-53), "8 iterations should not suffice at x=1");
    }

    #[test]
    fn ilm_backend_with_full_budget_matches_exact() {
        let (cfg, mut be) = engine_exact(5);
        let mut eng = TaylorEngine::new(cfg.clone(), &mut be);
        let mut ilm = IlmBackend::new(64);
        let mut eng_ilm = TaylorEngine::new(cfg, &mut ilm);
        for xf in [1.01, 1.2, 1.55, 1.83] {
            let scale = (1u128 << F) as f64;
            let xq = (xf * scale) as u64;
            assert_eq!(
                eng.reciprocal(xq).recip,
                eng_ilm.reciprocal(xq).recip,
                "x={xf}"
            );
        }
    }

    #[test]
    fn ilm_iterations_sweep_degrades_gracefully() {
        // Fewer ILM corrections → more error, but still a valid
        // approximation (error < 2^-8 even with 4 corrections).
        let x = 1.37;
        let mut errs = Vec::new();
        for iters in [4u32, 8, 16, 64] {
            let cfg = TaylorConfig::with_segments(5, 53, F);
            let mut be = IlmBackend::new(iters);
            let mut eng = TaylorEngine::new(cfg, &mut be);
            errs.push((eng.reciprocal_f64(x) - 1.0 / x).abs());
        }
        assert!(errs[0] < 2f64.powi(-8));
        for w in errs.windows(2) {
            assert!(w[1] <= w[0] * 1.01 + 1e-18, "error rose with more ILM iters: {errs:?}");
        }
    }

    #[test]
    fn counts_and_cycles_reported() {
        let (cfg, mut be) = engine_exact(5);
        let mut eng = TaylorEngine::new(cfg, &mut be);
        let r = eng.reciprocal((1.4 * (1u64 << F) as f64) as u64);
        // order 5 → powering computes m^2..m^5: 2 squares (2,4), 2 muls
        // (3,5); plus the m multiply and the final multiply (the seed
        // multiply lives inside the PLA table, not the shared backend).
        assert_eq!(r.counts.squares, 2);
        assert_eq!(r.counts.muls, 2 + 2);
        assert_eq!(r.powering_cycles, 3); // x²; (x³,x⁴); (x⁵,—)
        assert!(r.m < 1 << F);
        assert!(r.segment < eng.cfg.table.num_segments());
    }

    #[test]
    fn analytic_error_bound_basics() {
        // Matches eq (12) shape: decreasing in n, increasing in m.
        assert!(analytic_error_bound(0.1, 3) < analytic_error_bound(0.1, 2));
        assert!(analytic_error_bound(0.2, 3) > analytic_error_bound(0.1, 3));
        // For [1,2] worst case m=1/9, n=17: below 2^-53… times ξ slack.
        let e = analytic_error_bound(1.0 / 9.0, 17);
        assert!(e < 2f64.powi(-49));
    }

    #[test]
    fn taylor_reference_converges_to_true_reciprocal() {
        let x = 1.618;
        let y0 = crate::pla::y0(x, 1.0, 2.0);
        let mut prev = f64::INFINITY;
        for n in [1u32, 3, 6, 12, 24] {
            let err = (taylor_reference(x, y0, n) - 1.0 / x).abs();
            // Allow f64 noise wobble once converged below ~1e-15.
            assert!(err <= prev + 1e-15, "error rose at n={n}");
            prev = err;
        }
        assert!(prev < 1e-12);
    }

    #[test]
    fn datapath_error_splits_into_method_plus_truncation() {
        // With the exact backend, |datapath − reference| ≤ a few dozen
        // Q2.60 ulps (truncation only).
        let (cfg, mut be) = engine_exact(5);
        let table = cfg.table.clone();
        let mut eng = TaylorEngine::new(cfg, &mut be);
        forall(Config::named("datapath ≈ reference").cases(200), |d| {
            let x = d.f64_range(1.0, 1.999_999);
            let y0q = table.seed_f64(x);
            let reference = taylor_reference(x, y0q, 5);
            let got = eng.reciprocal_f64(x);
            // The f64 reference itself carries ~2^-53 arithmetic noise on
            // values near 1, which dominates the Q2.60 truncation.
            let tol = 100.0 / (1u128 << F) as f64 + 4.0 * 2f64.powi(-53);
            check_that!(
                (got - reference).abs() < tol,
                "x={x}: datapath {got} vs reference {reference}"
            );
            Ok(())
        });
    }

    #[test]
    fn fast_path_bit_identical_to_diagnostic_path() {
        let cfg = TaylorConfig::paper_default(60);
        for be_iters in [None, Some(2u32), Some(8)] {
            for i in 0..500u64 {
                let x = (1u64 << 60) + i * ((1u64 << 60) / 500) + 12345;
                let x = x.min((1u64 << 61) - 1);
                let (slow, fast) = match be_iters {
                    None => {
                        let mut b1 = ExactMul::default();
                        let mut b2 = ExactMul::default();
                        (
                            reciprocal_fixed(&cfg, &mut b1, x).recip,
                            reciprocal_fast(&cfg, &mut b2, x),
                        )
                    }
                    Some(k) => {
                        let mut b1 = IlmBackend::new(k);
                        let mut b2 = IlmBackend::new(k);
                        (
                            reciprocal_fixed(&cfg, &mut b1, x).recip,
                            reciprocal_fast(&cfg, &mut b2, x),
                        )
                    }
                };
                assert_eq!(slow, fast, "x={x} backend={be_iters:?}");
            }
        }
    }
}
