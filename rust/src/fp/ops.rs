//! Soft-float operations and ULP metrics.
//!
//! Only what the divider pipeline and the analysis layer need: an exact
//! soft multiply (used for the final `a · (1/b)` stage and the
//! Newton/Goldschmidt baselines), ULP distance, and neighbour stepping.

use super::format::{unpack, Class, Format, F32};
use super::round::{round_pack, Rounding};

/// IEEE-754 multiplication in an arbitrary format, correctly rounded.
pub fn soft_mul(a_bits: u64, b_bits: u64, fmt: Format, rm: Rounding) -> u64 {
    let a = unpack(a_bits, fmt);
    let b = unpack(b_bits, fmt);
    let sign = a.sign ^ b.sign;
    use Class::*;
    match (a.class, b.class) {
        (NaN, _) | (_, NaN) => fmt.nan(),
        (Inf, Zero) | (Zero, Inf) => fmt.nan(),
        (Inf, _) | (_, Inf) => fmt.inf(sign),
        (Zero, _) | (_, Zero) => fmt.zero(sign),
        _ => {
            // Both (sub)normal, normalized sig in [1,2) at frac_bits.
            let prod = a.sig as u128 * b.sig as u128; // [1,4) at 2·frac_bits
            let exp = a.exp + b.exp;
            round_pack(sign, exp, prod, 2 * fmt.frac_bits, false, fmt, rm).0
        }
    }
}

/// Convert an f32 value into `fmt`'s bit pattern, correctly rounded to
/// nearest-even (with gradual underflow and overflow-to-Inf) — the
/// client-side encoder for mixed-precision [`crate::coordinator`]
/// requests (e.g. packing f32 model values into bf16/f16 lanes).
pub fn encode_f32(x: f32, fmt: Format) -> u64 {
    let u = unpack(x.to_bits() as u64, F32);
    match u.class {
        Class::NaN => fmt.nan(),
        Class::Inf => fmt.inf(u.sign),
        Class::Zero => fmt.zero(u.sign),
        _ => round_pack(
            u.sign,
            u.exp,
            u.sig as u128,
            F32.frac_bits,
            false,
            fmt,
            Rounding::NearestEven,
        )
        .0,
    }
}

/// Decode `fmt` bits into an f32. Exact for f16/bf16 (every value is
/// representable in binary32); f64 values round to the nearest f32 and
/// may overflow to ±Inf.
pub fn decode_f32(bits: u64, fmt: Format) -> f32 {
    let u = unpack(bits, fmt);
    match u.class {
        Class::NaN => f32::NAN,
        Class::Inf => {
            if u.sign {
                f32::NEG_INFINITY
            } else {
                f32::INFINITY
            }
        }
        Class::Zero => {
            if u.sign {
                -0.0
            } else {
                0.0
            }
        }
        _ => {
            // sig is ≤ 53 bits → exact as f64; the scale stays finite
            // for every interchange format.
            let mag = u.sig as f64 * 2f64.powi(u.exp - fmt.frac_bits as i32);
            let v = mag as f32;
            if u.sign {
                -v
            } else {
                v
            }
        }
    }
}

/// The order-preserving integer key for a floating-point pattern:
/// monotone in the real ordering (−Inf .. +Inf), used for ULP distances.
/// NaN has no key.
pub fn ordered_key(bits: u64, fmt: Format) -> Option<i128> {
    let u = unpack(bits, fmt);
    if u.class == Class::NaN {
        return None;
    }
    let bits = bits & fmt.width_mask();
    let mag = (bits & !fmt.sign_mask()) as i128;
    Some(if fmt.sign_field(bits) { -mag } else { mag })
}

/// Distance in ULPs between two same-format patterns (absolute value of
/// the difference of their ordered keys). `None` if either is NaN.
/// Note ±0 are 0 ULPs apart.
pub fn ulp_diff(a_bits: u64, b_bits: u64, fmt: Format) -> Option<u64> {
    let ka = ordered_key(a_bits, fmt)?;
    let kb = ordered_key(b_bits, fmt)?;
    Some((ka - kb).unsigned_abs() as u64)
}

/// ULP distance for f32 values (convenience).
pub fn ulp_diff_f32(a: f32, b: f32) -> Option<u64> {
    ulp_diff(a.to_bits() as u64, b.to_bits() as u64, super::format::F32)
}

/// ULP distance for f64 values (convenience).
pub fn ulp_diff_f64(a: f64, b: f64) -> Option<u64> {
    ulp_diff(a.to_bits(), b.to_bits(), super::format::F64)
}

/// The next representable value toward +Inf (finite inputs; saturates at Inf).
pub fn next_up(bits: u64, fmt: Format) -> u64 {
    let u = unpack(bits, fmt);
    match u.class {
        Class::NaN => fmt.nan(),
        Class::Inf => {
            if u.sign {
                fmt.max_finite(true)
            } else {
                bits
            }
        }
        _ => {
            let bits = bits & fmt.width_mask();
            if bits == fmt.zero(true) {
                // -0 → +smallest subnormal? IEEE nextUp(-0) = +min_subnormal
                fmt.assemble(false, 0, 1)
            } else if fmt.sign_field(bits) {
                (bits - 1) & fmt.width_mask()
            } else {
                bits + 1
            }
        }
    }
}

/// The next representable value toward −Inf.
pub fn next_down(bits: u64, fmt: Format) -> u64 {
    let u = unpack(bits, fmt);
    match u.class {
        Class::NaN => fmt.nan(),
        Class::Inf => {
            if u.sign {
                bits
            } else {
                fmt.max_finite(false)
            }
        }
        _ => {
            let bits = bits & fmt.width_mask();
            if bits == fmt.zero(false) {
                fmt.assemble(true, 0, 1)
            } else if fmt.sign_field(bits) {
                bits + 1
            } else {
                bits - 1
            }
        }
    }
}

/// Relative error |x − reference| / |reference| computed in f64,
/// tolerant of zero references (returns absolute error then).
pub fn rel_err(x: f64, reference: f64) -> f64 {
    if reference == 0.0 {
        x.abs()
    } else {
        ((x - reference) / reference).abs()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp::format::{F32, F64};
    use crate::util::rng::Rng;

    fn mul32(a: f32, b: f32) -> f32 {
        f32::from_bits(soft_mul(
            a.to_bits() as u64,
            b.to_bits() as u64,
            F32,
            Rounding::NearestEven,
        ) as u32)
    }

    #[test]
    fn mul_exact_cases() {
        assert_eq!(mul32(2.0, 3.0), 6.0);
        assert_eq!(mul32(-2.0, 3.0), -6.0);
        assert_eq!(mul32(0.5, 0.5), 0.25);
        assert_eq!(mul32(1.5, 1.5), 2.25);
    }

    #[test]
    fn mul_specials() {
        assert!(mul32(f32::NAN, 1.0).is_nan());
        assert!(mul32(f32::INFINITY, 0.0).is_nan());
        assert_eq!(mul32(f32::INFINITY, -2.0), f32::NEG_INFINITY);
        assert_eq!(mul32(0.0, -3.0), -0.0);
        assert!(mul32(0.0, -3.0).is_sign_negative());
        assert_eq!(mul32(f32::MAX, 2.0), f32::INFINITY);
    }

    #[test]
    fn mul_matches_hardware_randomized() {
        let mut r = Rng::new(42);
        for _ in 0..20_000 {
            let a = f32::from_bits(r.next_u32());
            let b = f32::from_bits(r.next_u32());
            let ours = mul32(a, b);
            let hw = a * b;
            if hw.is_nan() {
                assert!(ours.is_nan(), "{a:?} * {b:?}: expected NaN, got {ours:?}");
            } else {
                assert_eq!(
                    ours.to_bits(),
                    hw.to_bits(),
                    "{a:?} * {b:?}: got {ours:?}, want {hw:?}"
                );
            }
        }
    }

    #[test]
    fn mul_subnormal_results_match_hardware() {
        let mut r = Rng::new(7);
        for _ in 0..20_000 {
            // Small operands likely to underflow.
            let a = f32::from_bits(r.next_u32() & 0x0FFF_FFFF);
            let b = f32::from_bits(r.next_u32() & 0x0FFF_FFFF);
            let ours = mul32(a, b);
            let hw = a * b;
            assert_eq!(ours.to_bits(), hw.to_bits(), "{a:e} * {b:e}");
        }
    }

    #[test]
    fn mul_f64_matches_hardware_randomized() {
        let mut r = Rng::new(43);
        for _ in 0..10_000 {
            let a = f64::from_bits(r.next_u64());
            let b = f64::from_bits(r.next_u64());
            let ours = f64::from_bits(soft_mul(
                a.to_bits(),
                b.to_bits(),
                F64,
                Rounding::NearestEven,
            ));
            let hw = a * b;
            if hw.is_nan() {
                assert!(ours.is_nan());
            } else {
                assert_eq!(ours.to_bits(), hw.to_bits(), "{a:?} * {b:?}");
            }
        }
    }

    #[test]
    fn encode_decode_f32_roundtrip_known_patterns() {
        use crate::fp::format::{BF16, F16};
        // 1.0 / 1.5 / 6.0 / 3.0 in each 16-bit format's own encoding.
        assert_eq!(encode_f32(1.0, F16), 0x3C00);
        assert_eq!(encode_f32(6.0, F16), 0x4600);
        assert_eq!(encode_f32(1.0, BF16), 0x3F80);
        assert_eq!(encode_f32(-1.5, BF16), 0xBFC0);
        assert_eq!(decode_f32(0x4200, F16), 3.0);
        assert_eq!(decode_f32(0x4040, BF16), 3.0);
        // Specials survive both directions.
        assert!(decode_f32(encode_f32(f32::NAN, F16), F16).is_nan());
        assert_eq!(decode_f32(encode_f32(f32::INFINITY, BF16), BF16), f32::INFINITY);
        assert_eq!(
            decode_f32(encode_f32(-0.0, F16), F16).to_bits(),
            (-0.0f32).to_bits()
        );
        // f32::MAX overflows bf16's finite range at nearest → Inf.
        assert_eq!(encode_f32(f32::MAX, BF16), BF16.inf(false));
        // f16 subnormal decodes exactly.
        assert_eq!(decode_f32(1, F16), 2f32.powi(-24));
    }

    #[test]
    fn encode_decode_f32_roundtrip_randomized_16bit() {
        use crate::fp::format::{BF16, F16};
        // decode(encode(decode(p))) must be the identity on every 16-bit
        // pattern (16-bit values are exact in f32), modulo NaN payloads.
        for fmt in [F16, BF16] {
            for p in 0u64..=0xFFFF {
                let v = decode_f32(p, fmt);
                if v.is_nan() {
                    assert!(decode_f32(encode_f32(v, fmt), fmt).is_nan());
                    continue;
                }
                let back = encode_f32(v, fmt);
                assert_eq!(back, p, "{} pattern {p:#06x} → {v:?} → {back:#06x}", fmt.name());
            }
        }
    }

    #[test]
    fn encode_f32_rounds_to_nearest_in_bf16() {
        use crate::fp::format::BF16;
        // 1 + 2^-8 is exactly between bf16(1.0) and bf16(1 + 2^-7):
        // ties-to-even keeps 1.0; anything above the tie rounds up.
        let tie = 1.0 + 2f32.powi(-8);
        assert_eq!(encode_f32(tie, BF16), 0x3F80);
        let above = f32::from_bits(tie.to_bits() + 1);
        assert_eq!(encode_f32(above, BF16), 0x3F81);
    }

    #[test]
    fn ulp_diff_basics() {
        assert_eq!(ulp_diff_f32(1.0, 1.0), Some(0));
        assert_eq!(ulp_diff_f32(1.0, f32::from_bits(1.0f32.to_bits() + 1)), Some(1));
        assert_eq!(ulp_diff_f32(0.0, -0.0), Some(0));
        assert_eq!(ulp_diff_f32(f32::NAN, 1.0), None);
        // Across zero: ±min_subnormal are 2 ulps apart.
        let tiny = f32::from_bits(1);
        assert_eq!(ulp_diff_f32(tiny, -tiny), Some(2));
    }

    #[test]
    fn next_up_down_roundtrip() {
        for x in [1.0f32, -1.0, 0.0, f32::MAX, f32::MIN_POSITIVE, -2.5e-40] {
            let bits = x.to_bits() as u64;
            let up = next_up(bits, F32);
            assert_eq!(next_down(up, F32), bits, "x={x}");
            let ux = f32::from_bits(up as u32);
            assert!(ux > x, "next_up({x}) = {ux} not greater");
        }
    }

    #[test]
    fn next_up_saturates_at_inf() {
        let inf = F32.inf(false);
        assert_eq!(next_up(inf, F32), inf);
        assert_eq!(next_up(F32.max_finite(false), F32), inf);
    }

    #[test]
    fn ordered_key_monotone_randomized() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            let a = f32::from_bits(r.next_u32());
            let b = f32::from_bits(r.next_u32());
            if a.is_nan() || b.is_nan() {
                continue;
            }
            let ka = ordered_key(a.to_bits() as u64, F32).unwrap();
            let kb = ordered_key(b.to_bits() as u64, F32).unwrap();
            match a.partial_cmp(&b).unwrap() {
                std::cmp::Ordering::Less => assert!(ka < kb || (a == b)),
                std::cmp::Ordering::Greater => assert!(ka > kb || (a == b)),
                std::cmp::Ordering::Equal => {
                    // ±0 compare equal but keys both 0
                    assert_eq!(ka, kb)
                }
            }
        }
    }

    #[test]
    fn rel_err_zero_reference() {
        assert_eq!(rel_err(0.25, 0.0), 0.25);
        assert_eq!(rel_err(1.01, 1.0), 0.010000000000000009);
    }
}
