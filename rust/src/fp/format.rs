//! IEEE-754 binary format descriptors and bit-level pack/unpack.
//!
//! All bit patterns are carried in `u64` regardless of format width so
//! one code path serves binary16/bfloat16/binary32/binary64.

/// An IEEE-754 binary interchange format.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Format {
    /// Exponent field width in bits.
    pub exp_bits: u32,
    /// Fraction (trailing significand) field width in bits.
    pub frac_bits: u32,
}

/// binary32 (f32).
pub const F32: Format = Format {
    exp_bits: 8,
    frac_bits: 23,
};

/// binary64 (f64).
pub const F64: Format = Format {
    exp_bits: 11,
    frac_bits: 52,
};

/// binary16 (half).
pub const F16: Format = Format {
    exp_bits: 5,
    frac_bits: 10,
};

/// bfloat16.
pub const BF16: Format = Format {
    exp_bits: 8,
    frac_bits: 7,
};

impl Format {
    /// Total storage width (sign + exponent + fraction).
    pub const fn width(&self) -> u32 {
        1 + self.exp_bits + self.frac_bits
    }

    /// Exponent bias (2^(exp_bits-1) − 1).
    pub const fn bias(&self) -> i32 {
        (1 << (self.exp_bits - 1)) - 1
    }

    /// Maximum biased exponent value (all ones = Inf/NaN).
    pub const fn exp_max(&self) -> u64 {
        (1 << self.exp_bits) - 1
    }

    /// Largest unbiased exponent of a finite normal number.
    pub const fn emax(&self) -> i32 {
        self.bias()
    }

    /// Smallest unbiased exponent of a normal number.
    pub const fn emin(&self) -> i32 {
        1 - self.bias()
    }

    /// Significand precision in bits (hidden bit + fraction).
    pub const fn precision(&self) -> u32 {
        self.frac_bits + 1
    }

    pub const fn sign_mask(&self) -> u64 {
        1 << (self.width() - 1)
    }

    pub const fn frac_mask(&self) -> u64 {
        (1 << self.frac_bits) - 1
    }

    pub const fn exp_field(&self, bits: u64) -> u64 {
        (bits >> self.frac_bits) & self.exp_max()
    }

    pub const fn frac_field(&self, bits: u64) -> u64 {
        bits & self.frac_mask()
    }

    pub const fn sign_field(&self, bits: u64) -> bool {
        bits & self.sign_mask() != 0
    }

    /// Assemble raw fields into a bit pattern.
    pub const fn assemble(&self, sign: bool, biased_exp: u64, frac: u64) -> u64 {
        ((sign as u64) << (self.width() - 1))
            | ((biased_exp & self.exp_max()) << self.frac_bits)
            | (frac & self.frac_mask())
    }

    /// Positive infinity bit pattern.
    pub const fn inf(&self, sign: bool) -> u64 {
        self.assemble(sign, self.exp_max(), 0)
    }

    /// Canonical quiet NaN.
    pub const fn nan(&self) -> u64 {
        self.assemble(false, self.exp_max(), 1 << (self.frac_bits - 1))
    }

    /// Signed zero.
    pub const fn zero(&self, sign: bool) -> u64 {
        self.assemble(sign, 0, 0)
    }

    /// Positive one (the implicit dividend of the reciprocal ops).
    pub const fn one(&self) -> u64 {
        self.assemble(false, self.bias() as u64, 0)
    }

    /// Largest finite magnitude with the given sign.
    pub const fn max_finite(&self, sign: bool) -> u64 {
        self.assemble(sign, self.exp_max() - 1, self.frac_mask())
    }

    /// Mask covering the whole storage width.
    pub const fn width_mask(&self) -> u64 {
        if self.width() == 64 {
            u64::MAX
        } else {
            (1u64 << self.width()) - 1
        }
    }

    /// Short name of the interchange formats ("f16", "bf16", "f32",
    /// "f64"); "custom" for any other field layout.
    pub const fn name(&self) -> &'static str {
        match (self.exp_bits, self.frac_bits) {
            (5, 10) => "f16",
            (8, 7) => "bf16",
            (8, 23) => "f32",
            (11, 52) => "f64",
            _ => "custom",
        }
    }

    /// Relative per-lane serving cost of this format in the batched
    /// datapath, in small integer units. The kernel runs every format
    /// through the same u64 stage loops, but the wider significands pay
    /// for it in unpack/round width, reciprocal precision actually
    /// consumed, and cache footprint — measured on the serving benches,
    /// a binary64 lane costs roughly **2×** a binary16/bfloat16 lane,
    /// with binary32 in between. The batcher meters its coalescing
    /// budget in these units ([`crate::coordinator::BatchAssembler`]),
    /// so an f64 bucket ships with fewer lanes than an f16 bucket of
    /// equal cost. Unknown field layouts are priced like f64
    /// (conservative: flush earlier, never starve the budget).
    pub const fn lane_cost(&self) -> usize {
        match (self.exp_bits, self.frac_bits) {
            (5, 10) | (8, 7) => 2, // f16, bf16
            (8, 23) => 3,          // f32
            _ => 4,                // f64 and custom layouts
        }
    }

    /// Parse a format name as accepted by the CLI and the service
    /// request constructors.
    pub fn from_name(s: &str) -> Option<Format> {
        match s {
            "f16" | "half" | "binary16" => Some(F16),
            "bf16" | "bfloat16" => Some(BF16),
            "f32" | "single" | "binary32" => Some(F32),
            "f64" | "double" | "binary64" => Some(F64),
            _ => None,
        }
    }
}

/// The four interchange formats the service accepts, smallest first.
pub const ALL_FORMATS: [Format; 4] = [F16, BF16, F32, F64];

/// Classification of a value.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Class {
    Zero,
    Subnormal,
    Normal,
    Inf,
    NaN,
}

/// A decoded value. For `Normal` and `Subnormal`, the significand is
/// normalized so that bit `frac_bits` is the leading 1 — i.e. the real
/// value is `(-1)^sign · (sig / 2^frac_bits) · 2^exp` with
/// `sig / 2^frac_bits ∈ [1, 2)`. Subnormals get an `exp` below `emin`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Unpacked {
    pub sign: bool,
    pub class: Class,
    /// Unbiased exponent of the normalized significand (Normal/Subnormal).
    pub exp: i32,
    /// Normalized significand with the hidden bit explicit at position
    /// `frac_bits` (Normal/Subnormal only; 0 otherwise).
    pub sig: u64,
}

/// Decode a bit pattern.
pub fn unpack(bits: u64, fmt: Format) -> Unpacked {
    let bits = bits & fmt.width_mask();
    let sign = fmt.sign_field(bits);
    let e = fmt.exp_field(bits);
    let f = fmt.frac_field(bits);
    if e == fmt.exp_max() {
        return Unpacked {
            sign,
            class: if f == 0 { Class::Inf } else { Class::NaN },
            exp: 0,
            sig: 0,
        };
    }
    if e == 0 {
        if f == 0 {
            return Unpacked {
                sign,
                class: Class::Zero,
                exp: 0,
                sig: 0,
            };
        }
        // Subnormal: value = f/2^frac_bits · 2^emin. Normalize.
        let shift = fmt.frac_bits as i32 - (63 - f.leading_zeros() as i32);
        debug_assert!(shift > 0);
        return Unpacked {
            sign,
            class: Class::Subnormal,
            exp: fmt.emin() - shift,
            sig: f << shift,
        };
    }
    Unpacked {
        sign,
        class: Class::Normal,
        exp: e as i32 - fmt.bias(),
        sig: f | (1 << fmt.frac_bits),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_constants() {
        assert_eq!(F32.width(), 32);
        assert_eq!(F32.bias(), 127);
        assert_eq!(F32.emin(), -126);
        assert_eq!(F32.emax(), 127);
        assert_eq!(F32.precision(), 24);
        assert_eq!(F32.sign_mask(), 0x8000_0000);
    }

    #[test]
    fn f64_constants() {
        assert_eq!(F64.width(), 64);
        assert_eq!(F64.bias(), 1023);
        assert_eq!(F64.emin(), -1022);
        assert_eq!(F64.precision(), 53);
        assert_eq!(F64.width_mask(), u64::MAX);
    }

    #[test]
    fn lane_costs_ordered_and_f64_twice_f16() {
        assert_eq!(F16.lane_cost(), BF16.lane_cost());
        assert!(F16.lane_cost() < F32.lane_cost());
        assert!(F32.lane_cost() < F64.lane_cost());
        assert_eq!(F64.lane_cost(), 2 * F16.lane_cost());
        // Custom layouts price like the widest format.
        let custom = Format {
            exp_bits: 6,
            frac_bits: 9,
        };
        assert_eq!(custom.lane_cost(), F64.lane_cost());
    }

    #[test]
    fn one_patterns_match_std() {
        assert_eq!(F32.one(), 1.0f32.to_bits() as u64);
        assert_eq!(F64.one(), 1.0f64.to_bits());
        assert_eq!(F16.one(), 0x3C00);
        assert_eq!(BF16.one(), 0x3F80);
    }

    #[test]
    fn special_patterns_match_std() {
        assert_eq!(F32.inf(false), f32::INFINITY.to_bits() as u64);
        assert_eq!(F32.inf(true), f32::NEG_INFINITY.to_bits() as u64);
        assert_eq!(F32.zero(true), (-0.0f32).to_bits() as u64);
        assert_eq!(F32.max_finite(false), f32::MAX.to_bits() as u64);
        assert_eq!(F64.inf(false), f64::INFINITY.to_bits());
        assert_eq!(F64.max_finite(true), f64::MIN.to_bits());
        // Our canonical NaN is *a* NaN per std
        assert!(f32::from_bits(F32.nan() as u32).is_nan());
    }

    #[test]
    fn unpack_one() {
        let u = unpack(1.0f32.to_bits() as u64, F32);
        assert_eq!(u.class, Class::Normal);
        assert_eq!(u.exp, 0);
        assert_eq!(u.sig, 1 << 23);
        assert!(!u.sign);
    }

    #[test]
    fn unpack_normals_f32() {
        for (x, exp) in [(2.0f32, 1), (0.5, -1), (1.5, 0), (3.0, 1), (0.75, -1)] {
            let u = unpack(x.to_bits() as u64, F32);
            assert_eq!(u.class, Class::Normal, "{x}");
            assert_eq!(u.exp, exp, "{x}");
            let val = u.sig as f64 / (1u64 << 23) as f64 * 2f64.powi(u.exp);
            assert_eq!(val as f32, x);
        }
    }

    #[test]
    fn unpack_negative() {
        let u = unpack((-2.5f32).to_bits() as u64, F32);
        assert!(u.sign);
        assert_eq!(u.exp, 1);
        let val = u.sig as f64 / (1u64 << 23) as f64 * 2.0;
        assert_eq!(val, 2.5);
    }

    #[test]
    fn unpack_specials() {
        assert_eq!(unpack(F32.inf(false), F32).class, Class::Inf);
        assert_eq!(unpack(F32.nan(), F32).class, Class::NaN);
        assert_eq!(unpack(0, F32).class, Class::Zero);
        assert_eq!(unpack(F32.sign_mask(), F32).class, Class::Zero);
    }

    #[test]
    fn unpack_subnormal_normalizes() {
        // Smallest positive subnormal f32: 2^-149.
        let u = unpack(1u64, F32);
        assert_eq!(u.class, Class::Subnormal);
        assert_eq!(u.sig, 1 << 23); // normalized hidden-one form
        assert_eq!(u.exp, -149);
        // A mid-range subnormal.
        let x = f32::from_bits(0x0040_0000); // 2^-127
        let u = unpack(x.to_bits() as u64, F32);
        assert_eq!(u.exp, -127);
        assert_eq!(u.sig, 1 << 23);
    }

    #[test]
    fn unpack_f16_and_bf16() {
        // 1.0 in f16 = 0x3C00; in bf16 = 0x3F80.
        let u = unpack(0x3C00, F16);
        assert_eq!((u.class, u.exp, u.sig), (Class::Normal, 0, 1 << 10));
        let u = unpack(0x3F80, BF16);
        assert_eq!((u.class, u.exp, u.sig), (Class::Normal, 0, 1 << 7));
    }

    #[test]
    fn assemble_roundtrip() {
        for bits in [0u64, 1, 0x3F80_0000, 0x7F80_0000, 0xFF80_0001, 0x1234_5678] {
            let s = F32.sign_field(bits);
            let e = F32.exp_field(bits);
            let f = F32.frac_field(bits);
            assert_eq!(F32.assemble(s, e, f), bits);
        }
    }
}
