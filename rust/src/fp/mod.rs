//! Soft IEEE-754 floating point.
//!
//! The divider (Fig 7 of the paper) needs full control over the
//! sign/exponent/significand datapath, so the crate carries its own
//! format-generic soft-float layer instead of relying on host FP:
//!
//! * [`format`] — format descriptors (binary16/bfloat16/binary32/binary64),
//!   field extraction, classification, normalization of subnormals;
//! * [`round`] — rounding of extended-precision results into a format
//!   under the four IEEE rounding-direction attributes;
//! * [`ops`] — correctly-rounded soft multiply, ULP metrics, neighbour
//!   stepping.
//!
//! All bit patterns travel as `u64` independent of format width.

pub mod format;
pub mod op;
pub mod ops;
pub mod round;

pub use format::{unpack, Class, Format, Unpacked, ALL_FORMATS, BF16, F16, F32, F64};
pub use op::Op;
pub use ops::{
    decode_f32, encode_f32, next_down, next_up, ordered_key, rel_err, soft_mul, ulp_diff,
    ulp_diff_f32, ulp_diff_f64,
};
pub use round::{round_pack, Rounding};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reexports_work_together() {
        // unpack → round_pack identity on a normal f32
        let x = 1.75f32;
        let u = unpack(x.to_bits() as u64, F32);
        assert_eq!(u.class, Class::Normal);
        let (bits, inexact) = round_pack(
            u.sign,
            u.exp,
            u.sig as u128,
            F32.frac_bits,
            false,
            F32,
            Rounding::NearestEven,
        );
        assert!(!inexact);
        assert_eq!(bits as u32, x.to_bits());
    }

    #[test]
    fn unpack_pack_roundtrip_randomized_all_finite() {
        use crate::util::rng::Rng;
        let mut r = Rng::new(31);
        let mut done = 0;
        while done < 50_000 {
            let x = f32::from_bits(r.next_u32());
            if !x.is_finite() || x == 0.0 {
                continue;
            }
            done += 1;
            let u = unpack(x.to_bits() as u64, F32);
            let (bits, inexact) = round_pack(
                u.sign,
                u.exp,
                u.sig as u128,
                F32.frac_bits,
                false,
                F32,
                Rounding::NearestEven,
            );
            assert!(!inexact, "roundtrip of representable value inexact: {x:?}");
            assert_eq!(bits as u32, x.to_bits(), "roundtrip failed for {x:?}");
        }
    }
}
