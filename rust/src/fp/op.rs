//! The typed operation dimension of the batch service.
//!
//! Everything the Taylor/ILM machinery computes goes through the same
//! reciprocal core (seed → simultaneous odd/even powers → sum), so the
//! service exposes the nearby operations as first-class variants instead
//! of special-casing `a/b`:
//!
//! * [`Op::Div`] — `a / b`, the paper's operation: reciprocal core plus
//!   one final multiply by the dividend significand;
//! * [`Op::Recip`] — `1 / a`, the core with the final multiply skipped;
//! * [`Op::Rsqrt`] — `1 / sqrt(a)`, the same seed/tiles plus a short
//!   Newton–Raphson tail on the lane engine;
//! * [`Op::ScaleByRecip`] — `a[i] / b[row]`, one reciprocal amortized
//!   across a whole row of lanes (the QR/Givens normalization pattern).
//!
//! The enum lives in `fp` (not `coordinator`) so the router — which
//! depends only on `fp`/`util`/`harness` — can key its scoring cells on
//! the op axis; `coordinator::request` re-exports it as part of the
//! service API.

/// Operation requested on a batch of lanes.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Op {
    /// Elementwise division `a[i] / b[i]` (two operand vectors of equal
    /// length).
    Div,
    /// Elementwise reciprocal `1 / a[i]` (one operand vector).
    Recip,
    /// Elementwise reciprocal square root `1 / sqrt(a[i])` (one operand
    /// vector).
    Rsqrt,
    /// Fused scale-by-reciprocal: `a` holds rows of lanes, `b` one
    /// divisor per row, and every lane of row `r` is divided by `b[r]`.
    /// One reciprocal is computed per row and broadcast-multiplied
    /// across the row's lanes.
    ScaleByRecip,
}

impl Op {
    /// All operations, in stable index order (test/bench sweeps and the
    /// router's cell table).
    pub const ALL: [Op; 4] = [Op::Div, Op::Recip, Op::Rsqrt, Op::ScaleByRecip];

    /// Stable dense index (router cell tables, service key slots).
    pub const fn idx(self) -> usize {
        match self {
            Op::Div => 0,
            Op::Recip => 1,
            Op::Rsqrt => 2,
            Op::ScaleByRecip => 3,
        }
    }

    /// Short name as accepted by [`Op::from_name`] (CLI `--op`).
    pub const fn name(self) -> &'static str {
        match self {
            Op::Div => "div",
            Op::Recip => "recip",
            Op::Rsqrt => "rsqrt",
            Op::ScaleByRecip => "scale-recip",
        }
    }

    /// Underscore-safe key spelling for bench-history JSON keys (which
    /// never contain hyphens): identical to [`Op::name`] except
    /// `ScaleByRecip`, whose CLI name is `scale-recip` but whose history
    /// rows are `scale_recip_*`. The router's history seeding and the
    /// serving bench must agree on this spelling, so both go through
    /// this accessor.
    pub const fn key_name(self) -> &'static str {
        match self {
            Op::ScaleByRecip => "scale_recip",
            _ => self.name(),
        }
    }

    /// Parse an operation name (CLI and service surfaces).
    pub fn from_name(s: &str) -> Option<Op> {
        match s {
            "div" | "divide" => Some(Op::Div),
            "recip" | "reciprocal" => Some(Op::Recip),
            "rsqrt" | "reciprocal-sqrt" => Some(Op::Rsqrt),
            "scale-recip" | "scale-by-recip" | "scale_by_recip" => Some(Op::ScaleByRecip),
            _ => None,
        }
    }

    /// True for the one-operand ops (`Recip`, `Rsqrt`) whose requests
    /// carry no `b` lanes at all.
    pub const fn is_unary(self) -> bool {
        matches!(self, Op::Recip | Op::Rsqrt)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_roundtrip_and_indices_are_dense() {
        for (i, op) in Op::ALL.iter().enumerate() {
            assert_eq!(op.idx(), i);
            assert_eq!(Op::from_name(op.name()), Some(*op));
        }
        assert_eq!(Op::from_name("divide"), Some(Op::Div));
        assert_eq!(Op::from_name("scale_by_recip"), Some(Op::ScaleByRecip));
        assert_eq!(Op::from_name("sqrt"), None);
    }

    #[test]
    fn key_names_are_underscore_safe() {
        for op in Op::ALL {
            assert!(
                !op.key_name().contains('-'),
                "{:?}: history keys must not contain hyphens",
                op
            );
        }
        assert_eq!(Op::Div.key_name(), "div");
        assert_eq!(Op::Recip.key_name(), "recip");
        assert_eq!(Op::Rsqrt.key_name(), "rsqrt");
        assert_eq!(Op::ScaleByRecip.key_name(), "scale_recip");
    }

    #[test]
    fn unary_ops_are_exactly_recip_and_rsqrt() {
        assert!(!Op::Div.is_unary());
        assert!(Op::Recip.is_unary());
        assert!(Op::Rsqrt.is_unary());
        assert!(!Op::ScaleByRecip.is_unary());
    }
}
