//! Rounding of an extended-precision result into a storage format.
//!
//! The divider and multipliers produce a sign, an unbiased exponent and a
//! significand carried in `u128` at some precision `q_frac_bits` (value =
//! sig / 2^q_frac_bits · 2^exp). [`round_pack`] normalizes, rounds under
//! the selected mode, and handles overflow to Inf and gradual underflow
//! to subnormals/zero.

use super::format::Format;

/// IEEE-754 rounding-direction attributes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Rounding {
    /// roundTiesToEven (the default).
    NearestEven,
    /// roundTowardZero.
    TowardZero,
    /// roundTowardPositive.
    TowardPositive,
    /// roundTowardNegative.
    TowardNegative,
}

impl Rounding {
    /// All four rounding-direction attributes (test/bench sweeps).
    pub const ALL: [Rounding; 4] = [
        Rounding::NearestEven,
        Rounding::TowardZero,
        Rounding::TowardPositive,
        Rounding::TowardNegative,
    ];

    /// Short name as accepted by [`Rounding::from_name`].
    pub const fn name(self) -> &'static str {
        match self {
            Rounding::NearestEven => "nearest",
            Rounding::TowardZero => "zero",
            Rounding::TowardPositive => "up",
            Rounding::TowardNegative => "down",
        }
    }

    /// Parse a rounding-mode name (CLI and service requests).
    pub fn from_name(s: &str) -> Option<Rounding> {
        match s {
            "nearest" | "ne" | "rne" | "nearest-even" => Some(Rounding::NearestEven),
            "zero" | "rtz" | "toward-zero" => Some(Rounding::TowardZero),
            "up" | "rtp" | "toward-positive" => Some(Rounding::TowardPositive),
            "down" | "rtn" | "toward-negative" => Some(Rounding::TowardNegative),
            _ => None,
        }
    }

    /// Should a magnitude with the given (guard, sticky) round up?
    /// `lsb_odd` is the parity of the kept LSB (for ties-to-even).
    #[inline]
    fn round_up(self, sign: bool, guard: bool, sticky: bool, lsb_odd: bool) -> bool {
        match self {
            Rounding::NearestEven => guard && (sticky || lsb_odd),
            Rounding::TowardZero => false,
            Rounding::TowardPositive => !sign && (guard || sticky),
            Rounding::TowardNegative => sign && (guard || sticky),
        }
    }
}

/// Round and pack a finite non-zero magnitude.
///
/// * `sign` — sign of the result;
/// * `exp` — unbiased exponent such that value = sig/2^q_frac_bits · 2^exp;
/// * `sig` — extended significand, **must be non-zero**;
/// * `q_frac_bits` — fractional bits in `sig`;
/// * `sticky_in` — true if already-discarded lower bits were non-zero.
///
/// Returns the format's bit pattern (Inf on overflow, ±0/subnormal on
/// underflow). The "inexact" status is returned alongside for tests.
pub fn round_pack(
    sign: bool,
    exp: i32,
    sig: u128,
    q_frac_bits: u32,
    sticky_in: bool,
    fmt: Format,
    rm: Rounding,
) -> (u64, bool) {
    assert!(sig != 0, "round_pack requires non-zero significand");
    // Normalize: shift so the MSB of sig sits at position q_frac_bits
    // (i.e. sig/2^q ∈ [1,2)).
    let msb = 127 - sig.leading_zeros() as i32;
    let mut exp = exp + (msb - q_frac_bits as i32);
    // We want the significand normalized with its hidden bit at position
    // `fmt.frac_bits`; the first dropped bit is the guard, everything
    // lower ORs into sticky.
    let shift = msb - fmt.frac_bits as i32; // bits to drop (may be ≤ 0)
    let (mut kept, guard, mut sticky) = if shift > 0 {
        let kept = (sig >> shift) as u64;
        // All dropped bits at the top of one word: guard is its MSB,
        // sticky any remaining bit (§Perf: one shift instead of building
        // a mask).
        let dropped = sig << (128 - shift as u32);
        let guard = (dropped >> 127) == 1;
        let sticky = sticky_in || (dropped << 1) != 0;
        (kept, guard, sticky)
    } else {
        ((sig as u64) << (-shift) as u32, false, sticky_in)
    };
    debug_assert!(kept >> fmt.frac_bits == 1, "normalization failed");

    // Gradual underflow: if exp < emin, shift right further into a
    // subnormal representation before rounding.
    if exp < fmt.emin() {
        let deficit = (fmt.emin() - exp) as u32;
        if deficit > fmt.frac_bits + 2 {
            // Entire value below half the smallest subnormal (or equal —
            // sticky decides). Round the tiny residue.
            let up = match rm {
                Rounding::NearestEven => false, // magnitude < 2^(emin-frac-1) tie impossible here
                Rounding::TowardZero => false,
                Rounding::TowardPositive => !sign,
                Rounding::TowardNegative => sign,
            };
            let bits = if up {
                fmt.assemble(sign, 0, 1)
            } else {
                fmt.zero(sign)
            };
            return (bits, true);
        }
        // Re-derive guard/sticky at the subnormal precision.
        let g2 = (kept >> (deficit - 1)) & 1 == 1;
        let below = kept & ((1u64 << (deficit - 1)) - 1);
        sticky = sticky || guard || below != 0;
        kept >>= deficit;
        let lsb_odd = kept & 1 == 1;
        let mut frac = kept;
        if rm.round_up(sign, g2, sticky, lsb_odd) {
            frac += 1;
        }
        let inexact = g2 || sticky;
        if frac >> fmt.frac_bits == 1 {
            // Rounded up into the smallest normal.
            return (fmt.assemble(sign, 1, 0), inexact);
        }
        return (fmt.assemble(sign, 0, frac), inexact);
    }

    // Normal-range rounding.
    let lsb_odd = kept & 1 == 1;
    let mut sig_rounded = kept;
    if rm.round_up(sign, guard, sticky, lsb_odd) {
        sig_rounded += 1;
        if sig_rounded >> (fmt.frac_bits + 1) == 1 {
            // Carry out of the significand: renormalize.
            sig_rounded >>= 1;
            exp += 1;
        }
    }
    let inexact = guard || sticky;

    if exp > fmt.emax() {
        // Overflow: Inf or max-finite depending on direction.
        let bits = match rm {
            Rounding::NearestEven => fmt.inf(sign),
            Rounding::TowardZero => fmt.max_finite(sign),
            Rounding::TowardPositive => {
                if sign {
                    fmt.max_finite(true)
                } else {
                    fmt.inf(false)
                }
            }
            Rounding::TowardNegative => {
                if sign {
                    fmt.inf(true)
                } else {
                    fmt.max_finite(false)
                }
            }
        };
        return (bits, true);
    }

    let biased = (exp + fmt.bias()) as u64;
    let frac = sig_rounded & fmt.frac_mask();
    (fmt.assemble(sign, biased, frac), inexact)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp::format::{F32, F64};

    fn pack_f32(sign: bool, exp: i32, sig: u128, q: u32, rm: Rounding) -> f32 {
        let (bits, _) = round_pack(sign, exp, sig, q, false, F32, rm);
        f32::from_bits(bits as u32)
    }

    #[test]
    fn exact_one() {
        assert_eq!(pack_f32(false, 0, 1 << 60, 60, Rounding::NearestEven), 1.0);
    }

    #[test]
    fn exact_unnormalized_input() {
        // 3.0 presented as 0b11 with q=1 (value 3.0 · 2^0? no: 3/2 · 2^1)
        assert_eq!(pack_f32(false, 1, 3, 1, Rounding::NearestEven), 3.0);
        // 0.5 presented denormalized high
        assert_eq!(pack_f32(false, -1, 1 << 40, 40, Rounding::NearestEven), 0.5);
    }

    #[test]
    fn ties_to_even() {
        // 1 + 2^-24 exactly between 1.0 and 1+2^-23 → rounds to even (1.0).
        let q = 40u32;
        let sig = (1u128 << q) + (1u128 << (q - 24));
        assert_eq!(pack_f32(false, 0, sig, q, Rounding::NearestEven), 1.0);
        // 1 + 3·2^-24 between 1+2^-23 and 1+2^-22 → rounds up to even.
        let sig = (1u128 << q) + 3 * (1u128 << (q - 24));
        assert_eq!(
            pack_f32(false, 0, sig, q, Rounding::NearestEven),
            1.0 + 2.0 * 2f32.powi(-23)
        );
    }

    #[test]
    fn sticky_breaks_tie_upward() {
        let q = 40u32;
        // 1 + 2^-24 + 2^-40: just above the tie → rounds up.
        let sig = (1u128 << q) + (1u128 << (q - 24)) + 1;
        assert_eq!(
            pack_f32(false, 0, sig, q, Rounding::NearestEven),
            1.0 + 2f32.powi(-23)
        );
    }

    #[test]
    fn directed_modes() {
        let q = 40u32;
        let just_above_one = (1u128 << q) + 1;
        assert_eq!(
            pack_f32(false, 0, just_above_one, q, Rounding::TowardZero),
            1.0
        );
        assert_eq!(
            pack_f32(false, 0, just_above_one, q, Rounding::TowardPositive),
            1.0 + 2f32.powi(-23)
        );
        assert_eq!(
            pack_f32(false, 0, just_above_one, q, Rounding::TowardNegative),
            1.0
        );
        // Negative value: toward-negative rounds away from zero.
        assert_eq!(
            pack_f32(true, 0, just_above_one, q, Rounding::TowardNegative),
            -(1.0 + 2f32.powi(-23))
        );
        assert_eq!(
            pack_f32(true, 0, just_above_one, q, Rounding::TowardPositive),
            -1.0
        );
    }

    #[test]
    fn overflow_behaviour() {
        assert_eq!(
            pack_f32(false, 128, 1 << 30, 30, Rounding::NearestEven),
            f32::INFINITY
        );
        assert_eq!(
            pack_f32(false, 128, 1 << 30, 30, Rounding::TowardZero),
            f32::MAX
        );
        assert_eq!(
            pack_f32(true, 128, 1 << 30, 30, Rounding::TowardPositive),
            f32::MIN
        );
        assert_eq!(
            pack_f32(true, 128, 1 << 30, 30, Rounding::NearestEven),
            f32::NEG_INFINITY
        );
    }

    #[test]
    fn carry_propagation_renormalizes() {
        // 1.111...1 (24 ones) + guard=1 → rounds to 2.0.
        let q = 24u32;
        let sig = ((1u128 << 25) - 1) << (q - 24); // 25 bits of ones at q=24
        let v = pack_f32(false, 0, sig, q, Rounding::NearestEven);
        assert_eq!(v, 2.0);
    }

    #[test]
    fn subnormal_rounding() {
        // 2^-149 (smallest subnormal), exactly representable.
        let v = pack_f32(false, -149, 1 << 30, 30, Rounding::NearestEven);
        assert_eq!(v, f32::from_bits(1));
        // 2^-150 = half the smallest subnormal: ties to even → 0.
        let v = pack_f32(false, -150, 1 << 30, 30, Rounding::NearestEven);
        assert_eq!(v, 0.0);
        // 2^-150 + ulp-ish → rounds to smallest subnormal.
        let v = pack_f32(false, -150, (1 << 30) + 1, 30, Rounding::NearestEven);
        assert_eq!(v, f32::from_bits(1));
        // Toward-positive rounds any positive residue up.
        let v = pack_f32(false, -160, 1 << 30, 30, Rounding::TowardPositive);
        assert_eq!(v, f32::from_bits(1));
    }

    #[test]
    fn subnormal_mid_range() {
        // 0.75 · 2^-126 = 0x00600000
        let v = pack_f32(false, -127, 3 << 29, 30, Rounding::NearestEven);
        assert_eq!(v.to_bits(), 0x0060_0000);
    }

    #[test]
    fn rounds_up_into_smallest_normal() {
        // Value (2^25 − 1)·2^-151 = (1 − 2^-25)·2^-126 sits between the
        // largest subnormal and 2^-126, closer to the latter → rounds up
        // into the smallest normal.
        let sig = (1u128 << 25) - 1;
        let (bits, inexact) =
            round_pack(false, -151 + 24, sig, 24, false, F32, Rounding::NearestEven);
        assert_eq!(f32::from_bits(bits as u32), f32::MIN_POSITIVE);
        assert!(inexact);
    }

    #[test]
    fn f64_exact_roundtrip_various() {
        for x in [1.0f64, 1.5, 0.1, 3.141592653589793, 1e300, 1e-300] {
            let bits = x.to_bits();
            let exp = ((bits >> 52) & 0x7FF) as i32 - 1023;
            let sig = ((bits & ((1u64 << 52) - 1)) | (1u64 << 52)) as u128;
            let (packed, inexact) =
                round_pack(false, exp, sig, 52, false, F64, Rounding::NearestEven);
            assert_eq!(packed, bits);
            assert!(!inexact);
        }
    }

    #[test]
    fn inexact_flag() {
        let q = 40u32;
        let (_, inexact) = round_pack(
            false,
            0,
            (1u128 << q) + 1,
            q,
            false,
            F32,
            Rounding::NearestEven,
        );
        assert!(inexact);
        let (_, inexact) = round_pack(false, 0, 1u128 << q, q, false, F32, Rounding::NearestEven);
        assert!(!inexact);
        let (_, inexact) = round_pack(false, 0, 1u128 << q, q, true, F32, Rounding::NearestEven);
        assert!(inexact, "sticky_in must propagate");
    }
}
