//! Rounding of an extended-precision result into a storage format.
//!
//! The divider and multipliers produce a sign, an unbiased exponent and a
//! significand carried in `u128` at some precision `q_frac_bits` (value =
//! sig / 2^q_frac_bits · 2^exp). [`round_pack`] normalizes, rounds under
//! the selected mode, and handles overflow to Inf and gradual underflow
//! to subnormals/zero.

use super::format::Format;

#[cfg(any(test, feature = "mutation"))]
use crate::verify::mutation::{self, Mutant};

/// IEEE-754 rounding-direction attributes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Rounding {
    /// roundTiesToEven (the default).
    NearestEven,
    /// roundTowardZero.
    TowardZero,
    /// roundTowardPositive.
    TowardPositive,
    /// roundTowardNegative.
    TowardNegative,
}

impl Rounding {
    /// All four rounding-direction attributes (test/bench sweeps).
    pub const ALL: [Rounding; 4] = [
        Rounding::NearestEven,
        Rounding::TowardZero,
        Rounding::TowardPositive,
        Rounding::TowardNegative,
    ];

    /// Short name as accepted by [`Rounding::from_name`].
    pub const fn name(self) -> &'static str {
        match self {
            Rounding::NearestEven => "nearest",
            Rounding::TowardZero => "zero",
            Rounding::TowardPositive => "up",
            Rounding::TowardNegative => "down",
        }
    }

    /// Parse a rounding-mode name (CLI and service requests).
    pub fn from_name(s: &str) -> Option<Rounding> {
        match s {
            "nearest" | "ne" | "rne" | "nearest-even" => Some(Rounding::NearestEven),
            "zero" | "rtz" | "toward-zero" => Some(Rounding::TowardZero),
            "up" | "rtp" | "toward-positive" => Some(Rounding::TowardPositive),
            "down" | "rtn" | "toward-negative" => Some(Rounding::TowardNegative),
            _ => None,
        }
    }

    /// Should a magnitude with the given (guard, sticky) round up?
    /// `lsb_odd` is the parity of the kept LSB (for ties-to-even).
    #[inline]
    fn round_up(self, sign: bool, guard: bool, sticky: bool, lsb_odd: bool) -> bool {
        // Mutation smoke: nearest-even loses its tie-parity term.
        #[cfg(any(test, feature = "mutation"))]
        if matches!(self, Rounding::NearestEven) && mutation::is_active(Mutant::TieDropsParity) {
            return guard && sticky;
        }
        match self {
            Rounding::NearestEven => guard && (sticky || lsb_odd),
            Rounding::TowardZero => false,
            Rounding::TowardPositive => !sign && (guard || sticky),
            Rounding::TowardNegative => sign && (guard || sticky),
        }
    }
}

/// Round and pack a finite non-zero magnitude.
///
/// * `sign` — sign of the result;
/// * `exp` — unbiased exponent such that value = sig/2^q_frac_bits · 2^exp;
/// * `sig` — extended significand, **must be non-zero**;
/// * `q_frac_bits` — fractional bits in `sig`;
/// * `sticky_in` — true if already-discarded lower bits were non-zero.
///
/// Returns the format's bit pattern (Inf on overflow, ±0/subnormal on
/// underflow). The "inexact" status is returned alongside for tests.
pub fn round_pack(
    sign: bool,
    exp: i32,
    sig: u128,
    q_frac_bits: u32,
    sticky_in: bool,
    fmt: Format,
    rm: Rounding,
) -> (u64, bool) {
    assert!(sig != 0, "round_pack requires non-zero significand");
    // Normalize: shift so the MSB of sig sits at position q_frac_bits
    // (i.e. sig/2^q ∈ [1,2)).
    let msb = 127 - sig.leading_zeros() as i32;
    let mut exp = exp + (msb - q_frac_bits as i32);
    // We want the significand normalized with its hidden bit at position
    // `fmt.frac_bits`; the first dropped bit is the guard, everything
    // lower ORs into sticky.
    let shift = msb - fmt.frac_bits as i32; // bits to drop (may be ≤ 0)
    let (mut kept, guard, mut sticky) = if shift > 0 {
        let kept = (sig >> shift) as u64;
        // All dropped bits at the top of one word: guard is its MSB,
        // sticky any remaining bit (§Perf: one shift instead of building
        // a mask).
        let dropped = sig << (128 - shift as u32);
        let guard = (dropped >> 127) == 1;
        let sticky = sticky_in || (dropped << 1) != 0;
        (kept, guard, sticky)
    } else {
        ((sig as u64) << (-shift) as u32, false, sticky_in)
    };
    // Mutation smoke: the classic guard-bit-only defect.
    #[cfg(any(test, feature = "mutation"))]
    if mutation::is_active(Mutant::DropSticky) {
        sticky = false;
    }
    debug_assert!(kept >> fmt.frac_bits == 1, "normalization failed");

    // Gradual underflow: if exp < emin, shift right further into a
    // subnormal representation before rounding.
    if exp < fmt.emin() {
        let deficit = (fmt.emin() - exp) as u32;
        if deficit > fmt.frac_bits + 2 {
            // Entire value below half the smallest subnormal (or equal —
            // sticky decides). Round the tiny residue.
            let up = match rm {
                Rounding::NearestEven => false, // magnitude < 2^(emin-frac-1) tie impossible here
                Rounding::TowardZero => false,
                Rounding::TowardPositive => !sign,
                Rounding::TowardNegative => sign,
            };
            let bits = if up {
                fmt.assemble(sign, 0, 1)
            } else {
                fmt.zero(sign)
            };
            return (bits, true);
        }
        // Re-derive guard/sticky at the subnormal precision.
        let g2 = (kept >> (deficit - 1)) & 1 == 1;
        let below = kept & ((1u64 << (deficit - 1)) - 1);
        sticky = sticky || guard || below != 0;
        kept >>= deficit;
        let lsb_odd = kept & 1 == 1;
        let mut frac = kept;
        if rm.round_up(sign, g2, sticky, lsb_odd) {
            frac += 1;
        }
        let inexact = g2 || sticky;
        if frac >> fmt.frac_bits == 1 {
            // Rounded up into the smallest normal.
            return (fmt.assemble(sign, 1, 0), inexact);
        }
        return (fmt.assemble(sign, 0, frac), inexact);
    }

    // Normal-range rounding.
    let lsb_odd = kept & 1 == 1;
    let mut sig_rounded = kept;
    if rm.round_up(sign, guard, sticky, lsb_odd) {
        sig_rounded += 1;
        // Mutation smoke: skip the post-round renormalize.
        #[cfg(any(test, feature = "mutation"))]
        let renormalize = !mutation::is_active(Mutant::SkipCarryRenorm);
        #[cfg(not(any(test, feature = "mutation")))]
        let renormalize = true;
        if renormalize && sig_rounded >> (fmt.frac_bits + 1) == 1 {
            // Carry out of the significand: renormalize.
            sig_rounded >>= 1;
            exp += 1;
        }
    }
    let inexact = guard || sticky;

    // Mutation smoke: overflow comparison off by one.
    #[cfg(any(test, feature = "mutation"))]
    let overflow = if mutation::is_active(Mutant::OverflowBoundaryOffByOne) {
        exp >= fmt.emax()
    } else {
        exp > fmt.emax()
    };
    #[cfg(not(any(test, feature = "mutation")))]
    let overflow = exp > fmt.emax();

    if overflow {
        // Overflow: Inf or max-finite depending on direction.
        let bits = match rm {
            Rounding::NearestEven => fmt.inf(sign),
            Rounding::TowardZero => fmt.max_finite(sign),
            Rounding::TowardPositive => {
                if sign {
                    fmt.max_finite(true)
                } else {
                    fmt.inf(false)
                }
            }
            Rounding::TowardNegative => {
                if sign {
                    fmt.inf(true)
                } else {
                    fmt.max_finite(false)
                }
            }
        };
        return (bits, true);
    }

    let biased = (exp + fmt.bias()) as u64;
    let frac = sig_rounded & fmt.frac_mask();
    (fmt.assemble(sign, biased, frac), inexact)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp::format::{BF16, F16, F32, F64};

    fn pack_f32(sign: bool, exp: i32, sig: u128, q: u32, rm: Rounding) -> f32 {
        let (bits, _) = round_pack(sign, exp, sig, q, false, F32, rm);
        f32::from_bits(bits as u32)
    }

    #[test]
    fn exact_one() {
        assert_eq!(pack_f32(false, 0, 1 << 60, 60, Rounding::NearestEven), 1.0);
    }

    #[test]
    fn exact_unnormalized_input() {
        // 3.0 presented as 0b11 with q=1 (value 3.0 · 2^0? no: 3/2 · 2^1)
        assert_eq!(pack_f32(false, 1, 3, 1, Rounding::NearestEven), 3.0);
        // 0.5 presented denormalized high
        assert_eq!(pack_f32(false, -1, 1 << 40, 40, Rounding::NearestEven), 0.5);
    }

    #[test]
    fn ties_to_even() {
        // 1 + 2^-24 exactly between 1.0 and 1+2^-23 → rounds to even (1.0).
        let q = 40u32;
        let sig = (1u128 << q) + (1u128 << (q - 24));
        assert_eq!(pack_f32(false, 0, sig, q, Rounding::NearestEven), 1.0);
        // 1 + 3·2^-24 between 1+2^-23 and 1+2^-22 → rounds up to even.
        let sig = (1u128 << q) + 3 * (1u128 << (q - 24));
        assert_eq!(
            pack_f32(false, 0, sig, q, Rounding::NearestEven),
            1.0 + 2.0 * 2f32.powi(-23)
        );
    }

    #[test]
    fn sticky_breaks_tie_upward() {
        let q = 40u32;
        // 1 + 2^-24 + 2^-40: just above the tie → rounds up.
        let sig = (1u128 << q) + (1u128 << (q - 24)) + 1;
        assert_eq!(
            pack_f32(false, 0, sig, q, Rounding::NearestEven),
            1.0 + 2f32.powi(-23)
        );
    }

    #[test]
    fn directed_modes() {
        let q = 40u32;
        let just_above_one = (1u128 << q) + 1;
        assert_eq!(
            pack_f32(false, 0, just_above_one, q, Rounding::TowardZero),
            1.0
        );
        assert_eq!(
            pack_f32(false, 0, just_above_one, q, Rounding::TowardPositive),
            1.0 + 2f32.powi(-23)
        );
        assert_eq!(
            pack_f32(false, 0, just_above_one, q, Rounding::TowardNegative),
            1.0
        );
        // Negative value: toward-negative rounds away from zero.
        assert_eq!(
            pack_f32(true, 0, just_above_one, q, Rounding::TowardNegative),
            -(1.0 + 2f32.powi(-23))
        );
        assert_eq!(
            pack_f32(true, 0, just_above_one, q, Rounding::TowardPositive),
            -1.0
        );
    }

    #[test]
    fn overflow_behaviour() {
        assert_eq!(
            pack_f32(false, 128, 1 << 30, 30, Rounding::NearestEven),
            f32::INFINITY
        );
        assert_eq!(
            pack_f32(false, 128, 1 << 30, 30, Rounding::TowardZero),
            f32::MAX
        );
        assert_eq!(
            pack_f32(true, 128, 1 << 30, 30, Rounding::TowardPositive),
            f32::MIN
        );
        assert_eq!(
            pack_f32(true, 128, 1 << 30, 30, Rounding::NearestEven),
            f32::NEG_INFINITY
        );
    }

    #[test]
    fn carry_propagation_renormalizes() {
        // 1.111...1 (24 ones) + guard=1 → rounds to 2.0.
        let q = 24u32;
        let sig = ((1u128 << 25) - 1) << (q - 24); // 25 bits of ones at q=24
        let v = pack_f32(false, 0, sig, q, Rounding::NearestEven);
        assert_eq!(v, 2.0);
    }

    #[test]
    fn subnormal_rounding() {
        // 2^-149 (smallest subnormal), exactly representable.
        let v = pack_f32(false, -149, 1 << 30, 30, Rounding::NearestEven);
        assert_eq!(v, f32::from_bits(1));
        // 2^-150 = half the smallest subnormal: ties to even → 0.
        let v = pack_f32(false, -150, 1 << 30, 30, Rounding::NearestEven);
        assert_eq!(v, 0.0);
        // 2^-150 + ulp-ish → rounds to smallest subnormal.
        let v = pack_f32(false, -150, (1 << 30) + 1, 30, Rounding::NearestEven);
        assert_eq!(v, f32::from_bits(1));
        // Toward-positive rounds any positive residue up.
        let v = pack_f32(false, -160, 1 << 30, 30, Rounding::TowardPositive);
        assert_eq!(v, f32::from_bits(1));
    }

    #[test]
    fn subnormal_mid_range() {
        // 0.75 · 2^-126 = 0x00600000
        let v = pack_f32(false, -127, 3 << 29, 30, Rounding::NearestEven);
        assert_eq!(v.to_bits(), 0x0060_0000);
    }

    #[test]
    fn rounds_up_into_smallest_normal() {
        // Value (2^25 − 1)·2^-151 = (1 − 2^-25)·2^-126 sits between the
        // largest subnormal and 2^-126, closer to the latter → rounds up
        // into the smallest normal.
        let sig = (1u128 << 25) - 1;
        let (bits, inexact) =
            round_pack(false, -151 + 24, sig, 24, false, F32, Rounding::NearestEven);
        assert_eq!(f32::from_bits(bits as u32), f32::MIN_POSITIVE);
        assert!(inexact);
    }

    #[test]
    fn f64_exact_roundtrip_various() {
        for x in [1.0f64, 1.5, 0.1, 3.141592653589793, 1e300, 1e-300] {
            let bits = x.to_bits();
            let exp = ((bits >> 52) & 0x7FF) as i32 - 1023;
            let sig = ((bits & ((1u64 << 52) - 1)) | (1u64 << 52)) as u128;
            let (packed, inexact) =
                round_pack(false, exp, sig, 52, false, F64, Rounding::NearestEven);
            assert_eq!(packed, bits);
            assert!(!inexact);
        }
    }

    #[test]
    fn f16_subnormal_flush_directed_edges() {
        // f16: frac_bits = 10, emin = −14, smallest subnormal 2^−24.
        // A deficit beyond frac_bits + 2 (value 2^−27, deficit 13) takes
        // the total-flush path: only the away-from-zero directed mode
        // may produce the smallest subnormal.
        let q = 30u32;
        for (rm, want) in [
            (Rounding::NearestEven, F16.zero(false)),
            (Rounding::TowardZero, F16.zero(false)),
            (Rounding::TowardNegative, F16.zero(false)),
            (Rounding::TowardPositive, F16.assemble(false, 0, 1)),
        ] {
            let (bits, inexact) = round_pack(false, -27, 1 << q, q, false, F16, rm);
            assert_eq!(bits, want, "{rm:?}");
            assert!(inexact, "{rm:?}");
        }
        // Mirrored for the negative sign.
        let (bits, _) = round_pack(true, -27, 1 << q, q, false, F16, Rounding::TowardNegative);
        assert_eq!(bits, F16.assemble(true, 0, 1));
        let (bits, _) = round_pack(true, -27, 1 << q, q, false, F16, Rounding::TowardPositive);
        assert_eq!(bits, F16.zero(true));
        let (bits, _) = round_pack(true, -27, 1 << q, q, false, F16, Rounding::TowardZero);
        assert_eq!(bits, F16.zero(true));
    }

    #[test]
    fn f16_subnormal_deficit_boundary_and_tie() {
        let q = 30u32;
        // Deficit exactly frac_bits + 2 = 12 (value 2^−26 < half the
        // smallest subnormal): the re-derive path, sticky set, guard
        // clear — nearest flushes to zero, toward-positive rounds to
        // the smallest subnormal.
        let (bits, inexact) =
            round_pack(false, -26, 1 << q, q, false, F16, Rounding::NearestEven);
        assert_eq!(bits, F16.zero(false));
        assert!(inexact);
        let (bits, _) = round_pack(false, -26, 1 << q, q, false, F16, Rounding::TowardPositive);
        assert_eq!(bits, F16.assemble(false, 0, 1));
        // Exactly half the smallest subnormal (2^−25): a true tie —
        // nearest-even picks zero (even), directed modes split by sign.
        let (bits, _) = round_pack(false, -25, 1 << q, q, false, F16, Rounding::NearestEven);
        assert_eq!(bits, F16.zero(false), "tie must go to even (zero)");
        let (bits, _) = round_pack(false, -25, 1 << q, q, false, F16, Rounding::TowardPositive);
        assert_eq!(bits, F16.assemble(false, 0, 1));
        let (bits, _) = round_pack(false, -25, 1 << q, q, false, F16, Rounding::TowardZero);
        assert_eq!(bits, F16.zero(false));
        // Just above the tie: sticky breaks it upward under nearest.
        let (bits, _) =
            round_pack(false, -25, (1u128 << q) + 1, q, false, F16, Rounding::NearestEven);
        assert_eq!(bits, F16.assemble(false, 0, 1));
    }

    #[test]
    fn f16_rounds_up_across_subnormal_normal_boundary() {
        // (2 − 2^−24)·2^−15 = (1 − 2^−25)·2^−14, just below the smallest
        // normal: nearest rounds up into it, toward-zero stays at the
        // largest subnormal.
        let sig = (1u128 << 25) - 1; // 25 ones, msb 24 → value ≈ 2·(1−2^−25)
        let (bits, inexact) = round_pack(false, -15, sig, 24, false, F16, Rounding::NearestEven);
        assert_eq!(bits, F16.assemble(false, 1, 0), "smallest normal");
        assert!(inexact);
        let (bits, _) = round_pack(false, -15, sig, 24, false, F16, Rounding::TowardZero);
        assert_eq!(bits, F16.assemble(false, 0, F16.frac_mask()), "largest subnormal");
        let (bits, _) = round_pack(true, -15, sig, 24, false, F16, Rounding::TowardNegative);
        assert_eq!(bits, F16.assemble(true, 1, 0), "−smallest normal (away from zero)");
    }

    #[test]
    fn f16_overflow_directed_edges() {
        // Above emax = 15: nearest → Inf, toward-zero → max finite
        // (65504), and the directed modes saturate toward their side.
        let q = 30u32;
        let (bits, inexact) = round_pack(false, 16, 1 << q, q, false, F16, Rounding::NearestEven);
        assert_eq!(bits, F16.inf(false));
        assert!(inexact);
        let (bits, _) = round_pack(false, 16, 1 << q, q, false, F16, Rounding::TowardZero);
        assert_eq!(bits, F16.max_finite(false));
        let (bits, _) = round_pack(false, 16, 1 << q, q, false, F16, Rounding::TowardNegative);
        assert_eq!(bits, F16.max_finite(false));
        let (bits, _) = round_pack(false, 16, 1 << q, q, false, F16, Rounding::TowardPositive);
        assert_eq!(bits, F16.inf(false));
        let (bits, _) = round_pack(true, 16, 1 << q, q, false, F16, Rounding::TowardPositive);
        assert_eq!(bits, F16.max_finite(true));
        let (bits, _) = round_pack(true, 16, 1 << q, q, false, F16, Rounding::TowardNegative);
        assert_eq!(bits, F16.inf(true));
        // Sanity: f16 max finite is 65504.
        assert_eq!(F16.max_finite(false), 0x7BFF);
    }

    #[test]
    fn bf16_subnormal_flush_and_deficit_boundary() {
        // bf16: frac_bits = 7, emin = −126, smallest subnormal 2^−133.
        let q = 40u32;
        // Deficit 10 > frac_bits + 2 = 9 (value 2^−136): total flush.
        for (rm, want) in [
            (Rounding::NearestEven, BF16.zero(false)),
            (Rounding::TowardZero, BF16.zero(false)),
            (Rounding::TowardPositive, BF16.assemble(false, 0, 1)),
        ] {
            let (bits, inexact) = round_pack(false, -136, 1 << q, q, false, BF16, rm);
            assert_eq!(bits, want, "{rm:?}");
            assert!(inexact);
        }
        let (bits, _) = round_pack(true, -136, 1 << q, q, false, BF16, Rounding::TowardNegative);
        assert_eq!(bits, BF16.assemble(true, 0, 1));
        // Half the smallest subnormal (2^−134): tie → even (zero) under
        // nearest; away-from-zero directed mode rounds up.
        let (bits, _) = round_pack(false, -134, 1 << q, q, false, BF16, Rounding::NearestEven);
        assert_eq!(bits, BF16.zero(false));
        let (bits, _) = round_pack(false, -134, 1 << q, q, false, BF16, Rounding::TowardPositive);
        assert_eq!(bits, BF16.assemble(false, 0, 1));
        // Smallest subnormal itself survives exactly.
        let (bits, inexact) =
            round_pack(false, -133, 1 << q, q, false, BF16, Rounding::NearestEven);
        assert_eq!(bits, BF16.assemble(false, 0, 1));
        assert!(!inexact);
        // Just below the smallest normal rounds up into it (nearest) or
        // stays at the largest subnormal (toward zero).
        let sig = (1u128 << 22) - 1; // 22 ones, msb 21 → ≈ 2·(1−2^−22)
        let (bits, _) = round_pack(false, -127, sig, 21, false, BF16, Rounding::NearestEven);
        assert_eq!(bits, BF16.assemble(false, 1, 0));
        let (bits, _) = round_pack(false, -127, sig, 21, false, BF16, Rounding::TowardZero);
        assert_eq!(bits, BF16.assemble(false, 0, BF16.frac_mask()));
    }

    #[test]
    fn bf16_overflow_directed_edges() {
        let q = 40u32;
        let (bits, _) = round_pack(false, 128, 1 << q, q, false, BF16, Rounding::NearestEven);
        assert_eq!(bits, BF16.inf(false));
        let (bits, _) = round_pack(false, 128, 1 << q, q, false, BF16, Rounding::TowardZero);
        assert_eq!(bits, BF16.max_finite(false));
        let (bits, _) = round_pack(true, 128, 1 << q, q, false, BF16, Rounding::TowardPositive);
        assert_eq!(bits, BF16.max_finite(true));
        let (bits, _) = round_pack(true, 128, 1 << q, q, false, BF16, Rounding::TowardNegative);
        assert_eq!(bits, BF16.inf(true));
        // Carry-out of an all-ones significand overflows to Inf under
        // nearest even at the very top of the range.
        let sig = (1u128 << 9) - 1; // 1.11111111₂ at q = 8 (9 ones)
        let (bits, _) = round_pack(false, 127, sig, 8, false, BF16, Rounding::NearestEven);
        assert_eq!(bits, BF16.inf(false));
        let (bits, _) = round_pack(false, 127, sig, 8, false, BF16, Rounding::TowardZero);
        assert_eq!(bits, BF16.max_finite(false));
    }

    #[test]
    fn inexact_flag() {
        let q = 40u32;
        let (_, inexact) = round_pack(
            false,
            0,
            (1u128 << q) + 1,
            q,
            false,
            F32,
            Rounding::NearestEven,
        );
        assert!(inexact);
        let (_, inexact) = round_pack(false, 0, 1u128 << q, q, false, F32, Rounding::NearestEven);
        assert!(!inexact);
        let (_, inexact) = round_pack(false, 0, 1u128 << q, q, true, F32, Rounding::NearestEven);
        assert!(inexact, "sticky_in must propagate");
    }
}
