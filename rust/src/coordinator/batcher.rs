//! Pure batch-assembly logic: coalesce many small requests into one
//! backend batch and split the result back, independent of threading.

/// A request's lanes plus its index for response routing.
#[derive(Clone, Debug)]
pub struct BatchItem {
    pub request_id: u64,
    pub a: Vec<f32>,
    pub b: Vec<f32>,
}

/// A coalesced batch ready for a backend.
#[derive(Clone, Debug, Default)]
pub struct Batch {
    pub items: Vec<BatchItem>,
    pub lanes: usize,
}

impl Batch {
    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// Flatten all items into contiguous operand vectors.
    pub fn flatten(&self) -> (Vec<f32>, Vec<f32>) {
        let mut a = Vec::with_capacity(self.lanes);
        let mut b = Vec::with_capacity(self.lanes);
        for it in &self.items {
            a.extend_from_slice(&it.a);
            b.extend_from_slice(&it.b);
        }
        (a, b)
    }

    /// Split a flat result back into per-request chunks
    /// `(request_id, Vec<f32>)`, in item order.
    pub fn split(&self, flat: &[f32]) -> Vec<(u64, Vec<f32>)> {
        assert_eq!(flat.len(), self.lanes, "result length mismatch");
        let mut out = Vec::with_capacity(self.items.len());
        let mut off = 0;
        for it in &self.items {
            out.push((it.request_id, flat[off..off + it.a.len()].to_vec()));
            off += it.a.len();
        }
        out
    }
}

/// Accumulates requests until a lane budget is met.
#[derive(Debug)]
pub struct BatchAssembler {
    max_lanes: usize,
    current: Batch,
}

impl BatchAssembler {
    pub fn new(max_lanes: usize) -> Self {
        assert!(max_lanes > 0);
        Self {
            max_lanes,
            current: Batch::default(),
        }
    }

    /// Add a request. Returns a completed batch when the lane budget is
    /// reached (the new item may itself trigger the flush).
    pub fn push(&mut self, item: BatchItem) -> Option<Batch> {
        debug_assert_eq!(item.a.len(), item.b.len());
        // An oversize single request: flush what we have, emit it alone.
        if item.a.len() >= self.max_lanes {
            let pending = self.take();
            let lanes = item.a.len();
            let solo = Batch {
                items: vec![item],
                lanes,
            };
            return Some(match pending {
                Some(mut p) => {
                    // Merge: pending first, oversize item after (order kept).
                    p.items.extend(solo.items);
                    p.lanes += solo.lanes;
                    p
                }
                None => solo,
            });
        }
        if self.current.lanes + item.a.len() > self.max_lanes {
            let done = self.take();
            self.current.lanes = item.a.len();
            self.current.items.push(item);
            return done;
        }
        self.current.lanes += item.a.len();
        self.current.items.push(item);
        if self.current.lanes == self.max_lanes {
            return self.take();
        }
        None
    }

    /// Flush whatever has accumulated (deadline expiry).
    pub fn take(&mut self) -> Option<Batch> {
        if self.current.is_empty() {
            None
        } else {
            Some(std::mem::take(&mut self.current))
        }
    }

    pub fn pending_lanes(&self) -> usize {
        self.current.lanes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn item(id: u64, n: usize) -> BatchItem {
        BatchItem {
            request_id: id,
            a: vec![id as f32; n],
            b: vec![1.0; n],
        }
    }

    #[test]
    fn accumulates_until_budget() {
        let mut asm = BatchAssembler::new(10);
        assert!(asm.push(item(1, 4)).is_none());
        assert!(asm.push(item(2, 4)).is_none());
        assert_eq!(asm.pending_lanes(), 8);
        // 8 + 4 > 10 → flush the first two, start fresh with the third.
        let b = asm.push(item(3, 4)).unwrap();
        assert_eq!(b.lanes, 8);
        assert_eq!(b.items.len(), 2);
        assert_eq!(asm.pending_lanes(), 4);
    }

    #[test]
    fn exact_fill_flushes() {
        let mut asm = BatchAssembler::new(8);
        assert!(asm.push(item(1, 4)).is_none());
        let b = asm.push(item(2, 4)).unwrap();
        assert_eq!(b.lanes, 8);
        assert_eq!(asm.pending_lanes(), 0);
    }

    #[test]
    fn oversize_request_emitted_with_pending() {
        let mut asm = BatchAssembler::new(8);
        assert!(asm.push(item(1, 3)).is_none());
        let b = asm.push(item(2, 20)).unwrap();
        assert_eq!(b.lanes, 23);
        assert_eq!(b.items.len(), 2);
        assert_eq!(b.items[0].request_id, 1, "order preserved");
        assert_eq!(asm.pending_lanes(), 0);
    }

    #[test]
    fn take_drains() {
        let mut asm = BatchAssembler::new(100);
        assert!(asm.take().is_none());
        asm.push(item(1, 5));
        let b = asm.take().unwrap();
        assert_eq!(b.lanes, 5);
        assert!(asm.take().is_none());
    }

    #[test]
    fn flatten_split_roundtrip() {
        let mut batch = Batch::default();
        for (id, n) in [(10u64, 3usize), (11, 1), (12, 5)] {
            batch.items.push(item(id, n));
            batch.lanes += n;
        }
        let (a, b) = batch.flatten();
        assert_eq!(a.len(), 9);
        assert_eq!(b.len(), 9);
        // Identity "result": split must route lanes back by request.
        let parts = batch.split(&a);
        assert_eq!(parts.len(), 3);
        assert_eq!(parts[0], (10, vec![10.0; 3]));
        assert_eq!(parts[1], (11, vec![11.0; 1]));
        assert_eq!(parts[2], (12, vec![12.0; 5]));
    }

    #[test]
    #[should_panic(expected = "result length mismatch")]
    fn split_length_mismatch_panics() {
        let mut batch = Batch::default();
        batch.items.push(item(1, 2));
        batch.lanes = 2;
        let _ = batch.split(&[1.0]);
    }
}
