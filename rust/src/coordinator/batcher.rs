//! Pure batch-assembly logic: coalesce many small requests into
//! homogeneous backend batches and split results back, independent of
//! threading.
//!
//! Heterogeneous traffic (any mix of ops and of f16/bf16/f32/f64 at any
//! rounding mode) is bucketed by [`BatchKey`] so every emitted [`Batch`]
//! carries one `(Op, Format, Rounding)` triple and can run through a
//! single backend call. Each bucket accumulates **cost units**
//! independently until the shared budget is met: a lane is charged
//! [`BatchKey::lane_cost`] (f64 ≈ 2× f16/bf16), so a wide-format bucket
//! ships with fewer lanes than a half-format bucket of equal backend
//! work — the budget bounds *work per batch*, not lane count. Lane
//! order within a request is always preserved.
//!
//! Under the sharded runtime each shard owns a private `BatchAssembler`
//! (no locking here — this module stays single-threaded by
//! construction). Submissions are routed key-affinely, so one key's
//! whole coalescing window — its bucket, its cost meter, its
//! `take_expired` clock — lives on exactly one shard; nothing in this
//! module needs to know how many shards exist.

use std::time::{Duration, Instant};

use super::request::BatchKey;
use crate::fp::{Op, F32};

/// Cost units per binary32 lane — the reference the assembler's budget
/// is denominated in: a budget of `n` "lanes" means the backend work of
/// `n` f32 lanes, whatever format actually fills the bucket.
pub const REF_LANE_COST: usize = F32.lane_cost();

/// A request's lanes plus its index for response routing. Operands are
/// raw bit patterns of the owning batch's format, in the batch key's
/// op shape: matched `a`/`b` for `Div`, `b` empty for the unary ops,
/// `b` one-divisor-per-row for `ScaleByRecip`. A `ScaleByRecip` item's
/// row lengths are either uniform (`rows` empty — rows are
/// `a.len() / b.len()` lanes each) or explicitly ragged (`rows[r]`
/// lanes for divisor `b[r]`, summing to `a.len()`); either way they
/// are free to differ between coalesced items.
#[derive(Clone, Debug)]
pub struct BatchItem {
    pub request_id: u64,
    pub a: Vec<u64>,
    pub b: Vec<u64>,
    /// Per-row lane counts for ragged `ScaleByRecip` items; empty for
    /// uniform rows and for every other op (mirrors
    /// `DivRequest::rows`).
    pub rows: Vec<u32>,
}

/// A coalesced, format-homogeneous batch ready for a backend.
#[derive(Clone, Debug)]
pub struct Batch {
    pub key: BatchKey,
    pub items: Vec<BatchItem>,
    pub lanes: usize,
    /// Backend work this batch represents: `lanes × key.lane_cost()` —
    /// what the assembler metered against its budget, and what the
    /// service's cost gauge aggregates.
    pub cost: usize,
    /// When the oldest (first) item entered this batch — the per-key
    /// clock behind [`BatchAssembler::take_expired`]. `None` while
    /// empty.
    pub opened_at: Option<Instant>,
}

impl Batch {
    pub fn new(key: BatchKey) -> Self {
        Self {
            key,
            items: Vec::new(),
            lanes: 0,
            cost: 0,
            opened_at: None,
        }
    }

    /// Age of the oldest lane in this batch (zero when empty).
    pub fn age(&self, now: Instant) -> Duration {
        self.opened_at
            .map_or(Duration::ZERO, |t| now.saturating_duration_since(t))
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// Flatten all items into contiguous operand vectors, plus the
    /// per-row lane counts the `ScaleByRecip` backends consume (aligned
    /// with the flattened `b`: `rows[r]` lanes of `a` divide by `b[r]`).
    /// `rows` is empty for every other op; coalesced `ScaleByRecip`
    /// items keep their own row shapes — an item with an explicit
    /// (ragged) row vector contributes it verbatim, a uniform item
    /// contributes `b.len()` copies of its derived equal row length.
    pub fn flatten(&self) -> (Vec<u64>, Vec<u64>, Vec<u32>) {
        let mut a = Vec::with_capacity(self.lanes);
        let mut b = Vec::new();
        let mut rows = Vec::new();
        for it in &self.items {
            a.extend_from_slice(&it.a);
            b.extend_from_slice(&it.b);
            if self.key.op == Op::ScaleByRecip {
                if it.rows.is_empty() {
                    let row_len = (it.a.len() / it.b.len()) as u32;
                    rows.resize(rows.len() + it.b.len(), row_len);
                } else {
                    rows.extend_from_slice(&it.rows);
                }
            }
        }
        (a, b, rows)
    }

    /// Split a flat result back into per-request chunks
    /// `(request_id, Vec<u64>)`, in item order.
    pub fn split(&self, flat: &[u64]) -> Vec<(u64, Vec<u64>)> {
        assert_eq!(flat.len(), self.lanes, "result length mismatch");
        let mut out = Vec::with_capacity(self.items.len());
        let mut off = 0;
        for it in &self.items {
            out.push((it.request_id, flat[off..off + it.a.len()].to_vec()));
            off += it.a.len();
        }
        out
    }
}

/// Accumulates requests into per-`BatchKey` buckets until the cost
/// budget is met. The key population is tiny (4 formats × 4 rounding
/// modes), so buckets live in a linearly-scanned `Vec`.
#[derive(Debug)]
pub struct BatchAssembler {
    /// Configured budget in f32-equivalent lanes (the service's
    /// `max_batch` knob).
    max_lanes: usize,
    /// The same budget in cost units (`max_lanes × REF_LANE_COST`) —
    /// what `push` actually meters against.
    max_cost: usize,
    buckets: Vec<Batch>,
    pending_lanes: usize,
    pending_cost: usize,
}

impl BatchAssembler {
    /// `max_lanes` is denominated in **f32-equivalent lanes**: pure-f32
    /// traffic flushes at exactly `max_lanes` lanes, f64 buckets at
    /// ~3/4 of that, f16/bf16 buckets at ~3/2 — equal backend work per
    /// emitted batch across formats.
    pub fn new(max_lanes: usize) -> Self {
        assert!(max_lanes > 0);
        Self {
            max_lanes,
            max_cost: max_lanes * REF_LANE_COST,
            buckets: Vec::new(),
            pending_lanes: 0,
            pending_cost: 0,
        }
    }

    /// Current budget per emitted batch, in f32-equivalent lanes.
    pub fn max_lanes(&self) -> usize {
        self.max_lanes
    }

    /// Current budget per emitted batch, in cost units
    /// (`max_lanes() × REF_LANE_COST`).
    pub fn cost_budget(&self) -> usize {
        self.max_cost
    }

    /// Retune the budget (adaptive batching; still denominated in
    /// f32-equivalent lanes). Takes effect for the next `push`; an
    /// already-accumulated bucket above the new budget flushes on its
    /// next push.
    pub fn set_max_lanes(&mut self, max_lanes: usize) {
        self.max_lanes = max_lanes.max(1);
        self.max_cost = self.max_lanes * REF_LANE_COST;
    }

    fn bucket_mut(&mut self, key: BatchKey) -> &mut Batch {
        // No Entry API over a Vec: find the index first to appease the
        // borrow checker.
        if let Some(i) = self.buckets.iter().position(|b| b.key == key) {
            return &mut self.buckets[i];
        }
        self.buckets.push(Batch::new(key));
        self.buckets.last_mut().unwrap()
    }

    /// Add a request to its key's bucket. Returns that bucket as a
    /// completed batch when the **cost** budget is reached (the new item
    /// may itself trigger the flush). Other keys' buckets are
    /// unaffected. Invariant: an emitted batch never exceeds the budget
    /// by more than its own final request's cost.
    pub fn push(&mut self, key: BatchKey, item: BatchItem) -> Option<Batch> {
        match key.op {
            Op::Div => debug_assert_eq!(item.a.len(), item.b.len()),
            Op::Recip | Op::Rsqrt => debug_assert!(item.b.is_empty()),
            Op::ScaleByRecip if item.rows.is_empty() => {
                debug_assert!(!item.b.is_empty() && item.a.len() % item.b.len() == 0)
            }
            Op::ScaleByRecip => {
                debug_assert_eq!(item.rows.len(), item.b.len());
                debug_assert_eq!(
                    item.rows.iter().map(|&n| n as usize).sum::<usize>(),
                    item.a.len()
                );
            }
        }
        let max_cost = self.max_cost;
        let lanes = item.a.len();
        let cost = lanes * key.lane_cost();
        let now = Instant::now();
        let bucket = self.bucket_mut(key);
        if bucket.items.is_empty() {
            // First lane of this key's window: start its per-key clock.
            bucket.opened_at = Some(now);
        }
        let flushed = if cost >= max_cost {
            // An oversize single request: emit the bucket with the
            // oversize item appended (order kept) rather than splitting
            // the request.
            bucket.lanes += lanes;
            bucket.cost += cost;
            bucket.items.push(item);
            Some(std::mem::replace(bucket, Batch::new(key)))
        } else if bucket.cost + cost > max_cost {
            // Would overflow: ship what accumulated, start fresh (the
            // fresh bucket's clock starts with this item).
            let done = std::mem::replace(bucket, Batch::new(key));
            bucket.lanes = lanes;
            bucket.cost = cost;
            bucket.items.push(item);
            bucket.opened_at = Some(now);
            Some(done)
        } else {
            bucket.lanes += lanes;
            bucket.cost += cost;
            bucket.items.push(item);
            if bucket.cost == max_cost {
                Some(std::mem::replace(bucket, Batch::new(key)))
            } else {
                None
            }
        };
        // Uniform accounting: the new item's lanes/cost enter the
        // pending pool, whatever just flushed leaves it.
        self.pending_lanes += lanes;
        self.pending_cost += cost;
        if let Some(done) = &flushed {
            self.pending_lanes -= done.lanes;
            self.pending_cost -= done.cost;
        }
        flushed
    }

    /// Flush only the buckets whose **oldest lane** has waited at least
    /// `max_age` — the per-key `max_wait`: a rare `(Op, Format, Rounding)`
    /// bucket ships when *its* clock expires instead of riding the whole
    /// coalescing window opened by busier keys, and fresh buckets keep
    /// coalescing instead of being force-flushed alongside it.
    pub fn take_expired(&mut self, max_age: Duration) -> Vec<Batch> {
        let now = Instant::now();
        let mut out = Vec::new();
        for b in self.buckets.iter_mut() {
            if !b.is_empty() && b.age(now) >= max_age {
                self.pending_lanes -= b.lanes;
                self.pending_cost -= b.cost;
                let key = b.key;
                out.push(std::mem::replace(b, Batch::new(key)));
            }
        }
        out
    }

    /// Flush every non-empty bucket (idle-worker flush / shutdown).
    pub fn take_all(&mut self) -> Vec<Batch> {
        self.pending_lanes = 0;
        self.pending_cost = 0;
        self.buckets
            .iter_mut()
            .filter(|b| !b.is_empty())
            .map(|b| {
                let key = b.key;
                std::mem::replace(b, Batch::new(key))
            })
            .collect()
    }

    /// Total lanes accumulated across all buckets.
    pub fn pending_lanes(&self) -> usize {
        self.pending_lanes
    }

    /// Total cost units accumulated across all buckets (the sum of each
    /// pending item's `lanes × lane_cost`).
    pub fn pending_cost(&self) -> usize {
        self.pending_cost
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp::{Rounding, BF16, F16, F32, F64};

    fn key32() -> BatchKey {
        BatchKey::new(F32, Rounding::NearestEven)
    }

    fn item(id: u64, n: usize) -> BatchItem {
        BatchItem {
            request_id: id,
            a: vec![id; n],
            b: vec![1; n],
            rows: vec![],
        }
    }

    #[test]
    fn accumulates_until_budget() {
        let mut asm = BatchAssembler::new(10);
        assert_eq!(asm.cost_budget(), 10 * REF_LANE_COST);
        assert!(asm.push(key32(), item(1, 4)).is_none());
        assert!(asm.push(key32(), item(2, 4)).is_none());
        assert_eq!(asm.pending_lanes(), 8);
        assert_eq!(asm.pending_cost(), 8 * REF_LANE_COST);
        // 8 + 4 f32 lanes exceed the 10-lane budget in cost units →
        // flush the first two, start fresh with the third.
        let b = asm.push(key32(), item(3, 4)).unwrap();
        assert_eq!(b.lanes, 8);
        assert_eq!(b.cost, 8 * REF_LANE_COST);
        assert_eq!(b.items.len(), 2);
        assert_eq!(b.key, key32());
        assert_eq!(asm.pending_lanes(), 4);
        assert_eq!(asm.pending_cost(), 4 * REF_LANE_COST);
    }

    #[test]
    fn exact_fill_flushes() {
        let mut asm = BatchAssembler::new(8);
        assert!(asm.push(key32(), item(1, 4)).is_none());
        let b = asm.push(key32(), item(2, 4)).unwrap();
        assert_eq!(b.lanes, 8);
        assert_eq!(asm.pending_lanes(), 0);
        assert_eq!(asm.pending_cost(), 0);
    }

    #[test]
    fn oversize_request_emitted_with_pending() {
        let mut asm = BatchAssembler::new(8);
        assert!(asm.push(key32(), item(1, 3)).is_none());
        let b = asm.push(key32(), item(2, 20)).unwrap();
        assert_eq!(b.lanes, 23);
        assert_eq!(b.cost, 23 * REF_LANE_COST);
        assert_eq!(b.items.len(), 2);
        assert_eq!(b.items[0].request_id, 1, "order preserved");
        assert_eq!(asm.pending_lanes(), 0);
    }

    #[test]
    fn cost_weighted_flush_thresholds_per_format() {
        // One budget, three formats: the f64 bucket ships with the
        // fewest lanes, the half bucket with the most — equal backend
        // work per batch. Budget 12 f32-eq lanes = 36 cost units →
        // exact fills at 18 f16 lanes (×2), 12 f32 lanes (×3), 9 f64
        // lanes (×4).
        for (fmt, fill) in [(F16, 18usize), (BF16, 18), (F32, 12), (F64, 9)] {
            let key = BatchKey::new(fmt, Rounding::NearestEven);
            let mut asm = BatchAssembler::new(12);
            for id in 0..fill as u64 - 1 {
                assert!(
                    asm.push(key, item(id, 1)).is_none(),
                    "{} flushed before its cost fill",
                    fmt.name()
                );
            }
            let b = asm.push(key, item(99, 1)).unwrap();
            assert_eq!(b.lanes, fill, "{}", fmt.name());
            assert_eq!(b.cost, asm.cost_budget(), "{}", fmt.name());
        }
    }

    #[test]
    fn keys_accumulate_cost_independently() {
        // Budget 8 f32-eq lanes = 24 cost units. Three keys fill
        // side by side; only the bucket that crosses ITS cost budget
        // ships.
        let k64 = BatchKey::new(F64, Rounding::NearestEven);
        let k32z = BatchKey::new(F32, Rounding::TowardZero);
        let mut asm = BatchAssembler::new(8);
        assert!(asm.push(key32(), item(1, 5)).is_none()); // 15 cost
        assert!(asm.push(k64, item(2, 4)).is_none()); // 16 cost
        assert!(asm.push(k32z, item(3, 5)).is_none()); // 15 cost
        assert_eq!(asm.pending_lanes(), 14);
        assert_eq!(asm.pending_cost(), 15 + 16 + 15);
        // Two more f64 lanes exact-fill that bucket (24 cost) and flush
        // ONLY it — 6 f64 lanes where the same budget holds 8 f32 lanes.
        let b = asm.push(k64, item(4, 2)).unwrap();
        assert_eq!(b.key, k64);
        assert_eq!(b.lanes, 6);
        assert_eq!(b.cost, 24);
        assert_eq!(
            b.items.iter().map(|i| i.request_id).collect::<Vec<_>>(),
            vec![2, 4]
        );
        assert_eq!(asm.pending_lanes(), 10);
        assert_eq!(asm.pending_cost(), 30);
        // The rest drains by key.
        let rest = asm.take_all();
        assert_eq!(rest.len(), 2);
        assert!(rest.iter().any(|b| b.key == key32() && b.lanes == 5));
        assert!(rest.iter().any(|b| b.key == k32z && b.lanes == 5));
        assert_eq!(asm.pending_lanes(), 0);
        assert_eq!(asm.pending_cost(), 0);
    }

    #[test]
    fn same_format_different_rounding_never_coalesce() {
        let up = BatchKey::new(F32, Rounding::TowardPositive);
        let down = BatchKey::new(F32, Rounding::TowardNegative);
        let mut asm = BatchAssembler::new(100);
        asm.push(up, item(1, 4));
        asm.push(down, item(2, 4));
        let batches = asm.take_all();
        assert_eq!(batches.len(), 2);
        for b in &batches {
            assert_eq!(b.items.len(), 1, "rounding modes must not mix");
        }
    }

    #[test]
    fn take_all_drains() {
        let mut asm = BatchAssembler::new(100);
        assert!(asm.take_all().is_empty());
        asm.push(key32(), item(1, 5));
        let bs = asm.take_all();
        assert_eq!(bs.len(), 1);
        assert_eq!(bs[0].lanes, 5);
        assert_eq!(bs[0].cost, 5 * REF_LANE_COST);
        assert!(asm.take_all().is_empty());
    }

    #[test]
    fn stale_bf16_lane_expires_alone_among_f32_traffic() {
        // One bf16 lane arrives, then steady f32 traffic keeps the
        // window busy. Per-key expiry must ship the bf16 bucket once its
        // own clock runs out — and ONLY that bucket, leaving the fresher
        // f32 lanes to keep coalescing.
        let kbf16 = BatchKey::new(BF16, Rounding::NearestEven);
        let mut asm = BatchAssembler::new(1 << 20);
        asm.push(kbf16, item(1, 1));
        std::thread::sleep(Duration::from_millis(60));
        // Fresh f32 traffic after the stale lane aged. The expiry
        // threshold sits halfway between the bf16 lane's age (≥ 60 ms)
        // and the f32 lanes' (µs) so scheduler jitter cannot flip it.
        asm.push(key32(), item(2, 4));
        asm.push(key32(), item(3, 4));
        assert!(asm.take_expired(Duration::from_secs(60)).is_empty());
        let expired = asm.take_expired(Duration::from_millis(30));
        assert_eq!(expired.len(), 1, "only the stale bucket ships");
        assert_eq!(expired[0].key, kbf16);
        assert_eq!(expired[0].lanes, 1);
        assert_eq!(expired[0].cost, BF16.lane_cost());
        // The f32 bucket stayed behind, still coalescing — and the
        // expired bucket's cost left the pending gauge.
        assert_eq!(asm.pending_lanes(), 8);
        assert_eq!(asm.pending_cost(), 8 * REF_LANE_COST);
        let rest = asm.take_all();
        assert_eq!(rest.len(), 1);
        assert_eq!(rest[0].key, key32());
    }

    #[test]
    fn per_key_clock_restarts_after_flush() {
        let mut asm = BatchAssembler::new(8);
        assert!(asm.push(key32(), item(1, 4)).is_none());
        // Exact fill flushes; the replacement bucket is empty and has no
        // clock until the next push.
        let full = asm.push(key32(), item(2, 4)).unwrap();
        assert!(full.opened_at.is_some());
        assert!(asm.take_expired(Duration::ZERO).is_empty(), "empty buckets never expire");
        asm.push(key32(), item(3, 2));
        // A zero max_age expires anything with at least one lane.
        let b = asm.take_expired(Duration::ZERO);
        assert_eq!(b.len(), 1);
        assert_eq!(b[0].lanes, 2);
        assert_eq!(asm.pending_lanes(), 0);
        assert_eq!(asm.pending_cost(), 0);
    }

    #[test]
    fn budget_retune_applies_to_next_push() {
        let mut asm = BatchAssembler::new(100);
        asm.push(key32(), item(1, 30));
        asm.set_max_lanes(16);
        // 30 already-pending f32 lanes exceed the shrunk budget: the
        // next push flushes them and starts fresh.
        let b = asm.push(key32(), item(2, 4)).unwrap();
        assert_eq!(b.lanes, 30);
        assert_eq!(asm.pending_lanes(), 4);
        assert_eq!(asm.max_lanes(), 16);
        assert_eq!(asm.cost_budget(), 16 * REF_LANE_COST);
    }

    #[test]
    fn spare_divisor_retune_applies_on_next_push() {
        // The service's spare-capacity policy: budget ÷ spare_divisor
        // while every worker is idle, restored at saturation — exactly
        // the two set_max_lanes calls below. The shrink must apply on
        // the very next push (ship the over-budget pending lanes), not
        // wait for a flush boundary.
        let max_batch = 64usize;
        let spare_divisor = 8usize;
        let mut asm = BatchAssembler::new(max_batch);
        asm.push(key32(), item(1, 20)); // 60 cost, well under 192
        asm.set_max_lanes((max_batch / spare_divisor).max(1)); // 8 lanes → 24 cost
        let b = asm.push(key32(), item(2, 4)).unwrap();
        assert_eq!(b.lanes, 20, "shrunk budget ships the pending bucket");
        assert_eq!(asm.pending_lanes(), 4);
        // Saturation restores the full budget for the next push.
        asm.set_max_lanes(max_batch);
        assert_eq!(asm.max_lanes(), 64);
        assert!(asm.push(key32(), item(3, 30)).is_none(), "full budget holds again");
    }

    #[test]
    fn flatten_split_roundtrip() {
        let mut batch = Batch::new(BatchKey::new(F16, Rounding::NearestEven));
        for (id, n) in [(10u64, 3usize), (11, 1), (12, 5)] {
            batch.items.push(item(id, n));
            batch.lanes += n;
        }
        let (a, b, rows) = batch.flatten();
        assert_eq!(a.len(), 9);
        assert_eq!(b.len(), 9);
        assert!(rows.is_empty(), "rows only travel for scale-recip");
        // Identity "result": split must route lanes back by request.
        let parts = batch.split(&a);
        assert_eq!(parts.len(), 3);
        assert_eq!(parts[0], (10, vec![10u64; 3]));
        assert_eq!(parts[1], (11, vec![11u64; 1]));
        assert_eq!(parts[2], (12, vec![12u64; 5]));
    }

    #[test]
    fn ops_never_coalesce_across_keys() {
        // Same format and rounding, four different ops: four buckets.
        let mut asm = BatchAssembler::new(100);
        asm.push(key32(), item(1, 4));
        asm.push(
            BatchKey::for_op(Op::Recip, F32, Rounding::NearestEven),
            BatchItem {
                request_id: 2,
                a: vec![2; 4],
                b: vec![],
                rows: vec![],
            },
        );
        asm.push(
            BatchKey::for_op(Op::Rsqrt, F32, Rounding::NearestEven),
            BatchItem {
                request_id: 3,
                a: vec![3; 4],
                b: vec![],
                rows: vec![],
            },
        );
        asm.push(
            BatchKey::for_op(Op::ScaleByRecip, F32, Rounding::NearestEven),
            BatchItem {
                request_id: 4,
                a: vec![4; 4],
                b: vec![9, 9],
                rows: vec![],
            },
        );
        let batches = asm.take_all();
        assert_eq!(batches.len(), 4);
        for b in &batches {
            assert_eq!(b.items.len(), 1, "ops must not mix in one batch");
        }
    }

    #[test]
    fn scale_recip_items_flatten_with_their_own_row_lengths() {
        // Two coalesced scale-recip requests with different row shapes:
        // 6 lanes over 2 rows (3 each), then 4 lanes over 4 rows (1
        // each). The flattened rows vector interleaves nothing — it
        // follows item order, one entry per divisor.
        let key = BatchKey::for_op(Op::ScaleByRecip, F32, Rounding::NearestEven);
        let mut asm = BatchAssembler::new(100);
        asm.push(
            key,
            BatchItem {
                request_id: 1,
                a: (0..6).collect(),
                b: vec![100, 101],
                rows: vec![],
            },
        );
        asm.push(
            key,
            BatchItem {
                request_id: 2,
                a: (6..10).collect(),
                b: vec![102, 103, 104, 105],
                rows: vec![],
            },
        );
        let batches = asm.take_all();
        assert_eq!(batches.len(), 1);
        let (a, b, rows) = batches[0].flatten();
        assert_eq!(a, (0..10).collect::<Vec<u64>>());
        assert_eq!(b, vec![100, 101, 102, 103, 104, 105]);
        assert_eq!(rows, vec![3, 3, 1, 1, 1, 1]);
        // split() routes by a-lanes, independent of row shape.
        let parts = batches[0].split(&a);
        assert_eq!(parts[0], (1, (0..6).collect::<Vec<u64>>()));
        assert_eq!(parts[1], (2, (6..10).collect::<Vec<u64>>()));
    }

    #[test]
    fn ragged_scale_recip_items_flatten_their_explicit_row_vectors() {
        // A ragged item (explicit rows 4+1+2) coalesced with a uniform
        // one (3 lanes over 1 row): flatten must emit the explicit
        // vector verbatim, then the derived uniform length — the old
        // single-`row_len` derivation would have mispriced the ragged
        // item as 7/3 lanes per row.
        let key = BatchKey::for_op(Op::ScaleByRecip, F32, Rounding::NearestEven);
        let mut asm = BatchAssembler::new(100);
        asm.push(
            key,
            BatchItem {
                request_id: 1,
                a: (0..7).collect(),
                b: vec![100, 101, 102],
                rows: vec![4, 1, 2],
            },
        );
        asm.push(
            key,
            BatchItem {
                request_id: 2,
                a: (7..10).collect(),
                b: vec![103],
                rows: vec![],
            },
        );
        let batches = asm.take_all();
        assert_eq!(batches.len(), 1);
        let (a, b, rows) = batches[0].flatten();
        assert_eq!(a, (0..10).collect::<Vec<u64>>());
        assert_eq!(b, vec![100, 101, 102, 103]);
        assert_eq!(rows, vec![4, 1, 2, 3]);
        assert_eq!(rows.iter().map(|&n| n as usize).sum::<usize>(), a.len());
        // split() still routes whole items back by lane count.
        let parts = batches[0].split(&a);
        assert_eq!(parts[0], (1, (0..7).collect::<Vec<u64>>()));
        assert_eq!(parts[1], (2, (7..10).collect::<Vec<u64>>()));
    }

    #[test]
    #[should_panic(expected = "result length mismatch")]
    fn split_length_mismatch_panics() {
        let mut batch = Batch::new(key32());
        batch.items.push(item(1, 2));
        batch.lanes = 2;
        let _ = batch.split(&[1]);
    }
}
