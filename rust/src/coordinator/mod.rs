//! The division service: a sharded, work-stealing batch coordinator in
//! plain threads (no async runtime is vendored — see DESIGN.md §1).
//!
//! Architecture (sharded runtime, PR 6):
//!
//! ```text
//! clients ─submit_request(DivRequest{op,fmt,rm,a,b})─┐
//!     │ typed constructors:                          │ shard_for(BatchKey):
//!     │ new/from_* (Div), recip, rsqrt,              │ Fibonacci hash of
//!     │ scale_by_recip (one divisor/row)             │ (op × format × rounding)
//!     │                                              │ — key-affine, so a bucket's
//!     │                                              │ lanes always coalesce on ONE
//!     │                                              │ shard; oversize requests
//!     │                                              │ (≥ full batch budget) spread
//!     │                                              │ by request id instead
//!     │               ┌──────────────┬───────────────┴┬──────────────┐
//!     │               ▼              ▼                ▼              │
//!     │        shard 0        shard 1          shard N-1             │
//!     │        bounded queue  bounded queue    bounded queue         │
//!     │        (Busy when full: queue_capacity / shards each)        │
//!     │        batcher thread batcher thread   batcher thread        │
//!     │          │ local BatchAssembler per shard: bucket by         │
//!     │          │ (Op, Format, Rounding), cost budgets, adaptive    │
//!     │          │ flush (full bucket / idle worker / per-key        │
//!     │          │ max_wait), spare-capacity budget shrink           │
//!     │          ▼              ▼                ▼                   │
//!     │        ready deque   ready deque      ready deque            │
//!     │        └──────────────┴───(one mutex + condvar)──┘           │
//!     │                         ▲          ▲                         │
//!     │                 worker pool (home shard = id % shards):      │
//!     │                 1. pop home deque                            │
//!     │                 2. else STEAL: raid the busiest other deque, │
//!     │                    take half (exec first, migrate rest home) │
//!     │                 3. else park (flush MetricsBatch → relaxed   │
//!     │                    stores into WorkerMetrics, once per park) │
//!     │                 Backend::compute(op, …, fmt, rm) per batch   │
//!     │   ┌─ BackendRouter (crate::router, Auto only) ────────────┐  │
//!     │   │ pick(op, fmt, rm, lanes): per-bucket per-lane-seconds │  │
//!     │   │ table (history-seeded / static prior, epsilon-greedy) │  │
//!     │   │   ├─► Taylor kernel      ─┐ observe(measured          │  │
//!     │   │   └─► Goldschmidt kernel ─┘         batch latency)    │  │
//!     │   └───────────────────────────────────────────────────────┘  │
//!     │        ┌─ staged SoA kernel (crate::kernel) ─┐               │
//!     │        │ plan ─► seed ─► power ─► mul_round  │  backends:    │
//!     │        │ unpack,  PLA     Taylor    final ·, │  Kernel/Native│
//!     │        │ specials seg     powers    round    │  /NativeScalar│
//!     │        │ aside    lookup  (odd/even) pack    │  /Goldschmidt │
//!     │        │ (Goldschmidt path: plan ─► seed ─►  │  /Auto        │
//!     │        │  iterate ─► round, same scratch)    │  /Gold/Pjrt   │
//!     │        │ (op tails: Recip drops ·a, Rsqrt    │               │
//!     │        │  Newton, ScaleByRecip broadcasts)   │               │
//!     │        └─ 8-lane tiles, crate::simd engine ──┘               │
//!     └──◄── DivTicket::wait() → DivResponse{fmt,rm,bits} ◄──────────┘
//! ```
//!
//! Batches travel **whole** — each carries its positionally-aligned
//! responders — so the no-cross-wired/no-hung-waiter invariant survives
//! any interleaving of steals and shutdown. Heterogeneous traffic (any
//! mix of the four typed ops — `Div`, `Recip`, `Rsqrt`,
//! `ScaleByRecip` — over binary16/bfloat16/binary32/binary64 under any
//! rounding mode) rides the same batch lanes: no shard ever mixes keys
//! inside a batch, so each backend call is monomorphic over one
//! `(Op, Format, Rounding)`.
//!
//! The `Kernel`, `Native` and `NativeScalar` backends are the **same
//! datapath** at three loop shapes: `Kernel` drives the staged
//! structure-of-arrays pipeline directly, `Native` wraps the identical
//! pipeline in a divisor-grouping permutation (repeats arrive in runs,
//! so the kernel's reciprocal cache hits every repeat), and
//! `NativeScalar` is the pre-batching per-lane loop kept as the serving
//! benches' baseline. All three are bit-identical by property test;
//! `Gold` is the exactly-rounded reference they are measured against.
//! `Goldschmidt` is a genuinely different datapath (multiplicative
//! iteration instead of a Taylor polynomial) over the same staged
//! scratch and lane engine, and `Auto` routes every batch to whichever
//! of the two kernel datapaths currently scores fastest for its
//! (Op, Format, Rounding, batch-size) bucket — bit-identical per batch
//! to the fixed backend it picks, since routing never changes what a
//! datapath computes. The `Kernel`, `Goldschmidt`, `Auto` and `Gold`
//! backends serve every typed op; `Native`, `NativeScalar` and `Pjrt`
//! are division-only and reject other ops with a typed error, failing
//! the batch rather than the service.
//!
//! * [`request`] — the typed request/response surface ([`DivRequest`],
//!   [`DivResponse`], [`BatchKey`]);
//! * [`batcher`] — pure batch-assembly logic (per-key coalesce/split),
//!   testable without threads;
//! * [`worker`] — the backend trait and its Kernel/Goldschmidt/Native/
//!   Gold/PJRT implementations, plus the router-driven [`RoutedBackend`];
//! * [`metrics`] — batched worker counters ([`MetricsBatch`] flushed
//!   once per park), lock-free latency histograms, and the aggregate
//!   [`MetricsSnapshot`];
//! * [`service`] — the running system: shards, steal loop, shutdown,
//!   fault containment (a panicking backend fails the batch, not the
//!   service).

pub mod batcher;
pub mod metrics;
pub mod request;
pub mod service;
pub mod worker;

pub use batcher::{Batch, BatchAssembler, BatchItem, REF_LANE_COST};
pub use metrics::{AtomicHistogram, MetricsBatch, MetricsSnapshot, WorkerMetrics};
pub use request::{BatchKey, DivRequest, DivResponse};
pub use service::{DivTicket, DivisionService, ServiceConfig, SubmitError};
pub use worker::{
    Backend, BackendChoice, GoldBackend, GoldschmidtBackend, KernelBackend, NativeBackend,
    RoutedBackend, ScalarNativeBackend,
};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp::{Rounding, F64};
    use std::time::Duration;

    #[test]
    fn end_to_end_native_service() {
        let svc = DivisionService::start(
            ServiceConfig {
                workers: 2,
                max_batch: 64,
                max_wait: Duration::from_millis(2),
                queue_capacity: 128,
                ..ServiceConfig::default()
            },
            BackendChoice::Native {
                order: 5,
                ilm_iterations: None,
            },
        )
        .unwrap();
        let a: Vec<f32> = (1..=40).map(|i| i as f32).collect();
        let b: Vec<f32> = (1..=40).map(|i| (i % 7 + 1) as f32).collect();
        let out = svc
            .divide_request_blocking(DivRequest::from_f32(&a, &b))
            .unwrap()
            .to_f32()
            .unwrap();
        for i in 0..a.len() {
            let want = a[i] / b[i];
            assert!(
                (out[i] - want).abs() <= want.abs() * 1e-6,
                "lane {i}: {} vs {want}",
                out[i]
            );
        }
        let m = svc.metrics();
        assert_eq!(m.requests, 1);
        assert_eq!(m.lanes, 40);
        svc.shutdown();
    }

    #[test]
    fn concurrent_submissions_batch_together() {
        let svc = DivisionService::start(
            ServiceConfig {
                workers: 1,
                max_batch: 256,
                max_wait: Duration::from_millis(5),
                queue_capacity: 512,
                ..ServiceConfig::default()
            },
            BackendChoice::Native {
                order: 5,
                ilm_iterations: None,
            },
        )
        .unwrap();
        let tickets: Vec<DivTicket> = (0..16)
            .map(|i| {
                svc.submit_request(DivRequest::from_f32(&[i as f32 + 1.0; 8], &[2.0; 8]))
                    .unwrap()
            })
            .collect();
        for (i, t) in tickets.into_iter().enumerate() {
            let out = t.wait().unwrap().to_f32().unwrap();
            assert_eq!(out.len(), 8);
            assert_eq!(out[0], (i as f32 + 1.0) / 2.0);
        }
        let m = svc.metrics();
        assert_eq!(m.requests, 16);
        // Coalescing must have produced fewer backend batches than requests.
        assert!(m.batches < 16, "batches = {}", m.batches);
        svc.shutdown();
    }

    #[test]
    fn rounding_modes_thread_through_the_service() {
        let svc = DivisionService::start(
            ServiceConfig::default(),
            BackendChoice::Gold,
        )
        .unwrap();
        // 1/3 in f64: toward-positive and toward-negative must bracket,
        // differing in the last bit.
        let up = svc
            .divide_request_blocking(
                DivRequest::from_f64(&[1.0], &[3.0]).with_rounding(Rounding::TowardPositive),
            )
            .unwrap();
        let down = svc
            .divide_request_blocking(
                DivRequest::from_f64(&[1.0], &[3.0]).with_rounding(Rounding::TowardNegative),
            )
            .unwrap();
        assert_eq!(up.fmt, F64);
        assert_eq!(up.bits[0], down.bits[0] + 1, "directed modes must bracket 1/3");
        svc.shutdown();
    }
}
