//! The division service: a batched request coordinator in plain threads
//! (no async runtime is vendored — see DESIGN.md §1).
//!
//! Architecture (vLLM-router-like, scaled to an arithmetic service):
//!
//! ```text
//!  clients ──submit(Vec<f32>,Vec<f32>)──► bounded queue
//!                                            │ (backpressure: Busy)
//!                                       batcher thread
//!                                            │ coalesce ≤ max_batch,
//!                                            │ flush on max_wait
//!                                       work queue ──► worker pool
//!                                                        │ backend:
//!                                                        │  Native (bit-exact
//!                                                        │  Taylor/ILM datapath)
//!                                                        │  or PJRT (AOT artifact)
//!                                       per-request response channels
//! ```
//!
//! * [`batcher`] — pure batch-assembly logic (coalesce/split), testable
//!   without threads;
//! * [`worker`] — the backend trait and its Native/PJRT implementations;
//! * [`service`] — the running system: threads, channels, metrics,
//!   shutdown, fault containment (a panicking backend fails the batch,
//!   not the service).

pub mod batcher;
pub mod service;
pub mod worker;

pub use batcher::{Batch, BatchAssembler};
pub use service::{DivisionService, MetricsSnapshot, ServiceConfig, SubmitError, Ticket};
pub use worker::{Backend, BackendChoice, NativeBackend, ScalarNativeBackend};

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn end_to_end_native_service() {
        let svc = DivisionService::start(
            ServiceConfig {
                workers: 2,
                max_batch: 64,
                max_wait: Duration::from_millis(2),
                queue_capacity: 128,
            },
            BackendChoice::Native {
                order: 5,
                ilm_iterations: None,
            },
        )
        .unwrap();
        let a: Vec<f32> = (1..=40).map(|i| i as f32).collect();
        let b: Vec<f32> = (1..=40).map(|i| (i % 7 + 1) as f32).collect();
        let out = svc.divide_blocking(a.clone(), b.clone()).unwrap();
        for i in 0..a.len() {
            let want = a[i] / b[i];
            assert!(
                (out[i] - want).abs() <= want.abs() * 1e-6,
                "lane {i}: {} vs {want}",
                out[i]
            );
        }
        let m = svc.metrics();
        assert_eq!(m.requests, 1);
        assert_eq!(m.lanes, 40);
        svc.shutdown();
    }

    #[test]
    fn concurrent_submissions_batch_together() {
        let svc = DivisionService::start(
            ServiceConfig {
                workers: 1,
                max_batch: 256,
                max_wait: Duration::from_millis(5),
                queue_capacity: 512,
            },
            BackendChoice::Native {
                order: 5,
                ilm_iterations: None,
            },
        )
        .unwrap();
        let tickets: Vec<Ticket> = (0..16)
            .map(|i| {
                svc.submit(vec![i as f32 + 1.0; 8], vec![2.0; 8]).unwrap()
            })
            .collect();
        for (i, t) in tickets.into_iter().enumerate() {
            let out = t.wait().unwrap();
            assert_eq!(out.len(), 8);
            assert_eq!(out[0], (i as f32 + 1.0) / 2.0);
        }
        let m = svc.metrics();
        assert_eq!(m.requests, 16);
        // Coalescing must have produced fewer backend batches than requests.
        assert!(m.batches < 16, "batches = {}", m.batches);
        svc.shutdown();
    }
}
