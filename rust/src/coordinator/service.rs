//! The running division service: sharded batchers + a work-stealing
//! worker pool + batched metrics.
//!
//! ## Sharding
//!
//! Submissions hash on their [`BatchKey`] (op × format × rounding) to one of
//! `shards` independent shards ([`ServiceConfig::shards`], default one
//! per worker). Each shard owns a bounded submission queue, a batcher
//! thread with its own [`BatchAssembler`] (cost-unit budgets and
//! per-key `take_expired` clocks intact), and a ready-batch deque. The
//! hash is key-affine — every lane of one `(Op, Format, Rounding)`
//! bucket lands on the same shard, so sharding never splits a
//! coalescing window. The one exception is the submitter-spread tiebreak: a
//! request so large it can only ship alone (its cost meets the full
//! batch budget) gains nothing from key affinity, so it spreads across
//! shards by request id instead of hot-spotting its key's shard.
//!
//! ## Work stealing
//!
//! Workers pop ready batches from their home shard (`wid % shards`)
//! first. A worker whose home deque is empty raids the busiest other
//! shard before parking: it takes half of that deque (rounded up),
//! executes the first stolen batch and migrates the rest to its home
//! deque. Batches travel whole — each carries its positionally-aligned
//! responders — so the PR-4 invariant (no cross-wired or hung waiters)
//! holds under any interleaving of steals. The ready deques share one
//! mutex + condvar: handoff is per *batch* (hundreds-to-thousands of
//! lanes), so a single uncontended lock costs far less than the work it
//! hands over, and it makes steal-vs-shutdown races impossible by
//! construction (the old design serialized on a `Mutex<Receiver>` at
//! exactly the same point).
//!
//! ## Metrics
//!
//! Worker counters are batched ([`super::metrics`]): accumulated in a
//! thread-local [`MetricsBatch`] and flushed with relaxed stores once
//! per park. Submit-path and dispatch counters ([`ServiceCounters`])
//! stay direct relaxed atomics — they feed the adaptive flush policy
//! and mid-flight assertions. [`DivisionService::metrics`] aggregates
//! both plus the latency histograms into one [`MetricsSnapshot`].

use std::collections::{HashMap, VecDeque};
use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender, SyncSender, TrySendError};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::batcher::{Batch, BatchAssembler, BatchItem, REF_LANE_COST};
use super::metrics::{AtomicHistogram, MetricsBatch, MetricsSnapshot, ServiceCounters, WorkerMetrics};
use super::request::{BatchKey, DivRequest, DivResponse};
use super::worker::{Backend, BackendChoice, RoutedBackend, ROUTER_SEED};
use crate::bail;
use crate::fp::{Format, Rounding};
use crate::router::{BackendRouter, Candidate};
use crate::util::error::Result;

/// Service configuration.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    /// Worker threads (each with its own backend instance).
    pub workers: usize,
    /// Shards: independent {submission queue + batcher + ready deque}
    /// units that submissions hash onto by `BatchKey`. `None` (the
    /// default) resolves to one shard per worker, overridable via the
    /// `TSDIV_SHARDS` env var (clamped to `[1, workers]`); an explicit
    /// `Some(n)` is validated strictly (`0 < n ≤ workers`) and ignores
    /// the env var.
    pub shards: Option<usize>,
    /// Coalescing budget per backend batch, in **f32-equivalent lanes**:
    /// the assembler meters cost units (`Format::lane_cost`, f64 ≈ 2×
    /// f16/bf16), so pure-f32 traffic batches exactly `max_batch` lanes
    /// while wider formats ship fewer lanes of equal backend work.
    pub max_batch: usize,
    /// Max time a request waits for co-batching before flush.
    pub max_wait: Duration,
    /// Bounded submission capacity (backpressure beyond this depth),
    /// split evenly across shards.
    pub queue_capacity: usize,
    /// Spare-capacity budget divisor: while every worker is idle and the
    /// queue is shallow, the coalescing budget drops to
    /// `max_batch / spare_divisor` so bursts split across idle workers
    /// instead of serializing into one deep batch. `1` disables the
    /// shrink; `0` is rejected by [`ServiceConfig::validate`].
    pub spare_divisor: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            workers: 2,
            shards: None,
            max_batch: 1024,
            max_wait: Duration::from_millis(1),
            queue_capacity: 4096,
            spare_divisor: 4,
        }
    }
}

impl ServiceConfig {
    /// Reject configurations that could only fail later, deep inside
    /// thread spawn or the assembler, with a useless panic.
    pub fn validate(&self) -> Result<()> {
        if self.workers == 0 {
            bail!("service config: workers must be > 0");
        }
        if let Some(s) = self.shards {
            if s == 0 {
                bail!("service config: shards must be > 0 (or None for one per worker)");
            }
            if s > self.workers {
                bail!(
                    "service config: shards ({s}) must not exceed workers ({}) — \
                     a shard with no home worker only ever drains by theft",
                    self.workers
                );
            }
        }
        if self.max_batch == 0 {
            bail!("service config: max_batch must be > 0 lanes");
        }
        if self.queue_capacity == 0 {
            bail!("service config: queue_capacity must be > 0");
        }
        if self.spare_divisor == 0 {
            bail!(
                "service config: spare_divisor must be > 0 \
                 (1 disables the spare-capacity budget shrink)"
            );
        }
        Ok(())
    }

    /// The shard count [`DivisionService::start`] will run with:
    /// explicit `Some(n)` verbatim; otherwise the `TSDIV_SHARDS` env
    /// override clamped to `[1, workers]`; otherwise one per worker.
    pub fn resolved_shards(&self) -> usize {
        if let Some(s) = self.shards {
            return s;
        }
        if let Ok(v) = std::env::var("TSDIV_SHARDS") {
            match v.trim().parse::<usize>() {
                Ok(n) if n >= 1 => return n.min(self.workers.max(1)),
                _ => crate::log_warn!(
                    "TSDIV_SHARDS='{v}' ignored (want a positive integer); \
                     defaulting to one shard per worker"
                ),
            }
        }
        self.workers
    }
}

/// Submission failure modes.
#[derive(Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// Queue full — backpressure; retry later.
    Busy,
    /// Service is shutting down.
    Closed,
    /// Operand vectors don't match the op's shape contract, are empty,
    /// or carry bits outside the format's storage width.
    BadRequest(String),
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Busy => write!(f, "queue full (backpressure)"),
            SubmitError::Closed => write!(f, "service closed"),
            SubmitError::BadRequest(m) => write!(f, "bad request: {m}"),
        }
    }
}
impl std::error::Error for SubmitError {}

/// Response handle for one submitted [`DivRequest`].
pub struct DivTicket {
    rx: Receiver<Result<Vec<u64>, String>>,
    fmt: Format,
    rm: Rounding,
    request_id: u64,
    submitted: Instant,
    latency_sink: Arc<AtomicHistogram>,
}

impl DivTicket {
    /// The id the service assigned this request (response routing).
    pub fn request_id(&self) -> u64 {
        self.request_id
    }

    pub fn format(&self) -> Format {
        self.fmt
    }

    pub fn rounding(&self) -> Rounding {
        self.rm
    }

    /// Block until the quotient lanes arrive.
    pub fn wait(self) -> Result<DivResponse, String> {
        let bits = self
            .rx
            .recv()
            .map_err(|_| "worker dropped the response channel".to_string())??;
        self.latency_sink.record(self.submitted.elapsed());
        Ok(DivResponse {
            fmt: self.fmt,
            rm: self.rm,
            bits,
        })
    }

    /// Non-blocking poll. A dropped responder resolves to an explicit
    /// error (matching [`DivTicket::wait`]) rather than reading as
    /// still-pending forever — polling loops must terminate through
    /// shutdown.
    pub fn try_wait(&self) -> Option<Result<DivResponse, String>> {
        match self.rx.try_recv() {
            Ok(Ok(bits)) => Some(Ok(DivResponse {
                fmt: self.fmt,
                rm: self.rm,
                bits,
            })),
            Ok(Err(e)) => Some(Err(e)),
            Err(mpsc::TryRecvError::Empty) => None,
            Err(mpsc::TryRecvError::Disconnected) => {
                Some(Err("worker dropped the response channel".to_string()))
            }
        }
    }
}

struct Submission {
    key: BatchKey,
    item: BatchItem,
    responder: Sender<Result<Vec<u64>, String>>,
}

/// One job for the worker pool: the batch plus one responder **slot per
/// item**, positionally aligned with `batch.items`. The alignment is
/// load-bearing: a missing responder must leave a `None` hole, never
/// shorten the list — a shorter list zipped against the items would
/// cross-wire every later item's reply onto the wrong waiter (and hang
/// the tail waiters forever in release builds). Jobs travel whole when
/// stolen, so the alignment survives any steal interleaving.
type Responders = Vec<Option<Sender<Result<Vec<u64>, String>>>>;
type WorkItem = (Batch, Responders);

/// Stable small index of a batch key: 4 ops × 4 formats × 4 rounding
/// modes.
fn key_slot(key: BatchKey) -> u64 {
    let f = match (key.fmt.exp_bits, key.fmt.frac_bits) {
        (5, 10) => 0u64,  // f16
        (8, 7) => 1,      // bf16
        (8, 23) => 2,     // f32
        _ => 3,           // f64 (and any future wide format)
    };
    let r = match key.rm {
        Rounding::NearestEven => 0u64,
        Rounding::TowardZero => 1,
        Rounding::TowardPositive => 2,
        Rounding::TowardNegative => 3,
    };
    key.op.idx() as u64 * 16 + f * 4 + r
}

/// Shard routing: a Fibonacci hash of the key slot keeps each
/// `(Op, Format, Rounding)` bucket's lanes on one shard (coalescing
/// windows never split), with `spread` folded in only for oversize requests
/// that ship alone anyway (`spread = 0` preserves pure key affinity).
fn shard_for(key: BatchKey, spread: u64, shards: usize) -> usize {
    const GOLDEN: u64 = 0x9E37_79B9_7F4A_7C15;
    let h = (key_slot(key) + 1).wrapping_mul(GOLDEN)
        ^ spread.wrapping_mul(GOLDEN).rotate_left(32);
    ((h >> 32) as usize) % shards.max(1)
}

/// The ready-batch exchange between shard batchers and workers: one
/// deque per shard behind a single mutex + condvar. `open_shards`
/// counts live batcher threads — workers exit once it hits zero *and*
/// every deque is drained, so shutdown never strands a dispatched
/// batch.
struct RunQueues {
    state: Mutex<RunState>,
    cv: Condvar,
}

struct RunState {
    ready: Vec<VecDeque<WorkItem>>,
    open_shards: usize,
}

impl RunQueues {
    fn new(shards: usize) -> Self {
        Self {
            state: Mutex::new(RunState {
                ready: (0..shards).map(|_| VecDeque::new()).collect(),
                open_shards: shards,
            }),
            cv: Condvar::new(),
        }
    }

    fn push(&self, shard: usize, job: WorkItem) {
        let mut st = self.state.lock().unwrap();
        st.ready[shard].push_back(job);
        drop(st);
        self.cv.notify_one();
    }

    fn shard_closed(&self) {
        let mut st = self.state.lock().unwrap();
        st.open_shards -= 1;
        let done = st.open_shards == 0;
        drop(st);
        if done {
            self.cv.notify_all();
        }
    }

    /// Worker job acquisition: home deque first, then steal half of the
    /// busiest other deque, else park. Returns `None` when every shard
    /// has closed and every deque is drained. Parking flushes the
    /// worker's metrics batch and maintains the global idle gauge.
    fn next_job(
        &self,
        home: usize,
        mb: &mut MetricsBatch,
        wm: &WorkerMetrics,
        batch_latency: &AtomicHistogram,
        counters: &ServiceCounters,
    ) -> Option<WorkItem> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(job) = st.ready[home].pop_front() {
                return Some(job);
            }
            // Steal from the busiest non-home shard: take half of its
            // deque (rounded up), execute the front batch, migrate the
            // rest home so this worker (or a woken peer) keeps draining
            // without revisiting the victim.
            let victim = (0..st.ready.len())
                .filter(|&s| s != home && !st.ready[s].is_empty())
                .max_by_key(|&s| st.ready[s].len());
            if let Some(v) = victim {
                let take = st.ready[v].len().div_ceil(2);
                let job = st.ready[v].pop_front().expect("victim checked non-empty");
                for _ in 1..take {
                    let migrated = st.ready[v].pop_front().expect("take ≤ victim len");
                    st.ready[home].push_back(migrated);
                }
                mb.incr_steal(take as u64);
                if take > 1 {
                    // Migrated batches are ready work a parked peer can
                    // start on while this worker runs the first one.
                    self.cv.notify_one();
                }
                return Some(job);
            }
            if st.open_shards == 0 {
                return None;
            }
            // Nothing anywhere: park. Flush the metrics batch first — a
            // parked worker has nothing better to do, and this is the
            // only point counters cross from thread-local to shared.
            mb.about_to_park();
            mb.submit(wm, batch_latency);
            counters.idle_workers.fetch_add(1, Ordering::Relaxed);
            st = self.cv.wait(st).unwrap();
            counters.idle_workers.fetch_sub(1, Ordering::Relaxed);
            mb.returned_from_park();
        }
    }
}

/// Decrements `open_shards` when the shard batcher exits — via `Drop`,
/// so a panicking batcher still releases the workers instead of
/// wedging shutdown.
struct ShardCloseGuard(Arc<RunQueues>);

impl Drop for ShardCloseGuard {
    fn drop(&mut self) {
        self.0.shard_closed();
    }
}

/// The running service.
pub struct DivisionService {
    /// Per-shard submission senders; `None` once closed. Behind an
    /// `RwLock` so [`DivisionService::close`] can disconnect the shards
    /// from `&self` while submitters race it (they observe `Closed`).
    shard_txs: RwLock<Option<Vec<SyncSender<Submission>>>>,
    shards: usize,
    worker_count: usize,
    /// Cost at or above which a request ships alone (the assembler's
    /// full budget) and therefore spreads across shards by request id.
    oversize_cost: usize,
    next_id: AtomicU64,
    counters: Arc<ServiceCounters>,
    request_latency: Arc<AtomicHistogram>,
    batch_latency: Arc<AtomicHistogram>,
    worker_metrics: Vec<Arc<WorkerMetrics>>,
    /// Present when serving `BackendChoice::Auto`: the routing table
    /// shared by every worker's [`RoutedBackend`], held here so
    /// [`DivisionService::metrics`] can report per-backend dispatch
    /// counts and win-rate.
    router: Option<Arc<BackendRouter>>,
    shard_threads: Vec<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

/// `TSDIV_ROUTER=auto` upgrades the *default* backend (`Native` without
/// an ILM override) to the routed `Auto` backend — the same
/// env-tunes-the-default contract as `TSDIV_SHARDS`. Explicitly pinned
/// backends (kernel, goldschmidt, gold, …, `Native` with an ILM
/// multiplier configured, and `Auto` itself) are never touched, so
/// tests and benches that pin a datapath stay pinned under a CI-wide
/// env.
fn resolve_router_env(choice: BackendChoice) -> BackendChoice {
    if let BackendChoice::Native {
        ilm_iterations: None,
        ..
    } = choice
    {
        if let Ok(v) = std::env::var("TSDIV_ROUTER") {
            match v.trim() {
                "auto" => return BackendChoice::Auto,
                "" => {}
                other => crate::log_warn!(
                    "TSDIV_ROUTER='{other}' ignored (only 'auto' is recognized)"
                ),
            }
        }
    }
    choice
}

/// One shard's batcher loop: coalesce this shard's submissions into
/// per-(Op, Format, Rounding) batches with the adaptive flush policy
/// (§Perf):
///
/// * a bucket reaching the lane budget ships immediately;
/// * every bucket carries its own clock: once its **oldest** lane has
///   waited `max_wait`, that bucket ships alone (per-key max_wait) — a
///   rare-(Op,Format,Rounding) lane no longer rides a window kept open by
///   busier keys, and fresh buckets keep coalescing instead of being
///   force-flushed alongside it;
/// * when this shard's queue runs dry, pending work ships only if a
///   worker is idle to take it (otherwise flushing buys no latency —
///   the buckets stay open, each bounded by its own max_wait, so deeper
///   batches form while every worker is busy);
/// * the lane budget itself adapts to load: spare capacity (all workers
///   idle, shallow queue) divides the budget so bursts split across
///   idle workers instead of serializing into one.
#[allow(clippy::too_many_arguments)]
fn run_shard(
    shard_id: usize,
    rx: Receiver<Submission>,
    rt: Arc<RunQueues>,
    counters: Arc<ServiceCounters>,
    max_wait: Duration,
    max_batch: usize,
    spare_divisor: usize,
    worker_count: usize,
) {
    let _close = ShardCloseGuard(Arc::clone(&rt));
    let mut asm = BatchAssembler::new(max_batch);
    let mut responders: HashMap<u64, Sender<Result<Vec<u64>, String>>> = HashMap::new();
    let dispatch = |batch: Batch,
                    responders: &mut HashMap<u64, Sender<Result<Vec<u64>, String>>>| {
        // One positional slot per item (see [`Responders`]). A lost
        // responder — a routing bug, not a load condition — is counted
        // as a failure and logged; its waiter's channel sender is gone,
        // so that `wait()` returns an explicit channel-closed error
        // instead of hanging, and every other item still routes to the
        // waiter that submitted it.
        let rs: Responders = batch
            .items
            .iter()
            .map(|it| responders.remove(&it.request_id))
            .collect();
        let lost = rs.iter().filter(|r| r.is_none()).count();
        if lost > 0 {
            // One count per affected batch, matching the
            // backend-error/panic paths' unit (the log line carries the
            // per-item count).
            counters.failures.fetch_add(1, Ordering::Relaxed);
            crate::log_error!(
                "shard {shard_id}: {lost} responder(s) missing for a batch of {} item(s); \
                 affected waiters receive a closed-channel error",
                batch.items.len()
            );
        }
        counters.batches.fetch_add(1, Ordering::Relaxed);
        counters
            .cost_units
            .fetch_add(batch.cost as u64, Ordering::Relaxed);
        rt.push(shard_id, (batch, rs));
    };
    let flush = |asm: &mut BatchAssembler,
                 responders: &mut HashMap<u64, Sender<Result<Vec<u64>, String>>>| {
        for batch in asm.take_all() {
            dispatch(batch, responders);
        }
    };
    // Retune the cost budget from load: spare capacity (all workers
    // idle, shallow queue) divides the budget by the configured
    // `spare_divisor` so bursts split across idle workers; saturation
    // restores the full budget. Called at window start AND on every
    // drain pass — sustained load must not pin a budget picked during
    // an idle burst-start. The budget stays denominated in
    // f32-equivalent lanes; the assembler meters it in cost units per
    // format. The gauges are global (all shards see the same pool of
    // workers), so every shard retunes from the same load signal.
    let retune = |asm: &mut BatchAssembler| {
        let spare_capacity = counters.idle_workers.load(Ordering::Relaxed) >= worker_count
            && counters.queue_depth.load(Ordering::Relaxed) <= worker_count;
        asm.set_max_lanes(if spare_capacity {
            (max_batch / spare_divisor).max(1)
        } else {
            max_batch
        });
    };
    'outer: loop {
        // Block for the first submission of a batch window.
        let sub = match rx.recv_timeout(Duration::from_millis(100)) {
            Ok(s) => s,
            Err(RecvTimeoutError::Timeout) => continue,
            Err(RecvTimeoutError::Disconnected) => break,
        };
        retune(&mut asm);
        counters.queue_depth.fetch_sub(1, Ordering::Relaxed);
        responders.insert(sub.item.request_id, sub.responder);
        if let Some(batch) = asm.push(sub.key, sub.item) {
            dispatch(batch, &mut responders);
        }
        // Drain this shard's queue while work is pending. Each bucket's
        // own clock (started at its first lane) bounds its latency:
        // take_expired ships exactly the buckets whose oldest lane
        // waited max_wait.
        loop {
            match rx.try_recv() {
                Ok(sub) => {
                    counters.queue_depth.fetch_sub(1, Ordering::Relaxed);
                    responders.insert(sub.item.request_id, sub.responder);
                    if let Some(batch) = asm.push(sub.key, sub.item) {
                        dispatch(batch, &mut responders);
                    }
                }
                Err(mpsc::TryRecvError::Empty) => {
                    if asm.pending_lanes() == 0 {
                        break;
                    }
                    // Queue dry. Ship everything if a worker can start
                    // on it right now; otherwise hold the buckets open
                    // so more lanes coalesce while all workers are busy
                    // — per-key expiry below still bounds every
                    // bucket's wait.
                    if counters.idle_workers.load(Ordering::Relaxed) > 0 {
                        flush(&mut asm, &mut responders);
                        break;
                    }
                    std::thread::sleep(Duration::from_micros(10));
                }
                Err(mpsc::TryRecvError::Disconnected) => {
                    flush(&mut asm, &mut responders);
                    break 'outer;
                }
            }
            retune(&mut asm);
            for batch in asm.take_expired(max_wait) {
                dispatch(batch, &mut responders);
            }
        }
    }
    // Shutdown: drain any pending work.
    flush(&mut asm, &mut responders);
}

impl DivisionService {
    /// Start `shards` batcher threads and `cfg.workers` worker threads.
    pub fn start(cfg: ServiceConfig, backend: BackendChoice) -> Result<Self> {
        cfg.validate()?;
        let backend = resolve_router_env(backend);
        backend.validate()?;
        // One routing table for the whole pool: every worker's routed
        // backend feeds the same per-bucket scores, seeded from rolling
        // bench-history medians when the file exists (a fresh checkout
        // starts from the static cost model instead).
        let router: Option<Arc<BackendRouter>> = match backend {
            BackendChoice::Auto => {
                let r = Arc::new(BackendRouter::new(ROUTER_SEED));
                if let Ok(records) =
                    crate::harness::read_bench_history(&crate::harness::bench_history_path())
                {
                    r.seed_from_history(&records);
                }
                Some(r)
            }
            _ => None,
        };
        let shards = cfg.resolved_shards();
        let counters = Arc::new(ServiceCounters::default());
        let request_latency = Arc::new(AtomicHistogram::new());
        let batch_latency = Arc::new(AtomicHistogram::new());
        let runtime = Arc::new(RunQueues::new(shards));

        // Shard batcher threads, each owning its bounded queue slice.
        let per_shard_cap = cfg.queue_capacity.div_ceil(shards).max(1);
        let mut shard_txs = Vec::with_capacity(shards);
        let mut shard_threads = Vec::with_capacity(shards);
        for shard_id in 0..shards {
            let (tx, rx) = mpsc::sync_channel::<Submission>(per_shard_cap);
            shard_txs.push(tx);
            let rt = Arc::clone(&runtime);
            let c = Arc::clone(&counters);
            let (max_wait, max_batch) = (cfg.max_wait, cfg.max_batch);
            let (spare_divisor, worker_count) = (cfg.spare_divisor, cfg.workers);
            shard_threads.push(
                std::thread::Builder::new()
                    .name(format!("tsdiv-shard-{shard_id}"))
                    .spawn(move || {
                        run_shard(
                            shard_id,
                            rx,
                            rt,
                            c,
                            max_wait,
                            max_batch,
                            spare_divisor,
                            worker_count,
                        )
                    })?,
            );
        }

        // Worker pool: home shard by id, stealing from the rest.
        let mut workers = Vec::with_capacity(cfg.workers);
        let mut worker_metrics = Vec::with_capacity(cfg.workers);
        for wid in 0..cfg.workers {
            let rt = Arc::clone(&runtime);
            let c = Arc::clone(&counters);
            let bl = Arc::clone(&batch_latency);
            let wm = Arc::new(WorkerMetrics::default());
            worker_metrics.push(Arc::clone(&wm));
            let home = wid % shards;
            let choice = backend;
            let shared_router = router.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("tsdiv-worker-{wid}"))
                    .spawn(move || {
                        // `Auto` workers share the service's router
                        // (one table, history-seeded) instead of the
                        // private one a standalone `build()` creates.
                        let built: Result<Box<dyn Backend>> = match &shared_router {
                            Some(r) => RoutedBackend::new(Arc::clone(r))
                                .map(|b| Box::new(b) as Box<dyn Backend>),
                            None => choice.build(),
                        };
                        let mut backend = match built {
                            Ok(b) => b,
                            Err(e) => {
                                crate::log_error!("worker {wid}: backend init failed: {e}");
                                return;
                            }
                        };
                        let mut mb = MetricsBatch::new();
                        while let Some((batch, responders)) =
                            rt.next_job(home, &mut mb, &wm, &bl, &c)
                        {
                            mb.incr_poll();
                            let (a, b, rows) = batch.flatten();
                            let key = batch.key;
                            let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
                                backend.compute(key.op, &a, &b, &rows, key.fmt, key.rm)
                            }));
                            match result {
                                Ok(Ok(flat)) => {
                                    // Positional zip: responders is one
                                    // slot per item by construction, so
                                    // lanes can never shift onto
                                    // another item's waiter.
                                    for ((_, lanes), r) in
                                        batch.split(&flat).into_iter().zip(responders)
                                    {
                                        if let Some(r) = r {
                                            let _ = r.send(Ok(lanes));
                                        }
                                    }
                                }
                                Ok(Err(e)) => {
                                    c.failures.fetch_add(1, Ordering::Relaxed);
                                    for r in responders.into_iter().flatten() {
                                        let _ = r.send(Err(format!("backend error: {e}")));
                                    }
                                }
                                Err(_) => {
                                    c.failures.fetch_add(1, Ordering::Relaxed);
                                    for r in responders.into_iter().flatten() {
                                        let _ = r
                                            .send(Err("backend panicked on batch".to_string()));
                                    }
                                }
                            }
                            // Oldest lane queued → responses sent: the
                            // batch-latency sample (buffered; flushed
                            // on the next park).
                            mb.record_batch_latency(batch.age(Instant::now()));
                        }
                        mb.finish();
                        mb.submit(&wm, &bl);
                    })?,
            );
        }

        Ok(Self {
            shard_txs: RwLock::new(Some(shard_txs)),
            shards,
            worker_count: cfg.workers,
            oversize_cost: cfg.max_batch * REF_LANE_COST,
            next_id: AtomicU64::new(0),
            counters,
            request_latency,
            batch_latency,
            worker_metrics,
            router,
            shard_threads,
            workers,
        })
    }

    /// Submit a typed request. Non-blocking; `Busy` under backpressure.
    /// Requests of any `(Op, Format, Rounding)` mix coalesce into
    /// homogeneous backend batches keyed by that triple, on the shard
    /// their key hashes to.
    pub fn submit_request(&self, req: DivRequest) -> Result<DivTicket, SubmitError> {
        if let Err(defect) = req.validate() {
            return Err(SubmitError::BadRequest(defect));
        }
        let lanes = req.lanes() as u64;
        let (fmt, rm) = (req.fmt, req.rm);
        let key = req.key();
        let request_id = self.next_id.fetch_add(1, Ordering::Relaxed);
        // Submitter-spread tiebreak: a request that meets the full
        // batch budget on its own ships alone whatever shard it lands
        // on, so spread those by id instead of hot-spotting the key's
        // home shard.
        let spread = if req.lanes() * key.lane_cost() >= self.oversize_cost {
            request_id
        } else {
            0
        };
        let (rtx, rrx) = mpsc::channel();
        let sub = Submission {
            key,
            item: BatchItem {
                request_id,
                a: req.a,
                b: req.b,
                rows: req.rows,
            },
            responder: rtx,
        };
        let guard = self.shard_txs.read().map_err(|_| SubmitError::Closed)?;
        let txs = guard.as_ref().ok_or(SubmitError::Closed)?;
        let shard = shard_for(key, spread, txs.len());
        // Count the submission BEFORE it becomes visible to the shard:
        // incrementing after a successful try_send races the batcher's
        // decrement and can wrap the gauge below zero (the adaptive
        // flush policy reads it). Over-counting an in-flight rejected
        // submission for a moment is harmless; undo on failure.
        self.counters.queue_depth.fetch_add(1, Ordering::Relaxed);
        match txs[shard].try_send(sub) {
            Ok(()) => {
                self.counters.requests.fetch_add(1, Ordering::Relaxed);
                self.counters.lanes.fetch_add(lanes, Ordering::Relaxed);
                Ok(DivTicket {
                    rx: rrx,
                    fmt,
                    rm,
                    request_id,
                    submitted: Instant::now(),
                    latency_sink: Arc::clone(&self.request_latency),
                })
            }
            Err(TrySendError::Full(_)) => {
                self.counters.queue_depth.fetch_sub(1, Ordering::Relaxed);
                self.counters.rejected.fetch_add(1, Ordering::Relaxed);
                Err(SubmitError::Busy)
            }
            Err(TrySendError::Disconnected(_)) => {
                self.counters.queue_depth.fetch_sub(1, Ordering::Relaxed);
                Err(SubmitError::Closed)
            }
        }
    }

    /// Submit a typed request and wait for its response.
    pub fn divide_request_blocking(&self, req: DivRequest) -> Result<DivResponse, String> {
        let t = self.submit_request(req).map_err(|e| e.to_string())?;
        t.wait()
    }

    /// Close the submission intake from `&self`: every subsequent
    /// submit observes `Closed`, already-accepted work still drains and
    /// responds. Idempotent; `shutdown`/`Drop` call it before joining.
    pub fn close(&self) {
        if let Ok(mut txs) = self.shard_txs.write() {
            *txs = None; // disconnect → shard batchers drain and exit
        }
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        let latency_count = self.request_latency.count();
        let (mut parks, mut noops, mut steals) = (0u64, 0u64, 0u64);
        let (mut steal_operations, mut polls, mut busy_ns) = (0u64, 0u64, 0u64);
        for wm in &self.worker_metrics {
            parks += wm.parks();
            noops += wm.noops();
            steals += wm.steals();
            steal_operations += wm.steal_operations();
            polls += wm.polls();
            busy_ns += wm.busy_duration().as_nanos().min(u64::MAX as u128) as u64;
        }
        MetricsSnapshot {
            requests: self.counters.requests.load(Ordering::Relaxed),
            lanes: self.counters.lanes.load(Ordering::Relaxed),
            cost_units: self.counters.cost_units.load(Ordering::Relaxed),
            batches: self.counters.batches.load(Ordering::Relaxed),
            failures: self.counters.failures.load(Ordering::Relaxed),
            rejected: self.counters.rejected.load(Ordering::Relaxed),
            queue_depth: self.counters.queue_depth.load(Ordering::Relaxed),
            workers_idle: self.counters.idle_workers.load(Ordering::Relaxed),
            latency_p50: self.request_latency.percentile_seconds(0.5),
            latency_p99: self.request_latency.percentile_seconds(0.99),
            latency_mean: self.request_latency.mean_seconds(),
            latency_count,
            shards: self.shards,
            workers: self.worker_count,
            parks,
            noops,
            steals,
            steal_operations,
            polls,
            busy_seconds: busy_ns as f64 * 1e-9,
            batch_latency_p50: self.batch_latency.percentile_seconds(0.5),
            batch_latency_p99: self.batch_latency.percentile_seconds(0.99),
            batch_latency_count: self.batch_latency.count(),
            router_kernel_batches: self
                .router
                .as_ref()
                .map_or(0, |r| r.dispatches(Candidate::Kernel)),
            router_goldschmidt_batches: self
                .router
                .as_ref()
                .map_or(0, |r| r.dispatches(Candidate::Goldschmidt)),
            router_kernel_win_rate: self
                .router
                .as_ref()
                .map_or(0.0, |r| r.win_rate(Candidate::Kernel)),
        }
    }

    fn join_all(&mut self) {
        self.close();
        for s in self.shard_threads.drain(..) {
            let _ = s.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }

    /// Graceful shutdown: close the intake, join every shard batcher
    /// and worker (all accepted work resolves first).
    pub fn shutdown(mut self) {
        self.join_all();
    }
}

impl Drop for DivisionService {
    fn drop(&mut self) {
        self.join_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp::{F16, F32, F64};

    fn svc(workers: usize, max_batch: usize, cap: usize) -> DivisionService {
        DivisionService::start(
            ServiceConfig {
                workers,
                max_batch,
                max_wait: Duration::from_millis(1),
                queue_capacity: cap,
                ..ServiceConfig::default()
            },
            BackendChoice::Native {
                order: 5,
                ilm_iterations: None,
            },
        )
        .unwrap()
    }

    fn f32_req(a: &[f32], b: &[f32]) -> DivRequest {
        DivRequest::from_f32(a, b)
    }

    #[test]
    fn zero_sized_configs_rejected_up_front() {
        for cfg in [
            ServiceConfig {
                workers: 0,
                ..Default::default()
            },
            ServiceConfig {
                max_batch: 0,
                ..Default::default()
            },
            ServiceConfig {
                queue_capacity: 0,
                ..Default::default()
            },
            ServiceConfig {
                spare_divisor: 0,
                ..Default::default()
            },
            ServiceConfig {
                shards: Some(0),
                ..Default::default()
            },
            ServiceConfig {
                workers: 2,
                shards: Some(3),
                ..Default::default()
            },
        ] {
            let r = DivisionService::start(
                cfg.clone(),
                BackendChoice::Native {
                    order: 5,
                    ilm_iterations: None,
                },
            );
            let e = match r {
                Err(e) => e,
                Ok(_) => panic!("config {cfg:?} must be rejected"),
            };
            assert!(e.to_string().contains("service config"), "{e}");
        }
    }

    #[test]
    fn shard_hashing_is_key_affine_and_spreads_oversize() {
        use crate::fp::{ALL_FORMATS, BF16};
        // Same key, same shard — always (spread = 0 for in-budget work).
        for fmt in ALL_FORMATS {
            for rm in Rounding::ALL {
                let key = BatchKey::new(fmt, rm);
                let s = shard_for(key, 0, 4);
                assert_eq!(s, shard_for(key, 0, 4), "routing must be deterministic");
                assert!(s < 4);
            }
        }
        // The 16 keys must not all collapse onto one shard of 4.
        let shards: std::collections::HashSet<usize> = ALL_FORMATS
            .into_iter()
            .flat_map(|fmt| {
                Rounding::ALL
                    .into_iter()
                    .map(move |rm| shard_for(BatchKey::new(fmt, rm), 0, 4))
            })
            .collect();
        assert!(shards.len() >= 2, "keys all hashed to one shard: {shards:?}");
        // Oversize spread: one hot key fans out across shards by id.
        let key = BatchKey::new(BF16, Rounding::NearestEven);
        let spread: std::collections::HashSet<usize> =
            (0..32u64).map(|id| shard_for(key, id, 4)).collect();
        assert!(spread.len() >= 2, "oversize requests must spread: {spread:?}");
        // Single shard: everything routes to 0.
        assert_eq!(shard_for(key, 7, 1), 0);
    }

    #[test]
    fn explicit_shard_count_is_honored_and_reported() {
        let s = DivisionService::start(
            ServiceConfig {
                workers: 4,
                shards: Some(2),
                max_batch: 64,
                queue_capacity: 256,
                ..ServiceConfig::default()
            },
            BackendChoice::Native {
                order: 5,
                ilm_iterations: None,
            },
        )
        .unwrap();
        let out = s
            .divide_request_blocking(f32_req(&[9.0, 6.0], &[3.0, 2.0]))
            .unwrap();
        assert_eq!(out.to_f32().unwrap(), vec![3.0, 3.0]);
        let m = s.metrics();
        assert_eq!(m.shards, 2);
        assert_eq!(m.workers, 4);
        s.shutdown();
    }

    #[test]
    fn close_rejects_new_work_but_resolves_accepted_tickets() {
        let s = svc(2, 64, 64);
        let t = s.submit_request(f32_req(&[8.0; 8], &[2.0; 8])).unwrap();
        s.close();
        assert!(matches!(
            s.submit_request(f32_req(&[1.0], &[1.0])),
            Err(SubmitError::Closed)
        ));
        // The accepted ticket still resolves (drain-on-close).
        assert_eq!(t.wait().unwrap().to_f32().unwrap(), vec![4.0; 8]);
        s.shutdown();
    }

    #[test]
    fn worker_metrics_flush_on_park() {
        let s = svc(2, 64, 64);
        for _ in 0..4 {
            let t = s.submit_request(f32_req(&[9.0; 4], &[3.0; 4])).unwrap();
            assert_eq!(t.wait().unwrap().to_f32().unwrap(), vec![3.0; 4]);
        }
        // Flushes land once the workers park after the drain.
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            let m = s.metrics();
            if m.polls > 0 && m.parks > 0 && m.batch_latency_count > 0 {
                assert!(m.batch_latency_p99 >= m.batch_latency_p50);
                assert!(m.busy_seconds > 0.0);
                break;
            }
            assert!(
                Instant::now() < deadline,
                "worker metrics never flushed: {m:?}"
            );
            std::thread::sleep(Duration::from_millis(1));
        }
        s.shutdown();
    }

    #[test]
    fn kernel_backend_serves_and_bad_kernel_config_rejected_up_front() {
        use crate::kernel::KernelConfig;
        let s = DivisionService::start(
            ServiceConfig::default(),
            BackendChoice::Kernel {
                order: 5,
                kernel: KernelConfig::default(),
            },
        )
        .unwrap();
        let resp = s
            .divide_request_blocking(DivRequest::from_f32(&[9.0, 6.0, 1.0], &[3.0, 2.0, 4.0]))
            .unwrap();
        assert_eq!(resp.to_f32().unwrap(), vec![3.0, 3.0, 0.25]);
        s.shutdown();
        let r = DivisionService::start(
            ServiceConfig::default(),
            BackendChoice::Kernel {
                order: 5,
                kernel: KernelConfig {
                    tile: 0,
                    ilm_iterations: None,
                    ..KernelConfig::default()
                },
            },
        );
        let e = match r {
            Err(e) => e,
            Ok(_) => panic!("zero-tile kernel config must be rejected"),
        };
        assert!(e.to_string().contains("kernel config"), "{e}");
    }

    #[test]
    fn bad_requests_rejected() {
        let s = svc(1, 64, 16);
        assert!(matches!(
            s.submit_request(f32_req(&[1.0], &[1.0, 2.0])),
            Err(SubmitError::BadRequest(_))
        ));
        assert!(matches!(
            s.submit_request(f32_req(&[], &[])),
            Err(SubmitError::BadRequest(_))
        ));
        // Bits beyond f16's storage width.
        assert!(matches!(
            s.submit_request(DivRequest::new(
                F16,
                Rounding::NearestEven,
                vec![0x3C00],
                vec![0x12_3456],
            )),
            Err(SubmitError::BadRequest(_))
        ));
        s.shutdown();
    }

    #[test]
    fn typed_roundtrip_f64_and_f16() {
        let s = svc(1, 64, 64);
        let resp = s
            .divide_request_blocking(DivRequest::from_f64(&[10.0, -3.0], &[4.0, 2.0]))
            .unwrap();
        assert_eq!(resp.fmt, F64);
        assert_eq!(resp.to_f64().unwrap(), vec![2.5, -1.5]);
        // f16: 6.0/2.0 = 3.0 (0x4600 / 0x4000 = 0x4200).
        let resp = s
            .divide_request_blocking(DivRequest::from_f16_bits(&[0x4600], &[0x4000]))
            .unwrap();
        assert_eq!(resp.to_u16_bits().unwrap(), vec![0x4200]);
        s.shutdown();
    }

    #[test]
    fn ticket_reports_request_metadata() {
        let s = svc(1, 64, 64);
        let t1 = s.submit_request(f32_req(&[1.0], &[2.0])).unwrap();
        let t2 = s
            .submit_request(DivRequest::from_f64(&[1.0], &[2.0]).with_rounding(Rounding::TowardZero))
            .unwrap();
        assert!(t2.request_id() > t1.request_id());
        assert_eq!(t1.format(), F32);
        assert_eq!(t2.format(), F64);
        assert_eq!(t2.rounding(), Rounding::TowardZero);
        let r1 = t1.wait().unwrap();
        let r2 = t2.wait().unwrap();
        assert_eq!(r1.to_f32().unwrap(), vec![0.5]);
        assert_eq!(r2.to_f64().unwrap(), vec![0.5]);
        s.shutdown();
    }

    #[test]
    fn latency_metrics_populate() {
        let s = svc(1, 64, 64);
        for _ in 0..5 {
            let t = s.submit_request(f32_req(&[9.0; 4], &[3.0; 4])).unwrap();
            assert_eq!(t.wait().unwrap().to_f32().unwrap(), vec![3.0; 4]);
        }
        let m = s.metrics();
        assert_eq!(m.latency_count, 5);
        assert!(m.latency_p50 > 0.0);
        assert!(m.latency_p99 >= m.latency_p50);
        assert!(m.mean_batch_lanes() >= 4.0);
        s.shutdown();
    }

    #[test]
    fn backpressure_returns_busy() {
        // Tiny queue + many submissions without waiting → at least one Busy
        // (the batcher drains fast, so spam it).
        let s = svc(1, 1 << 20, 2);
        let mut busy = 0;
        let mut tickets = Vec::new();
        for _ in 0..2000 {
            match s.submit_request(f32_req(&[1.0; 64], &[2.0; 64])) {
                Ok(t) => tickets.push(t),
                Err(SubmitError::Busy) => busy += 1,
                Err(e) => panic!("unexpected {e}"),
            }
        }
        // Drain what was accepted.
        for t in tickets {
            let _ = t.wait();
        }
        assert!(busy > 0, "expected backpressure");
        assert_eq!(s.metrics().rejected, busy);
        s.shutdown();
    }

    #[test]
    fn shutdown_after_inflight_work() {
        let s = svc(4, 128, 512);
        let tickets: Vec<_> = (0..64)
            .map(|i| s.submit_request(f32_req(&[i as f32; 16], &[4.0; 16])).unwrap())
            .collect();
        for (i, t) in tickets.into_iter().enumerate() {
            assert_eq!(t.wait().unwrap().to_f32().unwrap()[0], i as f32 / 4.0);
        }
        s.shutdown();
    }

    #[test]
    fn drop_without_shutdown_joins_cleanly() {
        let s = svc(2, 64, 64);
        let t = s.submit_request(f32_req(&[8.0; 8], &[2.0; 8])).unwrap();
        assert_eq!(t.wait().unwrap().to_f32().unwrap(), vec![4.0; 8]);
        drop(s); // must not hang or panic
    }

    #[test]
    fn auto_backend_serves_and_reports_router_metrics() {
        let s = DivisionService::start(
            ServiceConfig {
                workers: 2,
                max_batch: 64,
                queue_capacity: 256,
                ..ServiceConfig::default()
            },
            BackendChoice::Auto,
        )
        .unwrap();
        for i in 1..=16u32 {
            let resp = s
                .divide_request_blocking(f32_req(&[i as f32; 8], &[2.0; 8]))
                .unwrap();
            assert_eq!(resp.to_f32().unwrap(), vec![i as f32 / 2.0; 8]);
        }
        let m = s.metrics();
        // Every dispatched batch is attributed to exactly one datapath.
        assert_eq!(
            m.router_kernel_batches + m.router_goldschmidt_batches,
            m.batches,
            "{m:?}"
        );
        assert!(m.batches >= 1);
        assert!((0.0..=1.0).contains(&m.router_kernel_win_rate));
        s.shutdown();
        // Fixed backends report zeroed router metrics.
        let s = svc(1, 64, 64);
        s.divide_request_blocking(f32_req(&[8.0], &[2.0])).unwrap();
        let m = s.metrics();
        assert_eq!(m.router_kernel_batches + m.router_goldschmidt_batches, 0);
        assert_eq!(m.router_kernel_win_rate, 0.0);
        s.shutdown();
    }

    #[test]
    fn cost_units_metric_weighs_formats() {
        // Equal lane counts per format; the dispatched cost gauge must
        // weigh them by lane_cost (f64 2× f16), not count raw lanes.
        let s = svc(1, 64, 64);
        let lanes_per_fmt = 8u64;
        let resp = s
            .divide_request_blocking(DivRequest::from_f16_bits(&[0x4600; 8], &[0x4000; 8]))
            .unwrap();
        assert_eq!(resp.lanes(), 8);
        s.divide_request_blocking(DivRequest::from_f32(&[6.0; 8], &[2.0; 8]))
            .unwrap();
        s.divide_request_blocking(DivRequest::from_f64(&[6.0; 8], &[2.0; 8]))
            .unwrap();
        let m = s.metrics();
        assert_eq!(m.lanes, 3 * lanes_per_fmt);
        let want = lanes_per_fmt * (F16.lane_cost() + F32.lane_cost() + F64.lane_cost()) as u64;
        assert_eq!(m.cost_units, want, "cost gauge must sum per-format lane_cost");
        assert!(m.mean_batch_cost() > 0.0);
        s.shutdown();
    }

    #[test]
    fn spare_divisor_one_disables_budget_shrink_and_serves() {
        // spare_divisor = 1 keeps the full budget under idle workers;
        // the service must validate and serve normally.
        let s = DivisionService::start(
            ServiceConfig {
                workers: 1,
                shards: None,
                max_batch: 64,
                max_wait: Duration::from_millis(1),
                queue_capacity: 64,
                spare_divisor: 1,
            },
            BackendChoice::Native {
                order: 5,
                ilm_iterations: None,
            },
        )
        .unwrap();
        let out = s
            .divide_request_blocking(f32_req(&[9.0, 6.0], &[3.0, 2.0]))
            .unwrap();
        assert_eq!(out.to_f32().unwrap(), vec![3.0, 3.0]);
        s.shutdown();
    }

    #[test]
    fn mixed_format_requests_coalesce_homogeneously() {
        // One service, interleaved f32/f64 submissions: responses must
        // come back typed and correct even when batches interleave.
        let s = svc(2, 256, 256);
        let mut tickets = Vec::new();
        for i in 1..=24u32 {
            if i % 2 == 0 {
                tickets.push((i, s.submit_request(f32_req(&[i as f32], &[2.0])).unwrap()));
            } else {
                tickets.push((
                    i,
                    s.submit_request(DivRequest::from_f64(&[i as f64], &[2.0])).unwrap(),
                ));
            }
        }
        for (i, t) in tickets {
            let resp = t.wait().unwrap();
            if i % 2 == 0 {
                assert_eq!(resp.to_f32().unwrap(), vec![i as f32 / 2.0]);
            } else {
                assert_eq!(resp.to_f64().unwrap(), vec![i as f64 / 2.0]);
            }
        }
        assert_eq!(s.metrics().failures, 0);
        s.shutdown();
    }

    #[test]
    fn per_op_requests_serve_end_to_end_in_lane_order() {
        use crate::fp::Op;
        let bits = |xs: &[f32]| -> Vec<u64> { xs.iter().map(|&x| x.to_bits() as u64).collect() };
        let s = DivisionService::start(
            ServiceConfig {
                workers: 2,
                max_batch: 64,
                queue_capacity: 256,
                ..ServiceConfig::default()
            },
            BackendChoice::Kernel {
                order: 5,
                kernel: crate::kernel::KernelConfig::default(),
            },
        )
        .unwrap();
        // Unary ops carry no divisor vector at all.
        let r = s
            .divide_request_blocking(DivRequest::recip(
                F32,
                Rounding::NearestEven,
                bits(&[4.0, 0.5, -8.0]),
            ))
            .unwrap();
        assert_eq!(r.to_f32().unwrap(), vec![0.25, 2.0, -0.125]);
        let r = s
            .divide_request_blocking(DivRequest::rsqrt(
                F32,
                Rounding::NearestEven,
                bits(&[4.0, 0.25, 1.0]),
            ))
            .unwrap();
        assert_eq!(r.to_f32().unwrap(), vec![0.5, 2.0, 1.0]);
        // ScaleByRecip with rows of 5 lanes: not a multiple of the
        // kernel's 8-lane tile, so the second row straddles a tile
        // boundary — results must still come back in lane order.
        let lanes: Vec<f32> = (1..=10).map(|i| i as f32).collect();
        let r = s
            .divide_request_blocking(DivRequest::scale_by_recip(
                F32,
                Rounding::NearestEven,
                bits(&lanes),
                bits(&[2.0, 4.0]),
            ))
            .unwrap();
        let want: Vec<f32> = lanes
            .iter()
            .enumerate()
            .map(|(i, &x)| if i < 5 { x / 2.0 } else { x / 4.0 })
            .collect();
        assert_eq!(r.to_f32().unwrap(), want);
        // Shape violations reject at submit time, before any queueing.
        assert!(matches!(
            s.submit_request(DivRequest {
                op: Op::Recip,
                fmt: F32,
                rm: Rounding::NearestEven,
                a: bits(&[1.0]),
                b: bits(&[2.0]),
                rows: vec![],
            }),
            Err(SubmitError::BadRequest(_))
        ));
        assert!(matches!(
            s.submit_request(DivRequest::scale_by_recip(
                F32,
                Rounding::NearestEven,
                bits(&[1.0, 2.0, 3.0]),
                bits(&[2.0, 4.0]),
            )),
            Err(SubmitError::BadRequest(_))
        ));
        s.shutdown();
    }

    #[test]
    fn ragged_scale_recip_serves_end_to_end_in_lane_order() {
        // Named regression for the equal-length-rows restriction: a
        // ragged row shape (4 + 1 + 5 lanes over three divisors) must
        // serve through the batched kernel and come back in lane order.
        let bits = |xs: &[f32]| -> Vec<u64> { xs.iter().map(|&x| x.to_bits() as u64).collect() };
        let s = DivisionService::start(
            ServiceConfig {
                workers: 2,
                max_batch: 64,
                queue_capacity: 256,
                ..ServiceConfig::default()
            },
            BackendChoice::Kernel {
                order: 5,
                kernel: crate::kernel::KernelConfig::default(),
            },
        )
        .unwrap();
        let lanes: Vec<f32> = (1..=10).map(|i| i as f32).collect();
        let rows = [4u32, 1, 5];
        let divisors = [2.0f32, 8.0, 4.0];
        let r = s
            .divide_request_blocking(DivRequest::scale_by_recip_ragged(
                F32,
                Rounding::NearestEven,
                bits(&lanes),
                bits(&divisors),
                rows.to_vec(),
            ))
            .unwrap();
        let mut want = Vec::new();
        let mut lane = 0;
        for (row, &n) in rows.iter().enumerate() {
            for _ in 0..n {
                want.push(lanes[lane] / divisors[row]);
                lane += 1;
            }
        }
        assert_eq!(r.to_f32().unwrap(), want);
        // A malformed ragged shape rejects at submit, before queueing.
        assert!(matches!(
            s.submit_request(DivRequest::scale_by_recip_ragged(
                F32,
                Rounding::NearestEven,
                bits(&lanes),
                bits(&divisors),
                vec![4, 1, 4],
            )),
            Err(SubmitError::BadRequest(_))
        ));
        s.shutdown();
    }

    #[test]
    fn division_only_backend_surfaces_op_rejection_to_the_waiter() {
        let s = svc(1, 64, 64); // Native backend: div only
        let err = s
            .divide_request_blocking(DivRequest::recip(
                F32,
                Rounding::NearestEven,
                vec![2.0f32.to_bits() as u64],
            ))
            .unwrap_err();
        assert!(err.contains("div only"), "{err}");
        // Division keeps working on the same service afterwards.
        let out = s
            .divide_request_blocking(f32_req(&[9.0], &[3.0]))
            .unwrap();
        assert_eq!(out.to_f32().unwrap(), vec![3.0]);
        s.shutdown();
    }
}
