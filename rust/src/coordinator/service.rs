//! The running division service: batcher thread + worker pool + metrics.

use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::batcher::{Batch, BatchAssembler, BatchItem};
use super::worker::BackendChoice;
use crate::util::error::Result;
use crate::util::stats::Summary;

/// Service configuration.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    /// Worker threads (each with its own backend instance).
    pub workers: usize,
    /// Max lanes coalesced into one backend batch.
    pub max_batch: usize,
    /// Max time a request waits for co-batching before flush.
    pub max_wait: Duration,
    /// Bounded submission queue (backpressure beyond this depth).
    pub queue_capacity: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            workers: 2,
            max_batch: 1024,
            max_wait: Duration::from_millis(1),
            queue_capacity: 4096,
        }
    }
}

/// Submission failure modes.
#[derive(Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// Queue full — backpressure; retry later.
    Busy,
    /// Service is shutting down.
    Closed,
    /// Operand vectors disagree in length or are empty.
    BadRequest(String),
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Busy => write!(f, "queue full (backpressure)"),
            SubmitError::Closed => write!(f, "service closed"),
            SubmitError::BadRequest(m) => write!(f, "bad request: {m}"),
        }
    }
}
impl std::error::Error for SubmitError {}

/// Response handle for one submitted request.
pub struct Ticket {
    rx: Receiver<Result<Vec<f32>, String>>,
    submitted: Instant,
    latency_sink: Arc<Mutex<Summary>>,
}

impl Ticket {
    /// Block until the quotient lanes arrive.
    pub fn wait(self) -> Result<Vec<f32>, String> {
        let out = self
            .rx
            .recv()
            .map_err(|_| "worker dropped the response channel".to_string())?;
        let dt = self.submitted.elapsed().as_secs_f64();
        if let Ok(mut s) = self.latency_sink.lock() {
            s.push(dt);
        }
        out
    }

    /// Non-blocking poll.
    pub fn try_wait(&self) -> Option<Result<Vec<f32>, String>> {
        self.rx.try_recv().ok()
    }
}

struct Submission {
    item: BatchItem,
    responder: Sender<Result<Vec<f32>, String>>,
}

/// Counters shared across threads.
#[derive(Default)]
struct Metrics {
    requests: AtomicU64,
    lanes: AtomicU64,
    batches: AtomicU64,
    failures: AtomicU64,
    rejected: AtomicU64,
    queue_depth: AtomicUsize,
}

/// A point-in-time metrics snapshot.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub lanes: u64,
    pub batches: u64,
    pub failures: u64,
    pub rejected: u64,
    pub queue_depth: usize,
    /// End-to-end latency stats over completed `wait()`s (seconds).
    pub latency_p50: f64,
    pub latency_p99: f64,
    pub latency_mean: f64,
    pub latency_count: u64,
}

impl MetricsSnapshot {
    /// Mean lanes per backend batch (coalescing effectiveness).
    pub fn mean_batch_lanes(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.lanes as f64 / self.batches as f64
        }
    }
}

/// The running service.
pub struct DivisionService {
    tx: Option<SyncSender<Submission>>,
    next_id: AtomicU64,
    metrics: Arc<Metrics>,
    latency: Arc<Mutex<Summary>>,
    batcher: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

impl DivisionService {
    /// Start the batcher thread and `cfg.workers` worker threads.
    pub fn start(cfg: ServiceConfig, backend: BackendChoice) -> Result<Self> {
        assert!(cfg.workers > 0 && cfg.max_batch > 0);
        let (tx, rx) = mpsc::sync_channel::<Submission>(cfg.queue_capacity);
        let (work_tx, work_rx) = mpsc::channel::<(Batch, Vec<Sender<Result<Vec<f32>, String>>>)>();
        let work_rx = Arc::new(Mutex::new(work_rx));
        let metrics = Arc::new(Metrics::default());
        let latency = Arc::new(Mutex::new(Summary::keeping_samples()));

        // Batcher thread: coalesce submissions.
        let m = Arc::clone(&metrics);
        let max_wait = cfg.max_wait;
        let max_batch = cfg.max_batch;
        let batcher = std::thread::Builder::new()
            .name("tsdiv-batcher".into())
            .spawn(move || {
                let mut asm = BatchAssembler::new(max_batch);
                let mut responders: Vec<Sender<Result<Vec<f32>, String>>> = Vec::new();
                // Adaptive batching (§Perf): coalesce everything already
                // queued, but flush the moment the queue runs dry instead
                // of waiting out max_wait — a closed-loop client set would
                // otherwise stall the pipeline for max_wait per batch.
                // max_wait still bounds accumulation under steady trickle.
                let flush =
                    |asm: &mut BatchAssembler,
                     responders: &mut Vec<Sender<Result<Vec<f32>, String>>>| {
                        if let Some(batch) = asm.take() {
                            let rs = std::mem::take(responders);
                            m.batches.fetch_add(1, Ordering::Relaxed);
                            let _ = work_tx.send((batch, rs));
                        }
                    };
                'outer: loop {
                    // Block for the first submission of a batch window.
                    let sub = match rx.recv_timeout(Duration::from_millis(100)) {
                        Ok(s) => s,
                        Err(RecvTimeoutError::Timeout) => continue,
                        Err(RecvTimeoutError::Disconnected) => break,
                    };
                    m.queue_depth.fetch_sub(1, Ordering::Relaxed);
                    responders.push(sub.responder);
                    if let Some(batch) = asm.push(sub.item) {
                        let (done_rs, keep) =
                            split_responders(std::mem::take(&mut responders), batch.items.len());
                        responders = keep;
                        m.batches.fetch_add(1, Ordering::Relaxed);
                        let _ = work_tx.send((batch, done_rs));
                    }
                    // Drain whatever is queued right now, up to max_wait.
                    let deadline = Instant::now() + max_wait;
                    loop {
                        match rx.try_recv() {
                            Ok(sub) => {
                                m.queue_depth.fetch_sub(1, Ordering::Relaxed);
                                responders.push(sub.responder);
                                if let Some(batch) = asm.push(sub.item) {
                                    let (done_rs, keep) = split_responders(
                                        std::mem::take(&mut responders),
                                        batch.items.len(),
                                    );
                                    responders = keep;
                                    m.batches.fetch_add(1, Ordering::Relaxed);
                                    let _ = work_tx.send((batch, done_rs));
                                }
                                if Instant::now() >= deadline {
                                    flush(&mut asm, &mut responders);
                                    break;
                                }
                            }
                            Err(std::sync::mpsc::TryRecvError::Empty) => {
                                // Queue dry: ship what we have immediately.
                                flush(&mut asm, &mut responders);
                                break;
                            }
                            Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                                flush(&mut asm, &mut responders);
                                break 'outer;
                            }
                        }
                    }
                }
                // Shutdown: drain any pending work.
                flush(&mut asm, &mut responders);
            })?;

        // Worker pool.
        let mut workers = Vec::new();
        for wid in 0..cfg.workers {
            let work_rx = Arc::clone(&work_rx);
            let m = Arc::clone(&metrics);
            let choice = backend;
            workers.push(
                std::thread::Builder::new()
                    .name(format!("tsdiv-worker-{wid}"))
                    .spawn(move || {
                        let mut backend = match choice.build() {
                            Ok(b) => b,
                            Err(e) => {
                                crate::log_error!("worker {wid}: backend init failed: {e}");
                                return;
                            }
                        };
                        loop {
                            let job = {
                                let guard = work_rx.lock().unwrap();
                                guard.recv()
                            };
                            let (batch, responders) = match job {
                                Ok(j) => j,
                                Err(_) => break, // batcher gone
                            };
                            let (a, b) = batch.flatten();
                            let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
                                backend.divide_batch(&a, &b)
                            }));
                            match result {
                                Ok(Ok(flat)) => {
                                    for ((_, lanes), r) in
                                        batch.split(&flat).into_iter().zip(responders)
                                    {
                                        let _ = r.send(Ok(lanes));
                                    }
                                }
                                Ok(Err(e)) => {
                                    m.failures.fetch_add(1, Ordering::Relaxed);
                                    for r in responders {
                                        let _ = r.send(Err(format!("backend error: {e}")));
                                    }
                                }
                                Err(_) => {
                                    m.failures.fetch_add(1, Ordering::Relaxed);
                                    for r in responders {
                                        let _ =
                                            r.send(Err("backend panicked on batch".to_string()));
                                    }
                                }
                            }
                        }
                    })?,
            );
        }

        Ok(Self {
            tx: Some(tx),
            next_id: AtomicU64::new(0),
            metrics,
            latency,
            batcher: Some(batcher),
            workers,
        })
    }

    /// Submit a request (vector of divisions). Non-blocking; `Busy` under
    /// backpressure.
    pub fn submit(&self, a: Vec<f32>, b: Vec<f32>) -> Result<Ticket, SubmitError> {
        if a.len() != b.len() {
            return Err(SubmitError::BadRequest(format!(
                "operand length mismatch: {} vs {}",
                a.len(),
                b.len()
            )));
        }
        if a.is_empty() {
            return Err(SubmitError::BadRequest("empty request".into()));
        }
        let lanes = a.len() as u64;
        let (rtx, rrx) = mpsc::channel();
        let sub = Submission {
            item: BatchItem {
                request_id: self.next_id.fetch_add(1, Ordering::Relaxed),
                a,
                b,
            },
            responder: rtx,
        };
        let tx = self.tx.as_ref().ok_or(SubmitError::Closed)?;
        match tx.try_send(sub) {
            Ok(()) => {
                self.metrics.queue_depth.fetch_add(1, Ordering::Relaxed);
                self.metrics.requests.fetch_add(1, Ordering::Relaxed);
                self.metrics.lanes.fetch_add(lanes, Ordering::Relaxed);
                Ok(Ticket {
                    rx: rrx,
                    submitted: Instant::now(),
                    latency_sink: Arc::clone(&self.latency),
                })
            }
            Err(TrySendError::Full(_)) => {
                self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                Err(SubmitError::Busy)
            }
            Err(TrySendError::Disconnected(_)) => Err(SubmitError::Closed),
        }
    }

    /// Submit and wait.
    pub fn divide_blocking(&self, a: Vec<f32>, b: Vec<f32>) -> Result<Vec<f32>, String> {
        let t = self.submit(a, b).map_err(|e| e.to_string())?;
        t.wait()
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        let lat = self.latency.lock().unwrap();
        let count = lat.count();
        MetricsSnapshot {
            requests: self.metrics.requests.load(Ordering::Relaxed),
            lanes: self.metrics.lanes.load(Ordering::Relaxed),
            batches: self.metrics.batches.load(Ordering::Relaxed),
            failures: self.metrics.failures.load(Ordering::Relaxed),
            rejected: self.metrics.rejected.load(Ordering::Relaxed),
            queue_depth: self.metrics.queue_depth.load(Ordering::Relaxed),
            latency_p50: if count > 0 { lat.percentile(0.5) } else { 0.0 },
            latency_p99: if count > 0 { lat.percentile(0.99) } else { 0.0 },
            latency_mean: if count > 0 { lat.mean() } else { 0.0 },
            latency_count: count,
        }
    }

    /// Graceful shutdown: close the queue, join all threads.
    pub fn shutdown(mut self) {
        self.tx = None; // disconnect → batcher drains and exits
        if let Some(b) = self.batcher.take() {
            let _ = b.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for DivisionService {
    fn drop(&mut self) {
        self.tx = None;
        if let Some(b) = self.batcher.take() {
            let _ = b.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// First `n` responders for the flushed batch; the rest stay pending.
fn split_responders(
    mut rs: Vec<Sender<Result<Vec<f32>, String>>>,
    n: usize,
) -> (
    Vec<Sender<Result<Vec<f32>, String>>>,
    Vec<Sender<Result<Vec<f32>, String>>>,
) {
    let keep = rs.split_off(n.min(rs.len()));
    (rs, keep)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn svc(workers: usize, max_batch: usize, cap: usize) -> DivisionService {
        DivisionService::start(
            ServiceConfig {
                workers,
                max_batch,
                max_wait: Duration::from_millis(1),
                queue_capacity: cap,
            },
            BackendChoice::Native {
                order: 5,
                ilm_iterations: None,
            },
        )
        .unwrap()
    }

    #[test]
    fn bad_requests_rejected() {
        let s = svc(1, 64, 16);
        assert!(matches!(
            s.submit(vec![1.0], vec![1.0, 2.0]),
            Err(SubmitError::BadRequest(_))
        ));
        assert!(matches!(
            s.submit(vec![], vec![]),
            Err(SubmitError::BadRequest(_))
        ));
        s.shutdown();
    }

    #[test]
    fn latency_metrics_populate() {
        let s = svc(1, 64, 64);
        for _ in 0..5 {
            let t = s.submit(vec![9.0; 4], vec![3.0; 4]).unwrap();
            assert_eq!(t.wait().unwrap(), vec![3.0; 4]);
        }
        let m = s.metrics();
        assert_eq!(m.latency_count, 5);
        assert!(m.latency_p50 > 0.0);
        assert!(m.latency_p99 >= m.latency_p50);
        assert!(m.mean_batch_lanes() >= 4.0);
        s.shutdown();
    }

    #[test]
    fn backpressure_returns_busy() {
        // Tiny queue + many submissions without waiting → at least one Busy
        // (the batcher drains fast, so spam it).
        let s = svc(1, 1 << 20, 2);
        let mut busy = 0;
        let mut tickets = Vec::new();
        for _ in 0..2000 {
            match s.submit(vec![1.0; 64], vec![2.0; 64]) {
                Ok(t) => tickets.push(t),
                Err(SubmitError::Busy) => busy += 1,
                Err(e) => panic!("unexpected {e}"),
            }
        }
        // Drain what was accepted.
        for t in tickets {
            let _ = t.wait();
        }
        assert!(busy > 0, "expected backpressure");
        assert_eq!(s.metrics().rejected, busy);
        s.shutdown();
    }

    #[test]
    fn shutdown_after_inflight_work() {
        let s = svc(4, 128, 512);
        let tickets: Vec<_> = (0..64)
            .map(|i| s.submit(vec![i as f32; 16], vec![4.0; 16]).unwrap())
            .collect();
        for (i, t) in tickets.into_iter().enumerate() {
            assert_eq!(t.wait().unwrap()[0], i as f32 / 4.0);
        }
        s.shutdown();
    }

    #[test]
    fn drop_without_shutdown_joins_cleanly() {
        let s = svc(2, 64, 64);
        let t = s.submit(vec![8.0; 8], vec![2.0; 8]).unwrap();
        assert_eq!(t.wait().unwrap(), vec![4.0; 8]);
        drop(s); // must not hang or panic
    }
}
