//! The running division service: batcher thread + worker pool + metrics.

use std::collections::HashMap;
use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::batcher::{Batch, BatchAssembler, BatchItem};
use super::request::{BatchKey, DivRequest, DivResponse};
use super::worker::BackendChoice;
use crate::bail;
use crate::fp::{Format, Rounding};
use crate::util::error::Result;
use crate::util::stats::Summary;

/// Service configuration.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    /// Worker threads (each with its own backend instance).
    pub workers: usize,
    /// Coalescing budget per backend batch, in **f32-equivalent lanes**:
    /// the assembler meters cost units (`Format::lane_cost`, f64 ≈ 2×
    /// f16/bf16), so pure-f32 traffic batches exactly `max_batch` lanes
    /// while wider formats ship fewer lanes of equal backend work.
    pub max_batch: usize,
    /// Max time a request waits for co-batching before flush.
    pub max_wait: Duration,
    /// Bounded submission queue (backpressure beyond this depth).
    pub queue_capacity: usize,
    /// Spare-capacity budget divisor: while every worker is idle and the
    /// queue is shallow, the coalescing budget drops to
    /// `max_batch / spare_divisor` so bursts split across idle workers
    /// instead of serializing into one deep batch. `1` disables the
    /// shrink; `0` is rejected by [`ServiceConfig::validate`].
    pub spare_divisor: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            workers: 2,
            max_batch: 1024,
            max_wait: Duration::from_millis(1),
            queue_capacity: 4096,
            spare_divisor: 4,
        }
    }
}

impl ServiceConfig {
    /// Reject configurations that could only fail later, deep inside
    /// thread spawn or the assembler, with a useless panic.
    pub fn validate(&self) -> Result<()> {
        if self.workers == 0 {
            bail!("service config: workers must be > 0");
        }
        if self.max_batch == 0 {
            bail!("service config: max_batch must be > 0 lanes");
        }
        if self.queue_capacity == 0 {
            bail!("service config: queue_capacity must be > 0");
        }
        if self.spare_divisor == 0 {
            bail!(
                "service config: spare_divisor must be > 0 \
                 (1 disables the spare-capacity budget shrink)"
            );
        }
        Ok(())
    }
}

/// Submission failure modes.
#[derive(Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// Queue full — backpressure; retry later.
    Busy,
    /// Service is shutting down.
    Closed,
    /// Operand vectors disagree in length, are empty, or carry bits
    /// outside the format's storage width.
    BadRequest(String),
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Busy => write!(f, "queue full (backpressure)"),
            SubmitError::Closed => write!(f, "service closed"),
            SubmitError::BadRequest(m) => write!(f, "bad request: {m}"),
        }
    }
}
impl std::error::Error for SubmitError {}

/// Response handle for one submitted [`DivRequest`].
pub struct DivTicket {
    rx: Receiver<Result<Vec<u64>, String>>,
    fmt: Format,
    rm: Rounding,
    request_id: u64,
    submitted: Instant,
    latency_sink: Arc<Mutex<Summary>>,
}

impl DivTicket {
    /// The id the service assigned this request (response routing).
    pub fn request_id(&self) -> u64 {
        self.request_id
    }

    pub fn format(&self) -> Format {
        self.fmt
    }

    pub fn rounding(&self) -> Rounding {
        self.rm
    }

    /// Block until the quotient lanes arrive.
    pub fn wait(self) -> Result<DivResponse, String> {
        let bits = self
            .rx
            .recv()
            .map_err(|_| "worker dropped the response channel".to_string())??;
        let dt = self.submitted.elapsed().as_secs_f64();
        if let Ok(mut s) = self.latency_sink.lock() {
            s.push(dt);
        }
        Ok(DivResponse {
            fmt: self.fmt,
            rm: self.rm,
            bits,
        })
    }

    /// Non-blocking poll.
    pub fn try_wait(&self) -> Option<Result<DivResponse, String>> {
        match self.rx.try_recv() {
            Ok(Ok(bits)) => Some(Ok(DivResponse {
                fmt: self.fmt,
                rm: self.rm,
                bits,
            })),
            Ok(Err(e)) => Some(Err(e)),
            Err(_) => None,
        }
    }
}

/// Legacy f32 response handle (see [`DivisionService::submit`]).
pub struct Ticket(DivTicket);

impl Ticket {
    /// Block until the quotient lanes arrive.
    pub fn wait(self) -> Result<Vec<f32>, String> {
        let resp = self.0.wait()?;
        resp.to_f32()
            .ok_or_else(|| "response was not binary32".to_string())
    }

    /// Non-blocking poll.
    pub fn try_wait(&self) -> Option<Result<Vec<f32>, String>> {
        self.0.try_wait().map(|r| {
            r.and_then(|resp| {
                resp.to_f32()
                    .ok_or_else(|| "response was not binary32".to_string())
            })
        })
    }
}

struct Submission {
    key: BatchKey,
    item: BatchItem,
    responder: Sender<Result<Vec<u64>, String>>,
}

/// Counters shared across threads.
#[derive(Default)]
struct Metrics {
    requests: AtomicU64,
    lanes: AtomicU64,
    cost_units: AtomicU64,
    batches: AtomicU64,
    failures: AtomicU64,
    rejected: AtomicU64,
    queue_depth: AtomicUsize,
    idle_workers: AtomicUsize,
}

/// A point-in-time metrics snapshot.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub lanes: u64,
    /// Cost units dispatched to workers (Σ batch `lanes × lane_cost`):
    /// the format-weighted work gauge behind the cost-metered batcher.
    pub cost_units: u64,
    pub batches: u64,
    pub failures: u64,
    pub rejected: u64,
    pub queue_depth: usize,
    /// Workers currently waiting for a batch (adaptive-flush signal).
    pub workers_idle: usize,
    /// End-to-end latency stats over completed `wait()`s (seconds).
    pub latency_p50: f64,
    pub latency_p99: f64,
    pub latency_mean: f64,
    pub latency_count: u64,
}

impl MetricsSnapshot {
    /// Mean lanes per backend batch (coalescing effectiveness).
    pub fn mean_batch_lanes(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.lanes as f64 / self.batches as f64
        }
    }

    /// Mean cost units per backend batch — how close emitted batches run
    /// to the cost budget, independent of the format mix.
    pub fn mean_batch_cost(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.cost_units as f64 / self.batches as f64
        }
    }
}

/// The running service.
pub struct DivisionService {
    tx: Option<SyncSender<Submission>>,
    next_id: AtomicU64,
    metrics: Arc<Metrics>,
    latency: Arc<Mutex<Summary>>,
    batcher: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

/// One job for the worker pool: the batch plus one responder **slot per
/// item**, positionally aligned with `batch.items`. The alignment is
/// load-bearing: a missing responder must leave a `None` hole, never
/// shorten the list — a shorter list zipped against the items would
/// cross-wire every later item's reply onto the wrong waiter (and hang
/// the tail waiters forever in release builds).
type Responders = Vec<Option<Sender<Result<Vec<u64>, String>>>>;
type WorkItem = (Batch, Responders);

impl DivisionService {
    /// Start the batcher thread and `cfg.workers` worker threads.
    pub fn start(cfg: ServiceConfig, backend: BackendChoice) -> Result<Self> {
        cfg.validate()?;
        backend.validate()?;
        let (tx, rx) = mpsc::sync_channel::<Submission>(cfg.queue_capacity);
        let (work_tx, work_rx) = mpsc::channel::<WorkItem>();
        let work_rx = Arc::new(Mutex::new(work_rx));
        let metrics = Arc::new(Metrics::default());
        let latency = Arc::new(Mutex::new(Summary::keeping_samples()));

        // Batcher thread: coalesce submissions into per-(Format,Rounding)
        // batches, with an adaptive flush policy (§Perf):
        //
        // * a bucket reaching the lane budget ships immediately;
        // * every bucket carries its own clock: once its **oldest** lane
        //   has waited `max_wait`, that bucket ships alone (per-key
        //   max_wait) — a rare-(Format,Rounding) lane no longer rides a
        //   window kept open by busier keys, and fresh buckets keep
        //   coalescing instead of being force-flushed alongside it;
        // * when the queue runs dry, pending work ships only if a worker
        //   is idle to take it (otherwise flushing buys no latency — the
        //   buckets stay open, each bounded by its own max_wait, so
        //   deeper batches form while every worker is busy);
        // * the lane budget itself adapts to load: spare capacity (all
        //   workers idle, shallow queue) quarters the budget so bursts
        //   split across idle workers instead of serializing into one.
        let m = Arc::clone(&metrics);
        let max_wait = cfg.max_wait;
        let max_batch = cfg.max_batch;
        let spare_divisor = cfg.spare_divisor;
        let worker_count = cfg.workers;
        let batcher = std::thread::Builder::new()
            .name("tsdiv-batcher".into())
            .spawn(move || {
                let mut asm = BatchAssembler::new(max_batch);
                let mut responders: HashMap<u64, Sender<Result<Vec<u64>, String>>> =
                    HashMap::new();
                let dispatch = |batch: Batch,
                                responders: &mut HashMap<u64, Sender<Result<Vec<u64>, String>>>| {
                    // One positional slot per item (see [`Responders`]).
                    // A lost responder — a routing bug, not a load
                    // condition — is counted as a failure and logged; its
                    // waiter's channel sender is gone, so that `wait()`
                    // returns an explicit channel-closed error instead of
                    // hanging, and every other item still routes to the
                    // waiter that submitted it.
                    let rs: Responders = batch
                        .items
                        .iter()
                        .map(|it| responders.remove(&it.request_id))
                        .collect();
                    let lost = rs.iter().filter(|r| r.is_none()).count();
                    if lost > 0 {
                        // One count per affected batch, matching the
                        // backend-error/panic paths' unit (the log line
                        // carries the per-item count).
                        m.failures.fetch_add(1, Ordering::Relaxed);
                        crate::log_error!(
                            "batcher: {lost} responder(s) missing for a batch of {} item(s); \
                             affected waiters receive a closed-channel error",
                            batch.items.len()
                        );
                    }
                    m.batches.fetch_add(1, Ordering::Relaxed);
                    m.cost_units.fetch_add(batch.cost as u64, Ordering::Relaxed);
                    let _ = work_tx.send((batch, rs));
                };
                let flush = |asm: &mut BatchAssembler,
                             responders: &mut HashMap<u64, Sender<Result<Vec<u64>, String>>>| {
                    for batch in asm.take_all() {
                        dispatch(batch, responders);
                    }
                };
                // Retune the cost budget from load: spare capacity (all
                // workers idle, shallow queue) divides the budget by the
                // configured `spare_divisor` so bursts split across idle
                // workers; saturation restores the full budget. Called
                // at window start AND on every drain pass — sustained
                // load must not pin a budget picked during an idle
                // burst-start. The budget stays denominated in
                // f32-equivalent lanes; the assembler meters it in cost
                // units per format.
                let retune = |asm: &mut BatchAssembler| {
                    let spare_capacity = m.idle_workers.load(Ordering::Relaxed) >= worker_count
                        && m.queue_depth.load(Ordering::Relaxed) <= worker_count;
                    asm.set_max_lanes(if spare_capacity {
                        (max_batch / spare_divisor).max(1)
                    } else {
                        max_batch
                    });
                };
                'outer: loop {
                    // Block for the first submission of a batch window.
                    let sub = match rx.recv_timeout(Duration::from_millis(100)) {
                        Ok(s) => s,
                        Err(RecvTimeoutError::Timeout) => continue,
                        Err(RecvTimeoutError::Disconnected) => break,
                    };
                    retune(&mut asm);
                    m.queue_depth.fetch_sub(1, Ordering::Relaxed);
                    responders.insert(sub.item.request_id, sub.responder);
                    if let Some(batch) = asm.push(sub.key, sub.item) {
                        dispatch(batch, &mut responders);
                    }
                    // Drain the queue while work is pending. Each
                    // bucket's own clock (started at its first lane)
                    // bounds its latency: take_expired ships exactly
                    // the buckets whose oldest lane waited max_wait.
                    loop {
                        match rx.try_recv() {
                            Ok(sub) => {
                                m.queue_depth.fetch_sub(1, Ordering::Relaxed);
                                responders.insert(sub.item.request_id, sub.responder);
                                if let Some(batch) = asm.push(sub.key, sub.item) {
                                    dispatch(batch, &mut responders);
                                }
                            }
                            Err(std::sync::mpsc::TryRecvError::Empty) => {
                                if asm.pending_lanes() == 0 {
                                    break;
                                }
                                // Queue dry. Ship everything if a worker
                                // can start on it right now; otherwise
                                // hold the buckets open so more lanes
                                // coalesce while all workers are busy —
                                // per-key expiry below still bounds
                                // every bucket's wait.
                                if m.idle_workers.load(Ordering::Relaxed) > 0 {
                                    flush(&mut asm, &mut responders);
                                    break;
                                }
                                std::thread::sleep(Duration::from_micros(10));
                            }
                            Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                                flush(&mut asm, &mut responders);
                                break 'outer;
                            }
                        }
                        retune(&mut asm);
                        for batch in asm.take_expired(max_wait) {
                            dispatch(batch, &mut responders);
                        }
                    }
                }
                // Shutdown: drain any pending work.
                flush(&mut asm, &mut responders);
            })?;

        // Worker pool.
        let mut workers = Vec::new();
        for wid in 0..cfg.workers {
            let work_rx = Arc::clone(&work_rx);
            let m = Arc::clone(&metrics);
            let choice = backend;
            workers.push(
                std::thread::Builder::new()
                    .name(format!("tsdiv-worker-{wid}"))
                    .spawn(move || {
                        let mut backend = match choice.build() {
                            Ok(b) => b,
                            Err(e) => {
                                crate::log_error!("worker {wid}: backend init failed: {e}");
                                return;
                            }
                        };
                        loop {
                            // Waiting for the job queue (including the
                            // receiver lock) counts as idle: the batcher
                            // flushes eagerly while anyone is ready.
                            m.idle_workers.fetch_add(1, Ordering::Relaxed);
                            let job = {
                                let guard = work_rx.lock().unwrap();
                                guard.recv()
                            };
                            m.idle_workers.fetch_sub(1, Ordering::Relaxed);
                            let (batch, responders) = match job {
                                Ok(j) => j,
                                Err(_) => break, // batcher gone
                            };
                            let (a, b) = batch.flatten();
                            let key = batch.key;
                            let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
                                backend.divide(&a, &b, key.fmt, key.rm)
                            }));
                            match result {
                                Ok(Ok(flat)) => {
                                    // Positional zip: responders is one
                                    // slot per item by construction, so
                                    // lanes can never shift onto another
                                    // item's waiter.
                                    for ((_, lanes), r) in
                                        batch.split(&flat).into_iter().zip(responders)
                                    {
                                        if let Some(r) = r {
                                            let _ = r.send(Ok(lanes));
                                        }
                                    }
                                }
                                Ok(Err(e)) => {
                                    m.failures.fetch_add(1, Ordering::Relaxed);
                                    for r in responders.into_iter().flatten() {
                                        let _ = r.send(Err(format!("backend error: {e}")));
                                    }
                                }
                                Err(_) => {
                                    m.failures.fetch_add(1, Ordering::Relaxed);
                                    for r in responders.into_iter().flatten() {
                                        let _ =
                                            r.send(Err("backend panicked on batch".to_string()));
                                    }
                                }
                            }
                        }
                    })?,
            );
        }

        Ok(Self {
            tx: Some(tx),
            next_id: AtomicU64::new(0),
            metrics,
            latency,
            batcher: Some(batcher),
            workers,
        })
    }

    /// Submit a typed request. Non-blocking; `Busy` under backpressure.
    /// Requests of any `(Format, Rounding)` mix coalesce into
    /// homogeneous backend batches keyed by that pair.
    pub fn submit_request(&self, req: DivRequest) -> Result<DivTicket, SubmitError> {
        if let Err(defect) = req.validate() {
            return Err(SubmitError::BadRequest(defect));
        }
        let lanes = req.lanes() as u64;
        let (fmt, rm) = (req.fmt, req.rm);
        let request_id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (rtx, rrx) = mpsc::channel();
        let sub = Submission {
            key: req.key(),
            item: BatchItem {
                request_id,
                a: req.a,
                b: req.b,
            },
            responder: rtx,
        };
        let tx = self.tx.as_ref().ok_or(SubmitError::Closed)?;
        // Count the submission BEFORE it becomes visible to the batcher:
        // incrementing after a successful try_send races the batcher's
        // decrement and can wrap the gauge below zero (the adaptive
        // flush policy reads it). Over-counting an in-flight rejected
        // submission for a moment is harmless; undo on failure.
        self.metrics.queue_depth.fetch_add(1, Ordering::Relaxed);
        match tx.try_send(sub) {
            Ok(()) => {
                self.metrics.requests.fetch_add(1, Ordering::Relaxed);
                self.metrics.lanes.fetch_add(lanes, Ordering::Relaxed);
                Ok(DivTicket {
                    rx: rrx,
                    fmt,
                    rm,
                    request_id,
                    submitted: Instant::now(),
                    latency_sink: Arc::clone(&self.latency),
                })
            }
            Err(TrySendError::Full(_)) => {
                self.metrics.queue_depth.fetch_sub(1, Ordering::Relaxed);
                self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                Err(SubmitError::Busy)
            }
            Err(TrySendError::Disconnected(_)) => {
                self.metrics.queue_depth.fetch_sub(1, Ordering::Relaxed);
                Err(SubmitError::Closed)
            }
        }
    }

    /// Submit a typed request and wait for its response.
    pub fn divide_request_blocking(&self, req: DivRequest) -> Result<DivResponse, String> {
        let t = self.submit_request(req).map_err(|e| e.to_string())?;
        t.wait()
    }

    /// Submit an f32 request at round-to-nearest-even.
    #[deprecated(note = "use submit_request(DivRequest::from_f32(..))")]
    pub fn submit(&self, a: Vec<f32>, b: Vec<f32>) -> Result<Ticket, SubmitError> {
        Ok(Ticket(self.submit_request(DivRequest::from_f32(&a, &b))?))
    }

    /// Submit f32 lanes and wait.
    #[deprecated(note = "use divide_request_blocking(DivRequest::from_f32(..))")]
    pub fn divide_blocking(&self, a: Vec<f32>, b: Vec<f32>) -> Result<Vec<f32>, String> {
        self.divide_request_blocking(DivRequest::from_f32(&a, &b))?
            .to_f32()
            .ok_or_else(|| "response was not binary32".to_string())
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        let lat = self.latency.lock().unwrap();
        let count = lat.count();
        MetricsSnapshot {
            requests: self.metrics.requests.load(Ordering::Relaxed),
            lanes: self.metrics.lanes.load(Ordering::Relaxed),
            cost_units: self.metrics.cost_units.load(Ordering::Relaxed),
            batches: self.metrics.batches.load(Ordering::Relaxed),
            failures: self.metrics.failures.load(Ordering::Relaxed),
            rejected: self.metrics.rejected.load(Ordering::Relaxed),
            queue_depth: self.metrics.queue_depth.load(Ordering::Relaxed),
            workers_idle: self.metrics.idle_workers.load(Ordering::Relaxed),
            latency_p50: if count > 0 { lat.percentile(0.5) } else { 0.0 },
            latency_p99: if count > 0 { lat.percentile(0.99) } else { 0.0 },
            latency_mean: if count > 0 { lat.mean() } else { 0.0 },
            latency_count: count,
        }
    }

    /// Graceful shutdown: close the queue, join all threads.
    pub fn shutdown(mut self) {
        self.tx = None; // disconnect → batcher drains and exits
        if let Some(b) = self.batcher.take() {
            let _ = b.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for DivisionService {
    fn drop(&mut self) {
        self.tx = None;
        if let Some(b) = self.batcher.take() {
            let _ = b.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp::{F16, F32, F64};

    fn svc(workers: usize, max_batch: usize, cap: usize) -> DivisionService {
        DivisionService::start(
            ServiceConfig {
                workers,
                max_batch,
                max_wait: Duration::from_millis(1),
                queue_capacity: cap,
                ..ServiceConfig::default()
            },
            BackendChoice::Native {
                order: 5,
                ilm_iterations: None,
            },
        )
        .unwrap()
    }

    fn f32_req(a: &[f32], b: &[f32]) -> DivRequest {
        DivRequest::from_f32(a, b)
    }

    #[test]
    fn zero_sized_configs_rejected_up_front() {
        for cfg in [
            ServiceConfig {
                workers: 0,
                ..Default::default()
            },
            ServiceConfig {
                max_batch: 0,
                ..Default::default()
            },
            ServiceConfig {
                queue_capacity: 0,
                ..Default::default()
            },
            ServiceConfig {
                spare_divisor: 0,
                ..Default::default()
            },
        ] {
            let r = DivisionService::start(
                cfg.clone(),
                BackendChoice::Native {
                    order: 5,
                    ilm_iterations: None,
                },
            );
            let e = match r {
                Err(e) => e,
                Ok(_) => panic!("config {cfg:?} must be rejected"),
            };
            assert!(e.to_string().contains("service config"), "{e}");
        }
    }

    #[test]
    fn kernel_backend_serves_and_bad_kernel_config_rejected_up_front() {
        use crate::kernel::KernelConfig;
        let s = DivisionService::start(
            ServiceConfig::default(),
            BackendChoice::Kernel {
                order: 5,
                kernel: KernelConfig::default(),
            },
        )
        .unwrap();
        let resp = s
            .divide_request_blocking(DivRequest::from_f32(&[9.0, 6.0, 1.0], &[3.0, 2.0, 4.0]))
            .unwrap();
        assert_eq!(resp.to_f32().unwrap(), vec![3.0, 3.0, 0.25]);
        s.shutdown();
        let r = DivisionService::start(
            ServiceConfig::default(),
            BackendChoice::Kernel {
                order: 5,
                kernel: KernelConfig {
                    tile: 0,
                    ilm_iterations: None,
                    ..KernelConfig::default()
                },
            },
        );
        let e = match r {
            Err(e) => e,
            Ok(_) => panic!("zero-tile kernel config must be rejected"),
        };
        assert!(e.to_string().contains("kernel config"), "{e}");
    }

    #[test]
    fn bad_requests_rejected() {
        let s = svc(1, 64, 16);
        assert!(matches!(
            s.submit_request(f32_req(&[1.0], &[1.0, 2.0])),
            Err(SubmitError::BadRequest(_))
        ));
        assert!(matches!(
            s.submit_request(f32_req(&[], &[])),
            Err(SubmitError::BadRequest(_))
        ));
        // Bits beyond f16's storage width.
        assert!(matches!(
            s.submit_request(DivRequest::new(
                F16,
                Rounding::NearestEven,
                vec![0x3C00],
                vec![0x12_3456],
            )),
            Err(SubmitError::BadRequest(_))
        ));
        s.shutdown();
    }

    #[test]
    fn typed_roundtrip_f64_and_f16() {
        let s = svc(1, 64, 64);
        let resp = s
            .divide_request_blocking(DivRequest::from_f64(&[10.0, -3.0], &[4.0, 2.0]))
            .unwrap();
        assert_eq!(resp.fmt, F64);
        assert_eq!(resp.to_f64().unwrap(), vec![2.5, -1.5]);
        // f16: 6.0/2.0 = 3.0 (0x4600 / 0x4000 = 0x4200).
        let resp = s
            .divide_request_blocking(DivRequest::from_f16_bits(&[0x4600], &[0x4000]))
            .unwrap();
        assert_eq!(resp.to_u16_bits().unwrap(), vec![0x4200]);
        s.shutdown();
    }

    #[test]
    fn ticket_reports_request_metadata() {
        let s = svc(1, 64, 64);
        let t1 = s.submit_request(f32_req(&[1.0], &[2.0])).unwrap();
        let t2 = s
            .submit_request(DivRequest::from_f64(&[1.0], &[2.0]).with_rounding(Rounding::TowardZero))
            .unwrap();
        assert!(t2.request_id() > t1.request_id());
        assert_eq!(t1.format(), F32);
        assert_eq!(t2.format(), F64);
        assert_eq!(t2.rounding(), Rounding::TowardZero);
        let r1 = t1.wait().unwrap();
        let r2 = t2.wait().unwrap();
        assert_eq!(r1.to_f32().unwrap(), vec![0.5]);
        assert_eq!(r2.to_f64().unwrap(), vec![0.5]);
        s.shutdown();
    }

    #[test]
    fn latency_metrics_populate() {
        let s = svc(1, 64, 64);
        for _ in 0..5 {
            let t = s.submit_request(f32_req(&[9.0; 4], &[3.0; 4])).unwrap();
            assert_eq!(t.wait().unwrap().to_f32().unwrap(), vec![3.0; 4]);
        }
        let m = s.metrics();
        assert_eq!(m.latency_count, 5);
        assert!(m.latency_p50 > 0.0);
        assert!(m.latency_p99 >= m.latency_p50);
        assert!(m.mean_batch_lanes() >= 4.0);
        s.shutdown();
    }

    #[test]
    fn backpressure_returns_busy() {
        // Tiny queue + many submissions without waiting → at least one Busy
        // (the batcher drains fast, so spam it).
        let s = svc(1, 1 << 20, 2);
        let mut busy = 0;
        let mut tickets = Vec::new();
        for _ in 0..2000 {
            match s.submit_request(f32_req(&[1.0; 64], &[2.0; 64])) {
                Ok(t) => tickets.push(t),
                Err(SubmitError::Busy) => busy += 1,
                Err(e) => panic!("unexpected {e}"),
            }
        }
        // Drain what was accepted.
        for t in tickets {
            let _ = t.wait();
        }
        assert!(busy > 0, "expected backpressure");
        assert_eq!(s.metrics().rejected, busy);
        s.shutdown();
    }

    #[test]
    fn shutdown_after_inflight_work() {
        let s = svc(4, 128, 512);
        let tickets: Vec<_> = (0..64)
            .map(|i| s.submit_request(f32_req(&[i as f32; 16], &[4.0; 16])).unwrap())
            .collect();
        for (i, t) in tickets.into_iter().enumerate() {
            assert_eq!(t.wait().unwrap().to_f32().unwrap()[0], i as f32 / 4.0);
        }
        s.shutdown();
    }

    #[test]
    fn drop_without_shutdown_joins_cleanly() {
        let s = svc(2, 64, 64);
        let t = s.submit_request(f32_req(&[8.0; 8], &[2.0; 8])).unwrap();
        assert_eq!(t.wait().unwrap().to_f32().unwrap(), vec![4.0; 8]);
        drop(s); // must not hang or panic
    }

    #[test]
    #[allow(deprecated)]
    fn legacy_f32_wrappers_still_work() {
        let s = svc(1, 64, 64);
        let t = s.submit(vec![9.0; 4], vec![3.0; 4]).unwrap();
        assert_eq!(t.wait().unwrap(), vec![3.0; 4]);
        assert_eq!(
            s.divide_blocking(vec![8.0], vec![2.0]).unwrap(),
            vec![4.0]
        );
        assert!(matches!(
            s.submit(vec![1.0], vec![]),
            Err(SubmitError::BadRequest(_))
        ));
        s.shutdown();
    }

    #[test]
    fn cost_units_metric_weighs_formats() {
        // Equal lane counts per format; the dispatched cost gauge must
        // weigh them by lane_cost (f64 2× f16), not count raw lanes.
        let s = svc(1, 64, 64);
        let lanes_per_fmt = 8u64;
        let resp = s
            .divide_request_blocking(DivRequest::from_f16_bits(&[0x4600; 8], &[0x4000; 8]))
            .unwrap();
        assert_eq!(resp.lanes(), 8);
        s.divide_request_blocking(DivRequest::from_f32(&[6.0; 8], &[2.0; 8]))
            .unwrap();
        s.divide_request_blocking(DivRequest::from_f64(&[6.0; 8], &[2.0; 8]))
            .unwrap();
        let m = s.metrics();
        assert_eq!(m.lanes, 3 * lanes_per_fmt);
        let want = lanes_per_fmt * (F16.lane_cost() + F32.lane_cost() + F64.lane_cost()) as u64;
        assert_eq!(m.cost_units, want, "cost gauge must sum per-format lane_cost");
        assert!(m.mean_batch_cost() > 0.0);
        s.shutdown();
    }

    #[test]
    fn spare_divisor_one_disables_budget_shrink_and_serves() {
        // spare_divisor = 1 keeps the full budget under idle workers;
        // the service must validate and serve normally.
        let s = DivisionService::start(
            ServiceConfig {
                workers: 1,
                max_batch: 64,
                max_wait: Duration::from_millis(1),
                queue_capacity: 64,
                spare_divisor: 1,
            },
            BackendChoice::Native {
                order: 5,
                ilm_iterations: None,
            },
        )
        .unwrap();
        let out = s
            .divide_request_blocking(f32_req(&[9.0, 6.0], &[3.0, 2.0]))
            .unwrap();
        assert_eq!(out.to_f32().unwrap(), vec![3.0, 3.0]);
        s.shutdown();
    }

    #[test]
    fn mixed_format_requests_coalesce_homogeneously() {
        // One service, interleaved f32/f64 submissions: responses must
        // come back typed and correct even when batches interleave.
        let s = svc(2, 256, 256);
        let mut tickets = Vec::new();
        for i in 1..=24u32 {
            if i % 2 == 0 {
                tickets.push((i, s.submit_request(f32_req(&[i as f32], &[2.0])).unwrap()));
            } else {
                tickets.push((
                    i,
                    s.submit_request(DivRequest::from_f64(&[i as f64], &[2.0])).unwrap(),
                ));
            }
        }
        for (i, t) in tickets {
            let resp = t.wait().unwrap();
            if i % 2 == 0 {
                assert_eq!(resp.to_f32().unwrap(), vec![i as f32 / 2.0]);
            } else {
                assert_eq!(resp.to_f64().unwrap(), vec![i as f64 / 2.0]);
            }
        }
        assert_eq!(s.metrics().failures, 0);
        s.shutdown();
    }
}
