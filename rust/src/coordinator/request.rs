//! Typed requests and responses for the division service.
//!
//! The datapath is format-parametric by construction (every bit pattern
//! travels in the low bits of a `u64`, see [`crate::fp::format`]), so the
//! service speaks the same language: a [`DivRequest`] carries raw
//! bit-pattern lanes plus the [`Op`] to apply, the [`Format`] that
//! interprets the lanes and the [`Rounding`] attribute. Convenience
//! constructors cover the four interchange formats and the four ops;
//! [`DivResponse`] converts back.
//!
//! Operand shape is per-op: `Div` carries matched `a`/`b` lanes; the
//! unary ops (`Recip`, `Rsqrt`) carry only `a` — no dummy divisor
//! vector travels with them; `ScaleByRecip` carries `a` as `b.len()`
//! concatenated rows with `b[r]` the divisor of row `r`. Rows are
//! equal-length by default (`a.len() % b.len() == 0`, constructor
//! [`DivRequest::scale_by_recip`]) or explicitly ragged — one length
//! per row via [`DivRequest::scale_by_recip_ragged`], which both
//! batched kernels honor natively.

pub use crate::fp::Op;
use crate::fp::{Format, Rounding, BF16, F16, F32, F64};

/// The batching key: requests coalesce only with requests of the same
/// operation, format and rounding mode, so every backend batch is
/// homogeneous.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BatchKey {
    pub op: Op,
    pub fmt: Format,
    pub rm: Rounding,
}

impl BatchKey {
    /// Division key — the overwhelmingly common case keeps the short
    /// constructor; other ops use [`BatchKey::for_op`].
    pub fn new(fmt: Format, rm: Rounding) -> Self {
        Self::for_op(Op::Div, fmt, rm)
    }

    pub fn for_op(op: Op, fmt: Format, rm: Rounding) -> Self {
        Self { op, fmt, rm }
    }

    /// Cost units one lane of this key charges against the assembler's
    /// coalescing budget, per op around the format baseline
    /// ([`Format::lane_cost`]; rounding mode does not change the
    /// per-lane work): `Recip` skips the final multiply and
    /// `ScaleByRecip` amortizes the reciprocal across a row (one
    /// cheaper), `Rsqrt` appends the Newton tail (one dearer).
    pub const fn lane_cost(&self) -> usize {
        let c = self.fmt.lane_cost();
        match self.op {
            Op::Div => c,
            Op::Recip | Op::ScaleByRecip => {
                if c > 1 {
                    c - 1
                } else {
                    1
                }
            }
            Op::Rsqrt => c + 1,
        }
    }
}

impl std::fmt::Display for BatchKey {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        // Div keys keep their historical "f32/nearest" spelling (logs,
        // bench keys); other ops prefix their name.
        if self.op == Op::Div {
            write!(f, "{}/{}", self.fmt.name(), self.rm.name())
        } else {
            write!(f, "{}:{}/{}", self.op.name(), self.fmt.name(), self.rm.name())
        }
    }
}

/// One service request: an [`Op`] over `fmt` bit patterns under
/// rounding mode `rm`. Historically division-only (hence the name);
/// operand shape is per-op — see the module docs.
#[derive(Clone, Debug)]
pub struct DivRequest {
    pub op: Op,
    pub fmt: Format,
    pub rm: Rounding,
    /// Input bit patterns (low `fmt.width()` bits of each `u64`):
    /// dividends for `Div`, the operand for `Recip`/`Rsqrt`,
    /// concatenated equal-length rows for `ScaleByRecip`.
    pub a: Vec<u64>,
    /// Divisor bit patterns: same length as `a` for `Div`, one per row
    /// for `ScaleByRecip`, **empty** for the unary ops.
    pub b: Vec<u64>,
    /// Per-row lane counts for ragged `ScaleByRecip` requests: one
    /// entry per divisor row, summing to `a.len()`. **Empty** means
    /// equal-length rows derived as `a.len() / b.len()` (and empty is
    /// the only valid state for every other op).
    pub rows: Vec<u32>,
}

impl DivRequest {
    /// Raw division constructor over bit patterns of an arbitrary
    /// format.
    pub fn new(fmt: Format, rm: Rounding, a: Vec<u64>, b: Vec<u64>) -> Self {
        Self {
            op: Op::Div,
            fmt,
            rm,
            a,
            b,
            rows: Vec::new(),
        }
    }

    /// Reciprocal request: `out[i] = 1/x[i]`. No divisor vector.
    pub fn recip(fmt: Format, rm: Rounding, x: Vec<u64>) -> Self {
        Self {
            op: Op::Recip,
            fmt,
            rm,
            a: x,
            b: Vec::new(),
            rows: Vec::new(),
        }
    }

    /// Reciprocal square root request: `out[i] = 1/sqrt(x[i])`.
    pub fn rsqrt(fmt: Format, rm: Rounding, x: Vec<u64>) -> Self {
        Self {
            op: Op::Rsqrt,
            fmt,
            rm,
            a: x,
            b: Vec::new(),
            rows: Vec::new(),
        }
    }

    /// Fused scale-by-reciprocal: `lanes` is `divisors.len()`
    /// equal-length concatenated rows; every lane of row `r` is divided
    /// by `divisors[r]` (one reciprocal per row on the batched
    /// datapaths).
    pub fn scale_by_recip(fmt: Format, rm: Rounding, lanes: Vec<u64>, divisors: Vec<u64>) -> Self {
        Self {
            op: Op::ScaleByRecip,
            fmt,
            rm,
            a: lanes,
            b: divisors,
            rows: Vec::new(),
        }
    }

    /// Ragged scale-by-reciprocal: `rows[r]` lanes of `lanes` belong to
    /// divisor `divisors[r]`, in order — row lengths need not match
    /// (the QR/Givens pattern where trailing columns shrink). Validation
    /// requires one positive length per divisor, summing to
    /// `lanes.len()`; both batched kernels consume the per-row lengths
    /// natively, so ragged requests cost nothing over uniform ones.
    pub fn scale_by_recip_ragged(
        fmt: Format,
        rm: Rounding,
        lanes: Vec<u64>,
        divisors: Vec<u64>,
        rows: Vec<u32>,
    ) -> Self {
        Self {
            op: Op::ScaleByRecip,
            fmt,
            rm,
            a: lanes,
            b: divisors,
            rows,
        }
    }

    /// binary32 lanes at round-to-nearest-even.
    pub fn from_f32(a: &[f32], b: &[f32]) -> Self {
        Self::new(
            F32,
            Rounding::NearestEven,
            a.iter().map(|&x| x.to_bits() as u64).collect(),
            b.iter().map(|&x| x.to_bits() as u64).collect(),
        )
    }

    /// binary64 lanes at round-to-nearest-even.
    pub fn from_f64(a: &[f64], b: &[f64]) -> Self {
        Self::new(
            F64,
            Rounding::NearestEven,
            a.iter().map(|&x| x.to_bits()).collect(),
            b.iter().map(|&x| x.to_bits()).collect(),
        )
    }

    /// binary16 lanes given as raw `u16` bit patterns.
    pub fn from_f16_bits(a: &[u16], b: &[u16]) -> Self {
        Self::new(
            F16,
            Rounding::NearestEven,
            a.iter().map(|&x| x as u64).collect(),
            b.iter().map(|&x| x as u64).collect(),
        )
    }

    /// bfloat16 lanes given as raw `u16` bit patterns.
    pub fn from_bf16_bits(a: &[u16], b: &[u16]) -> Self {
        Self::new(
            BF16,
            Rounding::NearestEven,
            a.iter().map(|&x| x as u64).collect(),
            b.iter().map(|&x| x as u64).collect(),
        )
    }

    /// Override the rounding mode (builder style).
    pub fn with_rounding(mut self, rm: Rounding) -> Self {
        self.rm = rm;
        self
    }

    /// Output lanes this request produces (always `a.len()` — every op
    /// maps input lanes one-to-one to quotient lanes).
    pub fn lanes(&self) -> usize {
        self.a.len()
    }

    pub fn key(&self) -> BatchKey {
        BatchKey::for_op(self.op, self.fmt, self.rm)
    }

    /// Structural validation: non-empty lanes in the op's shape, bit
    /// patterns inside the format's storage width. Returns a
    /// human-readable defect.
    pub fn validate(&self) -> Result<(), String> {
        match self.op {
            Op::Div => {
                if self.a.len() != self.b.len() {
                    return Err(format!(
                        "operand length mismatch: {} vs {}",
                        self.a.len(),
                        self.b.len()
                    ));
                }
            }
            Op::Recip | Op::Rsqrt => {
                if !self.b.is_empty() {
                    return Err(format!(
                        "{} is unary: divisor vector must be empty, got {} lanes",
                        self.op.name(),
                        self.b.len()
                    ));
                }
            }
            Op::ScaleByRecip => {
                if self.b.is_empty() {
                    return Err("scale-recip needs at least one divisor row".into());
                }
                if self.rows.is_empty() {
                    // Uniform shape: lanes split evenly across rows.
                    if self.a.len() % self.b.len() != 0 {
                        return Err(format!(
                            "scale-recip rows must be equal length: {} lanes over {} rows \
                             (use scale_by_recip_ragged for per-row lengths)",
                            self.a.len(),
                            self.b.len()
                        ));
                    }
                } else {
                    // Ragged shape: one positive length per divisor,
                    // covering the lane vector exactly.
                    if self.rows.len() != self.b.len() {
                        return Err(format!(
                            "scale-recip row-length vector must match divisors: \
                             {} lengths for {} rows",
                            self.rows.len(),
                            self.b.len()
                        ));
                    }
                    if let Some(r) = self.rows.iter().position(|&n| n == 0) {
                        return Err(format!("scale-recip row {r} is empty"));
                    }
                    let total: usize = self.rows.iter().map(|&n| n as usize).sum();
                    if total != self.a.len() {
                        return Err(format!(
                            "scale-recip row lengths sum to {total}, but {} lanes were given",
                            self.a.len()
                        ));
                    }
                }
            }
        }
        if self.op != Op::ScaleByRecip && !self.rows.is_empty() {
            return Err(format!(
                "{} carries no row-length vector (rows is scale-recip only)",
                self.op.name()
            ));
        }
        if self.a.is_empty() {
            return Err("empty request".into());
        }
        let mask = self.fmt.width_mask();
        if mask != u64::MAX {
            let stray = |bits: &[u64]| bits.iter().any(|&x| x & !mask != 0);
            if stray(&self.a) || stray(&self.b) {
                return Err(format!(
                    "operand bits exceed {} storage width",
                    self.fmt.name()
                ));
            }
        }
        Ok(())
    }
}

/// Quotient lanes for one request, in the request's format.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct DivResponse {
    pub fmt: Format,
    pub rm: Rounding,
    /// Quotient bit patterns, one per request lane, in lane order.
    pub bits: Vec<u64>,
}

impl DivResponse {
    pub fn lanes(&self) -> usize {
        self.bits.len()
    }

    /// Decode as f32 values (`None` unless the request was binary32).
    pub fn to_f32(&self) -> Option<Vec<f32>> {
        (self.fmt == F32).then(|| self.bits.iter().map(|&q| f32::from_bits(q as u32)).collect())
    }

    /// Decode as f64 values (`None` unless the request was binary64).
    pub fn to_f64(&self) -> Option<Vec<f64>> {
        (self.fmt == F64).then(|| self.bits.iter().map(f64::from_bits).collect())
    }

    /// Raw 16-bit patterns (`None` unless the request was f16/bf16).
    pub fn to_u16_bits(&self) -> Option<Vec<u16>> {
        (self.fmt == F16 || self.fmt == BF16)
            .then(|| self.bits.iter().map(|&q| q as u16).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip_and_key() {
        let r = DivRequest::from_f32(&[6.0, -1.5], &[2.0, 3.0]);
        assert_eq!(r.fmt, F32);
        assert_eq!(r.rm, Rounding::NearestEven);
        assert_eq!(r.lanes(), 2);
        assert_eq!(r.key(), BatchKey::new(F32, Rounding::NearestEven));
        assert!(r.validate().is_ok());
        let resp = DivResponse {
            fmt: F32,
            rm: r.rm,
            bits: r.a.clone(),
        };
        assert_eq!(resp.to_f32().unwrap(), vec![6.0, -1.5]);
        assert!(resp.to_f64().is_none());
        assert!(resp.to_u16_bits().is_none());
    }

    #[test]
    fn half_formats_carry_u16_patterns() {
        // 1.0 in f16 = 0x3C00; in bf16 = 0x3F80.
        let r = DivRequest::from_f16_bits(&[0x3C00], &[0x3C00]);
        assert_eq!(r.fmt, F16);
        assert_eq!(r.a, vec![0x3C00]);
        let r = DivRequest::from_bf16_bits(&[0x3F80], &[0x3F80]).with_rounding(Rounding::TowardZero);
        assert_eq!(r.fmt, BF16);
        assert_eq!(r.rm, Rounding::TowardZero);
        let resp = DivResponse {
            fmt: BF16,
            rm: r.rm,
            bits: vec![0x3F80],
        };
        assert_eq!(resp.to_u16_bits().unwrap(), vec![0x3F80]);
    }

    #[test]
    fn validate_rejects_defects() {
        assert!(DivRequest::new(F32, Rounding::NearestEven, vec![0], vec![])
            .validate()
            .is_err());
        assert!(DivRequest::new(F32, Rounding::NearestEven, vec![], vec![])
            .validate()
            .is_err());
        // A pattern wider than f16's 16 storage bits.
        assert!(
            DivRequest::new(F16, Rounding::NearestEven, vec![0x1_0000], vec![0x3C00])
                .validate()
                .is_err()
        );
        // f64 uses the whole carrier; any u64 is in range.
        assert!(
            DivRequest::new(F64, Rounding::NearestEven, vec![u64::MAX], vec![1])
                .validate()
                .is_ok()
        );
    }

    #[test]
    fn key_display_names() {
        let k = BatchKey::new(F16, Rounding::TowardNegative);
        assert_eq!(k.to_string(), "f16/down");
        // Div keys keep the historical spelling; other ops prefix.
        assert_eq!(
            BatchKey::for_op(Op::Recip, F32, Rounding::NearestEven).to_string(),
            "recip:f32/nearest"
        );
        assert_eq!(
            BatchKey::for_op(Op::Rsqrt, F64, Rounding::TowardZero).to_string(),
            "rsqrt:f64/zero"
        );
        assert_eq!(
            BatchKey::for_op(Op::ScaleByRecip, BF16, Rounding::TowardPositive).to_string(),
            "scale-recip:bf16/up"
        );
    }

    #[test]
    fn per_op_shapes_validate() {
        // Unary ops: no divisor vector travels, and none is tolerated.
        let r = DivRequest::recip(F32, Rounding::NearestEven, vec![0x4000_0000]);
        assert_eq!(r.op, Op::Recip);
        assert!(r.b.is_empty());
        assert!(r.validate().is_ok());
        assert_eq!(r.key(), BatchKey::for_op(Op::Recip, F32, Rounding::NearestEven));
        let mut bad = DivRequest::rsqrt(F32, Rounding::NearestEven, vec![0x4000_0000]);
        bad.b = vec![0x3F80_0000];
        assert!(bad.validate().unwrap_err().contains("unary"));
        // Unary lengths are free: no a/b equality requirement at all.
        let r = DivRequest::rsqrt(F16, Rounding::TowardZero, vec![0x3C00, 0x4000, 0x4400]);
        assert!(r.validate().is_ok());

        // ScaleByRecip: equal-length rows, one divisor per row.
        let r = DivRequest::scale_by_recip(
            F32,
            Rounding::NearestEven,
            vec![1, 2, 3, 4, 5, 6],
            vec![7, 8],
        );
        assert!(r.validate().is_ok());
        assert_eq!(r.lanes(), 6);
        let r = DivRequest::scale_by_recip(F32, Rounding::NearestEven, vec![1, 2, 3], vec![7, 8]);
        assert!(r.validate().unwrap_err().contains("equal length"));
        let r = DivRequest::scale_by_recip(F32, Rounding::NearestEven, vec![1, 2, 3], vec![]);
        assert!(r.validate().is_err());
        // Width masking applies to the divisor rows too.
        let r = DivRequest::scale_by_recip(
            F16,
            Rounding::NearestEven,
            vec![0x3C00, 0x4000],
            vec![0x1_0000],
        );
        assert!(r.validate().is_err());
    }

    #[test]
    fn ragged_scale_recip_shapes_validate() {
        // 3 + 1 + 2 lanes across three divisor rows.
        let r = DivRequest::scale_by_recip_ragged(
            F32,
            Rounding::NearestEven,
            vec![1, 2, 3, 4, 5, 6],
            vec![7, 8, 9],
            vec![3, 1, 2],
        );
        assert!(r.validate().is_ok(), "{:?}", r.validate());
        assert_eq!(r.lanes(), 6);
        assert_eq!(r.key(), BatchKey::for_op(Op::ScaleByRecip, F32, Rounding::NearestEven));

        // Row-length vector must match the divisor count...
        let r = DivRequest::scale_by_recip_ragged(
            F32,
            Rounding::NearestEven,
            vec![1, 2, 3],
            vec![7, 8],
            vec![3],
        );
        assert!(r.validate().unwrap_err().contains("match divisors"));
        // ...cover the lanes exactly...
        let r = DivRequest::scale_by_recip_ragged(
            F32,
            Rounding::NearestEven,
            vec![1, 2, 3],
            vec![7, 8],
            vec![1, 1],
        );
        assert!(r.validate().unwrap_err().contains("sum to 2"));
        // ...and contain no empty row.
        let r = DivRequest::scale_by_recip_ragged(
            F32,
            Rounding::NearestEven,
            vec![1, 2, 3],
            vec![7, 8],
            vec![3, 0],
        );
        assert!(r.validate().unwrap_err().contains("row 1 is empty"));

        // A lane/divisor shape the uniform constructor rejects is
        // exactly what the ragged one is for.
        let uniform =
            DivRequest::scale_by_recip(F32, Rounding::NearestEven, vec![1, 2, 3], vec![7, 8]);
        assert!(uniform.validate().unwrap_err().contains("equal length"));
        let ragged = DivRequest::scale_by_recip_ragged(
            F32,
            Rounding::NearestEven,
            vec![1, 2, 3],
            vec![7, 8],
            vec![2, 1],
        );
        assert!(ragged.validate().is_ok());

        // rows is scale-recip-only: any other op must travel without it.
        let mut r = DivRequest::recip(F32, Rounding::NearestEven, vec![0x4000_0000]);
        r.rows = vec![1];
        assert!(r.validate().unwrap_err().contains("scale-recip only"));
        let mut r = DivRequest::from_f32(&[1.0], &[2.0]);
        r.rows = vec![1];
        assert!(r.validate().is_err());
    }

    #[test]
    fn per_op_lane_costs_bracket_division() {
        for fmt in [F16, BF16, F32, F64] {
            let div = BatchKey::new(fmt, Rounding::NearestEven).lane_cost();
            let recip = BatchKey::for_op(Op::Recip, fmt, Rounding::NearestEven).lane_cost();
            let rsqrt = BatchKey::for_op(Op::Rsqrt, fmt, Rounding::NearestEven).lane_cost();
            let scale =
                BatchKey::for_op(Op::ScaleByRecip, fmt, Rounding::NearestEven).lane_cost();
            assert!(recip <= div && scale <= div && rsqrt > div, "{}", fmt.name());
            assert!(recip >= 1 && scale >= 1);
            assert_eq!(recip, scale);
        }
    }

    #[test]
    fn key_cost_follows_format_not_rounding() {
        for rm in Rounding::ALL {
            assert_eq!(BatchKey::new(F16, rm).lane_cost(), F16.lane_cost());
            assert_eq!(BatchKey::new(F64, rm).lane_cost(), F64.lane_cost());
        }
        assert_eq!(
            BatchKey::new(F64, Rounding::NearestEven).lane_cost(),
            2 * BatchKey::new(BF16, Rounding::NearestEven).lane_cost()
        );
    }
}
