//! Typed requests and responses for the division service.
//!
//! The datapath is format-parametric by construction (every bit pattern
//! travels in the low bits of a `u64`, see [`crate::fp::format`]), so the
//! service speaks the same language: a [`DivRequest`] carries raw
//! bit-pattern lanes plus the [`Format`] that interprets them and the
//! [`Rounding`] attribute to apply. Convenience constructors cover the
//! four interchange formats; [`DivResponse`] converts back.

use crate::fp::{Format, Rounding, BF16, F16, F32, F64};

/// The batching key: requests coalesce only with requests of the same
/// format and rounding mode, so every backend batch is homogeneous.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BatchKey {
    pub fmt: Format,
    pub rm: Rounding,
}

impl BatchKey {
    pub fn new(fmt: Format, rm: Rounding) -> Self {
        Self { fmt, rm }
    }

    /// Cost units one lane of this key charges against the assembler's
    /// coalescing budget (see [`Format::lane_cost`]; rounding mode does
    /// not change the per-lane work).
    pub const fn lane_cost(&self) -> usize {
        self.fmt.lane_cost()
    }
}

impl std::fmt::Display for BatchKey {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}/{}", self.fmt.name(), self.rm.name())
    }
}

/// One division request: `out[i] = a[i] / b[i]` over `fmt` bit patterns
/// under rounding mode `rm`.
#[derive(Clone, Debug)]
pub struct DivRequest {
    pub fmt: Format,
    pub rm: Rounding,
    /// Dividend bit patterns (low `fmt.width()` bits of each `u64`).
    pub a: Vec<u64>,
    /// Divisor bit patterns, same length as `a`.
    pub b: Vec<u64>,
}

impl DivRequest {
    /// Raw constructor over bit patterns of an arbitrary format.
    pub fn new(fmt: Format, rm: Rounding, a: Vec<u64>, b: Vec<u64>) -> Self {
        Self { fmt, rm, a, b }
    }

    /// binary32 lanes at round-to-nearest-even.
    pub fn from_f32(a: &[f32], b: &[f32]) -> Self {
        Self {
            fmt: F32,
            rm: Rounding::NearestEven,
            a: a.iter().map(|&x| x.to_bits() as u64).collect(),
            b: b.iter().map(|&x| x.to_bits() as u64).collect(),
        }
    }

    /// binary64 lanes at round-to-nearest-even.
    pub fn from_f64(a: &[f64], b: &[f64]) -> Self {
        Self {
            fmt: F64,
            rm: Rounding::NearestEven,
            a: a.iter().map(|&x| x.to_bits()).collect(),
            b: b.iter().map(|&x| x.to_bits()).collect(),
        }
    }

    /// binary16 lanes given as raw `u16` bit patterns.
    pub fn from_f16_bits(a: &[u16], b: &[u16]) -> Self {
        Self {
            fmt: F16,
            rm: Rounding::NearestEven,
            a: a.iter().map(|&x| x as u64).collect(),
            b: b.iter().map(|&x| x as u64).collect(),
        }
    }

    /// bfloat16 lanes given as raw `u16` bit patterns.
    pub fn from_bf16_bits(a: &[u16], b: &[u16]) -> Self {
        Self {
            fmt: BF16,
            rm: Rounding::NearestEven,
            a: a.iter().map(|&x| x as u64).collect(),
            b: b.iter().map(|&x| x as u64).collect(),
        }
    }

    /// Override the rounding mode (builder style).
    pub fn with_rounding(mut self, rm: Rounding) -> Self {
        self.rm = rm;
        self
    }

    pub fn lanes(&self) -> usize {
        self.a.len()
    }

    pub fn key(&self) -> BatchKey {
        BatchKey::new(self.fmt, self.rm)
    }

    /// Structural validation: matched non-empty lanes whose bit patterns
    /// fit the format's storage width. Returns a human-readable defect.
    pub fn validate(&self) -> Result<(), String> {
        if self.a.len() != self.b.len() {
            return Err(format!(
                "operand length mismatch: {} vs {}",
                self.a.len(),
                self.b.len()
            ));
        }
        if self.a.is_empty() {
            return Err("empty request".into());
        }
        let mask = self.fmt.width_mask();
        if mask != u64::MAX {
            let stray = |bits: &[u64]| bits.iter().any(|&x| x & !mask != 0);
            if stray(&self.a) || stray(&self.b) {
                return Err(format!(
                    "operand bits exceed {} storage width",
                    self.fmt.name()
                ));
            }
        }
        Ok(())
    }
}

/// Quotient lanes for one request, in the request's format.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct DivResponse {
    pub fmt: Format,
    pub rm: Rounding,
    /// Quotient bit patterns, one per request lane, in lane order.
    pub bits: Vec<u64>,
}

impl DivResponse {
    pub fn lanes(&self) -> usize {
        self.bits.len()
    }

    /// Decode as f32 values (`None` unless the request was binary32).
    pub fn to_f32(&self) -> Option<Vec<f32>> {
        (self.fmt == F32).then(|| self.bits.iter().map(|&q| f32::from_bits(q as u32)).collect())
    }

    /// Decode as f64 values (`None` unless the request was binary64).
    pub fn to_f64(&self) -> Option<Vec<f64>> {
        (self.fmt == F64).then(|| self.bits.iter().map(f64::from_bits).collect())
    }

    /// Raw 16-bit patterns (`None` unless the request was f16/bf16).
    pub fn to_u16_bits(&self) -> Option<Vec<u16>> {
        (self.fmt == F16 || self.fmt == BF16)
            .then(|| self.bits.iter().map(|&q| q as u16).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip_and_key() {
        let r = DivRequest::from_f32(&[6.0, -1.5], &[2.0, 3.0]);
        assert_eq!(r.fmt, F32);
        assert_eq!(r.rm, Rounding::NearestEven);
        assert_eq!(r.lanes(), 2);
        assert_eq!(r.key(), BatchKey::new(F32, Rounding::NearestEven));
        assert!(r.validate().is_ok());
        let resp = DivResponse {
            fmt: F32,
            rm: r.rm,
            bits: r.a.clone(),
        };
        assert_eq!(resp.to_f32().unwrap(), vec![6.0, -1.5]);
        assert!(resp.to_f64().is_none());
        assert!(resp.to_u16_bits().is_none());
    }

    #[test]
    fn half_formats_carry_u16_patterns() {
        // 1.0 in f16 = 0x3C00; in bf16 = 0x3F80.
        let r = DivRequest::from_f16_bits(&[0x3C00], &[0x3C00]);
        assert_eq!(r.fmt, F16);
        assert_eq!(r.a, vec![0x3C00]);
        let r = DivRequest::from_bf16_bits(&[0x3F80], &[0x3F80]).with_rounding(Rounding::TowardZero);
        assert_eq!(r.fmt, BF16);
        assert_eq!(r.rm, Rounding::TowardZero);
        let resp = DivResponse {
            fmt: BF16,
            rm: r.rm,
            bits: vec![0x3F80],
        };
        assert_eq!(resp.to_u16_bits().unwrap(), vec![0x3F80]);
    }

    #[test]
    fn validate_rejects_defects() {
        assert!(DivRequest::new(F32, Rounding::NearestEven, vec![0], vec![])
            .validate()
            .is_err());
        assert!(DivRequest::new(F32, Rounding::NearestEven, vec![], vec![])
            .validate()
            .is_err());
        // A pattern wider than f16's 16 storage bits.
        assert!(
            DivRequest::new(F16, Rounding::NearestEven, vec![0x1_0000], vec![0x3C00])
                .validate()
                .is_err()
        );
        // f64 uses the whole carrier; any u64 is in range.
        assert!(
            DivRequest::new(F64, Rounding::NearestEven, vec![u64::MAX], vec![1])
                .validate()
                .is_ok()
        );
    }

    #[test]
    fn key_display_names() {
        let k = BatchKey::new(F16, Rounding::TowardNegative);
        assert_eq!(k.to_string(), "f16/down");
    }

    #[test]
    fn key_cost_follows_format_not_rounding() {
        for rm in Rounding::ALL {
            assert_eq!(BatchKey::new(F16, rm).lane_cost(), F16.lane_cost());
            assert_eq!(BatchKey::new(F64, rm).lane_cost(), F64.lane_cost());
        }
        assert_eq!(
            BatchKey::new(F64, Rounding::NearestEven).lane_cost(),
            2 * BatchKey::new(BF16, Rounding::NearestEven).lane_cost()
        );
    }
}
