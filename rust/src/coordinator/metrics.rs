//! Service metrics: batched worker counters and lock-free histograms.
//!
//! The sharded runtime replaces the old lock-and-increment `Metrics`
//! struct with a two-tier scheme (tokio's `MetricsBatch` idiom, adapted
//! to this crate's thread pool):
//!
//! * **Hot-path counters stay thread-local.** Each worker accumulates
//!   its `park/noop/steal/steal_operations/poll` counts, busy duration
//!   and a batch-latency histogram in a plain [`MetricsBatch`] (no
//!   atomics at all), and flushes them with `Relaxed` **stores** into
//!   its shared [`WorkerMetrics`] slot exactly once per park — a parked
//!   worker has nothing better to do, and a busy worker never pays for
//!   metric visibility.
//! * **Submit-path and dispatch counters stay direct.** Request,
//!   rejection, failure and queue-depth accounting in
//!   [`ServiceCounters`] must be visible immediately (tests and the
//!   adaptive flush policy read them mid-flight), so they remain plain
//!   relaxed atomics touched at most once per request or batch —
//!   already far off the per-lane hot path.
//!
//! Latency distributions use [`AtomicHistogram`]: 64 log₂-spaced
//! nanosecond buckets recorded with relaxed `fetch_add`, read back as
//! p50/p99 via geometric bucket midpoints. Quantiles are resolved to
//! within a factor of √2, which is plenty for a serving dashboard and
//! costs no locks, no samples, and a fixed 1 KiB per histogram.
//! [`MetricsSnapshot`] aggregates all three sources so existing callers
//! keep a single point-in-time view.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::time::{Duration, Instant};

/// Log₂-spaced nanosecond buckets: bucket `i` holds durations in
/// `[2^i, 2^{i+1})` ns, so 64 buckets span every representable `u64`
/// duration (~584 years) — no clamping case to reason about.
const HIST_BUCKETS: usize = 64;

/// A lock-free duration histogram: 64 log₂ nanosecond buckets plus an
/// exact count and sum, all relaxed atomics. Writers call
/// [`AtomicHistogram::record`]; readers derive mean (exact) and
/// quantiles (bucket-resolution) from a snapshot of the buckets.
pub struct AtomicHistogram {
    buckets: [AtomicU64; HIST_BUCKETS],
    count: AtomicU64,
    sum_ns: AtomicU64,
}

impl Default for AtomicHistogram {
    fn default() -> Self {
        Self {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
        }
    }
}

/// Bucket index for a duration in nanoseconds (zero maps with one).
fn bucket_of(ns: u64) -> usize {
    (63 - ns.max(1).leading_zeros()) as usize
}

/// Geometric midpoint of bucket `i` in nanoseconds: `2^i · √2`, the
/// unbiased representative of a log-spaced bin.
fn bucket_mid_ns(i: usize) -> f64 {
    (1u64 << i) as f64 * std::f64::consts::SQRT_2
}

impl AtomicHistogram {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one duration (relaxed; safe from any thread).
    pub fn record(&self, d: Duration) {
        self.record_ns(d.as_nanos().min(u64::MAX as u128) as u64);
    }

    pub fn record_ns(&self, ns: u64) {
        self.buckets[bucket_of(ns)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Exact mean in seconds (0.0 while empty).
    pub fn mean_seconds(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        self.sum_ns.load(Ordering::Relaxed) as f64 / n as f64 * 1e-9
    }

    /// Quantile `q ∈ (0, 1]` in seconds, resolved to the geometric
    /// midpoint of the owning bucket (0.0 while empty). Monotone in `q`
    /// by construction, so `p99 ≥ p50` always holds.
    pub fn percentile_seconds(&self, q: f64) -> f64 {
        let snap: Vec<u64> = self
            .buckets
            .iter()
            .map(|b| b.load(Ordering::Relaxed))
            .collect();
        let total: u64 = snap.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let target = ((q * total as f64).ceil() as u64).clamp(1, total);
        let mut seen = 0u64;
        for (i, &n) in snap.iter().enumerate() {
            seen += n;
            if seen >= target {
                return bucket_mid_ns(i) * 1e-9;
            }
        }
        bucket_mid_ns(HIST_BUCKETS - 1) * 1e-9
    }
}

/// Worker-local histogram deltas, merged into a shared
/// [`AtomicHistogram`] on flush (plain integers until then).
#[derive(Default)]
pub struct HistogramBatch {
    buckets: [u64; HIST_BUCKETS],
    count: u64,
    sum_ns: u64,
}

impl HistogramBatch {
    pub fn record(&mut self, d: Duration) {
        let ns = d.as_nanos().min(u64::MAX as u128) as u64;
        self.buckets[bucket_of(ns)] += 1;
        self.count += 1;
        self.sum_ns += ns;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    /// Add the accumulated deltas into `sink` and reset to empty.
    pub fn flush_into(&mut self, sink: &AtomicHistogram) {
        if self.count == 0 {
            return;
        }
        for (local, shared) in self.buckets.iter_mut().zip(sink.buckets.iter()) {
            if *local > 0 {
                shared.fetch_add(*local, Ordering::Relaxed);
                *local = 0;
            }
        }
        sink.count.fetch_add(self.count, Ordering::Relaxed);
        sink.sum_ns.fetch_add(self.sum_ns, Ordering::Relaxed);
        self.count = 0;
        self.sum_ns = 0;
    }
}

/// One worker's shared metric slot. The owning worker is the only
/// writer ([`MetricsBatch::submit`] stores absolute totals), so every
/// field is a relaxed store/load pair — never a read-modify-write.
#[derive(Default)]
pub struct WorkerMetrics {
    park_count: AtomicU64,
    noop_count: AtomicU64,
    steal_count: AtomicU64,
    steal_operations: AtomicU64,
    poll_count: AtomicU64,
    busy_duration_ns: AtomicU64,
}

impl WorkerMetrics {
    /// Times this worker parked (waited on the ready-queue condvar).
    pub fn parks(&self) -> u64 {
        self.park_count.load(Ordering::Relaxed)
    }

    /// Parks that followed a wakeup which found no work (condvar churn).
    pub fn noops(&self) -> u64 {
        self.noop_count.load(Ordering::Relaxed)
    }

    /// Ready batches taken from other shards' queues (executed or
    /// migrated home).
    pub fn steals(&self) -> u64 {
        self.steal_count.load(Ordering::Relaxed)
    }

    /// Steal operations (one per raid on a victim shard, however many
    /// batches it carried off).
    pub fn steal_operations(&self) -> u64 {
        self.steal_operations.load(Ordering::Relaxed)
    }

    /// Batches this worker executed.
    pub fn polls(&self) -> u64 {
        self.poll_count.load(Ordering::Relaxed)
    }

    /// Total time spent unparked (processing or scanning for work).
    pub fn busy_duration(&self) -> Duration {
        Duration::from_nanos(self.busy_duration_ns.load(Ordering::Relaxed))
    }
}

/// A worker thread's private metric accumulator: plain integers bumped
/// on the hot path, flushed to the shared [`WorkerMetrics`] slot (and
/// the shared batch-latency [`AtomicHistogram`]) once per park.
pub struct MetricsBatch {
    park_count: u64,
    noop_count: u64,
    steal_count: u64,
    steal_operations: u64,
    poll_count: u64,
    /// `poll_count` at the previous park — equal at the next park means
    /// the wakeup in between did no work (a no-op park).
    poll_count_on_last_park: u64,
    busy_duration_ns: u64,
    /// When the current unparked (busy) period began.
    processing_started_at: Instant,
    batch_latency: HistogramBatch,
}

impl Default for MetricsBatch {
    fn default() -> Self {
        Self::new()
    }
}

impl MetricsBatch {
    pub fn new() -> Self {
        Self {
            park_count: 0,
            noop_count: 0,
            steal_count: 0,
            steal_operations: 0,
            poll_count: 0,
            poll_count_on_last_park: 0,
            busy_duration_ns: 0,
            processing_started_at: Instant::now(),
            batch_latency: HistogramBatch::default(),
        }
    }

    /// One batch executed.
    pub fn incr_poll(&mut self) {
        self.poll_count += 1;
    }

    /// One raid on a victim shard that carried off `batches` ready
    /// batches (the first executed, the rest migrated home).
    pub fn incr_steal(&mut self, batches: u64) {
        self.steal_count += batches;
        self.steal_operations += 1;
    }

    /// Record one batch's end-to-end latency (oldest lane entering its
    /// assembler bucket → responses sent). Buffered locally; reaches
    /// the shared histogram on the next flush.
    pub fn record_batch_latency(&mut self, d: Duration) {
        self.batch_latency.record(d);
    }

    fn accumulate_busy(&mut self) {
        let now = Instant::now();
        self.busy_duration_ns += now
            .saturating_duration_since(self.processing_started_at)
            .as_nanos()
            .min(u64::MAX as u128) as u64;
        self.processing_started_at = now;
    }

    /// Called right before blocking on the ready-queue condvar: close
    /// the busy period, count the park, and classify it as a no-op when
    /// nothing was polled since the previous park.
    pub fn about_to_park(&mut self) {
        self.accumulate_busy();
        self.park_count += 1;
        if self.poll_count == self.poll_count_on_last_park {
            self.noop_count += 1;
        }
        self.poll_count_on_last_park = self.poll_count;
    }

    /// Called right after the condvar wait returns: reopen the busy
    /// clock (time spent parked is not busy time).
    pub fn returned_from_park(&mut self) {
        self.processing_started_at = Instant::now();
    }

    /// Close the busy period without counting a park (worker exit).
    pub fn finish(&mut self) {
        self.accumulate_busy();
    }

    /// Flush to the shared slots: absolute `Relaxed` stores for the
    /// counters (this batch is the only writer of `worker`), additive
    /// merge for the latency histogram.
    pub fn submit(&mut self, worker: &WorkerMetrics, batch_latency: &AtomicHistogram) {
        worker.park_count.store(self.park_count, Ordering::Relaxed);
        worker.noop_count.store(self.noop_count, Ordering::Relaxed);
        worker.steal_count.store(self.steal_count, Ordering::Relaxed);
        worker
            .steal_operations
            .store(self.steal_operations, Ordering::Relaxed);
        worker.poll_count.store(self.poll_count, Ordering::Relaxed);
        worker
            .busy_duration_ns
            .store(self.busy_duration_ns, Ordering::Relaxed);
        self.batch_latency.flush_into(batch_latency);
    }
}

/// Submit-path and dispatch counters: direct relaxed atomics, shared by
/// every shard and worker. These are read mid-flight — by tests, by the
/// adaptive flush policy (`queue_depth`, `idle_workers`) and by error
/// paths — so they are deliberately **not** batched.
#[derive(Default)]
pub struct ServiceCounters {
    pub(crate) requests: AtomicU64,
    pub(crate) lanes: AtomicU64,
    pub(crate) cost_units: AtomicU64,
    pub(crate) batches: AtomicU64,
    pub(crate) failures: AtomicU64,
    pub(crate) rejected: AtomicU64,
    pub(crate) queue_depth: AtomicUsize,
    pub(crate) idle_workers: AtomicUsize,
}

/// A point-in-time metrics snapshot, aggregated across every shard and
/// worker. The pre-shard fields keep their names and meanings so
/// existing callers compile and read unchanged.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub lanes: u64,
    /// Cost units dispatched to workers (Σ batch `lanes × lane_cost`):
    /// the format-weighted work gauge behind the cost-metered batcher.
    pub cost_units: u64,
    pub batches: u64,
    pub failures: u64,
    pub rejected: u64,
    /// Submissions accepted but not yet drained by a shard batcher
    /// (summed over shards).
    pub queue_depth: usize,
    /// Workers currently parked waiting for a ready batch
    /// (adaptive-flush signal).
    pub workers_idle: usize,
    /// End-to-end latency stats over completed `wait()`s (seconds).
    pub latency_p50: f64,
    pub latency_p99: f64,
    pub latency_mean: f64,
    pub latency_count: u64,
    /// Shards the service was started with.
    pub shards: usize,
    /// Worker threads the service was started with.
    pub workers: usize,
    /// Σ worker parks (condvar waits).
    pub parks: u64,
    /// Σ parks that followed a wakeup which found no work.
    pub noops: u64,
    /// Σ ready batches stolen from non-home shards.
    pub steals: u64,
    /// Σ steal raids (one per victim visit, ≥ 1 batch each).
    pub steal_operations: u64,
    /// Σ batches executed by workers (flushed once per park, so this
    /// may trail `batches` while workers are running flat out).
    pub polls: u64,
    /// Σ worker busy time in seconds (unparked wall-clock).
    pub busy_seconds: f64,
    /// Batch latency (oldest lane queued → responses sent), seconds.
    pub batch_latency_p50: f64,
    pub batch_latency_p99: f64,
    pub batch_latency_count: u64,
    /// Batches the adaptive router sent to the Taylor kernel datapath
    /// (zero unless serving `BackendChoice::Auto`).
    pub router_kernel_batches: u64,
    /// Batches the adaptive router sent to the Goldschmidt datapath.
    pub router_goldschmidt_batches: u64,
    /// Fraction of measured (Format, Rounding, batch-size) buckets
    /// where the Taylor kernel currently scores fastest; the
    /// Goldschmidt win-rate is its complement over measured buckets.
    pub router_kernel_win_rate: f64,
}

impl MetricsSnapshot {
    /// Mean lanes per backend batch (coalescing effectiveness).
    pub fn mean_batch_lanes(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.lanes as f64 / self.batches as f64
        }
    }

    /// Mean cost units per backend batch — how close emitted batches run
    /// to the cost budget, independent of the format mix.
    pub fn mean_batch_cost(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.cost_units as f64 / self.batches as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_indexing_spans_u64() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 0);
        assert_eq!(bucket_of(2), 1);
        assert_eq!(bucket_of(3), 1);
        assert_eq!(bucket_of(1024), 10);
        assert_eq!(bucket_of(u64::MAX), 63);
    }

    #[test]
    fn histogram_percentiles_are_monotone_and_bracketed() {
        let h = AtomicHistogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.percentile_seconds(0.5), 0.0);
        assert_eq!(h.mean_seconds(), 0.0);
        for _ in 0..10 {
            h.record(Duration::from_nanos(1_000));
        }
        h.record(Duration::from_micros(1_000)); // one 1 ms outlier
        assert_eq!(h.count(), 11);
        let p50 = h.percentile_seconds(0.5);
        let p99 = h.percentile_seconds(0.99);
        // p50 sits in the 1 µs bucket (within √2 of 1e-6), p99 in the
        // 1 ms bucket; monotone by construction.
        assert!(p50 > 0.25e-6 && p50 < 4e-6, "p50 = {p50}");
        assert!(p99 > 0.25e-3 && p99 < 4e-3, "p99 = {p99}");
        assert!(p99 >= p50);
        // Mean is exact: (10·1µs + 1ms) / 11 ≈ 91.8 µs.
        let mean = h.mean_seconds();
        assert!((mean - 91.8e-6).abs() < 1e-6, "mean = {mean}");
    }

    #[test]
    fn histogram_batch_flushes_additively_and_resets() {
        let shared = AtomicHistogram::new();
        let mut local = HistogramBatch::default();
        local.record(Duration::from_nanos(100));
        local.record(Duration::from_nanos(200));
        assert_eq!(local.count(), 2);
        local.flush_into(&shared);
        assert_eq!(local.count(), 0);
        assert_eq!(shared.count(), 2);
        // A second flush with nothing buffered is a no-op.
        local.flush_into(&shared);
        assert_eq!(shared.count(), 2);
        local.record(Duration::from_nanos(400));
        local.flush_into(&shared);
        assert_eq!(shared.count(), 3);
    }

    #[test]
    fn metrics_batch_park_noop_and_steal_accounting() {
        let wm = WorkerMetrics::default();
        let hist = AtomicHistogram::new();
        let mut mb = MetricsBatch::new();
        // First park with no polls: a no-op park.
        mb.about_to_park();
        mb.submit(&wm, &hist);
        assert_eq!(wm.parks(), 1);
        assert_eq!(wm.noops(), 1);
        mb.returned_from_park();
        // Work happens: poll + steal of 3 batches, then a real park.
        mb.incr_poll();
        mb.incr_steal(3);
        mb.record_batch_latency(Duration::from_micros(5));
        mb.about_to_park();
        mb.submit(&wm, &hist);
        assert_eq!(wm.parks(), 2);
        assert_eq!(wm.noops(), 1, "a park after work is not a no-op");
        assert_eq!(wm.polls(), 1);
        assert_eq!(wm.steals(), 3);
        assert_eq!(wm.steal_operations(), 1);
        assert_eq!(hist.count(), 1, "batch latency flushed on park");
        // Wake, find nothing, park again: no-op count grows.
        mb.returned_from_park();
        mb.about_to_park();
        mb.submit(&wm, &hist);
        assert_eq!(wm.noops(), 2);
        // Stores are absolute, not additive: totals, not deltas.
        assert_eq!(wm.parks(), 3);
        mb.finish();
        mb.submit(&wm, &hist);
        assert!(wm.busy_duration() >= Duration::ZERO);
    }

    #[test]
    fn snapshot_means_guard_division_by_zero() {
        let snap = MetricsSnapshot {
            requests: 0,
            lanes: 0,
            cost_units: 0,
            batches: 0,
            failures: 0,
            rejected: 0,
            queue_depth: 0,
            workers_idle: 0,
            latency_p50: 0.0,
            latency_p99: 0.0,
            latency_mean: 0.0,
            latency_count: 0,
            shards: 1,
            workers: 1,
            parks: 0,
            noops: 0,
            steals: 0,
            steal_operations: 0,
            polls: 0,
            busy_seconds: 0.0,
            batch_latency_p50: 0.0,
            batch_latency_p99: 0.0,
            batch_latency_count: 0,
            router_kernel_batches: 0,
            router_goldschmidt_batches: 0,
            router_kernel_win_rate: 0.0,
        };
        assert_eq!(snap.mean_batch_lanes(), 0.0);
        assert_eq!(snap.mean_batch_cost(), 0.0);
    }
}
