//! Worker backends: the computation a worker thread runs per batch.
//!
//! A [`Backend`] consumes one flattened, format-homogeneous batch of
//! bit-pattern lanes (see [`super::batcher::Batch`]) plus its
//! `(Op, Format, Rounding)` key. The kernel-family backends
//! (`Kernel`, `Goldschmidt`, `Auto`) and the gold reference serve all
//! four operations; the legacy native loops and the PJRT artifact are
//! division-only and reject other ops by name. Implementations:
//!
//! * [`KernelBackend`] — the staged SoA kernel ([`crate::kernel`])
//!   driven directly: plan → seed → power → mul_round over lane tiles,
//!   tile width, ILM budget and lane-engine choice (auto/forced/scalar
//!   SIMD, [`crate::simd::SimdChoice`]) from
//!   [`crate::kernel::KernelConfig`];
//! * [`NativeBackend`] — the same staged kernel behind
//!   [`crate::divider::Divider::div_bits_batch`], plus a
//!   divisor-grouping permutation so repeated divisors arrive in runs
//!   and the kernel's reciprocal cache hits on every repeat;
//! * [`ScalarNativeBackend`] — the same datapath one lane at a time (the
//!   pre-batching worker loop), kept as the baseline the serving benches
//!   compare against;
//! * [`GoldBackend`] — exactly-rounded digit recurrence
//!   ([`crate::divider::longdiv::LongDivider`]); slow, but the service's
//!   routing and format threading can be property-tested bit-for-bit
//!   against per-lane gold results;
//! * [`GoldschmidtBackend`] — the second first-class kernel datapath:
//!   the batched Goldschmidt iterate pipeline
//!   ([`crate::kernel::GoldschmidtKernel`]) over the same staged SoA
//!   scratch and lane engine as the Taylor kernel;
//! * [`RoutedBackend`] — owns one Taylor kernel and one Goldschmidt
//!   backend plus a [`crate::router::BackendRouter`] handle, and asks
//!   the router which datapath should run each batch (the
//!   `BackendChoice::Auto` path), feeding measured batch latencies
//!   back so the routing table tracks the live machine;
//! * [`PjrtBackend`] — the AOT-compiled JAX/Pallas artifact executed via
//!   PJRT ([`crate::runtime::DivideEngine`], `pjrt` feature); serves
//!   binary32 at round-to-nearest only.
//!
//! Backends are created *inside* each worker thread by a factory (PJRT
//! handles are not `Send`), so [`BackendChoice`] is the serializable
//! configuration and [`Backend`] the per-thread instance. Under the
//! sharded runtime each worker still owns exactly one backend for its
//! whole life: work stealing moves *batches* between shards' ready
//! deques, never backends between threads, so a stolen batch simply
//! runs on the thief's own backend instance.

use std::sync::Arc;
use std::time::Instant;

use crate::divider::longdiv::LongDivider;
use crate::divider::{BackendKind, Divider, TaylorDivider};
use crate::fp::{Format, Op, Rounding, F32};
use crate::kernel::{GoldschmidtKernel, KernelConfig, KernelScratch};
use crate::router::{BackendRouter, Candidate};
use crate::taylor::TaylorConfig;
use crate::util::error::Result;

/// What a worker does with one flattened batch: apply `op` to `fmt`
/// bit-pattern lanes under rounding mode `rm`. Operand shape follows
/// [`super::batcher::Batch::flatten`]: `Div` gets matched `a`/`b` and
/// empty `rows`; `Recip`/`Rsqrt` get only `a`; `ScaleByRecip` gets one
/// divisor per row in `b` with `rows[r]` lanes of `a` each. The result
/// always has `a.len()` lanes, in lane order.
pub trait Backend {
    fn compute(
        &mut self,
        op: Op,
        a: &[u64],
        b: &[u64],
        rows: &[u32],
        fmt: Format,
        rm: Rounding,
    ) -> Result<Vec<u64>>;

    /// Division shorthand — the historical entry point, and still the
    /// hot path's common case.
    fn divide(&mut self, a: &[u64], b: &[u64], fmt: Format, rm: Rounding) -> Result<Vec<u64>> {
        self.compute(Op::Div, a, b, &[], fmt, rm)
    }

    fn describe(&self) -> String;
}

/// Uniform rejection for the division-only backends (`Native`,
/// `NativeScalar`, `Pjrt`): name the backend and the op so a misrouted
/// request says what to reconfigure.
fn reject_non_div(backend: &str, op: Op) -> crate::util::error::Error {
    crate::err!(
        "{backend} backend serves div only (got {}); use the kernel, goldschmidt, \
         auto or gold backend for other ops",
        op.name()
    )
}

/// Serializable backend configuration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendChoice {
    /// Bit-exact Rust datapath through `div_bits_batch` (Taylor order,
    /// optional ILM budget — `None` = exact multiplies).
    Native {
        order: u32,
        ilm_iterations: Option<u32>,
    },
    /// The same datapath through the scalar `div_bits` loop — the
    /// pre-batching baseline, kept for batch-vs-scalar comparisons.
    NativeScalar {
        order: u32,
        ilm_iterations: Option<u32>,
    },
    /// The staged SoA kernel driven directly (no divisor-grouping
    /// permutation): lane-parallel plan → seed → power → mul_round
    /// tiles, configured by [`KernelConfig`].
    Kernel { order: u32, kernel: KernelConfig },
    /// The batched Goldschmidt iterate datapath over the same staged
    /// SoA scratch and lane engine as `Kernel`
    /// ([`crate::kernel::GoldschmidtKernel`]); `iterations` refinement
    /// rounds (the paper-matched default is 3) and `trunc_bits` low
    /// product bits dropped per refinement multiply (the paper's
    /// hardware-reduction knob; 0 = bit-exact wide products).
    Goldschmidt {
        iterations: u32,
        kernel: KernelConfig,
        trunc_bits: u32,
    },
    /// Adaptive per-bucket routing between the Taylor kernel and the
    /// Goldschmidt datapath ([`crate::router::BackendRouter`]): each
    /// batch runs on whichever datapath currently scores fastest for
    /// its (Format, Rounding, batch-size) bucket, with epsilon-greedy
    /// exploration keeping both datapaths measured.
    Auto,
    /// Exactly-rounded digit recurrence (the gold reference) as a
    /// service backend — for routing/bit-identity tests.
    Gold,
    /// AOT artifact through PJRT (requires `make artifacts` and the
    /// `pjrt` feature). binary32 / NearestEven only.
    Pjrt,
}

impl BackendChoice {
    /// Reject configurations that could only fail later inside a worker
    /// thread; called by `DivisionService::start` alongside
    /// `ServiceConfig::validate`. Every rejection names the offending
    /// field — `order`, `tile`, `iterations`, or `simd` — so a bad
    /// `serve` invocation says what to change, not just that the config
    /// was rejected.
    pub fn validate(&self) -> Result<()> {
        match self {
            BackendChoice::Native { order, .. } | BackendChoice::NativeScalar { order, .. } => {
                // These backends resolve their lane engine as `Auto`,
                // which honors the TSDIV_SIMD process override —
                // pre-flight it here so `forced` on a host without a
                // vector engine rejects the service start instead of
                // killing every worker at build time (waiters would
                // hang on a service with zero workers).
                crate::simd::SimdChoice::Auto.validate()?;
                validate_order(*order)
            }
            BackendChoice::Kernel { order, kernel } => {
                kernel.validate()?;
                validate_order(*order)
            }
            BackendChoice::Goldschmidt {
                iterations,
                kernel,
                trunc_bits,
            } => {
                kernel.validate()?;
                validate_goldschmidt_iterations(*iterations)?;
                validate_goldschmidt_trunc_bits(*trunc_bits)
            }
            BackendChoice::Auto => {
                // The routed backend builds both datapaths with the
                // default kernel config; pre-flight the same engine
                // resolution so `TSDIV_SIMD=forced` on a host without a
                // vector engine rejects the start instead of killing
                // workers.
                KernelConfig::default().validate()
            }
            BackendChoice::Gold => Ok(()),
            BackendChoice::Pjrt => {
                // Same zero-worker-hang prevention as the SIMD
                // pre-flight: without artifacts every worker would die
                // at build time while the service reports a clean start.
                if !crate::runtime::artifacts_available() {
                    crate::bail!(
                        "backend config: pjrt requires built artifacts \
                         (run `make artifacts` and build with the `pjrt` feature)"
                    );
                }
                Ok(())
            }
        }
    }

    /// Instantiate inside the worker thread. The constructors themselves
    /// run every check [`BackendChoice::validate`] performs (validate is
    /// the cheap pre-flight for `DivisionService::start`; the
    /// constructors are authoritative), so a bad configuration errors on
    /// any path.
    pub fn build(&self) -> Result<Box<dyn Backend>> {
        match *self {
            BackendChoice::Native {
                order,
                ilm_iterations,
            } => Ok(Box::new(NativeBackend::new(order, ilm_iterations)?)),
            BackendChoice::NativeScalar {
                order,
                ilm_iterations,
            } => Ok(Box::new(ScalarNativeBackend::new(order, ilm_iterations)?)),
            BackendChoice::Kernel { order, kernel } => {
                Ok(Box::new(KernelBackend::new(order, kernel)?))
            }
            BackendChoice::Goldschmidt {
                iterations,
                kernel,
                trunc_bits,
            } => Ok(Box::new(GoldschmidtBackend::with_trunc(
                iterations, trunc_bits, kernel,
            )?)),
            // A standalone build gets a private router seeded from the
            // static cost model; the service instead constructs the
            // routed backend with one shared, history-seeded router so
            // every worker feeds the same table.
            BackendChoice::Auto => Ok(Box::new(RoutedBackend::new(Arc::new(
                BackendRouter::new(ROUTER_SEED),
            ))?)),
            BackendChoice::Gold => Ok(Box::new(GoldBackend::new())),
            BackendChoice::Pjrt => Ok(Box::new(PjrtBackend::load_default()?)),
        }
    }
}

/// Fixed RNG seed for routers the crate constructs itself (standalone
/// `Auto` builds and the service's shared router): exploration order is
/// reproducible run to run.
pub const ROUTER_SEED: u64 = 0x7510_0d17_5eed;

/// Goldschmidt refinement-round bound shared by
/// [`BackendChoice::validate`] (cheap pre-flight, no table build) and
/// [`GoldschmidtBackend::new`] (authoritative, via
/// [`GoldschmidtKernel::validate`]).
fn validate_goldschmidt_iterations(iterations: u32) -> Result<()> {
    if iterations == 0 || iterations > crate::kernel::goldschmidt::MAX_GOLDSCHMIDT_ITERATIONS {
        crate::bail!(
            "backend config: goldschmidt iterations must be 1..={}, got {iterations}",
            crate::kernel::goldschmidt::MAX_GOLDSCHMIDT_ITERATIONS
        );
    }
    Ok(())
}

/// Goldschmidt truncation bound shared by [`BackendChoice::validate`]
/// (cheap pre-flight) and [`GoldschmidtBackend::with_trunc`]
/// (authoritative, via [`GoldschmidtKernel::validate`] after the table
/// build): the paper's Q2.60 grid tolerates dropping at most half the
/// fraction bits per refinement product before the iterate diverges.
fn validate_goldschmidt_trunc_bits(trunc_bits: u32) -> Result<()> {
    // frac_bits/2 for the paper-default Q2.60 kernel every service
    // backend builds; GoldschmidtKernel::validate re-checks against the
    // actual frac_bits.
    const MAX_TRUNC_BITS: u32 = 30;
    if trunc_bits > MAX_TRUNC_BITS {
        crate::bail!(
            "backend config: goldschmidt trunc_bits must be 0..={MAX_TRUNC_BITS} \
             (half the Q2.60 fraction), got {trunc_bits}"
        );
    }
    Ok(())
}

/// The single authoritative Taylor-order bound for every native-family
/// backend: beyond [`crate::taylor::MAX_FAST_ORDER`] the hot path would
/// assert inside the worker. Shared by [`BackendChoice::validate`]
/// (cheap pre-flight, no table construction) and [`native_divider`]
/// (constructors are also reachable directly, bypassing the choice).
fn validate_order(order: u32) -> Result<()> {
    if order > crate::taylor::MAX_FAST_ORDER {
        crate::bail!(
            "backend config: Taylor order {order} exceeds the fast-path maximum {}",
            crate::taylor::MAX_FAST_ORDER
        );
    }
    Ok(())
}

/// Build the Taylor datapath for a worker backend through the fallible
/// construction chain (segment derivation → table build → lane-engine
/// selection), so a bad configuration is an error the service start
/// rejects, not a panic in a worker thread.
///
/// `simd` is the backend's engine choice: the Kernel backend passes its
/// explicit `KernelConfig::simd` (which ignores the env), the
/// Native/NativeScalar backends pass `Auto`, which honors the
/// process-wide `TSDIV_SIMD` override with its hard-error contract —
/// `forced` on a host without a vector engine fails construction (and,
/// via `BackendChoice::validate`, the service start) instead of
/// silently measuring the scalar engine.
fn native_divider(
    order: u32,
    ilm_iterations: Option<u32>,
    simd: crate::simd::SimdChoice,
) -> Result<TaylorDivider> {
    validate_order(order)?;
    let cfg = TaylorConfig {
        order,
        ..TaylorConfig::try_paper_default(60)?
    };
    let kind = match ilm_iterations {
        None => BackendKind::Exact,
        Some(iterations) => BackendKind::Ilm { iterations },
    };
    let mut divider = TaylorDivider::new(cfg, kind);
    divider.set_batch_simd(simd)?;
    Ok(divider)
}

/// The bit-exact Rust datapath as a service backend, dividing each
/// assembled batch with one `div_bits_batch` call over lanes grouped by
/// divisor.
pub struct NativeBackend {
    divider: TaylorDivider,
    // Scratch reused across batches (capacity warms up to the service's
    // max_batch and stays there — no steady-state allocation beyond the
    // response vector the Backend contract requires).
    perm: Vec<u32>,
    a_grouped: Vec<u64>,
    b_grouped: Vec<u64>,
    q_grouped: Vec<u64>,
}

impl NativeBackend {
    pub fn new(order: u32, ilm_iterations: Option<u32>) -> Result<Self> {
        Ok(Self {
            divider: native_divider(order, ilm_iterations, crate::simd::SimdChoice::Auto)?,
            perm: Vec::new(),
            a_grouped: Vec::new(),
            b_grouped: Vec::new(),
            q_grouped: Vec::new(),
        })
    }
}

/// Cheap repeat probe: pairwise-compare up to 32 evenly spaced divisors.
/// Repeated-divisor traffic (k-means counts, normalization constants)
/// has few distinct values, so a spaced sample finds a duplicate with
/// high probability; all-distinct traffic returns false and skips the
/// grouping sort. A false negative only costs cache hits, never
/// correctness.
fn probably_has_repeats(b: &[u64]) -> bool {
    let n = b.len();
    if n < 4 {
        return false;
    }
    let samples = n.min(32);
    let step = n / samples;
    let mut seen = [0u64; 32];
    let mut count = 0;
    for k in 0..samples {
        let x = b[k * step];
        if seen[..count].contains(&x) {
            return true;
        }
        seen[count] = x;
        count += 1;
    }
    false
}

impl Backend for NativeBackend {
    fn compute(
        &mut self,
        op: Op,
        a: &[u64],
        b: &[u64],
        _rows: &[u32],
        fmt: Format,
        rm: Rounding,
    ) -> Result<Vec<u64>> {
        if op != Op::Div {
            return Err(reject_non_div("native", op));
        }
        let n = a.len();
        // Group lanes by divisor bit pattern before dispatch so equal
        // divisors land adjacent and the divider's reciprocal cache hits
        // on every repeat (service traffic repeats divisors: k-means
        // centroid counts, normalization constants). Each lane's result
        // depends only on its own operands, so permuting and scattering
        // back is bit-identical to dividing in arrival order; the sort
        // costs one u64 key sort vs ~7 wide multiplies per cache miss.
        // All-distinct traffic (per the sampled probe) skips the sort.
        if !probably_has_repeats(b) {
            let mut out = vec![0u64; n];
            self.divider.div_bits_batch(a, b, fmt, rm, &mut out);
            return Ok(out);
        }
        self.perm.clear();
        self.perm.extend(0..n as u32);
        self.perm.sort_unstable_by_key(|&i| b[i as usize]);
        self.a_grouped.clear();
        self.a_grouped.extend(self.perm.iter().map(|&i| a[i as usize]));
        self.b_grouped.clear();
        self.b_grouped.extend(self.perm.iter().map(|&i| b[i as usize]));
        self.q_grouped.clear();
        self.q_grouped.resize(n, 0);
        self.divider.div_bits_batch(
            &self.a_grouped,
            &self.b_grouped,
            fmt,
            rm,
            &mut self.q_grouped,
        );
        let mut out = vec![0u64; n];
        for (k, &i) in self.perm.iter().enumerate() {
            out[i as usize] = self.q_grouped[k];
        }
        Ok(out)
    }

    fn describe(&self) -> String {
        format!("native[{}]", self.divider.name())
    }
}

/// The staged SoA kernel as a service backend: each assembled batch
/// runs one `kernel::divide_batch` pipeline (plan → seed → power →
/// mul_round in `KernelConfig::tile`-lane tiles). Unlike
/// [`NativeBackend`] there is no divisor-grouping permutation — the
/// kernel's own 8-way reciprocal cache captures repeated divisors, and
/// lanes stay in arrival order throughout.
pub struct KernelBackend {
    divider: TaylorDivider,
    cfg: KernelConfig,
}

impl KernelBackend {
    pub fn new(order: u32, cfg: KernelConfig) -> Result<Self> {
        cfg.validate()?;
        // The explicit config choice goes straight into the divider —
        // a pinned `Scalar` kernel stays scalar even under
        // TSDIV_SIMD=forced (only `Auto` defers to the env).
        let mut divider = native_divider(order, cfg.ilm_iterations, cfg.simd)?;
        divider.set_batch_tile(cfg.tile);
        Ok(Self { divider, cfg })
    }

    /// The kernel configuration this backend was built with.
    pub fn config(&self) -> KernelConfig {
        self.cfg
    }
}

impl Backend for KernelBackend {
    fn compute(
        &mut self,
        op: Op,
        a: &[u64],
        b: &[u64],
        rows: &[u32],
        fmt: Format,
        rm: Rounding,
    ) -> Result<Vec<u64>> {
        let mut out = vec![0u64; a.len()];
        self.divider.compute_bits_batch(op, a, b, rows, fmt, rm, &mut out);
        Ok(out)
    }

    fn describe(&self) -> String {
        format!(
            "kernel[tile={}, simd={}, {}]",
            self.cfg.tile,
            self.divider.batch_engine().name(),
            self.divider.name()
        )
    }
}

/// The batched Goldschmidt iterate datapath as a service backend: each
/// assembled batch runs one [`GoldschmidtKernel::divide_batch`]
/// pipeline (plan → seed → iterate → round) over the same
/// [`KernelScratch`] SoA buffers and lane engine the Taylor kernel
/// uses. The `ilm_iterations` knob of [`KernelConfig`] is ignored —
/// Goldschmidt refinement multiplies are exact wide products (its
/// hardware-reduction knob is the kernel's `trunc_bits`, pinned to 0
/// for the bit-exact service path).
pub struct GoldschmidtBackend {
    kernel: GoldschmidtKernel,
    scratch: KernelScratch,
    eng: crate::simd::Engine,
    cfg: KernelConfig,
}

impl GoldschmidtBackend {
    /// Bit-exact refinement products (`trunc_bits = 0`), the service
    /// default.
    pub fn new(iterations: u32, cfg: KernelConfig) -> Result<Self> {
        Self::with_trunc(iterations, 0, cfg)
    }

    /// Goldschmidt datapath with `trunc_bits` low bits dropped per
    /// refinement multiply — the paper's truncated-multiplier study.
    /// `GoldschmidtKernel::validate` is the authoritative bound check
    /// (against the built table's actual fraction width).
    pub fn with_trunc(iterations: u32, trunc_bits: u32, cfg: KernelConfig) -> Result<Self> {
        cfg.validate()?;
        validate_goldschmidt_iterations(iterations)?;
        let mut kernel = GoldschmidtKernel::paper_default(iterations)?;
        kernel.trunc_bits = trunc_bits;
        kernel.validate()?;
        Ok(Self {
            kernel,
            scratch: KernelScratch::new(),
            // Explicit config choice, same contract as KernelBackend:
            // a pinned `Scalar` stays scalar under TSDIV_SIMD=forced.
            eng: cfg.simd.resolve()?,
            cfg,
        })
    }

    /// The kernel configuration this backend was built with.
    pub fn config(&self) -> KernelConfig {
        self.cfg
    }
}

impl Backend for GoldschmidtBackend {
    fn compute(
        &mut self,
        op: Op,
        a: &[u64],
        b: &[u64],
        rows: &[u32],
        fmt: Format,
        rm: Rounding,
    ) -> Result<Vec<u64>> {
        let mut out = vec![0u64; a.len()];
        self.kernel.compute_batch(
            &mut self.scratch,
            self.cfg.tile,
            self.eng,
            op,
            a,
            b,
            rows,
            fmt,
            rm,
            &mut out,
        );
        Ok(out)
    }

    fn describe(&self) -> String {
        format!(
            "goldschmidt[k={}, tile={}, simd={}, trunc={}]",
            self.kernel.iterations,
            self.cfg.tile,
            self.eng.name(),
            self.kernel.trunc_bits
        )
    }
}

/// Adaptive dispatch between the two kernel datapaths
/// (`BackendChoice::Auto`): every batch asks the shared
/// [`BackendRouter`] which datapath currently scores fastest for its
/// (Format, Rounding, batch-size) bucket, runs it, and reports the
/// measured wall latency back. Both inner backends are built with the
/// default kernel config, so any response is bit-identical to what the
/// corresponding fixed `BackendChoice::Kernel`/`Goldschmidt` service
/// would have produced — routing changes *when* a datapath runs, never
/// what it computes.
pub struct RoutedBackend {
    router: Arc<BackendRouter>,
    kernel: KernelBackend,
    goldschmidt: GoldschmidtBackend,
}

impl RoutedBackend {
    /// Routed backend over a shared router handle (the service passes
    /// one history-seeded router to every worker).
    pub fn new(router: Arc<BackendRouter>) -> Result<Self> {
        Ok(Self {
            router,
            kernel: KernelBackend::new(5, KernelConfig::default())?,
            goldschmidt: GoldschmidtBackend::new(3, KernelConfig::default())?,
        })
    }
}

impl Backend for RoutedBackend {
    fn compute(
        &mut self,
        op: Op,
        a: &[u64],
        b: &[u64],
        rows: &[u32],
        fmt: Format,
        rm: Rounding,
    ) -> Result<Vec<u64>> {
        let pick = self.router.pick(op, fmt, rm, a.len());
        let start = Instant::now();
        let out = match pick {
            Candidate::Kernel => self.kernel.compute(op, a, b, rows, fmt, rm),
            Candidate::Goldschmidt => self.goldschmidt.compute(op, a, b, rows, fmt, rm),
        }?;
        self.router.observe(op, fmt, rm, a.len(), pick, start.elapsed());
        Ok(out)
    }

    fn describe(&self) -> String {
        format!(
            "auto[{} | {}]",
            self.kernel.describe(),
            self.goldschmidt.describe()
        )
    }
}

/// The pre-batching worker loop: one scalar `div_bits` call per lane.
pub struct ScalarNativeBackend {
    divider: TaylorDivider,
}

impl ScalarNativeBackend {
    pub fn new(order: u32, ilm_iterations: Option<u32>) -> Result<Self> {
        Ok(Self {
            divider: native_divider(order, ilm_iterations, crate::simd::SimdChoice::Auto)?,
        })
    }
}

impl Backend for ScalarNativeBackend {
    fn compute(
        &mut self,
        op: Op,
        a: &[u64],
        b: &[u64],
        _rows: &[u32],
        fmt: Format,
        rm: Rounding,
    ) -> Result<Vec<u64>> {
        if op != Op::Div {
            return Err(reject_non_div("native-scalar", op));
        }
        Ok(a.iter()
            .zip(b)
            .map(|(&x, &y)| self.divider.div_bits(x, y, fmt, rm))
            .collect())
    }

    fn describe(&self) -> String {
        format!("native-scalar[{}]", self.divider.name())
    }
}

/// The exactly-rounded digit-recurrence reference as a backend.
pub struct GoldBackend {
    divider: LongDivider,
}

impl GoldBackend {
    pub fn new() -> Self {
        Self {
            divider: LongDivider::new(),
        }
    }
}

impl Default for GoldBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl Backend for GoldBackend {
    fn compute(
        &mut self,
        op: Op,
        a: &[u64],
        b: &[u64],
        rows: &[u32],
        fmt: Format,
        rm: Rounding,
    ) -> Result<Vec<u64>> {
        let mut out = vec![0u64; a.len()];
        match op {
            Op::Div => self.divider.div_bits_batch(a, b, fmt, rm, &mut out),
            Op::Recip => {
                for (o, &x) in out.iter_mut().zip(a) {
                    *o = self.divider.recip_bits(x, fmt, rm);
                }
            }
            Op::Rsqrt => {
                for (o, &x) in out.iter_mut().zip(a) {
                    *o = self.divider.rsqrt_bits(x, fmt, rm);
                }
            }
            Op::ScaleByRecip => {
                // One exactly-rounded division per lane against the
                // row's shared divisor — the reference semantics the
                // fused kernels' single-reciprocal tails approximate.
                let mut lane = 0usize;
                for (r, &len) in rows.iter().enumerate() {
                    for _ in 0..len {
                        out[lane] = self.divider.div_bits(a[lane], b[r], fmt, rm);
                        lane += 1;
                    }
                }
            }
        }
        Ok(out)
    }

    fn describe(&self) -> String {
        format!("gold[{}]", self.divider.name())
    }
}

/// The PJRT artifact as a service backend.
pub struct PjrtBackend {
    engine: crate::runtime::DivideEngine,
}

impl PjrtBackend {
    pub fn load_default() -> Result<Self> {
        Ok(Self {
            engine: crate::runtime::DivideEngine::load_default()?,
        })
    }
}

impl Backend for PjrtBackend {
    fn compute(
        &mut self,
        op: Op,
        a: &[u64],
        b: &[u64],
        _rows: &[u32],
        fmt: Format,
        rm: Rounding,
    ) -> Result<Vec<u64>> {
        if op != Op::Div {
            return Err(reject_non_div("pjrt", op));
        }
        if fmt != F32 || rm != Rounding::NearestEven {
            crate::bail!(
                "pjrt backend serves f32/nearest only (got {}/{})",
                fmt.name(),
                rm.name()
            );
        }
        let af: Vec<f32> = a.iter().map(|&x| f32::from_bits(x as u32)).collect();
        let bf: Vec<f32> = b.iter().map(|&x| f32::from_bits(x as u32)).collect();
        let q = self.engine.divide(&af, &bf)?;
        Ok(q.iter().map(|&x| x.to_bits() as u64).collect())
    }

    fn describe(&self) -> String {
        format!(
            "pjrt[{} batches {:?}]",
            self.engine.platform(),
            self.engine.batch_sizes()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp::{BF16, F16, F64};

    fn bits32(xs: &[f32]) -> Vec<u64> {
        xs.iter().map(|&x| x.to_bits() as u64).collect()
    }

    #[test]
    fn native_backend_divides() {
        let mut be = NativeBackend::new(5, None).unwrap();
        let out = be
            .divide(
                &bits32(&[6.0, 1.0, -8.0]),
                &bits32(&[2.0, 4.0, 2.0]),
                F32,
                Rounding::NearestEven,
            )
            .unwrap();
        assert_eq!(out, bits32(&[3.0, 0.25, -4.0]));
        assert!(be.describe().starts_with("native["));
    }

    #[test]
    fn native_backend_with_ilm_budget() {
        let mut be = NativeBackend::new(5, Some(8)).unwrap();
        let out = be
            .divide(&bits32(&[10.0]), &bits32(&[5.0]), F32, Rounding::NearestEven)
            .unwrap();
        assert!((f32::from_bits(out[0] as u32) - 2.0).abs() < 1e-6);
    }

    #[test]
    fn native_backend_serves_all_four_formats() {
        let mut be = NativeBackend::new(5, None).unwrap();
        // 6.0 / 2.0 = 3.0 in each format's own encoding.
        for (fmt, a, b, want) in [
            (F16, 0x4600u64, 0x4000, 0x4200),
            (BF16, 0x40C0, 0x4000, 0x4040),
            (F32, 0x40C0_0000, 0x4000_0000, 0x4040_0000),
            (F64, 0x4018_0000_0000_0000, 0x4000_0000_0000_0000, 0x4008_0000_0000_0000),
        ] {
            let q = be.divide(&[a], &[b], fmt, Rounding::NearestEven).unwrap();
            assert_eq!(q, vec![want], "{}", fmt.name());
        }
    }

    #[test]
    fn choice_builds_native() {
        let be = BackendChoice::Native {
            order: 3,
            ilm_iterations: Some(4),
        }
        .build()
        .unwrap();
        assert!(be.describe().contains("ilm4"));
    }

    #[test]
    fn choice_builds_native_scalar_and_gold() {
        let mut be = BackendChoice::NativeScalar {
            order: 5,
            ilm_iterations: None,
        }
        .build()
        .unwrap();
        assert!(be.describe().starts_with("native-scalar["));
        assert_eq!(
            be.divide(&bits32(&[9.0]), &bits32(&[3.0]), F32, Rounding::NearestEven)
                .unwrap(),
            bits32(&[3.0])
        );
        let mut gold = BackendChoice::Gold.build().unwrap();
        assert!(gold.describe().starts_with("gold["));
        assert_eq!(
            gold.divide(&bits32(&[9.0]), &bits32(&[3.0]), F32, Rounding::NearestEven)
                .unwrap(),
            bits32(&[3.0])
        );
    }

    #[test]
    fn kernel_backend_divides_and_describes() {
        let mut be = KernelBackend::new(5, KernelConfig::default()).unwrap();
        let out = be
            .divide(
                &bits32(&[6.0, 1.0, -8.0]),
                &bits32(&[2.0, 4.0, 2.0]),
                F32,
                Rounding::NearestEven,
            )
            .unwrap();
        assert_eq!(out, bits32(&[3.0, 0.25, -4.0]));
        assert!(be.describe().starts_with("kernel[tile=8"));
        assert_eq!(be.config().tile, 8);
    }

    #[test]
    fn kernel_choice_builds_and_validates() {
        let good = BackendChoice::Kernel {
            order: 5,
            kernel: KernelConfig {
                tile: 4,
                ilm_iterations: Some(6),
                ..KernelConfig::default()
            },
        };
        assert!(good.validate().is_ok());
        let be = good.build().unwrap();
        assert!(be.describe().contains("tile=4"));
        assert!(be.describe().contains("ilm6"));
        assert!(be.describe().contains("simd="));
        let bad = BackendChoice::Kernel {
            order: 5,
            kernel: KernelConfig {
                tile: 0,
                ilm_iterations: None,
                ..KernelConfig::default()
            },
        };
        assert!(bad.validate().is_err());
        assert!(bad.build().is_err());
    }

    #[test]
    fn oversized_taylor_order_rejected_not_panicking() {
        // Orders beyond the fast-path schedule used to assert inside the
        // worker thread; now every native-family choice rejects them at
        // validate/build time.
        let order = crate::taylor::MAX_FAST_ORDER + 1;
        for choice in [
            BackendChoice::Native {
                order,
                ilm_iterations: None,
            },
            BackendChoice::NativeScalar {
                order,
                ilm_iterations: None,
            },
            BackendChoice::Kernel {
                order,
                kernel: KernelConfig::default(),
            },
        ] {
            assert!(choice.validate().is_err(), "{choice:?}");
            assert!(choice.build().is_err(), "{choice:?}");
        }
    }

    #[test]
    fn forced_simd_kernel_choice_follows_host_capability() {
        use crate::simd::{simd_available, SimdChoice};
        let forced = BackendChoice::Kernel {
            order: 5,
            kernel: KernelConfig {
                simd: SimdChoice::Forced,
                ..KernelConfig::default()
            },
        };
        assert_eq!(forced.validate().is_ok(), simd_available());
        assert_eq!(forced.build().is_ok(), simd_available());
        // The pinned-scalar engine builds everywhere and says so.
        let scalar = KernelBackend::new(
            5,
            KernelConfig {
                simd: SimdChoice::Scalar,
                ..KernelConfig::default()
            },
        )
        .unwrap();
        assert!(scalar.describe().contains("simd=scalar"), "{}", scalar.describe());
    }

    #[test]
    fn kernel_backend_bit_identical_to_native_and_scalar_backends() {
        // Same operands through all three native datapaths — arrival
        // order, grouping order and tile width must not change a bit.
        let a = bits32(&[6.0, -1.5, f32::NAN, 0.0, f32::INFINITY, 1.0e-40, 355.0, -0.0, 9.0]);
        let b = bits32(&[2.0, 3.0, 2.0, 3.0, 2.0, 3.0, 113.0, 2.0, 3.0]);
        for tile in [1usize, 3, 8] {
            let mut kern = KernelBackend::new(
                5,
                KernelConfig {
                    tile,
                    ilm_iterations: None,
                    ..KernelConfig::default()
                },
            )
            .unwrap();
            let mut native = NativeBackend::new(5, None).unwrap();
            let mut scalar = ScalarNativeBackend::new(5, None).unwrap();
            for rm in Rounding::ALL {
                let qk = kern.divide(&a, &b, F32, rm).unwrap();
                let qn = native.divide(&a, &b, F32, rm).unwrap();
                let qs = scalar.divide(&a, &b, F32, rm).unwrap();
                assert_eq!(qk, qs, "kernel vs scalar, tile={tile} {rm:?}");
                assert_eq!(qn, qs, "native vs scalar, tile={tile} {rm:?}");
            }
        }
    }

    #[test]
    fn divisor_grouping_bit_identical_to_scalar_backend() {
        let mut batched = NativeBackend::new(5, None).unwrap();
        let mut scalar = ScalarNativeBackend::new(5, None).unwrap();
        // Interleaved repeated divisors: grouping reorders internally,
        // results must still come back in lane order, bit for bit.
        let a = bits32(&[6.0, -1.5, f32::NAN, 0.0, f32::INFINITY, 1.0e-40, 355.0, -0.0]);
        let b = bits32(&[2.0, 3.0, 2.0, 3.0, 2.0, 3.0, 113.0, 2.0]);
        for rm in Rounding::ALL {
            let qb = batched.divide(&a, &b, F32, rm).unwrap();
            let qs = scalar.divide(&a, &b, F32, rm).unwrap();
            assert_eq!(qb, qs, "{rm:?}");
        }
        // Buffers are reused: a second, differently-sized batch works too.
        let q = batched
            .divide(&bits32(&[8.0, 4.0]), &bits32(&[2.0, 2.0]), F32, Rounding::NearestEven)
            .unwrap();
        assert_eq!(q, bits32(&[4.0, 2.0]));
    }

    #[test]
    fn repeat_probe_finds_repeats_and_clears_distinct() {
        assert!(!probably_has_repeats(&[1, 1])); // below probe threshold
        let distinct: Vec<u64> = (0..4096).map(|i| i * 7 + 3).collect();
        assert!(!probably_has_repeats(&distinct));
        let repeated: Vec<u64> = (0..4096u64).map(|i| i % 6).collect();
        assert!(probably_has_repeats(&repeated));
    }

    #[test]
    fn goldschmidt_backend_divides_and_describes() {
        let mut be = GoldschmidtBackend::new(3, KernelConfig::default()).unwrap();
        let out = be
            .divide(
                &bits32(&[6.0, 1.0, -8.0]),
                &bits32(&[2.0, 4.0, 2.0]),
                F32,
                Rounding::NearestEven,
            )
            .unwrap();
        assert_eq!(out, bits32(&[3.0, 0.25, -4.0]));
        assert!(be.describe().starts_with("goldschmidt[k=3"));
        assert_eq!(be.config().tile, 8);
    }

    #[test]
    fn goldschmidt_choice_builds_and_matches_direct_backend() {
        let choice = BackendChoice::Goldschmidt {
            iterations: 3,
            kernel: KernelConfig::default(),
            trunc_bits: 0,
        };
        assert!(choice.validate().is_ok());
        let mut via_choice = choice.build().unwrap();
        let mut direct = GoldschmidtBackend::new(3, KernelConfig::default()).unwrap();
        let a = bits32(&[6.0, -1.5, f32::NAN, 0.0, f32::INFINITY, 1.0e-40, 355.0, -0.0, 9.0]);
        let b = bits32(&[2.0, 3.0, 2.0, 3.0, 2.0, 3.0, 113.0, 2.0, 3.0]);
        for rm in Rounding::ALL {
            assert_eq!(
                via_choice.divide(&a, &b, F32, rm).unwrap(),
                direct.divide(&a, &b, F32, rm).unwrap(),
                "{rm:?}"
            );
        }
    }

    #[test]
    fn validate_names_the_failing_field_per_arm() {
        // order
        let err = BackendChoice::Native {
            order: crate::taylor::MAX_FAST_ORDER + 1,
            ilm_iterations: None,
        }
        .validate()
        .unwrap_err()
        .to_string();
        assert!(err.contains("order"), "{err}");
        // tile
        let err = BackendChoice::Kernel {
            order: 5,
            kernel: KernelConfig {
                tile: 0,
                ilm_iterations: None,
                ..KernelConfig::default()
            },
        }
        .validate()
        .unwrap_err()
        .to_string();
        assert!(err.contains("tile"), "{err}");
        // goldschmidt iterations (both ends of the range)
        for iterations in [0, crate::kernel::goldschmidt::MAX_GOLDSCHMIDT_ITERATIONS + 1] {
            let err = BackendChoice::Goldschmidt {
                iterations,
                kernel: KernelConfig::default(),
                trunc_bits: 0,
            }
            .validate()
            .unwrap_err()
            .to_string();
            assert!(err.contains("iterations"), "{err}");
            assert!(
                BackendChoice::Goldschmidt {
                    iterations,
                    kernel: KernelConfig::default(),
                    trunc_bits: 0,
                }
                .build()
                .is_err()
            );
        }
        // trunc_bits (beyond half the Q2.60 fraction)
        let over_trunc = BackendChoice::Goldschmidt {
            iterations: 3,
            kernel: KernelConfig::default(),
            trunc_bits: 31,
        };
        let err = over_trunc.validate().unwrap_err().to_string();
        assert!(err.contains("trunc_bits"), "{err}");
        assert!(over_trunc.build().is_err());
        // simd (only diagnosable on hosts where `forced` cannot resolve)
        if !crate::simd::simd_available() {
            let err = BackendChoice::Goldschmidt {
                iterations: 3,
                kernel: KernelConfig {
                    simd: crate::simd::SimdChoice::Forced,
                    ..KernelConfig::default()
                },
                trunc_bits: 0,
            }
            .validate()
            .unwrap_err()
            .to_string();
            assert!(err.contains("simd"), "{err}");
            // The rejection must name what this architecture is
            // actually missing (AVX-512/AVX2 on x86_64, NEON on
            // aarch64) — not hard-code any single extension.
            assert!(
                err.contains(crate::simd::forced_requirement()),
                "error '{err}' must quote '{}'",
                crate::simd::forced_requirement()
            );
        }
    }

    #[test]
    fn auto_choice_validates_and_builds_a_routed_backend() {
        let choice = BackendChoice::Auto;
        assert!(choice.validate().is_ok());
        let mut be = choice.build().unwrap();
        assert!(be.describe().starts_with("auto["), "{}", be.describe());
        let a = bits32(&[6.0, 1.0, -8.0, f32::NAN]);
        let b = bits32(&[2.0, 4.0, 2.0, 2.0]);
        // Whatever the router picks, the response must equal one of the
        // two fixed datapaths' outputs (here they agree exactly).
        let out = be.divide(&a, &b, F32, Rounding::NearestEven).unwrap();
        let mut kern = KernelBackend::new(5, KernelConfig::default()).unwrap();
        assert_eq!(out, kern.divide(&a, &b, F32, Rounding::NearestEven).unwrap());
    }

    #[test]
    fn routed_backend_responses_always_match_a_fixed_datapath() {
        use crate::harness::gen_bits_batch;
        let router = Arc::new(BackendRouter::new(42));
        let mut routed = RoutedBackend::new(router.clone()).unwrap();
        let mut kern = KernelBackend::new(5, KernelConfig::default()).unwrap();
        let mut gold = GoldschmidtBackend::new(3, KernelConfig::default()).unwrap();
        for (rep, &fmt) in [F16, BF16, F32, F64].iter().enumerate() {
            for rm in Rounding::ALL {
                let (a, b) = gen_bits_batch(fmt, 57, 8, 0xA5A5 + rep as u64);
                let out = routed.divide(&a, &b, fmt, rm).unwrap();
                let qk = kern.divide(&a, &b, fmt, rm).unwrap();
                let qg = gold.divide(&a, &b, fmt, rm).unwrap();
                assert!(
                    out == qk || out == qg,
                    "routed response matches neither datapath ({}/{:?})",
                    fmt.name(),
                    rm
                );
            }
        }
        // Both datapaths got exercised... or at least every dispatch is
        // accounted for by the two counters.
        let total = router.dispatches(crate::router::Candidate::Kernel)
            + router.dispatches(crate::router::Candidate::Goldschmidt);
        assert_eq!(total, 4 * Rounding::ALL.len() as u64);
    }

    #[test]
    fn division_only_backends_reject_other_ops_by_name() {
        let xs = bits32(&[2.0, 4.0]);
        let mut native = NativeBackend::new(5, None).unwrap();
        let mut scalar = ScalarNativeBackend::new(5, None).unwrap();
        for op in [Op::Recip, Op::Rsqrt, Op::ScaleByRecip] {
            for be in [&mut native as &mut dyn Backend, &mut scalar] {
                let err = be
                    .compute(op, &xs, &[], &[], F32, Rounding::NearestEven)
                    .unwrap_err()
                    .to_string();
                assert!(err.contains("div only"), "{err}");
                assert!(err.contains(op.name()), "{err}");
            }
        }
        // The division shorthand still works through the same trait.
        let q = native
            .divide(&bits32(&[6.0]), &bits32(&[2.0]), F32, Rounding::NearestEven)
            .unwrap();
        assert_eq!(q, bits32(&[3.0]));
    }

    #[test]
    fn kernel_and_goldschmidt_recip_matches_divide_by_one() {
        // Recip is the Div datapath with the dividend pinned to 1.0 —
        // on both kernels that must be bit-identical, not just close.
        let xs = bits32(&[3.0, -7.0, 0.1, f32::NAN, 0.0, f32::INFINITY, 1.0e-40, 113.0]);
        let ones = bits32(&[1.0; 8]);
        let mut kern = KernelBackend::new(5, KernelConfig::default()).unwrap();
        let mut gsch = GoldschmidtBackend::new(3, KernelConfig::default()).unwrap();
        for rm in Rounding::ALL {
            for be in [&mut kern as &mut dyn Backend, &mut gsch] {
                let recip = be.compute(Op::Recip, &xs, &[], &[], F32, rm).unwrap();
                let div = be.divide(&ones, &xs, F32, rm).unwrap();
                assert_eq!(recip, div, "{} {rm:?}", be.describe());
            }
        }
    }

    #[test]
    fn gold_backend_serves_every_op() {
        let mut gold = GoldBackend::new();
        let xs = bits32(&[4.0, 2.0, -9.0, 0.25]);
        let recip = gold
            .compute(Op::Recip, &xs, &[], &[], F32, Rounding::NearestEven)
            .unwrap();
        assert_eq!(recip, bits32(&[0.25, 0.5, -1.0 / 9.0, 4.0]));
        let rsqrt = gold
            .compute(Op::Rsqrt, &bits32(&[4.0, 0.25]), &[], &[], F32, Rounding::NearestEven)
            .unwrap();
        assert_eq!(rsqrt, bits32(&[0.5, 2.0]));
        // ScaleByRecip: rows of unequal length, each against its own
        // divisor, results in lane order.
        let a = bits32(&[6.0, 9.0, 12.0, 5.0, 8.0]);
        let b = bits32(&[3.0, 0.5]);
        let out = gold
            .compute(Op::ScaleByRecip, &a, &b, &[3, 2], F32, Rounding::NearestEven)
            .unwrap();
        assert_eq!(out, bits32(&[2.0, 3.0, 4.0, 10.0, 16.0]));
    }

    #[test]
    fn goldschmidt_trunc_backend_builds_and_stays_within_a_ulp() {
        let mut trunc = GoldschmidtBackend::with_trunc(3, 8, KernelConfig::default()).unwrap();
        assert!(trunc.describe().contains("trunc=8"), "{}", trunc.describe());
        let mut exact = GoldschmidtBackend::new(3, KernelConfig::default()).unwrap();
        let a = bits32(&[6.0, -1.5, f32::NAN, 0.0, f32::INFINITY, 1.0e-40, 355.0, -0.0, 9.0]);
        let b = bits32(&[2.0, 3.0, 2.0, 3.0, 2.0, 3.0, 113.0, 2.0, 3.0]);
        for rm in Rounding::ALL {
            let qt = trunc.divide(&a, &b, F32, rm).unwrap();
            let qe = exact.divide(&a, &b, F32, rm).unwrap();
            for (j, (&t, &e)) in qt.iter().zip(&qe).enumerate() {
                match crate::fp::ulp_diff(t, e, F32) {
                    // Dropping 8 of 60 fraction bits per refinement
                    // product perturbs the Q2.60 iterate far below
                    // binary32 rounding granularity.
                    Some(u) => assert!(u <= 1, "lane {j} {rm:?}: {u} ulp"),
                    None => assert_eq!(t, e, "lane {j} {rm:?}"),
                }
            }
        }
        // Beyond the kernel's own bound the authoritative check fires.
        assert!(GoldschmidtBackend::with_trunc(3, 31, KernelConfig::default()).is_err());
    }
}
