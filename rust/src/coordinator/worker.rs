//! Worker backends: the computation a worker thread runs per batch.
//!
//! Three implementations:
//! * [`NativeBackend`] — the bit-exact Rust Taylor/ILM datapath driven
//!   through the **batched** entry point
//!   ([`crate::divider::Divider::div_bits_batch`]): one backend borrow,
//!   hoisted per-op checks and a divisor-reciprocal cache per batch,
//!   with packing buffers reused across batches;
//! * [`ScalarNativeBackend`] — the same datapath one lane at a time (the
//!   pre-batching worker loop), kept as the baseline the coordinator
//!   bench compares against;
//! * [`PjrtBackend`] — the AOT-compiled JAX/Pallas artifact executed via
//!   PJRT ([`crate::runtime::DivideEngine`], `pjrt` feature).
//!
//! Backends are created *inside* each worker thread by a factory (PJRT
//! handles are not `Send`), so [`BackendChoice`] is the serializable
//! configuration and [`Backend`] the per-thread instance.

use crate::divider::{BackendKind, Divider, TaylorDivider};
use crate::fp::{F32, Rounding};
use crate::taylor::TaylorConfig;
use crate::util::error::Result;

/// What a worker does with one flattened batch.
pub trait Backend {
    fn divide_batch(&mut self, a: &[f32], b: &[f32]) -> Result<Vec<f32>>;
    fn describe(&self) -> String;
}

/// Serializable backend configuration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendChoice {
    /// Bit-exact Rust datapath through `div_bits_batch` (Taylor order,
    /// optional ILM budget — `None` = exact multiplies).
    Native {
        order: u32,
        ilm_iterations: Option<u32>,
    },
    /// The same datapath through the scalar `div_bits` loop — the
    /// pre-batching baseline, kept for batch-vs-scalar comparisons.
    NativeScalar {
        order: u32,
        ilm_iterations: Option<u32>,
    },
    /// AOT artifact through PJRT (requires `make artifacts` and the
    /// `pjrt` feature).
    Pjrt,
}

impl BackendChoice {
    /// Instantiate inside the worker thread.
    pub fn build(&self) -> Result<Box<dyn Backend>> {
        match *self {
            BackendChoice::Native {
                order,
                ilm_iterations,
            } => Ok(Box::new(NativeBackend::new(order, ilm_iterations))),
            BackendChoice::NativeScalar {
                order,
                ilm_iterations,
            } => Ok(Box::new(ScalarNativeBackend::new(order, ilm_iterations))),
            BackendChoice::Pjrt => Ok(Box::new(PjrtBackend::load_default()?)),
        }
    }
}

fn native_divider(order: u32, ilm_iterations: Option<u32>) -> TaylorDivider {
    let cfg = TaylorConfig {
        order,
        ..TaylorConfig::paper_default(60)
    };
    let kind = match ilm_iterations {
        None => BackendKind::Exact,
        Some(iterations) => BackendKind::Ilm { iterations },
    };
    TaylorDivider::new(cfg, kind)
}

/// The bit-exact Rust datapath as a service backend, dividing each
/// assembled batch with one `div_bits_batch` call.
pub struct NativeBackend {
    divider: TaylorDivider,
    // Packing buffers reused across batches (capacity warms up to the
    // service's max_batch and stays there — no steady-state allocation
    // beyond the response vector the Backend contract requires).
    a_bits: Vec<u64>,
    b_bits: Vec<u64>,
    q_bits: Vec<u64>,
}

impl NativeBackend {
    pub fn new(order: u32, ilm_iterations: Option<u32>) -> Self {
        Self {
            divider: native_divider(order, ilm_iterations),
            a_bits: Vec::new(),
            b_bits: Vec::new(),
            q_bits: Vec::new(),
        }
    }
}

impl Backend for NativeBackend {
    fn divide_batch(&mut self, a: &[f32], b: &[f32]) -> Result<Vec<f32>> {
        self.a_bits.clear();
        self.a_bits.extend(a.iter().map(|&x| x.to_bits() as u64));
        self.b_bits.clear();
        self.b_bits.extend(b.iter().map(|&x| x.to_bits() as u64));
        self.q_bits.clear();
        self.q_bits.resize(a.len(), 0);
        self.divider.div_bits_batch(
            &self.a_bits,
            &self.b_bits,
            F32,
            Rounding::NearestEven,
            &mut self.q_bits,
        );
        Ok(self.q_bits.iter().map(|&q| f32::from_bits(q as u32)).collect())
    }

    fn describe(&self) -> String {
        format!("native[{}]", self.divider.name())
    }
}

/// The pre-batching worker loop: one scalar `div_bits` call per lane.
pub struct ScalarNativeBackend {
    divider: TaylorDivider,
}

impl ScalarNativeBackend {
    pub fn new(order: u32, ilm_iterations: Option<u32>) -> Self {
        Self {
            divider: native_divider(order, ilm_iterations),
        }
    }
}

impl Backend for ScalarNativeBackend {
    fn divide_batch(&mut self, a: &[f32], b: &[f32]) -> Result<Vec<f32>> {
        Ok(a.iter()
            .zip(b)
            .map(|(&x, &y)| self.divider.div_f32(x, y))
            .collect())
    }

    fn describe(&self) -> String {
        format!("native-scalar[{}]", self.divider.name())
    }
}

/// The PJRT artifact as a service backend.
pub struct PjrtBackend {
    engine: crate::runtime::DivideEngine,
}

impl PjrtBackend {
    pub fn load_default() -> Result<Self> {
        Ok(Self {
            engine: crate::runtime::DivideEngine::load_default()?,
        })
    }
}

impl Backend for PjrtBackend {
    fn divide_batch(&mut self, a: &[f32], b: &[f32]) -> Result<Vec<f32>> {
        self.engine.divide(a, b)
    }

    fn describe(&self) -> String {
        format!(
            "pjrt[{} batches {:?}]",
            self.engine.platform(),
            self.engine.batch_sizes()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_backend_divides() {
        let mut be = NativeBackend::new(5, None);
        let out = be
            .divide_batch(&[6.0, 1.0, -8.0], &[2.0, 4.0, 2.0])
            .unwrap();
        assert_eq!(out, vec![3.0, 0.25, -4.0]);
        assert!(be.describe().starts_with("native["));
    }

    #[test]
    fn native_backend_with_ilm_budget() {
        let mut be = NativeBackend::new(5, Some(8));
        let out = be.divide_batch(&[10.0], &[5.0]).unwrap();
        assert!((out[0] - 2.0).abs() < 1e-6);
    }

    #[test]
    fn choice_builds_native() {
        let be = BackendChoice::Native {
            order: 3,
            ilm_iterations: Some(4),
        }
        .build()
        .unwrap();
        assert!(be.describe().contains("ilm4"));
    }

    #[test]
    fn choice_builds_native_scalar() {
        let mut be = BackendChoice::NativeScalar {
            order: 5,
            ilm_iterations: None,
        }
        .build()
        .unwrap();
        assert!(be.describe().starts_with("native-scalar["));
        assert_eq!(be.divide_batch(&[9.0], &[3.0]).unwrap(), vec![3.0]);
    }

    #[test]
    fn batched_backend_bit_identical_to_scalar_backend() {
        let mut batched = NativeBackend::new(5, None);
        let mut scalar = ScalarNativeBackend::new(5, None);
        let a = vec![
            6.0f32,
            -1.5,
            f32::NAN,
            0.0,
            f32::INFINITY,
            1.0e-40,
            355.0,
            -0.0,
        ];
        let b = vec![2.0f32, 3.0, 1.0, 0.0, 2.0, 2.0, 113.0, 5.0];
        let qb = batched.divide_batch(&a, &b).unwrap();
        let qs = scalar.divide_batch(&a, &b).unwrap();
        assert_eq!(qb.len(), qs.len());
        for i in 0..qb.len() {
            assert_eq!(qb[i].to_bits(), qs[i].to_bits(), "lane {i}");
        }
        // Buffers are reused: a second, differently-sized batch works too.
        let q = batched.divide_batch(&[8.0, 4.0], &[2.0, 2.0]).unwrap();
        assert_eq!(q, vec![4.0, 2.0]);
    }
}
