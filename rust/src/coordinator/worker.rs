//! Worker backends: the computation a worker thread runs per batch.
//!
//! Two implementations:
//! * [`NativeBackend`] — the bit-exact Rust Taylor/ILM datapath
//!   ([`crate::divider::TaylorDivider`]);
//! * [`PjrtBackend`] — the AOT-compiled JAX/Pallas artifact executed via
//!   PJRT ([`crate::runtime::DivideEngine`]).
//!
//! Backends are created *inside* each worker thread by a factory (PJRT
//! handles are not `Send`), so [`BackendChoice`] is the serializable
//! configuration and [`Backend`] the per-thread instance.

use anyhow::Result;

use crate::divider::{BackendKind, Divider, TaylorDivider};
use crate::taylor::TaylorConfig;

/// What a worker does with one flattened batch.
pub trait Backend {
    fn divide_batch(&mut self, a: &[f32], b: &[f32]) -> Result<Vec<f32>>;
    fn describe(&self) -> String;
}

/// Serializable backend configuration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendChoice {
    /// Bit-exact Rust datapath (Taylor order, optional ILM budget —
    /// `None` = exact multiplies).
    Native {
        order: u32,
        ilm_iterations: Option<u32>,
    },
    /// AOT artifact through PJRT (requires `make artifacts`).
    Pjrt,
}

impl BackendChoice {
    /// Instantiate inside the worker thread.
    pub fn build(&self) -> Result<Box<dyn Backend>> {
        match *self {
            BackendChoice::Native {
                order,
                ilm_iterations,
            } => Ok(Box::new(NativeBackend::new(order, ilm_iterations))),
            BackendChoice::Pjrt => Ok(Box::new(PjrtBackend::load_default()?)),
        }
    }
}

/// The bit-exact Rust datapath as a service backend.
pub struct NativeBackend {
    divider: TaylorDivider,
}

impl NativeBackend {
    pub fn new(order: u32, ilm_iterations: Option<u32>) -> Self {
        let cfg = TaylorConfig {
            order,
            ..TaylorConfig::paper_default(60)
        };
        let kind = match ilm_iterations {
            None => BackendKind::Exact,
            Some(iterations) => BackendKind::Ilm { iterations },
        };
        Self {
            divider: TaylorDivider::new(cfg, kind),
        }
    }
}

impl Backend for NativeBackend {
    fn divide_batch(&mut self, a: &[f32], b: &[f32]) -> Result<Vec<f32>> {
        Ok(a.iter()
            .zip(b)
            .map(|(&x, &y)| self.divider.div_f32(x, y))
            .collect())
    }

    fn describe(&self) -> String {
        format!("native[{}]", self.divider.name())
    }
}

/// The PJRT artifact as a service backend.
pub struct PjrtBackend {
    engine: crate::runtime::DivideEngine,
}

impl PjrtBackend {
    pub fn load_default() -> Result<Self> {
        Ok(Self {
            engine: crate::runtime::DivideEngine::load_default()?,
        })
    }
}

impl Backend for PjrtBackend {
    fn divide_batch(&mut self, a: &[f32], b: &[f32]) -> Result<Vec<f32>> {
        self.engine.divide(a, b)
    }

    fn describe(&self) -> String {
        format!(
            "pjrt[{} batches {:?}]",
            self.engine.platform(),
            self.engine.batch_sizes()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_backend_divides() {
        let mut be = NativeBackend::new(5, None);
        let out = be
            .divide_batch(&[6.0, 1.0, -8.0], &[2.0, 4.0, 2.0])
            .unwrap();
        assert_eq!(out, vec![3.0, 0.25, -4.0]);
        assert!(be.describe().starts_with("native["));
    }

    #[test]
    fn native_backend_with_ilm_budget() {
        let mut be = NativeBackend::new(5, Some(8));
        let out = be.divide_batch(&[10.0], &[5.0]).unwrap();
        assert!((out[0] - 2.0).abs() < 1e-6);
    }

    #[test]
    fn choice_builds_native() {
        let be = BackendChoice::Native {
            order: 3,
            ilm_iterations: Some(4),
        }
        .build()
        .unwrap();
        assert!(be.describe().contains("ilm4"));
    }
}
