//! Piecewise-linear initial approximation of `1/x` (paper §3).
//!
//! The Taylor-series engine needs a seed `y0 ≈ 1/x`; the paper derives it
//! from a piecewise-linear fit over the IEEE significand range `[1, 2)`:
//!
//! * eq (13): pointwise error of the tangent-at-`p` line,
//!   `E(x) = 1/x + x/p² − 2/p`;
//! * eq (14): total error over `[a,b]`,
//!   `E_total = ln(b/a) + (b²−a²)/(2p²) − 2(b−a)/p`, minimized at
//!   `p = (a+b)/2`;
//! * eq (15): the optimal line `y0 = −4x/(a+b)² + 4/(a+b)`;
//! * eq (16): `m(x) = 1 − x·y0` — algebraically `(1 − 2x/(a+b))²`,
//!   so `m ∈ [0, ((b−a)/(a+b))²]` with the maximum at both endpoints;
//! * eq (17): Taylor error bound
//!   `E_n ≤ ((a+b)²/(4ab))^(n+2) · m_max^(n+1)`;
//! * eq (19)/(20): the segment-boundary recurrence solved (here by
//!   bisection in the log domain) to regenerate **Table I**.
//!
//! [`table`] holds the fixed-point seed-table hardware model.

pub mod table;

pub use table::SegmentTable;

use crate::bail;
use crate::util::error::Result;

/// Paper Table I: the published segment boundaries for n = 5 and 53-bit
/// precision, used by benches to compare derived vs published values.
pub const PAPER_TABLE_I: [f64; 8] = [
    1.09811, 1.20835, 1.3269, 1.45709, 1.59866, 1.75616, 1.92922, 2.12392,
];

/// Pointwise error of the tangent-at-`p` linear approximation (eq 13).
pub fn pointwise_error(x: f64, p: f64) -> f64 {
    1.0 / x + x / (p * p) - 2.0 / p
}

/// Total (integrated) error over `[a,b]` for slope parameter `p` (eq 14).
pub fn total_error(a: f64, b: f64, p: f64) -> f64 {
    (b / a).ln() + (b * b - a * a) / (2.0 * p * p) - 2.0 * (b - a) / p
}

/// The `p` minimizing eq (14): `p = (a+b)/2`.
pub fn optimal_p(a: f64, b: f64) -> f64 {
    (a + b) / 2.0
}

/// The optimal linear approximation of `1/x` on `[a,b]` (eq 15),
/// returned as `(slope, intercept)` with `y0 = slope·x + intercept`
/// (slope is negative).
pub fn optimal_line(a: f64, b: f64) -> (f64, f64) {
    let s = a + b;
    (-4.0 / (s * s), 4.0 / s)
}

/// `y0(x)` for the optimal line on `[a,b]`.
pub fn y0(x: f64, a: f64, b: f64) -> f64 {
    let (slope, intercept) = optimal_line(a, b);
    slope * x + intercept
}

/// `m(x, a, b) = 1 − x·y0(x)` (eq 16). Algebraically `(1 − 2x/(a+b))²`.
pub fn m_value(x: f64, a: f64, b: f64) -> f64 {
    let t = 1.0 - 2.0 * x / (a + b);
    t * t
}

/// Maximum of `m` over the segment: attained at both endpoints,
/// `m_max = ((b−a)/(a+b))²`.
pub fn m_max(a: f64, b: f64) -> f64 {
    let t = (b - a) / (a + b);
    t * t
}

/// The eq-(17) Taylor-error bound after `n` iterations on segment `[a,b]`
/// with the optimal line, in log2 (the quantities underflow f64 quickly):
/// `log2 E_n ≤ (n+2)·log2((a+b)²/(4ab)) + (n+1)·log2(m_max)`.
pub fn error_bound_log2(a: f64, b: f64, n: u32) -> f64 {
    let xi_factor = (a + b) * (a + b) / (4.0 * a * b);
    let mm = m_max(a, b);
    if mm == 0.0 {
        return f64::NEG_INFINITY;
    }
    (n as f64 + 2.0) * xi_factor.log2() + (n as f64 + 1.0) * mm.log2()
}

/// Left-hand side of the boundary recurrence (eq 19/20) in log2:
/// `log2[(a+b)²·(b−a)^(2n+2) / (4ab)^(n+2)]`. Identical to
/// [`error_bound_log2`] — eq (19) is eq (17) with `m_max` substituted.
pub fn segment_bound_log2(a: f64, b: f64, n: u32) -> f64 {
    error_bound_log2(a, b, n)
}

/// Solve eq (20) for the next boundary: the largest `b > a` with
/// `segment_bound(a, b, n) ≤ 2^(−pr_max)`. Bisection in the log domain;
/// the bound is strictly increasing in `b` on `(a, ∞)`.
///
/// A bracket failure (pathological `a`/`n`/`pr_max` combination) is an
/// error, not a panic: this runs during table construction, which the
/// division service performs at start-up — a bad configuration must be
/// a rejected request, not a process abort.
pub fn solve_next_boundary(a: f64, n: u32, pr_max: u32) -> Result<f64> {
    let target = -(pr_max as f64);
    // Bracket: bound → −∞ as b→a⁺; grows without limit as b→∞.
    let mut lo = a * (1.0 + 1e-15);
    let mut hi = a * 2.0;
    while segment_bound_log2(a, hi, n) < target {
        hi *= 2.0;
        if hi >= a * 1e6 {
            bail!(
                "segment boundary solve failed to bracket from a={a} \
                 (n={n}, pr_max={pr_max})"
            );
        }
    }
    for _ in 0..200 {
        let mid = 0.5 * (lo + hi);
        if segment_bound_log2(a, mid, n) <= target {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    // Return the inner point: the bound is guaranteed ≤ target there.
    Ok(lo)
}

/// Derive the full segment partition of `[1, 2]` for a given iteration
/// budget `n` and precision target (paper §3 procedure; Table I is
/// `derive_segments(5, 53)`). Returns the boundaries
/// `[1, b0, b1, …, b_k]` with the last `≥ 2`, or an error when the
/// recurrence fails to cover the range (see [`solve_next_boundary`]).
pub fn derive_segments(n: u32, pr_max: u32) -> Result<Vec<f64>> {
    let mut bounds = vec![1.0];
    let mut a = 1.0;
    loop {
        let b = solve_next_boundary(a, n, pr_max)?;
        bounds.push(b);
        if b >= 2.0 {
            return Ok(bounds);
        }
        if bounds.len() >= 1024 {
            bail!(
                "segment derivation diverged: 1024 boundaries without covering [1,2] \
                 (n={n}, pr_max={pr_max})"
            );
        }
        a = b;
    }
}

/// Minimum Taylor iterations `n` so that the eq-(17) bound on `[a,b]`
/// is at most `2^(−pr_max)` (paper §3: 17 for `[1,2]`, 5 for Table I).
///
/// Non-convergence within 1 000 iterations (an unsatisfiable precision
/// target, e.g. a degenerate segment) is an error the caller can
/// surface — this is reachable from `TaylorConfig`/table construction at
/// service start, where it used to abort the process.
pub fn min_iterations(a: f64, b: f64, pr_max: u32) -> Result<u32> {
    let target = -(pr_max as f64);
    for n in 0..=1_000 {
        if error_bound_log2(a, b, n) <= target {
            return Ok(n);
        }
    }
    bail!("min_iterations did not converge for [{a}, {b}] at 2^-{pr_max}")
}

/// Minimum iterations for a piecewise partition: the worst segment rules
/// (paper §3, "account for the maximum error").
pub fn min_iterations_piecewise(bounds: &[f64], pr_max: u32) -> Result<u32> {
    if bounds.len() < 2 {
        bail!("piecewise partition needs at least two boundaries");
    }
    let mut worst = 0;
    for w in bounds.windows(2) {
        worst = worst.max(min_iterations(w[0], w[1], pr_max)?);
    }
    Ok(worst)
}

/// The two-segment split with equal per-segment total error: `p = √(ab)`
/// (paper §3). For `[1,2]` this is `√2`.
pub fn equal_error_split(a: f64, b: f64) -> f64 {
    (a * b).sqrt()
}

/// Find the segment index for `x` in a boundary list (first segment whose
/// right edge is ≥ x). Mirrors the hardware compare tree.
pub fn segment_index(bounds: &[f64], x: f64) -> usize {
    debug_assert!(bounds.len() >= 2);
    // Mutation smoke: flip the left-closed boundary to right-closed.
    #[cfg(any(test, feature = "mutation"))]
    let right_closed = crate::verify::mutation::is_active(
        crate::verify::mutation::Mutant::SegmentBoundaryOffByOne,
    );
    #[cfg(not(any(test, feature = "mutation")))]
    let right_closed = false;
    for (i, w) in bounds.windows(2).enumerate() {
        if x < w[1] || (right_closed && x <= w[1]) {
            return i;
        }
    }
    bounds.len() - 2
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::check_that;
    use crate::util::check::{forall, Config};

    #[test]
    fn optimal_p_minimizes_total_error() {
        let (a, b) = (1.0, 2.0);
        let p_opt = optimal_p(a, b);
        let e_opt = total_error(a, b, p_opt);
        for p in [1.2, 1.4, 1.45, 1.55, 1.6, 1.8] {
            assert!(
                total_error(a, b, p) >= e_opt - 1e-12,
                "p={p} beats the optimum"
            );
        }
    }

    #[test]
    fn pointwise_error_zero_at_tangent_touch() {
        // The tangent-at-p line touches 1/x at x=p.
        let p = 1.5;
        assert!(pointwise_error(p, p).abs() < 1e-15);
        assert!(pointwise_error(1.0, p) > 0.0);
        assert!(pointwise_error(2.0, p) > 0.0);
    }

    #[test]
    fn m_closed_form_matches_definition() {
        forall(Config::named("m = 1 − x·y0").cases(300), |d| {
            let a = d.f64_range(1.0, 1.9);
            let b = a + d.f64_range(0.01, 0.5);
            let x = d.f64_range(a, b);
            let m1 = 1.0 - x * y0(x, a, b);
            let m2 = m_value(x, a, b);
            check_that!((m1 - m2).abs() < 1e-12, "mismatch {m1} vs {m2}");
            check_that!(m2 >= 0.0, "m negative");
            Ok(())
        });
    }

    #[test]
    fn m_max_at_endpoints() {
        let (a, b) = (1.0, 2.0);
        let mm = m_max(a, b);
        assert!((m_value(a, a, b) - mm).abs() < 1e-15);
        assert!((m_value(b, a, b) - mm).abs() < 1e-15);
        // Paper: for [1,2], m_max = 1/9 and ξ factor = 9/8.
        assert!((mm - 1.0 / 9.0).abs() < 1e-15);
        // Interior is strictly smaller; zero at the midpoint.
        assert!(m_value(1.5, a, b) < 1e-30);
        assert!(m_value(1.2, a, b) < mm);
    }

    #[test]
    fn unsatisfiable_precision_targets_error_instead_of_panicking() {
        // A precision target the iteration bound can never reach within
        // the solver budget must come back as an Err (the service
        // surfaces it as a rejected configuration), not a panic.
        let e = min_iterations(1.0, 2.0, 10_000).unwrap_err();
        assert!(e.to_string().contains("did not converge"), "{e}");
        assert!(min_iterations_piecewise(&[1.0, 1.5, 2.0], 10_000).is_err());
        assert!(min_iterations_piecewise(&[1.0], 53).is_err());
    }

    #[test]
    fn paper_17_iterations_single_segment() {
        // §3: one linear segment on [1,2] needs a maximum of 17 iterations
        // for 53 bits.
        assert_eq!(min_iterations(1.0, 2.0, 53).unwrap(), 17);
    }

    #[test]
    fn paper_5_iterations_with_table_i_segments() {
        let bounds = derive_segments(5, 53).unwrap();
        assert_eq!(min_iterations_piecewise(&bounds, 53).unwrap(), 5);
    }

    #[test]
    fn table_i_reproduced() {
        // §3 / Table I: n = 5, 53-bit target, 8 segments.
        let bounds = derive_segments(5, 53).unwrap();
        assert_eq!(bounds.len(), 9, "1 start + 8 boundaries");
        // b0 solves eq (19) exactly and matches to all published digits.
        let rel0 = ((bounds[1] - PAPER_TABLE_I[0]) / PAPER_TABLE_I[0]).abs();
        assert!(rel0 < 5e-5, "b0: derived {:.6} vs paper (rel {rel0:.2e})", bounds[1]);
        // Eq (20) is scale-invariant (bound depends only on b/a), so the
        // exact recurrence is geometric with ratio b0. The paper's later
        // entries drift from their own recurrence by up to ~0.4 % — we
        // compare loosely and flag the drift in the E1 bench (DESIGN.md).
        for (i, (&ours, paper)) in bounds[1..].iter().zip(PAPER_TABLE_I).enumerate() {
            let rel = ((ours - paper) / paper).abs();
            assert!(
                rel < 5e-3,
                "b{i}: derived {ours:.6} vs paper {paper} (rel {rel:.2e})"
            );
        }
        // And our derivation IS self-consistent: constant ratio b0.
        let r0 = bounds[1] / bounds[0];
        for w in bounds.windows(2) {
            assert!(((w[1] / w[0]) / r0 - 1.0).abs() < 1e-9, "not geometric");
        }
    }

    #[test]
    fn two_segment_split_point() {
        assert!((equal_error_split(1.0, 2.0) - 2f64.sqrt()).abs() < 1e-15);
        // E_total is NOT exactly equal at p=√(ab) for the optimal
        // per-segment lines (the paper's equal-error argument is about the
        // shared-endpoint construction); just sanity-check both positive.
        let p = equal_error_split(1.0, 2.0);
        let e1 = total_error(1.0, p, optimal_p(1.0, p));
        let e2 = total_error(p, 2.0, optimal_p(p, 2.0));
        assert!(e1 > 0.0 && e2 > 0.0);
    }

    #[test]
    fn two_segment_iteration_count_documented_discrepancy() {
        // The paper claims 15 iterations for the two-segment √(ab) split.
        // Our eq-(17) solver gives a *smaller* bound; record the actual
        // value so the bench can flag the mismatch (see DESIGN.md E5).
        let p = equal_error_split(1.0, 2.0);
        let n = min_iterations(1.0, p, 53).unwrap().max(min_iterations(p, 2.0, 53).unwrap());
        assert!(n < 15, "expected < 15 by eq (17), got {n}");
        assert!(n >= 9, "sanity: still ≥ 9, got {n}");
    }

    #[test]
    fn segments_shrink_monotonically() {
        // E_total is larger on the left of the range (paper §3), so
        // derived segments get *wider* to the right but their bound stays
        // equal; widths must increase.
        let bounds = derive_segments(5, 53).unwrap();
        let widths: Vec<f64> = bounds.windows(2).map(|w| w[1] - w[0]).collect();
        for w in widths.windows(2) {
            assert!(w[1] > w[0], "segment widths should increase: {widths:?}");
        }
    }

    #[test]
    fn more_iterations_need_fewer_segments() {
        let s3 = derive_segments(3, 53).unwrap().len();
        let s5 = derive_segments(5, 53).unwrap().len();
        let s8 = derive_segments(8, 53).unwrap().len();
        assert!(s3 > s5 && s5 > s8, "{s3} {s5} {s8}");
    }

    #[test]
    fn bound_monotone_in_b() {
        forall(Config::named("eq 19 bound increases with b").cases(200), |d| {
            let a = d.f64_range(1.0, 1.8);
            let b1 = a + d.f64_range(1e-4, 0.2);
            let b2 = b1 + d.f64_range(1e-4, 0.2);
            let n = d.range_u64(1, 10) as u32;
            check_that!(
                segment_bound_log2(a, b1, n) < segment_bound_log2(a, b2, n),
                "bound not monotone"
            );
            Ok(())
        });
    }

    #[test]
    fn solver_hits_target_bound() {
        for n in [3u32, 5, 7] {
            let b = solve_next_boundary(1.0, n, 53).unwrap();
            let lhs = segment_bound_log2(1.0, b, n);
            assert!(
                (lhs - (-53.0)).abs() < 1e-6,
                "n={n}: bound at solution {lhs} ≠ −53"
            );
        }
    }

    #[test]
    fn segment_index_lookup() {
        let bounds = [1.0, 1.25, 1.5, 2.0];
        assert_eq!(segment_index(&bounds, 1.0), 0);
        assert_eq!(segment_index(&bounds, 1.1), 0);
        assert_eq!(segment_index(&bounds, 1.25), 1);
        assert_eq!(segment_index(&bounds, 1.49), 1);
        assert_eq!(segment_index(&bounds, 1.75), 2);
        assert_eq!(segment_index(&bounds, 1.9999), 2);
        // Values at/above the last edge clamp to the last segment.
        assert_eq!(segment_index(&bounds, 2.5), 2);
    }

    #[test]
    fn error_bound_log2_matches_linear_domain_for_moderate_n() {
        let (a, b, n) = (1.0f64, 1.2f64, 3u32);
        let xi = (a + b) * (a + b) / (4.0 * a * b);
        let lin = xi.powi(n as i32 + 2) * m_max(a, b).powi(n as i32 + 1);
        assert!((error_bound_log2(a, b, n) - lin.log2()).abs() < 1e-9);
    }
}
