//! Fixed-point seed table — the hardware model of the PLA unit (Fig 7,
//! "Piecewise Linear Approximation unit").
//!
//! Per segment `[bᵢ₋₁, bᵢ]` the unit stores the optimal line of eq (15)
//! as a positive slope magnitude `sᵢ = 4/(a+b)²` and intercept
//! `cᵢ = 4/(a+b)` in Q2.F fixed point. A compare tree selects the
//! segment; one multiply and one subtract produce the seed:
//! `y0 = cᵢ − sᵢ·x` (truncating fixed-point arithmetic, like the
//! datapath).

use super::optimal_line;
use crate::simd::Engine;
use crate::util::error::Result;

/// Fixed-point piecewise-linear seed table.
#[derive(Clone, Debug)]
pub struct SegmentTable {
    /// Fraction bits of every entry (Q2.F).
    pub frac_bits: u32,
    /// Segment right edges in fixed point (left edge of segment 0 is 1.0).
    /// Length = number of segments; the last edge covers up to 2.0+.
    pub edges: Vec<u64>,
    /// Per-segment slope magnitudes `4/(a+b)²` in Q2.F.
    pub slopes: Vec<u64>,
    /// Per-segment intercepts `4/(a+b)` in Q2.F.
    pub intercepts: Vec<u64>,
    /// The float boundaries the table was built from (for reports).
    pub boundaries: Vec<f64>,
}

impl SegmentTable {
    /// Build from boundary list `[1, b0, …, bk]` (see
    /// [`super::derive_segments`]) at `frac_bits` of fraction.
    ///
    /// Panics on an invalid boundary list or width; configuration paths
    /// that must reject bad input instead of aborting (service start)
    /// use [`Self::try_build`].
    pub fn build(boundaries: &[f64], frac_bits: u32) -> Self {
        Self::try_build(boundaries, frac_bits).expect("segment table")
    }

    /// Fallible [`Self::build`]: a bad boundary list or datapath width
    /// is an error the caller can surface (the service rejects the
    /// config at `DivisionService::start`) rather than a process abort.
    pub fn try_build(boundaries: &[f64], frac_bits: u32) -> Result<Self> {
        if boundaries.len() < 2 {
            crate::bail!("segment table: need at least one segment");
        }
        if frac_bits > 61 {
            crate::bail!("segment table: Q2.{frac_bits} must fit in u64 (frac_bits ≤ 61)");
        }
        if (boundaries[0] - 1.0).abs() >= 1e-12 {
            crate::bail!(
                "segment table: range starts at 1.0, got {}",
                boundaries[0]
            );
        }
        let scale = (1u128 << frac_bits) as f64;
        let mut edges = Vec::new();
        let mut slopes = Vec::new();
        let mut intercepts = Vec::new();
        for w in boundaries.windows(2) {
            let (a, b) = (w[0], w[1]);
            let (slope, intercept) = optimal_line(a, b);
            edges.push((b * scale) as u64);
            // Slope is negative in eq (15); store |slope|.
            slopes.push((-slope * scale).round() as u64);
            intercepts.push((intercept * scale).round() as u64);
        }
        Ok(Self {
            frac_bits,
            edges,
            slopes,
            intercepts,
            boundaries: boundaries.to_vec(),
        })
    }

    pub fn num_segments(&self) -> usize {
        self.edges.len()
    }

    /// Segment select: the compare tree of the hardware. `x` in Q2.F.
    #[inline]
    pub fn select(&self, x: u64) -> usize {
        // Linear scan mirrors a priority chain; the hot path uses a
        // branch-free binary search (see `select_fast`).
        for (i, &e) in self.edges.iter().enumerate() {
            if x < e {
                return i;
            }
        }
        self.edges.len() - 1
    }

    /// Branch-reduced binary-search select (hot-path variant; identical
    /// result to [`Self::select`]).
    #[inline]
    pub fn select_fast(&self, x: u64) -> usize {
        let mut lo = 0usize;
        let mut len = self.edges.len();
        while len > 1 {
            let half = len / 2;
            let mid = lo + half;
            // Move lo past the first half when x is at/above its edge.
            if x >= self.edges[mid - 1] {
                lo = mid;
            }
            len -= half;
        }
        lo
    }

    /// The seed `y0 = c − s·x` in Q2.F with truncating arithmetic.
    /// Returns `(y0, segment_index)`.
    #[inline]
    pub fn seed(&self, x: u64) -> (u64, usize) {
        let i = self.select_fast(x);
        let prod = (self.slopes[i] as u128 * x as u128) >> self.frac_bits;
        let y0 = self.intercepts[i].saturating_sub(prod as u64);
        (y0, i)
    }

    /// Seed stage over a lane array: `y0_out[i] = seed(xs[i]).0` — the
    /// staged kernel's SoA entry point ([`crate::kernel`]), expressed on
    /// the explicit lane engine ([`crate::simd`]). Per stack-buffered
    /// chunk: the compare tree runs as an edge-count pass (identical to
    /// the scalar `select`, see [`Engine::segment_counts`]), the line
    /// coefficients are gathered per lane, and the truncating multiply
    /// plus saturating subtract of [`Self::seed`] run as one engine op
    /// each — bit-identical to the scalar seed, lane by lane.
    pub fn seed_batch(&self, eng: Engine, xs: &[u64], y0_out: &mut [u64]) {
        // Allocation-free (the edge staging happens inside each
        // `segment_counts` call); callers with a reusable
        // [`crate::simd::BiasedEdges`] use [`Self::seed_batch_with`]
        // to hoist that staging out of the per-tile loop.
        debug_assert_eq!(xs.len(), y0_out.len());
        const W: usize = 32;
        let mut idx = [0u64; W];
        let mut slope = [0u64; W];
        let mut icpt = [0u64; W];
        let mut prod = [0u64; W];
        let mut done = 0;
        while done < xs.len() {
            let n = (xs.len() - done).min(W);
            let xc = &xs[done..done + n];
            eng.segment_counts(xc, &self.edges, &mut idx[..n]);
            for ((&s, sl), ic) in idx[..n].iter().zip(&mut slope[..n]).zip(&mut icpt[..n]) {
                *sl = self.slopes[s as usize];
                *ic = self.intercepts[s as usize];
            }
            // y0 = c ⊖ ((s·x) >> F): the same truncating multiply and
            // saturating subtract as the scalar seed().
            eng.mul_shr(&slope[..n], xc, self.frac_bits, &mut prod[..n]);
            eng.sub_sat(&icpt[..n], &prod[..n], &mut y0_out[done..done + n]);
            done += n;
        }
    }

    /// [`Self::seed_batch`] with the compare-tree edge staging hoisted
    /// into a caller-owned [`crate::simd::BiasedEdges`] cache (built
    /// from **this** table's edges): the kernel builds the cache once
    /// per `divide_batch` call and reuses it across every seed tile,
    /// instead of re-staging the edges inside each `segment_counts`
    /// call. Bit-identical to the uncached path on every engine.
    pub fn seed_batch_with(
        &self,
        eng: Engine,
        edge_cache: &crate::simd::BiasedEdges,
        xs: &[u64],
        y0_out: &mut [u64],
    ) {
        debug_assert_eq!(xs.len(), y0_out.len());
        debug_assert!(
            edge_cache.matches(&self.edges),
            "edge cache built from a different segment table"
        );
        const W: usize = 32;
        let mut idx = [0u64; W];
        let mut slope = [0u64; W];
        let mut icpt = [0u64; W];
        let mut prod = [0u64; W];
        let mut done = 0;
        while done < xs.len() {
            let n = (xs.len() - done).min(W);
            let xc = &xs[done..done + n];
            eng.segment_counts_cached(xc, edge_cache, &mut idx[..n]);
            for ((&s, sl), ic) in idx[..n].iter().zip(&mut slope[..n]).zip(&mut icpt[..n]) {
                *sl = self.slopes[s as usize];
                *ic = self.intercepts[s as usize];
            }
            // y0 = c ⊖ ((s·x) >> F): the same truncating multiply and
            // saturating subtract as the scalar seed().
            eng.mul_shr(&slope[..n], xc, self.frac_bits, &mut prod[..n]);
            eng.sub_sat(&icpt[..n], &prod[..n], &mut y0_out[done..done + n]);
            done += n;
        }
    }

    /// Float view of the seed for analysis.
    pub fn seed_f64(&self, x: f64) -> f64 {
        let scale = (1u128 << self.frac_bits) as f64;
        let xf = (x * scale) as u64;
        let (y0, _) = self.seed(xf);
        y0 as f64 / scale
    }

    /// ROM size of the table in bits (edges + slopes + intercepts), for
    /// the hardware cost model.
    pub fn rom_bits(&self) -> u64 {
        let w = (self.frac_bits + 2) as u64; // Q2.F words
        3 * w * self.num_segments() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::super::{derive_segments, m_value, y0};
    use super::*;
    use crate::check_that;
    use crate::util::check::{forall, Config};

    const F: u32 = 40;

    fn fx(x: f64) -> u64 {
        (x * (1u64 << F) as f64).round() as u64
    }

    fn table() -> SegmentTable {
        SegmentTable::build(&derive_segments(5, 53).unwrap(), F)
    }

    #[test]
    fn build_has_one_entry_per_segment() {
        let t = table();
        assert_eq!(t.num_segments(), 8);
        assert_eq!(t.slopes.len(), 8);
        assert_eq!(t.intercepts.len(), 8);
        assert_eq!(t.rom_bits(), 3 * 42 * 8);
    }

    #[test]
    fn select_matches_float_boundaries() {
        let t = table();
        for (i, w) in t.boundaries.windows(2).enumerate() {
            let mid = 0.5 * (w[0] + w[1]);
            assert_eq!(t.select(fx(mid)), i, "midpoint of segment {i}");
        }
        // x = 1.0 is in segment 0; x just below the last edge in the last.
        assert_eq!(t.select(fx(1.0)), 0);
        assert_eq!(t.select(fx(1.9999)), t.num_segments() - 1);
    }

    #[test]
    fn select_fast_equals_select_everywhere() {
        let t = table();
        forall(Config::named("select_fast == select").cases(2000), |d| {
            let x = d.range_u64(fx(1.0), fx(2.0) - 1);
            check_that!(
                t.select_fast(x) == t.select(x),
                "mismatch at x={x}: fast {} vs ref {}",
                t.select_fast(x),
                t.select(x)
            );
            Ok(())
        });
    }

    #[test]
    fn seed_close_to_analytic_line() {
        let t = table();
        forall(Config::named("fixed-point seed ≈ eq 15").cases(500), |d| {
            let x = d.f64_range(1.0, 1.999_999);
            let i = crate::pla::segment_index(&t.boundaries, x);
            let (a, b) = (t.boundaries[i], t.boundaries[i + 1]);
            let want = y0(x, a, b);
            let got = t.seed_f64(x);
            // Two truncations of F-bit values → error ≤ ~3 ulp of Q2.F.
            let tol = 4.0 / (1u64 << F) as f64;
            check_that!((got - want).abs() <= tol, "x={x}: {got} vs {want}");
            Ok(())
        });
    }

    #[test]
    fn seed_error_within_segment_bound() {
        // The seed's m = 1 − x·y0 never exceeds the analytic m_max by more
        // than the fixed-point tolerance.
        let t = table();
        forall(Config::named("seed m within m_max").cases(500), |d| {
            let x = d.f64_range(1.0, 1.999_999);
            let i = crate::pla::segment_index(&t.boundaries, x);
            let (a, b) = (t.boundaries[i], t.boundaries[i + 1]);
            let y = t.seed_f64(x);
            let m = 1.0 - x * y;
            let tol = 8.0 / (1u64 << F) as f64;
            check_that!(
                m <= crate::pla::m_max(a, b) + tol,
                "x={x}: m={m} exceeds bound"
            );
            // m may dip below 0 by at most the truncation tolerance.
            check_that!(m >= -tol, "x={x}: m={m} < −tol");
            let _ = m_value(x, a, b);
            Ok(())
        });
    }

    #[test]
    fn seed_is_monotone_nonincreasing_within_segment() {
        // y0 is a falling line per segment; fixed-point evaluation must
        // preserve that (truncation is monotone).
        let t = table();
        let bounds = t.boundaries.clone();
        for w in bounds.windows(2) {
            let lo = fx(w[0]);
            let hi = fx(w[1].min(2.0)) - 1;
            let mut last = u64::MAX;
            let step = ((hi - lo) / 97).max(1);
            let mut x = lo;
            while x <= hi {
                let (y, _) = t.seed(x);
                assert!(y <= last, "seed rose within a segment at x={x}");
                last = y;
                x += step;
            }
        }
    }

    #[test]
    fn seed_batch_matches_scalar_seed_every_engine() {
        // 257 lanes: not a multiple of the chunk width or the vector
        // width, so tails are exercised; both engines must equal the
        // scalar seed() bit for bit.
        let t = table();
        let xs: Vec<u64> = (0..257)
            .map(|i| fx(1.0) + i * ((fx(2.0) - fx(1.0)) / 257))
            .collect();
        for eng in crate::simd::engines_available() {
            let mut ys = vec![0u64; xs.len()];
            t.seed_batch(eng, &xs, &mut ys);
            for (i, &x) in xs.iter().enumerate() {
                assert_eq!(ys[i], t.seed(x).0, "{} lane {i}", eng.name());
            }
        }
    }

    #[test]
    fn seed_batch_with_shared_cache_matches_uncached_every_engine() {
        // One cache, many seed calls (the per-divide_batch shape): the
        // cached path must equal both the uncached batch path and the
        // scalar seed(), bit for bit, on every engine.
        let t = table();
        let mut cache = crate::simd::BiasedEdges::new();
        cache.rebuild(&t.edges);
        let xs: Vec<u64> = (0..143)
            .map(|i| fx(1.0) + i * ((fx(2.0) - fx(1.0)) / 143) + 17)
            .map(|x| x.min(fx(2.0) - 1))
            .collect();
        for eng in crate::simd::engines_available() {
            let mut plain = vec![0u64; xs.len()];
            t.seed_batch(eng, &xs, &mut plain);
            let mut cached = vec![0u64; xs.len()];
            // Several tile-sized calls sharing the one cache.
            for chunk in [8usize, 3, 64] {
                let mut done = 0;
                while done < xs.len() {
                    let n = (xs.len() - done).min(chunk);
                    let dst = &mut cached[done..done + n];
                    t.seed_batch_with(eng, &cache, &xs[done..done + n], dst);
                    done += n;
                }
                assert_eq!(cached, plain, "{} chunk={chunk}", eng.name());
            }
            for (i, &x) in xs.iter().enumerate() {
                assert_eq!(cached[i], t.seed(x).0, "{} lane {i}", eng.name());
            }
        }
    }

    #[test]
    fn try_build_rejects_bad_configs_with_errors() {
        assert!(SegmentTable::try_build(&[1.0], F).is_err());
        assert!(SegmentTable::try_build(&[1.0, 2.0], 62).is_err());
        assert!(SegmentTable::try_build(&[1.5, 2.0], F).is_err());
        assert!(SegmentTable::try_build(&[1.0, 2.0], F).is_ok());
    }

    #[test]
    fn single_segment_table_matches_eq15_line() {
        let t = SegmentTable::build(&[1.0, 2.0], F);
        assert_eq!(t.num_segments(), 1);
        // slope 4/9, intercept 4/3 for [1,2]
        let scale = (1u64 << F) as f64;
        assert!((t.slopes[0] as f64 / scale - 4.0 / 9.0).abs() < 1e-9);
        assert!((t.intercepts[0] as f64 / scale - 4.0 / 3.0).abs() < 1e-9);
        // Seed at x=1: y0 = 8/9.
        assert!((t.seed_f64(1.0) - 8.0 / 9.0).abs() < 1e-9);
    }

    #[test]
    #[should_panic(expected = "need at least one segment")]
    fn build_rejects_empty() {
        let _ = SegmentTable::build(&[1.0], F);
    }
}
