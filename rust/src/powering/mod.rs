//! The powering unit (paper §6, Fig 6).
//!
//! Computes `x², x³, …, x^P` under the paper's "maximize squaring"
//! heuristic:
//!
//! * every **even** power `x^(2m)` is the square of `x^m` → squaring unit
//!   (half the hardware of the ILM, see [`crate::squaring`]);
//! * every **odd** power `x^(2m+1)` is `x^(2m) · x` → ILM, with the
//!   priority-encoder and LOD values of `x` **cached** after the first
//!   squaring so the multiplier needs only one PE and one LOD;
//! * one odd and one even power are produced **simultaneously per cycle**
//!   ("two iterations worth of correction" per cycle, paper step 6).
//!
//! The unit is generic over the multiplier backend so the Taylor engine
//! can sweep exact-vs-ILM arithmetic without code changes.

use crate::ilm::{ilm_mul, priority_encode};
use crate::simd::Engine;
use crate::squaring::ilm_square;

/// Operation counters shared by all backends.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct OpCounts {
    pub muls: u64,
    pub squares: u64,
    /// Priority-encoder evaluations actually performed.
    pub pe_ops: u64,
    /// PE evaluations avoided by the §6 operand cache.
    pub pe_cache_hits: u64,
}

impl OpCounts {
    pub fn add(&mut self, other: OpCounts) {
        self.muls += other.muls;
        self.squares += other.squares;
        self.pe_ops += other.pe_ops;
        self.pe_cache_hits += other.pe_cache_hits;
    }
}

/// A multiplier backend: produces full-width (2·frac) products.
pub trait Multiplier {
    /// Full product of two fixed-point operands (2f fraction bits out).
    fn mul(&mut self, a: u64, b: u64) -> u128;
    /// Full square (2f fraction bits out).
    fn square(&mut self, a: u64) -> u128;
    fn counts(&self) -> OpCounts;
    fn reset_counts(&mut self);
    fn describe(&self) -> String;

    /// Hot-path product without op-count bookkeeping (§Perf step 3).
    /// Same numerics as [`Multiplier::mul`]; backends override to skip
    /// their counters.
    #[inline]
    fn mul_hot(&mut self, a: u64, b: u64) -> u128 {
        self.mul(a, b)
    }

    /// Hot-path square without op-count bookkeeping.
    #[inline]
    fn square_hot(&mut self, a: u64) -> u128 {
        self.square(a)
    }

    /// Batched fixed-point hot-path products:
    /// `out[i] = (mul_hot(a[i], b[i]) >> frac_bits) as u64` — one stage
    /// loop of the SoA kernel ([`crate::kernel`]), driven by an explicit
    /// lane engine ([`crate::simd::Engine`]). The default implementation
    /// is the per-lane scalar hot loop (engines are ignored — a custom
    /// backend stays correct without vector code); both in-tree backends
    /// override with engine-routed lane ops that are bit-identical to
    /// this loop.
    #[inline]
    fn mul_fixed_hot_batch(
        &mut self,
        eng: Engine,
        a: &[u64],
        b: &[u64],
        frac_bits: u32,
        out: &mut [u64],
    ) {
        let _ = eng;
        debug_assert!(a.len() == b.len() && a.len() == out.len());
        for ((&x, &y), o) in a.iter().zip(b).zip(out.iter_mut()) {
            *o = (self.mul_hot(x, y) >> frac_bits) as u64;
        }
    }

    /// Batched fixed-point hot-path squares:
    /// `out[i] = (square_hot(a[i]) >> frac_bits) as u64`.
    #[inline]
    fn square_fixed_hot_batch(&mut self, eng: Engine, a: &[u64], frac_bits: u32, out: &mut [u64]) {
        let _ = eng;
        debug_assert_eq!(a.len(), out.len());
        for (&x, o) in a.iter().zip(out.iter_mut()) {
            *o = (self.square_hot(x) >> frac_bits) as u64;
        }
    }
}

/// Exact integer multiplier (infinite-precision reference backend).
#[derive(Debug, Default, Clone)]
pub struct ExactMul {
    counts: OpCounts,
}

impl Multiplier for ExactMul {
    fn mul(&mut self, a: u64, b: u64) -> u128 {
        self.counts.muls += 1;
        self.counts.pe_ops += 2;
        a as u128 * b as u128
    }

    fn square(&mut self, a: u64) -> u128 {
        self.counts.squares += 1;
        self.counts.pe_ops += 1;
        a as u128 * a as u128
    }

    #[inline]
    fn mul_hot(&mut self, a: u64, b: u64) -> u128 {
        a as u128 * b as u128
    }

    #[inline]
    fn square_hot(&mut self, a: u64) -> u128 {
        a as u128 * a as u128
    }

    /// Exact products route straight to the lane engine's wide multiply
    /// — `(a·b) >> f` per lane, identical to the scalar hot loop.
    #[inline]
    fn mul_fixed_hot_batch(
        &mut self,
        eng: Engine,
        a: &[u64],
        b: &[u64],
        frac_bits: u32,
        out: &mut [u64],
    ) {
        eng.mul_shr(a, b, frac_bits, out);
    }

    #[inline]
    fn square_fixed_hot_batch(&mut self, eng: Engine, a: &[u64], frac_bits: u32, out: &mut [u64]) {
        eng.sqr_shr(a, frac_bits, out);
    }

    fn counts(&self) -> OpCounts {
        self.counts
    }

    fn reset_counts(&mut self) {
        self.counts = OpCounts::default();
    }

    fn describe(&self) -> String {
        "exact".to_string()
    }
}

/// ILM backend with a fixed correction-iteration budget (paper §4–5).
#[derive(Debug, Clone)]
pub struct IlmBackend {
    pub iterations: u32,
    counts: OpCounts,
}

impl IlmBackend {
    pub fn new(iterations: u32) -> Self {
        Self {
            iterations,
            counts: OpCounts::default(),
        }
    }
}

impl Multiplier for IlmBackend {
    fn mul(&mut self, a: u64, b: u64) -> u128 {
        self.counts.muls += 1;
        self.counts.pe_ops += 2;
        ilm_mul(a, b, self.iterations).product
    }

    fn square(&mut self, a: u64) -> u128 {
        self.counts.squares += 1;
        self.counts.pe_ops += 1;
        ilm_square(a, self.iterations).square
    }

    #[inline]
    fn mul_hot(&mut self, a: u64, b: u64) -> u128 {
        ilm_mul(a, b, self.iterations).product
    }

    #[inline]
    fn square_hot(&mut self, a: u64) -> u128 {
        ilm_square(a, self.iterations).square
    }

    /// Route the batched multiply stage through the ILM's staged lane
    /// recursion (the priority-encoder pass runs once per correction
    /// stage across the tile; numerically identical to per-lane
    /// `ilm_mul`).
    #[inline]
    fn mul_fixed_hot_batch(
        &mut self,
        eng: Engine,
        a: &[u64],
        b: &[u64],
        frac_bits: u32,
        out: &mut [u64],
    ) {
        crate::ilm::ilm_mul_fixed_batch(eng, a, b, frac_bits, self.iterations, out);
    }

    /// Route the batched square stage through the squaring unit's own
    /// staged lane loop (numerically identical to the default
    /// implementation).
    #[inline]
    fn square_fixed_hot_batch(&mut self, eng: Engine, a: &[u64], frac_bits: u32, out: &mut [u64]) {
        crate::squaring::ilm_square_fixed_batch(eng, a, frac_bits, self.iterations, out);
    }

    fn counts(&self) -> OpCounts {
        self.counts
    }

    fn reset_counts(&mut self) {
        self.counts = OpCounts::default();
    }

    fn describe(&self) -> String {
        format!("ilm({} iter)", self.iterations)
    }
}

/// What a cycle of the Fig-6 schedule produced.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CycleTrace {
    pub cycle: u32,
    /// Power index computed on the multiplier this cycle (odd), if any.
    pub odd_power: Option<u32>,
    /// Power index computed on the squaring unit this cycle (even), if any.
    pub even_power: Option<u32>,
}

/// Result of a powering-unit run.
#[derive(Clone, Debug)]
pub struct PowersResult {
    /// `powers[i]` = x^(i+1) as Q(frac_bits) — `powers[0]` is x itself.
    pub powers: Vec<u64>,
    /// Fig-6 schedule actually executed.
    pub schedule: Vec<CycleTrace>,
    /// Total cycles (= schedule length).
    pub cycles: u32,
    /// Backend op counters accumulated during this run.
    pub counts: OpCounts,
}

/// Reusable buffers for [`PoweringUnit::compute_powers_into`], so
/// repeated diagnostic reciprocals (the Taylor engine, analysis sweeps)
/// allocate only once and reuse capacity afterwards.
#[derive(Clone, Debug, Default)]
pub struct PowersScratch {
    /// `powers[i]` = x^(i+1), as in [`PowersResult::powers`].
    pub powers: Vec<u64>,
    /// Executed Fig-6 schedule, as in [`PowersResult::schedule`].
    pub schedule: Vec<CycleTrace>,
}

impl PowersScratch {
    pub fn new() -> Self {
        Self::default()
    }
}

/// The powering unit.
///
/// `frac_bits` is the fixed-point fraction width of `x` (< 64); products
/// are truncated back to `frac_bits` after every stage, matching the
/// hardware datapath width.
pub struct PoweringUnit<'m, M: Multiplier + ?Sized> {
    backend: &'m mut M,
    frac_bits: u32,
}

impl<'m, M: Multiplier + ?Sized> PoweringUnit<'m, M> {
    pub fn new(backend: &'m mut M, frac_bits: u32) -> Self {
        assert!(frac_bits < 64);
        Self { backend, frac_bits }
    }

    /// Compute `x^1 … x^max_power` per the Fig-6 schedule.
    ///
    /// Allocating convenience over [`Self::compute_powers_into`].
    pub fn compute_powers(&mut self, x: u64, max_power: u32) -> PowersResult {
        let mut scratch = PowersScratch::new();
        let (cycles, counts) = self.compute_powers_into(x, max_power, &mut scratch);
        PowersResult {
            powers: scratch.powers,
            schedule: scratch.schedule,
            cycles,
            counts,
        }
    }

    /// Compute `x^1 … x^max_power` per the Fig-6 schedule into reusable
    /// buffers; returns `(cycles, op counts)` with the powers and the
    /// executed schedule left in `scratch`.
    ///
    /// Cycle 1 computes x² and caches the PE/LOD of x (paper step 1);
    /// every later cycle computes the next odd power on the multiplier
    /// (using the cached x, saving one PE evaluation — step 3) and the
    /// next even power on the squaring unit (step 4), in parallel.
    pub fn compute_powers_into(
        &mut self,
        x: u64,
        max_power: u32,
        scratch: &mut PowersScratch,
    ) -> (u32, OpCounts) {
        assert!(max_power >= 1, "need at least x^1");
        let before = self.backend.counts();
        let f = self.frac_bits;
        let powers = &mut scratch.powers;
        let schedule = &mut scratch.schedule;
        powers.clear();
        powers.reserve(max_power as usize);
        schedule.clear();
        powers.push(x); // x^1
        let mut counts_extra = OpCounts::default();

        if max_power >= 2 {
            // Cycle 1: x² on the squaring unit; PE/LOD of x cached.
            let sq = self.backend.square(x) >> f;
            // Model the §6 cache: the PE of x is evaluated once here and
            // reused for every later odd-power multiply.
            let _ = priority_encode(x.max(1));
            powers.push(sq as u64);
            schedule.push(CycleTrace {
                cycle: 1,
                odd_power: None,
                even_power: Some(2),
            });

            let mut cycle = 2;
            let mut next_odd = 3u32;
            let mut next_even = 4u32;
            while next_odd <= max_power || next_even <= max_power {
                let mut trace = CycleTrace {
                    cycle,
                    odd_power: None,
                    even_power: None,
                };
                if next_odd <= max_power {
                    // x^(2m+1) = x^(2m) · x, with x's PE cached → count a hit.
                    let even_operand = powers[(next_odd - 2) as usize]; // x^(2m)
                    let p = self.backend.mul(even_operand, x) >> f;
                    counts_extra.pe_cache_hits += 1;
                    ensure_len(powers, next_odd as usize);
                    powers[(next_odd - 1) as usize] = p as u64;
                    trace.odd_power = Some(next_odd);
                    next_odd += 2;
                }
                if next_even <= max_power {
                    // x^(2m) = (x^m)², operand available from earlier cycles.
                    let half = powers[(next_even / 2 - 1) as usize];
                    let p = self.backend.square(half) >> f;
                    ensure_len(powers, next_even as usize);
                    powers[(next_even - 1) as usize] = p as u64;
                    trace.even_power = Some(next_even);
                    next_even += 2;
                }
                schedule.push(trace);
                cycle += 1;
            }
        }

        let mut counts = self.backend.counts();
        counts.muls -= before.muls;
        counts.squares -= before.squares;
        counts.pe_ops -= before.pe_ops;
        // Cache hits: the backend charged 2 PE per mul, but one operand
        // (x) was cached — refund it.
        counts.pe_ops -= counts_extra.pe_cache_hits;
        counts.pe_cache_hits += counts_extra.pe_cache_hits;

        (schedule.len() as u32, counts)
    }
}

fn ensure_len(v: &mut Vec<u64>, len: usize) {
    if v.len() < len {
        v.resize(len, 0);
    }
}

/// Cycles the Fig-6 schedule needs for `max_power` powers: one cycle for
/// x², then one cycle per (odd, even) pair.
pub const fn schedule_cycles(max_power: u32) -> u32 {
    if max_power < 2 {
        0
    } else if max_power == 2 {
        1
    } else {
        // Powers 3..=max_power arrive two per cycle.
        1 + (max_power - 1) / 2
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::check_that;
    use crate::util::check::{forall, Config};

    const F: u32 = 24;

    fn fx(x: f64) -> u64 {
        (x * (1u64 << F) as f64).round() as u64
    }

    fn to_f(x: u64) -> f64 {
        x as f64 / (1u64 << F) as f64
    }

    #[test]
    fn exact_backend_computes_true_powers() {
        let mut be = ExactMul::default();
        let mut pu = PoweringUnit::new(&mut be, F);
        let x = fx(0.5);
        let r = pu.compute_powers(x, 8);
        assert_eq!(r.powers.len(), 8);
        for (i, &p) in r.powers.iter().enumerate() {
            let want = 0.5f64.powi(i as i32 + 1);
            let got = to_f(p);
            assert!(
                (got - want).abs() < 1e-5,
                "x^{}: got {got}, want {want}",
                i + 1
            );
        }
    }

    #[test]
    fn schedule_matches_fig6_for_12_powers() {
        // Fig 6 computes up to 12 powers: cycle 1 → x²; cycles 2..6 →
        // (x³,x⁴), (x⁵,x⁶), (x⁷,x⁸), (x⁹,x¹⁰), (x¹¹,x¹²).
        let mut be = ExactMul::default();
        let mut pu = PoweringUnit::new(&mut be, F);
        let r = pu.compute_powers(fx(0.9), 12);
        assert_eq!(r.cycles, 6);
        assert_eq!(r.cycles, schedule_cycles(12));
        assert_eq!(
            r.schedule[0],
            CycleTrace { cycle: 1, odd_power: None, even_power: Some(2) }
        );
        assert_eq!(
            r.schedule[1],
            CycleTrace { cycle: 2, odd_power: Some(3), even_power: Some(4) }
        );
        assert_eq!(
            r.schedule[5],
            CycleTrace { cycle: 6, odd_power: Some(11), even_power: Some(12) }
        );
    }

    #[test]
    fn schedule_cycles_closed_form() {
        assert_eq!(schedule_cycles(1), 0);
        assert_eq!(schedule_cycles(2), 1);
        assert_eq!(schedule_cycles(3), 2);
        assert_eq!(schedule_cycles(4), 2);
        assert_eq!(schedule_cycles(5), 3);
        assert_eq!(schedule_cycles(12), 6);
        // And the executed schedule agrees for every count.
        for p in 2..20 {
            let mut be = ExactMul::default();
            let mut pu = PoweringUnit::new(&mut be, F);
            let r = pu.compute_powers(fx(0.7), p);
            assert_eq!(r.cycles, schedule_cycles(p), "max_power={p}");
        }
    }

    #[test]
    fn even_powers_use_squares_odd_use_muls() {
        let mut be = ExactMul::default();
        let mut pu = PoweringUnit::new(&mut be, F);
        let r = pu.compute_powers(fx(0.8), 12);
        // 12 powers: squares for 2,4,6,8,10,12 (6), muls for 3,5,7,9,11 (5).
        assert_eq!(r.counts.squares, 6);
        assert_eq!(r.counts.muls, 5);
        // One PE per square (6) + one PE per mul (5, second operand cached).
        assert_eq!(r.counts.pe_ops, 11);
        assert_eq!(r.counts.pe_cache_hits, 5);
    }

    #[test]
    fn compute_powers_into_reuses_scratch_and_matches_allocating_path() {
        let mut be = ExactMul::default();
        let mut pu = PoweringUnit::new(&mut be, F);
        let mut scratch = PowersScratch::new();
        for (x, p) in [(fx(0.9), 12u32), (fx(0.5), 5), (fx(0.73), 8)] {
            let (cycles, counts) = pu.compute_powers_into(x, p, &mut scratch);
            let mut be2 = ExactMul::default();
            let r = PoweringUnit::new(&mut be2, F).compute_powers(x, p);
            assert_eq!(scratch.powers, r.powers, "x={x} p={p}");
            assert_eq!(scratch.schedule, r.schedule);
            assert_eq!(cycles, r.cycles);
            assert_eq!(counts, r.counts);
        }
    }

    #[test]
    fn batched_hot_ops_match_scalar_hot_ops_both_backends() {
        // The SoA kernel's stage loops must be numerically identical to
        // the scalar hot path on every lane engine, including the
        // IlmBackend's staged-recursion overrides and zero operands
        // (m = 0 lanes).
        let a: Vec<u64> = vec![0, 1, 3 << (F - 1), (1 << F) - 1, 12345, 1 << F, 7, 0, 42];
        let b: Vec<u64> = vec![5, 0, 1 << F, 99, (1 << F) + 7, 3, 7, 0, (1 << F) - 1];
        let mut out = vec![0u64; a.len()];
        for eng in crate::simd::engines_available() {
            let mut exact = ExactMul::default();
            exact.mul_fixed_hot_batch(eng, &a, &b, F, &mut out);
            for i in 0..a.len() {
                assert_eq!(
                    out[i],
                    (exact.mul_hot(a[i], b[i]) >> F) as u64,
                    "{} exact mul {i}",
                    eng.name()
                );
            }
            exact.square_fixed_hot_batch(eng, &a, F, &mut out);
            for i in 0..a.len() {
                assert_eq!(
                    out[i],
                    (exact.square_hot(a[i]) >> F) as u64,
                    "{} exact sq {i}",
                    eng.name()
                );
            }
            for iters in [0u32, 2, 8] {
                let mut ilm = IlmBackend::new(iters);
                ilm.mul_fixed_hot_batch(eng, &a, &b, F, &mut out);
                for i in 0..a.len() {
                    assert_eq!(
                        out[i],
                        (ilm.mul_hot(a[i], b[i]) >> F) as u64,
                        "{} ilm{iters} mul {i}",
                        eng.name()
                    );
                }
                ilm.square_fixed_hot_batch(eng, &a, F, &mut out);
                for i in 0..a.len() {
                    assert_eq!(
                        out[i],
                        (ilm.square_hot(a[i]) >> F) as u64,
                        "{} ilm{iters} sq {i}",
                        eng.name()
                    );
                }
            }
        }
    }

    #[test]
    fn max_power_one_is_trivial() {
        let mut be = ExactMul::default();
        let mut pu = PoweringUnit::new(&mut be, F);
        let x = fx(0.3);
        let r = pu.compute_powers(x, 1);
        assert_eq!(r.powers, vec![x]);
        assert_eq!(r.cycles, 0);
        assert_eq!(r.counts.muls + r.counts.squares, 0);
    }

    #[test]
    fn ilm_backend_with_full_iterations_matches_exact() {
        let x = fx(0.437);
        let mut exact = ExactMul::default();
        let r_exact = PoweringUnit::new(&mut exact, F).compute_powers(x, 10);
        let mut ilm = IlmBackend::new(64);
        let r_ilm = PoweringUnit::new(&mut ilm, F).compute_powers(x, 10);
        assert_eq!(r_exact.powers, r_ilm.powers);
    }

    #[test]
    fn ilm_backend_underestimates_with_few_iterations() {
        forall(Config::named("ilm powers ≤ exact powers").cases(100), |d| {
            let x = d.range_u64(1, (1 << F) - 1); // x < 1.0
            let iters = d.range_u64(0, 3) as u32;
            let mut exact = ExactMul::default();
            let re = PoweringUnit::new(&mut exact, F).compute_powers(x, 6);
            let mut ilm = IlmBackend::new(iters);
            let ri = PoweringUnit::new(&mut ilm, F).compute_powers(x, 6);
            for (i, (&pi, &pe)) in ri.powers.iter().zip(re.powers.iter()).enumerate() {
                check_that!(pi <= pe, "x^{} ilm {} > exact {}", i + 1, pi, pe);
            }
            Ok(())
        });
    }

    #[test]
    fn powers_of_value_below_one_decrease() {
        forall(Config::named("powers decrease for x<1").cases(200), |d| {
            let x = d.range_u64(1, (1 << F) - 1);
            let mut be = ExactMul::default();
            let r = PoweringUnit::new(&mut be, F).compute_powers(x, 8);
            for w in r.powers.windows(2) {
                check_that!(w[1] <= w[0], "powers increased: {:?}", w);
            }
            Ok(())
        });
    }

    #[test]
    fn truncation_error_bounded_per_stage() {
        // Each truncation drops < 1 ulp; x^k accumulated error is < k ulps
        // (powers of x < 1 only shrink the absolute error).
        forall(Config::named("truncation error bound").cases(100), |d| {
            let xf = d.f64_range(0.01, 0.999);
            let x = fx(xf);
            let mut be = ExactMul::default();
            let r = PoweringUnit::new(&mut be, F).compute_powers(x, 10);
            for (i, &p) in r.powers.iter().enumerate() {
                let k = i as i32 + 1;
                let want = to_f(x).powi(k);
                let err = (to_f(p) - want).abs();
                let bound = (k as f64) / (1u64 << F) as f64;
                check_that!(
                    err <= bound,
                    "x^{k}: err {err} > bound {bound} (x={xf})"
                );
            }
            Ok(())
        });
    }
}
