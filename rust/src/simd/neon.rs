//! NEON implementations of the lane-engine ops — 2 × u64 lanes per
//! `uint64x2_t`, bit-identical to [`super::scalar`] by construction.
//! This carries the whole kernel hot path (seed compare tree, Q2.F
//! multiplies, saturating clamps, the ILM priority encoder) to aarch64.
//!
//! ISA notes relative to the x86 modules:
//!
//! * **Saturating subtract is native** (`vqsubq_u64` / `uqsub`) — the
//!   seed and power-stage clamps need no compare-and-blend at all.
//! * **Unsigned 64-bit compares are native** (`vcgeq_u64`), so like
//!   AVX-512 (and unlike AVX2) the segment count reads raw edges with
//!   no sign-bias staging.
//! * **No wide 64-bit multiply**: [`mul_u64_wide`] is the same exact
//!   schoolbook as the x86 modules, from four `vmull_u32` 32×32→64
//!   limb products.
//! * **No 64-bit clz**: `vclzq` stops at 32-bit lanes, so
//!   [`priority_encode_batch`] emulates it — `vclzq_u32` over both
//!   halves, then selects `clz(hi)` or `32 + clz(lo)` on the
//!   `hi == 0` mask. The ROADMAP asked for this shuffle tree to be
//!   measured against the scalar chain: with only two lanes per vector
//!   the win is modest, but the select tree is branch-free where the
//!   scalar chain is a per-lane `if v == 0` (the zero-lane pin), and it
//!   keeps the operands in vector registers between the PE pass and the
//!   surrounding ILM vector ops — `pe_batch_per_s_neon` in
//!   `BENCH_HISTORY.jsonl` is the trend gate on that choice. The scalar
//!   chain remains the tail/reference path.
//!
//! Every function here requires NEON: callers reach them only through
//! [`super::Engine::Neon`], which `SimdChoice::resolve` constructs
//! strictly after `is_aarch64_feature_detected!("neon")` succeeded
//! (NEON is baseline on aarch64-unknown-linux-gnu, but the token keeps
//! the proof obligation uniform across engines). Tails shorter than one
//! vector fall through to the scalar reference.

#![allow(unsafe_op_in_unsafe_fn)]

use core::arch::aarch64::*;

/// # Safety
/// Requires NEON (guaranteed by `Engine::Neon` construction).
#[target_feature(enable = "neon")]
pub unsafe fn mul_shr(a: &[u64], b: &[u64], f: u32, out: &mut [u64]) {
    debug_assert!(a.len() == b.len() && a.len() == out.len());
    if f == 0 || f >= 64 {
        // Pure-low or pure-high extraction: rare configs, scalar keeps
        // the shift-combination below branch-free for the 1..=63 case.
        return super::scalar::mul_shr(a, b, f, out);
    }
    let n = a.len();
    // USHL with a negative count shifts right: one vector op does the
    // (lo >> f) | (hi << (64 − f)) recombination's variable shifts.
    let shr = vdupq_n_s64(-(f as i64));
    let shl = vdupq_n_s64((64 - f) as i64);
    let mut i = 0;
    while i + 2 <= n {
        let va = vld1q_u64(a.as_ptr().add(i));
        let vb = vld1q_u64(b.as_ptr().add(i));
        let (lo, hi) = mul_u64_wide(va, vb);
        let r = vorrq_u64(vshlq_u64(lo, shr), vshlq_u64(hi, shl));
        vst1q_u64(out.as_mut_ptr().add(i), r);
        i += 2;
    }
    super::scalar::mul_shr(&a[i..], &b[i..], f, &mut out[i..]);
}

/// # Safety
/// Requires NEON (guaranteed by `Engine::Neon` construction).
#[target_feature(enable = "neon")]
pub unsafe fn sqr_shr(a: &[u64], f: u32, out: &mut [u64]) {
    debug_assert_eq!(a.len(), out.len());
    if f == 0 || f >= 64 {
        return super::scalar::sqr_shr(a, f, out);
    }
    let n = a.len();
    let shr = vdupq_n_s64(-(f as i64));
    let shl = vdupq_n_s64((64 - f) as i64);
    let mut i = 0;
    while i + 2 <= n {
        let va = vld1q_u64(a.as_ptr().add(i));
        let (lo, hi) = mul_u64_wide(va, va);
        let r = vorrq_u64(vshlq_u64(lo, shr), vshlq_u64(hi, shl));
        vst1q_u64(out.as_mut_ptr().add(i), r);
        i += 2;
    }
    super::scalar::sqr_shr(&a[i..], f, &mut out[i..]);
}

/// Full 128-bit products of two u64 lane pairs as (low, high) 64-bit
/// halves — the same exact schoolbook over 32-bit limbs as the x86
/// modules, with the limbs extracted by narrowing moves:
/// `al = vmovn(a)`, `ah = vshrn(a, 32)`, four `vmull_u32` products,
/// then `t = (al·bl >> 32) + lo32(al·bh) + lo32(ah·bl)` (≤ 3·(2^32−1),
/// no overflow), `lo = lo32(al·bl) | (t << 32)`,
/// `hi = ah·bh + hi32(al·bh) + hi32(ah·bl) + (t >> 32)`.
#[inline]
#[target_feature(enable = "neon")]
unsafe fn mul_u64_wide(a: uint64x2_t, b: uint64x2_t) -> (uint64x2_t, uint64x2_t) {
    let m32 = vdupq_n_u64(0xFFFF_FFFF);
    let al = vmovn_u64(a);
    let ah = vshrn_n_u64::<32>(a);
    let bl = vmovn_u64(b);
    let bh = vshrn_n_u64::<32>(b);
    let ll = vmull_u32(al, bl); // al·bl
    let lh = vmull_u32(al, bh); // al·bh
    let hl = vmull_u32(ah, bl); // ah·bl
    let hh = vmull_u32(ah, bh); // ah·bh
    let t = vaddq_u64(
        vshrq_n_u64::<32>(ll),
        vaddq_u64(vandq_u64(lh, m32), vandq_u64(hl, m32)),
    );
    let lo = vorrq_u64(vandq_u64(ll, m32), vshlq_n_u64::<32>(t));
    let hi = vaddq_u64(
        hh,
        vaddq_u64(
            vaddq_u64(vshrq_n_u64::<32>(lh), vshrq_n_u64::<32>(hl)),
            vshrq_n_u64::<32>(t),
        ),
    );
    (lo, hi)
}

/// # Safety
/// Requires NEON (guaranteed by `Engine::Neon` construction).
#[target_feature(enable = "neon")]
pub unsafe fn sub_sat(a: &[u64], b: &[u64], out: &mut [u64]) {
    debug_assert!(a.len() == b.len() && a.len() == out.len());
    let n = a.len();
    let mut i = 0;
    while i + 2 <= n {
        let va = vld1q_u64(a.as_ptr().add(i));
        let vb = vld1q_u64(b.as_ptr().add(i));
        // UQSUB: saturating unsigned subtract is a single instruction.
        vst1q_u64(out.as_mut_ptr().add(i), vqsubq_u64(va, vb));
        i += 2;
    }
    super::scalar::sub_sat(&a[i..], &b[i..], &mut out[i..]);
}

/// # Safety
/// Requires NEON (guaranteed by `Engine::Neon` construction).
#[target_feature(enable = "neon")]
pub unsafe fn rsub_sat(minuend: u64, v: &mut [u64]) {
    let n = v.len();
    let vm = vdupq_n_u64(minuend);
    let mut i = 0;
    while i + 2 <= n {
        let vv = vld1q_u64(v.as_ptr().add(i));
        vst1q_u64(v.as_mut_ptr().add(i), vqsubq_u64(vm, vv));
        i += 2;
    }
    super::scalar::rsub_sat(minuend, &mut v[i..]);
}

/// # Safety
/// Requires NEON (guaranteed by `Engine::Neon` construction).
#[target_feature(enable = "neon")]
pub unsafe fn add_wrapping(acc: &mut [u64], x: &[u64]) {
    debug_assert_eq!(acc.len(), x.len());
    let n = acc.len();
    let mut i = 0;
    while i + 2 <= n {
        let va = vld1q_u64(acc.as_ptr().add(i));
        let vx = vld1q_u64(x.as_ptr().add(i));
        vst1q_u64(acc.as_mut_ptr().add(i), vaddq_u64(va, vx));
        i += 2;
    }
    super::scalar::add_wrapping(&mut acc[i..], &x[i..]);
}

/// # Safety
/// Requires NEON (guaranteed by `Engine::Neon` construction).
#[target_feature(enable = "neon")]
pub unsafe fn fill_add(base: u64, x: &[u64], out: &mut [u64]) {
    debug_assert_eq!(x.len(), out.len());
    let n = x.len();
    let vb = vdupq_n_u64(base);
    let mut i = 0;
    while i + 2 <= n {
        let vx = vld1q_u64(x.as_ptr().add(i));
        vst1q_u64(out.as_mut_ptr().add(i), vaddq_u64(vb, vx));
        i += 2;
    }
    super::scalar::fill_add(base, &x[i..], &mut out[i..]);
}

/// PLA compare tree: count how many sorted edges each lane is at or
/// above, clamped to the last segment. `vcgeq_u64` compares unsigned
/// 64-bit lanes natively, so — as on AVX-512 — the loop reads the raw
/// edge list and [`super::BiasedEdges`] contributes nothing beyond the
/// cached edge slice. The ≥ mask is all-ones (−1) per true lane, so
/// subtracting it increments the count; NEON has no 64-bit unsigned
/// min, so the final clamp is a compare-and-select.
///
/// # Safety
/// Requires NEON (guaranteed by `Engine::Neon` construction).
#[target_feature(enable = "neon")]
pub unsafe fn segment_counts(x: &[u64], edges: &[u64], idx: &mut [u64]) {
    debug_assert_eq!(x.len(), idx.len());
    debug_assert!(!edges.is_empty());
    let n = x.len();
    let last = vdupq_n_u64((edges.len() - 1) as u64);
    let mut i = 0;
    while i + 2 <= n {
        let xv = vld1q_u64(x.as_ptr().add(i));
        let mut cnt = vdupq_n_u64(0);
        for &e in edges {
            let ge = vcgeq_u64(xv, vdupq_n_u64(e));
            cnt = vsubq_u64(cnt, ge);
        }
        let over = vcgtq_u64(cnt, last);
        let r = vbslq_u64(over, last, cnt);
        vst1q_u64(idx.as_mut_ptr().add(i), r);
        i += 2;
    }
    super::scalar::segment_counts(&x[i..], edges, &mut idx[i..]);
}

/// The vectorized ILM priority-encoder pass:
/// `(k[i], r[i]) = (⌊log2 n[i]⌋, n[i] − 2^k)`, zero lanes pinned to
/// `(0, 0)` — bit-identical to [`super::scalar::priority_encode_batch`].
///
/// NEON's `vclzq` tops out at 32-bit lanes, so the 64-bit leading-zero
/// count is a select tree over the halves:
/// `clz64 = hi == 0 ? 32 + clz32(lo) : clz32(hi)` — one `vclzq_u32`
/// covers both halves of both lanes at once, then a shift/mask splits
/// them back out and `vbslq` picks per the `hi == 0` mask. Zero lanes
/// (where the select yields 64 and `63 − clz` would wrap) are cleared
/// with `vbicq` against the `v == 0` mask, matching the scalar pin.
/// `r = v ^ (1 << k)` uses `USHL`'s per-lane variable shift.
///
/// # Safety
/// Requires NEON (guaranteed by `Engine::Neon` construction).
#[target_feature(enable = "neon")]
pub unsafe fn priority_encode_batch(n: &[u64], k: &mut [u32], r: &mut [u64]) {
    debug_assert!(n.len() == k.len() && n.len() == r.len());
    let len = n.len();
    let m32 = vdupq_n_u64(0xFFFF_FFFF);
    let c32 = vdupq_n_u64(32);
    let c63 = vdupq_n_u64(63);
    let one = vdupq_n_u64(1);
    let mut i = 0;
    while i + 2 <= len {
        let v = vld1q_u64(n.as_ptr().add(i));
        // clz of every 32-bit half, still in 64-bit lane positions.
        let cz = vreinterpretq_u64_u32(vclzq_u32(vreinterpretq_u32_u64(v)));
        let clz_hi = vshrq_n_u64::<32>(cz);
        let clz_lo = vandq_u64(cz, m32);
        let hi_zero = vceqzq_u64(vshrq_n_u64::<32>(v));
        let clz64 = vbslq_u64(hi_zero, vaddq_u64(clz_lo, c32), clz_hi);
        let zero = vceqzq_u64(v);
        // k = 63 − clz64; wraps on zero lanes, cleared by the mask.
        let kk = vbicq_u64(vsubq_u64(c63, clz64), zero);
        let top = vshlq_u64(one, vreinterpretq_s64_u64(kk));
        // Nonzero lanes: v ^ 2^k clears the leading bit; zero lanes
        // would see v ^ 1 = 1, cleared by the same mask.
        let rr = vbicq_u64(veorq_u64(v, top), zero);
        vst1q_u64(r.as_mut_ptr().add(i), rr);
        vst1_u32(k.as_mut_ptr().add(i), vmovn_u64(kk));
        i += 2;
    }
    super::scalar::priority_encode_batch(&n[i..], &mut k[i..], &mut r[i..]);
}
