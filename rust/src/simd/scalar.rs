//! Scalar-unrolled reference implementations of the lane-engine ops.
//!
//! These define the semantics of every [`super::Engine`] op: plain
//! integer arithmetic, four lanes per loop body so the compiler can
//! keep the lanes in flight without loop-carried stalls (and so the
//! structure mirrors an AVX2 vector / half an AVX-512 vector — each
//! unrolled body is one vector's worth of work). Every vector module
//! (`avx2`, `avx512`, `neon`) must match these bit for bit; the module
//! tests sweep all detected engines against `u128` references.

#[inline]
pub fn mul_shr(a: &[u64], b: &[u64], f: u32, out: &mut [u64]) {
    debug_assert!(a.len() == b.len() && a.len() == out.len());
    debug_assert!(f < 128);
    let mut ai = a.chunks_exact(4);
    let mut bi = b.chunks_exact(4);
    let mut oi = out.chunks_exact_mut(4);
    for ((ca, cb), co) in (&mut ai).zip(&mut bi).zip(&mut oi) {
        co[0] = ((ca[0] as u128 * cb[0] as u128) >> f) as u64;
        co[1] = ((ca[1] as u128 * cb[1] as u128) >> f) as u64;
        co[2] = ((ca[2] as u128 * cb[2] as u128) >> f) as u64;
        co[3] = ((ca[3] as u128 * cb[3] as u128) >> f) as u64;
    }
    for ((&x, &y), o) in ai
        .remainder()
        .iter()
        .zip(bi.remainder())
        .zip(oi.into_remainder())
    {
        *o = ((x as u128 * y as u128) >> f) as u64;
    }
}

#[inline]
pub fn sqr_shr(a: &[u64], f: u32, out: &mut [u64]) {
    debug_assert_eq!(a.len(), out.len());
    debug_assert!(f < 128);
    let mut ai = a.chunks_exact(4);
    let mut oi = out.chunks_exact_mut(4);
    for (ca, co) in (&mut ai).zip(&mut oi) {
        co[0] = ((ca[0] as u128 * ca[0] as u128) >> f) as u64;
        co[1] = ((ca[1] as u128 * ca[1] as u128) >> f) as u64;
        co[2] = ((ca[2] as u128 * ca[2] as u128) >> f) as u64;
        co[3] = ((ca[3] as u128 * ca[3] as u128) >> f) as u64;
    }
    for (&x, o) in ai.remainder().iter().zip(oi.into_remainder()) {
        *o = ((x as u128 * x as u128) >> f) as u64;
    }
}

#[inline]
pub fn sub_sat(a: &[u64], b: &[u64], out: &mut [u64]) {
    debug_assert!(a.len() == b.len() && a.len() == out.len());
    for ((&x, &y), o) in a.iter().zip(b).zip(out.iter_mut()) {
        *o = x.saturating_sub(y);
    }
}

#[inline]
pub fn rsub_sat(minuend: u64, v: &mut [u64]) {
    let mut vi = v.chunks_exact_mut(4);
    for c in &mut vi {
        c[0] = minuend.saturating_sub(c[0]);
        c[1] = minuend.saturating_sub(c[1]);
        c[2] = minuend.saturating_sub(c[2]);
        c[3] = minuend.saturating_sub(c[3]);
    }
    for x in vi.into_remainder() {
        *x = minuend.saturating_sub(*x);
    }
}

#[inline]
pub fn add_wrapping(acc: &mut [u64], x: &[u64]) {
    debug_assert_eq!(acc.len(), x.len());
    let mut ai = acc.chunks_exact_mut(4);
    let mut xi = x.chunks_exact(4);
    for (ca, cx) in (&mut ai).zip(&mut xi) {
        ca[0] = ca[0].wrapping_add(cx[0]);
        ca[1] = ca[1].wrapping_add(cx[1]);
        ca[2] = ca[2].wrapping_add(cx[2]);
        ca[3] = ca[3].wrapping_add(cx[3]);
    }
    for (a, &v) in ai.into_remainder().iter_mut().zip(xi.remainder()) {
        *a = a.wrapping_add(v);
    }
}

#[inline]
pub fn fill_add(base: u64, x: &[u64], out: &mut [u64]) {
    debug_assert_eq!(x.len(), out.len());
    for (&v, o) in x.iter().zip(out.iter_mut()) {
        *o = base.wrapping_add(v);
    }
}

#[inline]
pub fn segment_counts(x: &[u64], edges: &[u64], idx: &mut [u64]) {
    debug_assert_eq!(x.len(), idx.len());
    debug_assert!(!edges.is_empty());
    let last = (edges.len() - 1) as u64;
    for (&v, o) in x.iter().zip(idx.iter_mut()) {
        // Count of edges ≤ v: for a sorted edge list this equals the
        // index of the first edge above v — the compare-tree select —
        // and the count form is branch-free per edge.
        let mut c = 0u64;
        for &e in edges {
            c += (v >= e) as u64;
        }
        *o = c.min(last);
    }
}

#[inline]
pub fn priority_encode_batch(n: &[u64], k: &mut [u32], r: &mut [u64]) {
    debug_assert!(n.len() == k.len() && n.len() == r.len());
    for ((&v, kk), rr) in n.iter().zip(k.iter_mut()).zip(r.iter_mut()) {
        if v == 0 {
            // Zero lanes are settled; the ILM control logic never feeds
            // a zero operand to the encoder, callers test the operand.
            *kk = 0;
            *rr = 0;
        } else {
            let lead = 63 - v.leading_zeros();
            *kk = lead;
            *rr = v ^ (1 << lead);
        }
    }
}
