//! Explicit SIMD lane engine under the staged kernel.
//!
//! The PR-3 kernel gave the hot path its structure-of-arrays layout and
//! tile loops, but left vectorization to the compiler. This module makes
//! the lane parallelism explicit: a small portable engine of fixed-width
//! `u64`-lane slice ops — wide multiply-and-shift, saturating subtract,
//! wrapping accumulate, the PLA compare tree as a lane count, the ILM
//! priority-encoder pass — with one reference implementation and a
//! per-ISA vector backend roster:
//!
//! | engine   | module     | lanes | detection                | notes |
//! |----------|------------|-------|--------------------------|-------|
//! | `scalar` | [`scalar`] | 4/body| always                   | reference semantics; plain integer ops, unrolled |
//! | `avx2`   | [`avx2`]   | 4     | `avx2`                   | biased signed compares; scalar PE (no `vplzcntq`) |
//! | `avx512` | [`avx512`] | 8     | `avx512f`+`avx512cd`     | native unsigned compares; vector PE via `vplzcntq` |
//! | `neon`   | [`neon`]   | 2     | aarch64 `neon`           | native `uqsub`; vector PE via `vclzq` half-select |
//!
//! `unsafe` is confined to the vector modules (all behind *runtime*
//! feature detection); everything here and above it is safe code.
//!
//! Selection is a three-way [`SimdChoice`] — `Auto` (detect, widest
//! wins), `Forced` (error if the host has no vector engine), `Scalar`
//! (pin the fallback) — threaded from `KernelConfig::simd` / the serve
//! CLI / the `TSDIV_SIMD` env override down to a resolved [`Engine`]
//! that the kernel's stage loops dispatch on. All engines are
//! **bit-identical** by construction (every op is defined by its scalar
//! semantics; each vector module must reproduce them exactly) and
//! pinned so by unit tests here plus the kernel-level property tests,
//! which sweep [`engines_available`].

mod scalar;

#[cfg(target_arch = "x86_64")]
mod avx2;
#[cfg(target_arch = "x86_64")]
mod avx512;
#[cfg(target_arch = "aarch64")]
mod neon;

use crate::bail;
use crate::util::error::Result;

/// How the kernel should pick its lane engine. Serializable service
/// configuration (rides in `KernelConfig`); resolve to an [`Engine`]
/// with [`SimdChoice::resolve`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SimdChoice {
    /// Use the widest vector engine the host supports, else scalar.
    #[default]
    Auto,
    /// Require a vector engine; configuration error on hosts without
    /// one (benchmark rigs use this so a silent scalar fallback cannot
    /// masquerade as a SIMD measurement). The error names the features
    /// this architecture is missing — see [`forced_requirement`].
    Forced,
    /// Pin the scalar-unrolled engine (the autovectorization baseline
    /// the serving benches compare against).
    Scalar,
}

impl SimdChoice {
    /// Short name as accepted by [`SimdChoice::from_name`].
    pub const fn name(self) -> &'static str {
        match self {
            SimdChoice::Auto => "auto",
            SimdChoice::Forced => "forced",
            SimdChoice::Scalar => "scalar",
        }
    }

    /// Parse a choice name (CLI `--simd`, `TSDIV_SIMD`).
    pub fn from_name(s: &str) -> Option<SimdChoice> {
        match s {
            "auto" => Some(SimdChoice::Auto),
            "forced" | "force" | "simd" => Some(SimdChoice::Forced),
            "scalar" | "off" => Some(SimdChoice::Scalar),
            _ => None,
        }
    }

    /// The process-wide default: `TSDIV_SIMD` if set (this is how CI
    /// runs the whole test suite once per engine), else `Auto`. Parsed
    /// once; an unrecognized value warns and falls back to `Auto`.
    pub fn from_env() -> SimdChoice {
        use std::sync::OnceLock;
        static ENV_CHOICE: OnceLock<SimdChoice> = OnceLock::new();
        *ENV_CHOICE.get_or_init(|| match std::env::var("TSDIV_SIMD") {
            Ok(v) => SimdChoice::from_name(&v).unwrap_or_else(|| {
                crate::log_warn!("TSDIV_SIMD='{v}' is not auto|forced|scalar — using auto");
                SimdChoice::Auto
            }),
            Err(_) => SimdChoice::Auto,
        })
    }

    /// Resolve to a concrete engine. `Forced` on a host without a
    /// vector engine is a configuration error (surfaced by
    /// `KernelConfig::validate` / `DivisionService::start`), not a
    /// silent downgrade; the error names the per-architecture features
    /// that were missing ([`forced_requirement`]).
    ///
    /// An `Auto` choice defers to the `TSDIV_SIMD` process override:
    /// `scalar` pins the fallback engine (how CI runs the *entire*
    /// suite — including `KernelConfig::default()` backends — on the
    /// scalar engine for its second test pass) and `forced` demands a
    /// vector engine with the same hard-error contract as a `Forced`
    /// configuration. Explicit `Forced`/`Scalar` configurations ignore
    /// the env.
    pub fn resolve(self) -> Result<Engine> {
        match self {
            SimdChoice::Scalar => Ok(Engine::Scalar),
            SimdChoice::Auto => match SimdChoice::from_env() {
                SimdChoice::Scalar => Ok(Engine::Scalar),
                SimdChoice::Forced => SimdChoice::Forced.resolve(),
                SimdChoice::Auto => Ok(best_vector_engine().unwrap_or(Engine::Scalar)),
            },
            SimdChoice::Forced => match best_vector_engine() {
                Some(eng) => Ok(eng),
                None => bail!(
                    "simd choice 'forced' requires {}, which this host does not support",
                    forced_requirement()
                ),
            },
        }
    }

    /// Resolve, downgrading an unavailable `Forced` to scalar with a
    /// warning — for env-driven defaults, where failing the whole test
    /// suite over host capabilities would be worse than the downgrade.
    pub fn resolve_lenient(self) -> Engine {
        self.resolve().unwrap_or_else(|e| {
            crate::log_warn!("{e}; falling back to the scalar lane engine");
            Engine::Scalar
        })
    }

    /// Cheap pre-flight used by config validation.
    pub fn validate(self) -> Result<()> {
        self.resolve().map(|_| ())
    }
}

/// The feature set a `Forced` choice demands **on this architecture** —
/// what its resolution error reports as missing. Config-validation
/// errors (`KernelConfig::validate`, `BackendChoice::validate`) quote
/// this, so the message tracks the engine roster instead of
/// hard-coding any one ISA extension.
pub const fn forced_requirement() -> &'static str {
    if cfg!(target_arch = "x86_64") {
        "AVX-512 (F+CD) or AVX2"
    } else if cfg!(target_arch = "aarch64") {
        "NEON"
    } else {
        "a vector engine (none exists for this architecture)"
    }
}

/// AVX-512 as this crate uses it: foundation ops + `vplzcntq` for the
/// vector priority encoder, plus AVX2 for the narrowed 256-bit stores
/// (every AVX-512 CPU has it; detected anyway so the token proves every
/// instruction the module emits).
#[cfg(target_arch = "x86_64")]
fn avx512_detected() -> bool {
    std::arch::is_x86_feature_detected!("avx512f")
        && std::arch::is_x86_feature_detected!("avx512cd")
        && std::arch::is_x86_feature_detected!("avx2")
}

/// The widest vector engine this host supports, if any — the engine
/// `Auto` picks and `Forced` demands. Preference order on x86_64 is
/// AVX-512 over AVX2 (8 lanes over 4, and the only x86 engine with a
/// vector priority encoder); aarch64 has the one NEON engine.
fn best_vector_engine() -> Option<Engine> {
    #[cfg(target_arch = "x86_64")]
    {
        if avx512_detected() {
            return Some(Engine::Avx512(Avx512Token(())));
        }
        if std::arch::is_x86_feature_detected!("avx2") {
            return Some(Engine::Avx2(Avx2Token(())));
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            return Some(Engine::Neon(NeonToken(())));
        }
    }
    None
}

/// True when a vector engine can run on this host (detected at
/// runtime). Tests and benches use this to gate `Forced` sweeps.
pub fn simd_available() -> bool {
    best_vector_engine().is_some()
}

/// Every engine this host can run: scalar always, then each detected
/// vector engine from narrowest to widest (so [`best_vector_engine`]
/// is always the last entry when any exists). Test/bench sweeps
/// iterate this; on an AVX-512 host it covers scalar, AVX2 *and*
/// AVX-512 in one pass.
pub fn engines_available() -> Vec<Engine> {
    let mut v = vec![Engine::Scalar];
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            v.push(Engine::Avx2(Avx2Token(())));
        }
        if avx512_detected() {
            v.push(Engine::Avx512(Avx512Token(())));
        }
    }
    #[cfg(target_arch = "aarch64")]
    if std::arch::is_aarch64_feature_detected!("neon") {
        v.push(Engine::Neon(NeonToken(())));
    }
    v
}

/// A PLA edge table staged for the compare pass, built **once** and
/// reused across every `segment_counts_cached` call that shares it.
///
/// The AVX2 compare trick biases unsigned operands by 2^63 so the
/// signed `_mm256_cmpgt_epi64` orders them correctly; without a cache
/// that bias (and the edge broadcast staging around it) re-runs on
/// every `segment_counts` call — once per 32-lane seed chunk, which for
/// the default 8-lane kernel tile rivals the compare work itself
/// (ROADMAP item e). The kernel builds one `BiasedEdges` per
/// `divide_batch` call in its [`crate::kernel::KernelScratch`] and
/// threads it through the seed stage instead.
///
/// AVX-512 and NEON have native unsigned 64-bit compares, so their
/// cached dispatch reads the raw [`BiasedEdges::edges`] slice — for
/// them the cache is just the stable home of the edge list, with no
/// per-ISA staging to amortize.
///
/// Caching is a pure re-encoding of the edge list: every engine
/// produces results bit-identical to the uncached
/// [`Engine::segment_counts`].
#[derive(Clone, Debug, Default)]
pub struct BiasedEdges {
    /// The raw sorted edges (scalar/AVX-512/NEON engines + vector-tail
    /// path).
    edges: Vec<u64>,
    /// The same edges biased by 2^63 (`e ^ SIGN`), ready for the AVX2
    /// signed-compare trick.
    biased: Vec<u64>,
}

impl BiasedEdges {
    pub fn new() -> Self {
        Self::default()
    }

    /// (Re)stage `edges`; reuses the allocations across calls.
    pub fn rebuild(&mut self, edges: &[u64]) {
        self.edges.clear();
        self.edges.extend_from_slice(edges);
        self.biased.clear();
        self.biased
            .extend(edges.iter().map(|&e| e ^ (1u64 << 63)));
    }

    /// True when this cache was built from exactly `edges` (cheap: the
    /// PLA tables hold ≤ a handful of segments).
    pub fn matches(&self, edges: &[u64]) -> bool {
        self.edges == edges
    }

    pub fn is_empty(&self) -> bool {
        self.edges.is_empty()
    }

    pub fn edges(&self) -> &[u64] {
        &self.edges
    }

    pub fn biased(&self) -> &[u64] {
        &self.biased
    }
}

/// Proof that AVX2 was detected on this host at runtime. The field is
/// private, so the only mints are [`SimdChoice::resolve`] and
/// [`engines_available`] — both strictly after
/// `is_x86_feature_detected!("avx2")` succeeded. This is what makes the
/// safe [`Engine`] ops sound: safe code outside this module **cannot**
/// construct `Engine::Avx2` and trick a dispatch arm into executing
/// AVX2 instructions on a CPU that lacks them.
#[cfg(target_arch = "x86_64")]
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Avx2Token(());

/// Proof that AVX-512F+CD (and AVX2) were detected on this host at
/// runtime — the [`Avx2Token`] pattern for the 512-bit engine; minted
/// only after [`avx512_detected`] succeeded.
#[cfg(target_arch = "x86_64")]
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Avx512Token(());

/// Proof that NEON was detected on this host at runtime — the
/// [`Avx2Token`] pattern for aarch64. NEON is baseline on the Linux
/// aarch64 targets, but minting the token through detection keeps the
/// soundness argument uniform: no safe code can conjure a vector
/// engine variant.
#[cfg(target_arch = "aarch64")]
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct NeonToken(());

/// A resolved lane engine. Copy-cheap; every op takes `self` by value
/// and dispatches once per *slice*, so the per-lane loop bodies stay
/// monomorphic and branch-free.
///
/// All ops are defined by their scalar per-lane semantics (documented
/// per method); the vector implementations reproduce those semantics
/// bit for bit — the kernel's bit-identity guarantee rests on this, and
/// the module tests plus the forced-SIMD-vs-forced-scalar property
/// tests pin it for every engine [`engines_available`] reports.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Engine {
    /// Portable scalar-unrolled fallback (reference semantics).
    Scalar,
    /// 4 × u64 lanes per `__m256i` vector, runtime-detected (the
    /// [`Avx2Token`] payload is the constructibility proof).
    #[cfg(target_arch = "x86_64")]
    Avx2(Avx2Token),
    /// 8 × u64 lanes per `__m512i` vector, runtime-detected
    /// (`avx512f` + `avx512cd`); the only x86 engine with a vector
    /// priority encoder (`vplzcntq`).
    #[cfg(target_arch = "x86_64")]
    Avx512(Avx512Token),
    /// 2 × u64 lanes per `uint64x2_t` vector on aarch64; native
    /// saturating subtract and unsigned compares, priority encoder via
    /// a `vclzq` half-select tree.
    #[cfg(target_arch = "aarch64")]
    Neon(NeonToken),
}

// SAFETY of every vector arm below: the variants are only ever
// constructed by `SimdChoice::resolve` / `engines_available` after
// their runtime feature detection succeeded (avx2; avx512f+avx512cd+
// avx2; neon), so the `#[target_feature]` functions are called on a
// host that supports them.
impl Engine {
    /// Short name for tables, `describe()` strings and per-engine bench
    /// keys (`pe_batch_per_s_{name}`).
    pub const fn name(self) -> &'static str {
        match self {
            Engine::Scalar => "scalar",
            #[cfg(target_arch = "x86_64")]
            Engine::Avx2(_) => "avx2",
            #[cfg(target_arch = "x86_64")]
            Engine::Avx512(_) => "avx512",
            #[cfg(target_arch = "aarch64")]
            Engine::Neon(_) => "neon",
        }
    }

    /// `out[i] = ((a[i] as u128 * b[i] as u128) >> f) as u64` — the
    /// truncating fixed-point multiply of the Q2.F datapath (and of the
    /// PLA seed's slope multiply). `f < 128`; all slices equal length.
    #[inline]
    pub fn mul_shr(self, a: &[u64], b: &[u64], f: u32, out: &mut [u64]) {
        match self {
            Engine::Scalar => scalar::mul_shr(a, b, f, out),
            #[cfg(target_arch = "x86_64")]
            Engine::Avx2(_) => unsafe { avx2::mul_shr(a, b, f, out) },
            #[cfg(target_arch = "x86_64")]
            Engine::Avx512(_) => unsafe { avx512::mul_shr(a, b, f, out) },
            #[cfg(target_arch = "aarch64")]
            Engine::Neon(_) => unsafe { neon::mul_shr(a, b, f, out) },
        }
    }

    /// `out[i] = ((a[i] as u128 * a[i] as u128) >> f) as u64` — the
    /// squaring-unit port of [`Engine::mul_shr`].
    #[inline]
    pub fn sqr_shr(self, a: &[u64], f: u32, out: &mut [u64]) {
        match self {
            Engine::Scalar => scalar::sqr_shr(a, f, out),
            #[cfg(target_arch = "x86_64")]
            Engine::Avx2(_) => unsafe { avx2::sqr_shr(a, f, out) },
            #[cfg(target_arch = "x86_64")]
            Engine::Avx512(_) => unsafe { avx512::sqr_shr(a, f, out) },
            #[cfg(target_arch = "aarch64")]
            Engine::Neon(_) => unsafe { neon::sqr_shr(a, f, out) },
        }
    }

    /// `out[i] = a[i].saturating_sub(b[i])` — the hardware clamp of the
    /// seed subtract (`y0 = c ⊖ s·x`).
    #[inline]
    pub fn sub_sat(self, a: &[u64], b: &[u64], out: &mut [u64]) {
        match self {
            Engine::Scalar => scalar::sub_sat(a, b, out),
            #[cfg(target_arch = "x86_64")]
            Engine::Avx2(_) => unsafe { avx2::sub_sat(a, b, out) },
            #[cfg(target_arch = "x86_64")]
            Engine::Avx512(_) => unsafe { avx512::sub_sat(a, b, out) },
            #[cfg(target_arch = "aarch64")]
            Engine::Neon(_) => unsafe { neon::sub_sat(a, b, out) },
        }
    }

    /// In place, `v[i] = minuend.saturating_sub(v[i])` — the
    /// `m = 1 − x·y0` clamp of the power stage.
    #[inline]
    pub fn rsub_sat(self, minuend: u64, v: &mut [u64]) {
        match self {
            Engine::Scalar => scalar::rsub_sat(minuend, v),
            #[cfg(target_arch = "x86_64")]
            Engine::Avx2(_) => unsafe { avx2::rsub_sat(minuend, v) },
            #[cfg(target_arch = "x86_64")]
            Engine::Avx512(_) => unsafe { avx512::rsub_sat(minuend, v) },
            #[cfg(target_arch = "aarch64")]
            Engine::Neon(_) => unsafe { neon::rsub_sat(minuend, v) },
        }
    }

    /// `acc[i] = acc[i].wrapping_add(x[i])` — the Taylor accumulator
    /// row-add. Wrapping on purpose: the scalar datapath accumulates in
    /// `u128` and truncates once at the end, and addition commutes with
    /// truncation mod 2^64, so wrapping lane adds are bit-identical.
    #[inline]
    pub fn add_wrapping(self, acc: &mut [u64], x: &[u64]) {
        match self {
            Engine::Scalar => scalar::add_wrapping(acc, x),
            #[cfg(target_arch = "x86_64")]
            Engine::Avx2(_) => unsafe { avx2::add_wrapping(acc, x) },
            #[cfg(target_arch = "x86_64")]
            Engine::Avx512(_) => unsafe { avx512::add_wrapping(acc, x) },
            #[cfg(target_arch = "aarch64")]
            Engine::Neon(_) => unsafe { neon::add_wrapping(acc, x) },
        }
    }

    /// `out[i] = base.wrapping_add(x[i])` — accumulator initialization
    /// (`S = 1 + m` per lane).
    #[inline]
    pub fn fill_add(self, base: u64, x: &[u64], out: &mut [u64]) {
        match self {
            Engine::Scalar => scalar::fill_add(base, x, out),
            #[cfg(target_arch = "x86_64")]
            Engine::Avx2(_) => unsafe { avx2::fill_add(base, x, out) },
            #[cfg(target_arch = "x86_64")]
            Engine::Avx512(_) => unsafe { avx512::fill_add(base, x, out) },
            #[cfg(target_arch = "aarch64")]
            Engine::Neon(_) => unsafe { neon::fill_add(base, x, out) },
        }
    }

    /// The PLA compare tree over a lane tile: `idx[i]` = index of the
    /// first sorted `edges` entry above `x[i]`, clamped to the last
    /// segment — computed as the count of edges ≤ `x[i]`, which for a
    /// sorted edge list equals the scalar `SegmentTable::select` result
    /// exactly. `edges` must be non-empty.
    #[inline]
    pub fn segment_counts(self, x: &[u64], edges: &[u64], idx: &mut [u64]) {
        match self {
            Engine::Scalar => scalar::segment_counts(x, edges, idx),
            #[cfg(target_arch = "x86_64")]
            Engine::Avx2(_) => unsafe { avx2::segment_counts(x, edges, idx) },
            #[cfg(target_arch = "x86_64")]
            Engine::Avx512(_) => unsafe { avx512::segment_counts(x, edges, idx) },
            #[cfg(target_arch = "aarch64")]
            Engine::Neon(_) => unsafe { neon::segment_counts(x, edges, idx) },
        }
    }

    /// [`Engine::segment_counts`] with the per-call edge staging hoisted
    /// into a reusable [`BiasedEdges`] cache: identical results, but the
    /// bias/broadcast setup of the AVX2 path runs once per cache build
    /// instead of once per call. AVX-512 and NEON compare unsigned lanes
    /// natively, so their arms read the cache's raw edge slice — same
    /// entry point, nothing to prestage. The hot seed path
    /// ([`crate::pla::SegmentTable::seed_batch_with`]) uses this;
    /// `edges` must be non-empty.
    #[inline]
    pub fn segment_counts_cached(self, x: &[u64], edges: &BiasedEdges, idx: &mut [u64]) {
        debug_assert!(!edges.is_empty());
        match self {
            Engine::Scalar => scalar::segment_counts(x, edges.edges(), idx),
            #[cfg(target_arch = "x86_64")]
            Engine::Avx2(_) => unsafe {
                avx2::segment_counts_prebiased(x, edges.edges(), edges.biased(), idx)
            },
            #[cfg(target_arch = "x86_64")]
            Engine::Avx512(_) => unsafe { avx512::segment_counts(x, edges.edges(), idx) },
            #[cfg(target_arch = "aarch64")]
            Engine::Neon(_) => unsafe { neon::segment_counts(x, edges.edges(), idx) },
        }
    }

    /// The ILM priority-encoder pass over a lane tile:
    /// `(k[i], r[i]) = (⌊log2 n[i]⌋, n[i] − 2^k)` with the zero lane
    /// defined as `(0, 0)` (the unit's control logic short-circuits zero
    /// operands, so callers test the operand, not `k`).
    ///
    /// This pass dispatches per engine like every other op. AVX-512CD's
    /// `vplzcntq` runs the LZCNT chain eight lanes per instruction and
    /// NEON emulates a 64-bit clz with a `vclzq` half-select, so both
    /// run genuinely vectorized PE ([`avx512::priority_encode_batch`],
    /// [`neon::priority_encode_batch`]). AVX2 has no 64-bit lzcnt (and
    /// no emulation that beats per-lane `LZCNT` without losing bit
    /// exactness), so its arm shares the scalar-unrolled loop. Across
    /// all engines the structural win stands: the ILM correction
    /// recursion runs this as one pass per stage over the tile instead
    /// of per lane over stages.
    #[inline]
    pub fn priority_encode_batch(self, n: &[u64], k: &mut [u32], r: &mut [u64]) {
        match self {
            Engine::Scalar => scalar::priority_encode_batch(n, k, r),
            #[cfg(target_arch = "x86_64")]
            Engine::Avx2(_) => scalar::priority_encode_batch(n, k, r),
            #[cfg(target_arch = "x86_64")]
            Engine::Avx512(_) => unsafe { avx512::priority_encode_batch(n, k, r) },
            #[cfg(target_arch = "aarch64")]
            Engine::Neon(_) => unsafe { neon::priority_encode_batch(n, k, r) },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn gen(n: usize, seed: u64) -> Vec<u64> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.next_u64() >> (rng.below(4) * 8)).collect()
    }

    /// Edge-heavy operand menu: zeros, ones, powers of two and their
    /// neighbors on both sides of the 32-bit limb split, all-ones, the
    /// sign bit (the AVX2 bias pivot), and mixed-limb patterns.
    const EDGE: [u64; 16] = [
        0,
        1,
        2,
        3,
        (1 << 32) - 1,
        1 << 32,
        (1 << 32) + 1,
        u64::MAX,
        u64::MAX - 1,
        0x8000_0000_0000_0000,
        0x8000_0000_0000_0001,
        0x7FFF_FFFF_FFFF_FFFF,
        0xFFFF_FFFF_0000_0000,
        (1 << 52) | (1 << 31),
        0x5555_5555_5555_5555,
        0x0123_4567_89AB_CDEF,
    ];

    #[test]
    fn choice_names_roundtrip_and_env_default() {
        for c in [SimdChoice::Auto, SimdChoice::Forced, SimdChoice::Scalar] {
            assert_eq!(SimdChoice::from_name(c.name()), Some(c));
        }
        assert_eq!(SimdChoice::from_name("simd"), Some(SimdChoice::Forced));
        assert_eq!(SimdChoice::from_name("warp"), None);
        assert_eq!(SimdChoice::default(), SimdChoice::Auto);
        // from_env never panics and is stable across calls (OnceLock).
        let first = SimdChoice::from_env();
        let second = SimdChoice::from_env();
        assert_eq!(first, second);
    }

    #[test]
    fn resolution_matches_host_capabilities() {
        assert_eq!(SimdChoice::Scalar.resolve().unwrap(), Engine::Scalar);
        let auto = SimdChoice::Auto.resolve();
        match SimdChoice::from_env() {
            // CI's second test pass: the process override pins Auto.
            SimdChoice::Scalar => {
                assert_eq!(auto.unwrap(), Engine::Scalar, "TSDIV_SIMD=scalar must pin auto");
            }
            // An env override of `forced` carries the hard-error
            // contract into Auto configs too.
            SimdChoice::Forced => assert_eq!(auto.is_ok(), simd_available()),
            SimdChoice::Auto if simd_available() => {
                assert_ne!(auto.unwrap(), Engine::Scalar, "auto must pick a vector engine");
            }
            SimdChoice::Auto => assert_eq!(auto.unwrap(), Engine::Scalar),
        }
        let engines = engines_available();
        assert_eq!(engines[0], Engine::Scalar, "scalar is always first");
        if simd_available() {
            // Forced ignores the env: it always demands a vector
            // engine — specifically the widest detected one, which the
            // sweep list ends with.
            let forced = SimdChoice::Forced.resolve().unwrap();
            assert_ne!(forced, Engine::Scalar);
            assert!(engines.len() >= 2, "vector host must sweep ≥ 2 engines");
            assert_eq!(*engines.last().unwrap(), forced, "sweep ends at the widest engine");
        } else {
            assert!(SimdChoice::Forced.resolve().is_err());
            assert!(SimdChoice::Forced.validate().is_err());
            assert_eq!(SimdChoice::Forced.resolve_lenient(), Engine::Scalar);
            assert_eq!(engines, vec![Engine::Scalar]);
        }
        // Engine names key per-engine bench rows; they must be unique.
        for (i, a) in engines.iter().enumerate() {
            for b in &engines[i + 1..] {
                assert_ne!(a.name(), b.name(), "duplicate engine name");
            }
        }
        assert_eq!(Engine::Scalar.name(), "scalar");
    }

    #[test]
    fn forced_requirement_names_this_architectures_features() {
        // The Forced error must name what *this* architecture is
        // missing — one assertion arm per ISA roster entry, so the
        // string cannot silently regress to a single hard-coded
        // extension again.
        let req = forced_requirement();
        if cfg!(target_arch = "x86_64") {
            assert!(req.contains("AVX-512"), "x86_64 arm must name AVX-512: {req}");
            assert!(req.contains("AVX2"), "x86_64 arm must name AVX2: {req}");
        } else if cfg!(target_arch = "aarch64") {
            assert!(req.contains("NEON"), "aarch64 arm must name NEON: {req}");
        } else {
            assert!(req.contains("vector engine"), "fallback arm: {req}");
        }
        // And the resolution error actually quotes it (only observable
        // on hosts where Forced fails).
        if !simd_available() {
            let err = SimdChoice::Forced.resolve().unwrap_err().to_string();
            assert!(err.contains(req), "error '{err}' must quote '{req}'");
        }
    }

    #[test]
    fn mul_shr_matches_u128_reference_all_engines() {
        let mut a = gen(67, 1);
        let mut b = gen(67, 2);
        a.extend_from_slice(&EDGE);
        b.extend_from_slice(&EDGE);
        // Misaligned pairings of the edge menu too.
        a.extend_from_slice(&EDGE);
        b.extend(EDGE.iter().rev());
        let mut out = vec![0u64; a.len()];
        for eng in engines_available() {
            for f in [0u32, 1, 7, 23, 32, 52, 60, 63, 64, 100, 127] {
                eng.mul_shr(&a, &b, f, &mut out);
                for i in 0..a.len() {
                    let want = ((a[i] as u128 * b[i] as u128) >> f) as u64;
                    assert_eq!(out[i], want, "{} f={f} lane {i}", eng.name());
                }
                eng.sqr_shr(&a, f, &mut out);
                for i in 0..a.len() {
                    let want = ((a[i] as u128 * a[i] as u128) >> f) as u64;
                    assert_eq!(out[i], want, "{} sqr f={f} lane {i}", eng.name());
                }
            }
        }
    }

    #[test]
    fn saturating_and_wrapping_ops_match_reference() {
        let mut a = gen(61, 3);
        let mut b = gen(61, 4);
        a.extend_from_slice(&EDGE);
        b.extend(EDGE.iter().rev());
        let n = a.len();
        for eng in engines_available() {
            let mut out = vec![0u64; n];
            eng.sub_sat(&a, &b, &mut out);
            for i in 0..n {
                assert_eq!(out[i], a[i].saturating_sub(b[i]), "{} sub_sat {i}", eng.name());
            }
            for minuend in [0u64, 1, 1 << 60, u64::MAX] {
                let mut v = b.clone();
                eng.rsub_sat(minuend, &mut v);
                for i in 0..n {
                    assert_eq!(v[i], minuend.saturating_sub(b[i]), "{} rsub {i}", eng.name());
                }
            }
            let mut acc = a.clone();
            eng.add_wrapping(&mut acc, &b);
            for i in 0..n {
                assert_eq!(acc[i], a[i].wrapping_add(b[i]), "{} add {i}", eng.name());
            }
            eng.fill_add(u64::MAX - 1, &b, &mut out);
            for i in 0..n {
                assert_eq!(out[i], (u64::MAX - 1).wrapping_add(b[i]), "{} fill {i}", eng.name());
            }
        }
    }

    #[test]
    fn segment_counts_equal_linear_select_reference() {
        // Sorted edges like a real PLA table, lanes spanning below/at/
        // between/above every edge.
        let edges: Vec<u64> = vec![100, 250, 251, 900, 4000, 1 << 40, 1 << 60, u64::MAX - 4];
        let mut xs: Vec<u64> = Vec::new();
        for &e in &edges {
            xs.extend_from_slice(&[e.wrapping_sub(1), e, e.wrapping_add(1)]);
        }
        xs.extend_from_slice(&[0, 50, u64::MAX]);
        let select = |x: u64| -> u64 {
            for (i, &e) in edges.iter().enumerate() {
                if x < e {
                    return i as u64;
                }
            }
            edges.len() as u64 - 1
        };
        let mut idx = vec![0u64; xs.len()];
        for eng in engines_available() {
            eng.segment_counts(&xs, &edges, &mut idx);
            for (i, &x) in xs.iter().enumerate() {
                assert_eq!(idx[i], select(x), "{} x={x}", eng.name());
            }
        }
        // Single-segment table: every lane is segment 0.
        for eng in engines_available() {
            eng.segment_counts(&xs, &[1u64 << 61], &mut idx);
            assert!(idx.iter().all(|&i| i == 0), "{}", eng.name());
        }
    }

    #[test]
    fn cached_segment_counts_bit_identical_to_uncached() {
        // The cache is a pure re-encoding of the edge list: across all
        // engines, many chunked calls sharing one cache, and tails
        // shorter than a vector, cached == uncached == linear select.
        let edges: Vec<u64> = vec![10, 1 << 20, 1 << 40, (1 << 60) + 3, u64::MAX - 1];
        let mut cache = BiasedEdges::new();
        assert!(cache.is_empty());
        cache.rebuild(&edges);
        assert!(!cache.is_empty());
        assert!(cache.matches(&edges));
        assert!(!cache.matches(&edges[..3]));
        assert_eq!(cache.edges(), &edges[..]);
        assert_eq!(cache.biased().len(), edges.len());
        for (e, b) in edges.iter().zip(cache.biased()) {
            assert_eq!(*b, *e ^ (1u64 << 63), "bias is 2^63");
        }
        let mut xs = gen(77, 12);
        xs.extend_from_slice(&EDGE);
        for &e in &edges {
            xs.extend_from_slice(&[e.wrapping_sub(1), e, e.wrapping_add(1)]);
        }
        for eng in engines_available() {
            let mut plain = vec![0u64; xs.len()];
            eng.segment_counts(&xs, &edges, &mut plain);
            // One cache, many calls (the per-divide_batch reuse shape):
            // chunk sizes deliberately off the 2/4/8-lane vector widths.
            let mut cached = vec![0u64; xs.len()];
            for chunk in [5usize, 32, 3, 100] {
                let mut done = 0;
                while done < xs.len() {
                    let n = (xs.len() - done).min(chunk);
                    eng.segment_counts_cached(
                        &xs[done..done + n],
                        &cache,
                        &mut cached[done..done + n],
                    );
                    done += n;
                }
                assert_eq!(cached, plain, "{} chunk={chunk}", eng.name());
            }
        }
        // Rebuilding with a different table replaces, not appends.
        cache.rebuild(&edges[..2]);
        assert_eq!(cache.edges().len(), 2);
        assert_eq!(cache.biased().len(), 2);
        assert!(cache.matches(&edges[..2]));
    }

    #[test]
    fn priority_encode_batch_matches_scalar_pe() {
        let mut xs = gen(53, 9);
        xs.extend_from_slice(&EDGE);
        // Interleave zero lanes through the vector bodies: settled ILM
        // lanes appear mid-tile exactly like this, and the vector PEs
        // pin them to (0, 0) with masks rather than branches.
        for (i, v) in gen(24, 10).into_iter().enumerate() {
            xs.push(if i % 3 == 0 { 0 } else { v });
        }
        let check = |eng: Engine, xs: &[u64], k: &[u32], r: &[u64]| {
            for (i, &x) in xs.iter().enumerate() {
                if x == 0 {
                    assert_eq!((k[i], r[i]), (0, 0), "{} zero lane {i}", eng.name());
                } else {
                    let (kk, rr) = crate::ilm::priority_encode(x);
                    assert_eq!((k[i], r[i]), (kk, rr), "{} lane {i}", eng.name());
                }
            }
        };
        let mut k = vec![0u32; xs.len()];
        let mut r = vec![0u64; xs.len()];
        for eng in engines_available() {
            eng.priority_encode_batch(&xs, &mut k, &mut r);
            check(eng, &xs, &k, &r);
            // Non-tile-multiple lengths: every prefix exercises a
            // different vector-body/scalar-tail split for the 2-, 4-
            // and 8-lane widths.
            for n in 0..xs.len().min(19) {
                let mut kn = vec![0u32; n];
                let mut rn = vec![0u64; n];
                eng.priority_encode_batch(&xs[..n], &mut kn, &mut rn);
                check(eng, &xs[..n], &kn, &rn);
            }
        }
    }

    #[test]
    fn short_and_empty_slices_are_fine() {
        // Below one vector width, and empty: tails must be handled.
        for eng in engines_available() {
            for n in 0..6usize {
                let a: Vec<u64> = (0..n as u64).map(|i| i * 3 + 1).collect();
                let b: Vec<u64> = (0..n as u64).map(|i| i + (1 << 40)).collect();
                let mut out = vec![0u64; n];
                eng.mul_shr(&a, &b, 30, &mut out);
                for i in 0..n {
                    assert_eq!(out[i], ((a[i] as u128 * b[i] as u128) >> 30) as u64);
                }
                let mut idx = vec![0u64; n];
                eng.segment_counts(&a, &[2, 4], &mut idx);
                let mut k = vec![0u32; n];
                let mut r = vec![0u64; n];
                eng.priority_encode_batch(&a, &mut k, &mut r);
            }
        }
    }
}
