//! AVX-512 implementations of the lane-engine ops — 8 × u64 lanes per
//! `__m512i`, bit-identical to [`super::scalar`] by construction.
//!
//! Relative to [`super::avx2`] this module gains three things. The
//! vectors are twice as wide. The unsigned-compare bias trick
//! disappears: AVX-512F has native unsigned 64-bit compares
//! (`_mm512_cmple_epu64_mask` and friends) that produce `__mmask8`
//! predicates, so [`segment_counts`] reads the *raw* sorted edges and
//! the cached entry point needs no prebias staging at all. And AVX-512CD
//! brings `vplzcntq` (`_mm512_lzcnt_epi64`), which finally makes the ILM
//! priority-encoder pass vectorizable: [`priority_encode_batch`]
//! computes `⌊log2 n⌋ = 63 − lzcnt(n)` for eight lanes at once, with the
//! zero lanes masked to `(0, 0)` via the nonzero predicate.
//!
//! The 64×64→128 multiply is the same schoolbook over `_mm512_mul_epu32`
//! limb products as the AVX2 module — AVX-512F also lacks a wide 64-bit
//! multiply (`vpmullq` is AVX-512DQ and only returns the low half).
//!
//! Every function here requires AVX-512F+CD: callers reach them only
//! through [`super::Engine::Avx512`], which `SimdChoice::resolve`
//! constructs strictly after runtime feature detection of `avx512f`,
//! `avx512cd` *and* `avx2` (the narrowed-store tail uses a 256-bit
//! store; every AVX-512 CPU has AVX2, but the detector checks anyway so
//! the token proves everything this module emits). Tails shorter than
//! one vector fall through to the scalar reference.

#![allow(unsafe_op_in_unsafe_fn)]

use core::arch::x86_64::*;

/// # Safety
/// Requires AVX-512F (guaranteed by `Engine::Avx512` construction).
#[target_feature(enable = "avx512f,avx512cd,avx2")]
pub unsafe fn mul_shr(a: &[u64], b: &[u64], f: u32, out: &mut [u64]) {
    debug_assert!(a.len() == b.len() && a.len() == out.len());
    if f == 0 || f >= 64 {
        // Pure-low or pure-high extraction: rare configs, scalar keeps
        // the shift-combination below branch-free for the 1..=63 case.
        return super::scalar::mul_shr(a, b, f, out);
    }
    let n = a.len();
    let shr = _mm_cvtsi32_si128(f as i32);
    let shl = _mm_cvtsi32_si128(64 - f as i32);
    let m32 = _mm512_set1_epi64(0xFFFF_FFFF);
    let mut i = 0;
    while i + 8 <= n {
        let va = _mm512_loadu_epi64(a.as_ptr().add(i) as *const i64);
        let vb = _mm512_loadu_epi64(b.as_ptr().add(i) as *const i64);
        let (lo, hi) = mul_u64_wide(va, vb, m32);
        let r = _mm512_or_si512(_mm512_srl_epi64(lo, shr), _mm512_sll_epi64(hi, shl));
        _mm512_storeu_epi64(out.as_mut_ptr().add(i) as *mut i64, r);
        i += 8;
    }
    super::scalar::mul_shr(&a[i..], &b[i..], f, &mut out[i..]);
}

/// # Safety
/// Requires AVX-512F (guaranteed by `Engine::Avx512` construction).
#[target_feature(enable = "avx512f,avx512cd,avx2")]
pub unsafe fn sqr_shr(a: &[u64], f: u32, out: &mut [u64]) {
    debug_assert_eq!(a.len(), out.len());
    if f == 0 || f >= 64 {
        return super::scalar::sqr_shr(a, f, out);
    }
    let n = a.len();
    let shr = _mm_cvtsi32_si128(f as i32);
    let shl = _mm_cvtsi32_si128(64 - f as i32);
    let m32 = _mm512_set1_epi64(0xFFFF_FFFF);
    let mut i = 0;
    while i + 8 <= n {
        let va = _mm512_loadu_epi64(a.as_ptr().add(i) as *const i64);
        let (lo, hi) = mul_u64_wide(va, va, m32);
        let r = _mm512_or_si512(_mm512_srl_epi64(lo, shr), _mm512_sll_epi64(hi, shl));
        _mm512_storeu_epi64(out.as_mut_ptr().add(i) as *mut i64, r);
        i += 8;
    }
    super::scalar::sqr_shr(&a[i..], f, &mut out[i..]);
}

/// Full 128-bit products of eight u64 lane pairs as (low, high) 64-bit
/// halves — the same exact schoolbook over 32-bit limbs as the AVX2
/// module: with `a = ah·2^32 + al`, `b = bh·2^32 + bl`,
/// `t = (al·bl >> 32) + lo32(al·bh) + lo32(ah·bl)` (≤ 3·(2^32−1), no
/// overflow), `lo = lo32(al·bl) | (t << 32)`,
/// `hi = ah·bh + hi32(al·bh) + hi32(ah·bl) + (t >> 32)`.
#[inline]
#[target_feature(enable = "avx512f")]
unsafe fn mul_u64_wide(a: __m512i, b: __m512i, m32: __m512i) -> (__m512i, __m512i) {
    let a_hi = _mm512_srli_epi64::<32>(a);
    let b_hi = _mm512_srli_epi64::<32>(b);
    let ll = _mm512_mul_epu32(a, b); // al·bl
    let lh = _mm512_mul_epu32(a, b_hi); // al·bh
    let hl = _mm512_mul_epu32(a_hi, b); // ah·bl
    let hh = _mm512_mul_epu32(a_hi, b_hi); // ah·bh
    let t = _mm512_add_epi64(
        _mm512_srli_epi64::<32>(ll),
        _mm512_add_epi64(_mm512_and_si512(lh, m32), _mm512_and_si512(hl, m32)),
    );
    let lo = _mm512_or_si512(_mm512_and_si512(ll, m32), _mm512_slli_epi64::<32>(t));
    let hi = _mm512_add_epi64(
        hh,
        _mm512_add_epi64(
            _mm512_add_epi64(_mm512_srli_epi64::<32>(lh), _mm512_srli_epi64::<32>(hl)),
            _mm512_srli_epi64::<32>(t),
        ),
    );
    (lo, hi)
}

/// # Safety
/// Requires AVX-512F (guaranteed by `Engine::Avx512` construction).
#[target_feature(enable = "avx512f,avx512cd,avx2")]
pub unsafe fn sub_sat(a: &[u64], b: &[u64], out: &mut [u64]) {
    debug_assert!(a.len() == b.len() && a.len() == out.len());
    let n = a.len();
    let mut i = 0;
    while i + 8 <= n {
        let va = _mm512_loadu_epi64(a.as_ptr().add(i) as *const i64);
        let vb = _mm512_loadu_epi64(b.as_ptr().add(i) as *const i64);
        // Native unsigned ≥: compute a − b only on the lanes where it
        // cannot underflow, zero the rest — saturation in one masked op.
        let ok = _mm512_cmpge_epu64_mask(va, vb);
        let r = _mm512_maskz_sub_epi64(ok, va, vb);
        _mm512_storeu_epi64(out.as_mut_ptr().add(i) as *mut i64, r);
        i += 8;
    }
    super::scalar::sub_sat(&a[i..], &b[i..], &mut out[i..]);
}

/// # Safety
/// Requires AVX-512F (guaranteed by `Engine::Avx512` construction).
#[target_feature(enable = "avx512f,avx512cd,avx2")]
pub unsafe fn rsub_sat(minuend: u64, v: &mut [u64]) {
    let n = v.len();
    let vm = _mm512_set1_epi64(minuend as i64);
    let mut i = 0;
    while i + 8 <= n {
        let vv = _mm512_loadu_epi64(v.as_ptr().add(i) as *const i64);
        let ok = _mm512_cmpge_epu64_mask(vm, vv);
        let r = _mm512_maskz_sub_epi64(ok, vm, vv);
        _mm512_storeu_epi64(v.as_mut_ptr().add(i) as *mut i64, r);
        i += 8;
    }
    super::scalar::rsub_sat(minuend, &mut v[i..]);
}

/// # Safety
/// Requires AVX-512F (guaranteed by `Engine::Avx512` construction).
#[target_feature(enable = "avx512f,avx512cd,avx2")]
pub unsafe fn add_wrapping(acc: &mut [u64], x: &[u64]) {
    debug_assert_eq!(acc.len(), x.len());
    let n = acc.len();
    let mut i = 0;
    while i + 8 <= n {
        let va = _mm512_loadu_epi64(acc.as_ptr().add(i) as *const i64);
        let vx = _mm512_loadu_epi64(x.as_ptr().add(i) as *const i64);
        let r = _mm512_add_epi64(va, vx);
        _mm512_storeu_epi64(acc.as_mut_ptr().add(i) as *mut i64, r);
        i += 8;
    }
    super::scalar::add_wrapping(&mut acc[i..], &x[i..]);
}

/// # Safety
/// Requires AVX-512F (guaranteed by `Engine::Avx512` construction).
#[target_feature(enable = "avx512f,avx512cd,avx2")]
pub unsafe fn fill_add(base: u64, x: &[u64], out: &mut [u64]) {
    debug_assert_eq!(x.len(), out.len());
    let n = x.len();
    let vb = _mm512_set1_epi64(base as i64);
    let mut i = 0;
    while i + 8 <= n {
        let vx = _mm512_loadu_epi64(x.as_ptr().add(i) as *const i64);
        let r = _mm512_add_epi64(vb, vx);
        _mm512_storeu_epi64(out.as_mut_ptr().add(i) as *mut i64, r);
        i += 8;
    }
    super::scalar::fill_add(base, &x[i..], &mut out[i..]);
}

/// PLA compare tree: count how many sorted edges each lane is at or
/// above, clamped to the last segment. Unlike the AVX2 path there is no
/// bias staging and no stack-capacity limit — `_mm512_cmple_epu64_mask`
/// compares unsigned 64-bit lanes natively, so the loop reads the raw
/// edge list directly. This is also why [`super::BiasedEdges`] carries
/// no AVX-512-specific staging: the cached entry point dispatches here
/// with the cache's raw `edges()` and is bit-identical to the uncached
/// call by construction.
///
/// # Safety
/// Requires AVX-512F (guaranteed by `Engine::Avx512` construction).
#[target_feature(enable = "avx512f,avx512cd,avx2")]
pub unsafe fn segment_counts(x: &[u64], edges: &[u64], idx: &mut [u64]) {
    debug_assert_eq!(x.len(), idx.len());
    debug_assert!(!edges.is_empty());
    let n = x.len();
    let one = _mm512_set1_epi64(1);
    let last = _mm512_set1_epi64((edges.len() - 1) as i64);
    let mut i = 0;
    while i + 8 <= n {
        let xv = _mm512_loadu_epi64(x.as_ptr().add(i) as *const i64);
        let mut cnt = _mm512_setzero_si512();
        for &e in edges {
            // e ≤ x per lane, as a predicate mask; masked add counts it.
            let ge = _mm512_cmple_epu64_mask(_mm512_set1_epi64(e as i64), xv);
            cnt = _mm512_mask_add_epi64(cnt, ge, cnt, one);
        }
        // Lanes at/above the last edge clamp to the last segment.
        let r = _mm512_min_epu64(cnt, last);
        _mm512_storeu_epi64(idx.as_mut_ptr().add(i) as *mut i64, r);
        i += 8;
    }
    super::scalar::segment_counts(&x[i..], edges, &mut idx[i..]);
}

/// The vectorized ILM priority-encoder pass:
/// `(k[i], r[i]) = (⌊log2 n[i]⌋, n[i] − 2^k)`, zero lanes pinned to
/// `(0, 0)` — bit-identical to [`super::scalar::priority_encode_batch`].
///
/// `vplzcntq` (AVX-512CD) gives `⌊log2 n⌋ = 63 − lzcnt(n)` for eight
/// lanes per instruction; zero lanes (where `lzcnt` returns 64 and the
/// subtract would wrap) are excluded via the `vptestmq` nonzero
/// predicate, so `k` and `r` land as zeros there without a branch.
/// `r = n ^ (1 << k)` clears the leading bit via `vpsllvq`. The `k`
/// outputs narrow to `u32` through `vpmovqd`.
///
/// # Safety
/// Requires AVX-512F + AVX-512CD (guaranteed by `Engine::Avx512`
/// construction).
#[target_feature(enable = "avx512f,avx512cd,avx2")]
pub unsafe fn priority_encode_batch(n: &[u64], k: &mut [u32], r: &mut [u64]) {
    debug_assert!(n.len() == k.len() && n.len() == r.len());
    let len = n.len();
    let c63 = _mm512_set1_epi64(63);
    let one = _mm512_set1_epi64(1);
    let mut i = 0;
    while i + 8 <= len {
        let v = _mm512_loadu_epi64(n.as_ptr().add(i) as *const i64);
        let nz = _mm512_test_epi64_mask(v, v);
        let lz = _mm512_lzcnt_epi64(v);
        // k = 63 − lzcnt on nonzero lanes, 0 on zero lanes.
        let kk = _mm512_maskz_sub_epi64(nz, c63, lz);
        // r = v ^ 2^k on nonzero lanes (2^k is the leading bit, so the
        // xor is the subtract), 0 on zero lanes.
        let top = _mm512_sllv_epi64(one, kk);
        let rr = _mm512_maskz_xor_epi64(nz, v, top);
        _mm512_storeu_epi64(r.as_mut_ptr().add(i) as *mut i64, rr);
        _mm256_storeu_si256(
            k.as_mut_ptr().add(i) as *mut __m256i,
            _mm512_cvtepi64_epi32(kk),
        );
        i += 8;
    }
    super::scalar::priority_encode_batch(&n[i..], &mut k[i..], &mut r[i..]);
}
