//! AVX2 implementations of the lane-engine ops — 4 × u64 lanes per
//! `__m256i`, bit-identical to [`super::scalar`] by construction.
//!
//! The only non-obvious piece is the 64×64→128 multiply: AVX2 has no
//! wide 64-bit multiply, so [`mul_u64_wide`] builds it from four
//! `_mm256_mul_epu32` limb products (schoolbook, exact), and the
//! fixed-point ops recombine `(lo >> f) | (hi << (64 − f))`. Unsigned
//! 64-bit compares bias both operands by 2^63 and use the signed
//! compare.
//!
//! Every function here requires AVX2: callers reach them only through
//! [`super::Engine::Avx2`], which `SimdChoice::resolve` constructs
//! strictly after runtime feature detection. Tails shorter than one
//! vector fall through to the scalar reference.

#![allow(unsafe_op_in_unsafe_fn)]

use core::arch::x86_64::*;

/// # Safety
/// Requires AVX2 (guaranteed by `Engine::Avx2` construction).
#[target_feature(enable = "avx2")]
pub unsafe fn mul_shr(a: &[u64], b: &[u64], f: u32, out: &mut [u64]) {
    debug_assert!(a.len() == b.len() && a.len() == out.len());
    if f == 0 || f >= 64 {
        // Pure-low or pure-high extraction: rare configs, scalar keeps
        // the shift-combination below branch-free for the 1..=63 case.
        return super::scalar::mul_shr(a, b, f, out);
    }
    let n = a.len();
    let shr = _mm_cvtsi32_si128(f as i32);
    let shl = _mm_cvtsi32_si128(64 - f as i32);
    let m32 = _mm256_set1_epi64x(0xFFFF_FFFF);
    let mut i = 0;
    while i + 4 <= n {
        let va = _mm256_loadu_si256(a.as_ptr().add(i) as *const __m256i);
        let vb = _mm256_loadu_si256(b.as_ptr().add(i) as *const __m256i);
        let (lo, hi) = mul_u64_wide(va, vb, m32);
        let r = _mm256_or_si256(_mm256_srl_epi64(lo, shr), _mm256_sll_epi64(hi, shl));
        _mm256_storeu_si256(out.as_mut_ptr().add(i) as *mut __m256i, r);
        i += 4;
    }
    super::scalar::mul_shr(&a[i..], &b[i..], f, &mut out[i..]);
}

/// # Safety
/// Requires AVX2 (guaranteed by `Engine::Avx2` construction).
#[target_feature(enable = "avx2")]
pub unsafe fn sqr_shr(a: &[u64], f: u32, out: &mut [u64]) {
    debug_assert_eq!(a.len(), out.len());
    if f == 0 || f >= 64 {
        return super::scalar::sqr_shr(a, f, out);
    }
    let n = a.len();
    let shr = _mm_cvtsi32_si128(f as i32);
    let shl = _mm_cvtsi32_si128(64 - f as i32);
    let m32 = _mm256_set1_epi64x(0xFFFF_FFFF);
    let mut i = 0;
    while i + 4 <= n {
        let va = _mm256_loadu_si256(a.as_ptr().add(i) as *const __m256i);
        let (lo, hi) = mul_u64_wide(va, va, m32);
        let r = _mm256_or_si256(_mm256_srl_epi64(lo, shr), _mm256_sll_epi64(hi, shl));
        _mm256_storeu_si256(out.as_mut_ptr().add(i) as *mut __m256i, r);
        i += 4;
    }
    super::scalar::sqr_shr(&a[i..], f, &mut out[i..]);
}

/// Full 128-bit products of four u64 lane pairs as (low, high) 64-bit
/// halves — schoolbook over 32-bit limbs, exact:
/// with `a = ah·2^32 + al`, `b = bh·2^32 + bl`,
/// `t = (al·bl >> 32) + lo32(al·bh) + lo32(ah·bl)` (≤ 3·(2^32−1), no
/// overflow), `lo = lo32(al·bl) | (t << 32)`,
/// `hi = ah·bh + hi32(al·bh) + hi32(ah·bl) + (t >> 32)`.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn mul_u64_wide(a: __m256i, b: __m256i, m32: __m256i) -> (__m256i, __m256i) {
    let a_hi = _mm256_srli_epi64(a, 32);
    let b_hi = _mm256_srli_epi64(b, 32);
    let ll = _mm256_mul_epu32(a, b); // al·bl
    let lh = _mm256_mul_epu32(a, b_hi); // al·bh
    let hl = _mm256_mul_epu32(a_hi, b); // ah·bl
    let hh = _mm256_mul_epu32(a_hi, b_hi); // ah·bh
    let t = _mm256_add_epi64(
        _mm256_srli_epi64(ll, 32),
        _mm256_add_epi64(_mm256_and_si256(lh, m32), _mm256_and_si256(hl, m32)),
    );
    let lo = _mm256_or_si256(_mm256_and_si256(ll, m32), _mm256_slli_epi64(t, 32));
    let hi = _mm256_add_epi64(
        hh,
        _mm256_add_epi64(
            _mm256_add_epi64(_mm256_srli_epi64(lh, 32), _mm256_srli_epi64(hl, 32)),
            _mm256_srli_epi64(t, 32),
        ),
    );
    (lo, hi)
}

/// Unsigned 64-bit `a > b` lane mask (bias-to-signed compare).
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn gt_u64(a: __m256i, b: __m256i, sign: __m256i) -> __m256i {
    _mm256_cmpgt_epi64(_mm256_xor_si256(a, sign), _mm256_xor_si256(b, sign))
}

/// # Safety
/// Requires AVX2 (guaranteed by `Engine::Avx2` construction).
#[target_feature(enable = "avx2")]
pub unsafe fn sub_sat(a: &[u64], b: &[u64], out: &mut [u64]) {
    debug_assert!(a.len() == b.len() && a.len() == out.len());
    let n = a.len();
    let sign = _mm256_set1_epi64x(i64::MIN);
    let mut i = 0;
    while i + 4 <= n {
        let va = _mm256_loadu_si256(a.as_ptr().add(i) as *const __m256i);
        let vb = _mm256_loadu_si256(b.as_ptr().add(i) as *const __m256i);
        let d = _mm256_sub_epi64(va, vb);
        // Clamp lanes where b > a to zero.
        let r = _mm256_andnot_si256(gt_u64(vb, va, sign), d);
        _mm256_storeu_si256(out.as_mut_ptr().add(i) as *mut __m256i, r);
        i += 4;
    }
    super::scalar::sub_sat(&a[i..], &b[i..], &mut out[i..]);
}

/// # Safety
/// Requires AVX2 (guaranteed by `Engine::Avx2` construction).
#[target_feature(enable = "avx2")]
pub unsafe fn rsub_sat(minuend: u64, v: &mut [u64]) {
    let n = v.len();
    let sign = _mm256_set1_epi64x(i64::MIN);
    let vm = _mm256_set1_epi64x(minuend as i64);
    let mut i = 0;
    while i + 4 <= n {
        let vv = _mm256_loadu_si256(v.as_ptr().add(i) as *const __m256i);
        let d = _mm256_sub_epi64(vm, vv);
        let r = _mm256_andnot_si256(gt_u64(vv, vm, sign), d);
        _mm256_storeu_si256(v.as_mut_ptr().add(i) as *mut __m256i, r);
        i += 4;
    }
    super::scalar::rsub_sat(minuend, &mut v[i..]);
}

/// # Safety
/// Requires AVX2 (guaranteed by `Engine::Avx2` construction).
#[target_feature(enable = "avx2")]
pub unsafe fn add_wrapping(acc: &mut [u64], x: &[u64]) {
    debug_assert_eq!(acc.len(), x.len());
    let n = acc.len();
    let mut i = 0;
    while i + 4 <= n {
        let va = _mm256_loadu_si256(acc.as_ptr().add(i) as *const __m256i);
        let vx = _mm256_loadu_si256(x.as_ptr().add(i) as *const __m256i);
        let r = _mm256_add_epi64(va, vx);
        _mm256_storeu_si256(acc.as_mut_ptr().add(i) as *mut __m256i, r);
        i += 4;
    }
    super::scalar::add_wrapping(&mut acc[i..], &x[i..]);
}

/// # Safety
/// Requires AVX2 (guaranteed by `Engine::Avx2` construction).
#[target_feature(enable = "avx2")]
pub unsafe fn fill_add(base: u64, x: &[u64], out: &mut [u64]) {
    debug_assert_eq!(x.len(), out.len());
    let n = x.len();
    let vb = _mm256_set1_epi64x(base as i64);
    let mut i = 0;
    while i + 4 <= n {
        let vx = _mm256_loadu_si256(x.as_ptr().add(i) as *const __m256i);
        let r = _mm256_add_epi64(vb, vx);
        _mm256_storeu_si256(out.as_mut_ptr().add(i) as *mut __m256i, r);
        i += 4;
    }
    super::scalar::fill_add(base, &x[i..], &mut out[i..]);
}

/// The compare loop shared by [`segment_counts`] (edges staged on the
/// stack per call) and [`segment_counts_prebiased`] (edges staged once
/// in a [`super::BiasedEdges`] cache): count how many biased edges each
/// lane is at-or-above, clamped to the last segment.
///
/// # Safety
/// Requires AVX2 (callers are themselves `#[target_feature(avx2)]`).
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn count_segments_biased(x: &[u64], edges: &[u64], biased: &[u64], idx: &mut [u64]) {
    debug_assert_eq!(x.len(), idx.len());
    debug_assert_eq!(edges.len(), biased.len());
    debug_assert!(!edges.is_empty());
    let n = x.len();
    let sign = _mm256_set1_epi64x(i64::MIN);
    let ones = _mm256_set1_epi64x(-1);
    let last = _mm256_set1_epi64x((edges.len() - 1) as i64);
    let mut i = 0;
    while i + 4 <= n {
        let xv = _mm256_loadu_si256(x.as_ptr().add(i) as *const __m256i);
        let xb = _mm256_xor_si256(xv, sign);
        let mut cnt = _mm256_setzero_si256();
        for &eb in biased {
            // One broadcast from the cached biased word per edge —
            // x ≥ e ⇔ !(e > x); the ≥ mask is −1 per true lane, so
            // subtracting it increments the count.
            let ebv = _mm256_set1_epi64x(eb as i64);
            let ge = _mm256_andnot_si256(_mm256_cmpgt_epi64(ebv, xb), ones);
            cnt = _mm256_sub_epi64(cnt, ge);
        }
        // Lanes at/above the last edge clamp to the last segment. The
        // counts are tiny positive integers, so the signed compare is
        // exact here.
        let over = _mm256_cmpgt_epi64(cnt, last);
        let r = _mm256_blendv_epi8(cnt, last, over);
        _mm256_storeu_si256(idx.as_mut_ptr().add(i) as *mut __m256i, r);
        i += 4;
    }
    super::scalar::segment_counts(&x[i..], edges, &mut idx[i..]);
}

/// [`segment_counts`] with the sign-bias of every edge precomputed
/// (`biased[k] = edges[k] ^ 2^63`, staged by [`super::BiasedEdges`]) —
/// the per-call edge setup drops out entirely, and there is no table
/// size limit because nothing is staged on the stack.
///
/// # Safety
/// Requires AVX2 (guaranteed by `Engine::Avx2` construction).
#[target_feature(enable = "avx2")]
pub unsafe fn segment_counts_prebiased(x: &[u64], edges: &[u64], biased: &[u64], idx: &mut [u64]) {
    count_segments_biased(x, edges, biased, idx);
}

/// Biased-edge staging capacity: any realistic PLA table has ≤ 64
/// segments (Table I has 8; even the n=2 derivation stays far below);
/// larger tables fall back to the scalar path rather than grow stacks.
const MAX_EDGES: usize = 64;

/// # Safety
/// Requires AVX2 (guaranteed by `Engine::Avx2` construction).
#[target_feature(enable = "avx2")]
pub unsafe fn segment_counts(x: &[u64], edges: &[u64], idx: &mut [u64]) {
    debug_assert_eq!(x.len(), idx.len());
    debug_assert!(!edges.is_empty());
    if edges.len() > MAX_EDGES {
        return super::scalar::segment_counts(x, edges, idx);
    }
    // Stage the biased edges on the stack — the per-call setup the
    // cached path (`segment_counts_prebiased`) exists to amortize.
    let mut biased = [0u64; MAX_EDGES];
    for (b, &e) in biased.iter_mut().zip(edges) {
        *b = e ^ (1u64 << 63);
    }
    count_segments_biased(x, edges, &biased[..edges.len()], idx);
}
