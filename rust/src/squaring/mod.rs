//! The squaring unit (paper §5, eq 28, Fig 5).
//!
//! Squaring a number through the ILM decomposition collapses the
//! two-operand machinery: with `N = 2^k + r`,
//!
//! `N² = 4^k + 2^(k+1)·r + r²`,
//!
//! so one priority encoder, one LOD and one adder/shifter pair suffice
//! (the paper's "< 50 % hardware" claim, quantified in
//! [`crate::hw::units`]). The correction term `r²` is again a square, so
//! the same block iterates, exactly like the ILM.
//!
//! `4^k` needs no decoder: it is `0b100 << …` — a shift of a constant
//! (paper §5).

use crate::ilm::priority_encode;
use crate::simd::Engine;

/// Outcome of a squaring-unit evaluation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SquareResult {
    pub square: u128,
    /// Correction stages executed.
    pub stages: u32,
    /// True when the result is exactly `n²`.
    pub exact: bool,
}

/// One basic squaring block: approximate `n²` by `4^k + 2^(k+1)·r`,
/// returning the residue whose square is the error term.
#[inline]
pub fn basic_square_block(n: u64) -> (u128, u64) {
    debug_assert!(n != 0);
    let (k, r) = priority_encode(n);
    let p0 = (1u128 << (2 * k)) + ((r as u128) << (k + 1));
    (p0, r)
}

/// Squaring-unit evaluation of `n²` with at most `iterations` correction
/// stages. `iterations = 0` is the Mitchell-style basic approximation.
pub fn ilm_square(n: u64, iterations: u32) -> SquareResult {
    if n == 0 {
        return SquareResult {
            square: 0,
            stages: 0,
            exact: true,
        };
    }
    let (mut acc, mut r) = basic_square_block(n);
    let mut stages = 0;
    while stages < iterations {
        if r == 0 {
            return SquareResult {
                square: acc,
                stages,
                exact: true,
            };
        }
        let (p, nr) = basic_square_block(r);
        acc += p;
        r = nr;
        stages += 1;
    }
    SquareResult {
        square: acc,
        stages,
        exact: r == 0,
    }
}

/// Exact square via the unit (enough stages for any u64: ≤ 63).
#[inline]
pub fn ilm_square_exact(n: u64) -> u128 {
    ilm_square(n, 64).square
}

/// Fixed-point square: Q(m.f) input, 2f-bit product truncated to f.
#[inline]
pub fn ilm_square_fixed(a: u64, frac_bits: u32, iterations: u32) -> u64 {
    (ilm_square(a, iterations).square >> frac_bits) as u64
}

/// Lane-array fixed-point squares:
/// `out[i] = ilm_square_fixed(a[i], frac_bits, iterations)` — the
/// squaring unit driven across a whole kernel tile at once, restructured
/// for the explicit lane engine ([`crate::simd`]): instead of iterating
/// the correction recursion per lane, every correction **stage** runs as
/// one pass over the tile — first the priority-encoder pass
/// ([`Engine::priority_encode_batch`], vectorized on AVX-512/NEON),
/// then the eq-28 assembly — so the inner loops are branch-light and
/// lane-parallel. Per lane the executed
/// operation sequence is exactly [`ilm_square`]'s (settled lanes skip
/// their remaining stages, as the scalar early-out does), so results are
/// bit-identical; the unit test pins this per engine.
pub fn ilm_square_fixed_batch(
    eng: Engine,
    a: &[u64],
    frac_bits: u32,
    iterations: u32,
    out: &mut [u64],
) {
    debug_assert_eq!(a.len(), out.len());
    const W: usize = 16;
    let mut k = [0u32; W];
    let mut r = [0u64; W];
    let mut acc = [0u128; W];
    let mut done = 0;
    while done < a.len() {
        let n = (a.len() - done).min(W);
        let ac = &a[done..done + n];
        // Stage 0 — the basic block (eq 28) over the tile; zero lanes
        // are settled immediately (N² = 0).
        eng.priority_encode_batch(ac, &mut k[..n], &mut r[..n]);
        for j in 0..n {
            acc[j] = if ac[j] == 0 {
                0
            } else {
                (1u128 << (2 * k[j])) + ((r[j] as u128) << (k[j] + 1))
            };
        }
        // Correction stages: r² is again a square, so the same pass
        // iterates until the budget runs out or every residue is zero.
        for _stage in 0..iterations {
            if r[..n].iter().all(|&v| v == 0) {
                break;
            }
            let prev = r;
            eng.priority_encode_batch(&prev[..n], &mut k[..n], &mut r[..n]);
            for j in 0..n {
                if prev[j] != 0 {
                    acc[j] += (1u128 << (2 * k[j])) + ((r[j] as u128) << (k[j] + 1));
                }
            }
        }
        for (o, &s) in out[done..done + n].iter_mut().zip(acc[..n].iter()) {
            *o = (s >> frac_bits) as u64;
        }
        done += n;
    }
}

/// Relative error of an `iterations`-stage square vs exact.
pub fn square_rel_error(n: u64, iterations: u32) -> f64 {
    if n == 0 {
        return 0.0;
    }
    let exact = (n as u128) * (n as u128);
    let approx = ilm_square(n, iterations).square;
    (exact - approx) as f64 / exact as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::check_that;
    use crate::ilm::ilm_mul;
    use crate::util::check::{forall, Config};

    #[test]
    fn zero_and_powers_of_two() {
        assert_eq!(ilm_square(0, 0).square, 0);
        for k in 0..32 {
            let n = 1u64 << k;
            let r = ilm_square(n, 0);
            assert_eq!(r.square, (n as u128) * (n as u128));
            assert!(r.exact);
        }
    }

    #[test]
    fn small_known_case() {
        // 3² : k=1, r=1 → P0 = 4 + 4 = 8; correction r²=1 → 9.
        assert_eq!(ilm_square(3, 0).square, 8);
        let r = ilm_square(3, 1);
        assert_eq!(r.square, 9);
        assert!(r.exact);
    }

    #[test]
    fn exhaustive_16bit_exact_with_full_stages() {
        for n in 0u64..(1 << 16) {
            let r = ilm_square(n, 64);
            assert_eq!(r.square, (n as u128) * (n as u128), "n={n}");
            assert!(r.exact);
        }
    }

    #[test]
    fn squaring_unit_matches_ilm_on_equal_operands_every_stage() {
        // The squaring unit is algebraically the ILM with N1 = N2, so the
        // partial sums must agree stage for stage.
        for n in (1u64..(1 << 12)).step_by(17) {
            for iters in 0..6 {
                assert_eq!(
                    ilm_square(n, iters).square,
                    ilm_mul(n, n, iters).product,
                    "n={n} iters={iters}"
                );
            }
        }
    }

    #[test]
    fn property_never_overshoots_and_monotone() {
        forall(Config::named("square monotone under iterations").cases(400), |d| {
            let n = d.range_u64(1, u32::MAX as u64);
            let exact = (n as u128) * (n as u128);
            let mut last = 0u128;
            for i in 0..8 {
                let s = ilm_square(n, i).square;
                check_that!(s >= last, "decreasing at stage {i} for {n}");
                check_that!(s <= exact, "overshoot at stage {i} for {n}");
                last = s;
            }
            Ok(())
        });
    }

    #[test]
    fn property_stage_count_popcount_bound() {
        forall(Config::named("square stage bound").cases(400), |d| {
            let n = d.range_u64(1, u32::MAX as u64);
            let r = ilm_square(n, 64);
            check_that!(r.exact);
            check_that!(r.stages < n.count_ones().max(1));
            Ok(())
        });
    }

    #[test]
    fn fixed_point_square() {
        // 1.5² = 2.25 in Q.16
        let a = 3u64 << 15;
        assert_eq!(ilm_square_fixed(a, 16, 64), 9u64 << 14);
    }

    #[test]
    fn fixed_point_square_batch_matches_scalar() {
        // 37 lanes (not a tile multiple), zeros and mixed magnitudes:
        // the staged recursion must equal the per-lane unit bit for bit
        // on every engine and at every budget, including lanes that
        // settle mid-budget while neighbours keep correcting.
        let mut xs: Vec<u64> =
            vec![0, 1, 3 << 15, (1 << 16) - 1, 77777, 1 << 20, 0, u32::MAX as u64];
        let mut rng = crate::util::rng::Rng::new(13);
        while xs.len() < 37 {
            xs.push(rng.next_u64() >> rng.below(40));
        }
        let mut out = vec![0u64; xs.len()];
        for eng in crate::simd::engines_available() {
            for iters in [0u32, 1, 4, 64] {
                ilm_square_fixed_batch(eng, &xs, 16, iters, &mut out);
                for (i, &x) in xs.iter().enumerate() {
                    assert_eq!(
                        out[i],
                        ilm_square_fixed(x, 16, iters),
                        "{} x={x} iters={iters}",
                        eng.name()
                    );
                }
            }
        }
    }

    #[test]
    fn worst_case_error_matches_mitchell_square() {
        // Basic block drops r² ≤ (2^k − 1)² < 4^k, while n² ≥ 4^k → error
        // ratio < 25 %. Check empirically on 12-bit inputs.
        let mut max_err: f64 = 0.0;
        for n in 1u64..(1 << 12) {
            max_err = max_err.max(square_rel_error(n, 0));
        }
        assert!(max_err < 0.25);
        assert!(max_err > 0.2);
    }
}
