//! E8 — §4 ILM accuracy: relative error of the Iterative Logarithmic
//! Multiplier as a function of the correction-iteration budget
//! (exhaustive at 8 bits, sampled at 16/24 bits), plus throughput.

use tsdiv::harness::{timed_section, Report, Verdict};
use tsdiv::ilm::{ilm_mul, ilm_rel_error, max_stages_for_width};
use tsdiv::util::rng::Rng;
use tsdiv::util::table::{sig, Align, Table};

fn exhaustive_8bit(iters: u32) -> (f64, f64, f64) {
    let mut max_e: f64 = 0.0;
    let mut sum = 0.0;
    let mut exact = 0u64;
    let mut n = 0u64;
    for a in 1u64..256 {
        for b in 1u64..256 {
            let e = ilm_rel_error(a, b, iters);
            max_e = max_e.max(e);
            sum += e;
            exact += (e == 0.0) as u64;
            n += 1;
        }
    }
    (max_e, sum / n as f64, exact as f64 / n as f64)
}

fn sampled(width: u32, iters: u32, samples: u64, seed: u64) -> (f64, f64) {
    let mut rng = Rng::new(seed);
    let mut max_e: f64 = 0.0;
    let mut sum = 0.0;
    let hi = (1u64 << width) - 1;
    for _ in 0..samples {
        let a = rng.range_u64(1, hi);
        let b = rng.range_u64(1, hi);
        let e = ilm_rel_error(a, b, iters);
        max_e = max_e.max(e);
        sum += e;
    }
    (max_e, sum / samples as f64)
}

fn main() {
    println!("\n===== E8: ILM accuracy vs correction iterations (§4) =====\n");

    let mut t = Table::new(
        "8-bit operands, exhaustive (65 025 pairs)",
        &["iterations", "max rel err", "mean rel err", "exact %"],
    )
    .aligns(&[Align::Right; 4]);
    let mut maxes = Vec::new();
    for iters in 0..=7 {
        let (mx, mean, exact) = exhaustive_8bit(iters);
        maxes.push(mx);
        t.row(&[
            iters.to_string(),
            sig(mx, 4),
            sig(mean, 4),
            format!("{:.2}", exact * 100.0),
        ]);
    }
    t.print();

    let mut report = Report::new("ILM invariants (§4 / ref [12])");
    report.row(
        "Mitchell worst case < 25 %",
        "< 0.25",
        &sig(maxes[0], 4),
        if maxes[0] < 0.25 { Verdict::Match } else { Verdict::Mismatch },
    );
    report.row(
        "error shrinks ≳4× per stage",
        "monotone /4",
        &format!("{} → {} → {}", sig(maxes[0], 3), sig(maxes[1], 3), sig(maxes[2], 3)),
        if maxes[1] < maxes[0] / 3.0 && maxes[2] < maxes[1] / 3.0 {
            Verdict::Match
        } else {
            Verdict::Mismatch
        },
    );
    report.row(
        "exact within w−1 stages",
        "err = 0",
        &sig(maxes[7.min(max_stages_for_width(8) as usize)], 4),
        if maxes[7] == 0.0 { Verdict::Match } else { Verdict::Mismatch },
    );
    report.print();

    let mut t = Table::new(
        "wider operands (200k samples each)",
        &["width", "iterations", "max rel err", "mean rel err"],
    )
    .aligns(&[Align::Right; 4]);
    for width in [16u32, 24] {
        for iters in [0u32, 1, 2, 4, 8] {
            let (mx, mean) = sampled(width, iters, 200_000, width as u64 * 31 + iters as u64);
            t.row(&[width.to_string(), iters.to_string(), sig(mx, 4), sig(mean, 4)]);
        }
    }
    t.print();

    // Throughput of the word-level model by budget.
    println!();
    for iters in [0u32, 2, 8] {
        let mut rng = Rng::new(5);
        let ops: Vec<(u64, u64)> = (0..1024)
            .map(|_| (rng.range_u64(1, u32::MAX as u64), rng.range_u64(1, u32::MAX as u64)))
            .collect();
        let m = timed_section(&format!("ilm_mul x1024, {iters} corrections"), || {
            let mut acc = 0u128;
            for &(a, b) in &ops {
                acc ^= ilm_mul(a, b, iters).product;
            }
            tsdiv::util::black_box(acc);
        });
        println!("    = {:.1} M mults/s", m.items_per_sec(1024) / 1e6);
    }
    assert_eq!(report.mismatches(), 0);
}
