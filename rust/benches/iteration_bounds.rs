//! E5 — §3 iteration-count claims: 17 iterations for one segment,
//! "15" for the two-segment √(ab) split (paper value — our eq-(17)
//! solver disagrees, documented), 5 for the Table-I partition.

use tsdiv::harness::{Report, Verdict};
use tsdiv::pla::{
    derive_segments, equal_error_split, error_bound_log2, min_iterations,
    min_iterations_piecewise,
};
use tsdiv::util::table::{Align, Table};

fn main() {
    println!("\n===== E5: minimum Taylor iterations for 53-bit precision =====\n");

    let one_seg = min_iterations(1.0, 2.0, 53).expect("eq-17 converges on [1,2]");
    let p = equal_error_split(1.0, 2.0);
    let two_seg =
        min_iterations_piecewise(&[1.0, p, 2.0], 53).expect("eq-17 converges at the split");
    let bounds_ti = derive_segments(5, 53).expect("Table-I derivation");
    let table_i = min_iterations_piecewise(&bounds_ti, 53).expect("eq-17 converges on Table I");

    let mut report = Report::new("paper §3 iteration counts (eq 17 solver)");
    report.row(
        "1 segment [1,2]",
        "17",
        &one_seg.to_string(),
        if one_seg == 17 { Verdict::Match } else { Verdict::Mismatch },
    );
    report.row(
        "2 segments split at √2",
        "15",
        &two_seg.to_string(),
        if two_seg == 15 {
            Verdict::Match
        } else {
            // Documented discrepancy: eq (17) with per-segment optimal
            // lines gives a smaller bound than the paper's 15 (DESIGN.md E5).
            Verdict::Mismatch
        },
    );
    report.row(
        "8 segments (Table I)",
        "5",
        &table_i.to_string(),
        if table_i == 5 { Verdict::Match } else { Verdict::Mismatch },
    );
    report.print();
    println!(
        "note: the two-segment MISMATCH is a *paper-internal* inconsistency we\n\
         document rather than hide — eq (17) evaluated at the √2 split needs only\n\
         {two_seg} iterations. Both of the paper's other claims reproduce exactly.\n"
    );

    // The full convergence picture: bound (log2) vs iteration count.
    let mut t = Table::new(
        "eq-(17) error bound (log2) by iteration count",
        &["n", "1 seg", "2 seg (worst)", "Table I (worst)"],
    )
    .aligns(&[Align::Right; 4]);
    for n in [0u32, 2, 5, 8, 11, 14, 17, 20] {
        let b1 = error_bound_log2(1.0, 2.0, n);
        let b2 = error_bound_log2(1.0, p, n).max(error_bound_log2(p, 2.0, n));
        let bt = bounds_ti
            .windows(2)
            .map(|w| error_bound_log2(w[0], w[1], n))
            .fold(f64::NEG_INFINITY, f64::max);
        t.row(&[
            n.to_string(),
            format!("{b1:.1}"),
            format!("{b2:.1}"),
            format!("{bt:.1}"),
        ]);
    }
    t.print();

    // Iterations vs segment count tradeoff (the design space behind Table I).
    let mut t = Table::new(
        "partition size ↔ iteration budget (53-bit target)",
        &["derivation n", "segments", "min iterations"],
    )
    .aligns(&[Align::Right; 3]);
    for n in [2u32, 3, 4, 5, 6, 8, 10, 12] {
        let b = derive_segments(n, 53).expect("segment derivation");
        t.row(&[
            n.to_string(),
            (b.len() - 1).to_string(),
            min_iterations_piecewise(&b, 53).expect("iteration bound").to_string(),
        ]);
    }
    t.print();

    assert_eq!(one_seg, 17);
    assert_eq!(table_i, 5);
}
