//! E6 — Figures 4 & 5: the hardware comparison between the ILM basic
//! multiplier and the squaring unit, quantifying the §5 "< 50 %" claim
//! with the NAND2-equivalent cost model.

use tsdiv::harness::{Report, Verdict};
use tsdiv::hw::units::{powering_vs_two_ilm_ratio, squaring_vs_ilm_ratio_total};
use tsdiv::hw::{
    divider_system, ilm_unit, newton_system, pla_unit, powering_unit, squaring_unit,
    squaring_vs_ilm_ratio,
};
use tsdiv::util::table::{sig, Align, Table};

fn main() {
    println!("\n===== E6: Fig 4 vs Fig 5 — ILM vs squaring-unit hardware =====\n");

    // Full bills of materials at the paper-relevant width (one f64-grade
    // significand datapath).
    print!("{}", ilm_unit(53).render());
    println!();
    print!("{}", squaring_unit(53).render());
    println!();

    // The headline ratio across widths.
    let mut t = Table::new(
        "squaring-unit area / ILM area",
        &["width", "datapath ratio", "total ratio (regs+ctl)", "paper claim"],
    )
    .aligns(&[Align::Right, Align::Right, Align::Right, Align::Left]);
    let mut all_under_half = true;
    for w in [16u32, 24, 32, 53, 64] {
        let r = squaring_vs_ilm_ratio(w);
        let rt = squaring_vs_ilm_ratio_total(w);
        all_under_half &= r < 0.5;
        t.row(&[
            w.to_string(),
            format!("{r:.3}"),
            format!("{rt:.3}"),
            "< 0.5 (§5)".to_string(),
        ]);
    }
    t.print();

    let mut report = Report::new("paper hardware claims");
    report.row(
        "§5: squaring < 50 % of ILM (datapath)",
        "< 0.5",
        &format!("{:.3} @ w=53", squaring_vs_ilm_ratio(53)),
        if all_under_half { Verdict::Match } else { Verdict::Mismatch },
    );
    let pr = powering_vs_two_ilm_ratio(53);
    report.row(
        "§6: powering unit ≪ two multipliers",
        "\"little overhead\"",
        &format!("{pr:.3} of 2×ILM"),
        if pr < 0.85 { Verdict::Match } else { Verdict::Mismatch },
    );
    // §5 structural claims.
    let sq = squaring_unit(53);
    report.row(
        "§5: no decoder in squaring unit",
        "0 decoders",
        &format!("{}", sq.count_matching("DEC")),
        if sq.count_matching("DEC") == 0 { Verdict::Match } else { Verdict::Mismatch },
    );
    let ilm = ilm_unit(53);
    report.row(
        "§5: half the PE/LOD/shifter count",
        "2 → 1 each",
        &format!(
            "PE {}→{}, LOD {}→{}, SHIFT {}→{}",
            ilm.count_matching("PE"),
            sq.count_matching("PE"),
            ilm.count_matching("LOD"),
            sq.count_matching("LOD"),
            ilm.count_matching("SHIFT"),
            sq.count_matching("SHIFT")
        ),
        Verdict::Match,
    );
    report.print();

    // System-level roll-up (Fig 7 composition + baselines).
    let mut t = Table::new(
        "system areas at w=60, 8 segments (NAND2-eq gates)",
        &["unit", "datapath area", "total area"],
    )
    .aligns(&[Align::Left, Align::Right, Align::Right]);
    for (name, c) in [
        ("PLA unit", pla_unit(8, 60)),
        ("ILM multiplier (Fig 4)", ilm_unit(60)),
        ("Squaring unit (Fig 5)", squaring_unit(60)),
        ("Powering unit (Fig 6)", powering_unit(60)),
        ("Division unit (Fig 7)", divider_system(8, 60, 11)),
        ("Newton-Raphson system (baseline)", newton_system(8, 60, 11)),
    ] {
        t.row(&[name.to_string(), sig(c.datapath_area(), 6), sig(c.area(), 6)]);
    }
    t.print();
    assert_eq!(report.mismatches(), 0);
}
