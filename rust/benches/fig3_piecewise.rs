//! E4 — Figure 3: the piecewise-linear approximation of 1/x for the
//! Table-I partition (n = 5), including the fixed-point seed-table
//! hardware model's error.

use tsdiv::pla::{derive_segments, m_max, segment_index, y0, SegmentTable};
use tsdiv::harness::timed_section;
use tsdiv::util::table::{sig, Align, Table};

fn main() {
    println!("\n===== E4: Figure 3 — piecewise-linear approximation (n=5 partition) =====\n");
    let bounds = derive_segments(5, 53).expect("Table-I derivation");
    let table = SegmentTable::build(&bounds, 60);

    // Per-segment line parameters + worst seed quality.
    let mut t = Table::new(
        "piecewise lines per segment",
        &["seg", "[a, b)", "slope", "intercept", "m_max (analytic)", "max m (fixed-point)"],
    )
    .aligns(&[Align::Left, Align::Left, Align::Right, Align::Right, Align::Right, Align::Right]);
    for (i, w) in bounds.windows(2).enumerate() {
        let (a, b) = (w[0], w[1]);
        let (slope, intercept) = tsdiv::pla::optimal_line(a, b);
        // Scan the fixed-point seed across the segment.
        let mut worst_m: f64 = 0.0;
        for j in 0..200 {
            let x = a + (b.min(2.0) - a) * (j as f64 + 0.5) / 200.0;
            let yq = table.seed_f64(x);
            worst_m = worst_m.max(1.0 - x * yq);
        }
        t.row(&[
            i.to_string(),
            format!("[{:.5}, {:.5})", a, b),
            sig(slope, 5),
            sig(intercept, 5),
            sig(m_max(a, b), 4),
            sig(worst_m, 4),
        ]);
    }
    t.print();

    // The Fig-3 curve itself: seed vs true reciprocal (sampled rows).
    let mut t = Table::new(
        "Fig 3 series (sampled): piecewise y0 vs 1/x",
        &["x", "segment", "y0 (fixed-point)", "1/x", "seed error"],
    );
    for i in 0..=20 {
        let x = 1.0 + 0.9999 * i as f64 / 20.0;
        let seg = segment_index(&bounds, x);
        let yq = table.seed_f64(x);
        t.row(&[
            format!("{x:.4}"),
            seg.to_string(),
            format!("{yq:.8}"),
            format!("{:.8}", 1.0 / x),
            sig((yq - 1.0 / x).abs(), 3),
        ]);
    }
    t.print();

    // Fixed-point table vs analytic lines: agreement within Q2.60 slack.
    let mut worst_dev: f64 = 0.0;
    for i in 0..2000 {
        let x = 1.0 + 0.999_999 * (i as f64 + 0.5) / 2000.0;
        let seg = segment_index(&bounds, x);
        let analytic = y0(x, bounds[seg], bounds[seg + 1]);
        worst_dev = worst_dev.max((table.seed_f64(x) - analytic).abs());
    }
    println!(
        "max |fixed-point seed − eq(15) line| over 2000 points: {} (Q2.60 ulp = {:.1e})",
        sig(worst_dev, 3),
        2f64.powi(-60)
    );
    assert!(worst_dev < 1e-15);

    println!("seed ROM: {} bits for {} segments", table.rom_bits(), table.num_segments());

    timed_section("fixed-point seed (table lookup + mul-sub)", || {
        let x = tsdiv::util::black_box(5u64 << 58); // 1.25 in Q2.60
        tsdiv::util::black_box(table.seed(x));
    });
}
