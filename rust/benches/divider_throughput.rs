//! E9 — Figure 7 (complete system): accuracy and throughput of the
//! Taylor/ILM divider vs the Newton, Goldschmidt and digit-recurrence
//! baselines, plus the (order × ILM-budget) design-space sweep and the
//! cycle-model latency comparison.

use tsdiv::analysis::{measure_accuracy_f32, Workload};
use tsdiv::divider::{
    goldschmidt::GoldschmidtDivider, longdiv::LongDivider, newton::NewtonDivider, BackendKind,
    Divider, TaylorDivider,
};
use tsdiv::harness::{gen_batch, timed_section};
use tsdiv::hw::{divider_timing, longdiv_timing};
use tsdiv::taylor::TaylorConfig;
use tsdiv::util::table::{sig, Align, Table};

fn main() {
    println!("\n===== E9: Fig 7 — complete divider vs baselines =====\n");

    // Accuracy across workloads (vs exactly-rounded digit recurrence).
    let mut t = Table::new(
        "accuracy vs gold (5 000 samples per cell)",
        &["divider", "workload", "max ulp", "mean ulp", "exact %"],
    )
    .aligns(&[Align::Left, Align::Left, Align::Right, Align::Right, Align::Right]);
    let mk: Vec<Box<dyn Fn() -> Box<dyn Divider>>> = vec![
        Box::new(|| Box::new(TaylorDivider::paper_exact())),
        Box::new(|| Box::new(TaylorDivider::paper_ilm(8))),
        Box::new(|| Box::new(TaylorDivider::paper_ilm(2))),
        Box::new(|| Box::new(NewtonDivider::paper_default())),
        Box::new(|| Box::new(GoldschmidtDivider::paper_default())),
    ];
    for make in &mk {
        for wl in [Workload::LogUniform, Workload::SignificandOnly, Workload::RandomBits] {
            let mut d = make();
            let r = measure_accuracy_f32(d.as_mut(), wl, 5_000, 17);
            t.row(&[
                r.divider.clone(),
                wl.name().to_string(),
                r.max_ulp.to_string(),
                format!("{:.4}", r.mean_ulp),
                format!("{:.2}", r.exact_rate * 100.0),
            ]);
        }
    }
    t.print();

    // Design-space sweep: Taylor order × ILM budget → worst-case ulp.
    let mut t = Table::new(
        "max ulp by (Taylor order × ILM corrections), significand workload",
        &["order", "ilm=1", "ilm=2", "ilm=4", "ilm=8", "exact"],
    )
    .aligns(&[Align::Right; 6]);
    for order in [2u32, 3, 5] {
        let mut row = vec![order.to_string()];
        for budget in [Some(1u32), Some(2), Some(4), Some(8), None] {
            let cfg = TaylorConfig {
                order,
                ..TaylorConfig::paper_default(60)
            };
            let kind = match budget {
                Some(iterations) => BackendKind::Ilm { iterations },
                None => BackendKind::Exact,
            };
            let mut d = TaylorDivider::new(cfg, kind);
            let r = measure_accuracy_f32(&mut d, Workload::SignificandOnly, 2_000, 3);
            row.push(r.max_ulp.to_string());
        }
        t.row(&row);
    }
    t.print();

    // Software-model throughput (the L3 hot path the perf pass optimizes).
    println!();
    let batch = gen_batch(Workload::LogUniform, 4096, 9);
    let mut results = Vec::new();
    for (label, mut d) in [
        ("taylor exact", Box::new(TaylorDivider::paper_exact()) as Box<dyn Divider>),
        ("taylor ilm8", Box::new(TaylorDivider::paper_ilm(8))),
        ("newton", Box::new(NewtonDivider::paper_default())),
        ("goldschmidt", Box::new(GoldschmidtDivider::paper_default())),
        ("longdiv (gold)", Box::new(LongDivider::new())),
    ] {
        let m = timed_section(&format!("{label}: 4096 divisions"), || {
            let mut acc = 0u32;
            for i in 0..batch.len() {
                acc ^= d.div_f32(batch.a[i], batch.b[i]).to_bits();
            }
            tsdiv::util::black_box(acc);
        });
        results.push((label, m.items_per_sec(4096)));
    }
    let mut t = Table::new("word-level model throughput", &["divider", "Mdiv/s"])
        .aligns(&[Align::Left, Align::Right]);
    for (label, thr) in &results {
        t.row(&[label.to_string(), format!("{:.2}", thr / 1e6)]);
    }
    t.print();

    // Cycle-model comparison — the architectural claim the paper makes.
    let mut t = Table::new(
        "hardware cycle model (f64-grade significand, 15 ps gate)",
        &["unit", "latency cycles", "II", "latency ns"],
    )
    .aligns(&[Align::Left, Align::Right, Align::Right, Align::Right]);
    for (label, tm) in [
        ("taylor n=5, ilm 2, iterative", divider_timing(60, 5, 2, false)),
        ("taylor n=5, ilm 2, pipelined (§7)", divider_timing(60, 5, 2, true)),
        ("digit recurrence (1 bit/cycle)", longdiv_timing(52)),
    ] {
        t.row(&[
            label.to_string(),
            tm.latency_cycles.to_string(),
            tm.initiation_interval.to_string(),
            format!("{:.2}", tm.latency_ns(15.0)),
        ]);
    }
    t.print();
    println!(
        "shape check: taylor latency {} cycles < longdiv {} cycles — who-wins matches the paper's motivation",
        divider_timing(60, 5, 2, false).latency_cycles,
        longdiv_timing(52).latency_cycles
    );
    println!("\n(throughput target & perf log: EXPERIMENTS.md §Perf; {} = {})",
        "gold ref", sig(results[4].1 / 1e6, 4));
}
